"""Train a ~100M-param LM for a few hundred steps with early-exit ramps,
with checkpoint/restart — the training-side end-to-end driver.

~100M params: 8 layers x d512 x ff2048, vocab 8192 (+ per-site ramp heads).
On this CPU container that is a few minutes; pass --tiny for a fast pass.

  PYTHONPATH=src python examples/train_ramps_e2e.py [--tiny]
"""
import argparse
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.checkpoint import CheckpointManager
from repro.data import TokenPipeline
from repro.models import build_model
from repro.models.common import param_count
from repro.training import TrainConfig, init_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--steps", type=int, default=0)
args = ap.parse_args()

base = get_config("qwen2-1.5b")
if args.tiny:
    cfg = base.replace(name="lm-tiny", n_layers=4, d_model=128, n_heads=4,
                       n_kv_heads=2, d_ff=512, vocab_size=2048, dtype="float32")
    steps = args.steps or 60
    batch, seq = 8, 64
else:
    cfg = base.replace(name="lm-100m", n_layers=8, d_model=512, n_heads=8,
                       n_kv_heads=4, d_ff=2048, vocab_size=8192, dtype="float32")
    steps = args.steps or 300
    batch, seq = 16, 128

model = build_model(cfg)
print(f"model: {cfg.name}  params={param_count(model.schema())/1e6:.1f}M "
      f"(incl. {len(model.sites)} ramp heads)")

tcfg = TrainConfig(steps=steps, lr=6e-4, warmup=20)
step_fn, opt_cfg = make_train_step(model, tcfg)
jstep = jax.jit(step_fn)
state = init_state(model, jax.random.PRNGKey(0), opt_cfg)
pipe = TokenPipeline(cfg.vocab_size, seq, batch, seed=0)
ckdir = os.path.join(tempfile.gettempdir(), f"ck_{cfg.name}")
mgr = CheckpointManager(ckdir, keep=2)

start = 0
if mgr.latest_step():
    state = mgr.restore()
    start = int(np.asarray(state["step"]))
    print(f"resumed from checkpoint step {start}")

import jax.numpy as jnp

for s in range(start, steps):
    state, out = jstep(state, {k: jnp.asarray(v) for k, v in pipe.batch_at(s).items()})
    if s % max(steps // 10, 1) == 0 or s == steps - 1:
        print(f"step {s:4d}  loss {float(out['loss']):.4f}  "
              f"lm {float(out.get('lm_loss', 0)):.4f}  ramps {float(out.get('ramp_loss', 0)):.4f}")
    if (s + 1) % max(steps // 4, 1) == 0:
        mgr.save_async(state, step=s + 1)  # async: overlaps with compute
mgr.wait()
print(f"checkpoints at {ckdir}: steps {mgr.all_steps()}")
print("per-ramp losses fall with depth (later ramps match the final head better)")
