"""Every assigned architecture decoding with early-exit ramps through the
same API — tiny configs on CPU, exactly the code path the dry-run lowers
at production scale.

  PYTHONPATH=src python examples/multiarch_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_tiny
from repro.models import build_model

key = jax.random.PRNGKey(0)
for arch in ARCH_IDS:
    cfg = get_tiny(arch)
    m = build_model(cfg)
    params = m.init(key)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
    kw = {}
    if cfg.cross_attn_every:
        kw["image_embeds"] = jax.random.normal(key, (B, cfg.n_image_tokens, cfg.d_frontend)) * 0.1
    active = jnp.arange(min(2, max(len(m.sites), 1)), dtype=jnp.int32)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, 24, cfg.d_frontend)) * 0.1
        cache, _ = m.prefill(params, frames, toks[:, :S], cache_len=S + 4, active_sites=active)
        _, outs = m.decode(params, cache, toks[:, S:S + 1], jnp.int32(S), active_sites=active)
    else:
        cache, _ = m.prefill(params, toks[:, :S], cache_len=S + 4, active_sites=active,
                             moe_impl="dense", **kw)
        _, outs = m.decode(params, cache, toks[:, S:S + 1], jnp.int32(S),
                           active_sites=active, moe_impl="dense")
    f = outs["final"]
    r = outs["ramps"]
    print(f"{arch:26s} final tok {np.asarray(f['label'])[0]:4d} p={float(np.asarray(f['maxprob'])[0]):.3f}  "
          f"ramp0 tok {np.asarray(r['label'])[0,0]:4d} p={float(np.asarray(r['maxprob'])[0,0]):.3f}  "
          f"agree={bool(np.asarray(r['label'])[0,0] == np.asarray(f['label'])[0])}")
print("\nall 10 assigned architectures decode with EE ramps through one API")
