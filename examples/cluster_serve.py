"""Scale-out serving demo: the same bursty trace served by 1 worker vs an
N-worker cluster at equal SLO. The single replica saturates and sheds its
goodput; the cluster absorbs the burst while every replica's Apparate
controller independently keeps its ramp overhead within the budget.

  PYTHONPATH=src python examples/cluster_serve.py --workers 4
  PYTHONPATH=src python examples/cluster_serve.py --workers 4 --dispatch slo_aware
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.serving import (
    ClusterConfig,
    ClusterSimulator,
    PlatformConfig,
    SyntheticRunner,
    make_requests,
    maf_trace,
    summarize_cluster,
)


def run_cluster(prof, reqs, n_workers, *, dispatch="jsq", budget=0.02, slots=4):
    ns = len(prof.sites)
    pf = PlatformConfig(policy="tfserve", max_batch_size=8,
                        batch_timeout_ms=prof.vanilla_time(1))
    ctls = [
        ApparateController(ns, prof, ControllerConfig(max_slots=slots, ramp_budget_frac=budget))
        for _ in range(n_workers)
    ]
    sim = ClusterSimulator(
        prof,
        ClusterConfig(n_workers=n_workers, dispatch=dispatch, platform=pf),
        runner=SyntheticRunner(ns, exit_site=ns // 3),
        controllers=ctls,
    )
    resp = sim.run(reqs)
    return sim, ctls, summarize_cluster(resp, horizon_ms=sim.makespan_ms,
                                        n_workers=n_workers)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--dispatch", default="jsq",
                    choices=["round_robin", "jsq", "slo_aware"])
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--load", type=float, default=0.6, help="offered load per cluster worker")
    ap.add_argument("--budget", type=float, default=0.02)
    args = ap.parse_args(argv)
    if args.workers < 1:
        ap.error("--workers must be >= 1")

    prof = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)
    exec1 = prof.vanilla_time(1)
    # one worker's saturation throughput at full batches (batching amortizes
    # memory-bound decode, so capacity is mbs/exec(mbs), not 1/exec(1))
    mbs = 8
    qps_cap = mbs * 1000.0 / prof.vanilla_time(mbs)
    # offered load sized for the full cluster: one worker is underwater
    arr = maf_trace(args.n, mean_qps=args.workers * args.load * qps_cap, seed=7)
    reqs = make_requests(arr, slo_ms=3 * exec1)

    out = {"trace": {"n": args.n, "slo_ms": 3 * exec1,
                     "mean_qps": args.n / (arr[-1] / 1000.0)}}
    for nw in sorted({1, args.workers}):
        sim, ctls, summary = run_cluster(prof, reqs, nw, dispatch=args.dispatch,
                                         budget=args.budget)
        lim = args.budget * prof.vanilla_time(1)
        out[f"{nw}_worker"] = {
            "aggregate": summary["aggregate"],
            "per_worker_goodput_qps": [w.get("goodput_qps", 0.0) for w in summary["workers"].values()],
            "ramp_overhead_ms": [c.total_ramp_overhead(1) for c in ctls],
            "ramp_budget_ok": all(c.total_ramp_overhead(1) <= lim + 1e-9 for c in ctls),
            "worker_busy_frac": [
                s["busy_ms"] / sim.makespan_ms for s in sim.worker_stats().values()
            ],
        }
    g1 = out["1_worker"]["aggregate"].get("goodput_qps", 0.0)
    gn = out[f"{args.workers}_worker"]["aggregate"].get("goodput_qps", 0.0)
    out["goodput_scaleup"] = gn / max(g1, 1e-9)
    print(json.dumps(out, indent=1, default=float))
    return out


if __name__ == "__main__":
    main()
