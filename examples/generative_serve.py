"""Generative decode serving demo: the paper's third headline result
(§5, Table 4 — 22.6–77.9% lower median time-per-token) through the
continuous-batching decode engine with per-token early exits and KV
catch-up accounting.

Default is the profile-only synthetic runner (fast, deterministic);
``--real`` trains a tiny LM on CPU and drives ``model.decode`` with a
live cache through ``DecodeRunner`` (a few minutes). ``--mixed`` also
shows generative and classification replicas coexisting in one cluster.

  PYTHONPATH=src python examples/generative_serve.py
  PYTHONPATH=src python examples/generative_serve.py --real
  PYTHONPATH=src python examples/generative_serve.py --mixed
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.serving import (
    ClusterConfig,
    ClusterSimulator,
    GenerativeConfig,
    GenerativeEngine,
    MixedClusterSimulator,
    PlatformConfig,
    SyntheticDecodeRunner,
    SyntheticRunner,
    offered_decode_qps,
    make_gen_requests,
    make_requests,
    maf_trace,
    summarize,
    summarize_generative,
)


def synthetic_generative(n=150, tokens=24, mbs=8, load=0.6, easy_frac=0.7, seed=3,
                         budget=0.02, acc=0.99):
    """Vanilla vs Apparate decode on the GPT-2 generative profile
    (full-vocab head, tied ramps, KV catch-up charged)."""
    prof = build_profile(get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied"),
                         mode="decode", chips=1, charge_kv=True)
    ns = len(prof.sites)
    qps = offered_decode_qps(prof, max_batch_size=mbs, tokens_per_request=tokens, load=load)
    arr = maf_trace(n, mean_qps=qps, seed=seed)
    reqs = make_gen_requests(arr, n_tokens=tokens, prompt_len=128,
                             slo_ms=3 * prof.vanilla_time(1))
    gcfg = GenerativeConfig(max_batch_size=mbs)
    base_eng = GenerativeEngine(prof, gcfg)
    mb = summarize_generative(base_eng.run(reqs), horizon_ms=base_eng.makespan_ms)
    ctl = ApparateController(ns, prof, ControllerConfig(
        max_slots=4, ramp_budget_frac=budget, acc_constraint=acc))
    eng = GenerativeEngine(prof, gcfg, SyntheticDecodeRunner(ns, exit_site=ns // 3,
                                                            easy_frac=easy_frac), ctl)
    mo = summarize_generative(eng.run(reqs), horizon_ms=eng.makespan_ms)
    return {
        "vanilla": mb,
        "apparate": mo,
        # 0-TPT-sample streams (single-token requests) report 0.0, not NaN
        "tpt_p50_win_pct": (
            100.0 * (mb["tpt_p50_ms"] - mo["tpt_p50_ms"]) / mb["tpt_p50_ms"]
            if mb["tpt_p50_ms"] > 0 else 0.0
        ),
        "engine": eng.stats(),
        "active_ramps": list(map(int, ctl.active)),
    }


def mixed_cluster(seed=5):
    """Generative decode replicas + classification replicas in one cluster:
    the heterogeneous-replica axis the ROADMAP names."""
    gen_prof = build_profile(get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied"),
                             mode="decode", chips=1, charge_kv=True)
    cls_prof = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)
    ns_g, ns_c = len(gen_prof.sites), len(cls_prof.sites)
    # classification pool: 2 workers, own controllers
    pf = PlatformConfig(policy="tfserve", max_batch_size=8,
                        batch_timeout_ms=cls_prof.vanilla_time(1))
    cls_ctls = [ApparateController(ns_c, cls_prof, ControllerConfig(max_slots=4))
                for _ in range(2)]
    cls_sim = ClusterSimulator(
        cls_prof, ClusterConfig(n_workers=2, dispatch="jsq", platform=pf),
        runner=SyntheticRunner(ns_c, exit_site=ns_c // 3), controllers=cls_ctls,
    )
    # generative pool: 2 decode replicas, own controllers
    gen_engines = []
    for _ in range(2):
        ctl = ApparateController(ns_g, gen_prof, ControllerConfig(max_slots=4))
        gen_engines.append(GenerativeEngine(
            gen_prof, GenerativeConfig(max_batch_size=8),
            SyntheticDecodeRunner(ns_g, exit_site=ns_g // 3), ctl))
    mixed = MixedClusterSimulator(cls_sim, gen_engines)
    exec1 = cls_prof.vanilla_time(1)
    cls_reqs = make_requests(maf_trace(400, mean_qps=0.8 * 1000.0 / exec1, seed=seed),
                             slo_ms=3 * exec1)
    gen_qps = 2 * offered_decode_qps(gen_prof, max_batch_size=8, tokens_per_request=24, load=0.8)
    gen_reqs = make_gen_requests(
        maf_trace(80, mean_qps=gen_qps, seed=seed + 1),
        n_tokens=24, prompt_len=128, slo_ms=3 * gen_prof.vanilla_time(1))
    cls_resp, gen_resp = mixed.run(cls_reqs, gen_reqs)
    return {
        "classification": summarize(cls_resp, horizon_ms=mixed.makespan_ms),
        "generative": summarize_generative(gen_resp, horizon_ms=mixed.makespan_ms),
        "gen_per_worker_tokens": [e.n_tokens for e in gen_engines],
        "makespan_ms": mixed.makespan_ms,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=150)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--load", type=float, default=0.6)
    ap.add_argument("--easy-frac", type=float, default=0.7)
    ap.add_argument("--real", action="store_true",
                    help="train a tiny LM and drive model.decode (slow)")
    ap.add_argument("--mixed", action="store_true",
                    help="also run the heterogeneous (CV+generative) cluster")
    args = ap.parse_args(argv)
    if args.real:
        from repro.launch.serve import serve_generative

        out = serve_generative(args.n, decode_tokens=args.tokens, load=args.load,
                               verbose=False)
    else:
        out = synthetic_generative(args.n, tokens=args.tokens, load=args.load,
                                   easy_frac=args.easy_frac)
    if args.mixed:
        out["mixed_cluster"] = mixed_cluster()
    win = out["tpt_p50_win_pct"]
    agree = out["apparate"]["agreement"]
    out["headline"] = (
        f"median TPT win {win:.1f}% at agreement {agree:.3f} "
        f"(KV catch-up charged: {out['engine']['kv_catchup_ms']:.2f} ms total)"
    )
    print(json.dumps(out, indent=1, default=float))
    return out


if __name__ == "__main__":
    main()
