"""End-to-end serving driver (deliverable b): batched requests through a
real serving loop — queueing, SLO-aware batching, Apparate early exits,
continual adaptation — vs the vanilla baseline.

  PYTHONPATH=src python examples/serve_stream.py --domain cv
  PYTHONPATH=src python examples/serve_stream.py --domain nlp --policy clockwork
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
