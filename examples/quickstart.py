"""Quickstart: inject early exits into a model, train ramps (backbone
frozen), and watch the controller manage thresholds on a drifting stream.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.configs import get_bench, get_config
from repro.core import ApparateController, ControllerConfig, build_profile, evaluate_config
from repro.core.ramps import describe
from repro.data import make_image_stream
from repro.models import build_model
from repro.serving import ClassifierRunner
from repro.training import TrainConfig, train, train_ramps

# 1. Build a model; ramp sites = cut vertices (block boundaries).
cfg = get_bench("resnet18").replace(n_classes=10)
model = build_model(cfg)
print(describe(model))

# 2. Train the backbone on bootstrap data, then ramps only (frozen backbone).
stream = make_image_stream(2000, img_size=cfg.img_size, n_classes=10, mode="cv", seed=2)


def batches(s):
    rng = np.random.default_rng(s)
    idx = rng.integers(0, 200, 64)
    return {"images": stream.data[idx], "labels": stream.labels[idx]}


print("training backbone + ramps (paper trains ramps with backbone frozen;")
print("full joint training here for speed, then a frozen-ramp refinement):")
state, _ = train(model, batches, TrainConfig(steps=100, lr=3e-3, log_every=50))
state, _ = train_ramps(model, batches, steps=30, state=state)

# 3. Serve: the controller ingests per-ramp records and adapts.
prof = build_profile(
    get_config("resnet18").replace(resnet_widths=(64, 128, 256, 512), img_size=224),
    mode="decode",
)
runner = ClassifierRunner(model, state["params"], stream.data, max_slots=6)
ctl = ApparateController(len(model.sites), prof, ControllerConfig(max_slots=6))
print(f"\ninitial ramps {ctl.active} thresholds all 0 (no exits yet)")
for lo in range(200, 2000, 16):
    idx = np.arange(lo, min(lo + 16, 2000))
    labels, unc, final = runner.infer(idx, sorted(ctl.active))
    ctl.observe(labels, unc, final)
wd = ctl.window.last(512)
ev = evaluate_config(wd, ctl.thresholds, ctl.active, prof)
print(f"after 1800 samples: active={ctl.active}")
print(f"  thresholds={np.round(ctl.thresholds[sorted(ctl.active)], 3)}")
print(f"  window accuracy {ev.accuracy:.3f} | exit rate {ev.exit_rate:.2f} "
      f"| mean latency saved {ev.mean_saved_ms:.3f} ms of {prof.vanilla_time(1):.3f} ms")
print(f"  controller: {ctl.stats}")
