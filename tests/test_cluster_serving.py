"""Scale-out serving engine invariants: dispatch conservation, release-
offset physics, and the ROADMAP scenario — N workers beat 1 worker on
goodput under burst while every replica honors the ramp budget."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.serving import (
    ClusterConfig,
    ClusterSimulator,
    PlatformConfig,
    ServingSimulator,
    SyntheticRunner,
    make_requests,
    maf_trace,
    release_offset,
    summarize,
    summarize_cluster,
)

PROF = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)
NS = len(PROF.sites)


def _reqs(n=300, qps_scale=0.5, slo_mult=3.0, seed=0):
    # scale against *batched* capacity so overload factors mean what they say
    mbs = 8
    cap = mbs * 1000.0 / PROF.vanilla_time(mbs)
    arr = maf_trace(n, mean_qps=qps_scale * cap, seed=seed)
    return make_requests(arr, slo_ms=slo_mult * PROF.vanilla_time(1))


def _cluster(n_workers, dispatch="jsq", policy="tfserve", runner=None, ctls=None,
             drop=False):
    pf = PlatformConfig(policy=policy, max_batch_size=8,
                        batch_timeout_ms=PROF.vanilla_time(1), drop_on_slo_miss=drop)
    return ClusterSimulator(
        PROF, ClusterConfig(n_workers=n_workers, dispatch=dispatch, platform=pf),
        runner=runner, controllers=ctls,
    )


# -- conservation -------------------------------------------------------------


@pytest.mark.parametrize("seed,dispatch", list(enumerate(["round_robin", "jsq", "slo_aware"])))
@pytest.mark.parametrize("n_workers", [1, 3])
def test_conservation_every_request_answered_once(seed, dispatch, n_workers):
    reqs = _reqs(n=250, qps_scale=1.5, seed=seed)
    sim = _cluster(n_workers, dispatch)
    resp = sim.run(reqs)
    # exactly one response per request
    assert sorted(r.rid for r in resp) == list(range(250))
    by_rid = {r.rid: r for r in resp}
    for q in reqs:
        r = by_rid[q.rid]
        # causality: nothing is answered before it arrives
        assert r.release_ms >= q.arrival_ms - 1e-9
        assert 0 <= r.worker < n_workers
    # each worker's busy time fits in the makespan (no overlapping batches)
    for wid, st in sim.worker_stats().items():
        assert st["busy_ms"] <= sim.makespan_ms + 1e-6


def test_clockwork_drop_conservation():
    reqs = _reqs(n=200, qps_scale=2.5, slo_mult=1.2, seed=3)
    sim = _cluster(2, "jsq", policy="clockwork", drop=True)
    resp = sim.run(reqs)
    assert sorted(r.rid for r in resp) == list(range(200))  # drops still answer
    served = [r for r in resp if not r.dropped]
    viol = [r for r in served if r.latency_ms > r.slo_ms + 1e-6]
    assert len(viol) / max(len(served), 1) < 0.02


def test_single_worker_cluster_matches_serving_simulator():
    """ServingSimulator IS the 1-worker special case — byte-identical runs."""
    reqs = _reqs(n=200, qps_scale=0.8, seed=5)
    pf = PlatformConfig(policy="tfserve", max_batch_size=8,
                        batch_timeout_ms=PROF.vanilla_time(1))
    a = ServingSimulator(PROF, pf).run(reqs)
    b = _cluster(1).run(reqs)
    assert [(r.rid, r.release_ms, r.batch_size) for r in a] == [
        (r.rid, r.release_ms, r.batch_size) for r in b
    ]


# -- release offset physics ---------------------------------------------------


@pytest.mark.parametrize("bs", [1, 4, 16])
def test_release_offset_monotone_and_bounded(bs):
    """Regression: the exit-release offset is monotone in exit site and
    never exceeds the full-batch execution time (trunk + all ramps)."""
    sim = ServingSimulator(PROF, PlatformConfig())
    for active in ([0], [0, NS // 2, NS - 1], list(range(0, NS, 3))):
        offs = [sim._release_offset(s, bs, active) for s in range(NS)]
        assert all(b >= a - 1e-12 for a, b in zip(offs, offs[1:]))
        full = PROF.vanilla_time(bs) + sum(PROF.ramp_overhead(s, bs) for s in active)
        assert all(o <= full + 1e-9 for o in offs)
        # module-level helper agrees with the simulator method
        assert offs == [release_offset(PROF, s, bs, active) for s in range(NS)]


# -- dispatchers --------------------------------------------------------------


def test_round_robin_spreads_requests_evenly():
    reqs = _reqs(n=300, qps_scale=1.0, seed=1)
    resp = _cluster(3, "round_robin").run(reqs)
    counts = np.bincount([r.worker for r in resp], minlength=3)
    assert counts.tolist() == [100, 100, 100]


def test_jsq_balances_busy_time_under_burst():
    reqs = _reqs(n=400, qps_scale=2.0, seed=2)
    sim = _cluster(4, "jsq")
    sim.run(reqs)
    busy = np.asarray([st["busy_ms"] for st in sim.worker_stats().values()])
    assert busy.min() > 0.5 * busy.max()  # no idle replica while others drown


def test_bad_config_raises():
    with pytest.raises(ValueError):
        _cluster(2, dispatch="nope").run(_reqs(n=4))
    with pytest.raises(ValueError):
        ClusterSimulator(PROF, ClusterConfig(n_workers=2),
                         controllers=[None])  # one controller for two workers
    with pytest.raises(ValueError):
        ServingSimulator(PROF, PlatformConfig(policy="unknown")).run(_reqs(n=4))


# -- the ROADMAP scale-out scenario ------------------------------------------


def test_scaleout_goodput_beats_single_worker_within_budget():
    """4 workers on the bursty synthetic trace: strictly higher goodput than
    1 worker at equal SLO, with every worker's ramp overhead inside
    `ramp_budget_frac` and every controller adapting from its own stream."""
    reqs = _reqs(n=1200, qps_scale=4 * 0.6, slo_mult=3.0, seed=7)
    budget = 0.02
    results = {}
    for nw in (1, 4):
        ctls = [
            ApparateController(NS, PROF, ControllerConfig(max_slots=4, ramp_budget_frac=budget))
            for _ in range(nw)
        ]
        sim = _cluster(nw, "jsq", runner=SyntheticRunner(NS, exit_site=NS // 3), ctls=ctls)
        resp = sim.run(reqs)
        assert sorted(r.rid for r in resp) == list(range(1200))
        m = summarize(resp, horizon_ms=sim.makespan_ms)
        lim = budget * PROF.vanilla_time(1) + 1e-9
        assert all(c.total_ramp_overhead(1) <= lim for c in ctls)
        if nw > 1:  # each replica adapted from its own record stream
            assert all(c.stats["samples"] > 0 for c in ctls)
        results[nw] = m
    assert results[4]["goodput_qps"] > results[1]["goodput_qps"]
    assert results[4]["slo_miss_rate"] < results[1]["slo_miss_rate"]


def test_summarize_cluster_per_worker_rates_sum_to_aggregate():
    reqs = _reqs(n=400, qps_scale=1.5, seed=4)
    sim = _cluster(4, "round_robin")
    resp = sim.run(reqs)
    rep = summarize_cluster(resp, horizon_ms=sim.makespan_ms)
    agg = rep["aggregate"]
    assert agg["n_workers"] == 4
    per = sum(w["throughput_qps"] for w in rep["workers"].values())
    np.testing.assert_allclose(per, agg["throughput_qps"], rtol=1e-9)
    per_good = sum(w["goodput_qps"] for w in rep["workers"].values())
    np.testing.assert_allclose(per_good, agg["goodput_qps"], rtol=1e-9)
