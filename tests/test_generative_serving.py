"""Generative decode serving: engine invariants (token conservation, TPT
monotonicity in exit rate, slot-based continuous batching), KV catch-up
accounting, the mixed heterogeneous cluster, and a real-model DecodeRunner
smoke. Property tests draw cases from seeded numpy generators (suite
policy: stdlib + numpy + jax + pytest only)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, get_tiny
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.core.controller import BatchDecisions
from repro.serving import (
    ClusterConfig,
    ClusterSimulator,
    GenerativeConfig,
    GenerativeEngine,
    MixedClusterSimulator,
    PlatformConfig,
    SyntheticDecodeRunner,
    SyntheticRunner,
    make_gen_requests,
    make_requests,
    maf_trace,
    offered_decode_qps,
    summarize_generative,
)

PROF = build_profile(
    get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied"),
    mode="decode", chips=1, charge_kv=True,
)
NS = len(PROF.sites)


def _gen_reqs(n=40, tokens=16, mbs=8, load=0.7, seed=0, jitter_tokens=False):
    qps = offered_decode_qps(PROF, max_batch_size=mbs, tokens_per_request=tokens, load=load)
    arr = maf_trace(n, mean_qps=qps, seed=seed)
    nt = tokens
    if jitter_tokens:
        rng = np.random.default_rng(seed)
        nt = rng.integers(1, 2 * tokens, n)
    return make_gen_requests(arr, n_tokens=nt, prompt_len=64,
                             slo_ms=3 * PROF.vanilla_time(1))


class _StubController:
    """Deterministic exit pattern: a fixed fraction of decode tokens exits
    at one site (isolates the engine's timing model from adaptation)."""

    def __init__(self, site: int, rate: float):
        self.active = [site]
        self.site, self.rate = site, rate
        self._i = 0

    def observe(self, labels, unc, finals):
        B = len(finals)
        ex = np.full(B, -1, np.int64)
        for b in range(B):
            self._i += 1
            if (self._i * 2654435761 % 100) < self.rate * 100:
                ex[b] = self.site
        return BatchDecisions(ex, np.asarray(finals).copy(), ex >= 0)

    def total_ramp_overhead(self, bs: int = 1) -> float:
        return 0.0


# -- profile physics ----------------------------------------------------------


def test_decode_step_time_no_exits_equals_vanilla():
    for B in (1, 4, 8):
        st = PROF.decode_step_time([-1] * B, [])
        np.testing.assert_allclose(st, PROF.vanilla_time(B), rtol=1e-12)


@pytest.mark.parametrize("seed", range(6))
def test_decode_step_time_monotone_in_exits(seed):
    """Exiting strictly earlier (or more tokens) never makes a step slower."""
    rng = np.random.default_rng(seed)
    B = 8
    ex = rng.integers(-1, NS, B)
    base = PROF.decode_step_time(ex, [])
    # promote one random non-exit to an exit -> no slower
    j = int(rng.integers(B))
    ex2 = ex.copy()
    ex2[j] = int(rng.integers(NS)) if ex2[j] < 0 else max(ex2[j] - 1, 0)
    assert PROF.decode_step_time(ex2, []) <= base + 1e-12


def test_kv_fill_cost_decreases_with_depth_and_never_free():
    costs = [PROF.kv_fill_cost(s, 1) for s in range(NS)]
    assert all(b <= a + 1e-15 for a, b in zip(costs, costs[1:]))
    assert costs[0] > 0  # earliest exit owes the most catch-up
    # batching amortizes weight traffic: per-token cost shrinks with count
    assert PROF.kv_fill_cost(0, 8) < 8 * PROF.kv_fill_cost(0, 1)


def test_charge_kv_nets_savings():
    plain = dataclasses.replace(PROF, charge_kv_in_savings=False)
    for s in range(NS):
        assert PROF.savings_at_site(s, 1) <= plain.savings_at_site(s, 1) + 1e-15


# -- engine invariants --------------------------------------------------------


@pytest.mark.parametrize("seed,mbs", [(0, 2), (1, 4), (2, 8)])
def test_token_conservation_and_causality(seed, mbs):
    reqs = _gen_reqs(n=30, tokens=12, mbs=mbs, load=1.2, seed=seed, jitter_tokens=True)
    ctl = ApparateController(NS, PROF, ControllerConfig(max_slots=4))
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=mbs),
                           SyntheticDecodeRunner(NS, exit_site=NS // 3), ctl)
    resp = eng.run(reqs)
    assert sorted(r.rid for r in resp) == sorted(q.rid for q in reqs)
    by_rid = {r.rid: r for r in resp}
    for q in reqs:
        r = by_rid[q.rid]
        # token conservation: exactly n_tokens released, once each
        assert len(r.tokens) == q.n_tokens
        assert len(r.release_ms) == len(r.exit_sites) == len(r.final_tokens) == q.n_tokens
        # causality + per-request monotone release order
        assert r.release_ms[0] >= q.arrival_ms - 1e-9
        assert all(b >= a - 1e-9 for a, b in zip(r.release_ms, r.release_ms[1:]))
    assert sum(len(r.tokens) for r in resp) == sum(q.n_tokens for q in reqs)
    assert eng.n_tokens == sum(q.n_tokens for q in reqs)


def test_continuous_batching_slot_reuse_never_exceeds_capacity():
    """More requests than slots: the engine must reuse freed slots mid-run
    and never run more than max_batch_size tokens in one step."""
    mbs = 3
    reqs = _gen_reqs(n=24, tokens=8, mbs=mbs, load=2.0, seed=4, jitter_tokens=True)
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=mbs))
    resp = eng.run(reqs)
    assert len(resp) == 24  # all served despite 3 slots: slots were reused
    assert eng.peak_slots <= mbs
    assert max(eng.slot_history) <= mbs
    # under 2x overload the slots actually fill up
    assert eng.peak_slots == mbs


def test_tpt_monotone_in_exit_rate():
    """Paper Table 4 mechanism: higher per-token exit rates monotonically
    lower median TPT (KV catch-up included)."""
    reqs = _gen_reqs(n=30, tokens=16, mbs=8, load=0.8, seed=7)
    site = NS // 3
    p50 = []
    for rate in (0.0, 0.3, 0.6, 0.9):
        eng = GenerativeEngine(
            PROF, GenerativeConfig(max_batch_size=8),
            SyntheticDecodeRunner(NS, exit_site=site), _StubController(site, rate),
        )
        m = summarize_generative(eng.run(reqs), horizon_ms=eng.makespan_ms)
        p50.append(m["tpt_p50_ms"])
    assert all(b <= a + 1e-9 for a, b in zip(p50, p50[1:])), p50
    assert p50[-1] < p50[0]  # and the win is strict at high exit rates


def test_kv_catchup_is_charged_not_free():
    """The same exit pattern must cost strictly more wall time than a
    free-exit model (kv arrays stripped): exits are never free."""
    reqs = _gen_reqs(n=25, tokens=16, mbs=8, load=0.8, seed=9)
    free_prof = dataclasses.replace(PROF, kv_flops=None, kv_wbytes=None,
                                    kv_pibytes=None, charge_kv_in_savings=False)
    runs = {}
    for name, prof in (("charged", PROF), ("free", free_prof)):
        eng = GenerativeEngine(
            prof, GenerativeConfig(max_batch_size=8),
            SyntheticDecodeRunner(NS, exit_site=0), _StubController(0, 1.0),
        )
        eng.run(reqs)
        runs[name] = eng
    assert runs["charged"].kv_ms > 0
    assert runs["free"].kv_ms == 0
    assert runs["charged"].makespan_ms > runs["free"].makespan_ms
    # and despite the charge, exits still beat vanilla end to end
    van = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8))
    van.run(reqs)
    assert runs["charged"].busy_ms < van.busy_ms


def test_generative_ee_beats_vanilla_at_accuracy_constraint():
    """The PR's acceptance scenario: median TPT with Apparate exits strictly
    below the no-EE baseline at >=0.99 agreement, KV catch-up included."""
    reqs = _gen_reqs(n=120, tokens=24, mbs=8, load=0.6, seed=3)
    base_eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8))
    mb = summarize_generative(base_eng.run(reqs), horizon_ms=base_eng.makespan_ms)
    ctl = ApparateController(NS, PROF, ControllerConfig(max_slots=4, acc_constraint=0.99))
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8),
                           SyntheticDecodeRunner(NS, exit_site=NS // 3, easy_frac=0.7), ctl)
    mo = summarize_generative(eng.run(reqs), horizon_ms=eng.makespan_ms)
    assert mo["agreement"] >= 0.99
    assert mo["exit_rate"] > 0.2
    assert eng.kv_ms > 0  # catch-up actually charged
    assert mo["tpt_p50_ms"] < mb["tpt_p50_ms"]


def test_engine_config_validation():
    with pytest.raises(ValueError):
        GenerativeEngine(PROF, GenerativeConfig(max_batch_size=0))
    with pytest.raises(ValueError):
        GenerativeEngine(PROF, runner=SyntheticDecodeRunner(NS, 2))  # no controller
    with pytest.raises(ValueError):
        MixedClusterSimulator()  # no pool at all


# -- mixed heterogeneous cluster ---------------------------------------------


def test_mixed_cluster_both_pools_served_exactly_once():
    cls_prof = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)
    ns_c = len(cls_prof.sites)
    pf = PlatformConfig(policy="tfserve", max_batch_size=8,
                        batch_timeout_ms=cls_prof.vanilla_time(1))
    cls_sim = ClusterSimulator(
        cls_prof, ClusterConfig(n_workers=2, dispatch="jsq", platform=pf),
        runner=SyntheticRunner(ns_c, exit_site=ns_c // 3),
        controllers=[ApparateController(ns_c, cls_prof, ControllerConfig(max_slots=4))
                     for _ in range(2)],
    )
    gens = [
        GenerativeEngine(PROF, GenerativeConfig(max_batch_size=4),
                         SyntheticDecodeRunner(NS, exit_site=NS // 3),
                         ApparateController(NS, PROF, ControllerConfig(max_slots=4)))
        for _ in range(2)
    ]
    mixed = MixedClusterSimulator(cls_sim, gens)
    exec1 = cls_prof.vanilla_time(1)
    cls_reqs = make_requests(maf_trace(150, mean_qps=1.2 * 1000.0 / exec1, seed=1),
                             slo_ms=3 * exec1)
    gen_reqs = _gen_reqs(n=30, tokens=10, mbs=4, load=1.5, seed=2)
    cls_resp, gen_resp = mixed.run(cls_reqs, gen_reqs)
    assert sorted(r.rid for r in cls_resp) == list(range(150))
    assert sorted(r.rid for r in gen_resp) == list(range(30))
    assert sum(len(r.tokens) for r in gen_resp) == sum(q.n_tokens for q in gen_reqs)
    # both generative replicas got work (greedy token-work dispatch)
    assert all(e.n_tokens > 0 for e in gens)
    assert mixed.makespan_ms >= max(e.makespan_ms for e in gens)
    with pytest.raises(ValueError):
        MixedClusterSimulator(None, gens).run(cls_reqs, [])


# -- real-model DecodeRunner smoke -------------------------------------------


@pytest.fixture(scope="module")
def decode_setup():
    import jax  # noqa: F401  (CPU)

    from repro.data import make_decode_stream
    from repro.models import build_model
    from repro.serving import DecodeRunner
    from repro.training import TrainConfig, train

    cfg = get_tiny("qwen2-1.5b").replace(n_layers=4, vocab_size=128)
    model = build_model(cfg)
    stream = make_decode_stream(128, seq_len=17, vocab=128, predict=0.95, seed=11)

    def batches(s):
        rng = np.random.default_rng(s)
        idx = rng.integers(0, len(stream.data), 16)
        toks = stream.data[idx].astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    state, _ = train(model, batches, TrainConfig(steps=40, lr=3e-3), verbose=False)
    runner = DecodeRunner(model, state["params"], stream.data[:, :16],
                          max_new_tokens=10, max_slots=3)
    return cfg, model, runner


def test_decode_runner_streams_per_token_records(decode_setup):
    cfg, model, runner = decode_setup
    t0 = runner.start(0, 0)
    t1 = runner.start(1, 5)
    assert isinstance(t0, int) and isinstance(t1, int)
    lab, unc, fin = runner.step([0, 1], [0, 2])
    assert lab.shape == (2, 2) and unc.shape == (2, 2) and fin.shape == (2,)
    assert (unc >= 0).all() and (unc <= 1).all()
    # records row-ordered by sorted site regardless of caller order
    lab2, unc2, fin2 = runner.step([0, 1], [2, 0])
    assert lab2.shape == (2, 2)
    # slot freed -> stepping it again is a caller error (state removed)
    runner.free(1)
    with pytest.raises(KeyError):
        runner.step([1], [0])
    runner.free(0)


def test_decode_engine_end_to_end_with_real_model(decode_setup):
    cfg, model, runner = decode_setup
    ns = len(model.sites)
    prof_cfg = get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied")
    sites = [round((i + 1) * prof_cfg.n_layers / (ns + 1)) - 1 for i in range(ns)]
    prof = build_profile(prof_cfg, mode="decode", chips=1, sites=sites, charge_kv=True)
    ctl = ApparateController(ns, prof, ControllerConfig(max_slots=3, acc_constraint=0.99))
    qps = offered_decode_qps(prof, max_batch_size=3, tokens_per_request=6, load=0.6)
    arr = maf_trace(8, mean_qps=qps, seed=5)
    reqs = make_gen_requests(arr, n_tokens=6, prompt_len=16,
                             slo_ms=3 * prof.vanilla_time(1))
    eng = GenerativeEngine(prof, GenerativeConfig(max_batch_size=3), runner, ctl)
    resp = eng.run(reqs)
    assert sum(len(r.tokens) for r in resp) == sum(q.n_tokens for q in reqs)
    m = summarize_generative(resp, horizon_ms=eng.makespan_ms)
    assert m["agreement"] >= 0.95  # released tokens track the greedy stream
    assert ctl.stats["samples"] > 0  # controller really saw per-token records


# -- full TPT sweep (slow) ----------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("load", [0.4, 0.8])
@pytest.mark.parametrize("easy", [0.5, 0.9])
def test_full_tpt_sweep(load, easy):
    """Full EE-vs-vanilla TPT sweep over load x easy-traffic fraction: the
    win holds across the grid at the accuracy constraint."""
    reqs = _gen_reqs(n=120, tokens=24, mbs=8, load=load, seed=int(load * 10 + easy * 100))
    base_eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8))
    mb = summarize_generative(base_eng.run(reqs), horizon_ms=base_eng.makespan_ms)
    ctl = ApparateController(NS, PROF, ControllerConfig(max_slots=4, acc_constraint=0.99))
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8),
                           SyntheticDecodeRunner(NS, exit_site=NS // 3, easy_frac=easy), ctl)
    mo = summarize_generative(eng.run(reqs), horizon_ms=eng.makespan_ms)
    assert mo["agreement"] >= 0.99
    assert mo["tpt_p50_ms"] < mb["tpt_p50_ms"]


# -- summarize_generative edge cases ------------------------------------------


def _finite_summary(responses, **kw):
    """Summarize under errstate(raise): any divide-by-zero/invalid inside
    the metric computation becomes a test failure, and every returned
    value must be finite (no NaN TPT percentiles)."""
    with np.errstate(all="raise"):
        out = summarize_generative(responses, **kw)
    bad = {k: v for k, v in out.items() if not np.isfinite(v)}
    assert not bad, f"non-finite metrics: {bad}"
    return out


def test_summarize_generative_empty_stream():
    out = _finite_summary([])
    assert out["n"] == 0.0 and out["tokens"] == 0.0
    assert out["tpt_p50_ms"] == 0.0 and out["tokens_per_sec"] == 0.0


def test_summarize_generative_single_token_requests():
    """One-token requests have TTFT but zero TPT samples: percentiles must
    come back 0.0, not NaN, and agreement defaults to 1.0 (the prefill
    token is the final model's own output by construction)."""
    from repro.serving import GenResponse

    resp = [
        GenResponse(rid=i, arrival_ms=i * 2.0, release_ms=[i * 2.0 + 1.5],
                    exit_sites=[-1], tokens=[7], final_tokens=[7], slo_ms=10.0)
        for i in range(5)
    ]
    out = _finite_summary(resp)
    assert out["tpt_p50_ms"] == 0.0 and out["tpt_p95_ms"] == 0.0
    assert out["tpt_mean_ms"] == 0.0
    assert out["agreement"] == 1.0 and out["exit_rate"] == 0.0
    assert out["ttft_p50_ms"] == pytest.approx(1.5)


def test_summarize_generative_single_token_through_engine():
    """End-to-end: an n_tokens=1 request stream finishes at admission
    (prefill only) and must summarize NaN-free."""
    reqs = make_gen_requests(
        maf_trace(8, mean_qps=5.0, seed=0), n_tokens=1, prompt_len=16,
        slo_ms=3 * PROF.vanilla_time(1),
    )
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=4))
    out = _finite_summary(eng.run(reqs), horizon_ms=eng.makespan_ms)
    assert out["n"] == 8.0 and out["tokens"] == 8.0
    assert out["tpt_p50_ms"] == 0.0


def test_summarize_generative_zero_span_rates_are_zero():
    """A degenerate stream whose whole life is one instant (span == 0)
    must report tokens_per_sec == 0.0 — not inf, not count/eps — and
    raise nothing under errstate(raise). Regression: _per_sec used to
    divide by max(span, 1e-9), turning a zero span into an
    astronomically large bogus rate."""
    from repro.serving import GenResponse
    from repro.serving.metrics import _per_sec

    with np.errstate(all="raise"):
        assert _per_sec(5, 0.0) == 0.0
        assert _per_sec(0, 0.0) == 0.0
        assert _per_sec(3, -1.0) == 0.0  # clock skew: degenerate, not huge
        assert _per_sec(4, 2000.0) == 2.0
    # every release at t=0.0 -> derived span is exactly zero
    resp = [
        GenResponse(rid=i, arrival_ms=0.0, release_ms=[0.0, 0.0],
                    exit_sites=[-1, -1], tokens=[1, 2], final_tokens=[1, 2],
                    slo_ms=10.0)
        for i in range(2)
    ]
    out = _finite_summary(resp)
    assert out["tokens_per_sec"] == 0.0 and out["tokens"] == 4.0
    # explicit zero horizon: same guarantee through the kwarg path
    out = _finite_summary(resp, horizon_ms=0.0)
    assert out["tokens_per_sec"] == 0.0


def test_summarize_zero_span_through_engine():
    """Engine regression for the zero-span guard: summarizing a real run
    against a zero horizon must stay finite with rate 0.0 (the classifier
    summary path shares _per_sec, so it is covered by the same guard)."""
    reqs = make_gen_requests(
        maf_trace(4, mean_qps=5.0, seed=1), n_tokens=2, prompt_len=16,
        slo_ms=3 * PROF.vanilla_time(1),
    )
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=4))
    out = _finite_summary(eng.run(reqs), horizon_ms=0.0)
    assert out["tokens_per_sec"] == 0.0 and out["tokens"] == 8.0


def test_summarize_generative_all_exited_at_site_zero():
    from repro.serving import GenResponse

    resp = [
        GenResponse(rid=i, arrival_ms=0.0, release_ms=[1.0, 2.0, 3.0],
                    exit_sites=[-1, 0, 0], tokens=[1, 2, 3],
                    final_tokens=[1, 2, 3], slo_ms=10.0)
        for i in range(3)
    ]
    out = _finite_summary(resp)
    assert out["exit_rate"] == 1.0 and out["agreement"] == 1.0
    assert out["tpt_p50_ms"] == pytest.approx(1.0)
    assert out["tpt_slo_miss_rate"] == 0.0
