"""Dry-run tooling: HLO collective parser, metric extrapolation math,
artifact sanity (runs against the checked-in artifacts when present)."""
import glob
import json
import os

import numpy as np
import pytest

from repro.launch.dryrun import COLLECTIVE_W, collective_bytes, metric_overrides
from repro.configs import ARCH_IDS, get_config

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def test_collective_parser_shapes():
    hlo = """
  %all-reduce.1 = f32[16,4096,1536]{2,1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[8,1024]{1,0} all-gather(%y), dimensions={0}
  %t = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-reduce(%a, %b), channel_id=3
  %p = f32[2,2]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %noise = f32[9,9]{1,0} add(%q, %r)
"""
    out = collective_bytes(hlo)
    ar = out["bytes"]["all-reduce"]
    # 16*4096*1536*4*2(w) + 2*(4*4*4)*2(w)
    assert ar == 16 * 4096 * 1536 * 4 * 2 + 2 * 16 * 4 * 2
    assert out["bytes"]["all-gather"] == 8 * 1024 * 2
    assert out["bytes"]["collective-permute"] == 2 * 2 * 4
    assert out["counts"]["all-reduce"] == 2
    assert out["bytes"]["all-to-all"] == 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_metric_overrides_consistent(arch):
    """Reduced-depth override configs must build valid plans whose period
    structure matches the full config (same slots per period)."""
    from repro.models.transformer import build_plan

    cfg = get_config(arch)
    ovrs, (u1, u2, uf) = metric_overrides(cfg)
    assert u2 == u1 + 1 and uf >= u2
    if cfg.family == "encdec":
        return
    full = build_plan(cfg)
    for ovr, u in zip(ovrs, (u1, u2)):
        p = build_plan(cfg.replace(**ovr))
        assert p.period == full.period, arch
        assert p.n_periods == u, arch
        assert len(p.prefix) == len(full.prefix)
        assert len(p.suffix) == len(full.suffix)


def test_artifacts_if_present():
    paths = [
        p for p in glob.glob(os.path.join(ART, "*__single.json"))
        if "opt" not in os.path.basename(p)
    ]
    if not paths:
        pytest.skip("no dry-run artifacts checked in")
    n_ok = 0
    for p in paths:
        d = json.load(open(p))
        if not d.get("ok"):
            continue
        n_ok += 1
        assert d["chips"] == 256
        if "t_compute_s" in d:
            assert d["t_compute_s"] >= 0
            assert d["xp_flops"] >= 0
            # extrapolation sanity: full-depth >= 2-period measurement
            u = d["metric_points"]["u"]
            f = d["metric_points"]["flops"]
            if u[2] > u[1]:
                assert d["xp_flops"] >= f[1] - 1e-6
    assert n_ok >= 30  # 33 runnable cells
