"""Distribution: EP-vs-dense MoE equivalence, gradient compression,
pipeline, mini dry-run — all in a subprocess with 4 fake devices so the
rest of the suite keeps its single real device."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# XLA's host-platform collective thunks occasionally abort under heavy CPU
# oversubscription (observed only with the full suite running concurrently);
# rerun rather than fail the suite on the race.
pytestmark = pytest.mark.flaky(reruns=2)


def run_sub(code: str):
    env = dict(os.environ)
    # cap per-device thread pools: 8 fake devices on 1 core can exhaust
    # threads under load (observed as SIGABRT in Eigen worker spawn)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 --xla_cpu_multi_thread_eigen=false"
    )
    env["PYTHONPATH"] = SRC
    env["OMP_NUM_THREADS"] = "1"
    for attempt in range(2):  # one retry for transient thread exhaustion
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, timeout=560, env=env,
        )
        if r.returncode == 0:
            return r.stdout
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_moe_ep_matches_dense_on_mesh():
    run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import get_tiny
        from repro.models import build_model
        from repro.models.layers import MeshAxes
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        axes = MeshAxes(data=("data",), model="model", fsdp=True)
        cfg = get_tiny("qwen3-moe-30b-a3b").replace(capacity_factor=8.0)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        f = lambda impl: float(jax.jit(lambda p, b: m.loss(p, b, axes=axes, mesh=mesh, moe_impl=impl)[0])(params, batch))
        le, ld = f("ep"), f("dense")
        assert abs(le - ld) < 1e-3, (le, ld)
        print("ep==dense OK")
    """)


def test_moe_ep_small_batch_decode():
    """Per-shard tokens < model ranks (the decode regime) must still work."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_tiny
        from repro.models import build_model
        from repro.models.layers import MeshAxes
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        axes = MeshAxes(data=("data",), model="model", fsdp=False)
        cfg = get_tiny("qwen3-moe-30b-a3b").replace(capacity_factor=8.0)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 3), 0, cfg.vocab_size)  # 6 tokens < 4-dev granularity
        def f(impl):
            _, outs = m.prefill(params, toks, active_sites=jnp.asarray([0], jnp.int32),
                                with_cache=False, moe_impl=impl, axes=axes, mesh=mesh)
            return np.asarray(outs["final"]["maxprob"])
        np.testing.assert_allclose(f("ep"), f("dense"), rtol=2e-3, atol=2e-3)
        print("small-batch ep OK")
    """)


def test_gradient_compression_and_pipeline():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import make_compressed_grad_allreduce, pipeline_apply
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("pod", "data"))
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (33, 17)), "b": jnp.ones((5,))}
        r = jax.tree.map(jnp.zeros_like, g)
        out, res = make_compressed_grad_allreduce(mesh, "pod")(g, r)
        for k in g:
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(g[k]*2), atol=0.06, rtol=0.02)
        # error feedback: residual holds the quantization error
        assert float(sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(res))) > 0
        mesh2 = make_mesh((4,), ("stage",))
        W = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(2), (6, 3, 16))
        y = pipeline_apply(mesh2, "stage", lambda p, h: jnp.tanh(h @ p), W, x)
        ref = x
        for i in range(4): ref = jnp.tanh(ref @ W[i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)
        print("compression+pipeline OK")
    """)


def test_mini_dryrun_multidev():
    """Lower+compile a tiny arch on a (2,2) mesh — the dry-run machinery
    end-to-end without the 512-device cost."""
    run_sub("""
        import jax, numpy as np
        import repro.launch.dryrun as DR
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 2)
        fn, args, donate = DR.build_cell("qwen2-1.5b", "train_4k", mesh,
            overrides=dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                           d_ff=128, vocab_size=2048, dtype="float32"))
        # shrink the batch via rebuilt abstracts is overkill; just compile
        compiled = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
        from repro.compat import cost_analysis
        ca = cost_analysis(compiled)
        assert ca.get("flops", 0) > 0
        cb = DR.collective_bytes(compiled.as_text())
        print("mini dryrun OK", sum(cb["bytes"].values()))
    """)


def test_elastic_restore_across_meshes():
    """Checkpoint saved unsharded restores onto a different device layout."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("data",))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save({"w": xs}, step=1)
        mesh2 = make_mesh((2, 2), ("a", "b"))
        tree = mgr.restore(1, sharding_tree={"w": NamedSharding(mesh2, P("b", "a"))})
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(x))
        print("elastic OK")
    """)
