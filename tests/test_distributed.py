"""Distribution: EP-vs-dense MoE equivalence, gradient compression,
pipeline, tensor-parallel sharded decode, pipeline-escape decode windows,
mini dry-run — all in a subprocess with 4 fake devices so the rest of the
suite keeps its single real device."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# XLA's host-platform collective thunks occasionally abort under heavy CPU
# oversubscription (observed only with the full suite running concurrently);
# rerun rather than fail the suite on the race.
pytestmark = pytest.mark.flaky(reruns=2)


def run_sub(code: str):
    env = dict(os.environ)
    # cap per-device thread pools: 8 fake devices on 1 core can exhaust
    # threads under load (observed as SIGABRT in Eigen worker spawn)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 --xla_cpu_multi_thread_eigen=false"
    )
    env["PYTHONPATH"] = SRC
    env["OMP_NUM_THREADS"] = "1"
    for attempt in range(2):  # one retry for transient thread exhaustion
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, timeout=560, env=env,
        )
        if r.returncode == 0:
            return r.stdout
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_moe_ep_matches_dense_on_mesh():
    run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import get_tiny
        from repro.models import build_model
        from repro.models.layers import MeshAxes
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        axes = MeshAxes(data=("data",), model="model", fsdp=True)
        cfg = get_tiny("qwen3-moe-30b-a3b").replace(capacity_factor=8.0)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        f = lambda impl: float(jax.jit(lambda p, b: m.loss(p, b, axes=axes, mesh=mesh, moe_impl=impl)[0])(params, batch))
        le, ld = f("ep"), f("dense")
        assert abs(le - ld) < 1e-3, (le, ld)
        print("ep==dense OK")
    """)


def test_moe_ep_small_batch_decode():
    """Per-shard tokens < model ranks (the decode regime) must still work."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_tiny
        from repro.models import build_model
        from repro.models.layers import MeshAxes
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("data", "model"))
        axes = MeshAxes(data=("data",), model="model", fsdp=False)
        cfg = get_tiny("qwen3-moe-30b-a3b").replace(capacity_factor=8.0)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 3), 0, cfg.vocab_size)  # 6 tokens < 4-dev granularity
        def f(impl):
            _, outs = m.prefill(params, toks, active_sites=jnp.asarray([0], jnp.int32),
                                with_cache=False, moe_impl=impl, axes=axes, mesh=mesh)
            return np.asarray(outs["final"]["maxprob"])
        np.testing.assert_allclose(f("ep"), f("dense"), rtol=2e-3, atol=2e-3)
        print("small-batch ep OK")
    """)


def test_gradient_compression_and_pipeline():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed import make_compressed_grad_allreduce, pipeline_apply
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2), ("pod", "data"))
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (33, 17)), "b": jnp.ones((5,))}
        r = jax.tree.map(jnp.zeros_like, g)
        out, res = make_compressed_grad_allreduce(mesh, "pod")(g, r)
        for k in g:
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(g[k]*2), atol=0.06, rtol=0.02)
        # error feedback: residual holds the quantization error
        assert float(sum(jnp.sum(jnp.abs(x)) for x in jax.tree.leaves(res))) > 0
        mesh2 = make_mesh((4,), ("stage",))
        W = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16)) * 0.3
        x = jax.random.normal(jax.random.PRNGKey(2), (6, 3, 16))
        y = pipeline_apply(mesh2, "stage", lambda p, h: jnp.tanh(h @ p), W, x)
        ref = x
        for i in range(4): ref = jnp.tanh(ref @ W[i])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)
        print("compression+pipeline OK")
    """)


def test_mini_dryrun_multidev():
    """Lower+compile a tiny arch on a (2,2) mesh — the dry-run machinery
    end-to-end without the 512-device cost."""
    run_sub("""
        import jax, numpy as np
        import repro.launch.dryrun as DR
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(2, 2)
        fn, args, donate = DR.build_cell("qwen2-1.5b", "train_4k", mesh,
            overrides=dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                           d_ff=128, vocab_size=2048, dtype="float32"))
        # shrink the batch via rebuilt abstracts is overkill; just compile
        compiled = jax.jit(fn, donate_argnums=donate).lower(*args).compile()
        from repro.compat import cost_analysis
        ca = cost_analysis(compiled)
        assert ca.get("flops", 0) > 0
        cb = DR.collective_bytes(compiled.as_text())
        print("mini dryrun OK", sum(cb["bytes"].values()))
    """)


def test_sharded_decode_bit_identical():
    """`decode_sharded` / `decode_sharded_multi` at tp=2, tp=4 and
    dp=2 x tp=2 must be bit-identical to single-device `decode` — the
    tiled all_gather combine is a pure concatenation, so the sharded
    matmuls reduce in exactly the single-device order."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_tiny
        from repro.models.transformer import LM

        def eq_tree(a, b):
            la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
            return len(la) == len(lb) and all(
                bool(jnp.array_equal(x, y)) for x, y in zip(la, lb))

        cfg = get_tiny("qwen2-1.5b").replace(n_kv_heads=4)  # tp=4 needs 4 KV heads
        m = LM(cfg)
        params = m.init(jax.random.PRNGKey(0))
        B, S = 4, 8
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        cache, outs = m.prefill(params, toks, cache_len=32, moe_impl="dense")
        last = outs["final"]["label"].reshape(B, 1).astype(jnp.int32)
        pos = jnp.full((B,), S, jnp.int32)
        act = jnp.asarray([0, 1], jnp.int32)
        thr = jnp.asarray([0.5, 0.5], jnp.float32)
        c1, o1 = m.decode(params, cache, last, pos, active_sites=act,
                          moe_impl="dense", exit_thresholds=thr)
        shapes = [(1, 2), (1, 4), (2, 2)]
        for dp, tp in shapes:
            devs = np.array(jax.devices()[: dp * tp]).reshape(dp, tp)
            mesh = Mesh(devs, ("data", "model"))
            c2, o2 = m.decode_sharded(params, cache, last, pos, mesh=mesh,
                                      active_sites=act, moe_impl="dense",
                                      exit_thresholds=thr)
            assert eq_tree(o1, o2) and eq_tree(c1, c2), (dp, tp)
        # fused multi-step window, sharded vs single-device
        c4, rec1 = m.decode_multi(params, cache, last, pos, jnp.asarray(3),
                                  n_max=4, active_sites=act, thresholds=thr,
                                  moe_impl="dense")
        mesh = Mesh(np.array(jax.devices()[:2]).reshape(1, 2), ("data", "model"))
        c5, rec2 = m.decode_sharded_multi(params, cache, last, pos,
                                          jnp.asarray(3), mesh=mesh, n_max=4,
                                          active_sites=act, thresholds=thr,
                                          moe_impl="dense")
        nd = int(rec1[4])
        assert int(rec2[4]) == nd
        for i, (a, b) in enumerate(zip(rec1[:4], rec2[:4])):
            assert bool(jnp.array_equal(a[:nd], b[:nd])), f"rec[{i}]"
        assert eq_tree(c4, c5)
        print("sharded decode OK")
    """)


def test_pipeline_decode_window_escapes():
    """Pipeline-parallel decode: thresholds-off windows are bit-identical
    to a plain per-step decode loop (tokens AND caches) at S=1/2/4; with a
    near-1.0 threshold at the stage-boundary ramps every row exits at
    stage 0 and later stages do strictly less work — the exit mask gates
    the ppermute forwarding."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get_tiny
        from repro.models.transformer import LM
        from repro.distributed.pipeline import pipeline_decode_window, pipeline_check

        cfg = get_tiny("qwen2-1.5b").replace(n_layers=4)  # n_periods=4: 1/2/4 stages
        m = LM(cfg)
        params = m.init(jax.random.PRNGKey(0))
        B, S0, n_steps = 4, 8, 3
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0, cfg.vocab_size)
        cache, outs = m.prefill(params, toks, cache_len=32, moe_impl="dense")
        last = outs["final"]["label"].reshape(B, 1).astype(jnp.int32)
        pos = jnp.full((B,), S0, jnp.int32)
        ref_toks, c, t = [], cache, last
        for k in range(n_steps):
            c, o = m.decode(params, c, t, pos + k, moe_impl="dense")
            t = o["final"]["label"].reshape(B, 1).astype(jnp.int32)
            ref_toks.append(o["final"]["label"])
        ref_toks, ref_cache = jnp.stack(ref_toks), c

        def eq_tree(a, b):
            return all(bool(jnp.array_equal(x, y)) for x, y in
                       zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        for S in (1, 2, 4):
            mesh = Mesh(np.array(jax.devices()[:S]), ("stage",))
            nc, tok_rec, exit_rec, alive, steps = pipeline_decode_window(
                m, params, cache, last, pos, n_steps, mesh=mesh)
            assert bool(jnp.array_equal(tok_rec, ref_toks)), S
            assert eq_tree(nc, ref_cache), S
            assert bool(alive.all()) and int((exit_rec >= 0).sum()) == 0, S
        # exit-heavy: thr ~1.0 at every stage-boundary ramp
        sites = list(m.sites)
        for S in (2, 4):
            Lp, ns = m.plan.n_periods // S, len(m.plan.period)
            a = [sites.index(b) for b in
                 [(s + 1) * Lp * ns - 1 for s in range(S - 1)] if b in sites]
            assert a, f"S={S}: no boundary ramp in sites={sites}"
            mesh = Mesh(np.array(jax.devices()[:S]), ("stage",))
            nc, tok_rec, exit_rec, alive, steps = pipeline_decode_window(
                m, params, cache, last, pos, n_steps, mesh=mesh,
                active_sites=jnp.asarray(a, jnp.int32),
                thresholds=jnp.asarray([0.9999] * len(a), jnp.float32))
            assert int(steps[-1]) < int(steps[0]), (S, steps.tolist())
            assert int((exit_rec >= 0).sum()) > 0, S
        # rejection gates carry why-notes
        try:
            pipeline_check(LM(cfg.replace(decode_attn="paged")), 2)
            raise AssertionError("paged decode_attn should be rejected")
        except NotImplementedError as e:
            assert "block pool shards per-device" in str(e)
        try:
            pipeline_check(m, 3)
            raise AssertionError("n_periods % S != 0 should be rejected")
        except NotImplementedError:
            pass
        print("pipeline escapes OK")
    """)


def test_dryrun_merges_operator_xla_flags():
    """Importing `repro.launch.dryrun` must MERGE its 512-device default
    under any operator-exported XLA_FLAGS, never clobber them — the
    run_sub env already pins device_count=4, which must survive."""
    run_sub("""
        import os
        import repro.launch.dryrun  # noqa: F401  (import runs the env setup)
        flags = os.environ["XLA_FLAGS"]
        assert "--xla_force_host_platform_device_count=4" in flags, flags
        assert "512" not in flags, flags
        assert "--xla_cpu_multi_thread_eigen=false" in flags, flags
        # without an operator value the 512 default still lands
        from repro.launch.tuning import merge_xla_flags
        merged = merge_xla_flags("--xla_force_host_platform_device_count=512", None)
        assert merged == "--xla_force_host_platform_device_count=512", merged
        import jax
        assert jax.device_count() == 4, jax.device_count()
        print("dryrun flag merge OK")
    """)


def test_elastic_restore_across_meshes():
    """Checkpoint saved unsharded restores onto a different device layout."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("data",))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d)
        mgr.save({"w": xs}, step=1)
        mesh2 = make_mesh((2, 2), ("a", "b"))
        tree = mgr.restore(1, sharding_tree={"w": NamedSharding(mesh2, P("b", "a"))})
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(x))
        print("elastic OK")
    """)
