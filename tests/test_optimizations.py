"""Exactness tests for the §Perf optimization knobs: every hillclimb change
must preserve the baseline math (debug-forward methodology — keep the
speedup, prove equivalence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_tiny
from repro.models import build_model


def test_windowed_ring_cache_matches_full():
    cfg0 = get_tiny("gemma3-4b")  # window=16, pattern 2:1
    S = 24  # exceeds the window -> ring wraps
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S + 3), 0, cfg0.vocab_size)
    act = jnp.arange(2, dtype=jnp.int32)
    m0 = build_model(cfg0)
    params = m0.init(jax.random.PRNGKey(0))
    m1 = build_model(cfg0.replace(windowed_cache=True))
    c0, _ = m0.prefill(params, toks[:, :S], cache_len=S + 4, active_sites=act, moe_impl="dense")
    c1, _ = m1.prefill(params, toks[:, :S], cache_len=S + 4, active_sites=act, moe_impl="dense")
    for t in range(3):
        c0, r0 = m0.decode(params, c0, toks[:, S + t : S + t + 1], jnp.int32(S + t),
                           active_sites=act, moe_impl="dense")
        c1, r1 = m1.decode(params, c1, toks[:, S + t : S + t + 1], jnp.int32(S + t),
                           active_sites=act, moe_impl="dense")
        np.testing.assert_allclose(
            np.asarray(r0["final"]["maxprob"]), np.asarray(r1["final"]["maxprob"]),
            rtol=2e-2, atol=2e-2,
        )
        assert (np.asarray(r0["final"]["label"]) == np.asarray(r1["final"]["label"])).all()


def test_ring_wraparound_bit_identical_to_full():
    """Satellite audit of the ``slot = pos % W`` wraparound: at positions
    just below, at, and past exact multiples of the window the ring-cache
    decode must match a full-cache window-masked dense decode BIT-FOR-BIT
    (every local decode variant gathers the same W chronological rows and
    runs the identical W-column reduction — allclose would hide a
    rotated-sum or off-by-one slot bug behind ULP slack)."""
    cfg0 = get_tiny("gemma3-4b")
    W = cfg0.window
    m0 = build_model(cfg0)
    m1 = build_model(cfg0.replace(windowed_cache=True))
    params = m0.init(jax.random.PRNGKey(0))
    act = jnp.arange(2, dtype=jnp.int32)
    for pos in (W - 1, W, W + 1, 2 * W):
        toks = jax.random.randint(
            jax.random.PRNGKey(pos), (2, pos + 1), 0, cfg0.vocab_size
        )
        c0, _ = m0.prefill(params, toks[:, :pos], cache_len=pos + 2,
                           active_sites=act, moe_impl="dense")
        c1, _ = m1.prefill(params, toks[:, :pos], cache_len=pos + 2,
                           active_sites=act, moe_impl="dense")
        _, r0 = m0.decode(params, c0, toks[:, pos:], jnp.int32(pos),
                          active_sites=act, moe_impl="dense")
        _, r1 = m1.decode(params, c1, toks[:, pos:], jnp.int32(pos),
                          active_sites=act, moe_impl="dense")
        for key in ("maxprob", "label"):
            np.testing.assert_array_equal(
                np.asarray(r0["final"][key]), np.asarray(r1["final"][key]),
                err_msg=f"pos={pos} ({key})",
            )


def test_pallas_head_matches_dense_path():
    cfg = get_tiny("qwen2-1.5b")
    m0 = build_model(cfg)
    m1 = build_model(cfg.replace(pallas_head="interpret"))
    params = m0.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 12), 0, cfg.vocab_size)
    act = jnp.asarray([0, 1], jnp.int32)
    _, o0 = m0.prefill(params, toks, active_sites=act, with_cache=False, moe_impl="dense")
    _, o1 = m1.prefill(params, toks, active_sites=act, with_cache=False, moe_impl="dense")
    for part in ("final", "ramps"):
        assert (np.asarray(o0[part]["label"]) == np.asarray(o1[part]["label"])).all(), part
        np.testing.assert_allclose(
            np.asarray(o0[part]["maxprob"]), np.asarray(o1[part]["maxprob"]),
            rtol=2e-3, atol=2e-3,
        )
        np.testing.assert_allclose(
            np.asarray(o0[part]["entropy"]), np.asarray(o1[part]["entropy"]),
            rtol=2e-3, atol=2e-3,
        )


def test_pallas_head_tied_ramps():
    cfg = get_tiny("qwen2-1.5b").replace(ramp_style="tied")
    m0 = build_model(cfg)
    m1 = build_model(cfg.replace(pallas_head="interpret"))
    params = m0.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab_size)
    act = jnp.asarray([0, 1], jnp.int32)
    _, a = m0.prefill(params, toks, active_sites=act, with_cache=False, moe_impl="dense")
    _, b = m1.prefill(params, toks, active_sites=act, with_cache=False, moe_impl="dense")
    assert (np.asarray(a["ramps"]["label"]) == np.asarray(b["ramps"]["label"])).all()


def test_kv_seq_shard_spec_only():
    """kv_seq_shard changes cache PartitionSpecs, not math: single-device
    decode must be bit-identical."""
    cfg = get_tiny("qwen2-1.5b")
    m0 = build_model(cfg)
    m1 = build_model(cfg.replace(kv_seq_shard=True))
    params = m0.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    act = jnp.asarray([0], jnp.int32)
    c0, _ = m0.prefill(params, toks[:, :8], cache_len=12, active_sites=act, moe_impl="dense")
    c1, _ = m1.prefill(params, toks[:, :8], cache_len=12, active_sites=act, moe_impl="dense")
    _, r0 = m0.decode(params, c0, toks[:, 8:9], jnp.int32(8), active_sites=act, moe_impl="dense")
    _, r1 = m1.decode(params, c1, toks[:, 8:9], jnp.int32(8), active_sites=act, moe_impl="dense")
    np.testing.assert_array_equal(np.asarray(r0["final"]["label"]), np.asarray(r1["final"]["label"]))
    # spec difference is visible in the cache schema
    s0 = m0.cache_schema(128, 64, shard_batch=True)
    s1 = m1.cache_schema(128, 64, shard_batch=True)
    spec0 = jax.tree.leaves(s0, is_leaf=lambda x: hasattr(x, "spec"))[0].spec
    spec1 = jax.tree.leaves(s1, is_leaf=lambda x: hasattr(x, "spec"))[0].spec
    assert spec0 != spec1
    assert "model" in str(spec1[2])  # seq dim carries the model axis
