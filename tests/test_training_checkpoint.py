"""Training loop, frozen-backbone ramp training, checkpoint/restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_tiny
from repro.data import TokenPipeline
from repro.models import build_model
from repro.training import TrainConfig, init_state, make_train_step, ramp_mask, train


def _pipe_batches(cfg, batch=8, seq=24, seed=0):
    pipe = TokenPipeline(cfg.vocab_size, seq, batch, seed=seed)
    return lambda s: pipe.batch_at(s)


def test_loss_decreases():
    cfg = get_tiny("qwen2-1.5b")
    m = build_model(cfg)
    state, logs = train(m, _pipe_batches(cfg), TrainConfig(steps=30, lr=2e-3, log_every=29), verbose=False)
    first, last = logs[0]["loss"], logs[-1]["loss"]
    assert last < first - 0.1, (first, last)


def test_ramps_only_freezes_backbone():
    cfg = get_tiny("qwen2-1.5b")
    m = build_model(cfg)
    tcfg = TrainConfig(steps=5, lr=1e-2, train_mode="ramps_only")
    step_fn, opt_cfg = make_train_step(m, tcfg)
    state = init_state(m, jax.random.PRNGKey(0), opt_cfg)
    before = jax.tree.map(lambda x: np.asarray(x).copy(), state["params"])
    jstep = jax.jit(step_fn)
    batches = _pipe_batches(cfg)
    for s in range(5):
        state, _ = jstep(state, {k: jnp.asarray(v) for k, v in batches(s).items()})
    after = state["params"]
    # backbone identical
    for key in ("tok", "blocks", "final_norm"):
        for a, b in zip(jax.tree.leaves(before[key]), jax.tree.leaves(after[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # ramps moved
    moved = sum(
        float(np.abs(np.asarray(a) - np.asarray(b)).sum())
        for a, b in zip(jax.tree.leaves(before["ramps"]), jax.tree.leaves(after["ramps"]))
    )
    assert moved > 0


def test_ramp_mask_structure():
    cfg = get_tiny("qwen2-1.5b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    mask = ramp_mask(params)
    assert bool(np.asarray(jax.tree.leaves(mask["ramps"])[0]).all())
    assert not bool(np.asarray(jax.tree.leaves(mask["tok"])[0]).any())


def test_checkpoint_roundtrip_and_resume(tmp_path):
    cfg = get_tiny("qwen2-1.5b")
    m = build_model(cfg)
    tcfg = TrainConfig(steps=10, lr=1e-3)
    step_fn, opt_cfg = make_train_step(m, tcfg)
    jstep = jax.jit(step_fn)
    batches = _pipe_batches(cfg)

    def run(state, lo, hi):
        for s in range(lo, hi):
            state, _ = jstep(state, {k: jnp.asarray(v) for k, v in batches(s).items()})
        return state

    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    s0 = init_state(m, jax.random.PRNGKey(0), opt_cfg)
    # straight 10 steps
    straight = run(s0, 0, 10)
    # 5 steps -> checkpoint -> restore -> 5 more (preemption/restart)
    s1 = run(init_state(m, jax.random.PRNGKey(0), opt_cfg), 0, 5)
    mgr.save(s1, step=5)
    restored = mgr.restore()
    assert int(np.asarray(restored["step"])) == 5
    resumed = run(restored, 5, 10)
    for a, b in zip(jax.tree.leaves(straight["params"]), jax.tree.leaves(resumed["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_checkpoint_keep_n_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)}, "step": jnp.int32(0)}
    for s in (1, 2, 3, 4):
        mgr.save_async({**state, "step": jnp.int32(s)}, step=s)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]
    r = mgr.restore(4)
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]), np.arange(6.0).reshape(2, 3))


def test_checkpoint_atomicity(tmp_path):
    """A leftover .tmp dir from a crashed writer is never picked up."""
    mgr = CheckpointManager(str(tmp_path / "ck"))
    os.makedirs(tmp_path / "ck" / "step_00000007.tmp")
    assert mgr.latest_step() is None
    mgr.save({"x": jnp.ones(3)}, step=9)
    assert mgr.latest_step() == 9


def test_token_pipeline_deterministic_resume():
    p1 = TokenPipeline(128, 16, 4, seed=7)
    p2 = TokenPipeline(128, 16, 4, seed=7)
    for s in (0, 5, 11):
        np.testing.assert_array_equal(p1.batch_at(s)["tokens"], p2.batch_at(s)["tokens"])
    assert not np.array_equal(p1.batch_at(0)["tokens"], p1.batch_at(1)["tokens"])


def test_grad_accum_matches_full_batch():
    cfg = get_tiny("qwen2-1.5b")
    m = build_model(cfg)
    batch = TokenPipeline(cfg.vocab_size, 16, 8, seed=1).batch_at(0)
    tc1 = TrainConfig(steps=1, lr=1e-3, grad_accum=1)
    tc2 = TrainConfig(steps=1, lr=1e-3, grad_accum=2)
    step1, sched1 = make_train_step(m, tc1)
    step2, sched2 = make_train_step(m, tc2)
    jstep1, jstep2 = jax.jit(step1), jax.jit(step2)
    s1, _ = jstep1(
        init_state(m, jax.random.PRNGKey(0), sched1),
        {k: jnp.asarray(v) for k, v in batch.items()},
    )
    s2, _ = jstep2(
        init_state(m, jax.random.PRNGKey(0), sched2),
        {k: jnp.asarray(v) for k, v in batch.items()},
    )
    # same data, microbatched: params should land close (mean-of-means CE)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-2, atol=5e-4)
