"""Shared pytest config: deterministic RNG seeding + the `slow` marker.

Suite policy (recorded in ROADMAP.md): tier-1 (`pytest -x -q`) must run
with stdlib + numpy + jax + pytest only — no `hypothesis`, no plugins.
Long-running tests (interpret-mode Pallas kernel sweeps) carry the
``slow`` marker and are skipped unless the marker expression mentions
them (`-m slow` for the full sweep, `-m "not slow"` to be explicit in
CI); plain `pytest -x -q` therefore finishes in minutes.
"""
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running (interpret-mode Pallas sweeps); skipped unless -m mentions 'slow'",
    )
    config.addinivalue_line(
        "markers", "flaky: tolerated-rerun annotation (no-op without a rerun plugin)"
    )


def pytest_collection_modifyitems(config, items):
    if "slow" in (config.getoption("markexpr", "") or ""):
        return  # the caller took an explicit stance on slow tests
    skip = pytest.mark.skip(reason="slow: opt in with -m slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(autouse=True, scope="session")
def _seed_global_rng():
    """Session-wide seed for legacy ``np.random`` consumers; tests needing
    local randomness should build their own ``np.random.default_rng``."""
    # deliberate: this fixture IS the sanctioned global seed point
    np.random.seed(0)  # repro: allow[seeded-rng]


@pytest.fixture
def rng():
    """Deterministic per-test generator."""
    return np.random.default_rng(0)
