"""Chunked prefill interleaving + SLO-aware admission (PR 5 capabilities).

The unified engine core co-schedules a long prompt's prefill in
``prefill_chunk``-token chunks with the in-flight decode steps, so TPT
never stalls behind a monolithic prefill; ``LatencyProfile`` gained the
physics (``prefill_chunk_time``) and ``DecodeRunner.start`` became
resumable across chunks against the same (contiguous or paged) slot
cache. The shared ``AdmissionPolicy`` drops hopeless requests at
admission and sheds doomed slots mid-stream for both workload adapters.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import build_profile
from repro.serving import (
    AdmissionConfig,
    AdmissionPolicy,
    ClusterConfig,
    ClusterSimulator,
    GenerativeConfig,
    GenerativeEngine,
    GenRequest,
    PlatformConfig,
    make_gen_requests,
    make_requests,
    maf_trace,
    offered_decode_qps,
    summarize,
    summarize_generative,
)

PROF = build_profile(
    get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied"),
    mode="decode", chips=1, charge_kv=True,
)
CPROF = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)


def _mix_requests(n=40, *, long_every=5, long_prompt=512, short_prompt=32,
                  long_tokens=4, short_tokens=16, load=0.7, seed=1):
    """Long-prompt + short-decode mix: the workload where an unchunked
    prefill stalls every in-flight decode slot."""
    qps = offered_decode_qps(PROF, max_batch_size=8,
                             tokens_per_request=short_tokens, load=load)
    arr = maf_trace(n, mean_qps=qps, seed=seed)
    reqs = []
    for k, t in enumerate(arr):
        long = (k % long_every) == long_every - 1
        reqs.append(GenRequest(
            rid=k, arrival_ms=float(t), slo_ms=3 * PROF.vanilla_time(1), item=k,
            prompt_len=long_prompt if long else short_prompt,
            n_tokens=long_tokens if long else short_tokens,
        ))
    return reqs


# -- LatencyProfile.prefill_chunk_time ---------------------------------------


def test_prefill_chunk_time_physics():
    """Roofline chunk model: zero for empty chunks, monotone in the chunk,
    and sub-additive (weight reads amortize across a merged chunk) while
    never beating the pure-compute bound."""
    assert PROF.prefill_chunk_time(0) == 0.0
    assert PROF.prefill_chunk_time(-3) == 0.0
    ts = [PROF.prefill_chunk_time(n) for n in (1, 4, 16, 64, 256)]
    assert all(b >= a - 1e-15 for a, b in zip(ts, ts[1:]))
    assert ts[0] > 0.0
    for a, b in ((1, 7), (16, 16), (64, 192)):
        merged = PROF.prefill_chunk_time(a + b)
        split = PROF.prefill_chunk_time(a) + PROF.prefill_chunk_time(b)
        assert merged <= split + 1e-12
    # compute lower bound: flops of the chunk can never be beaten
    from repro.core.profiles import PEAK_FLOPS
    n = 128
    lb = float(PROF.layer_flops.sum()) * n / (PEAK_FLOPS * PROF.flops_scale) * 1e3
    assert PROF.prefill_chunk_time(n) >= lb - 1e-12


# -- engine-level chunked prefill --------------------------------------------


def test_chunked_prefill_conserves_tokens_and_unstalls_tpt():
    """The acceptance scenario: on the long-prompt + short-decode mix,
    chunking must (a) serve exactly the same tokens, (b) cut TPT p95 (no
    decode slot stalls behind a 512-token prefill), and (c) keep TTFT
    within the interleave bound (one co-scheduled decode step per chunk)."""
    reqs = _mix_requests()
    un = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8))
    mu = summarize_generative(un.run(reqs), horizon_ms=un.makespan_ms)
    chunk = 64
    ch = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8, prefill_chunk=chunk))
    mc = summarize_generative(ch.run(reqs), horizon_ms=ch.makespan_ms)
    assert mc["tokens"] == mu["tokens"] == sum(q.n_tokens for q in reqs)
    assert ch.n_chunks >= sum(-(-q.prompt_len // chunk) for q in reqs)
    # chunk pricing is linear, so total prefill time matches the serial path
    total_serial = sum(un.prefill_ms(q.prompt_len) for q in reqs)
    np.testing.assert_allclose(ch.chunk_ms, total_serial, rtol=1e-9)
    # the TPT tail no longer eats whole prefills
    assert mc["tpt_p95_ms"] < mu["tpt_p95_ms"]
    # TTFT pays at most the co-scheduled decode steps between chunks
    max_chunks = max(-(-q.prompt_len // chunk) for q in reqs)
    bound = mu["ttft_p95_ms"] + max_chunks * PROF.vanilla_time(8)
    assert mc["ttft_p95_ms"] <= bound + 1e-9


def test_chunked_prefill_degenerate_cases():
    """chunk >= prompt_len behaves like one chunk (first token still
    releases at a step boundary); single-token requests finish right
    after their prefill completes; invalid chunk sizes are rejected."""
    reqs = make_gen_requests(maf_trace(6, mean_qps=4.0, seed=0), n_tokens=1,
                             prompt_len=16, slo_ms=3 * PROF.vanilla_time(1))
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=2, prefill_chunk=64))
    resp = eng.run(reqs)
    assert sorted(r.rid for r in resp) == list(range(6))
    assert all(len(r.tokens) == 1 for r in resp)
    assert eng.n_chunks == 6
    # a zero-length prompt has no chunks to schedule: the first token still
    # releases at the next step boundary and decode proceeds
    z = [GenRequest(rid=0, arrival_ms=0.0, slo_ms=float("inf"), item=0,
                    prompt_len=0, n_tokens=3)]
    ez = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=2, prefill_chunk=8))
    rz = ez.run(z)
    assert len(rz) == 1 and len(rz[0].tokens) == 3 and ez.chunk_ms == 0.0
    with pytest.raises(ValueError):
        GenerativeEngine(PROF, GenerativeConfig(prefill_chunk=-1))


def test_chunked_prefill_with_ee_runner_keeps_invariants():
    """Chunking composes with per-token early exits: same token count,
    slots never exceed capacity, and the controller still adapts."""
    from repro.core import ApparateController, ControllerConfig
    from repro.serving import SyntheticDecodeRunner

    ns = len(PROF.sites)
    reqs = _mix_requests(n=30, load=1.2, seed=3)
    ctl = ApparateController(ns, PROF, ControllerConfig(max_slots=4))
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=4, prefill_chunk=64),
                           SyntheticDecodeRunner(ns, exit_site=ns // 3), ctl)
    resp = eng.run(reqs)
    assert sum(len(r.tokens) for r in resp) == sum(q.n_tokens for q in reqs)
    assert eng.peak_slots <= 4 and max(eng.slot_history) <= 4
    assert ctl.stats["samples"] > 0


# -- DecodeRunner: resumable prefill against the real slot cache --------------


@pytest.fixture(scope="module", params=["ref", "paged"])
def chunk_runners(request):
    import jax

    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.serving import DecodeRunner

    cfg = get_tiny("qwen2-1.5b").replace(n_layers=3, vocab_size=128,
                                         decode_attn=request.param)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    prompts = np.random.default_rng(5).integers(0, 128, (8, 12)).astype(np.int32)
    kw = dict(max_new_tokens=8, max_slots=3)
    if request.param == "paged":
        kw["kv_block_size"] = 4  # 12 prompt tokens -> 3 blocks
    mk = lambda: DecodeRunner(model, params, prompts, **kw)  # noqa: E731
    return mk(), mk()


def test_decode_runner_resumable_prefill_matches_one_shot(chunk_runners):
    """prefill_begin + prefill_resume must land the slot at the same
    position, same paged-block footprint, and (argmax-stable untrained
    model) the same greedy continuation as a one-shot start()."""
    full, chunked = chunk_runners
    t_full = full.start(0, 1)
    assert chunked.prefill_begin(0, 1, 5) is None
    assert chunked.prefill_resume(0, 5) is None
    t_ch = chunked.prefill_resume(0, 2)  # 5 + 5 + 2 = 12 prompt tokens
    assert isinstance(t_ch, int)
    assert chunked._pos[0] == full._pos[0] == 12
    if full.paged:
        assert full._alloc.owned_ids(0) == chunked._alloc.owned_ids(0)
    traj = {"full": [t_full], "chunked": [t_ch]}
    for _ in range(4):
        _, _, ff = full.step([0], [0, 2])
        _, _, fc = chunked.step([0], [0, 2])
        traj["full"].append(int(ff[0]))
        traj["chunked"].append(int(fc[0]))
    agree = np.mean([a == b for a, b in zip(traj["full"], traj["chunked"])])
    assert agree >= 0.8, traj  # cross-path numerics may flip rare argmax ties
    # a whole-prompt "chunk" IS start(): identical return, identical state
    assert full.start(1, 3) == chunked.prefill_begin(1, 3, 100)
    for r in (full, chunked):
        r.free(0)
        r.free(1)


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_admission_refuses_overflowing_prompts(layout):
    """Regression: ``start``/``prefill_begin`` must refuse AT ADMISSION
    when prompt_len + max_new exceeds the slot cache capacity sized at
    construction — silent overflow clamps the contiguous scatter tail
    (contiguous) or walks another slot's blocks (paged). The stale-
    capacity hazard is real: the engine may swap the prompts array for a
    longer one after the runner was built."""
    import jax

    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.serving import DecodeRunner

    cfg = get_tiny("qwen2-1.5b").replace(
        n_layers=3, vocab_size=128,
        decode_attn="paged" if layout == "paged" else "ref",
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    prompts = np.random.default_rng(5).integers(0, 128, (4, 12)).astype(np.int32)
    kw = dict(max_new_tokens=8, max_slots=2)
    if layout == "paged":
        kw["kv_block_size"] = 4  # capacity 5 blocks x 4 = 20 tokens
    runner = DecodeRunner(model, params, prompts, **kw)
    # in-capacity admission is untouched
    runner.start(0, 0)
    runner.free(0)
    # the hazard: a wider prompts array lands after construction
    runner.prompts = np.random.default_rng(6).integers(
        0, 128, (4, 16)
    ).astype(np.int32)  # 16 + 8 = 24 > 20
    with pytest.raises(ValueError, match="cannot admit"):
        runner.start(0, 0)
    with pytest.raises(ValueError, match="cannot admit"):
        runner.prefill_begin(0, 0, 4)
    # nothing was admitted, no blocks leaked
    assert not runner._live
    if runner.paged:
        assert runner._alloc.live_blocks == 0


def test_decode_runner_midprefill_guards(chunk_runners):
    """A mid-prefill slot must refuse decode steps, and freeing it must
    release its prefill progress (and paged blocks) cleanly."""
    full, chunked = chunk_runners
    assert chunked.prefill_begin(2, 0, 4) is None
    with pytest.raises(KeyError):
        chunked.step([2], [0])
    if chunked.paged:
        assert chunked._alloc.owned[2] > 0
    chunked.free(2)
    if chunked.paged:
        assert chunked._alloc.owned[2] == 0
    with pytest.raises(KeyError):
        chunked.prefill_resume(2, 4)
    # tiny chunks are rejected only below one token
    with pytest.raises(ValueError):
        chunked.prefill_begin(2, 0, 0)


def test_engine_chunked_with_real_decode_runner():
    """End to end: the engine's chunked path drives DecodeRunner's
    resumable prefill (prefill_begin/prefill_resume) against the real
    slot cache — conservation + agreement bookkeeping intact."""
    import jax

    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.core import ApparateController, ControllerConfig
    from repro.serving import DecodeRunner

    cfg = get_tiny("qwen2-1.5b").replace(n_layers=3, vocab_size=128, decode_attn="ref")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    prompts = np.random.default_rng(7).integers(0, 128, (16, 12)).astype(np.int32)
    ns = len(model.sites)
    prof_cfg = get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied")
    sites = [round((i + 1) * prof_cfg.n_layers / (ns + 1)) - 1 for i in range(ns)]
    prof = build_profile(prof_cfg, mode="decode", chips=1, sites=sites, charge_kv=True)
    runner = DecodeRunner(model, params, prompts, max_new_tokens=8, max_slots=3)
    ctl = ApparateController(ns, prof, ControllerConfig(max_slots=3))
    qps = offered_decode_qps(prof, max_batch_size=3, tokens_per_request=5, load=0.8)
    reqs = make_gen_requests(maf_trace(8, mean_qps=qps, seed=8), n_tokens=5,
                             prompt_len=12, slo_ms=3 * prof.vanilla_time(1))
    eng = GenerativeEngine(prof, GenerativeConfig(max_batch_size=3, prefill_chunk=5),
                           runner, ctl)
    resp = eng.run(reqs)
    assert sum(len(r.tokens) for r in resp) == sum(q.n_tokens for q in reqs)
    assert eng.n_chunks >= 8 * 3  # ceil(12 / 5) chunks per request
    assert runner._pf_progress == {}  # every chunked prefill completed
    m = summarize_generative(resp, horizon_ms=eng.makespan_ms)
    assert m["agreement"] >= 0.9


# -- SLO-aware admission ------------------------------------------------------


def test_generative_admission_drops_hopeless_streams():
    """A per-token SLO tighter than even an unbatched decode step is
    hopeless: the stream is dropped at admission (no slot wasted), while
    feasible requests are served in full."""
    arr = maf_trace(20, mean_qps=offered_decode_qps(
        PROF, max_batch_size=4, tokens_per_request=8, load=0.5), seed=2)
    hopeless = {k for k in range(20) if k % 4 == 0}
    reqs = [GenRequest(rid=k, arrival_ms=float(t),
                       slo_ms=(0.1 if k in hopeless else 1e9),
                       item=k, prompt_len=16, n_tokens=8)
            for k, t in enumerate(arr)]
    adm = AdmissionPolicy()
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=4), admission=adm)
    resp = eng.run(reqs)
    assert sorted(r.rid for r in resp) == list(range(20))  # drops still answer
    dropped = {r.rid for r in resp if r.dropped}
    assert dropped == hopeless
    assert all(len(r.tokens) == 0 for r in resp if r.dropped)
    assert all(len(r.tokens) == 8 for r in resp if not r.dropped)
    m = summarize_generative(resp, horizon_ms=eng.makespan_ms)
    assert m["dropped"] == len(hopeless) and m["shed"] == 0.0
    assert adm.stats()["admit_drops"] == len(hopeless)


def test_generative_midstream_shed_frees_doomed_slots():
    """A live slot whose observed TPT violates its SLO for `shed_after`
    consecutive tokens is shed at the step boundary: partial tokens kept,
    response marked, slot freed for other work."""
    step8 = PROF.vanilla_time(8)
    arr = maf_trace(24, mean_qps=offered_decode_qps(
        PROF, max_batch_size=8, tokens_per_request=16, load=1.5), seed=4)
    # SLO between the B=1 and B=8 step times: admissible at admission, but
    # doomed whenever the batch actually fills up
    slo = 0.5 * (PROF.vanilla_time(1) + step8)
    assert PROF.vanilla_time(1) < slo < step8
    reqs = make_gen_requests(arr, n_tokens=16, prompt_len=16, slo_ms=slo)
    adm = AdmissionPolicy(AdmissionConfig(shed_after=2))
    eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8), admission=adm)
    resp = eng.run(reqs)
    shed = [r for r in resp if r.shed]
    assert shed and eng.n_shed == len(shed) == int(adm.stats()["sheds"])
    assert all(0 < len(r.tokens) < 16 for r in shed)  # partial streams kept
    m = summarize_generative(resp, horizon_ms=eng.makespan_ms)
    assert m["shed"] == len(shed)
    assert eng.stats()["shed"] == len(shed)
    # without the policy, the same workload sheds nothing
    base = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8))
    assert all(not r.shed for r in base.run(reqs))


def test_classification_admission_drops_at_arrival():
    """Classification adapter: a request whose earliest estimated
    completion already misses its deadline is dropped at arrival (batch
    size 0, dropped=True), under exactly the backlog estimate the
    slo_aware dispatcher ranks by."""
    exec1 = CPROF.vanilla_time(1)
    arr = maf_trace(200, mean_qps=3.0 * 8 * 1000.0 / CPROF.vanilla_time(8), seed=6)
    reqs = make_requests(arr, slo_ms=1.5 * exec1)  # tight SLO under 3x load
    pf = PlatformConfig(policy="tfserve", max_batch_size=8, batch_timeout_ms=exec1)
    cc = ClusterConfig(n_workers=2, dispatch="jsq", platform=pf,
                       admission=AdmissionPolicy())
    sim = ClusterSimulator(CPROF, cc)
    resp = sim.run(reqs)
    assert sorted(r.rid for r in resp) == list(range(200))
    dropped = [r for r in resp if r.dropped]
    served = [r for r in resp if not r.dropped]
    assert dropped and served
    assert all(r.batch_size == 0 for r in dropped)
    # admission control keeps the served tail inside the SLO ballpark the
    # un-gated cluster blows through
    base = ClusterSimulator(CPROF, ClusterConfig(n_workers=2, dispatch="jsq", platform=pf))
    mb = summarize(base.run(reqs), horizon_ms=base.makespan_ms)
    mo = summarize(resp, horizon_ms=sim.makespan_ms)
    assert mo["p95_ms"] < mb["p95_ms"]
    assert cc.admission.stats()["admit_drops"] == len(dropped)


def test_shed_streaks_do_not_leak_across_streams():
    """Regression: a stream ending mid-streak used to leave its violation
    count in AdmissionPolicy._viol, so the next stream reusing the same
    (wid, slot, rid) key inherited it and shed early. The engine must
    forget a stream's streak when it finishes, so a reused policy behaves
    exactly like a fresh one."""
    step8 = PROF.vanilla_time(8)
    slo = 0.5 * (PROF.vanilla_time(1) + step8)
    arr = maf_trace(24, mean_qps=offered_decode_qps(
        PROF, max_batch_size=8, tokens_per_request=16, load=1.5), seed=4)
    reqs = make_gen_requests(arr, n_tokens=16, prompt_len=16, slo_ms=slo)

    def sheds(policy):
        eng = GenerativeEngine(PROF, GenerativeConfig(max_batch_size=8),
                               admission=policy)
        return sorted((r.rid, len(r.tokens)) for r in eng.run(reqs) if r.shed)

    reused = AdmissionPolicy(AdmissionConfig(shed_after=3))
    first = sheds(reused)
    second = sheds(reused)  # same policy, same key space (rids restart at 0)
    fresh = sheds(AdmissionPolicy(AdmissionConfig(shed_after=3)))
    assert first == fresh
    assert second == fresh  # no streak inherited across runs
    assert reused._viol == {}  # every ended stream forgot its streak


def test_admission_policy_validation_and_disable_flags():
    with pytest.raises(ValueError):
        AdmissionPolicy(AdmissionConfig(shed_after=0))
    off = AdmissionPolicy(AdmissionConfig(drop_on_admit=False, shed_mid_stream=False))
    r = GenRequest(rid=0, arrival_ms=0.0, slo_ms=0.001, item=0, prompt_len=4, n_tokens=4)
    assert off.admit_token_stream(r, 0.0, 10.0)  # dropping disabled
    assert not off.note_token("k", 100.0, 0.001)  # shedding disabled
    # infinite SLO is never dropped or shed
    on = AdmissionPolicy()
    rinf = GenRequest(rid=1, arrival_ms=0.0, slo_ms=float("inf"), item=0,
                      prompt_len=4, n_tokens=4)
    assert on.admit_token_stream(rinf, 0.0, 1e12)
    assert not on.note_token("k2", 1e12, float("inf"))
