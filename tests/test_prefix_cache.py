"""Prefix-sharing paged KV: trie hits, copy-on-write, swap preemption.

The equivalence bar everywhere is BIT-IDENTITY against a private-blocks
paged runner: shared prefix blocks hold KV produced by the same jit on
the same inputs, a partial hit runs the SAME one-shot prefill program
with cached chunks' scatters redirected to the trash block, CoW copies
whole physical blocks, and a swap round-trip restores identical content
into private blocks. Geometry note: ``kv_block_size`` must divide
``prompt_len + max_new_tokens`` for paged-vs-paged bit-identity, while
``prompt_len % kv_block_size != 0`` keeps a partial tail block in play
(tail entries in the trie, CoW on the first decode append after a hit).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, get_tiny
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.models import build_model
from repro.serving import (
    DecodeRunner,
    GenerativeConfig,
    GenerativeEngine,
    GenRequest,
    PoolExhausted,
)

MAX_NEW = 10  # cache_len = 14 + 10 = 24 = 6 blocks of 4 (bs | cache_len)
KW = dict(max_new_tokens=MAX_NEW, max_slots=3, n_slots=4, kv_block_size=4)


@pytest.fixture(scope="module")
def setup():
    cfg = get_tiny("qwen2-1.5b").replace(n_layers=2, vocab_size=64, decode_attn="paged")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(9))
    prompts = np.random.default_rng(10).integers(0, 64, (10, 14)).astype(np.int32)
    prompts[3, :8] = prompts[2, :8]  # items 2/3 share a 2-block prefix
    return cfg, model, params, prompts


@pytest.fixture(scope="module")
def pair(setup):
    """(private, prefix) paged runners over the same model/params — jits
    are identical, so records must match bit-for-bit. Module-scoped (a
    fresh runner per test would recompile); tests free their slots and
    compare counter DELTAS."""
    _, model, params, prompts = setup
    return (
        DecodeRunner(model, params, prompts, **KW),
        DecodeRunner(model, params, prompts, prefix_cache=True, **KW),
    )


def _steps_equal(pv, pr, sv, sr, n, act=(0,)):
    for _ in range(n):
        lv, uv, fv = pv.step(sv, list(act))
        lr, ur, fr = pr.step(sr, list(act))
        np.testing.assert_array_equal(lr, lv)
        np.testing.assert_array_equal(ur, uv)
        np.testing.assert_array_equal(fr, fv)


def test_full_prefix_hit_is_free_and_bit_identical(pair):
    """A repeated prompt costs ZERO device work (cached first token, no
    prefill dispatch) and the sharing slot's decode records stay
    bit-identical — including after copy-on-write moves both slots off
    the shared tail block."""
    pv, pr = pair
    assert pv.start(0, 0) == pr.start(0, 0)  # cold: registers item 0
    cache_obj = pr._cache
    assert pv.start(1, 0) == pr.start(1, 0)  # hot: whole-prompt hit
    assert pr._cache is cache_obj  # the hit touched no device state
    st = pr.kv_stats()
    assert st["prefix_hits"] == 1 and st["prefix_tokens_saved"] == 14
    assert st["saved_blocks"] == 4  # 3 full chunks + the tail block
    assert st["shared_blocks"] >= 4
    # first decode append of each slot lands INSIDE the shared tail block
    # (14 % 4 != 0) -> CoW; steps must stay bit-identical throughout
    _steps_equal(pv, pr, [0, 1], [0, 1], 4)
    assert pr.cow_copies >= 2  # both slots were moved off the shared tail
    # sharing is real dedup: fewer live blocks than private for same state
    assert pr._alloc.live_blocks < pv._alloc.live_blocks
    # CoW never mutated the CACHED copy: a third slot still hits the full
    # prompt and gets the same first token as a private prefill
    assert pv.start(2, 0) == pr.start(2, 0)
    assert pr.kv_stats()["prefix_hits"] == 2
    for r in pair:
        for s in (0, 1, 2):
            r.free(s)


def test_partial_prefix_hit_bit_identical(pair):
    """Prompts sharing only a prefix share only those whole blocks: the
    hit re-runs the one-shot prefill jit with the cached chunks' scatters
    pointed at the trash block, so the slot state (and every subsequent
    record) is bit-identical to a private prefill."""
    pv, pr = pair
    st0 = pr.kv_stats()
    assert pv.start(0, 2) == pr.start(0, 2)  # cold
    assert pv.start(1, 3) == pr.start(1, 3)  # shares blocks 0-1 (8 tokens)
    st = pr.kv_stats()
    assert st["prefix_hits"] - st0["prefix_hits"] == 1
    assert st["prefix_tokens_saved"] - st0["prefix_tokens_saved"] == 8
    assert st["saved_blocks"] - st0["saved_blocks"] == 2
    shared2 = set(pr._alloc.owned_ids(0)[:2])
    assert set(pr._alloc.owned_ids(1)[:2]) == shared2  # same physical ids
    _steps_equal(pv, pr, [0, 1], [0, 1], 4)
    for r in pair:
        r.free(0)
        r.free(1)


def test_swap_round_trip_bit_identical(pair):
    """swap_out -> swap_in (into a DIFFERENT slot) restores the stream's
    blocks bit-identically, so the continued trajectory matches a runner
    that never swapped. Guards: contiguous runners, dead slots, and
    mid-prefill slots all refuse to swap."""
    pv, pr = pair
    assert pv.start(0, 4) == pr.start(0, 4)
    _steps_equal(pv, pr, [0], [0], 2)
    live0 = pr._alloc.live_blocks
    h = pr.swap_out(0)
    assert h["n_blocks"] == 4 and h["pos"] == 16  # writes 14/15 fit block 3
    assert pr._alloc.live_blocks < live0  # the pool space is returned
    with pytest.raises(KeyError):
        pr.swap_out(0)  # already retired
    pr.swap_in(3, h)
    st = pr.kv_stats()
    assert st["swap_outs"] >= 1 and st["swap_ins"] >= 1
    assert st["swapped_blocks"] >= 4
    _steps_equal(pv, pr, [0], [3], 4)  # continued stream is unchanged
    pv.free(0)
    pr.free(3)
    # mid-prefill slots cannot swap (their pool blocks are half-filled)
    assert pr.prefill_begin(1, 5, 4) is None
    with pytest.raises(KeyError):
        pr.swap_out(1)
    pr.free(1)
    cont = DecodeRunner(
        build_model(pv.model.cfg.replace(decode_attn="ref")),
        pv.params, pv.prompts, max_new_tokens=MAX_NEW,
    )
    with pytest.raises(ValueError):
        cont.swap_out(0)


def test_prefill_resume_rejects_nonpositive_chunks(pair):
    """Regression (satellite): ``prefill_resume`` with a <1-token chunk
    used to silently no-op — the engine's accounting then believed the
    chunk was fed and the prefill never finished. It must raise."""
    _, pr = pair
    assert pr.prefill_begin(1, 6, 4) is None
    for bad in (0, -3):
        with pytest.raises(ValueError):
            pr.prefill_resume(1, bad)
    assert isinstance(pr.prefill_resume(1, 20), int)  # finishes cleanly
    pr.free(1)


def test_prefix_eviction_under_pressure(setup):
    """A pool too small to cache every prompt evicts LRU cache-only
    entries instead of failing admission: every start succeeds, evictions
    are counted, and clearing the cache fully drains the pool."""
    _, model, params, prompts = setup
    r = DecodeRunner(model, params, prompts, prefix_cache=True,
                     max_new_tokens=MAX_NEW, max_slots=3, n_slots=2,
                     kv_block_size=4, kv_blocks=8)
    first = {}
    for item in range(6):  # 6 prompts x 4 blocks vs an 8-block pool
        first[item] = r.start(0, item)
        r.free(0)
    st = r.kv_stats()
    assert st["prefix_evictions"] > 0
    assert st["pinned_blocks"] <= 8
    # the most recent prompt survived eviction: still a full (free) hit
    cache_obj = r._cache
    assert r.start(0, 5) == first[5]
    assert r._cache is cache_obj
    r.free(0)
    r._prefix.clear()
    assert r._alloc.pins == 0 and r._alloc.live_blocks == 0


def _engine_profile(model):
    ns = len(model.sites)
    prof_cfg = get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied")
    sites = [round((i + 1) * prof_cfg.n_layers / (ns + 1)) - 1 for i in range(ns)]
    return build_profile(prof_cfg, mode="decode", chips=1, sites=sites, charge_kv=True)


def test_engine_chunked_prefill_with_prefix_cache(setup):
    """The engine's chunked-prefill path composes with prefix sharing:
    cached whole chunks are skipped (priced via ``pf_skip``), every
    prefill completes, and token conservation holds on a hot-prefix
    request stream (each item requested twice)."""
    _, model, params, prompts = setup
    prof = _engine_profile(model)
    runner = DecodeRunner(model, params, prompts, prefix_cache=True,
                          max_new_tokens=MAX_NEW, max_slots=3, n_slots=4,
                          kv_block_size=4)
    ctl = ApparateController(len(model.sites), prof, ControllerConfig(max_slots=3))
    reqs = [GenRequest(rid=k, arrival_ms=2.0 * k, slo_ms=float("inf"),
                       item=k % 4, prompt_len=14, n_tokens=5)
            for k in range(8)]
    eng = GenerativeEngine(prof, GenerativeConfig(max_batch_size=3, prefill_chunk=6),
                           runner, ctl)
    resp = eng.run(reqs)
    assert sum(len(r.tokens) for r in resp) == sum(q.n_tokens for q in reqs)
    assert runner._pf_progress == {}  # every chunked prefill completed
    st = runner.kv_stats()
    assert st["prefix_hits"] > 0 and st["prefix_tokens_saved"] > 0


def test_engine_swap_preemption_completes_what_shed_drops(setup):
    """Acceptance: on a pool that fits only 2 of 4 admitted streams,
    'shed' discards victims' work while 'swap' parks them in host memory
    and finishes ALL streams — with final tokens identical to an
    uncontended (full-pool) run."""
    _, model, params, prompts = setup
    prof = _engine_profile(model)
    reqs = [GenRequest(rid=k, arrival_ms=0.0, slo_ms=float("inf"), item=k,
                       prompt_len=14, n_tokens=6) for k in range(10)]

    def run(preempt, kv_blocks):
        runner = DecodeRunner(model, params, prompts, max_new_tokens=MAX_NEW,
                              max_slots=3, n_slots=4, kv_block_size=4,
                              kv_blocks=kv_blocks)
        ctl = ApparateController(len(model.sites), prof, ControllerConfig(max_slots=3))
        eng = GenerativeEngine(
            prof, GenerativeConfig(max_batch_size=4, preempt=preempt), runner, ctl)
        return eng, eng.run(reqs)

    # a full stream needs ceil((14 + 6) / 4) = 5 blocks; 12 fit only 2
    es, rs = run("shed", 12)
    ew, rw = run("swap", 12)
    eu, ru = run("none", None)
    done = lambda rr: {r.rid: tuple(r.tokens) for r in rr if len(r.tokens) == 6}
    assert len(done(ru)) == 10  # uncontended baseline serves everything
    assert len(done(rs)) < 10 and es.n_preempt_sheds > 0  # shed loses work
    assert len(done(rw)) == 10  # swap completes every stream
    assert ew.n_preempt_swaps > 0 and ew.n_swap_ins > 0
    assert done(rw) == done(ru)  # swapped trajectories are unchanged
    st = ew.stats()
    assert st["preempt_swaps"] == ew.n_preempt_swaps
    assert st["swap_ins"] == ew.n_swap_ins


def test_zero_token_shed_keeps_metrics_finite(setup):
    """A mid-prefill preemption victim is shed with NO released tokens;
    ``summarize_generative`` must count it under ``shed`` without
    indexing its empty ``release_ms`` (regression: IndexError when
    --prefill-chunk, --admission and --preempt met on a tight pool)."""
    from repro.serving.metrics import summarize_generative
    from repro.serving.request import GenResponse

    # unit repro: one normal stream + one zero-token shed
    ok = GenResponse(rid=0, arrival_ms=0.0, release_ms=[1.0, 2.0],
                     exit_sites=[-1, -1], tokens=[3, 4], final_tokens=[3, 4],
                     worker=0, slo_ms=float("inf"))
    cut = GenResponse(rid=1, arrival_ms=0.0, release_ms=[], exit_sites=[],
                      tokens=[], final_tokens=[], worker=0,
                      slo_ms=float("inf"), shed=True)
    mo = summarize_generative([ok, cut])
    assert mo["n"] == 2.0 and mo["shed"] == 1.0 and mo["tokens"] == 2.0
    assert np.isfinite(mo["ttft_p50_ms"])
    only = summarize_generative([cut])  # every voiced stream gone
    assert only["shed"] == 1.0 and only["tokens"] == 0.0

    # engine repro: chunked prefill + swap preemption on a pool too small
    # for concurrent prefills forces the prefilling-victim shed path
    _, model, params, prompts = setup
    prof = _engine_profile(model)
    runner = DecodeRunner(model, params, prompts, max_new_tokens=MAX_NEW,
                          max_slots=3, n_slots=4, kv_block_size=4,
                          kv_blocks=8)
    ctl = ApparateController(len(model.sites), prof, ControllerConfig(max_slots=3))
    eng = GenerativeEngine(
        prof, GenerativeConfig(max_batch_size=4, preempt="swap",
                               prefill_chunk=5), runner, ctl)
    reqs = [GenRequest(rid=k, arrival_ms=0.0, slo_ms=float("inf"), item=k,
                       prompt_len=14, n_tokens=6) for k in range(8)]
    resp = eng.run(reqs)
    mo = summarize_generative(resp, horizon_ms=eng.makespan_ms)
    assert mo["n"] == 8.0  # every admitted stream is accounted for
    zero_shed = [r for r in resp if r.shed and not r.release_ms]
    assert eng.n_preempt_sheds >= len(zero_shed)
    assert all(np.isfinite(v) for v in mo.values())


def test_serve_flags_require_paged():
    from repro.launch.serve import serve_generative

    with pytest.raises(ValueError):
        serve_generative(n=2, prefix_cache=True)
    with pytest.raises(ValueError):
        serve_generative(n=2, preempt="swap")
    # runner-level analogue of the same contract
    ref = build_model(get_tiny("qwen2-1.5b").replace(
        n_layers=2, vocab_size=64, decode_attn="ref"))
    with pytest.raises(ValueError):
        DecodeRunner(ref, None, np.zeros((1, 4), np.int32), prefix_cache=True)
