"""Batched single-dispatch decode must be EXACTLY the per-slot loop.

`DecodeRunner` (one batched slot cache, one jitted `model.decode` per
engine step with per-row positions) and `LoopDecodeRunner` (independent
B=1 caches, one dispatch per slot) must produce bit-identical
(ramp_labels, ramp_unc, final) records and identical greedy trajectories
across staggered admits/retires — slots at different decode positions,
freed slots reused mid-run — including the k=0 no-ramp variant. The
batched runner's only legitimate difference is its dispatch count.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_tiny
from repro.models import build_model
from repro.serving import DecodeRunner, LoopDecodeRunner


@pytest.fixture(scope="module", params=["ref", "dense"])
def runner_pair(request):
    """Untrained tiny LM (records are arbitrary but deterministic — ideal
    for equivalence). 'ref' routes decode attention through the
    flash-decode wrapper (`kernels/decode_attention.attend_decode` with a
    per-row pos array); 'dense' keeps the masked-sdpa path."""
    cfg = get_tiny("qwen2-1.5b").replace(
        n_layers=4, vocab_size=128, decode_attn=request.param
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(1).integers(0, 128, (10, 12)).astype(np.int32)

    def mk(cls, **kw):
        return cls(model, params, prompts, max_new_tokens=14, max_slots=3, **kw)

    return mk(DecodeRunner), mk(LoopDecodeRunner)


def _check_step(batched, loop, slots, active, tag):
    lb, ub, fb = batched.step(slots, active)
    ll, ul, fl = loop.step(slots, active)
    np.testing.assert_array_equal(lb, ll, err_msg=f"{tag}: ramp_labels")
    np.testing.assert_array_equal(ub, ul, err_msg=f"{tag}: ramp_unc")
    np.testing.assert_array_equal(fb, fl, err_msg=f"{tag}: final")
    assert lb.dtype == ll.dtype and ub.dtype == ul.dtype and fb.dtype == fl.dtype
    return fb


def test_staggered_admits_and_retires_bit_identical(runner_pair):
    """The PR's acceptance scenario: slots admitted at different times (so
    their cache positions diverge), freed mid-run, and reused — every step
    record bit-identical between one batched dispatch and the B-dispatch
    loop."""
    batched, loop = runner_pair
    traj = {"batched": [], "loop": []}

    t0b = batched.start(0, 0)
    t0l = loop.start(0, 0)
    assert t0b == t0l
    _check_step(batched, loop, [0], [1], "lone slot")
    assert batched.start(2, 3) == loop.start(2, 3)  # staggered admit
    _check_step(batched, loop, [0, 2], [0, 2], "two staggered slots")
    assert batched.start(1, 5) == loop.start(1, 5)
    # caller passes slots in engine (sorted-sid) and arbitrary orders
    _check_step(batched, loop, [0, 1, 2], [2, 0], "three slots")
    _check_step(batched, loop, [2, 0, 1], [0, 1, 2], "permuted slot order")
    batched.free(2)
    loop.free(2)
    _check_step(batched, loop, [0, 1], [1], "after retire")
    # stepping a SUBSET while another slot stays live must not perturb the
    # idle slot (bucket padding never touches live-but-unstepped rows)
    _check_step(batched, loop, [1], [1], "subset step")
    _check_step(batched, loop, [0, 1], [1], "idle slot unperturbed")
    assert batched.start(2, 7) == loop.start(2, 7)  # slot reuse, fresh prompt
    for i in range(3):
        f = _check_step(batched, loop, [0, 1, 2], [0, 2], f"reused round {i}")
        traj["batched"].append(f)
    # all 4 rows live, 3 stepped: the bucket pad has no free row left and
    # must duplicate a stepped slot rather than touch live slot 2
    assert batched.start(3, 6) == loop.start(3, 6)
    _check_step(batched, loop, [0, 1, 3], [0, 2], "dup-padded subset")
    _check_step(batched, loop, [0, 1, 2, 3], [0, 2], "all four after dup pad")
    # one batched dispatch per step vs one per slot per step
    assert batched.dispatches == 12
    assert loop.dispatches == 1 + 2 + 3 + 3 + 2 + 1 + 2 + 3 * 3 + 3 + 4


def test_noramp_variant_bit_identical(runner_pair):
    """k=0 (controller bootstrap / budget-busted): the ramp-free compiled
    variant must also match exactly, with empty (0, B) record arrays."""
    batched, loop = runner_pair
    for s, item in ((0, 2), (1, 4)):
        assert batched.start(s, item) == loop.start(s, item)
    for i in range(3):
        lb, ub, fb = batched.step([0, 1], [])
        ll, ul, fl = loop.step([0, 1], [])
        assert lb.shape == ll.shape == (0, 2)
        assert ub.shape == ul.shape == (0, 2)
        np.testing.assert_array_equal(fb, fl, err_msg=f"noramp round {i}")
    batched.free(0)
    loop.free(0)
    with pytest.raises(KeyError):
        batched.step([0], [])
    with pytest.raises(KeyError):
        loop.step([0], [])


def test_greedy_trajectories_identical(runner_pair):
    """Whole-request greedy token streams (the agreement baseline the
    engine serves) must be identical token for token."""
    batched, loop = runner_pair
    n_tokens = 6
    seqs = {"batched": {0: [], 1: []}, "loop": {0: [], 1: []}}
    for name, r in (("batched", batched), ("loop", loop)):
        for s, item in ((0, 8), (1, 9)):
            seqs[name][s].append(r.start(s, item))
        for _ in range(n_tokens):
            _, _, fin = r.step([0, 1], [1, 2])
            for b, s in enumerate([0, 1]):
                seqs[name][s].append(int(fin[b]))
        for s in (0, 1):
            r.free(s)
    assert seqs["batched"] == seqs["loop"]


def test_engine_end_to_end_identical_records(runner_pair):
    """Through `GenerativeEngine` + a real `ApparateController` pair with
    identical configs: responses (tokens, exit sites, release times) must
    be identical — the engine semantics are unchanged by batching."""
    from repro.configs import get_config
    from repro.core import ApparateController, ControllerConfig, build_profile
    from repro.serving import (
        GenerativeConfig,
        GenerativeEngine,
        make_gen_requests,
        maf_trace,
        offered_decode_qps,
    )

    batched, loop = runner_pair
    ns = batched.n_sites
    prof_cfg = get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied")
    sites = [round((i + 1) * prof_cfg.n_layers / (ns + 1)) - 1 for i in range(ns)]
    prof = build_profile(prof_cfg, mode="decode", chips=1, sites=sites, charge_kv=True)
    qps = offered_decode_qps(prof, max_batch_size=3, tokens_per_request=5, load=0.8)
    reqs = make_gen_requests(
        maf_trace(6, mean_qps=qps, seed=2), n_tokens=5, prompt_len=12,
        slo_ms=3 * prof.vanilla_time(1),
    )
    resp = {}
    for name, r in (("batched", batched), ("loop", loop)):
        ctl = ApparateController(ns, prof, ControllerConfig(max_slots=3))
        eng = GenerativeEngine(prof, GenerativeConfig(max_batch_size=3), r, ctl)
        resp[name] = eng.run(reqs)
    for rb, rl in zip(resp["batched"], resp["loop"]):
        assert rb.rid == rl.rid
        assert rb.tokens == rl.tokens
        assert rb.final_tokens == rl.final_tokens
        assert rb.exit_sites == rl.exit_sites
        np.testing.assert_allclose(rb.release_ms, rl.release_ms, rtol=0, atol=0)
