"""Batched single-dispatch decode must be EXACTLY the per-slot loop.

`DecodeRunner` (one batched slot cache, one jitted `model.decode` per
engine step with per-row positions) and `LoopDecodeRunner` (independent
B=1 caches, one dispatch per slot) must produce bit-identical
(ramp_labels, ramp_unc, final) records and identical greedy trajectories
across staggered admits/retires — slots at different decode positions,
freed slots reused mid-run — including the k=0 no-ramp variant. The
batched runner's only legitimate difference is its dispatch count.

The 'paged' variant swaps the batched runner's cache for the paged block
pool (`decode_attn='paged'`, block allocator + per-slot block tables)
while the loop oracle stays contiguous — paging is a pure layout change,
so every record must STILL be bit-identical. Bit-identity needs the
block size to divide the cache length (then the paged gather reproduces
the contiguous softmax reduction exactly); non-dividing sizes are
numerically equal but only to rounding, and are covered by the kernel
tests in test_paged_kv.py.

`test_randomized_schedules_fuzz` drives hundreds of seeded random
admit/step/free/slot-reuse schedules through all four runners — the
hand-written schedules above pin the known-tricky corners, the fuzz
covers the schedule space.

The 'prefix' fuzz runner adds the prefix cache on top of the paged pool:
repeated items share physical prompt blocks (refcount > 1), whole-prompt
hits skip prefill entirely (the cached first token), and the first
decode write into a shared tail block copy-on-writes it. Its block size
(5) deliberately does NOT divide the prompt length (12), so every prompt
ends in a partial tail block — the CoW path runs constantly — while
still dividing cache_len (20) for bit-identity.

The 'sharded' variant (`test_sharded_runner_schedules_bit_identical`)
re-runs the same seeded schedule shapes through `ShardedDecodeRunner`
on a forced 4-device CPU mesh — tensor-parallel tp=2/tp=4 over the
paged pool (per-device KV shards) and dp=2 x tp=2 over the contiguous
cache — in a subprocess so the in-process fixtures keep their single
device. Sharding is a pure placement change: every record, on-device
exit site, and allocator field must STILL be bit-identical.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_tiny
from repro.models import build_model
from repro.serving import DecodeRunner, LoopDecodeRunner


@pytest.fixture(scope="module", params=["ref", "dense", "paged"])
def runner_pair(request):
    """Untrained tiny LM (records are arbitrary but deterministic — ideal
    for equivalence). 'ref' routes decode attention through the
    flash-decode wrapper (`kernels/decode_attention.attend_decode` with a
    per-row pos array); 'dense' keeps the masked-sdpa path; 'paged' runs
    the batched runner on the paged block pool against the contiguous
    'ref' loop oracle."""
    cfg = get_tiny("qwen2-1.5b").replace(
        n_layers=4, vocab_size=128, decode_attn=request.param
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(1).integers(0, 128, (10, 12)).astype(np.int32)
    kw = dict(max_new_tokens=14, max_slots=3)
    if request.param == "paged":
        # cache_len = 12 + 14 = 26 = 2 blocks of 13: bs | cache_len so the
        # paged gather is bit-identical to the contiguous layout
        batched = DecodeRunner(model, params, prompts, kv_block_size=13, **kw)
        loop = LoopDecodeRunner(
            build_model(cfg.replace(decode_attn="ref")), params, prompts, **kw
        )
        assert batched.paged
    else:
        batched = DecodeRunner(model, params, prompts, **kw)
        loop = LoopDecodeRunner(model, params, prompts, **kw)
    return batched, loop


def _check_step(batched, loop, slots, active, tag):
    lb, ub, fb = batched.step(slots, active)
    ll, ul, fl = loop.step(slots, active)
    np.testing.assert_array_equal(lb, ll, err_msg=f"{tag}: ramp_labels")
    np.testing.assert_array_equal(ub, ul, err_msg=f"{tag}: ramp_unc")
    np.testing.assert_array_equal(fb, fl, err_msg=f"{tag}: final")
    assert lb.dtype == ll.dtype and ub.dtype == ul.dtype and fb.dtype == fl.dtype
    return fb


def test_staggered_admits_and_retires_bit_identical(runner_pair):
    """The PR's acceptance scenario: slots admitted at different times (so
    their cache positions diverge), freed mid-run, and reused — every step
    record bit-identical between one batched dispatch and the B-dispatch
    loop."""
    batched, loop = runner_pair
    traj = {"batched": [], "loop": []}

    t0b = batched.start(0, 0)
    t0l = loop.start(0, 0)
    assert t0b == t0l
    _check_step(batched, loop, [0], [1], "lone slot")
    assert batched.start(2, 3) == loop.start(2, 3)  # staggered admit
    _check_step(batched, loop, [0, 2], [0, 2], "two staggered slots")
    assert batched.start(1, 5) == loop.start(1, 5)
    # caller passes slots in engine (sorted-sid) and arbitrary orders
    _check_step(batched, loop, [0, 1, 2], [2, 0], "three slots")
    _check_step(batched, loop, [2, 0, 1], [0, 1, 2], "permuted slot order")
    batched.free(2)
    loop.free(2)
    _check_step(batched, loop, [0, 1], [1], "after retire")
    # stepping a SUBSET while another slot stays live must not perturb the
    # idle slot (bucket padding never touches live-but-unstepped rows)
    _check_step(batched, loop, [1], [1], "subset step")
    _check_step(batched, loop, [0, 1], [1], "idle slot unperturbed")
    assert batched.start(2, 7) == loop.start(2, 7)  # slot reuse, fresh prompt
    for i in range(3):
        f = _check_step(batched, loop, [0, 1, 2], [0, 2], f"reused round {i}")
        traj["batched"].append(f)
    # all 4 rows live, 3 stepped: the bucket pad has no free row left and
    # must duplicate a stepped slot rather than touch live slot 2
    assert batched.start(3, 6) == loop.start(3, 6)
    _check_step(batched, loop, [0, 1, 3], [0, 2], "dup-padded subset")
    _check_step(batched, loop, [0, 1, 2, 3], [0, 2], "all four after dup pad")
    # one batched dispatch per step vs one per slot per step
    assert batched.dispatches == 12
    assert loop.dispatches == 1 + 2 + 3 + 3 + 2 + 1 + 2 + 3 * 3 + 3 + 4


def test_noramp_variant_bit_identical(runner_pair):
    """k=0 (controller bootstrap / budget-busted): the ramp-free compiled
    variant must also match exactly, with empty (0, B) record arrays."""
    batched, loop = runner_pair
    for s, item in ((0, 2), (1, 4)):
        assert batched.start(s, item) == loop.start(s, item)
    for i in range(3):
        lb, ub, fb = batched.step([0, 1], [])
        ll, ul, fl = loop.step([0, 1], [])
        assert lb.shape == ll.shape == (0, 2)
        assert ub.shape == ul.shape == (0, 2)
        np.testing.assert_array_equal(fb, fl, err_msg=f"noramp round {i}")
    batched.free(0)
    loop.free(0)
    with pytest.raises(KeyError):
        batched.step([0], [])
    with pytest.raises(KeyError):
        loop.step([0], [])


def test_greedy_trajectories_identical(runner_pair):
    """Whole-request greedy token streams (the agreement baseline the
    engine serves) must be identical token for token."""
    batched, loop = runner_pair
    n_tokens = 6
    seqs = {"batched": {0: [], 1: []}, "loop": {0: [], 1: []}}
    for name, r in (("batched", batched), ("loop", loop)):
        for s, item in ((0, 8), (1, 9)):
            seqs[name][s].append(r.start(s, item))
        for _ in range(n_tokens):
            _, _, fin = r.step([0, 1], [1, 2])
            for b, s in enumerate([0, 1]):
                seqs[name][s].append(int(fin[b]))
        for s in (0, 1):
            r.free(s)
    assert seqs["batched"] == seqs["loop"]


def test_engine_end_to_end_identical_records(runner_pair):
    """Through `GenerativeEngine` + a real `ApparateController` pair with
    identical configs: responses (tokens, exit sites, release times) must
    be identical — the engine semantics are unchanged by batching."""
    from repro.configs import get_config
    from repro.core import ApparateController, ControllerConfig, build_profile
    from repro.serving import (
        GenerativeConfig,
        GenerativeEngine,
        make_gen_requests,
        maf_trace,
        offered_decode_qps,
    )

    batched, loop = runner_pair
    ns = batched.n_sites
    prof_cfg = get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied")
    sites = [round((i + 1) * prof_cfg.n_layers / (ns + 1)) - 1 for i in range(ns)]
    prof = build_profile(prof_cfg, mode="decode", chips=1, sites=sites, charge_kv=True)
    qps = offered_decode_qps(prof, max_batch_size=3, tokens_per_request=5, load=0.8)
    reqs = make_gen_requests(
        maf_trace(6, mean_qps=qps, seed=2), n_tokens=5, prompt_len=12,
        slo_ms=3 * prof.vanilla_time(1),
    )
    resp = {}
    for name, r in (("batched", batched), ("loop", loop)):
        ctl = ApparateController(ns, prof, ControllerConfig(max_slots=3))
        eng = GenerativeEngine(prof, GenerativeConfig(max_batch_size=3), r, ctl)
        resp[name] = eng.run(reqs)
    for rb, rl in zip(resp["batched"], resp["loop"]):
        assert rb.rid == rl.rid
        assert rb.tokens == rl.tokens
        assert rb.final_tokens == rl.final_tokens
        assert rb.exit_sites == rl.exit_sites
        np.testing.assert_allclose(rb.release_ms, rl.release_ms, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# randomized-schedule fuzz: batched, loop, and paged runners in lockstep


N_SLOTS = 4
MAX_NEW = 8  # cache_len = 12 + 8 = 20: 5 blocks of 4 AND 4 blocks of 5
N_SCHEDULES = 300


@pytest.fixture(scope="module")
def fuzz_trio():
    """One runner of each kind, REUSED across every fuzz schedule (each
    fresh runner would recompile its jitted programs; reuse keeps the
    whole fuzz inside a handful of compiles). Slot reuse across schedules
    is exactly the production pattern: start() reclaims the row/blocks
    wholesale, so stale state from the previous schedule is dead. The
    prefix runner's cache ALSO persists across schedules, so later
    schedules hit hot prompts constantly and eviction churns (16 items x
    up to 3 pinned blocks vs a 16-block pool)."""
    cfg = get_tiny("qwen2-1.5b").replace(n_layers=3, vocab_size=128, decode_attn="ref")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    prompts = np.random.default_rng(3).integers(0, 128, (16, 12)).astype(np.int32)
    kw = dict(max_new_tokens=MAX_NEW, max_slots=3)
    paged_model = build_model(cfg.replace(decode_attn="paged"))
    return {
        "batched": DecodeRunner(model, params, prompts, **kw),
        "loop": LoopDecodeRunner(model, params, prompts, **kw),
        "paged": DecodeRunner(paged_model, params, prompts, kv_block_size=4, **kw),
        # bs=5 divides cache_len=20 (bit-identity) but NOT the prompt
        # length 12, so every cached prompt has a partial tail block:
        # full hits seed shared tails, and the first decode append after
        # one lands inside the shared block -> copy-on-write every time.
        "prefix": DecodeRunner(
            paged_model, params, prompts, kv_block_size=5, prefix_cache=True, **kw
        ),
    }


def _run_schedule(rng, runners, n_sites, sched_id):
    """One random admit/step/free/slot-reuse schedule, every record
    compared bit-for-bit across all runners (the loop is the oracle)."""
    live = {}  # slot -> decode steps taken
    for op_i in range(int(rng.integers(6, 16))):
        free_slots = [s for s in range(N_SLOTS) if s not in live]
        # a slot may take at most MAX_NEW - 1 decode steps after prefill
        steppable = [s for s in sorted(live) if live[s] < MAX_NEW - 1]
        ops = (["admit"] if free_slots else []) + (["step", "step"] if steppable else [])
        ops += ["free"] if live else []
        op = ops[int(rng.integers(len(ops)))]
        tag = f"schedule {sched_id} op {op_i} ({op})"
        if op == "admit":
            slot = int(free_slots[int(rng.integers(len(free_slots)))])
            item = int(rng.integers(16))
            toks = {name: r.start(slot, item) for name, r in runners.items()}
            assert len(set(toks.values())) == 1, f"{tag}: first tokens diverge"
            live[slot] = 0
        elif op == "step":
            k = int(rng.integers(1, len(steppable) + 1))
            subset = [int(s) for s in rng.permutation(steppable)[:k]]
            act = [int(s) for s in np.flatnonzero(rng.random(n_sites) < 0.6)]
            lo, uo, fo = runners["loop"].step(subset, act)
            for name in (n for n in runners if n != "loop"):
                lb, ub, fb = runners[name].step(subset, act)
                np.testing.assert_array_equal(lb, lo, err_msg=f"{tag}: {name} labels")
                np.testing.assert_array_equal(ub, uo, err_msg=f"{tag}: {name} unc")
                np.testing.assert_array_equal(fb, fo, err_msg=f"{tag}: {name} final")
            for s in subset:
                live[s] += 1
        else:
            slot = sorted(live)[int(rng.integers(len(live)))]
            for r in runners.values():
                r.free(slot)
            del live[slot]
    for s in list(live):
        for r in runners.values():
            r.free(s)


def test_randomized_schedules_fuzz(fuzz_trio):
    """Hundreds of seeded random schedules: admits into random free slots,
    random step subsets (staggered positions), random active-ramp sets
    (including k=0 no-ramp steps), random retires and slot reuse — every
    record bit-identical across batched/loop/paged/prefix runners."""
    rng = np.random.default_rng(0xA11CE)
    n_sites = fuzz_trio["batched"].n_sites
    for sched_id in range(N_SCHEDULES):
        _run_schedule(rng, fuzz_trio, n_sites, sched_id)
    # the paged pool must be fully drained after every slot was freed
    alloc = fuzz_trio["paged"]._alloc
    assert alloc.live_blocks == 0 and alloc.n_free == alloc.n_blocks
    # prefix runner: after freeing every slot, only cache pins keep
    # blocks alive; clearing the cache must drain the pool completely
    pr = fuzz_trio["prefix"]
    assert pr.saved_blocks > 0 and pr.cow_copies > 0, "fuzz never exercised sharing"
    pa = pr._alloc
    assert int(pa.refcount.sum()) == pa.pins  # only cache refs remain
    pr._prefix.clear()
    assert pa.pins == 0
    assert pa.live_blocks == 0 and pa.n_free == pa.n_blocks


# ---------------------------------------------------------------------------
# per-family fuzz: the mixer families the block pool newly covers, each
# driven through the same random-schedule harness against a contiguous
# loop oracle

N_FAMILY_SCHEDULES = 60

FAMILY_CONFIGS = {
    # paged MLA: block tables over the compressed {c, k_pe} latent streams
    # (the paged oracle IS the exact absorbed contiguous math, so the
    # dense oracle matches bit-for-bit)
    "mla": ("deepseek-v2-lite-16b", "dense"),
    # block-pooled SSM state: per-slot {conv, ssm} state pages (no
    # attention at all — the oracle impl is moot). The oracle is the
    # CONTIGUOUS batched runner, not the loop: XLA's SSM decode step is
    # not batch-size-invariant at the ULP level (B=1 vs B=2 dispatches
    # drift by one ulp), so the loop cannot be a bit-exact oracle here;
    # paged-vs-contiguous at the SAME batch shape isolates paging as a
    # pure layout change, which is the claim under test.
    "mamba": ("mamba2-2.7b", "dense"),
    # ring-paged local windows: slot = pos % W through the table. The
    # GLOBAL layers' paged oracle defers to `decode_attention_ref`, so the
    # loop oracle must route 'ref' too (same convention as `fuzz_trio`:
    # sdpa and the flash-decode ref differ by ULPs in scale/GQA order).
    "local": ("gemma3-4b", "ref"),
}


@pytest.fixture(scope="module", params=sorted(FAMILY_CONFIGS))
def family_pair(request):
    """Paged `DecodeRunner` vs contiguous `LoopDecodeRunner` oracle for
    each newly-paged mixer family. `decode_attn='paged'` is the jnp
    oracle path, so every record must be BIT-identical to the dense
    per-slot loop — paging is a pure layout change for every family."""
    name, oracle_attn = FAMILY_CONFIGS[request.param]
    cfg = get_tiny(name).replace(vocab_size=128, decode_attn=oracle_attn)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(8))
    prompts = np.random.default_rng(9).integers(0, 128, (16, 12)).astype(np.int32)
    # max_slots also bounds the active-ramp set a step may carry; the
    # deeper configs (gemma: 6 layers) expose more sites than 3
    kw = dict(max_new_tokens=MAX_NEW, max_slots=max(3, len(model.sites)))
    paged = DecodeRunner(
        build_model(cfg.replace(decode_attn="paged")), params, prompts,
        kv_block_size=4, **kw
    )
    assert paged.paged
    if request.param == "mamba":
        oracle = DecodeRunner(model, params, prompts, **kw)
        assert not oracle.paged
    else:
        oracle = LoopDecodeRunner(model, params, prompts, **kw)
    # _run_schedule treats the "loop" entry as the oracle
    return {"paged": paged, "loop": oracle}


def test_family_randomized_schedules_fuzz(family_pair):
    """Seeded random admit/step/free/slot-reuse schedules for MLA, mamba,
    and local-window configs: every record bit-identical between the
    paged runner and the contiguous loop oracle, and the block pool fully
    drained once every slot is freed."""
    rng = np.random.default_rng(0xFA111)
    n_sites = family_pair["paged"].n_sites
    for sched_id in range(N_FAMILY_SCHEDULES):
        _run_schedule(rng, family_pair, n_sites, sched_id)
    alloc = family_pair["paged"]._alloc
    assert alloc.live_blocks == 0 and alloc.n_free == alloc.n_blocks


# ---------------------------------------------------------------------------
# multi-step sync windows: step_multi vs the per-step path, bit for bit

STEPS_PER_SYNC = (1, 2, 4, 7)
N_WINDOW_SCHEDULES = 40


@pytest.fixture(scope="module")
def window_pairs():
    """(multi, oracle) `DecodeRunner` pairs — contiguous and paged —
    sharing one model/params/prompts. The multi runner takes whole sync
    windows (`step_multi`); the oracle is driven one `step` at a time
    (itself pinned bit-identical to `LoopDecodeRunner` by the fuzz above).
    The paged pool is generous (`kv_blocks=64`) so the window's up-front
    claim (blocks pre-claimed for steps an early exit then skips) never
    forces an eviction the per-step path wouldn't take."""
    cfg = get_tiny("qwen2-1.5b").replace(n_layers=3, vocab_size=128, decode_attn="ref")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    prompts = np.random.default_rng(5).integers(0, 128, (16, 12)).astype(np.int32)
    kw = dict(max_new_tokens=MAX_NEW, max_slots=3)
    paged_model = build_model(cfg.replace(decode_attn="paged"))
    pkw = dict(kv_block_size=4, kv_blocks=64, **kw)
    return {
        "contig": (DecodeRunner(model, params, prompts, **kw),
                   DecodeRunner(model, params, prompts, **kw)),
        "paged": (DecodeRunner(paged_model, params, prompts, **pkw),
                  DecodeRunner(paged_model, params, prompts, **pkw)),
    }


def _assert_alloc_equal(a, b, tag):
    """Full allocator-state equality (peak_blocks excluded: the window's
    transient over-claim legitimately raises the high-water mark)."""
    np.testing.assert_array_equal(a.table, b.table, err_msg=f"{tag}: block table")
    np.testing.assert_array_equal(a.owned, b.owned, err_msg=f"{tag}: owned")
    np.testing.assert_array_equal(a.refcount, b.refcount, err_msg=f"{tag}: refcount")
    assert (a.n_free, a.live_blocks) == (b.n_free, b.live_blocks), tag
    assert sorted(a._free) == sorted(b._free), f"{tag}: free set"


def _run_window_schedule(rng, pair, n_sites, tag0, allocs=None):
    from repro.core.exits import simulate_exits

    multi, oracle = pair
    live = {}  # slot -> decode steps taken
    for op_i in range(int(rng.integers(6, 14))):
        free_slots = [s for s in range(3) if s not in live]
        steppable = [s for s in sorted(live) if live[s] < MAX_NEW - 1]
        ops = (["admit"] if free_slots else []) + (["win", "win"] if steppable else [])
        ops += ["free"] if live else []
        op = ops[int(rng.integers(len(ops)))]
        tag = f"{tag0} op {op_i} ({op})"
        if op == "admit":
            slot = int(free_slots[int(rng.integers(len(free_slots)))])
            item = int(rng.integers(16))
            assert multi.start(slot, item) == oracle.start(slot, item), tag
            live[slot] = 0
        elif op == "win":
            nsub = int(rng.integers(1, len(steppable) + 1))
            subset = [int(s) for s in rng.permutation(steppable)[:nsub]]
            # ascending active set (engine passes sorted(ctl.active));
            # sometimes empty -> the no-ramp window variant
            act = [int(s) for s in np.flatnonzero(rng.random(n_sites) < 0.6)]
            # mix never-fires (0), rarely-fires, and often-fires thresholds
            # so windows run full length AND terminate early
            thr = rng.choice(
                [0.0, 0.3, 0.9, 0.999, 0.9999], size=len(act)
            ).astype(np.float32)
            n_req = int(rng.choice(STEPS_PER_SYNC))
            labels, unc, finals, exits = multi.step_multi(subset, act, n_req, thr)
            nd = finals.shape[0]
            # cache headroom: pos sits at prompt_len + live[s], cache_len =
            # prompt_len + MAX_NEW, so MAX_NEW - live[s] writes remain
            n_clamped = min(n_req, min(MAX_NEW - live[s] for s in subset))
            assert 1 <= nd <= n_clamped, tag
            thr_full = np.zeros(n_sites, np.float32)
            if act:
                thr_full[np.asarray(act)] = thr
            for t in range(nd):
                lo, uo, fo = oracle.step(subset, act)
                np.testing.assert_array_equal(labels[t], lo, err_msg=f"{tag} t={t}: labels")
                np.testing.assert_array_equal(unc[t], uo, err_msg=f"{tag} t={t}: unc")
                np.testing.assert_array_equal(finals[t], fo, err_msg=f"{tag} t={t}: final")
                # device exit decisions == host simulate_exits on the very
                # records the window streamed back (the replay contract)
                unc_m = np.zeros((len(subset), n_sites), np.float32)
                val_m = np.zeros((len(subset), n_sites), bool)
                for j, site in enumerate(act):
                    unc_m[:, site] = unc[t, j]
                    val_m[:, site] = True
                ex_host = simulate_exits(unc_m, val_m, thr_full, act)
                np.testing.assert_array_equal(exits[t], ex_host, err_msg=f"{tag} t={t}: exits")
            # a short window is EXACTLY "every row exited at its last step"
            if nd < n_clamped:
                assert (exits[nd - 1] >= 0).all(), tag
            for s in subset:
                live[s] += nd
            if allocs is not None:
                _assert_alloc_equal(*allocs, tag)
        else:
            slot = sorted(live)[int(rng.integers(len(live)))]
            multi.free(slot)
            oracle.free(slot)
            del live[slot]
    for s in list(live):
        multi.free(s)
        oracle.free(s)


@pytest.mark.parametrize("kind", ["contig", "paged"])
def test_sync_window_schedules_fuzz(window_pairs, kind):
    """Seeded random schedules: every executed window step bit-identical
    to the per-step path (labels/unc/finals), device exit sites identical
    to `simulate_exits` over the streamed records, early termination only
    when every row exited, and (paged) allocator state — block tables,
    refcounts, free SET — indistinguishable after every window."""
    pair = window_pairs[kind]
    allocs = None
    if kind == "paged":
        # allocators materialize on first start(); prime them
        for r in pair:
            r.start(0, 0)
            r.free(0)
        allocs = (pair[0]._alloc, pair[1]._alloc)
    rng = np.random.default_rng(0xF00D if kind == "contig" else 0xBEEF)
    n_sites = pair[0].n_sites
    for sched_id in range(N_WINDOW_SCHEDULES):
        _run_window_schedule(rng, pair, n_sites, f"{kind} schedule {sched_id}", allocs)
    if allocs is not None:
        for a in allocs:
            assert a.live_blocks == 0 and a.n_free == a.n_blocks
    # one dispatch per window: strictly fewer than the per-step oracle's
    assert pair[0].dispatches < pair[1].dispatches


def test_sync_window_single_step_bit_identical(window_pairs):
    """The pinned degenerate case: steps_per_sync=1 windows for a whole
    request are bit-identical to `step` — same records, same trajectory."""
    multi, oracle = window_pairs["contig"]
    assert multi.start(0, 3) == oracle.start(0, 3)
    assert multi.start(1, 5) == oracle.start(1, 5)
    thr = np.asarray([0.5, 0.5], np.float32)[: multi.n_sites]
    act = list(range(len(thr)))
    for i in range(MAX_NEW - 1):
        labels, unc, finals, exits = multi.step_multi([0, 1], act, 1, thr)
        assert finals.shape[0] == 1
        lo, uo, fo = oracle.step([0, 1], act)
        np.testing.assert_array_equal(labels[0], lo, err_msg=f"round {i}: labels")
        np.testing.assert_array_equal(unc[0], uo, err_msg=f"round {i}: unc")
        np.testing.assert_array_equal(finals[0], fo, err_msg=f"round {i}: final")
    for s in (0, 1):
        multi.free(s)
        oracle.free(s)


def test_step_validators_reject_bad_inputs(window_pairs):
    """Regression for the silent-truncation bug: an active set larger than
    max_slots used to be clipped by the record reshape (rows landing
    against the wrong sites); every runner now refuses. Plus the window's
    own argument contracts."""
    multi, oracle = window_pairs["contig"]
    multi.start(0, 1)
    oversize = [0] * (multi.max_slots + 1)
    with pytest.raises(ValueError, match="active ramp set"):
        multi.step([0], oversize)
    with pytest.raises(ValueError, match="active ramp set"):
        multi.step_multi([0], oversize, 2, np.zeros(len(oversize), np.float32))
    with pytest.raises(ValueError, match="n_steps >= 1"):
        multi.step_multi([0], [0], 0, np.zeros(1, np.float32))
    with pytest.raises(ValueError, match="thresholds"):
        multi.step_multi([0], [0], 2, np.zeros(2, np.float32))
    # stepping a non-live slot still refuses before any dispatch
    with pytest.raises(KeyError):
        multi.step_multi([2], [0], 2, np.zeros(1, np.float32))
    multi.free(0)

    cfg = get_tiny("qwen2-1.5b").replace(n_layers=3, vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    prompts = np.random.default_rng(7).integers(0, 128, (4, 12)).astype(np.int32)
    loop = LoopDecodeRunner(model, params, prompts, max_new_tokens=4, max_slots=2)
    loop.start(0, 0)
    with pytest.raises(ValueError, match="active ramp set"):
        loop.step([0], [0, 0, 0])


# ---------------------------------------------------------------------------
# sharded (tensor-parallel) runner: same fuzz harness on a 2-4 device CPU
# mesh, in a subprocess so the rest of the suite keeps its single device


def test_sharded_runner_schedules_bit_identical():
    """Seeded admit/step/window/free schedules driven through
    ``ShardedDecodeRunner`` at tp=2 and tp=4 (paged pool, per-device KV
    shards) and dp=2 x tp=2 (contiguous) against the single-device batched
    runner: every record, window exit site, and allocator field must be
    bit-identical, per-device cache bytes must be total/tp, and the pool
    must drain after the last free."""
    import os
    import subprocess
    import sys
    import textwrap

    code = """
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_tiny
        from repro.models import build_model
        from repro.serving import DecodeRunner, ShardedDecodeRunner
        from repro.core.exits import simulate_exits

        MAX_NEW = 8
        cfg = get_tiny("qwen2-1.5b").replace(
            n_layers=3, vocab_size=128, n_kv_heads=4, decode_attn="paged")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(2))
        prompts = np.random.default_rng(3).integers(0, 128, (16, 12)).astype(np.int32)
        pkw = dict(max_new_tokens=MAX_NEW, max_slots=3, kv_block_size=4)
        cfg_c = cfg.replace(decode_attn="ref")
        model_c = build_model(cfg_c)
        ckw = dict(max_new_tokens=MAX_NEW, max_slots=3)
        groups = {
            "paged": (DecodeRunner(model, params, prompts, **pkw), {
                "tp2": ShardedDecodeRunner(model, params, prompts, tp=2, **pkw),
                "tp4": ShardedDecodeRunner(model, params, prompts, tp=4, **pkw),
            }),
            "contig": (DecodeRunner(model_c, params, prompts, **ckw), {
                "dp2tp2": ShardedDecodeRunner(model_c, params, prompts,
                                              tp=2, dp=2, **ckw),
            }),
        }
        n_sites = groups["paged"][0].n_sites

        def alloc_eq(a, b, tag):
            np.testing.assert_array_equal(a.table, b.table, err_msg=tag)
            np.testing.assert_array_equal(a.owned, b.owned, err_msg=tag)
            assert (a.n_free, a.live_blocks, a.peak_blocks) == \\
                   (b.n_free, b.live_blocks, b.peak_blocks), tag
            assert sorted(a._free) == sorted(b._free), tag

        def run_group(kind, oracle, shards, n_sched, seed):
            rng = np.random.default_rng(seed)
            runners = dict(shards)
            runners["__oracle"] = oracle
            for sched_id in range(n_sched):
                live = {}
                for op_i in range(int(rng.integers(6, 14))):
                    free_slots = [s for s in range(3) if s not in live]
                    steppable = [s for s in sorted(live) if live[s] < MAX_NEW - 1]
                    ops = (["admit"] if free_slots else [])
                    ops += ["step", "win"] if steppable else []
                    ops += ["free"] if live else []
                    op = ops[int(rng.integers(len(ops)))]
                    tag = f"{kind} sched {sched_id} op {op_i} ({op})"
                    if op == "admit":
                        slot = int(free_slots[int(rng.integers(len(free_slots)))])
                        item = int(rng.integers(16))
                        toks = {n: r.start(slot, item) for n, r in runners.items()}
                        assert len(set(toks.values())) == 1, tag
                        live[slot] = 0
                    elif op == "step":
                        k = int(rng.integers(1, len(steppable) + 1))
                        subset = [int(s) for s in rng.permutation(steppable)[:k]]
                        act = [int(s) for s in
                               np.flatnonzero(rng.random(n_sites) < 0.6)]
                        lo, uo, fo = oracle.step(subset, act)
                        for name, r in shards.items():
                            lb, ub, fb = r.step(subset, act)
                            np.testing.assert_array_equal(lb, lo, err_msg=tag + name)
                            np.testing.assert_array_equal(ub, uo, err_msg=tag + name)
                            np.testing.assert_array_equal(fb, fo, err_msg=tag + name)
                        for s in subset:
                            live[s] += 1
                    elif op == "win":
                        k = int(rng.integers(1, len(steppable) + 1))
                        subset = [int(s) for s in rng.permutation(steppable)[:k]]
                        act = [int(s) for s in
                               np.flatnonzero(rng.random(n_sites) < 0.6)]
                        thr = rng.choice([0.0, 0.3, 0.999], size=len(act)
                                         ).astype(np.float32)
                        n_req = int(rng.choice([1, 2, 4]))
                        n_req = min(n_req, min(MAX_NEW - 1 - live[s] for s in subset))
                        lo, uo, fo, xo = oracle.step_multi(subset, act, n_req, thr)
                        for name, r in shards.items():
                            lb, ub, fb, xb = r.step_multi(subset, act, n_req, thr)
                            np.testing.assert_array_equal(lb, lo, err_msg=tag + name)
                            np.testing.assert_array_equal(ub, uo, err_msg=tag + name)
                            np.testing.assert_array_equal(fb, fo, err_msg=tag + name)
                            np.testing.assert_array_equal(xb, xo, err_msg=tag + name)
                            if kind == "paged":
                                alloc_eq(r._alloc, oracle._alloc, tag + name)
                        # device exits == host simulate_exits on the records
                        thr_full = np.zeros(n_sites, np.float32)
                        if act:
                            thr_full[np.asarray(act)] = thr
                        for t in range(fo.shape[0]):
                            unc_m = np.zeros((len(subset), n_sites), np.float32)
                            val_m = np.zeros((len(subset), n_sites), bool)
                            for j, site in enumerate(act):
                                unc_m[:, site] = uo[t, j]
                                val_m[:, site] = True
                            ex_host = simulate_exits(unc_m, val_m, thr_full, act)
                            np.testing.assert_array_equal(xo[t], ex_host, err_msg=tag)
                        for s in subset:
                            live[s] += fo.shape[0]
                    else:
                        slot = sorted(live)[int(rng.integers(len(live)))]
                        for r in runners.values():
                            r.free(slot)
                        del live[slot]
                for s in list(live):
                    for r in runners.values():
                        r.free(s)

        run_group("paged", *groups["paged"], n_sched=10, seed=0x5AFE)
        run_group("contig", *groups["contig"], n_sched=6, seed=0x5EED)
        # drained pools + per-device KV scaling
        oracle, shards = groups["paged"]
        total = oracle.cache_bytes()
        for name, r in shards.items():
            a = r._alloc
            assert a.live_blocks == 0 and a.n_free == a.n_blocks, name
            stats = r.kv_stats()
            assert stats["per_device_cache_bytes"] * r.tp == total, (name, stats)
        print("sharded fuzz OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 --xla_cpu_multi_thread_eigen=false"
    )
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["OMP_NUM_THREADS"] = "1"
    for _ in range(2):  # one retry for transient host-collective aborts
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, timeout=560, env=env,
        )
        if r.returncode == 0:
            return
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
