"""Paged KV cache: block-allocator properties, paged-kernel correctness,
pool exhaustion, and memory scaling.

Allocator property tests draw hundreds of random alloc/free schedules
from a module-seeded generator (suite policy: no hypothesis) and check
the three invariants the paged runner's soundness rests on: disjoint
ownership, pool conservation, and atomic failure at exhaustion. Kernel
tests validate the Pallas block-table walk against the jnp gather oracle
(interpret mode on CPU; the full shape sweep is ``-m slow``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import BlockAllocator, PoolExhausted

RNG = np.random.default_rng(0xB10C)


# ---------------------------------------------------------------------------
# allocator properties


def _check_invariants(al: BlockAllocator):
    owned = []
    for s in range(al.table.shape[0]):
        ids = al.owned_ids(s)
        assert all(1 <= b <= al.n_blocks for b in ids), "invalid block id"
        owned.extend(ids)
    assert len(owned) == len(set(owned)), "a block is owned by two slots"
    assert al.n_free + len(owned) == al.n_blocks, "pool not conserved"


@pytest.mark.parametrize("trial", range(8))
def test_allocator_random_schedules(trial):
    """Hundreds of random alloc/free ops (~40 schedules x 8 trials):
    no double ownership, pool conserved, table mirrors a reference model."""
    for _ in range(40):
        n_slots = int(RNG.integers(2, 6))
        max_blocks = int(RNG.integers(2, 6))
        n_blocks = int(RNG.integers(1, n_slots * max_blocks + 2))
        al = BlockAllocator(n_blocks, max_blocks, n_slots)
        ref = {s: [] for s in range(n_slots)}  # reference ownership model
        for _ in range(int(RNG.integers(5, 25))):
            s = int(RNG.integers(n_slots))
            if RNG.random() < 0.65:
                n = int(RNG.integers(1, max_blocks + 1))
                try:
                    ids = al.alloc(s, n)
                except PoolExhausted:
                    assert al.n_free < n
                except ValueError:
                    assert len(ref[s]) + n > max_blocks
                else:
                    assert len(ids) == n
                    ref[s].extend(ids)
            else:
                al.free_slot(s)
                ref[s] = []
            _check_invariants(al)
            for t in range(n_slots):
                assert al.owned_ids(t) == ref[t]
        for s in range(n_slots):
            al.free_slot(s)
        assert al.n_free == al.n_blocks and al.live_blocks == 0


def _check_share_invariants(al: BlockAllocator, ref, pinned):
    """The refcount invariants from the BlockAllocator docstring, checked
    against an independent reference model (ref: slot -> id list in table
    order, possibly with repeats across slots; pinned: pin-id multiset)."""
    import collections

    for s, ids in ref.items():
        assert al.owned_ids(s) == ids, "table diverged from reference model"
    refc = collections.Counter(b for ids in ref.values() for b in ids)
    refc.update(pinned)
    for b, c in refc.items():
        assert 1 <= b <= al.n_blocks, "reference to invalid block id"
    np.testing.assert_array_equal(
        al.refcount[1:], [refc.get(b, 0) for b in range(1, al.n_blocks + 1)]
    )
    assert al.pins == len(pinned)
    assert int(al.refcount.sum()) == int(al.owned.sum()) + al.pins
    free = set(al._free)
    assert len(free) == al.n_free, "duplicate id on the free heap"
    assert all(al.refcount[b] == 0 for b in free), "block both free and referenced"
    assert al.n_free + int((al.refcount > 0).sum()) == al.n_blocks, "pool leak"


@pytest.mark.parametrize("trial", range(8))
def test_allocator_sharing_random_schedules(trial):
    """Sharing-era property fuzz (~40 schedules x 8 trials x ~25 ops):
    random alloc/share/cow/pin/unpin/free schedules against a reference
    model. After every op: refcounts equal the reference multiset,
    refcount.sum() == owned.sum() + pins, the free heap is disjoint from
    referenced blocks, and nothing leaks. Failed ops (exhaustion, cap)
    must leave all of that untouched — the model is not updated on
    failure, so any partial mutation trips the next check."""
    for _ in range(40):
        n_slots = int(RNG.integers(2, 6))
        max_blocks = int(RNG.integers(2, 6))
        n_blocks = int(RNG.integers(2, n_slots * max_blocks + 2))
        al = BlockAllocator(n_blocks, max_blocks, n_slots)
        ref = {s: [] for s in range(n_slots)}
        pinned = []
        for _ in range(int(RNG.integers(10, 30))):
            s = int(RNG.integers(n_slots))
            live = sorted({b for ids in ref.values() for b in ids} | set(pinned))
            op = RNG.choice(["alloc", "share", "cow", "pin", "unpin", "free"])
            if op == "alloc":
                n = int(RNG.integers(1, max_blocks + 1))
                try:
                    ids = al.alloc(s, n)
                except PoolExhausted:
                    assert al.n_free < n
                except ValueError:
                    assert len(ref[s]) + n > max_blocks
                else:
                    ref[s].extend(ids)
            elif op == "share" and live:
                k = int(RNG.integers(1, min(len(live), max_blocks) + 1))
                ids = [int(b) for b in RNG.choice(live, k, replace=False)]
                try:
                    al.share(s, ids)
                except ValueError:
                    assert len(ref[s]) + k > max_blocks
                else:
                    ref[s].extend(ids)
            elif op == "cow" and ref[s]:
                idx = int(RNG.integers(len(ref[s])))
                try:
                    old, new = al.cow(s, idx)
                except PoolExhausted:
                    assert al.n_free < 1
                else:
                    assert old == ref[s][idx] and new != old
                    ref[s][idx] = new
            elif op == "pin" and live:
                b = int(RNG.choice(live))
                al.pin(b)
                pinned.append(b)
            elif op == "unpin" and pinned:
                b = pinned.pop(int(RNG.integers(len(pinned))))
                al.unpin(b)
            elif op == "free":
                al.free_slot(s)
                ref[s] = []
            _check_share_invariants(al, ref, pinned)
        for s in range(n_slots):
            al.free_slot(s)
        for b in pinned:
            al.unpin(b)
        assert al.n_free == al.n_blocks and al.pins == 0
        assert (al.refcount == 0).all()


def test_allocator_share_and_cow_refcounts():
    """Deterministic walk of the sharing lifecycle: share bumps refcount,
    cow gives the writer a private block (old keeps its other holders),
    and a shared block outlives the slot that allocated it."""
    al = BlockAllocator(6, max_blocks_per_slot=3, n_slots=3)
    ids = al.alloc(0, 2)  # [1, 2]
    al.share(1, ids)
    assert al.owned_ids(1) == ids and al.refcount[1] == al.refcount[2] == 2
    assert al.live_blocks == 2  # shared, not duplicated
    old, new = al.cow(1, 0)
    assert (old, new) == (1, 3)
    assert al.owned_ids(1) == [3, 2] and al.owned_ids(0) == [1, 2]
    assert al.refcount[1] == 1 and al.refcount[3] == 1 and al.refcount[2] == 2
    al.free_slot(0)  # block 2 survives via slot 1's reference
    assert al.refcount[2] == 1 and al.owned_ids(1) == [3, 2]
    al.free_slot(1)
    assert al.n_free == al.n_blocks


def test_allocator_pin_keeps_block_alive():
    al = BlockAllocator(3, max_blocks_per_slot=2, n_slots=2)
    (b,) = al.alloc(0, 1)
    al.pin(b)
    al.free_slot(0)
    assert al.refcount[b] == 1 and al.live_blocks == 1  # cache ref holds it
    al.unpin(b)
    assert al.live_blocks == 0
    assert al.alloc(1, 1) == [b]  # recycled
    # dead / reserved / out-of-range blocks cannot be shared or pinned
    with pytest.raises(ValueError):
        al.share(0, [3])
    with pytest.raises(ValueError):
        al.pin(0)


def test_allocator_exhaustion_is_atomic():
    """A failing multi-block alloc must not mutate the table or free list."""
    al = BlockAllocator(4, max_blocks_per_slot=6, n_slots=2)
    al.alloc(0, 3)
    before = (al.table.copy(), al.owned.copy(), al.n_free)
    with pytest.raises(PoolExhausted):
        al.alloc(1, 2)  # only 1 free
    np.testing.assert_array_equal(al.table, before[0])
    np.testing.assert_array_equal(al.owned, before[1])
    assert al.n_free == before[2]
    # the survivor block is still allocatable after the failure
    assert al.alloc(1, 1) == [4]


def test_allocator_free_returns_every_block():
    al = BlockAllocator(6, max_blocks_per_slot=3, n_slots=3)
    for s in range(3):
        al.alloc(s, 2)
    assert al.n_free == 0 and al.peak_blocks == 6
    for s in range(3):
        al.free_slot(s)
    assert al.n_free == 6
    # freed ids recycle deterministically lowest-first
    assert al.alloc(1, 2) == [1, 2]
    # stale table entries of freed slots stay valid (trash) pool indices
    assert (al.table[0] == 0).all() and (al.table[2] == 0).all()


def test_allocator_grow():
    al = BlockAllocator(2, max_blocks_per_slot=2, n_slots=1)
    al.alloc(0, 2)
    al.grow_slots(3)
    al.grow_pool(5)
    assert al.table.shape[0] == 3 and al.n_free == 3
    assert al.alloc(1, 2) == [3, 4]
    _check_invariants(al)


# ---------------------------------------------------------------------------
# paged kernel vs oracles


def _rand_paged(rng, B, H, KH, hd, bs, nb, dtype=np.float32):
    P = B * nb + 1
    q = jnp.asarray(rng.standard_normal((B, H, hd)), dtype)
    kp = jnp.asarray(rng.standard_normal((P, bs, KH, hd)), dtype)
    vp = jnp.asarray(rng.standard_normal((P, bs, KH, hd)), dtype)
    # rows own disjoint random blocks (ids >= 1; 0 is the trash block)
    ids = rng.permutation(np.arange(1, P))[: B * nb].reshape(B, nb)
    table = jnp.asarray(ids, jnp.int32)
    pos = jnp.asarray(rng.integers(0, nb * bs, B), jnp.int32)
    return q, kp, vp, table, pos


def test_paged_ref_matches_contiguous_gather():
    """The paged oracle IS the contiguous oracle on the gathered layout —
    bit-identical, which is what the runner equivalence harness rests on."""
    from repro.kernels.decode_attention import (
        decode_attention_ref,
        paged_decode_attention_ref,
    )

    rng = np.random.default_rng(0)
    B, H, KH, hd, bs, nb = 3, 4, 2, 8, 4, 4
    q, kp, vp, table, pos = _rand_paged(rng, B, H, KH, hd, bs, nb)
    o = paged_decode_attention_ref(q, kp, vp, table, pos)
    k = kp[table].reshape(B, nb * bs, KH, hd).transpose(0, 2, 1, 3)
    v = vp[table].reshape(B, nb * bs, KH, hd).transpose(0, 2, 1, 3)
    np.testing.assert_array_equal(
        np.asarray(o), np.asarray(decode_attention_ref(q, k, v, pos))
    )


def test_paged_kernel_matches_ref():
    from repro.kernels.decode_attention import (
        paged_decode_attention,
        paged_decode_attention_ref,
    )

    rng = np.random.default_rng(1)
    B, H, KH, hd, bs, nb = 2, 4, 2, 16, 8, 3
    q, kp, vp, table, pos = _rand_paged(rng, B, H, KH, hd, bs, nb)
    o_k = paged_decode_attention(q, kp, vp, table, pos, interpret=True)
    o_r = paged_decode_attention_ref(q, kp, vp, table, pos)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-6, rtol=2e-6)


@pytest.mark.slow
@pytest.mark.parametrize(
    "B,H,KH,hd,bs,nb",
    [
        (1, 2, 2, 8, 4, 1),  # single block: init tile is also the final tile
        (2, 4, 2, 16, 8, 3),
        (3, 8, 2, 32, 16, 2),  # GQA 4:1
        (4, 4, 4, 16, 4, 5),  # MHA, many small blocks
        (2, 6, 3, 16, 8, 4),  # 2:1 grouping
    ],
)
def test_paged_kernel_sweep(B, H, KH, hd, bs, nb):
    """Interpret-mode Pallas sweep over head groupings / block geometries,
    including per-row positions at every in-block offset."""
    from repro.kernels.decode_attention import (
        paged_decode_attention,
        paged_decode_attention_ref,
    )

    rng = np.random.default_rng(B * 1000 + nb)
    q, kp, vp, table, pos = _rand_paged(rng, B, H, KH, hd, bs, nb)
    # force the full offset range across rows: first/last token of a block
    pos = jnp.asarray(
        [(i * bs + [0, bs - 1, bs // 2][i % 3]) % (nb * bs) for i in range(B)],
        jnp.int32,
    )
    o_k = paged_decode_attention(q, kp, vp, table, pos, interpret=True)
    o_r = paged_decode_attention_ref(q, kp, vp, table, pos)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-6, rtol=2e-6)


def _rand_paged_mla(rng, B, H, r, dr, bs, nb, dtype=jnp.float32):
    P = B * nb + 4
    q_lat = jnp.asarray(rng.standard_normal((B, H, r)), dtype)
    q_pe = jnp.asarray(rng.standard_normal((B, H, dr)), dtype)
    cp = jnp.asarray(rng.standard_normal((P, bs, r)), dtype)
    kp = jnp.asarray(rng.standard_normal((P, bs, dr)), dtype)
    ids = rng.permutation(np.arange(1, P))[: B * nb].reshape(B, nb)
    table = jnp.asarray(ids, jnp.int32)
    pos = jnp.asarray(rng.integers(0, nb * bs, B), jnp.int32)
    return q_lat, q_pe, cp, kp, table, pos


def test_paged_mla_ref_matches_contiguous_math():
    """The paged MLA oracle IS the absorbed contiguous math on the
    gathered latent layout — bit-identical, which is what the MLA runner
    equivalence rests on (``decode_attn='paged'`` routes here)."""
    from repro.kernels.decode_attention import paged_mla_decode_attention_ref

    rng = np.random.default_rng(2)
    B, H, r, dr, bs, nb = 3, 4, 16, 8, 4, 4
    scale = 1.0 / np.sqrt(r + dr)
    ql, qp, cp, kp, table, pos = _rand_paged_mla(rng, B, H, r, dr, bs, nb)
    o = paged_mla_decode_attention_ref(ql, qp, cp, kp, table, pos, scale=scale)
    c = cp[table].reshape(B, nb * bs, r)
    k = kp[table].reshape(B, nb * bs, dr)
    s = (
        jnp.einsum("bhr,bsr->bhs", ql, c) + jnp.einsum("bhn,bsn->bhs", qp, k)
    ) * scale
    mask = jnp.arange(nb * bs)[None, None] <= pos[:, None, None]
    probs = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
    np.testing.assert_array_equal(
        np.asarray(o), np.asarray(jnp.einsum("bhs,bsr->bhr", probs, c))
    )


def test_paged_mla_kernel_matches_ref():
    """Kernel pairing for the paged MLA Pallas kernel: interpret-mode
    output vs the jnp oracle, per-row positions at mixed block offsets."""
    from repro.kernels.decode_attention import (
        paged_mla_decode_attention,
        paged_mla_decode_attention_ref,
    )

    rng = np.random.default_rng(3)
    B, H, r, dr, bs, nb = 2, 4, 16, 8, 8, 3
    scale = 1.0 / np.sqrt(r + dr)
    ql, qp, cp, kp, table, pos = _rand_paged_mla(rng, B, H, r, dr, bs, nb)
    pos = jnp.asarray([0, nb * bs - 1], jnp.int32)  # first + last offsets
    o_k = paged_mla_decode_attention(ql, qp, cp, kp, table, pos,
                                     scale=scale, interpret=True)
    o_r = paged_mla_decode_attention_ref(ql, qp, cp, kp, table, pos, scale=scale)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-6, rtol=2e-6)


@pytest.mark.slow
@pytest.mark.parametrize(
    "B,H,r,dr,bs,nb",
    [
        (1, 2, 8, 4, 4, 1),  # single block: init tile is also the final tile
        (3, 4, 16, 8, 4, 4),
        (2, 8, 32, 16, 8, 3),
    ],
)
def test_paged_mla_kernel_sweep(B, H, r, dr, bs, nb):
    from repro.kernels.decode_attention import (
        paged_mla_decode_attention,
        paged_mla_decode_attention_ref,
    )

    rng = np.random.default_rng(B * 100 + nb)
    scale = 1.0 / np.sqrt(r + dr)
    ql, qp, cp, kp, table, pos = _rand_paged_mla(rng, B, H, r, dr, bs, nb)
    o_k = paged_mla_decode_attention(ql, qp, cp, kp, table, pos,
                                     scale=scale, interpret=True)
    o_r = paged_mla_decode_attention_ref(ql, qp, cp, kp, table, pos, scale=scale)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), atol=2e-6, rtol=2e-6)


# ---------------------------------------------------------------------------
# paged runner: exhaustion + memory scaling


@pytest.fixture(scope="module")
def paged_setup():
    from repro.configs import get_tiny
    from repro.models import build_model

    cfg = get_tiny("qwen2-1.5b").replace(n_layers=2, vocab_size=128, decode_attn="paged")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(1).integers(0, 128, (8, 8)).astype(np.int32)
    return cfg, model, params, prompts


def test_runner_pool_exhaustion_raises_cleanly(paged_setup):
    from repro.serving import DecodeRunner

    _, model, params, prompts = paged_setup
    # prompt takes 2 blocks of 4; pool of 5 blocks fits two prompts + one
    # appended block, then runs dry
    r = DecodeRunner(model, params, prompts, max_new_tokens=8, max_slots=2,
                     n_slots=4, kv_block_size=4, kv_blocks=5)
    r.start(0, 0)
    r.start(1, 1)
    assert r._alloc.live_blocks == 4
    with pytest.raises(PoolExhausted):
        r.start(2, 2)  # needs 2 blocks, 1 free
    # the prompt exactly fills 2 blocks, so the first decode step must
    # append one block per slot — only one is free; the step raises
    # BEFORE any device update, leaving the allocator consistent
    with pytest.raises(PoolExhausted):
        r.step([0, 1], [0])
    assert r._alloc.n_free + r._alloc.live_blocks == r._alloc.n_blocks
    # freeing a slot returns its blocks; the survivor appends and proceeds
    r.free(0)
    assert r._alloc.n_free >= 2
    r.step([1], [0])
    assert r._pos[1] == 9


def test_step_block_claim_is_all_or_nothing(paged_setup):
    """Regression: a multi-slot step that exhausts the pool on a LATER
    slot's append must not have claimed blocks for earlier slots. The old
    per-slot append loop allocated slot 0's block before discovering slot
    1 couldn't get one — the retried step then double-appended. The claim
    is now precomputed for the whole batch and reserved atomically."""
    from repro.serving import DecodeRunner

    _, model, params, prompts = paged_setup
    # prompt = 8 tokens = 2 blocks of 4; pool of 5: two started slots own
    # 4 blocks, and the first decode step needs one append PER slot
    r = DecodeRunner(model, params, prompts, max_new_tokens=8, max_slots=2,
                     n_slots=4, kv_block_size=4, kv_blocks=5)
    r.start(0, 0)
    r.start(1, 1)
    al = r._alloc
    before = (al.table.copy(), al.owned.copy(), al.n_free, np.asarray(r._pos).copy())
    with pytest.raises(PoolExhausted):
        r.step([0, 1], [0])  # needs 2 appends, 1 free
    np.testing.assert_array_equal(al.table, before[0])  # slot 0 untouched too
    np.testing.assert_array_equal(al.owned, before[1])
    assert al.n_free == before[2]
    np.testing.assert_array_equal(np.asarray(r._pos), before[3])
    # after the failed step the survivor path still works untainted
    r.free(0)
    r.step([1], [0])
    assert int(np.asarray(r._pos)[1]) == 9


def test_paged_memory_scales_with_live_tokens(paged_setup):
    """The acceptance claim at unit scale: with few live tokens the paged
    pool is far smaller than n_slots * max_len contiguous rows, while
    records stay bit-identical to the contiguous runner."""
    from repro.models import build_model
    from repro.serving import DecodeRunner

    cfg, model, params, prompts = paged_setup
    n_slots = 8
    kw = dict(max_new_tokens=8, max_slots=2, n_slots=n_slots)
    cont = DecodeRunner(
        build_model(cfg.replace(decode_attn="ref")), params, prompts, **kw
    )
    # 2 concurrent short requests -> 2 slots * 4 blocks; pool of 8 blocks
    paged = DecodeRunner(model, params, prompts, kv_block_size=4, kv_blocks=8, **kw)
    for r in (cont, paged):
        r.start(0, 0)
        r.start(5, 3)
    for _ in range(4):
        lc, uc, fc = cont.step([0, 5], [0])
        lp, up, fp = paged.step([0, 5], [0])
        np.testing.assert_array_equal(lp, lc)
        np.testing.assert_array_equal(up, uc)
        np.testing.assert_array_equal(fp, fc)
    # contiguous holds n_slots(8) * cache_len(16) token rows; the paged
    # pool holds (kv_blocks + trash)(9) * 4 = 36 token slots
    assert cont.cache_bytes() == paged.cache_bytes() * (8 * 16) // 36
    assert paged.cache_bytes() * 3 < cont.cache_bytes()
    assert cont.dispatches == paged.dispatches
    st = paged.kv_stats()
    assert st["peak_blocks"] == 6 and st["live_blocks"] == 6


def test_paged_cache_schema_covers_all_mixer_families():
    """Every mixer family now owns a paged page layout drawn from the one
    shared block pool: MLA pools the compressed latent streams, mamba
    pools per-slot state pages, local-window layers reuse the k/v token
    pools (ring-redirected through the first ceil(W/bs) table entries)."""
    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.models.common import ParamInfo

    nb, bs = 4, 4
    mamba = build_model(get_tiny("mamba2-2.7b"))
    sch = mamba.paged_cache_schema(nb, bs)
    leaves = jax.tree.leaves(sch, is_leaf=lambda x: isinstance(x, ParamInfo))
    # state pages are per-slot, pool-leading, and NOT (P, bs, ...) token
    # shaped: conv (L?, P, d_conv-1, conv_dim) and ssm (L?, P, H, hp, N)
    assert leaves and all(nb in l.shape for l in leaves)
    assert not mamba.paged_sharing_ok

    mla = build_model(get_tiny("deepseek-v2-lite-16b"))
    cfg = mla.cfg
    sch = mla.paged_cache_schema(nb, bs)
    blk = sch["blocks"][0]
    assert set(blk) >= {"c", "k_pe"}
    assert blk["c"].shape[-2:] == (bs, cfg.kv_lora_rank)
    assert blk["k_pe"].shape[-2:] == (bs, cfg.qk_rope_dim)

    gemma = build_model(get_tiny("gemma3-4b").replace(decode_attn="paged"))
    sch = gemma.paged_cache_schema(nb, bs)
    leaves = jax.tree.leaves(sch, is_leaf=lambda x: isinstance(x, ParamInfo))
    assert leaves and all(l.shape[-3] == bs for l in leaves)
    # ring pages are position-aliased mod W: sharing refused
    assert not gemma.paged_sharing_ok


def test_runner_kv_block_size_validation(paged_setup):
    from repro.models import build_model
    from repro.serving import DecodeRunner

    cfg, model, params, prompts = paged_setup
    with pytest.raises(ValueError):
        DecodeRunner(model, params, prompts, kv_block_size=0)
    # kv_block_size=0 documents "contiguous" at the CLI: harmless on a
    # contiguous-cfg runner (must not divide by zero in __init__)
    cont = DecodeRunner(
        build_model(cfg.replace(decode_attn="ref")), params, prompts,
        max_new_tokens=4, kv_block_size=0,
    )
    assert not cont.paged
    cont.start(0, 0)
    cont.step([0], [0])
