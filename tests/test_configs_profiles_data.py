"""Config registry, shape cells, latency profiles, synthetic data."""
import numpy as np
import pytest

from repro.configs import (
    ARCH_IDS,
    PAPER_IDS,
    SHAPES,
    all_cells,
    cell_is_runnable,
    get_bench,
    get_config,
    get_tiny,
)
from repro.core import build_profile
from repro.data import make_image_stream, make_token_stream


def test_all_archs_resolve():
    for a in ARCH_IDS + PAPER_IDS:
        cfg = get_config(a)
        tiny = get_tiny(a)
        assert cfg.name == a
        assert tiny.n_layers <= cfg.n_layers


def test_cell_enumeration():
    cells = list(all_cells())
    assert len(cells) == 40  # 10 archs x 4 shapes
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    # long_500k skipped exactly for the 7 pure full-attention archs
    assert len(skipped) == 7
    assert all(s == "long_500k" for _, s, _ in skipped)


def test_production_divisibility():
    """Key sharded dims divide the production mesh axes (or the sanitizer
    replicates them — embeddings must always divide)."""
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.padded_vocab % 2048 == 0
        assert cfg.padded_vocab % 16 == 0, a  # model axis
        assert cfg.d_model % 16 == 0, a
        if cfg.moe:
            assert cfg.n_experts % 16 == 0, a  # EP over model axis


def test_assigned_shapes_exact():
    assert SHAPES["train_4k"] == dict(kind="train", seq_len=4096, global_batch=256)
    assert SHAPES["prefill_32k"] == dict(kind="prefill", seq_len=32768, global_batch=32)
    assert SHAPES["decode_32k"] == dict(kind="decode", seq_len=32768, global_batch=128)
    assert SHAPES["long_500k"] == dict(kind="decode", seq_len=524288, global_batch=1)


@pytest.mark.parametrize("arch", ["gpt2-medium", "resnet18", "deepseek-67b", "mamba2-2.7b"])
def test_profile_sanity(arch):
    cfg = get_config(arch)
    prof = build_profile(cfg, mode="decode", chips=1)
    t = prof.cum_times(1)
    assert (np.diff(t) > 0).all()  # strictly increasing cumulative time
    assert prof.vanilla_time(1) > t[-1]  # head adds time
    assert prof.vanilla_time(8) >= prof.vanilla_time(1)  # batch monotone
    for s in range(len(prof.sites)):
        assert prof.savings_at_site(s, 1) > 0
        assert prof.ramp_overhead(s, 1) >= 0
    # earlier exits save more
    sav = [prof.savings_at_site(s, 1) for s in range(len(prof.sites))]
    assert (np.diff(sav) < 0).all()


def test_resnet_latency_skew():
    """Paper §3.3: CV latency skews toward early layers (high-res inputs)."""
    cfg = get_config("resnet50").replace(resnet_widths=(64, 128, 256, 512), img_size=224)
    prof = build_profile(cfg, chips=1)
    times = [prof.layer_time(i, 1) for i in range(len(prof.layer_flops))]
    first_half = sum(times[: len(times) // 2])
    assert first_half > 0.35 * sum(times)


def test_image_stream_temporal_correlation():
    cv = make_image_stream(2000, mode="cv", seed=0)
    nlp = make_image_stream(2000, mode="nlp", seed=0)
    # CV labels persist; NLP labels iid
    cv_flips = np.mean(cv.labels[1:] != cv.labels[:-1])
    nlp_flips = np.mean(nlp.labels[1:] != nlp.labels[:-1])
    assert cv_flips < 0.2 < nlp_flips
    assert (cv.difficulty >= 0).all() and (cv.difficulty <= 1).all()


def test_token_stream_compositional():
    s = make_token_stream(500, seq_len=32, vocab=512, n_classes=10, seed=1)
    assert s.data.shape == (500, 32)
    assert (s.data[:, 0] == 0).all()  # CLS
    assert s.data.max() < 512


def test_bench_configs_preserve_depth():
    for name in ("gpt2-medium", "bert-base", "resnet18"):
        full, bench = get_config(name), get_bench(name)
        assert bench.n_layers == full.n_layers
        if name.startswith("resnet"):
            assert bench.resnet_blocks == full.resnet_blocks
