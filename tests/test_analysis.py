"""Linter engine + per-rule fixture tests (repro.analysis pass 1).

Each rule gets a bad/good fixture pair written to a synthetic repo under
``tmp_path`` (so rule paths like ``src/...`` vs ``tests/...`` resolve the
same way they do in the real tree), asserting exact rule ids AND line
numbers; plus pragma/allowlist suppression tests and a repo-wide
cleanliness gate.
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import parse_pragmas, run_lint
from repro.analysis.rules import all_rules, rule_ids

EXPECTED_RULES = {
    "compat-shim",
    "tier1-deps",
    "seeded-rng",
    "no-wallclock",
    "jit-cache-hygiene",
    "kernel-pairing",
    "host-sync",
}


def _mini_repo(tmp_path: Path, files: dict) -> Path:
    """files: repo-relative path -> dedented source."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return tmp_path


def _lint(tmp_path, files, rules=None):
    root = _mini_repo(tmp_path, files)
    if rules is not None:
        rules = [r for r in all_rules() if r.id in rules]
    return run_lint(root, rules=rules)


def _hits(res, rule):
    return [(f.path, f.line) for f in res.findings if f.rule == rule]


def test_rule_registry_complete():
    assert set(rule_ids()) == EXPECTED_RULES


# -- compat-shim -------------------------------------------------------------


def test_compat_shim_flags_jax_probes_and_version_reads(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/x.py": """\
            import jax

            if hasattr(jax, "shard_map"):       # line 3: jax-module probe
                pass
            f = getattr(jax.sharding, "Mesh", None)  # line 5: getattr probe
            v = jax.__version__                  # line 6: version read
        """,
    }, rules={"compat-shim"})
    assert _hits(res, "compat-shim") == [
        ("src/repro/x.py", 3),
        ("src/repro/x.py", 5),
        ("src/repro/x.py", 6),
    ]


def test_compat_shim_flags_old_moe_mesh_shape_sniff(tmp_path):
    # the exact shim shape moe.py:159 carried before mesh_axis_size existed:
    # reintroducing it must fail lint (ISSUE acceptance criterion)
    res = _lint(tmp_path, {
        "src/repro/models/m.py": """\
            def dsz_of(mesh, axes):
                dsz = 1
                for a in axes:
                    dsz *= mesh.shape.get(a, 1) if hasattr(mesh.shape, "get") else dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
                return dsz
        """,
    }, rules={"compat-shim"})
    assert _hits(res, "compat-shim") == [("src/repro/models/m.py", 4)]


def test_compat_shim_allows_duck_typing_and_shim_sites(tmp_path):
    res = _lint(tmp_path, {
        # duck typing on non-jax objects is NOT version sniffing
        "src/repro/ok.py": """\
            def f(tree, runner):
                if hasattr(tree, "shape"):
                    pass
                return hasattr(runner, "swap_out")
        """,
        # the sanctioned shim sites are allowlisted wholesale
        "src/repro/compat.py": """\
            import jax

            HAS = hasattr(jax, "shard_map")
        """,
        "src/repro/launch/mesh.py": """\
            import jax

            NEW = hasattr(jax.sharding, "AxisType")
        """,
    }, rules={"compat-shim"})
    assert _hits(res, "compat-shim") == []
    assert res.n_suppressed == 2  # the two allowlisted shim-site probes


# -- tier1-deps --------------------------------------------------------------


def test_tier1_deps_flags_non_allowed_imports_only_in_tests(tmp_path):
    res = _lint(tmp_path, {
        "tests/test_x.py": """\
            import json
            import numpy as np
            import hypothesis              # line 3: banned
            from scipy import stats       # line 4: banned
            import repro.models
            import pytest
        """,
        # src/ files may import whatever the runtime has
        "src/repro/y.py": """\
            import hypothesis
        """,
    }, rules={"tier1-deps"})
    assert _hits(res, "tier1-deps") == [
        ("tests/test_x.py", 3),
        ("tests/test_x.py", 4),
    ]


def test_tier1_deps_flags_pytest_plugins_assignment(tmp_path):
    res = _lint(tmp_path, {
        "tests/conftest.py": """\
            pytest_plugins = ("hypothesis",)
        """,
    }, rules={"tier1-deps"})
    assert _hits(res, "tier1-deps") == [("tests/conftest.py", 1)]


# -- seeded-rng --------------------------------------------------------------


def test_seeded_rng_flags_global_seed_legacy_draws_and_argless_rng(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/r.py": """\
            import numpy as np
            from numpy.random import default_rng

            np.random.seed(0)              # line 4: global seed
            x = np.random.randn(3)         # line 5: legacy global draw
            g1 = np.random.default_rng()   # line 6: unseeded
            g2 = default_rng()             # line 7: unseeded (bare import)
        """,
    }, rules={"seeded-rng"})
    assert _hits(res, "seeded-rng") == [
        ("src/repro/r.py", 4),
        ("src/repro/r.py", 5),
        ("src/repro/r.py", 6),
        ("src/repro/r.py", 7),
    ]


def test_seeded_rng_allows_seeded_generators(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/ok.py": """\
            import numpy as np

            g = np.random.default_rng(0)
            h = np.random.Generator(np.random.PCG64(7))
            x = g.normal(size=3)
        """,
    }, rules={"seeded-rng"})
    assert _hits(res, "seeded-rng") == []


# -- no-wallclock ------------------------------------------------------------


def test_no_wallclock_flags_time_reads(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/serving/sched.py": """\
            import time
            from time import monotonic     # line 2: aliased import

            def now():
                return time.time()         # line 5
        """,
    }, rules={"no-wallclock"})
    assert _hits(res, "no-wallclock") == [
        ("src/repro/serving/sched.py", 2),
        ("src/repro/serving/sched.py", 5),
    ]


def test_no_wallclock_perf_counter_banned_only_under_serving(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/serving/engine.py": """\
            import time

            t = time.perf_counter()        # line 3: banned in serving/
        """,
        "src/repro/training/bench.py": """\
            import time

            t = time.perf_counter()        # fine outside serving/
        """,
    }, rules={"no-wallclock"})
    assert _hits(res, "no-wallclock") == [("src/repro/serving/engine.py", 3)]


# -- jit-cache-hygiene -------------------------------------------------------


def test_jit_cache_flags_fresh_wrapper_callsites(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/j.py": """\
            import jax

            f = jax.jit(lambda x: x + 1)       # line 3: lambda
            y = jax.jit(abs)(-2)               # line 4: IIFE
            low = jax.jit(abs).lower(3)        # line 5: throwaway .lower
        """,
    }, rules={"jit-cache-hygiene"})
    assert _hits(res, "jit-cache-hygiene") == [
        ("src/repro/j.py", 3),
        ("src/repro/j.py", 4),
        ("src/repro/j.py", 5),
    ]


def test_jit_cache_flags_nested_jitted_def(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/j.py": """\
            import jax

            def outer(m):
                @jax.jit                       # line 4: fresh cache per call
                def step(x):
                    return m * x
                return step
        """,
    }, rules={"jit-cache-hygiene"})
    assert _hits(res, "jit-cache-hygiene") == [("src/repro/j.py", 4)]


def test_jit_cache_flags_truthiness_branch_on_traced_param(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/j.py": """\
            import jax
            from functools import partial

            @jax.jit
            def f(x, flag):
                if flag:                       # line 6: traced truthiness
                    return x
                return -x

            @partial(jax.jit, static_argnames=("flag",))
            def g(x, flag):
                if flag:                       # static: fine
                    return x
                return -x
        """,
    }, rules={"jit-cache-hygiene"})
    assert _hits(res, "jit-cache-hygiene") == [("src/repro/j.py", 6)]


def test_jit_cache_allows_module_scope_bindings(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/ok.py": """\
            import jax
            from functools import partial

            def step(x):
                return x + 1

            jstep = jax.jit(step)              # bound once: fine

            @partial(jax.jit, static_argnames=("n",))
            def top(x, n):
                return x * n
        """,
    }, rules={"jit-cache-hygiene"})
    assert _hits(res, "jit-cache-hygiene") == []


# -- kernel-pairing ----------------------------------------------------------

_KERNEL = """\
    def kernel(x):
        return x
"""
_REF = """\
    def ref(x):
        return x
"""


def test_kernel_pairing_missing_ref(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/kernels/fuzz/kernel.py": _KERNEL,
        "src/repro/kernels/fuzz/__init__.py": "",
    }, rules={"kernel-pairing"})
    assert _hits(res, "kernel-pairing") == [("src/repro/kernels/fuzz/kernel.py", 1)]
    assert "no ref.py" in res.findings[0].message


def test_kernel_pairing_missing_test(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/kernels/fuzz/kernel.py": _KERNEL,
        "src/repro/kernels/fuzz/ref.py": _REF,
        "src/repro/kernels/fuzz/__init__.py": "",
        "tests/test_other.py": "import repro.kernels.fuzz.kernel\n",  # ref missing
    }, rules={"kernel-pairing"})
    assert _hits(res, "kernel-pairing") == [("src/repro/kernels/fuzz/kernel.py", 1)]
    assert "imports both" in res.findings[0].message


def test_kernel_pairing_satisfied_directly_and_via_init(tmp_path):
    res = _lint(tmp_path, {
        # direct imports of both modules
        "src/repro/kernels/a/kernel.py": _KERNEL,
        "src/repro/kernels/a/ref.py": _REF,
        "src/repro/kernels/a/__init__.py": "",
        "tests/test_a.py": """\
            from repro.kernels.a.kernel import kernel
            from repro.kernels.a.ref import ref
        """,
        # via a package __init__ that re-exports both
        "src/repro/kernels/b/kernel.py": _KERNEL,
        "src/repro/kernels/b/ref.py": _REF,
        "src/repro/kernels/b/__init__.py": """\
            from repro.kernels.b.kernel import kernel
            from repro.kernels.b.ref import ref
        """,
        "tests/test_b.py": "from repro.kernels.b import kernel, ref\n",
    }, rules={"kernel-pairing"})
    assert _hits(res, "kernel-pairing") == []


# -- host-sync ---------------------------------------------------------------


def test_host_sync_flags_syncs_in_hot_methods(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/serving/r.py": """\
            import numpy as np

            class R:
                def step(self, slots, active):
                    labels = np.asarray(self._run())   # line 5: transfer
                    tok = int(self._run())             # line 6: scalar pull
                    labels.block_until_ready()         # line 7: barrier
                    return labels, tok
        """,
    }, rules={"host-sync"})
    assert _hits(res, "host-sync") == [
        ("src/repro/serving/r.py", 5),
        ("src/repro/serving/r.py", 6),
        ("src/repro/serving/r.py", 7),
    ]


def test_host_sync_scoped_to_hot_methods_and_serving_tree(tmp_path):
    res = _lint(tmp_path, {
        # same calls in a non-hot method: fine (cold path)
        "src/repro/serving/r.py": """\
            import numpy as np

            class R:
                def snapshot(self):
                    return np.asarray(self._run()).item()

                def step(self, slots):
                    n = int(self._pos[0])   # int() on a Subscript: host numpy
                    return n
        """,
        # hot method name outside src/repro/serving/: out of scope
        "src/repro/core/c.py": """\
            import numpy as np

            def step(x):
                return np.asarray(x)
        """,
    }, rules={"host-sync"})
    assert _hits(res, "host-sync") == []


def test_host_sync_pragma_marks_sanctioned_sync(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/serving/r.py": """\
            import numpy as np

            class R:
                def step_multi(self, slots, active, n_steps):
                    # repro: allow[host-sync] — the one sync per window
                    nd = int(self._dispatch())
                    return nd
        """,
    }, rules={"host-sync"})
    assert res.findings == []
    assert res.n_suppressed == 1


# -- pragmas / allowlist -----------------------------------------------------


def test_parse_pragmas_multi_rule():
    src = "x = 1  # repro: allow[seeded-rng, no-wallclock]\n# repro: allow[compat-shim]\n"
    assert parse_pragmas(src) == {
        1: {"seeded-rng", "no-wallclock"},
        2: {"compat-shim"},
    }


def test_pragma_suppresses_same_line(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/r.py": """\
            import numpy as np

            np.random.seed(0)  # repro: allow[seeded-rng]
        """,
    }, rules={"seeded-rng"})
    assert res.findings == []
    assert res.n_suppressed == 1


def test_pragma_suppresses_line_above(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/r.py": """\
            import numpy as np

            # repro: allow[seeded-rng]
            np.random.seed(0)
        """,
    }, rules={"seeded-rng"})
    assert res.findings == []
    assert res.n_suppressed == 1


def test_pragma_wrong_rule_does_not_suppress(tmp_path):
    res = _lint(tmp_path, {
        "src/repro/r.py": """\
            import numpy as np

            np.random.seed(0)  # repro: allow[no-wallclock]
        """,
    }, rules={"seeded-rng"})
    assert _hits(res, "seeded-rng") == [("src/repro/r.py", 3)]


def test_unparseable_file_is_an_error_not_a_crash(tmp_path):
    res = _lint(tmp_path, {"src/repro/bad.py": "def f(:\n"})
    assert not res.clean
    assert res.errors and "bad.py" in res.errors[0]


# -- the real repo is clean --------------------------------------------------


def test_repo_lint_clean():
    root = Path(__file__).resolve().parents[1]
    res = run_lint(root)
    assert res.errors == []
    assert res.findings == [], "\n".join(f.format() for f in res.findings)
