"""eval_shape support-audit snapshot tests (repro.analysis pass 2).

Pins the expected support cells for representative configs across every
mixer family the paged block pool now covers — plain full-attention,
pure-SSM (state pages), all-MLA (latent-stream pages), local ring-window,
cross-attention (pinned xkv pages), and enc-dec — and checks the committed
``support_matrix.json`` snapshot agrees with a freshly-derived audit for
those configs. Everything runs under ``jax.eval_shape``: no device math.
"""
import json
from pathlib import Path

import pytest

from repro.analysis.abstract import (
    PATH_IDS,
    STATUS_REJECTED,
    STATUS_SUPPORTED,
    audit_config,
    compare_matrices,
    shape_error_cells,
)

REPO = Path(__file__).resolve().parents[1]

# config -> {path: expected status}; any drift here is a deliberate API
# change and must update this table AND the committed snapshot together.
EXPECTED = {
    "gpt2-medium": {p: STATUS_SUPPORTED for p in PATH_IDS},
    "mamba2-2.7b": {
        # paged: per-slot state pages from the shared pool.
        # decode_kernel: no attention layers at all; decode_sharded: the
        # fused SSM recurrence has no head axis to divide across devices
        p: (STATUS_REJECTED if p in ("decode_kernel", "decode_sharded")
            else STATUS_SUPPORTED)
        for p in PATH_IDS
    },
    "deepseek-v2-lite-16b": {
        # paged: block tables over the compressed {c, k_pe} latent streams.
        # decode_kernel: all slots are MLA (paged_mla kernel routes via
        # decode_paged); decode_sharded: every head shard still needs the
        # full latent cache — no per-device KV scaling
        p: (STATUS_REJECTED if p in ("decode_kernel", "decode_sharded")
            else STATUS_SUPPORTED)
        for p in PATH_IDS
    },
    # local ring-window paging: slot = pos % W through the first
    # ceil(W/bs) table entries; TP shards ring slots like any KV leaf
    "gemma3-4b": {p: STATUS_SUPPORTED for p in PATH_IDS},
    "llama-3.2-vision-90b": {
        # cross-attention: read-only pinned xkv pages in trailing table
        # columns; decode_sharded: those pinned encoder pages sit outside
        # the TP-sharded KV pool
        p: (STATUS_REJECTED if p == "decode_sharded" else STATUS_SUPPORTED)
        for p in PATH_IDS
    },
    "jamba-1.5-large-398b": {
        # hybrid attn+mamba: token pages and state pages from one pool;
        # decode_sharded: the mamba slots block TP (no head axis)
        p: (STATUS_REJECTED if p == "decode_sharded" else STATUS_SUPPORTED)
        for p in PATH_IDS
    },
    "seamless-m4t-large-v2": {
        # enc-dec: decoder self-attn pages + pinned encoder-memory xkv
        # pages. decode_kernel: enc-dec wires dense/paged cache attention,
        # no flash-decode routing; decode_sharded: LM-stack only
        p: (STATUS_REJECTED if p in ("decode_kernel", "decode_sharded")
            else STATUS_SUPPORTED)
        for p in PATH_IDS
    },
}

_CACHE = {}


def _audit(name):
    if name not in _CACHE:
        _CACHE[name] = audit_config(name)
    return _CACHE[name]


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_audit_matches_expected_cells(name):
    cells = _audit(name)
    got = {p: c.status for p, c in cells.items()}
    assert got == EXPECTED[name], {
        p: (c.status, c.detail) for p, c in cells.items()
    }


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_audit_has_no_shape_errors(name):
    bugs = shape_error_cells({name: _audit(name)})
    assert bugs == [], [(c.path, c.detail) for c in bugs]


def test_committed_snapshot_agrees_with_fresh_audit():
    snap_path = REPO / "support_matrix.json"
    assert snap_path.is_file(), "run `python -m repro.analysis --audit --write`"
    committed = json.loads(snap_path.read_text())
    fresh = {
        "paths": list(PATH_IDS),
        "configs": {
            name: {p: {"status": c.status} for p, c in _audit(name).items()}
            for name in EXPECTED
        },
    }
    committed_subset = {
        "paths": committed["paths"],
        "configs": {k: v for k, v in committed["configs"].items() if k in EXPECTED},
    }
    problems = compare_matrices(committed_subset, fresh)
    assert problems == [], problems


def test_snapshot_covers_all_configs_and_paths():
    from repro.analysis.abstract import ALL_CONFIG_IDS

    committed = json.loads((REPO / "support_matrix.json").read_text())
    assert set(committed["configs"]) == set(ALL_CONFIG_IDS)
    assert committed["paths"] == list(PATH_IDS)
    for name, cells in committed["configs"].items():
        assert set(cells) == set(PATH_IDS), name
        for p, cell in cells.items():
            assert cell["status"] != "shape-error", (name, p, cell)


def test_compare_matrices_flags_regression_and_drift():
    old = {"configs": {"m": {"a": {"status": "supported"}, "b": {"status": "rejected"}}}}
    new = {"configs": {"m": {"a": {"status": "rejected"}, "b": {"status": "supported"}}}}
    probs = compare_matrices(old, new)
    assert any(p.startswith("REGRESSION") and "m × a" in p for p in probs)
    assert any(p.startswith("drift") and "m × b" in p for p in probs)
    assert compare_matrices(new, new) == []
