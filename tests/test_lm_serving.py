"""LM token-exit serving: the per-token early-exit path (the assigned
archs' serving mode) through runner + controller end to end."""
import jax
import numpy as np
import pytest

from repro.configs import get_tiny
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.data import make_token_stream
from repro.models import build_model
from repro.serving import LMTokenRunner
from repro.training import TrainConfig, train


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_tiny("qwen2-1.5b").replace(n_layers=4)
    model = build_model(cfg)
    stream = make_token_stream(800, seq_len=24, vocab=cfg.vocab_size, n_classes=8,
                               mode="nlp", seed=5)
    # next-token LM objective over the stream's sequences
    def batches(s):
        rng = np.random.default_rng(s)
        idx = rng.integers(0, 200, 16)
        toks = stream.data[idx].astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    state, _ = train(model, batches, TrainConfig(steps=40, lr=2e-3), verbose=False)
    runner = LMTokenRunner(model, state["params"], stream.data[:, :-1].astype(np.int32),
                           max_slots=3)
    return cfg, model, runner


def test_lm_token_runner_records(lm_setup):
    cfg, model, runner = lm_setup
    labels, unc, final = runner.infer(np.arange(16), [0, 1])
    assert labels.shape == (2, 16)
    assert unc.shape == (2, 16)
    assert final.shape == (16,)
    assert (unc >= 0).all() and (unc <= 1).all()
    # vanilla labels stable across calls (deterministic)
    v1 = runner.vanilla_labels(32)
    v2 = runner.vanilla_labels(32)
    np.testing.assert_array_equal(v1, v2)


def test_lm_token_controller_loop(lm_setup):
    cfg, model, runner = lm_setup
    prof = build_profile(get_tiny("qwen2-1.5b").replace(n_layers=4), mode="decode")
    ctl = ApparateController(
        len(model.sites), prof,
        ControllerConfig(max_slots=3, tune_window=128, acc_constraint=0.98),
    )
    agree = []
    van = runner.vanilla_labels(800)
    for lo in range(200, 800, 16):
        idx = np.arange(lo, min(lo + 16, 800))
        lab, unc, fin = runner.infer(idx, sorted(ctl.active))
        dec = ctl.observe(lab, unc, fin)
        agree.append(np.mean(dec.released_labels == van[idx]))
    assert np.mean(agree) >= 0.95  # token-exit agreement maintained
    assert ctl.stats["samples"] == 600
