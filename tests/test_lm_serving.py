"""LM token-exit serving: the per-token early-exit path (the assigned
archs' serving mode) through runner + controller end to end."""
import jax
import numpy as np
import pytest

from repro.configs import get_tiny
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.data import make_token_stream
from repro.models import build_model
from repro.serving import LMTokenRunner
from repro.training import TrainConfig, train


@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_tiny("qwen2-1.5b").replace(n_layers=4)
    model = build_model(cfg)
    stream = make_token_stream(800, seq_len=24, vocab=cfg.vocab_size, n_classes=8,
                               mode="nlp", seed=5)
    # next-token LM objective over the stream's sequences
    def batches(s):
        rng = np.random.default_rng(s)
        idx = rng.integers(0, 200, 16)
        toks = stream.data[idx].astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    state, _ = train(model, batches, TrainConfig(steps=40, lr=2e-3), verbose=False)
    runner = LMTokenRunner(model, state["params"], stream.data[:, :-1].astype(np.int32),
                           max_slots=3)
    return cfg, model, runner


def test_lm_token_runner_records(lm_setup):
    cfg, model, runner = lm_setup
    labels, unc, final = runner.infer(np.arange(16), [0, 1])
    assert labels.shape == (2, 16)
    assert unc.shape == (2, 16)
    assert final.shape == (16,)
    assert (unc >= 0).all() and (unc <= 1).all()
    # vanilla labels stable across calls (deterministic)
    v1 = runner.vanilla_labels(32)
    v2 = runner.vanilla_labels(32)
    np.testing.assert_array_equal(v1, v2)


def test_lm_runner_oversized_active_and_vanilla_n(lm_setup):
    """Regressions (same contract as ClassifierRunner): an active set
    larger than `max_slots` must raise instead of silently truncating the
    record rows, and `vanilla_labels(0)` must return an empty array
    instead of remapping 0 to the whole dataset."""
    _, _, runner = lm_setup
    with pytest.raises(ValueError):
        runner.infer(np.arange(8), [0, 1, 2, 3])  # 4 sites > max_slots=3
    assert runner.vanilla_labels(0).shape == (0,)
    assert runner.vanilla_labels(0).dtype == np.int64
    v = runner.vanilla_labels(16)
    np.testing.assert_array_equal(v, runner.vanilla_labels(32)[:16])


def test_lm_runner_sorts_unsorted_active(lm_setup):
    """Regression: ``LMTokenRunner.infer`` used to slice/pad the caller's
    active set verbatim, so an unsorted set mis-ordered record rows against
    the controller's sorted-site convention. Both orders must now produce
    identical, sorted-site-ordered records."""
    cfg, model, runner = lm_setup
    idx = np.arange(12)
    l_a, u_a, f_a = runner.infer(idx, [2, 0])
    l_b, u_b, f_b = runner.infer(idx, [0, 2])
    np.testing.assert_array_equal(l_a, l_b)
    np.testing.assert_allclose(u_a, u_b)
    np.testing.assert_array_equal(f_a, f_b)
    # row 0 corresponds to site 0 (ascending), matching a single-site call
    l0, _, _ = runner.infer(idx, [0])
    np.testing.assert_array_equal(l_a[0], l0[0])
    l2, _, _ = runner.infer(idx, [2])
    np.testing.assert_array_equal(l_a[1], l2[0])


def test_lm_runner_no_ramp_variant(lm_setup):
    """With zero active ramps the runner must use the ramp-free compiled
    variant (vanilla serving pays no ramp compute) and still return the
    same final labels."""
    cfg, model, runner = lm_setup
    idx = np.arange(10)
    labels, unc, f0 = runner.infer(idx, [])
    assert labels.shape == (0, 10) and unc.shape == (0, 10)
    assert 16 in runner._fns0  # dedicated no-ramp compile for this bucket
    _, _, f1 = runner.infer(idx, [0])
    np.testing.assert_array_equal(f0, f1)


def test_lm_token_controller_loop(lm_setup):
    cfg, model, runner = lm_setup
    prof = build_profile(get_tiny("qwen2-1.5b").replace(n_layers=4), mode="decode")
    ctl = ApparateController(
        len(model.sites), prof,
        ControllerConfig(max_slots=3, tune_window=128, acc_constraint=0.98),
    )
    agree = []
    van = runner.vanilla_labels(800)
    for lo in range(200, 800, 16):
        idx = np.arange(lo, min(lo + 16, 800))
        lab, unc, fin = runner.infer(idx, sorted(ctl.active))
        dec = ctl.observe(lab, unc, fin)
        agree.append(np.mean(dec.released_labels == van[idx]))
    assert np.mean(agree) >= 0.95  # token-exit agreement maintained
    assert ctl.stats["samples"] == 600
