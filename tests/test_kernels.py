"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode).

The full sweeps are `slow` (interpret-mode Pallas is seconds per case on
CPU; opt in with `-m slow`); tier-1 keeps one smallest-shape smoke per
kernel so the Pallas path is always exercised.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.flash_attention import flash_attention, attention_ref
from repro.kernels.ramp_head import (
    ramp_head_exit,
    ramp_head_exit_ref,
    ramp_head_stats,
    ramp_head_stats_ref,
    stats_to_confidence,
)
from repro.kernels.ssd import ssd_chunked, ssd_chunked_ref


def test_kernels_smoke_interpret():
    """Tier-1 smoke: every Pallas kernel once, smallest shape, vs oracle."""
    h = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 512)) * 0.05
    out_k = ramp_head_stats(h, w, interpret=True, block_v=256)
    out_r = ramp_head_stats_ref(h, w)
    assert (np.asarray(out_k[3]) == np.asarray(out_r[3])).all()
    np.testing.assert_allclose(np.asarray(out_k[0]), np.asarray(out_r[0]), rtol=3e-3, atol=3e-3)

    thr = jnp.asarray([0.0, 0.5, 0.9, 1.0], jnp.float32)
    out_k = ramp_head_exit(h, w, thr, interpret=True, block_v=256)
    out_r = ramp_head_exit_ref(h, w, thr)
    assert (np.asarray(out_k[3]) == np.asarray(out_r[3])).all()
    assert (np.asarray(out_k[4]) == np.asarray(out_r[4])).all()
    assert int(out_k[4][0]) == 0  # threshold 0 can never trigger (strict <)
    np.testing.assert_allclose(np.asarray(out_k[0]), np.asarray(out_r[0]), rtol=3e-3, atol=3e-3)

    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16))
    k = jax.random.normal(ks[1], (1, 2, 32, 16))
    v = jax.random.normal(ks[2], (1, 2, 32, 16))
    o_k = flash_attention(q, k, v, causal=True, block_q=16, block_k=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(o_k), np.asarray(attention_ref(q, k, v, causal=True)), rtol=2e-5, atol=2e-5
    )
    o_k = decode_attention(q[:, :, 0], k, v, jnp.int32(10), block_s=16, interpret=True)
    np.testing.assert_allclose(
        np.asarray(o_k), np.asarray(decode_attention_ref(q[:, :, 0], k, v, jnp.int32(10))),
        rtol=2e-5, atol=2e-5,
    )

    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (1, 1, 32, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 1, 32)))
    A = -jnp.exp(jax.random.normal(ks[2], (1,)) * 0.3)
    Bm = jax.random.normal(ks[3], (1, 32, 4)) * 0.5
    Cm = jax.random.normal(ks[4], (1, 32, 4)) * 0.5
    yk, sk = ssd_chunked(x, dt, A, Bm, Cm, chunk=8, interpret=True)
    yr, sr = ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=8)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize(
    "B,d,V,dt,bv",
    [
        (8, 64, 2048, jnp.float32, 512),
        (16, 128, 4096, jnp.bfloat16, 1024),
        (8, 256, 1024, jnp.float32, 256),
        (4, 32, 512, jnp.bfloat16, 512),
    ],
)
def test_ramp_head(B, d, V, dt, bv):
    h = jax.random.normal(jax.random.PRNGKey(0), (B, d), dt)
    w = jax.random.normal(jax.random.PRNGKey(1), (d, V), dt) * 0.05
    out_k = ramp_head_stats(h, w, interpret=True, block_v=bv)
    out_r = ramp_head_stats_ref(h, w)
    assert (np.asarray(out_k[3]) == np.asarray(out_r[3])).all()
    for a, b in zip(out_k[:3], out_r[:3]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3, atol=3e-3)
    ck, cr = stats_to_confidence(*out_k), stats_to_confidence(*out_r)
    np.testing.assert_allclose(np.asarray(ck[1]), np.asarray(cr[1]), rtol=5e-3)
    np.testing.assert_allclose(np.asarray(ck[2]), np.asarray(cr[2]), rtol=5e-3, atol=5e-3)


def test_ramp_head_confidence_semantics():
    """maxprob/entropy derived from streaming stats match direct softmax."""
    h = jax.random.normal(jax.random.PRNGKey(2), (4, 32))
    w = jax.random.normal(jax.random.PRNGKey(3), (32, 256)) * 0.3
    m, s, t, idx = ramp_head_stats_ref(h, w)
    label, maxprob, entropy, lse = stats_to_confidence(m, s, t, idx)
    logits = h @ w
    p = jax.nn.softmax(logits, -1)
    np.testing.assert_allclose(np.asarray(maxprob), np.asarray(p.max(-1)), rtol=1e-5)
    href = -jnp.sum(p * jnp.log(p + 1e-30), -1)
    np.testing.assert_allclose(np.asarray(entropy), np.asarray(href), rtol=1e-4, atol=1e-4)


def test_ramp_head_exit_threshold_semantics():
    """Strict-< exit boundary, bit-exact against the ref oracle's own unc:
    thr == unc must NOT exit; the next float up must; thr 0 never does."""
    h = jax.random.normal(jax.random.PRNGKey(4), (4, 32))
    w = jax.random.normal(jax.random.PRNGKey(5), (32, 256)) * 0.05
    _, s, _, _ = ramp_head_stats_ref(h, w)
    unc = np.asarray(1.0 - 1.0 / s, np.float32)

    for thr, want in [
        (np.zeros(4, np.float32), np.zeros(4, np.int32)),        # never exits
        (unc.copy(), np.zeros(4, np.int32)),                     # == : strict, no exit
        (np.nextafter(unc, np.float32(2.0)), np.ones(4, np.int32)),  # just above: exits
        (np.ones(4, np.float32), np.ones(4, np.int32)),          # 1.0 > unc always
    ]:
        for fn in (
            lambda t: ramp_head_exit(h, w, jnp.asarray(t), interpret=True, block_v=256)[4],
            lambda t: ramp_head_exit_ref(h, w, jnp.asarray(t))[4],
        ):
            got = np.asarray(fn(thr))
            assert (got == want).all(), (thr, got, want)


@pytest.mark.slow
@pytest.mark.parametrize(
    "B,d,V,dt,bv",
    [
        (8, 64, 2048, jnp.float32, 512),
        (16, 128, 4096, jnp.bfloat16, 1024),
        (4, 32, 512, jnp.bfloat16, 512),
    ],
)
def test_ramp_head_exit_sweep(B, d, V, dt, bv):
    h = jax.random.normal(jax.random.PRNGKey(0), (B, d), dt)
    w = jax.random.normal(jax.random.PRNGKey(1), (d, V), dt) * 0.05
    thr = jnp.linspace(0.0, 1.0, B, dtype=jnp.float32)
    out_k = ramp_head_exit(h, w, thr, interpret=True, block_v=bv)
    out_r = ramp_head_exit_ref(h, w, thr)
    assert (np.asarray(out_k[3]) == np.asarray(out_r[3])).all()
    assert (np.asarray(out_k[4]) == np.asarray(out_r[4])).all()
    for a, b in zip(out_k[:3], out_r[:3]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3, atol=3e-3)


@pytest.mark.slow
@pytest.mark.parametrize(
    "B,H,KH,Sq,Sk,hd,causal,window,dt",
    [
        (2, 4, 2, 64, 64, 32, True, None, jnp.float32),
        (1, 4, 4, 32, 64, 16, False, None, jnp.float32),
        (2, 8, 2, 64, 64, 32, True, 16, jnp.bfloat16),
        (1, 2, 1, 32, 32, 64, True, None, jnp.bfloat16),
    ],
)
def test_flash_attention(B, H, KH, Sq, Sk, hd, causal, window, dt):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), dt)
    k = jax.random.normal(ks[1], (B, KH, Sk, hd), dt)
    v = jax.random.normal(ks[2], (B, KH, Sk, hd), dt)
    o_k = flash_attention(q, k, v, causal=causal, window=window, block_q=16, block_k=16, interpret=True)
    o_r = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o_k, np.float32), np.asarray(o_r, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.slow
@pytest.mark.parametrize(
    "B,H,S,hp,N,ck", [(2, 3, 64, 16, 8, 16), (1, 2, 128, 32, 16, 32), (1, 1, 32, 8, 4, 8)]
)
def test_ssd_kernel(B, H, S, hp, N, ck):
    ks = jax.random.split(jax.random.PRNGKey(B + H), 5)
    x = jax.random.normal(ks[0], (B, H, S, hp))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.5
    yk, sk = ssd_chunked(x, dt, A, Bm, Cm, chunk=ck, interpret=True)
    yr, sr = ssd_chunked_ref(x, dt, A, Bm, Cm, chunk=ck)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-4, atol=1e-4)


def test_ssd_ref_matches_naive_recurrence():
    """Chunked SSD oracle vs the literal h' = e^{dtA} h + dt·B⊗x scan."""
    from repro.models.mamba import ssd_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, Pd, N = 1, 12, 2, 4, 3
    x = jax.random.normal(ks[0], (B, S, H, Pd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, 1, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, 1, N)) * 0.5
    y, st = ssd_ref(x, dt, A, Bm, Cm, chunk=4)
    h = np.zeros((B, H, Pd, N))
    for s in range(S):
        for b in range(B):
            for hh in range(H):
                a = np.exp(float(dt[b, s, hh]) * float(A[hh]))
                h[b, hh] = h[b, hh] * a + np.outer(
                    np.asarray(x[b, s, hh]) * float(dt[b, s, hh]), np.asarray(Bm[b, s, 0])
                )
                yy = h[b, hh] @ np.asarray(Cm[b, s, 0])
                np.testing.assert_allclose(np.asarray(y[b, s, hh]), yy, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), h, rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize(
    "B,H,KH,S,hd,pos",
    [(2, 4, 2, 128, 32, 63), (1, 8, 8, 256, 16, 255), (2, 4, 1, 64, 64, 10)],
)
def test_decode_attention(B, H, KH, S, hd, pos):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KH, S, hd))
    v = jax.random.normal(ks[2], (B, KH, S, hd))
    o_k = decode_attention(q, k, v, jnp.int32(pos), block_s=32, interpret=True)
    o_r = decode_attention_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S", [48, 47])  # non-multiple and PRIME cache lengths
def test_decode_attention_per_row_pos(S):
    """Per-row cache positions (batched slot caches at staggered decode
    offsets): the kernel masks each row at its own pos, matching the ref
    and per-row scalar-pos calls. Cache lengths that block_s does not
    divide (incl. primes) keep the full tile size — the padded tail tile
    is masked in-kernel, never shrunk."""
    B, H, KH, hd = 3, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KH, S, hd))
    v = jax.random.normal(ks[2], (B, KH, S, hd))
    pos = jnp.asarray([5, 31, S - 1], jnp.int32)
    o_k = decode_attention(q, k, v, pos, block_s=32, interpret=True)
    o_r = decode_attention_ref(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=2e-5, atol=2e-5)
    # each row == the same row computed alone with its scalar pos
    for b in range(B):
        o_b = decode_attention_ref(q[b : b + 1], k[b : b + 1], v[b : b + 1],
                                   jnp.int32(int(pos[b])))
        np.testing.assert_array_equal(np.asarray(o_r[b]), np.asarray(o_b[0]))
