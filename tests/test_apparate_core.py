"""Apparate core: exit evaluation, Algorithm-1 tuner, ramp adjustment,
controller — including seeded-numpy property tests on EE invariants.

The property tests draw their cases from a module-level seeded generator
(stdlib + numpy + pytest only — no `hypothesis`): every run sees the same
case set, and each case shows up as its own parametrized test id.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    ApparateController,
    ControllerConfig,
    build_profile,
    evaluate_config,
    evaluate_configs,
    exit_rates,
    grid_search_thresholds,
    ramp_utilities,
    simulate_exits,
    simulate_exits_many,
    tune_thresholds,
    tune_thresholds_reference,
)
from repro.core.exits import RecordWindow
from repro.core.ramp_adjust import adjust_ramps

PROF = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)
NS = len(PROF.sites)

_case_rng = np.random.default_rng(20240731)

# 30 random draws + deterministic edge cases (threshold bounds, first/last site)
MONO_CASES = [
    (int(_case_rng.integers(0, 101)), int(_case_rng.integers(0, NS)),
     float(_case_rng.random()), float(_case_rng.random()))
    for _ in range(30)
] + [(0, 0, 0.0, 1.0), (0, NS - 1, 0.0, 1.0), (7, NS // 2, 0.5, 0.5)]

ACC_MONO_CASES = [
    (int(_case_rng.integers(0, 51)), int(_case_rng.integers(0, NS)),
     float(0.1 + 0.9 * _case_rng.random()))
    for _ in range(20)
] + [(0, 0, 1.0), (0, NS - 1, 0.1)]


def synth_window(n=256, n_sites=NS, seed=0, difficulty=0.5, active=None):
    rng = np.random.default_rng(seed)
    active = list(range(n_sites)) if active is None else active
    unc = np.full((n, n_sites), np.nan, np.float32)
    cor = np.zeros((n, n_sites), bool)
    val = np.zeros((n, n_sites), bool)
    for s in active:
        frac = (s + 1) / n_sites
        p_agree = np.clip(1 - difficulty * (1 - frac) ** 1.5, 0, 1)
        cor[:, s] = rng.random(n) < p_agree
        unc[:, s] = np.clip(difficulty * (1 - frac) + rng.normal(0, 0.08, n), 0, 1)
        val[:, s] = True
    return unc, cor, val


# -- exit semantics -----------------------------------------------------------


def test_simulate_exits_first_site():
    unc = np.asarray([[0.5, 0.1, 0.0], [0.9, 0.9, 0.9], [0.0, 0.9, 0.9]], np.float32)
    val = np.ones_like(unc, bool)
    thr = np.asarray([0.2, 0.2, 0.2], np.float32)
    ex = simulate_exits(unc, val, thr, [0, 1, 2])
    assert ex.tolist() == [1, -1, 0]
    # inactive ramps never exit
    ex = simulate_exits(unc, val, thr, [2])
    assert ex.tolist() == [2, -1, -1]


def test_zero_thresholds_no_exits():
    wd = synth_window()
    ev = evaluate_config(wd, np.zeros(NS, np.float32), list(range(NS)), PROF)
    # threshold 0 admits only unc==0 samples; accuracy stays ~1
    assert ev.accuracy >= 0.99


@pytest.mark.parametrize("seed,site,t1,t2", MONO_CASES)
def test_monotonicity_property(seed, site, t1, t2):
    """Paper §3.2: raising any single threshold monotonically increases exit
    rate & latency savings. (Accuracy monotonicity is statistical — paper
    footnote 2: used only for search efficiency, not correctness — so it is
    asserted below only on windows with per-sample monotone correctness.)"""
    lo, hi = sorted([t1, t2])
    wd = synth_window(seed=seed, n=128)
    base = np.full(NS, 0.3, np.float32)
    a = base.copy(); a[site] = lo
    b = base.copy(); b[site] = hi
    act = list(range(NS))
    ea = evaluate_config(wd, a, act, PROF)
    eb = evaluate_config(wd, b, act, PROF)
    assert eb.exit_rate >= ea.exit_rate - 1e-9
    assert eb.mean_saved_ms >= ea.mean_saved_ms - 1e-9


@pytest.mark.parametrize("seed,site,hi", ACC_MONO_CASES)
def test_accuracy_monotone_on_monotone_windows(seed, site, hi):
    """When per-sample correctness is monotone in depth (later ramps at
    least as correct), raising thresholds never raises accuracy."""
    rng = np.random.default_rng(seed)
    n = 128
    unc = np.zeros((n, NS), np.float32)
    cor = np.zeros((n, NS), bool)
    hardness = rng.random(n)
    for s in range(NS):
        frac = (s + 1) / NS
        cor[:, s] = hardness < frac + 0.15  # monotone in s per sample
        unc[:, s] = np.clip(hardness * (1 - frac) + rng.normal(0, 0.02, n), 0, 1)
    val = np.ones((n, NS), bool)
    wd = (unc, cor, val)
    base = np.full(NS, 0.2, np.float32)
    b = base.copy(); b[site] = max(hi, base[site])
    act = list(range(NS))
    ea = evaluate_config(wd, base, act, PROF)
    eb = evaluate_config(wd, b, act, PROF)
    assert eb.accuracy <= ea.accuracy + 1e-9


# -- threshold tuning ---------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("difficulty", [0.3, 0.6])
def test_tuner_meets_constraint(seed, difficulty):
    """The tuner never violates `acc_constraint` on its tune window."""
    wd = synth_window(seed=seed, difficulty=difficulty)
    res = tune_thresholds(wd, list(range(NS)), PROF, n_sites=NS, acc_constraint=0.99)
    assert res.accuracy >= 0.99 - 1e-9
    assert res.savings_ms >= 0 or np.all(res.thresholds == 0)


def test_tuner_vs_grid_quality_and_speed():
    wd = synth_window(seed=3, difficulty=0.5)
    act = [2, 6, 10]
    g = grid_search_thresholds(wd, act, PROF, n_sites=NS, step=0.25)
    t = tune_thresholds(wd, act, PROF, n_sites=NS)
    assert t.accuracy >= 0.99 - 1e-9
    # greedy with fine steps should match/beat a coarse grid
    assert t.savings_ms >= g.savings_ms - 1e-6
    # far fewer evaluations than the 5^3 grid
    assert t.rounds < g.rounds


def test_tuner_zero_start():
    """Thresholds start at 0 (no exits) — the paper's safe bootstrap."""
    wd = synth_window(seed=0)
    res = tune_thresholds(wd, [0], PROF, n_sites=NS, acc_constraint=1.1)  # impossible
    assert np.all(res.thresholds == 0)


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("difficulty", [0.3, 0.6])
def test_vectorized_tuner_bit_identical_to_reference(seed, difficulty):
    """The vectorized hot loop (all K per-round candidates in one batched
    `simulate_exits` pass, per-site cost vectors hoisted) must reproduce
    the sequential Algorithm-1 implementation EXACTLY — thresholds,
    savings, accuracy, and round count, bit for bit."""
    rng = np.random.default_rng(seed)
    wd = synth_window(n=int(rng.integers(64, 512)), seed=seed, difficulty=difficulty)
    act = sorted(rng.choice(NS, size=int(rng.integers(1, 7)), replace=False).tolist())
    acc_c = float(rng.choice([0.95, 0.99, 0.995]))
    a = tune_thresholds(wd, act, PROF, n_sites=NS, acc_constraint=acc_c)
    b = tune_thresholds_reference(wd, act, PROF, n_sites=NS, acc_constraint=acc_c)
    np.testing.assert_array_equal(a.thresholds, b.thresholds)
    assert a.savings_ms == b.savings_ms
    assert a.accuracy == b.accuracy
    assert a.rounds == b.rounds


@pytest.mark.parametrize("seed", range(4))
def test_evaluate_configs_rows_match_evaluate_config(seed):
    """Each row of the batched evaluator == the sequential evaluator."""
    rng = np.random.default_rng(seed)
    wd = synth_window(seed=seed, difficulty=0.5)
    act = sorted(rng.choice(NS, size=4, replace=False).tolist())
    thr_batch = rng.random((7, NS)).astype(np.float32)
    accs, savs, rates, exs = evaluate_configs(wd, thr_batch, act, PROF)
    ex_many = simulate_exits_many(wd[0], wd[2], thr_batch, act)
    for c in range(thr_batch.shape[0]):
        ev = evaluate_config(wd, thr_batch[c], act, PROF)
        assert ev.accuracy == accs[c] and ev.mean_saved_ms == savs[c]
        assert ev.exit_rate == rates[c]
        np.testing.assert_array_equal(ev.exit_sites, exs[c])
        np.testing.assert_array_equal(
            simulate_exits(wd[0], wd[2], thr_batch[c], act), ex_many[c]
        )


# -- ramp utilities / adjustment ----------------------------------------------


def test_utilities_sign():
    wd = synth_window(seed=1, difficulty=0.3)
    thr = np.full(NS, 0.5, np.float32)
    utils = ramp_utilities(wd, thr, list(range(NS)), PROF)
    # easy workload + open thresholds: (almost) everything exits at ramp 0,
    # which must be net positive; downstream ramps see nothing (utility ~0)
    assert utils[0] > 0
    assert all(utils[s] <= utils[0] for s in range(NS))
    # with threshold 0 nothing exits -> every ramp utility <= 0
    utils0 = ramp_utilities(wd, np.zeros(NS, np.float32), list(range(NS)), PROF)
    assert all(u <= 0 for u in utils0.values())


def test_adjust_deactivates_negative():
    wd = synth_window(seed=2, difficulty=0.9)
    thr = np.zeros(NS, np.float32)
    thr[[1, 9]] = 0.4
    res = adjust_ramps(
        wd, [1, 9], thr, PROF, n_sites=NS, acc_constraint=0.99, budget_frac=0.05,
        max_slots=4,
    )
    # early ramp 1 on a hard workload should be unprofitable -> removed
    # (or rescued by tuning; both are valid paper behaviors)
    assert res.reason in ("deactivated-negative", "rescued-by-tuning")
    if res.reason == "deactivated-negative":
        assert 1 not in res.active or 9 not in res.active


@pytest.mark.parametrize("seed", range(4))
def test_adjust_budget_respected(seed):
    wd = synth_window(seed=seed, difficulty=0.2)
    thr = np.full(NS, 0.6, np.float32)
    res = adjust_ramps(
        wd, list(range(NS)), thr, PROF, n_sites=NS, acc_constraint=0.9,
        budget_frac=1e-9, max_slots=12,
    )
    assert res.reason in ("budget-shrink", "deactivated-negative")
    ovh = sum(PROF.ramp_overhead(s, 1) for s in res.active)
    assert ovh <= 1e-9 * PROF.vanilla_time(1) + 1e-12 or len(res.active) == 0


# -- controller ---------------------------------------------------------------


def _drive(ctl, n_steps, difficulty, seed=0, B=8, budget_probe=None):
    rng = np.random.default_rng(seed)
    accs = []
    for _ in range(n_steps):
        final = rng.integers(0, 50, B)
        act = sorted(ctl.active)
        K = len(act)
        labels = np.zeros((max(K, 1), B), np.int64)
        unc = np.ones((max(K, 1), B), np.float32)
        for j, s in enumerate(act):
            frac = (s + 1) / ctl.n_sites
            agree = rng.random(B) < np.clip(1 - difficulty * (1 - frac) ** 1.5, 0, 1)
            labels[j] = np.where(agree, final, (final + 1) % 50)
            unc[j] = np.clip(difficulty * (1 - frac) + rng.normal(0, 0.08, B), 0, 1)
        dec = ctl.observe(labels[:K] if K else labels[:0], unc[:K] if K else unc[:0], final)
        accs.append(np.mean(dec.released_labels == final))
        if budget_probe is not None:
            budget_probe(ctl)
    return np.asarray(accs)


def test_controller_maintains_accuracy_through_drift():
    ctl = ApparateController(NS, PROF, ControllerConfig(max_slots=4, tune_window=256))
    a1 = _drive(ctl, 150, 0.3, seed=1)
    a2 = _drive(ctl, 150, 0.8, seed=2)  # drift: harder
    # paper Table 1: continual tuning holds ~98-99% through drift
    assert a2[50:].mean() >= 0.96, a2[50:].mean()
    assert ctl.stats["tunes"] > 0
    assert ctl.stats["adjusts"] > 0


@pytest.mark.parametrize("seed,difficulty", [(0, 0.2), (1, 0.5), (2, 0.8)])
def test_controller_budget_invariant_under_drive(seed, difficulty):
    """Ramp budget holds at every step of the adaptation loop, not just at
    init: Σ ramp-overhead ≤ ramp_budget_frac · vanilla latency."""
    cfg = ControllerConfig(max_slots=6, ramp_budget_frac=0.02)
    ctl = ApparateController(NS, PROF, cfg)
    lim = cfg.ramp_budget_frac * PROF.vanilla_time(1) + 1e-9

    def probe(c):
        assert c.total_ramp_overhead(1) <= lim

    _drive(ctl, 120, difficulty, seed=seed, budget_probe=probe)
    assert ctl.stats["samples"] == 120 * 8


def test_controller_initial_state_no_exits():
    ctl = ApparateController(NS, PROF, ControllerConfig(max_slots=4))
    assert np.all(ctl.thresholds == 0)  # threshold 0 = no exiting (paper)
    assert len(ctl.active) >= 1
    ovh = ctl.total_ramp_overhead(1)
    assert ovh <= ctl.cfg.ramp_budget_frac * PROF.vanilla_time(1) + 1e-9


def test_record_window_ring():
    w = RecordWindow(4, capacity=8)
    for i in range(5):
        w.append([0, 2], np.full((2, 3), i / 10), np.ones((2, 3), bool))
    unc, cor, val = w.last(6)
    assert unc.shape == (6, 4)
    assert val[:, 0].all() and val[:, 2].all()
    assert not val[:, 1].any()
    assert w.count == 15


def test_record_window_overflow_batch_keeps_newest():
    """Regression: a batch larger than capacity used to produce duplicate
    ring indices — later rows overwrote earlier ones in arbitrary order
    while ``count`` advanced by B. Only the newest ``capacity`` samples can
    survive, in arrival order."""
    cap = 8
    w = RecordWindow(2, capacity=cap)
    B = 20
    unc = np.tile(np.arange(B, dtype=np.float32) / 100.0, (2, 1))
    w.append([0, 1], unc, np.ones((2, B), bool))
    assert w.count == B  # total ever observed
    u, c, v = w.last(cap)
    assert u.shape == (cap, 2)
    # the ring holds exactly the LAST `cap` samples, oldest-to-newest
    np.testing.assert_allclose(u[:, 0], np.arange(B - cap, B) / 100.0)
    assert v.all()
    # subsequent normal-size appends continue in order from the right ptr
    w.append([0, 1], np.full((2, 3), 0.77, np.float32), np.ones((2, 3), bool))
    u2, _, _ = w.last(5)
    np.testing.assert_allclose(u2[:, 0], [0.18, 0.19, 0.77, 0.77, 0.77])
    assert w.count == B + 3


def test_uncertainty_entropy_requires_n_classes_and_is_normalized():
    """Regression: the old ``n_classes`` fallback was an operator-precedence
    accident (``np.e ** H.max() + 1``) that could yield normalized
    uncertainty > 1. The entropy metric now requires ``n_classes`` and
    normalizes by log(n_classes) so uncertainty lands in [0, 1]."""
    ctl = ApparateController(NS, PROF, ControllerConfig(metric="entropy"))
    n_classes = 10
    # worst case: uniform distribution -> H = log(C) -> uncertainty 1.0
    ent = np.asarray([0.0, np.log(n_classes) / 2, np.log(n_classes)], np.float32)
    unc = ctl.uncertainty({"entropy": ent, "n_classes": n_classes})
    np.testing.assert_allclose(unc, [0.0, 0.5, 1.0], atol=1e-6)
    assert (unc <= 1.0 + 1e-6).all() and (unc >= 0).all()
    with pytest.raises(KeyError):
        ctl.uncertainty({"entropy": ent})  # n_classes is mandatory now
    # maxprob metric unchanged
    ctl2 = ApparateController(NS, PROF, ControllerConfig(metric="maxprob"))
    np.testing.assert_allclose(
        ctl2.uncertainty({"maxprob": np.asarray([0.25, 1.0])}), [0.75, 0.0]
    )
