"""End-to-end behaviour: train a real (tiny, paper-shape) model, serve a
drifting stream through the simulator with the Apparate controller, and
assert the paper's three headline properties:

  1. median/p25 latency drops vs vanilla serving,
  2. throughput (mean batch size) is unchanged and tail stays within the
     ramp budget,
  3. agreement accuracy with the original model's outputs meets the
     constraint (within drift-transient slack, paper Table 1).
"""
import numpy as np
import pytest

from repro.configs import get_bench, get_config
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.data import make_image_stream
from repro.models import build_model
from repro.serving import (
    ClassifierRunner,
    PlatformConfig,
    ServingSimulator,
    make_requests,
    summarize,
    video_trace,
)
from repro.training import TrainConfig, train


@pytest.fixture(scope="module")
def cv_setup():
    cfg = get_bench("resnet18").replace(n_classes=10)
    model = build_model(cfg)
    stream = make_image_stream(2200, img_size=cfg.img_size, n_classes=10, mode="cv", seed=2)

    def batches(s):
        rng = np.random.default_rng(s)
        idx = rng.integers(0, 220, 64)
        return {"images": stream.data[idx], "labels": stream.labels[idx]}

    state, _ = train(model, batches, TrainConfig(steps=120, lr=3e-3), verbose=False)
    prof = build_profile(
        get_config("resnet18").replace(resnet_widths=(64, 128, 256, 512), img_size=224),
        mode="decode", chips=1,
    )
    runner = ClassifierRunner(model, state["params"], stream.data, max_slots=6)
    return cfg, model, runner, stream, prof


def test_end_to_end_cv_serving(cv_setup):
    cfg, model, runner, stream, prof = cv_setup
    n0, n = 220, 2200
    exec1 = prof.vanilla_time(1)
    arr = video_trace(n - n0, fps=0.5 * 1000.0 / exec1)
    reqs = make_requests(arr, slo_ms=2 * exec1, items=np.arange(n0, n))
    pf = PlatformConfig(policy="tfserve", max_batch_size=8, batch_timeout_ms=exec1)
    base = ServingSimulator(prof, pf).run(reqs)
    ctl = ApparateController(
        len(model.sites), prof,
        ControllerConfig(max_slots=6, ramp_budget_frac=0.02, acc_constraint=0.99),
    )
    resp = ServingSimulator(prof, pf, runner, ctl).run(reqs)
    van = runner.vanilla_labels(n)
    agree = np.mean([r.label == van[n0 + r.rid] for r in resp if not r.dropped])
    mb, mo = summarize(base), summarize(resp)
    # 1. latency wins
    assert mo["p50_ms"] < mb["p50_ms"], (mo["p50_ms"], mb["p50_ms"])
    assert mo["p25_ms"] < mb["p25_ms"]
    # 2. throughput unchanged; tail within ramp budget
    assert abs(mo["mean_batch"] - mb["mean_batch"]) < 1e-6
    assert mo["p99_ms"] <= mb["p99_ms"] * 1.02 + 1e-6
    # 3. accuracy constraint (drift-transient slack per paper Table 1)
    assert agree >= 0.97, agree
    # controller actually adapted
    assert ctl.stats["adjusts"] > 0
