"""The unified engine facades must be EXACTLY the pre-refactor loops.

PR 5 rebuilt ``ServingSimulator``/``ClusterSimulator``/
``MixedClusterSimulator``/``GenerativeEngine`` as thin facades over the
event-driven core in `repro.serving.engine`. The pre-refactor loop
bodies are frozen verbatim in `repro.serving.reference` (the PR 3/4
oracle pattern: ``LoopDecodeRunner``, ``tune_thresholds_reference``),
and this suite drives seeded randomized arrival schedules through BOTH
entry points, comparing full response records bit-for-bit.

Also pins the one intentional behavior the refactor ADDS: all pools of a
``MixedClusterSimulator`` now run on ONE event heap and ONE monotone
clock, so completions interleave in true global time order
(``EngineCore.completions``) — the property the old independent-pool
frontend could not even observe. And the metrics-dedup satellite: the
shared percentile/span/rate helpers must reproduce the historical
summary outputs exactly on a recorded stream.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.serving import (
    ClusterConfig,
    ClusterSimulator,
    GenerativeConfig,
    GenerativeEngine,
    GenResponse,
    MixedClusterSimulator,
    PlatformConfig,
    ReferenceClusterSimulator,
    ReferenceGenerativeEngine,
    ReferenceMixedClusterSimulator,
    Response,
    ServingSimulator,
    SyntheticDecodeRunner,
    SyntheticRunner,
    make_gen_requests,
    make_requests,
    maf_trace,
    offered_decode_qps,
    summarize,
    summarize_cluster,
    summarize_generative,
)

PROF = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)
GPROF = build_profile(
    get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied"),
    mode="decode", chips=1, charge_kv=True,
)
NS = len(PROF.sites)
NGS = len(GPROF.sites)


def _cls_records(responses):
    return [
        (r.rid, r.release_ms, r.label, r.exit_site, r.latency_ms, r.batch_size,
         r.dropped, r.worker, r.slo_ms)
        for r in responses
    ]


def _gen_records(responses):
    return [
        (r.rid, r.arrival_ms, tuple(r.release_ms), tuple(r.exit_sites),
         tuple(r.tokens), tuple(r.final_tokens), r.worker)
        for r in responses
    ]


def _rand_cls_requests(rng, n):
    mbs = 8
    cap = mbs * 1000.0 / PROF.vanilla_time(mbs)
    arr = maf_trace(n, mean_qps=float(rng.uniform(0.3, 2.5)) * cap,
                    seed=int(rng.integers(1 << 30)))
    return make_requests(arr, slo_ms=float(rng.uniform(1.2, 4.0)) * PROF.vanilla_time(1))


# -- classification facade fuzz ----------------------------------------------


@pytest.mark.parametrize("seed", range(12))
def test_cluster_facade_bit_identical_fuzz(seed):
    """Seeded random arrival schedules x random platform/cluster configs:
    the facade's full response records (order included) match the frozen
    pre-refactor loop bit for bit, as do makespan and worker stats."""
    rng = np.random.default_rng(1000 + seed)
    reqs = _rand_cls_requests(rng, int(rng.integers(60, 260)))
    policy = ["tfserve", "clockwork"][int(rng.integers(2))]
    pf = PlatformConfig(
        policy=policy,
        max_batch_size=int(rng.integers(2, 17)),
        batch_timeout_ms=float(rng.uniform(0.3, 3.0)) * PROF.vanilla_time(1),
        drop_on_slo_miss=bool(rng.integers(2)) and policy == "clockwork",
    )
    nw = int(rng.integers(1, 5))
    dispatch = ["round_robin", "jsq", "slo_aware"][int(rng.integers(3))]
    cc = ClusterConfig(n_workers=nw, dispatch=dispatch, platform=pf)
    with_ee = bool(rng.integers(2))
    kw_new, kw_ref = {}, {}
    if with_ee:
        runner = SyntheticRunner(NS, exit_site=NS // 3, easy_frac=0.8)
        kw_new = dict(runner=runner, controllers=[
            ApparateController(NS, PROF, ControllerConfig(max_slots=4)) for _ in range(nw)])
        kw_ref = dict(runner=runner, controllers=[
            ApparateController(NS, PROF, ControllerConfig(max_slots=4)) for _ in range(nw)])
    sim = ClusterSimulator(PROF, cc, **kw_new)
    ref = ReferenceClusterSimulator(PROF, cc, **kw_ref)
    a, b = sim.run(reqs), ref.run(reqs)
    assert _cls_records(a) == _cls_records(b)
    assert sim.makespan_ms == ref.makespan_ms
    assert sim.worker_stats() == ref.worker_stats()


def test_serving_simulator_facade_matches_reference():
    """The 1-worker facade chain (ServingSimulator -> ClusterSimulator ->
    engine core) equals the reference loop byte for byte."""
    rng = np.random.default_rng(7)
    reqs = _rand_cls_requests(rng, 150)
    pf = PlatformConfig(policy="tfserve", max_batch_size=8,
                        batch_timeout_ms=PROF.vanilla_time(1))
    a = ServingSimulator(PROF, pf).run(reqs)
    b = ReferenceClusterSimulator(PROF, ClusterConfig(n_workers=1, platform=pf)).run(reqs)
    assert _cls_records(a) == _cls_records(b)


# -- generative facade fuzz ---------------------------------------------------


@pytest.mark.parametrize("seed", range(10))
def test_generative_facade_bit_identical_fuzz(seed):
    """Seeded random decode schedules (jittered token counts, random load
    and slot counts, with/without the EE runner+controller): facade and
    frozen loop produce identical responses AND identical engine stats."""
    rng = np.random.default_rng(2000 + seed)
    mbs = int(rng.integers(2, 9))
    tokens = int(rng.integers(2, 24))
    n = int(rng.integers(10, 50))
    qps = offered_decode_qps(GPROF, max_batch_size=mbs, tokens_per_request=tokens,
                             load=float(rng.uniform(0.3, 2.0)))
    arr = maf_trace(n, mean_qps=qps, seed=int(rng.integers(1 << 30)))
    nt = rng.integers(1, 2 * tokens + 1, n)
    reqs = make_gen_requests(arr, n_tokens=nt, prompt_len=int(rng.integers(8, 128)),
                             slo_ms=3 * GPROF.vanilla_time(1))
    with_ee = bool(rng.integers(2))
    kw_new, kw_ref = {}, {}
    if with_ee:
        site = int(rng.integers(NGS))
        kw_new = dict(runner=SyntheticDecodeRunner(NGS, exit_site=site),
                      controller=ApparateController(NGS, GPROF, ControllerConfig(max_slots=4)))
        kw_ref = dict(runner=SyntheticDecodeRunner(NGS, exit_site=site),
                      controller=ApparateController(NGS, GPROF, ControllerConfig(max_slots=4)))
    eng = GenerativeEngine(GPROF, GenerativeConfig(max_batch_size=mbs), **kw_new)
    ref = ReferenceGenerativeEngine(GPROF, GenerativeConfig(max_batch_size=mbs), **kw_ref)
    a, b = eng.run(reqs), ref.run(reqs)
    assert _gen_records(a) == _gen_records(b)
    assert (eng.makespan_ms, eng.busy_ms, eng.kv_ms, eng.n_steps, eng.n_tokens,
            eng.peak_slots, eng.slot_history) == (
        ref.makespan_ms, ref.busy_ms, ref.kv_ms, ref.n_steps, ref.n_tokens,
        ref.peak_slots, ref.slot_history)


def test_generative_facade_empty_run():
    eng = GenerativeEngine(GPROF, GenerativeConfig(max_batch_size=4))
    ref = ReferenceGenerativeEngine(GPROF, GenerativeConfig(max_batch_size=4))
    assert eng.run([]) == ref.run([]) == []
    assert eng.makespan_ms == ref.makespan_ms == 0.0


# -- mixed cluster: facade equivalence + the single-clock regression ---------


def _mixed_pair(seed):
    rng = np.random.default_rng(seed)
    pf = PlatformConfig(policy="tfserve", max_batch_size=8,
                        batch_timeout_ms=PROF.vanilla_time(1))

    def build(sim_cls, eng_cls):
        cls_sim = sim_cls(
            PROF, ClusterConfig(n_workers=2, dispatch="jsq", platform=pf),
            runner=SyntheticRunner(NS, exit_site=NS // 3),
            controllers=[ApparateController(NS, PROF, ControllerConfig(max_slots=4))
                         for _ in range(2)],
        )
        gens = [
            eng_cls(GPROF, GenerativeConfig(max_batch_size=4),
                    SyntheticDecodeRunner(NGS, exit_site=NGS // 3),
                    ApparateController(NGS, GPROF, ControllerConfig(max_slots=4)))
            for _ in range(2)
        ]
        return cls_sim, gens

    cls_reqs = _rand_cls_requests(rng, 120)
    qps = offered_decode_qps(GPROF, max_batch_size=4, tokens_per_request=10, load=1.4)
    gen_reqs = make_gen_requests(
        maf_trace(24, mean_qps=qps, seed=seed), n_tokens=10, prompt_len=32,
        slo_ms=3 * GPROF.vanilla_time(1),
    )
    return build, cls_reqs, gen_reqs


@pytest.mark.parametrize("seed", [3, 11])
def test_mixed_cluster_facade_bit_identical(seed):
    """Sharing one engine core across pools must not change any pool's
    results: responses, makespans, and worker stats all match the
    independent-pool reference exactly."""
    build, cls_reqs, gen_reqs = _mixed_pair(seed)
    cls_a, gens_a = build(ClusterSimulator, GenerativeEngine)
    cls_b, gens_b = build(ReferenceClusterSimulator, ReferenceGenerativeEngine)
    mixed = MixedClusterSimulator(cls_a, gens_a)
    ref = ReferenceMixedClusterSimulator(cls_b, gens_b)
    ca, ga = mixed.run(cls_reqs, gen_reqs)
    cb, gb = ref.run(cls_reqs, gen_reqs)
    assert _cls_records(ca) == _cls_records(cb)
    assert _gen_records(ga) == _gen_records(gb)
    assert mixed.makespan_ms == ref.makespan_ms
    assert cls_a.makespan_ms == cls_b.makespan_ms
    for ea, eb in zip(gens_a, gens_b):
        assert (ea.makespan_ms, ea.busy_ms, ea.n_steps) == (eb.makespan_ms, eb.busy_ms, eb.n_steps)


def test_mixed_cluster_completions_globally_time_ordered():
    """The PR's single-clock regression: the pre-refactor frontend ran its
    pools on independent clocks, so a global completion order between
    pools was untestable. On the unified core, every pool's completions
    ride ONE event heap — the completion log must be non-decreasing in
    time and genuinely interleave both workload kinds."""
    build, cls_reqs, gen_reqs = _mixed_pair(5)
    cls_sim, gens = build(ClusterSimulator, GenerativeEngine)
    mixed = MixedClusterSimulator(cls_sim, gens)
    mixed.run(cls_reqs, gen_reqs)
    comp = mixed.core.completions
    assert len(comp) >= len(cls_reqs) + sum(q.n_tokens for q in gen_reqs) - 1
    times = [t for t, _, _ in comp]
    assert all(b >= a - 1e-12 for a, b in zip(times, times[1:])), \
        "completion log must be globally time-ordered"
    kinds = [pool for _, pool, _ in comp]
    assert {"classification", "generative"} <= set(kinds)
    # genuine interleaving: neither pool's completions form one contiguous
    # block (the old independent-pool simulation could only produce blocks)
    first_gen = kinds.index("generative")
    last_gen = len(kinds) - 1 - kinds[::-1].index("generative")
    assert any(k == "classification" for k in kinds[first_gen:last_gen]), \
        "classification completions must interleave inside the generative span"


# -- metrics dedup: shared helpers pin the historical outputs ----------------


def _recorded_cls_stream():
    """A small fixed classification stream exercising drops, multiple
    workers, exits and full-model releases."""
    return [
        Response(0, 12.5, 3, 1, 10.0, 4, False, worker=0, slo_ms=20.0),
        Response(1, 13.0, 2, -1, 9.5, 4, False, worker=1, slo_ms=20.0),
        Response(2, 14.0, 1, 0, 12.0, 4, False, worker=0, slo_ms=20.0),
        Response(3, 16.0, -1, -1, 13.0, 0, True, worker=1, slo_ms=20.0),
        Response(4, 30.0, 5, 2, 25.0, 2, False, worker=1, slo_ms=20.0),
        Response(5, 31.0, 0, -1, 8.0, 2, False, worker=0, slo_ms=20.0),
    ]


def test_summarize_pinned_on_recorded_stream():
    """The shared percentile/span/rate helpers must reproduce the exact
    pre-dedup numbers on a recorded stream (values computed with the
    PR 4 implementation and pinned here)."""
    out = summarize(_recorded_cls_stream())
    assert out["n"] == 6.0 and out["dropped"] == 1.0
    np.testing.assert_allclose(out["p25_ms"], 9.5)
    np.testing.assert_allclose(out["p50_ms"], 10.0)
    np.testing.assert_allclose(out["p95_ms"], 22.4)
    np.testing.assert_allclose(out["p99_ms"], 24.48)
    np.testing.assert_allclose(out["mean_batch"], 3.2)
    np.testing.assert_allclose(out["exit_rate"], 0.6)
    np.testing.assert_allclose(out["throughput_qps"], 5 / 0.031)
    np.testing.assert_allclose(out["goodput_qps"], 4 / 0.031)
    np.testing.assert_allclose(out["slo_miss_rate"], 1 - 4 / 6)
    # empty stream: the historical NaN sentinels survive the dedup
    empty = summarize([])
    assert empty["n"] == 0.0 and np.isnan(empty["p50_ms"]) and np.isnan(empty["mean_batch"])
    assert empty["exit_rate"] == 0.0


def test_summarize_cluster_consistent_with_summarize():
    """The cluster aggregate IS `summarize` over the shared horizon —
    the dedup must keep them identical key for key."""
    stream = _recorded_cls_stream()
    rep = summarize_cluster(stream, n_workers=2)
    flat = summarize(stream, horizon_ms=31.0)
    for k, v in flat.items():
        np.testing.assert_allclose(rep["aggregate"][k], v, err_msg=k)
    assert rep["aggregate"]["n_workers"] == 2.0
    assert set(rep["workers"]) == {0, 1}
    # per-worker rates over the shared horizon sum to the aggregate
    per = sum(w["throughput_qps"] for w in rep["workers"].values())
    np.testing.assert_allclose(per, flat["throughput_qps"])


def test_summarize_generative_pinned_on_recorded_stream():
    """Generative summary on a recorded token stream: pinned values, plus
    the new dropped/shed accounting (dropped excluded from token metrics,
    sheds keep their partial tokens)."""
    resp = [
        GenResponse(rid=0, arrival_ms=0.0, release_ms=[2.0, 4.0, 8.0],
                    exit_sites=[-1, 0, -1], tokens=[1, 2, 3],
                    final_tokens=[1, 2, 9], slo_ms=5.0),
        GenResponse(rid=1, arrival_ms=1.0, release_ms=[3.0, 6.0],
                    exit_sites=[-1, 1], tokens=[4, 5],
                    final_tokens=[4, 5], slo_ms=5.0, shed=True),
        GenResponse(rid=2, arrival_ms=2.0, release_ms=[], exit_sites=[],
                    tokens=[], final_tokens=[], slo_ms=5.0, dropped=True),
    ]
    out = summarize_generative(resp)
    assert out["n"] == 3.0 and out["tokens"] == 5.0
    assert out["dropped"] == 1.0 and out["shed"] == 1.0
    np.testing.assert_allclose(out["ttft_p50_ms"], 2.0)
    np.testing.assert_allclose(out["tpt_p50_ms"], 3.0)
    np.testing.assert_allclose(out["tpt_p95_ms"], 3.9)
    np.testing.assert_allclose(out["tpt_mean_ms"], 3.0)
    np.testing.assert_allclose(out["exit_rate"], 2 / 3)
    np.testing.assert_allclose(out["agreement"], 2 / 3)
    np.testing.assert_allclose(out["tokens_per_sec"], 5 / 0.008)
    np.testing.assert_allclose(out["tpt_slo_miss_rate"], 0.0)
    # fully-dropped stream: zeroed key set, not NaN
    all_drop = [GenResponse(rid=0, arrival_ms=0.0, release_ms=[], exit_sites=[],
                           tokens=[], final_tokens=[], slo_ms=5.0, dropped=True)]
    z = summarize_generative(all_drop)
    assert z["n"] == 1.0 and z["dropped"] == 1.0 and z["tpt_p50_ms"] == 0.0
    assert all(np.isfinite(v) for v in z.values())
