"""Per-arch reduced-config smoke tests: one forward/train step on CPU,
shape checks, no NaNs; decode-vs-prefill consistency (KV-cache/SSM-state
correctness) for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_bench, get_tiny
from repro.models import build_model

LM_ARCHS = [a for a in ARCH_IDS if a != "seamless-m4t-large-v2"]


def _batch(cfg, key, B=2, S=16):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, 24, cfg.d_frontend)) * 0.1
    if cfg.cross_attn_every:
        batch["image_embeds"] = (
            jax.random.normal(key, (B, cfg.n_image_tokens, cfg.d_frontend)) * 0.1
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_loss_step(arch):
    cfg = get_tiny(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    kw = {"moe_impl": "dense"} if cfg.family == "lm" else {}
    loss, mets = m.loss(params, batch, **kw)
    assert jnp.isfinite(loss), (arch, mets)
    assert float(loss) > 0
    # one gradient step leaves params finite
    grads = jax.grad(lambda p: m.loss(p, batch, **kw)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_decode_matches_prefill(arch):
    cfg = get_tiny(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
    kw = {}
    if cfg.cross_attn_every:
        kw["image_embeds"] = (
            jax.random.normal(jax.random.PRNGKey(2), (B, cfg.n_image_tokens, cfg.d_frontend)) * 0.1
        )
    active = jnp.arange(min(2, len(m.sites)), dtype=jnp.int32)
    cache, _ = m.prefill(
        params, toks[:, :S], cache_len=S + 4, active_sites=active, moe_impl="dense", **kw
    )
    _, outs_d = m.decode(
        params, cache, toks[:, S : S + 1], jnp.int32(S), active_sites=active, moe_impl="dense"
    )
    _, outs_ref = m.prefill(
        params, toks[:, : S + 1], cache_len=S + 4, active_sites=active, moe_impl="dense", **kw
    )
    np.testing.assert_allclose(
        np.asarray(outs_d["final"]["maxprob"]),
        np.asarray(outs_ref["final"]["maxprob"]),
        rtol=2e-2, atol=2e-2,
    )
    assert (
        np.asarray(outs_d["final"]["label"]) == np.asarray(outs_ref["final"]["label"])
    ).all(), arch


def test_encdec_decode_matches_prefill():
    cfg = get_tiny("seamless-m4t-large-v2")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_frontend)) * 0.1
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 9), 0, cfg.vocab_size)
    active = jnp.arange(1, dtype=jnp.int32)
    cache, _ = m.prefill(params, frames, toks[:, :8], cache_len=12, active_sites=active)
    _, od = m.decode(params, cache, toks[:, 8:9], jnp.int32(8), active_sites=active)
    _, oref = m.prefill(params, frames, toks[:, :9], cache_len=12, active_sites=active)
    np.testing.assert_allclose(
        np.asarray(od["final"]["maxprob"]), np.asarray(oref["final"]["maxprob"]),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("arch", ["resnet18", "bert-base"])
def test_paper_models(arch):
    cfg = get_tiny(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    if arch.startswith("resnet"):
        x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.img_size, cfg.img_size, 3))
        batch = {"images": x, "labels": jnp.asarray([0, 1, 2, 3]) % cfg.n_classes}
        outs = m.forward(params, x, active_sites=list(m.sites))
    else:
        x = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
        batch = {"tokens": x, "labels": jnp.asarray([0, 1, 0, 1])}
        outs = m.forward(params, x, active_sites=list(m.sites))
    assert outs["ramps"]["label"].shape == (len(m.sites), 4)
    assert np.isfinite(np.asarray(outs["ramps"]["maxprob"])).all()
    loss, _ = m.loss(params, batch)
    assert jnp.isfinite(loss)


def test_ramp_gather_no_recompile_semantics():
    """Dynamic active-site gather: changing the active set changes outputs
    without retracing (same jitted fn, different int32 array)."""
    cfg = get_tiny("qwen2-1.5b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    traces = {"n": 0}

    # nested jit is the point here: the test counts retraces of this fn
    @jax.jit  # repro: allow[jit-cache-hygiene]
    def f(p, t, active):
        traces["n"] += 1
        _, outs = m.prefill(p, t, active_sites=active, with_cache=False, moe_impl="dense")
        return outs["ramps"]["label"]

    l1 = f(params, toks, jnp.asarray([0, 1], jnp.int32))
    l2 = f(params, toks, jnp.asarray([1, 1], jnp.int32))
    assert traces["n"] == 1, "ramp-set change must not retrace"
    assert (np.asarray(l1)[1] == np.asarray(l2)[1]).all()


def test_tied_ramp_style():
    cfg = get_tiny("qwen2-1.5b").replace(ramp_style="tied")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    assert "head" not in params["ramps"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    _, outs = m.prefill(
        params, toks, active_sites=jnp.asarray([0, 1], jnp.int32),
        with_cache=False, moe_impl="dense",
    )
    assert np.isfinite(np.asarray(outs["ramps"]["maxprob"])).all()


def test_mla_absorbed_equivalence():
    """Latent-space MLA decode == naive materialized decode (math identity)."""
    cfg = get_tiny("deepseek-v2-lite-16b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab_size)
    act = jnp.arange(1, dtype=jnp.int32)
    cache, _ = m.prefill(params, toks[:, :8], cache_len=12, active_sites=act, moe_impl="dense")
    _, o_naive = m.decode(params, cache, toks[:, 8:9], jnp.int32(8), active_sites=act, moe_impl="dense")
    m2 = build_model(cfg.replace(mla_absorbed=True))
    _, o_abs = m2.decode(params, cache, toks[:, 8:9], jnp.int32(8), active_sites=act, moe_impl="dense")
    np.testing.assert_allclose(
        np.asarray(o_abs["final"]["maxprob"]), np.asarray(o_naive["final"]["maxprob"]),
        rtol=1e-4, atol=1e-4,
    )
    assert (
        np.asarray(o_abs["final"]["label"]) == np.asarray(o_naive["final"]["label"])
    ).all()
