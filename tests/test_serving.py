"""Serving simulator invariants + platform policies."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.serving import (
    PlatformConfig,
    ServingSimulator,
    SyntheticRunner,
    make_requests,
    maf_trace,
    summarize,
    video_trace,
)

PROF = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)


def _reqs(n=200, qps_scale=0.5, slo_mult=2.0, seed=0):
    exec1 = PROF.vanilla_time(1)
    arr = maf_trace(n, mean_qps=qps_scale * 1000.0 / exec1, seed=seed)
    return make_requests(arr, slo_ms=slo_mult * exec1)


def test_latency_at_least_exec_time():
    sim = ServingSimulator(PROF, PlatformConfig(policy="tfserve", max_batch_size=4, batch_timeout_ms=1.0))
    resp = sim.run(_reqs())
    exec1 = PROF.vanilla_time(1)
    assert all(r.latency_ms >= exec1 - 1e-9 for r in resp)
    assert len(resp) == 200


def test_fifo_release_order_within_policy():
    sim = ServingSimulator(PROF, PlatformConfig(policy="tfserve", max_batch_size=8, batch_timeout_ms=2.0))
    resp = sim.run(_reqs(seed=3))
    # batches are formed from queue head: start order == arrival order
    rids = [r.rid for r in sorted(resp, key=lambda r: (r.release_ms, r.rid))]
    assert sorted(rids) == list(range(200))


def test_knob_tension_fig3():
    """Paper Fig 3: larger max_batch_size => bigger batches (throughput) but
    worse median latency under load."""
    out = {}
    for mbs in (1, 16):
        pf = PlatformConfig(policy="tfserve", max_batch_size=mbs,
                            batch_timeout_ms=PROF.vanilla_time(4))
        m = summarize(ServingSimulator(PROF, pf).run(_reqs(n=400, qps_scale=2.0)))
        out[mbs] = m
    assert out[16]["mean_batch"] > out[1]["mean_batch"]
    # bs=1 under 2x overload builds an unbounded queue -> worse latency
    assert out[1]["p50_ms"] > out[16]["p50_ms"]


def test_clockwork_slo_awareness():
    pf = PlatformConfig(policy="clockwork", max_batch_size=16, drop_on_slo_miss=True)
    resp = ServingSimulator(PROF, pf).run(_reqs(n=300, qps_scale=0.8, slo_mult=1.5))
    served = [r for r in resp if not r.dropped]
    # all served requests meet their SLO (drop-on-miss)
    viol = [r for r in served if r.latency_ms > 1.5 * PROF.vanilla_time(1) + 1e-6]
    assert len(viol) / max(len(served), 1) < 0.02


def test_apparate_preserves_throughput_and_cuts_latency():
    """The paper's headline: same batches, lower response latency, tail
    within the ramp budget. Since `SyntheticRunner` makes hard items
    DISAGREE at every ramp (over-opened thresholds cost accuracy, as with
    a trained model), the median win needs a predominantly-easy stream and
    enough samples for the controller to adapt — the old 0.7/600 setting
    only won because tiled-agree hard rows made every exit free."""
    n = 900
    reqs = _reqs(n=n, qps_scale=0.6, seed=5)
    pf = PlatformConfig(policy="tfserve", max_batch_size=8,
                        batch_timeout_ms=PROF.vanilla_time(1))
    base = summarize(ServingSimulator(PROF, pf).run(reqs))
    ns = len(PROF.sites)
    ctl = ApparateController(ns, PROF, ControllerConfig(max_slots=4, ramp_budget_frac=0.02))
    sim = ServingSimulator(PROF, pf, SyntheticRunner(ns, exit_site=4, easy_frac=0.9), ctl)
    ours = summarize(sim.run(reqs))
    assert ours["exit_rate"] > 0.2
    assert ours["p50_ms"] < base["p50_ms"]  # latency wins
    # throughput preserved (identical batch formation; tail within budget)
    assert abs(ours["mean_batch"] - base["mean_batch"]) < 1e-6
    assert ours["p99_ms"] <= base["p99_ms"] * (1 + 0.02) + 1e-6


def test_classifier_runner_no_ramp_compiled_variant():
    """Regression: with zero active ramps `ClassifierRunner.infer` used to
    execute a ramp at site 0 and discard it — vanilla serving silently paid
    one ramp head of compute per batch. The no-ramp path must compile its
    own ramp-free variant, counted separately from ramped compiles."""
    import jax

    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.serving import ClassifierRunner

    cfg = get_tiny("resnet18")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    data = rng.normal(0, 1, (32, cfg.img_size, cfg.img_size, 3)).astype(np.float32)
    runner = ClassifierRunner(model, params, data, max_slots=2)
    idx = np.arange(8)
    labels, unc, f0 = runner.infer(idx, [])
    assert labels.shape == (0, 8) and unc.shape == (0, 8)
    # a no-ramp compile is NOT a ramp-set change: it must land only in
    # noramp_compiles (it used to be double-counted into `compiles`,
    # inflating the paper's recompile-overhead stat)
    assert runner.compiles == 0 and runner.noramp_compiles == 1
    _, _, f1 = runner.infer(idx, [0])
    assert runner.compiles == 1 and runner.noramp_compiles == 1  # counted apart
    np.testing.assert_array_equal(f0, f1)  # same final labels either way
    runner.infer(idx, [])  # cached: no recompile
    assert runner.compiles == 1 and runner.noramp_compiles == 1


def test_classifier_runner_oversized_active_and_vanilla_n():
    """Regressions: (a) `infer` used to silently truncate the active ramp
    set to `max_slots` — the controller got fewer record rows than sites
    it activated, landing rows against the wrong sites; it must raise.
    (b) `vanilla_labels(0)` used to remap to the WHOLE dataset via
    `n or len(data)` — an explicit 0 must mean an empty array."""
    import jax

    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.serving import ClassifierRunner

    cfg = get_tiny("resnet18")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = np.random.default_rng(2).normal(
        0, 1, (5, cfg.img_size, cfg.img_size, 3)).astype(np.float32)
    runner = ClassifierRunner(model, params, data, max_slots=1)
    with pytest.raises(ValueError):
        runner.infer(np.arange(4), [0, 1])  # 2 sites > max_slots=1
    assert runner.compiles == 0  # the rejected call compiled nothing
    assert runner.vanilla_labels(0).shape == (0,)
    assert runner.vanilla_labels(0).dtype == np.int64
    full = runner.vanilla_labels()  # None still means the whole stream
    assert full.shape == (5,)
    np.testing.assert_array_equal(runner.vanilla_labels(4), full[:4])


def test_synthetic_runner_hard_items_cost_accuracy_when_forced_open():
    """Regression: `SyntheticRunner.infer` used to tile the original
    model's label into every ramp row, so "hard" items still AGREED and
    an over-opened threshold never cost accuracy (unlike
    `SyntheticDecodeRunner`, whose hard tokens disagree). Hard rows must
    disagree, so forcing thresholds open degrades released accuracy."""
    ns = len(PROF.sites)
    runner = SyntheticRunner(ns, exit_site=2, easy_frac=0.6)
    items = np.arange(500)
    labels, unc, final = runner.infer(items, [3])
    hard = unc[0] > 0.5
    assert hard.any() and (~hard).any()
    assert (labels[0][~hard] == final[~hard]).all()  # easy rows agree
    assert (labels[0][hard] != final[hard]).all()  # hard rows DISAGREE
    # below exit_site even easy items are undecided -> all rows disagree
    lab_lo, unc_lo, _ = runner.infer(items, [1])
    assert (lab_lo[0] != final).all() and (unc_lo[0] > 0.5).all()
    # forced-open thresholds exit every item at site 3: released labels are
    # wrong for exactly the hard fraction
    ctl = ApparateController(ns, PROF, ControllerConfig(max_slots=4))
    ctl.active = [3]
    ctl.thresholds = np.ones(ns, np.float32)
    dec = ctl.observe(labels, unc, final)
    assert dec.exited_early.all()
    wrong = (dec.released_labels != final).mean()
    np.testing.assert_allclose(wrong, hard.mean())
    assert wrong > 0.2  # accuracy genuinely degrades


def test_video_trace_shape():
    t = video_trace(100, fps=30)
    d = np.diff(t)
    np.testing.assert_allclose(d, 1000.0 / 30, rtol=1e-9)


def test_maf_trace_burstiness():
    t = maf_trace(2000, mean_qps=100, seed=0)
    d = np.diff(t)
    assert d.std() > d.mean() * 0.8  # burstier than deterministic
    # lognormal-burst rate has heavy tails; a short trace lands within ~3x
    qps = len(t) / (t[-1] / 1000.0)
    assert 100 / 3 < qps < 100 * 3
