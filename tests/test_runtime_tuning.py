"""Runtime tuning presets (`repro.launch.tuning`): env-merge semantics.

These never touch ``os.environ`` — every case runs against a plain dict,
so the suite's own XLA configuration is never perturbed.
"""
import pytest

from repro.launch.tuning import PRESETS, apply_preset, merge_xla_flags


def test_merge_xla_flags_existing_shadows_preset():
    out = merge_xla_flags(
        "--xla_step_marker_location=1 --xla_foo=2",
        "--xla_step_marker_location=0",
    )
    # the operator's value wins for the shared flag; the preset's other
    # flag is appended after the existing ones
    assert out == "--xla_step_marker_location=0 --xla_foo=2"
    assert merge_xla_flags("--a=1", None) == "--a=1"
    assert merge_xla_flags("--a=1", "") == "--a=1"


def test_apply_preset_writes_only_absent_vars():
    env = {"TF_CPP_MIN_LOG_LEVEL": "0"}
    written = apply_preset("serve", env)
    assert env["TF_CPP_MIN_LOG_LEVEL"] == "0"  # setdefault: operator wins
    assert "TF_CPP_MIN_LOG_LEVEL" not in written
    assert env["XLA_FLAGS"] == PRESETS["serve"]["XLA_FLAGS"]
    assert env["XLA_PYTHON_CLIENT_PREALLOCATE"] == "true"
    # force overrides the existing value
    written = apply_preset("serve", env, force=True)
    assert env["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert written["TF_CPP_MIN_LOG_LEVEL"] == "4"


def test_apply_preset_merges_xla_flags_never_clobbers():
    env = {"XLA_FLAGS": "--xla_step_marker_location=0 --xla_custom=z"}
    apply_preset("bench", env)
    assert env["XLA_FLAGS"] == "--xla_step_marker_location=0 --xla_custom=z"
    env2 = {"XLA_FLAGS": "--xla_custom=z"}
    apply_preset("bench", env2)
    assert env2["XLA_FLAGS"] == "--xla_custom=z --xla_step_marker_location=1"


def test_apply_preset_none_and_unknown():
    env = {}
    assert apply_preset("none", env) == {}
    assert apply_preset("", env) == {}
    assert env == {}
    with pytest.raises(ValueError, match="unknown runtime preset"):
        apply_preset("warp-speed", env)


def test_every_preset_applies_cleanly_to_empty_env():
    for name, preset in PRESETS.items():
        env = {}
        written = apply_preset(name, env)
        assert written == preset == env, name
