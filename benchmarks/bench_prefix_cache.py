"""Prefix-cache + preemption benchmark (gate rows for CI).

Three claims, measured on a real tiny LM with the paged DecodeRunner:

  * hot-prefix TTFT — a fully cached prompt admits with ZERO device work
    (host trie walk + cached first token), so ``start()`` wall-clock on a
    hot prompt must be strictly below the cold prefill;
  * block dedup — N concurrent slots serving the same prompt share ONE
    physical block set: live blocks shrink >= 2x vs private allocation,
    while every decode record stays bit-identical (CoW included);
  * swap preemption — on a pool that cannot hold every admitted stream,
    ``--preempt swap`` completes requests ``shed`` discards, with final
    tokens identical to an uncontended run.

Gate row (CI greps it): ``prefix_cache_hot_ttft`` must carry
``identical_trajectories=True;ttft_hot_prefix_lt_cold=True``.
"""
from __future__ import annotations

import time

import numpy as np


def bench_prefix_cache():
    import jax

    from benchmarks.run import emit, snapshot
    from repro.configs import get_config, get_tiny
    from repro.core import ApparateController, ControllerConfig, build_profile
    from repro.models import build_model
    from repro.serving import (
        DecodeRunner,
        GenerativeConfig,
        GenerativeEngine,
        GenRequest,
    )

    cfg = get_tiny("qwen2-1.5b").replace(n_layers=2, vocab_size=64, decode_attn="paged")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    prompts = np.random.default_rng(4).integers(0, 64, (10, 14)).astype(np.int32)
    max_new = 10  # cache_len 24 = 6 blocks of 4 (bs | cache_len: bit-identity)
    kw = dict(max_new_tokens=max_new, max_slots=3, n_slots=4, kv_block_size=4)

    private = DecodeRunner(model, params, prompts, **kw)
    shared = DecodeRunner(model, params, prompts, prefix_cache=True, **kw)
    for r in (private, shared):  # warmup: compile prefill + step + CoW paths
        r.start(0, 9)
        r.start(1, 9)
        r.step([0, 1], [0])
        r.free(0)
        r.free(1)
    shared._prefix.clear()

    # -- hot-prefix stream: 2 waves of 4 concurrent slots on ONE prompt ----
    ident = True
    cold_us, hot_us = [], []
    peak_private = peak_shared = 0
    for item in (0, 1):
        for slot in range(4):
            tp = private.start(slot, item)
            t0 = time.perf_counter()
            ts = shared.start(slot, item)
            t1 = time.perf_counter()
            ident &= tp == ts
            # slot 0 computes (and registers) the prompt; slots 1-3 hit
            (cold_us if slot == 0 else hot_us).append((t1 - t0) * 1e6)
        peak_private = max(peak_private, private.kv_stats()["live_blocks"])
        peak_shared = max(peak_shared, shared.kv_stats()["live_blocks"])
        for _ in range(max_new - 1):
            lp, up, fp = private.step([0, 1, 2, 3], [0])
            ls, us_, fs = shared.step([0, 1, 2, 3], [0])
            ident &= (
                np.array_equal(ls, lp) and np.array_equal(us_, up)
                and np.array_equal(fs, fp)
            )
        for slot in range(4):
            private.free(slot)
            shared.free(slot)
    st = shared.kv_stats()
    mean_cold = float(np.mean(cold_us))
    mean_hot = float(np.mean(hot_us))
    ttft_ok = mean_hot < mean_cold
    ratio = peak_private / peak_shared
    emit("prefix_cache_cold_ttft", mean_cold, f"n={len(cold_us)}")
    emit("prefix_cache_hot_ttft", mean_hot,
         f"identical_trajectories={ident};ttft_hot_prefix_lt_cold={ttft_ok}")
    emit("prefix_cache_blocks_ratio", ratio,
         f"private_peak={peak_private};shared_peak={peak_shared};"
         f"ratio_ge_2x={ratio >= 2.0};cow_copies={st['cow_copies']}")

    # -- swap-vs-shed preemption on an overloaded pool ---------------------
    ns = len(model.sites)
    prof_cfg = get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied")
    sites = [round((i + 1) * prof_cfg.n_layers / (ns + 1)) - 1 for i in range(ns)]
    prof = build_profile(prof_cfg, mode="decode", chips=1, sites=sites, charge_kv=True)
    reqs = [GenRequest(rid=k, arrival_ms=0.0, slo_ms=float("inf"), item=k,
                       prompt_len=14, n_tokens=6) for k in range(10)]

    def run(preempt, kv_blocks):
        # a full stream needs ceil((14 + 6) / 4) = 5 blocks; 12 fit only 2
        r = DecodeRunner(model, params, prompts, max_new_tokens=max_new,
                         max_slots=3, n_slots=4, kv_block_size=4,
                         kv_blocks=kv_blocks)
        ctl = ApparateController(ns, prof, ControllerConfig(max_slots=3))
        eng = GenerativeEngine(
            prof, GenerativeConfig(max_batch_size=4, preempt=preempt), r, ctl)
        return eng, eng.run(reqs)

    es, rs = run("shed", 12)
    ew, rw = run("swap", 12)
    _, ru = run("none", None)  # uncontended baseline
    done = lambda rr: {r.rid: tuple(r.tokens) for r in rr if len(r.tokens) == 6}
    swap_done, shed_done = len(done(rw)), len(done(rs))
    rescued = swap_done == 10 and shed_done < 10
    matches = done(rw) == done(ru)
    emit("prefix_cache_preempt", float(ew.n_preempt_swaps),
         f"swap_done={swap_done};shed_done={shed_done};"
         f"swap_completes_dropped={rescued};swap_matches_uncontended={matches}")

    snapshot("prefix_cache", {
        "cold_ttft_us": mean_cold,
        "hot_ttft_us": mean_hot,
        "ttft_hot_prefix_lt_cold": bool(ttft_ok),
        "identical_trajectories": bool(ident),
        "private_peak_blocks": int(peak_private),
        "shared_peak_blocks": int(peak_shared),
        "blocks_ratio": float(ratio),
        "prefix_hits": int(st["prefix_hits"]),
        "prefix_tokens_saved": int(st["prefix_tokens_saved"]),
        "cow_copies": int(st["cow_copies"]),
        "swap_done": swap_done,
        "shed_done": shed_done,
        "preempt_swaps": int(ew.n_preempt_swaps),
        "preempt_sheds_in_shed_run": int(es.n_preempt_sheds),
        "swap_ins": int(ew.n_swap_ins),
        "swap_matches_uncontended": bool(matches),
    })
