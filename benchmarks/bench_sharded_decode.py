"""Sharded mesh-decode benchmark (gate rows for CI).

Measures what the `(data, model)` mesh actually buys, on forced host
devices (`--xla_force_host_platform_device_count=4`), so it runs in a
subprocess — the parent benchmark process already initialized its
single-device backend.

Three claims, all gated:

* **Bit-identity** — `ShardedDecodeRunner` at tp=2 and tp=4 must stream
  back the exact records (labels, uncertainties, finals, exit sites) of
  the single-device batched runner across sync windows: the tiled
  all_gather combine is a pure concatenation, so sharding is a placement
  change, not a numerics change.
* **Per-device KV scaling** — every paged-pool leaf shards its head
  axis over `model`, so per-device peak KV bytes must be
  ≤ single-device bytes / tp + one block of slack (it is exact for the
  head counts here).
* **Pipeline escapes** — `pipeline_decode_window` with a near-1.0
  threshold at the stage-boundary ramp must show later stages doing
  strictly less row-steps than stage 0 at the same dispatch count
  (1 windowed dispatch either way): exited rows never enter later
  stages.

The us/token trend across tp is snapshotted, not gated — host-device
collectives on one core model communication structure, not speed.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N_STEPS = 16
N_ROWS = 3

_SUB = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_tiny
from repro.models import build_model
from repro.models.transformer import LM
from repro.serving import DecodeRunner, ShardedDecodeRunner
from repro.distributed.pipeline import pipeline_decode_window

N_STEPS, N_ROWS = %(n_steps)d, %(n_rows)d
cfg = get_tiny("qwen2-1.5b").replace(n_layers=4, vocab_size=128,
                                     n_kv_heads=4, decode_attn="paged")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(5))
prompts = np.random.default_rng(6).integers(0, 128, (8, 12)).astype(np.int32)
kw = dict(max_new_tokens=N_STEPS + 2, max_slots=N_ROWS, kv_block_size=4)
act = list(range(min(2, len(model.sites))))
thr = np.zeros(len(act), np.float32)  # strict <: never exits -> full windows

out = {"tp": {}}
ref = None
for tp in (1, 2, 4):
    r = (DecodeRunner(model, params, prompts, **kw) if tp == 1
         else ShardedDecodeRunner(model, params, prompts, tp=tp, **kw))
    for timed in (False, True):  # pass 1 compiles + records, pass 2 times
        for s in range(N_ROWS):
            r.start(s, s)
        recs, idx = [], 0
        t0 = time.perf_counter()
        while idx < N_STEPS:
            rec = r.step_multi(list(range(N_ROWS)), act, 4, thr)
            recs.append(rec)
            idx += rec[2].shape[0]
        wall = time.perf_counter() - t0
        stats = r.kv_stats()
        block_bytes = stats["cache_bytes"] / max(r._alloc.n_blocks, 1)
        for s in range(N_ROWS):
            r.free(s)
    flat = [np.concatenate([np.asarray(x[i]) for x in recs]) for i in range(4)]
    ident = ref is None or all(np.array_equal(a, b) for a, b in zip(ref, flat))
    if ref is None:
        ref = flat
    per_dev = stats.get("per_device_cache_bytes", stats["cache_bytes"])
    out["tp"][str(tp)] = {
        "us_per_token": wall / (N_STEPS * N_ROWS) * 1e6,
        "identical": bool(ident),
        "cache_bytes": float(stats["cache_bytes"]),
        "per_device_cache_bytes": float(per_dev),
        "kv_scaled": bool(per_dev <= stats["cache_bytes"] / tp + block_bytes),
    }

# pipeline escapes: S=2 ring, thresholds off vs ~1.0 at the boundary ramp
mp = LM(cfg.replace(decode_attn="ref"))
pp = mp.init(jax.random.PRNGKey(5))
B, S0, n = 4, 8, 8
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0, cfg.vocab_size)
cache, outs = mp.prefill(pp, toks, cache_len=S0 + n + 1, moe_impl="dense")
last = outs["final"]["label"].reshape(B, 1).astype(jnp.int32)
pos = jnp.full((B,), S0, jnp.int32)
S = 2
sites = list(mp.sites)
Lp, ns = mp.plan.n_periods // S, len(mp.plan.period)
a = [sites.index(b) for b in [(s + 1) * Lp * ns - 1 for s in range(S - 1)]
     if b in sites]
mesh = Mesh(np.array(jax.devices()[:S]), ("stage",))
pres = {}
for tag, th in (("no_exit", 0.0), ("exit", 0.9999)):
    _, _, xr, alive, steps = pipeline_decode_window(
        mp, pp, cache, last, pos, n, mesh=mesh,
        active_sites=jnp.asarray(a, jnp.int32),
        thresholds=jnp.asarray([th] * len(a), jnp.float32))
    pres[tag] = {"stage_steps": [int(v) for v in np.asarray(steps)],
                 "exits": int((np.asarray(xr) >= 0).sum()),
                 "dispatches": 1}
out["pipeline"] = {"stages": S, "batch": B, "n_steps": n,
                   "boundary_sites": a, **pres}
print("JSON::" + json.dumps(out))
""" % {"n_steps": N_STEPS, "n_rows": N_ROWS}


def bench_sharded_decode():
    from benchmarks.run import emit, snapshot

    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=4 --xla_cpu_multi_thread_eigen=false"
    )
    env["PYTHONPATH"] = _SRC
    env["OMP_NUM_THREADS"] = "1"
    r = subprocess.run([sys.executable, "-c", _SUB], capture_output=True,
                       text=True, timeout=560, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"sharded subprocess failed:\n{r.stderr[-2000:]}")
    payload = next(l for l in r.stdout.splitlines() if l.startswith("JSON::"))
    out = json.loads(payload[len("JSON::"):])

    for tp, row in sorted(out["tp"].items(), key=lambda kv: int(kv[0])):
        ratio = row["per_device_cache_bytes"] / row["cache_bytes"]
        emit(f"sharded_decode_tp{tp}", row["us_per_token"],
             f"identical={row['identical']};per_device_kv_ratio={ratio:.3f}")

    pipe = out["pipeline"]
    no_exit, ex = pipe["no_exit"], pipe["exit"]
    # equal dispatch counts, strictly less later-stage row-steps with exits
    escape = (ex["dispatches"] == no_exit["dispatches"]
              and ex["exits"] > 0
              and ex["stage_steps"][-1] < ex["stage_steps"][0]
              and no_exit["stage_steps"][-1] == no_exit["stage_steps"][0])
    saved = 1.0 - ex["stage_steps"][-1] / max(no_exit["stage_steps"][-1], 1)
    emit("sharded_decode_pipeline", 0.0,
         f"stage_steps_no_exit={no_exit['stage_steps']};"
         f"stage_steps_exit={ex['stage_steps']};"
         f"later_stage_work_saved={saved:.2f}")

    ident2 = out["tp"]["2"]["identical"]
    ident4 = out["tp"]["4"]["identical"]
    kv_scaled = out["tp"]["2"]["kv_scaled"] and out["tp"]["4"]["kv_scaled"]
    emit("sharded_decode_gate", out["tp"]["2"]["us_per_token"],
         f"identical_tp2={ident2};identical_tp4={ident4};"
         f"kv_scaled={kv_scaled};pipeline_escape={escape}")

    snapshot("sharded_decode", {
        "identical_tp2": bool(ident2),
        "identical_tp4": bool(ident4),
        "kv_scaled": bool(kv_scaled),
        "pipeline_escape": bool(escape),
        "tp": out["tp"],
        "pipeline": pipe,
    })
