"""Sync-window decode benchmark (gate rows for CI).

Measures what the multi-step window actually buys: with
``steps_per_sync=N`` the host dispatches ONE ``lax.while_loop`` program
per window and reads back one packed record block, so the host↔device
sync count per decode step drops from 1 to 1/N — while every record the
window streams back stays bit-identical to the per-step path (the
windows use never-firing thresholds so each runs its full length, giving
an exact 1/N sync ratio AND a maximal identity check).

Gate row (CI greps it): ``steps_per_sync_gate`` must carry
``identical_at_sync=True;syncs_reduced=True``. The us/token trend across
N is recorded in BENCH_decode.json (dispatch overhead amortizes with N;
the win is hardware-dependent, so it is snapshotted, not gated).
"""
from __future__ import annotations

import time

import numpy as np

WINDOWS = (1, 2, 4, 8)
N_STEPS = 32  # decode steps per pass; divisible by every window size
N_ROWS = 3  # concurrent slots


def bench_steps_per_sync():
    import jax

    from benchmarks.run import emit, snapshot
    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.serving import DecodeRunner

    cfg = get_tiny("qwen2-1.5b").replace(n_layers=2, vocab_size=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    prompts = np.random.default_rng(6).integers(0, 64, (8, 12)).astype(np.int32)
    kw = dict(max_new_tokens=N_STEPS, max_slots=3, n_slots=4)

    act = list(range(min(2, len(model.sites))))
    thr = np.zeros(len(act), np.float32)  # strict <: threshold 0 never exits

    # per-step reference records, one pass (the identity oracle)
    oracle = DecodeRunner(model, params, prompts, **kw)
    for s in range(N_ROWS):
        oracle.start(s, s)
    ref = [oracle.step(list(range(N_ROWS)), act) for _ in range(N_STEPS)]
    for s in range(N_ROWS):
        oracle.free(s)

    runner = DecodeRunner(model, params, prompts, **kw)
    ident_all = True
    rows = {}
    for n in WINDOWS:
        for timed in (False, True):  # pass 1 compiles + checks, pass 2 times
            for s in range(N_ROWS):
                runner.start(s, s)
            d0 = runner.dispatches
            idx = 0
            t0 = time.perf_counter()
            while idx < N_STEPS:
                labels, unc, finals, _ = runner.step_multi(
                    list(range(N_ROWS)), act, n, thr
                )
                nd = finals.shape[0]
                if not timed:
                    for j in range(nd):
                        lo, uo, fo = ref[idx + j]
                        ident_all &= (
                            np.array_equal(labels[j], lo)
                            and np.array_equal(unc[j], uo)
                            and np.array_equal(finals[j], fo)
                        )
                idx += nd
            wall = time.perf_counter() - t0
            syncs = runner.dispatches - d0
            for s in range(N_ROWS):
                runner.free(s)
        us_tok = wall / (N_STEPS * N_ROWS) * 1e6
        rows[n] = {"us_per_token": us_tok, "syncs_per_step": syncs / N_STEPS}
        emit(f"steps_per_sync_n{n}", us_tok,
             f"syncs_per_step={syncs / N_STEPS:.4f}")

    # full windows at never-firing thresholds: the sync count must drop by
    # EXACTLY the window factor, at bit-identical records
    reduced = all(
        abs(rows[n]["syncs_per_step"] - 1.0 / n) < 1e-9 for n in WINDOWS
    )
    speedup4 = rows[1]["us_per_token"] / rows[4]["us_per_token"]
    emit("steps_per_sync_gate", rows[4]["us_per_token"],
         f"identical_at_sync={ident_all};syncs_reduced={reduced};"
         f"speedup_n4={speedup4:.2f}")

    snapshot("steps_per_sync", {
        "identical_at_sync": bool(ident_all),
        "syncs_reduced": bool(reduced),
        "speedup_n4": float(speedup4),
        "windows": {
            str(n): {
                "us_per_token": float(rows[n]["us_per_token"]),
                "syncs_per_step": float(rows[n]["syncs_per_step"]),
            }
            for n in WINDOWS
        },
    })
