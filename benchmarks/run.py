"""Benchmark harness — one function per paper table/figure.

Output format: ``name,us_per_call,derived`` CSV rows (us_per_call is the
latency-like quantity for the row; derived carries the figure's headline
metric, e.g. win% or accuracy).

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig13 t1   # substring filter
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

ROWS = []

_SNAPSHOT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_decode.json")


def emit(name: str, us_per_call: float, derived):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def snapshot(section: str, data: dict) -> None:
    """Persist a decode-perf section into BENCH_decode.json (repo root) so
    the perf trajectory of the decode/controller hot paths is recorded
    across PRs, not just printed."""
    existing = {}
    if os.path.exists(_SNAPSHOT_PATH):
        try:
            with open(_SNAPSHOT_PATH) as f:
                existing = json.load(f)
        except (OSError, ValueError):
            existing = {}
    existing[section] = data
    with open(_SNAPSHOT_PATH, "w") as f:
        json.dump(existing, f, indent=1, sort_keys=True)
        f.write("\n")


def _dom(domain, **kw):
    from benchmarks.common import get_domain

    return get_domain(domain, **kw)


def _sim_setup(dom, *, load=0.5, slo_mult=2.0, policy="tfserve", mbs=8, seed=0):
    from repro.serving import PlatformConfig, make_requests, maf_trace, video_trace

    prof = dom["profile"]
    exec1 = prof.vanilla_time(1)
    n0, n = dom["boot"], len(dom["fin"])
    if dom["cfg"].family == "resnet":
        arr = video_trace(n - n0, fps=load * 1000.0 / exec1)
    else:
        arr = maf_trace(n - n0, mean_qps=load * 1000.0 / exec1, seed=seed)
    reqs = make_requests(arr, slo_ms=slo_mult * exec1, items=np.arange(n0, n))
    pf = PlatformConfig(policy=policy, max_batch_size=mbs, batch_timeout_ms=exec1)
    return reqs, pf, prof


# --------------------------------------------------------------- paper Fig 3


def bench_fig3_knobs():
    """Tuning platform knobs trades latency against batch size/throughput."""
    from repro.serving import ServingSimulator, summarize

    dom = _dom("cv")
    for mbs in (4, 8, 16):
        reqs, pf, prof = _sim_setup(dom, load=0.85, mbs=mbs)
        pf.batch_timeout_ms = prof.vanilla_time(1) * mbs  # knob under test
        m = summarize(ServingSimulator(prof, pf).run(reqs))
        emit(f"fig3_knobs_mbs{mbs}_p50", m["p50_ms"] * 1e3, f"mean_batch={m['mean_batch']:.2f}")


# --------------------------------------------------------------- paper Fig 5


def bench_fig5_optimal_ee():
    """Optimal exits cut latency without touching throughput (upper bound)."""
    from benchmarks.common import optimal_exits

    for domain in ("cv", "nlp"):
        dom = _dom(domain)
        idx = np.arange(dom["boot"], len(dom["fin"]))
        saved = optimal_exits(dom, idx)
        van = dom["profile"].vanilla_time(1)
        emit(
            f"fig5_optimal_{domain}_p50",
            (van - np.median(saved)) * 1e3,
            f"win_pct={100 * np.median(saved) / van:.1f}",
        )


# ------------------------------------------------------------- paper Table 1


def bench_table1_threshold_adaptation():
    """One-time vs continual threshold tuning under drift."""
    from benchmarks.common import replay_continual, replay_fixed, tune_on

    for domain in ("cv_hard", "nlp"):
        dom = _dom(domain)
        ns, boot = dom["n_sites"], dom["boot"]
        active = list(range(ns))
        t_init = tune_on(dom, np.arange(0, boot), active)
        r = replay_fixed(dom, t_init.thresholds, active)
        emit(f"t1_{domain}_initial_only", r["median_win_pct"] * 10, f"acc={r['accuracy']:.3f}")
        t_uni = tune_on(dom, np.linspace(0, len(dom['fin']) - 1, boot).astype(int), active)
        r = replay_fixed(dom, t_uni.thresholds, active)
        emit(f"t1_{domain}_uniform", r["median_win_pct"] * 10, f"acc={r['accuracy']:.3f}")
        r = replay_continual(dom)
        emit(f"t1_{domain}_continual", r["median_win_pct"] * 10, f"acc={r['accuracy']:.3f}")


# -------------------------------------------------------------- paper Fig 11


def bench_fig11_tuning_speed():
    """Greedy hill-climb vs grid search: wall time + achieved savings."""
    from benchmarks.common import tune_on, window_from_records
    from repro.core import grid_search_thresholds

    dom = _dom("nlp")
    idx = np.arange(0, 512)
    active = list(range(min(4, dom["n_sites"])))
    wd = window_from_records(dom, idx)
    t0 = time.perf_counter()
    g = grid_search_thresholds(wd, active, dom["profile"], n_sites=dom["n_sites"], step=0.1)
    t_grid = time.perf_counter() - t0
    t = tune_on(dom, idx, active)
    emit("fig11_greedy", t.wall_s * 1e6, f"savings_ms={t.savings_ms:.4f}")
    emit("fig11_grid", t_grid * 1e6, f"savings_ms={g.savings_ms:.4f}")
    emit("fig11_speedup", t_grid / max(t.wall_s, 1e-9),
         f"greedy_minus_grid_ms={t.savings_ms - g.savings_ms:.5f}")


# ----------------------------------------------------------- paper Fig 13/15


def bench_fig13_latency_savings():
    """Apparate vs vanilla end-to-end serving (median + p25 wins)."""
    from repro.core import ApparateController, ControllerConfig
    from repro.serving import ClassifierRunner, ServingSimulator, summarize

    for domain in ("cv", "nlp"):
        dom = _dom(domain)
        reqs, pf, prof = _sim_setup(dom, load=0.5)
        base = summarize(ServingSimulator(prof, pf).run(reqs))
        ctl = ApparateController(
            dom["n_sites"], prof, ControllerConfig(max_slots=6, ramp_budget_frac=0.02)
        )
        runner = ClassifierRunner(dom["model"], dom["params"], dom["stream"].data, max_slots=6)
        resp = ServingSimulator(prof, pf, runner, ctl).run(reqs)
        ours = summarize(resp)
        fin = dom["fin"]
        agree = float(np.mean([r.label == fin[dom["boot"] + r.rid] for r in resp if not r.dropped]))
        for q in ("p25", "p50"):
            win = 100 * (base[f"{q}_ms"] - ours[f"{q}_ms"]) / base[f"{q}_ms"]
            emit(f"fig13_{domain}_{q}", ours[f"{q}_ms"] * 1e3, f"win_pct={win:.1f}")
        emit(f"fig13_{domain}_acc", ours["exit_rate"] * 100, f"acc={agree:.3f}")
        globals().setdefault("_FIG13", {})[domain] = (base, ours)


# -------------------------------------------------------------- paper Fig 14


def bench_fig14_tail_latency():
    """Tail latency stays within the ramp budget (throughput preserved)."""
    cache = globals().get("_FIG13")
    if not cache:
        bench_fig13_latency_savings()
        cache = globals()["_FIG13"]
    for domain, (base, ours) in cache.items():
        d95 = 100 * (ours["p95_ms"] - base["p95_ms"]) / base["p95_ms"]
        emit(f"fig14_{domain}_p95", ours["p95_ms"] * 1e3, f"delta_pct={d95:.2f}")
        emit(
            f"fig14_{domain}_throughput",
            ours.get("throughput_qps", 0.0),
            f"delta_pct={100 * (ours['throughput_qps'] - base['throughput_qps']) / base['throughput_qps']:.2f}",
        )


# ------------------------------------------------------------- paper Table 2


def bench_table2_existing_ee():
    """BranchyNet/DeeBERT-style (all ramps always on, one-time tuning) vs
    Apparate's continual adaptation."""
    from benchmarks.common import per_sample_savings, replay_continual, replay_fixed, tune_on

    for domain, name in (("cv_hard", "branchynet"), ("nlp", "deebert")):
        dom = _dom(domain)
        ns, boot = dom["n_sites"], dom["boot"]
        active = list(range(ns))  # every layer, always active
        best = (None, -1e18)
        for thr in np.arange(0.0, 1.01, 0.05):
            t = np.full(ns, thr, np.float32)
            saved, correct = per_sample_savings(dom, np.arange(boot), t, active)
            if correct.mean() >= 0.99 and saved.mean() > best[1]:
                best = (t, saved.mean())
        t_shared = best[0] if best[0] is not None else np.zeros(ns, np.float32)
        r = replay_fixed(dom, t_shared, active)
        emit(f"t2_{name}", r["median_win_pct"] * 10, f"acc={r['accuracy']:.3f}")
        t_plus = tune_on(dom, np.arange(boot), active)
        r = replay_fixed(dom, t_plus.thresholds, active)
        emit(f"t2_{name}_plus", r["median_win_pct"] * 10, f"acc={r['accuracy']:.3f}")
        r = replay_continual(dom)
        emit(f"t2_apparate_{domain}", r["median_win_pct"] * 10, f"acc={r['accuracy']:.3f}")


# ------------------------------------------------- paper Table 3 and Fig 18


def bench_table3_ramp_budget():
    from benchmarks.common import replay_continual

    dom = _dom("cv_hard")
    for budget in (0.02, 0.05, 0.10):
        r = replay_continual(dom, budget=budget, slots=12)
        emit(f"t3_budget_{int(budget * 100)}pct", r["median_win_pct"] * 10, f"acc={r['accuracy']:.3f}")


def bench_fig18_accuracy_constraint():
    from benchmarks.common import replay_continual

    dom = _dom("cv_hard")
    for acc in (0.995, 0.99, 0.97, 0.95):
        r = replay_continual(dom, acc=acc)
        emit(f"fig18_acc_{acc}", r["median_win_pct"] * 10, f"acc={r['accuracy']:.3f}")


# -------------------------------------------------------------- paper Fig 9


def bench_fig9_ramp_styles():
    """Lightweight pool+FC ramps vs heavier MLP ramps (paper's finding:
    extra ramp compute barely helps, so cheap ramps win)."""
    from benchmarks.common import replay_continual

    for style in ("fc", "mlp"):
        dom = _dom("nlp", ramp_style=style)
        r = replay_continual(dom)
        emit(f"fig9_ramps_{style}", r["median_win_pct"] * 10, f"acc={r['accuracy']:.3f}")


# ------------------------------------------------------------- paper Table 4


def bench_table4_platforms():
    """Apparate's wins are platform-insensitive (TF-Serve vs Clockwork)."""
    from repro.core import ApparateController, ControllerConfig
    from repro.serving import ClassifierRunner, ServingSimulator, summarize

    dom = _dom("cv")
    for policy in ("tfserve", "clockwork"):
        reqs, pf, prof = _sim_setup(dom, load=0.3, policy=policy)
        pf.batch_timeout_ms = prof.vanilla_time(1) * 0.25
        base = summarize(ServingSimulator(prof, pf).run(reqs))
        ctl = ApparateController(dom["n_sites"], prof, ControllerConfig(max_slots=6))
        runner = ClassifierRunner(dom["model"], dom["params"], dom["stream"].data, max_slots=6)
        ours = summarize(ServingSimulator(prof, pf, runner, ctl).run(reqs))
        win = 100 * (base["p50_ms"] - ours["p50_ms"]) / base["p50_ms"]
        emit(f"t4_{policy}_p50", ours["p50_ms"] * 1e3, f"win_pct={win:.1f}")


# -------------------------------------------------------------- paper Fig 17


def bench_fig17_slo():
    from repro.core import ApparateController, ControllerConfig
    from repro.serving import ClassifierRunner, ServingSimulator, summarize

    dom = _dom("cv")
    for slo_mult in (2.0, 4.0, 8.0):
        reqs, pf, prof = _sim_setup(dom, load=0.8, slo_mult=slo_mult, mbs=16)
        pf.batch_timeout_ms = prof.vanilla_time(1) * slo_mult / 2
        base = summarize(ServingSimulator(prof, pf).run(reqs))
        ctl = ApparateController(dom["n_sites"], prof, ControllerConfig(max_slots=6))
        runner = ClassifierRunner(dom["model"], dom["params"], dom["stream"].data, max_slots=6)
        ours = summarize(ServingSimulator(prof, pf, runner, ctl).run(reqs))
        win = 100 * (base["p50_ms"] - ours["p50_ms"]) / base["p50_ms"]
        emit(f"fig17_slo{slo_mult}x", ours["p50_ms"] * 1e3, f"win_pct={win:.1f}")


# ------------------------------------------------------ scale-out (ROADMAP)


def bench_scaleout_goodput():
    """N-worker cluster vs single worker on the bursty MAF trace: goodput
    at equal SLO, with per-replica Apparate controllers staying inside the
    ramp budget (the paper's claim, scaled out)."""
    from repro.configs import get_config
    from repro.core import ApparateController, ControllerConfig, build_profile
    from repro.serving import (
        ClusterConfig,
        ClusterSimulator,
        PlatformConfig,
        SyntheticRunner,
        make_requests,
        maf_trace,
        summarize,
    )

    prof = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)
    ns = len(prof.sites)
    mbs = 8
    qps_cap = mbs * 1000.0 / prof.vanilla_time(mbs)
    arr = maf_trace(3000, mean_qps=4 * 0.6 * qps_cap, seed=7)
    reqs = make_requests(arr, slo_ms=3 * prof.vanilla_time(1))
    pf = PlatformConfig(policy="tfserve", max_batch_size=mbs,
                        batch_timeout_ms=prof.vanilla_time(1))

    def run(nw, dispatch):
        ctls = [ApparateController(ns, prof, ControllerConfig(max_slots=4)) for _ in range(nw)]
        sim = ClusterSimulator(
            prof, ClusterConfig(n_workers=nw, dispatch=dispatch, platform=pf),
            runner=SyntheticRunner(ns, exit_site=ns // 3), controllers=ctls,
        )
        m = summarize(sim.run(reqs), horizon_ms=sim.makespan_ms)
        lim = ControllerConfig().ramp_budget_frac * prof.vanilla_time(1)
        ok = all(c.total_ramp_overhead(1) <= lim + 1e-9 for c in ctls)
        return m, ok

    for nw in (1, 2, 4):
        m, ok = run(nw, "jsq")
        emit(f"scaleout_{nw}w_goodput", m["p50_ms"] * 1e3,
             f"goodput_qps={m.get('goodput_qps', 0.0):.1f};budget_ok={ok}")
    for dispatch in ("round_robin", "jsq", "slo_aware"):
        m, _ = run(4, dispatch)
        emit(f"scaleout_4w_{dispatch}", m["p50_ms"] * 1e3,
             f"goodput_qps={m.get('goodput_qps', 0.0):.1f}")


# ---------------------------------------------- generative decode (Table 4)


def bench_generative_tpt():
    """Generative decode: median time-per-token with per-token Apparate
    exits vs the no-EE baseline at the same accuracy constraint (>=0.99
    agreement), KV catch-up charged (paper §5 Table 4: 22.6–77.9% TPT
    wins). Swept over easy-traffic fractions; the profile pays the
    full-vocab token head (n_classes=0) with LM-head-tied ramps."""
    from repro.configs import get_config
    from repro.core import ApparateController, ControllerConfig, build_profile
    from repro.serving import (
        GenerativeConfig,
        GenerativeEngine,
        SyntheticDecodeRunner,
        make_gen_requests,
        maf_trace,
        offered_decode_qps,
        summarize_generative,
    )

    prof = build_profile(
        get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied"),
        mode="decode", chips=1, charge_kv=True,
    )
    ns = len(prof.sites)
    mbs, tokens = 8, 24
    qps = offered_decode_qps(prof, max_batch_size=mbs, tokens_per_request=tokens, load=0.6)
    arr = maf_trace(200, mean_qps=qps, seed=3)
    reqs = make_gen_requests(arr, n_tokens=tokens, prompt_len=128,
                             slo_ms=3 * prof.vanilla_time(1))
    gcfg = GenerativeConfig(max_batch_size=mbs)
    base_eng = GenerativeEngine(prof, gcfg)
    mb = summarize_generative(base_eng.run(reqs), horizon_ms=base_eng.makespan_ms)
    emit("gen_tpt_vanilla_p50", mb["tpt_p50_ms"] * 1e3,
         f"tokens_per_sec={mb['tokens_per_sec']:.0f}")
    for easy in (0.5, 0.7, 0.9):
        ctl = ApparateController(ns, prof, ControllerConfig(max_slots=4, acc_constraint=0.99))
        eng = GenerativeEngine(
            prof, gcfg, SyntheticDecodeRunner(ns, exit_site=ns // 3, easy_frac=easy), ctl
        )
        mo = summarize_generative(eng.run(reqs), horizon_ms=eng.makespan_ms)
        win = (100 * (mb["tpt_p50_ms"] - mo["tpt_p50_ms"]) / mb["tpt_p50_ms"]
               if mb["tpt_p50_ms"] > 0 else 0.0)
        emit(
            f"gen_tpt_easy{int(easy * 100)}_p50",
            mo["tpt_p50_ms"] * 1e3,
            f"win_pct={win:.1f};agree={mo['agreement']:.3f};"
            f"exit_rate={mo['exit_rate']:.2f};kv_ms={eng.kv_ms:.1f}",
        )


# ---------------------------------------- batched single-dispatch decode


def bench_decode_dispatch():
    """Batched slot-cache decode vs the per-slot B=1 loop on a real tiny
    LM: jitted dispatches issued per decode step (the tentpole claim:
    B -> 1) and step wall-clock at B in {1, 4, 8}, flash-decode wrapper
    ('ref' oracle on CPU; 'kernel' is the same call on TPU)."""
    import jax

    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.serving import DecodeRunner, LoopDecodeRunner

    cfg = get_tiny("qwen2-1.5b").replace(n_layers=4, vocab_size=128, decode_attn="ref")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 128, (8, 12)).astype(np.int32)
    act = [0, len(model.sites) - 1]
    iters = 8
    snap = {}
    for B in (1, 4, 8):
        wall = {}
        for name, cls in (("loop", LoopDecodeRunner), ("batched", DecodeRunner)):
            r = cls(model, params, prompts, max_new_tokens=iters + 4, max_slots=3)
            for s in range(B):
                r.start(s, s)
            r.step(list(range(B)), act)  # warmup: compile the step shape
            r.dispatches = 0
            t0 = time.perf_counter()
            for _ in range(iters):
                r.step(list(range(B)), act)
            us = (time.perf_counter() - t0) / iters * 1e6
            d = r.dispatches / iters
            emit(f"decode_dispatch_{name}_B{B}", us, f"dispatches_per_step={d:.1f}")
            snap[f"{name}_B{B}"] = {"us_per_step": us, "dispatches_per_step": d}
            wall[name] = us
        emit(f"decode_dispatch_win_B{B}", wall["loop"] / wall["batched"],
             f"batched_speedup_x={wall['loop'] / wall['batched']:.2f}")
        snap[f"speedup_B{B}"] = wall["loop"] / wall["batched"]
    snapshot("decode_dispatch", snap)


def bench_tune_wall():
    """Controller adaptation hot loop: threshold-tuning wall time,
    vectorized (one batched simulate_exits pass per round) vs the
    sequential reference — results asserted bit-identical."""
    from repro.configs import get_config
    from repro.core import build_profile, tune_thresholds, tune_thresholds_reference

    prof = build_profile(get_config("gpt2-medium"), mode="decode", chips=1)
    ns = len(prof.sites)
    rng = np.random.default_rng(0)
    N = 2048
    unc = rng.random((N, ns)).astype(np.float32)
    valid = np.ones((N, ns), bool)
    correct = rng.random((N, ns)) < (1 - 0.3 * unc)
    wd = (unc, correct, valid)
    act = list(range(6))
    reps = 5
    t0 = time.perf_counter()
    vec = [tune_thresholds(wd, act, prof, n_sites=ns) for _ in range(reps)][-1]
    t_vec = (time.perf_counter() - t0) / reps
    t0 = time.perf_counter()
    ref = [tune_thresholds_reference(wd, act, prof, n_sites=ns) for _ in range(reps)][-1]
    t_ref = (time.perf_counter() - t0) / reps
    identical = bool(
        np.array_equal(vec.thresholds, ref.thresholds)
        and vec.savings_ms == ref.savings_ms
        and vec.rounds == ref.rounds
    )
    emit("tune_wall_vectorized", t_vec * 1e6, f"rounds={vec.rounds}")
    emit("tune_wall_reference", t_ref * 1e6, f"identical={identical}")
    emit("tune_wall_speedup", t_ref / t_vec, f"speedup_x={t_ref / t_vec:.2f}")
    snapshot("tune_wall", {
        "us_vectorized": t_vec * 1e6,
        "us_reference": t_ref * 1e6,
        "speedup_x": t_ref / t_vec,
        "identical": identical,
        "rounds": int(vec.rounds),
    })


def bench_paged_kv():
    """Paged vs contiguous batched decode on a real tiny LM under a
    staggered continuous-batching workload (2 of 16 slots concurrently
    live): peak KV-cache bytes must scale with live tokens (block pool)
    rather than n_slots * max_len (contiguous rows), at the SAME dispatch
    count and bit-identical greedy tokens; step wall-clock recorded."""
    import jax

    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.serving import DecodeRunner

    cfg = get_tiny("qwen2-1.5b").replace(n_layers=4, vocab_size=128, decode_attn="ref")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 128, (16, 16)).astype(np.int32)
    n_slots, max_new, bs_blk, kv_blocks = 16, 16, 8, 12  # cache_len 32 = 4 blocks
    act = [0, len(model.sites) - 1]

    def staggered(r):
        """4 waves of 2 short-lived requests; at most 2 slots live at once
        (of n_slots capacity — the concurrency headroom paging buys)."""
        toks, wall, steps = [], 0.0, 0
        for w in range(4):
            s0, s1 = (2 * w) % n_slots, (2 * w + 1) % n_slots
            toks.append(r.start(s0, 2 * w))
            toks.append(r.start(s1, 2 * w + 1))
            for _ in range(6):
                t0 = time.perf_counter()
                _, _, fin = r.step([s0, s1], act)
                wall += time.perf_counter() - t0
                steps += 1
                toks.extend(int(t) for t in fin)
            r.free(s0)
            r.free(s1)
        return toks, wall / steps * 1e6

    cont = DecodeRunner(model, params, prompts, max_new_tokens=max_new,
                        max_slots=3, n_slots=n_slots)
    paged = DecodeRunner(build_model(cfg.replace(decode_attn="paged")), params,
                         prompts, max_new_tokens=max_new, max_slots=3,
                         n_slots=n_slots, kv_block_size=bs_blk, kv_blocks=kv_blocks)
    staggered(cont), staggered(paged)  # warmup: compile both paths
    tc, us_c = staggered(cont)
    tp, us_p = staggered(paged)
    identical = tc == tp
    dispatches_equal = cont.dispatches == paged.dispatches
    bc, bp = cont.cache_bytes(), paged.cache_bytes()
    st = paged.kv_stats()
    emit("paged_kv_step_contiguous", us_c, f"cache_bytes={bc}")
    emit("paged_kv_step_paged", us_p,
         f"cache_bytes={bp};identical={identical};dispatches_equal={dispatches_equal}")
    emit("paged_kv_bytes_ratio", bc / bp,
         f"peak_blocks={st['peak_blocks']};peak_tokens={st['peak_token_capacity']};"
         f"contig_tokens={cont._rows * cont._cache_len}")
    snapshot("paged_kv", {
        "us_per_step_contiguous": us_c,
        "us_per_step_paged": us_p,
        "contiguous_cache_bytes": bc,
        "paged_cache_bytes": bp,
        "bytes_ratio": bc / bp,
        "peak_blocks": int(st["peak_blocks"]),
        "peak_token_capacity": int(st["peak_token_capacity"]),
        "block_size": int(st["block_size"]),
        "identical": bool(identical),
        "dispatches_equal": bool(dispatches_equal),
    })


# ---------------------------------------------- chunked prefill interleaving


def bench_chunked_prefill():
    """Chunked prefill on the unified engine: TTFT/TPT p95 with and without
    ``--prefill-chunk`` on a long-prompt + short-decode mix. Unchunked, a
    512-token prefill stalls every in-flight decode slot (whole prefills
    land in the TPT tail); chunked, prefill work co-schedules between
    decode steps, so TPT p95 must come back DOWN to decode scale while
    TTFT stays within the interleave bound (one co-scheduled decode step
    per chunk). Gate rows: ``tpt_p95_le_unchunked`` and
    ``ttft_within_bound`` must both be True. Also checks the engine-facade
    equivalence smoke (facade == frozen pre-refactor loop on a seeded
    schedule) so CI catches a drifting core without the full fuzz."""
    from repro.configs import get_config
    from repro.core import build_profile
    from repro.serving import (
        GenerativeConfig,
        GenerativeEngine,
        GenRequest,
        ReferenceGenerativeEngine,
        maf_trace,
        offered_decode_qps,
        summarize_generative,
    )

    prof = build_profile(
        get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied"),
        mode="decode", chips=1, charge_kv=True,
    )
    mbs, chunk, long_prompt = 8, 64, 512
    qps = offered_decode_qps(prof, max_batch_size=mbs, tokens_per_request=16, load=0.7)
    arr = maf_trace(60, mean_qps=qps, seed=1)
    reqs = [
        GenRequest(rid=k, arrival_ms=float(t), slo_ms=3 * prof.vanilla_time(1),
                   item=k, prompt_len=long_prompt if k % 5 == 4 else 32,
                   n_tokens=4 if k % 5 == 4 else 16)
        for k, t in enumerate(arr)
    ]
    runs = {}
    for name, pc in (("unchunked", 0), ("chunked", chunk)):
        eng = GenerativeEngine(prof, GenerativeConfig(max_batch_size=mbs,
                                                      prefill_chunk=pc))
        runs[name] = (summarize_generative(eng.run(reqs), horizon_ms=eng.makespan_ms), eng)
    mu, mc = runs["unchunked"][0], runs["chunked"][0]
    n_chunks_max = -(-long_prompt // chunk)
    ttft_bound = mu["ttft_p95_ms"] + n_chunks_max * prof.vanilla_time(mbs)
    tpt_ok = mc["tpt_p95_ms"] <= mu["tpt_p95_ms"] + 1e-9
    ttft_ok = mc["ttft_p95_ms"] <= ttft_bound + 1e-9
    emit("chunked_prefill_unchunked_tpt_p95", mu["tpt_p95_ms"] * 1e3,
         f"ttft_p95_ms={mu['ttft_p95_ms']:.2f}")
    emit("chunked_prefill_chunked_tpt_p95", mc["tpt_p95_ms"] * 1e3,
         f"ttft_p95_ms={mc['ttft_p95_ms']:.2f};tpt_p95_le_unchunked={tpt_ok};"
         f"ttft_within_bound={ttft_ok}")
    win = (100 * (mu["tpt_p95_ms"] - mc["tpt_p95_ms"]) / mu["tpt_p95_ms"]
           if mu["tpt_p95_ms"] > 0 else 0.0)
    emit("chunked_prefill_tpt_p95_win", win, f"win_pct={win:.1f}")
    # engine-facade equivalence smoke (full fuzz: tests/test_engine_equivalence.py)
    facade = GenerativeEngine(prof, GenerativeConfig(max_batch_size=mbs))
    ref = ReferenceGenerativeEngine(prof, GenerativeConfig(max_batch_size=mbs))
    fa, fb = facade.run(reqs), ref.run(reqs)
    identical = [(r.rid, r.release_ms, r.tokens) for r in fa] == [
        (r.rid, r.release_ms, r.tokens) for r in fb]
    emit("chunked_prefill_facade_smoke", facade.makespan_ms, f"identical={identical}")
    snapshot("chunked_prefill", {
        "chunk_tokens": chunk,
        "unchunked_tpt_p95_ms": mu["tpt_p95_ms"],
        "chunked_tpt_p95_ms": mc["tpt_p95_ms"],
        "tpt_p95_win_pct": win,
        "unchunked_ttft_p95_ms": mu["ttft_p95_ms"],
        "chunked_ttft_p95_ms": mc["ttft_p95_ms"],
        "ttft_bound_ms": ttft_bound,
        "tpt_p95_le_unchunked": bool(tpt_ok),
        "ttft_within_bound": bool(ttft_ok),
        "facade_identical": bool(identical),
        "prefill_chunks": int(runs["chunked"][1].n_chunks),
    })


# ------------------------------------------------------------------ kernels


def bench_kernels():
    """Kernel wrappers vs oracles: wall time of the jnp reference path on
    CPU (the TPU kernel is validated in interpret mode; its perf story
    lives in the §Roofline dry-run numbers)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.ramp_head import ramp_head_stats, ramp_head_stats_ref
    from repro.kernels.ssd import ssd_chunked, ssd_chunked_ref

    h = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 4096)) * 0.05
    ref = jax.jit(ramp_head_stats_ref)
    ref(h, w)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        ref(h, w)[0].block_until_ready()
    us = (time.perf_counter() - t0) / 50 * 1e6
    mk = ramp_head_stats(h, w, interpret=True, block_v=1024)
    mr = ref(h, w)
    err = float(jnp.max(jnp.abs(mk[0] - mr[0])))
    emit("kernel_ramp_head_ref", us, f"interp_max_err={err:.2e}")

    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (2, 4, 128, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 4, 128)))
    A = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    Bm = jax.random.normal(ks[3], (2, 128, 16)) * 0.5
    Cm = jax.random.normal(ks[4], (2, 128, 16)) * 0.5
    ref2 = jax.jit(lambda *a: ssd_chunked_ref(*a, chunk=32))
    ref2(x, dt, A, Bm, Cm)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        ref2(x, dt, A, Bm, Cm)[0].block_until_ready()
    us = (time.perf_counter() - t0) / 20 * 1e6
    yk, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    yr, _ = ref2(x, dt, A, Bm, Cm)
    err = float(jnp.max(jnp.abs(yk - yr)))
    emit("kernel_ssd_ref", us, f"interp_max_err={err:.2e}")


from benchmarks.bench_paged_families import bench_paged_families  # noqa: E402
from benchmarks.bench_prefix_cache import bench_prefix_cache  # noqa: E402
from benchmarks.bench_sharded_decode import bench_sharded_decode  # noqa: E402
from benchmarks.bench_steps_per_sync import bench_steps_per_sync  # noqa: E402

ALL = [
    bench_fig3_knobs,
    bench_fig5_optimal_ee,
    bench_table1_threshold_adaptation,
    bench_fig11_tuning_speed,
    bench_fig13_latency_savings,
    bench_fig14_tail_latency,
    bench_table2_existing_ee,
    bench_table3_ramp_budget,
    bench_fig18_accuracy_constraint,
    bench_fig9_ramp_styles,
    bench_table4_platforms,
    bench_fig17_slo,
    bench_scaleout_goodput,
    bench_generative_tpt,
    bench_decode_dispatch,
    bench_tune_wall,
    bench_paged_kv,
    bench_paged_families,
    bench_chunked_prefill,
    bench_prefix_cache,
    bench_steps_per_sync,
    bench_sharded_decode,
    bench_kernels,
]


def main() -> None:
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    print("name,us_per_call,derived")
    for fn in ALL:
        name = fn.__name__
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception as e:  # pragma: no cover
            emit(f"{name}_ERROR", 0.0, repr(e)[:120])
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
