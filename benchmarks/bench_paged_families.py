"""Paged decode across mixer families (gate rows for CI).

The block pool now pages every mixer family the repo serves — MLA latent
streams, per-slot SSM state pages, and ring-paged local windows — and the
claim is the same everywhere: paging is a pure LAYOUT change. Per family
this runs the staggered continuous-batching workload on a paged
`DecodeRunner` and a contiguous `DecodeRunner` oracle and records

  * ``identical`` — bit-identical greedy tokens (gated per family),
  * ``dispatches_equal`` — paging adds zero extra dispatches (gated),
  * peak-KV-bytes savings — pool bytes vs ``n_slots x cache_len`` rows
    (snapshotted; token-cache families shrink ~`n_slots/live`, pure-SSM
    state does NOT scale with tokens so its ratio is reported, not sold).

Gate row (CI greps it): ``paged_families_gate`` must carry
``identical_all=True;dispatches_equal_all=True``.

Oracle attention impls mirror the equivalence tests
(`tests/test_decode_equivalence.py::FAMILY_CONFIGS`): the paged global
path defers to `decode_attention_ref`, so attention oracles route 'ref'
where global layers exist and the exact absorbed math ('dense') for MLA.
"""
from __future__ import annotations

import time

import numpy as np

N_SLOTS = 8
MAX_NEW = 8
PROMPT_LEN = 12  # cache_len 20 = 5 blocks of 4 (bs | cache_len: bit-identity)
BS_BLK = 4
KV_BLOCKS = 14  # >= 2 live slots x 5 blocks + headroom, << N_SLOTS x 5

FAMILIES = {
    # family -> (tiny config, contiguous-oracle decode_attn)
    "mla": ("deepseek-v2-lite-16b", "dense"),
    "mamba": ("mamba2-2.7b", "dense"),
    "local": ("gemma3-4b", "ref"),
}


def bench_paged_families():
    import jax

    from benchmarks.run import emit, snapshot
    from repro.configs import get_tiny
    from repro.models import build_model
    from repro.serving import DecodeRunner

    snap = {}
    ident_all = disp_all = True
    for family, (name, oracle_attn) in FAMILIES.items():
        cfg = get_tiny(name).replace(vocab_size=128, decode_attn=oracle_attn)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(10))
        prompts = np.random.default_rng(11).integers(
            0, 128, (16, PROMPT_LEN)
        ).astype(np.int32)
        act = [0, len(model.sites) - 1]
        kw = dict(max_new_tokens=MAX_NEW, max_slots=3, n_slots=N_SLOTS)

        def staggered(r):
            """4 waves of 2 short-lived requests; at most 2 of N_SLOTS
            live at once — the concurrency headroom paging buys."""
            toks, wall, steps = [], 0.0, 0
            for w in range(4):
                s0, s1 = (2 * w) % N_SLOTS, (2 * w + 1) % N_SLOTS
                toks.append(r.start(s0, 2 * w))
                toks.append(r.start(s1, 2 * w + 1))
                for _ in range(MAX_NEW - 2):
                    t0 = time.perf_counter()
                    _, _, fin = r.step([s0, s1], act)
                    wall += time.perf_counter() - t0
                    steps += 1
                    toks.extend(int(t) for t in fin)
                r.free(s0)
                r.free(s1)
            return toks, wall / steps * 1e6

        cont = DecodeRunner(model, params, prompts, **kw)
        paged = DecodeRunner(
            build_model(cfg.replace(decode_attn="paged")), params, prompts,
            kv_block_size=BS_BLK, kv_blocks=KV_BLOCKS, **kw
        )
        assert paged.paged and not cont.paged
        staggered(cont), staggered(paged)  # warmup: compile both paths
        cont.dispatches = paged.dispatches = 0
        tc, us_c = staggered(cont)
        tp, us_p = staggered(paged)
        identical = tc == tp
        dispatches_equal = cont.dispatches == paged.dispatches
        ident_all &= identical
        disp_all &= dispatches_equal
        bc, bp = cont.cache_bytes(), paged.cache_bytes()
        st = paged.kv_stats()
        emit(f"paged_families_{family}", us_p,
             f"identical={identical};dispatches_equal={dispatches_equal}")
        emit(f"paged_families_{family}_bytes", bc / bp,
             f"contig_bytes={bc};paged_bytes={bp};"
             f"peak_blocks={st['peak_blocks']}")
        snap[family] = {
            "config": name,
            "us_per_step_contiguous": float(us_c),
            "us_per_step_paged": float(us_p),
            "contiguous_cache_bytes": int(bc),
            "paged_cache_bytes": int(bp),
            "bytes_ratio": float(bc / bp),
            "peak_blocks": int(st["peak_blocks"]),
            "dispatches": int(paged.dispatches),
            "identical": bool(identical),
            "dispatches_equal": bool(dispatches_equal),
        }
    emit("paged_families_gate", 0.0,
         f"identical_all={ident_all};dispatches_equal_all={disp_all}")
    snap["identical_all"] = bool(ident_all)
    snap["dispatches_equal_all"] = bool(disp_all)
    snapshot("paged_families", snap)
