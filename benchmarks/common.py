"""Shared benchmark fixtures: paper-shape bench models trained once (cached
under .cache/bench), full per-stream ramp record matrices, and offline
replay helpers mirroring the paper's evaluation methodology (§5.1):
bootstrap = first 10% (train ramps/tuning), evaluation = remaining 90%.
"""
from __future__ import annotations

import os
from typing import Dict, List, Tuple

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_bench, get_config
from repro.core import (
    ApparateController,
    ControllerConfig,
    build_profile,
    evaluate_config,
    simulate_exits,
    tune_thresholds,
)
from repro.data import make_image_stream, make_token_stream
from repro.models import build_model
from repro.serving import ClassifierRunner
from repro.training import TrainConfig, train

CACHE = os.path.join(os.path.dirname(__file__), "..", ".cache", "bench")
N_STREAM = 3000


def get_domain(domain: str, *, seed: int = 2, ramp_style: str = "fc") -> Dict:
    """domain in {'cv','cv_hard','nlp'} -> trained paper-shape model + stream
    + profile + full record matrices (unc/lab per site, final labels).
    'cv_hard' uses confusable (mixed) class prototypes so early-ramp
    confidence is NOT perfectly separable — required for the adaptation-
    sensitivity tables (t1/t2/t3/fig18) to show non-degenerate behavior."""
    tag = f"{domain}_{seed}_{ramp_style}"
    if domain.startswith("cv"):
        hard = domain == "cv_hard"
        cfg = get_bench("resnet18").replace(n_classes=16 if hard else 10)
        stream = make_image_stream(
            N_STREAM, img_size=cfg.img_size, n_classes=cfg.n_classes, mode="cv",
            seed=seed, proto_mix=0.35 if hard else 0.0,
        )
        data_key, lr, steps = "images", 3e-3, 100 if hard else 150
        prof_cfg = get_config("resnet18").replace(resnet_widths=(64, 128, 256, 512), img_size=224)
    else:
        cfg = get_bench("bert-base").replace(n_classes=10, ramp_style=ramp_style)
        stream = make_token_stream(N_STREAM, seq_len=32, vocab=cfg.vocab_size, n_classes=10, mode="nlp", seed=seed)
        data_key, lr, steps = "tokens", 1e-3, 200
        prof_cfg = get_config("bert-base")
    model = build_model(cfg)
    boot = N_STREAM // 10

    mgr = CheckpointManager(os.path.join(CACHE, tag), keep=1)
    state = mgr.restore()
    if state is None:
        # paper §5.1: CV backbones are fine-tuned on a RANDOM 10% of frames
        # across the dataset; NLP ramp-training uses the first 10% (1:9 split)
        rng0 = np.random.default_rng(seed)
        if domain.startswith("cv"):
            pool = rng0.choice(N_STREAM, size=max(boot, 256), replace=False)
        else:
            pool = np.arange(boot)

        def batches(s):
            rng = np.random.default_rng(s)
            idx = pool[rng.integers(0, len(pool), 64)]
            return {data_key: stream.data[idx], "labels": stream.labels[idx]}

        state, _ = train(model, batches, TrainConfig(steps=steps, lr=lr), verbose=False)
        mgr.save(state, step=steps)
    params = state["params"]
    runner = ClassifierRunner(model, params, stream.data, max_slots=len(model.sites))
    profile = build_profile(
        prof_cfg, mode="decode", chips=1,
        ramp_cost_mult=4.0 if ramp_style == "mlp" else 1.0,
    )
    rec_path = os.path.join(CACHE, tag, "records.npz")
    if os.path.exists(rec_path):
        z = np.load(rec_path)
        lab, unc, fin = z["lab"], z["unc"], z["fin"]
    else:
        lab, unc, fin = [], [], []
        for lo in range(0, N_STREAM, 256):
            idx = np.arange(lo, min(lo + 256, N_STREAM))
            l, u, f = runner.infer(idx, list(model.sites))
            lab.append(l); unc.append(u); fin.append(f)
        lab = np.concatenate(lab, 1); unc = np.concatenate(unc, 1); fin = np.concatenate(fin)
        os.makedirs(os.path.dirname(rec_path), exist_ok=True)
        np.savez(rec_path, lab=lab, unc=unc, fin=fin)
    return dict(
        cfg=cfg, model=model, params=params, stream=stream, profile=profile,
        runner=runner, boot=boot, lab=lab, unc=unc, fin=fin,
        n_sites=len(model.sites),
    )


def window_from_records(dom, idx) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build an (unc, correct, valid) window for sample indices `idx`."""
    lab, unc, fin = dom["lab"], dom["unc"], dom["fin"]
    S = dom["n_sites"]
    u = unc[:, idx].T.astype(np.float32)  # (N, S)
    c = (lab[:, idx] == fin[idx][None, :]).T
    v = np.ones_like(c, bool)
    return u, c, v


def per_sample_savings(dom, idx, thresholds, active) -> Tuple[np.ndarray, np.ndarray]:
    """(saved_ms per sample, correct per sample) at reference bs=1."""
    prof = dom["profile"]
    u, c, v = window_from_records(dom, idx)
    ex = simulate_exits(u, v, thresholds, active)
    act = sorted(active)
    ovh = np.asarray([prof.ramp_overhead(s, 1) for s in act])
    total = ovh.sum()
    saved = np.full(len(idx), -total)
    correct = np.ones(len(idx), bool)
    for i, s in enumerate(act):
        m = ex == s
        saved[m] = prof.savings_at_site(s, 1) - ovh[: i + 1].sum()
        correct[m] = c[m, s]
    return saved, correct


def replay_fixed(dom, thresholds, active, chunk=64):
    """Evaluate FIXED thresholds over the eval split (one-time tuning)."""
    idx = np.arange(dom["boot"], N_STREAM)
    saved, correct = per_sample_savings(dom, idx, thresholds, active)
    van = dom["profile"].vanilla_time(1)
    return dict(
        accuracy=float(correct.mean()),
        median_win_pct=float(100 * np.median(saved) / van),
        mean_win_pct=float(100 * saved.mean() / van),
    )


def replay_continual(dom, *, acc=0.99, budget=0.02, slots=6, chunk=16):
    """Stream the eval split through a live controller (continual tuning)."""
    prof = dom["profile"]
    ctl = ApparateController(
        dom["n_sites"], prof,
        ControllerConfig(max_slots=slots, ramp_budget_frac=budget, acc_constraint=acc),
    )
    lab, unc, fin = dom["lab"], dom["unc"], dom["fin"]
    van = prof.vanilla_time(1)
    saved_all, correct_all = [], []
    for lo in range(dom["boot"], N_STREAM, chunk):
        idx = np.arange(lo, min(lo + chunk, N_STREAM))
        act = sorted(ctl.active)
        sub_lab = np.stack([lab[s, idx] for s in act]) if act else np.zeros((0, len(idx)), np.int64)
        sub_unc = np.stack([unc[s, idx] for s in act]) if act else np.zeros((0, len(idx)), np.float32)
        thr_before = ctl.thresholds.copy()
        dec = ctl.observe(sub_lab, sub_unc, fin[idx])
        ovh = np.asarray([prof.ramp_overhead(s, 1) for s in act]) if act else np.zeros(0)
        total = ovh.sum()
        for j, site in enumerate(dec.exit_sites):
            if site >= 0:
                i = act.index(site)
                saved_all.append(prof.savings_at_site(site, 1) - ovh[: i + 1].sum())
            else:
                saved_all.append(-total)
            correct_all.append(dec.released_labels[j] == fin[idx][j])
    saved_all = np.asarray(saved_all)
    return dict(
        accuracy=float(np.mean(correct_all)),
        median_win_pct=float(100 * np.median(saved_all) / van),
        mean_win_pct=float(100 * saved_all.mean() / van),
        controller=ctl,
    )


def tune_on(dom, idx, active, acc=0.99):
    wd = window_from_records(dom, idx)
    return tune_thresholds(
        wd, active, dom["profile"], n_sites=dom["n_sites"], acc_constraint=acc
    )


def optimal_exits(dom, idx):
    """Paper §2.2 'optimal': earliest ramp whose top-1 equals the final
    label, zero ramp overheads (conservative upper bound)."""
    lab, fin = dom["lab"], dom["fin"]
    prof = dom["profile"]
    van = prof.vanilla_time(1)
    saved = np.zeros(len(idx))
    for j, i in enumerate(idx):
        hit = np.nonzero(lab[:, i] == fin[i])[0]
        if len(hit):
            saved[j] = van - prof.time_to_layer(prof.sites[hit[0]], 1)
    return saved
