"""Roofline report generator: reads artifacts/dryrun/*.json and renders the
EXPERIMENTS.md §Dry-run and §Roofline tables.

Terms (per §Roofline spec; per-device, from the compiled artifacts):
  compute    = HLO_FLOPs / peak_FLOP/s            (197 bf16 TF/s per chip)
  memory     = HLO_bytes / HBM_bw                 (819 GB/s)
  collective = collective wire bytes / ICI_bw     (50 GB/s/link)

HLO_FLOPs/bytes come from the depth-extrapolated unrolled metric lowerings
(scan bodies are otherwise counted once); collective bytes are parsed from
the post-SPMD HLO with ring-cost weights (all-reduce 2N, others N). The
memory term from `cost_analysis` "bytes accessed" is an UPPER BOUND: the
CPU backend barely fuses, so every intermediate op's operands count; the
analytic weight+cache traffic column is shown alongside as the lower bound.

  PYTHONPATH=src python -m benchmarks.roofline [--update-experiments]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh="single", tag=""):
    out = {}
    for path in sorted(glob.glob(os.path.join(ART, f"*__{mesh}{tag}.json"))):
        with open(path) as f:
            d = json.load(f)
        if tag == "" and d.get("tag"):
            continue
        out[(d["arch"], d["shape"])] = d
    return out


def analytic_memory_bytes(d) -> float:
    """Lower-bound HBM traffic per device: one pass over sharded weights
    (+optimizer state for train) + KV-cache read/write for decode."""
    chips = d["chips"]
    w = d["params_total"] * 2 / chips  # bf16
    if d["kind"] == "train":
        return w * 3 + d["params_total"] * 8 / chips  # fwd+bwd+remat + adam f32
    if d["kind"] == "decode":
        kv = d.get("full_memory", {}).get("argument_size_in_bytes", 0)
        return w + kv * 0.9  # cache dominates the argument bytes
    return w


def fmt_row(d):
    tc, tm, tcl = d.get("t_compute_s"), d.get("t_memory_s"), d.get("t_collective_s")
    if tc is None:
        return None
    bott = d.get("bottleneck", "?")
    ratio = d.get("useful_flops_ratio", float("nan"))
    am = analytic_memory_bytes(d) / 819e9
    return (
        f"| {d['arch']} | {d['shape']} | {tc:.4f} | {tm:.3f} | {am:.3f} | "
        f"{tcl:.3f} | {bott} | {d['model_flops_ref']:.2e} | {ratio:.2f} |"
    )


def dominant_fix(d) -> str:
    b = d.get("bottleneck")
    if b == "collective":
        return "sequence-parallel RS/AG instead of TP all-reduce; bf16 comms"
    if b == "memory":
        if d["kind"] == "decode":
            return "shrink KV residency (windowed local caches / MLA latent cache); fuse"
        return "fusion + remat policy (bytes term is unfused upper bound)"
    return "larger per-chip batch or faster kernels"


def render(update=False):
    single = load("single")
    multi = load("multi")
    lines = []
    lines.append("### Roofline table (single-pod 16×16, per-device terms in seconds/step)\n")
    lines.append(
        "| arch | shape | t_compute | t_memory(hlo-UB) | t_memory(analytic-LB) | "
        "t_collective | bottleneck | MODEL_FLOPS | useful/compiled |"
    )
    lines.append("|---|---|---|---|---|---|---|---|---|"[:-1])
    n_ok = 0
    worst = []
    for (arch, shape), d in sorted(single.items()):
        if not d.get("ok"):
            lines.append(f"| {arch} | {shape} | FAILED: {d.get('error','')[:60]} |")
            continue
        row = fmt_row(d)
        if row:
            lines.append(row)
            n_ok += 1
            terms = dict(c=d["t_compute_s"], m=d["t_memory_s"], l=d["t_collective_s"])
            tot = max(sum(terms.values()), 1e-12)
            worst.append((d["t_compute_s"] / tot, arch, shape, d))
    lines.append("")
    lines.append("Per-cell dominant-term note (what moves it down):\n")
    for (arch, shape), d in sorted(single.items()):
        if d.get("ok") and d.get("bottleneck"):
            lines.append(f"- **{arch} × {shape}** → {d['bottleneck']}-bound: {dominant_fix(d)}")
    lines.append("")
    lines.append("### Multi-pod (2×16×16) dry-run pass\n")
    lines.append("| arch | shape | compile | bytes/device (args) | collectives seen |")
    lines.append("|---|---|---|---|---|")
    for (arch, shape), d in sorted(multi.items()):
        if d.get("ok"):
            mem = d.get("full_memory", {}).get("argument_size_in_bytes", 0) / 1e9
            counts = d.get("full_collectives", {}).get("counts", {})
            cs = " ".join(f"{k.split('-')[-1]}:{v}" for k, v in counts.items() if v)
            lines.append(
                f"| {arch} | {shape} | OK ({d.get('full_compile_s',0):.0f}s) | {mem:.2f} GB | {cs} |"
            )
        else:
            lines.append(f"| {arch} | {shape} | FAIL: {d.get('error','')[:60]} | | |")
    text = "\n".join(lines)
    print(text)
    print(f"\n# {n_ok} single-pod cells with roofline terms")
    return text


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--update-experiments", action="store_true")
    args = ap.parse_args()
    render(args.update_experiments)


if __name__ == "__main__":
    main()
