"""Version-compat shims for the installed JAX.

The repo targets the current ``jax.shard_map`` API; older JAX (≤0.4.x,
as shipped in this container) exposes shard_map under
``jax.experimental.shard_map`` and names the replication-check kwarg
``check_rep`` instead of ``check_vma``. Route every shard_map call
through here so call sites stay on the modern spelling.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
        )


def mesh_axis_size(mesh, axis: str, default: int = 1) -> int:
    """Size of a named mesh axis, ``default`` if absent (or ``mesh`` is None).

    Current JAX exposes ``Mesh.shape`` as a Mapping (``.get`` works); older
    versions return a plain tuple-like, where sizes must be rebuilt from
    ``axis_names``/``devices.shape``. All call sites go through here instead
    of probing ``mesh.shape`` inline."""
    if mesh is None:
        return default
    shape = mesh.shape
    if hasattr(shape, "get"):
        return int(shape.get(axis, default))
    return int(dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, default))


def cost_analysis(compiled) -> dict:
    """Normalized ``Compiled.cost_analysis()``: old JAX returns a one-element
    list of dicts (one per program), current JAX returns the dict itself."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
