"""Functional parameter-schema system.

Single source of truth: a model declares a *schema* — a pytree of
``ParamInfo`` — from which we derive (a) initialized parameters,
(b) PartitionSpecs for pjit, and (c) abstract ShapeDtypeStructs for
dry-run lowering. This guarantees params and shardings never drift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamInfo:
    shape: tuple
    dtype: Any = jnp.float32
    spec: P = P()
    # 'normal:<scale>' | 'zeros' | 'ones' | 'embed:<scale>' | 'ssm_a' | 'dt_bias'
    init: str = "normal:0.02"

    def initialize(self, key: jax.Array) -> jax.Array:
        kind, _, arg = self.init.partition(":")
        if kind == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if kind == "ones":
            return jnp.ones(self.shape, self.dtype)
        if kind in ("normal", "embed"):
            scale = float(arg) if arg else 0.02
            # fan-in scaled init for 2D+ weights
            x = jax.random.normal(key, self.shape, jnp.float32) * scale
            return x.astype(self.dtype)
        if kind == "ssm_a":  # A_log init in [log(1), log(16)) per Mamba2
            lo, hi = 1.0, 16.0
            u = jax.random.uniform(key, self.shape, jnp.float32)
            return jnp.log(lo + u * (hi - lo)).astype(self.dtype)
        if kind == "dt_bias":  # softplus^-1 of dt in [1e-3, 1e-1]
            u = jax.random.uniform(key, self.shape, jnp.float32)
            dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
            return (dt + jnp.log(-jnp.expm1(-dt))).astype(self.dtype)
        raise ValueError(f"unknown init {self.init!r}")


def is_info(x) -> bool:
    return isinstance(x, ParamInfo)


def init_from_schema(schema: Pytree, key: jax.Array) -> Pytree:
    """Initialize a parameter pytree from a schema; keys derived per-leaf."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_info)
    keys = jax.random.split(key, len(leaves))
    out = [info.initialize(k) for info, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def specs_from_schema(schema: Pytree) -> Pytree:
    return jax.tree.map(lambda i: i.spec, schema, is_leaf=is_info)


def abstract_from_schema(schema: Pytree) -> Pytree:
    return jax.tree.map(
        lambda i: jax.ShapeDtypeStruct(i.shape, i.dtype), schema, is_leaf=is_info
    )


def param_count(schema_or_params: Pytree) -> int:
    def _n(x):
        if is_info(x):
            return int(np.prod(x.shape)) if x.shape else 1
        return int(np.prod(x.shape)) if hasattr(x, "shape") else 0

    return sum(_n(l) for l in jax.tree.leaves(schema_or_params, is_leaf=is_info))


def param_bytes(schema: Pytree) -> int:
    def _b(i: ParamInfo):
        return int(np.prod(i.shape)) * jnp.dtype(i.dtype).itemsize

    return sum(_b(l) for l in jax.tree.leaves(schema, is_leaf=is_info))


# ---------------------------------------------------------------------------
# sharding helpers


def shard_if_divisible(dim: int, axis: Optional[str], mesh_axis_sizes: dict) -> Optional[str]:
    """Return `axis` if `dim` divides evenly over it on every mesh we target."""
    if axis is None:
        return None
    size = mesh_axis_sizes.get(axis, 1)
    return axis if dim % size == 0 else None


# Mesh axis sizes we must remain divisible under (the production meshes).
PRODUCTION_AXES = {"data": 32, "model": 16}  # data worst case = pod*data = 32


def mk_spec(*axes) -> P:
    return P(*axes)


def sanitize_specs(specs: Pytree, abstracts: Pytree, mesh) -> Pytree:
    """Drop sharding-axis entries whose mesh size doesn't divide the dim.
    Keeps every spec valid on the given mesh (e.g. kv_heads=8 on model=16
    falls back to replication; batch=1 long-decode drops the data axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _fix(spec: P, aval) -> P:
        out = []
        for d, entry in enumerate(spec):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes.get(a, 1)
            out.append(entry if aval.shape[d] % total == 0 else None)
        return P(*out)

    return jax.tree.map(
        lambda s, a: _fix(s, a) if isinstance(s, P) else s,
        specs,
        abstracts,
        is_leaf=lambda x: isinstance(x, P),
    )


def pad_vocab(v: int, multiple: int = 2048) -> int:
    return ((v + multiple - 1) // multiple) * multiple
