"""Encoder-decoder backbone (SeamlessM4T) and encoder classifier (BERT).

The seamless speech frontend is a STUB per the assignment: the encoder
consumes precomputed frame embeddings (B, frames, d_frontend). Early-exit
ramps attach to *decoder* blocks (enc-only intermediates have no output
semantics); for BERT they attach after every encoder block with CLS-pool +
classifier-FC ramps — exactly the paper's BERT recipe (§3.1).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as LY
from repro.models.common import (
    ParamInfo,
    abstract_from_schema,
    init_from_schema,
    is_info,
    specs_from_schema,
)
from repro.models.layers import MeshAxes
from repro.models.transformer import MultiStepDecodeMixin, paged_leaf_kinds


def _enc_layer_schema(cfg, L):
    return {
        "ln1": LY.norm_schema(cfg, L),
        "attn": LY.gqa_schema(cfg, L),
        "ln2": LY.norm_schema(cfg, L),
        "ffn": LY.ffn_schema(cfg, cfg.d_ff, L),
    }


def _dec_layer_schema(cfg, L):
    return {
        "ln1": LY.norm_schema(cfg, L),
        "attn": LY.gqa_schema(cfg, L),
        "lnx": LY.norm_schema(cfg, L),
        "xattn": LY.cross_attn_schema(cfg, L),
        "ln2": LY.norm_schema(cfg, L),
        "ffn": LY.ffn_schema(cfg, cfg.d_ff, L),
    }


class EncDecLM(MultiStepDecodeMixin):
    """SeamlessM4T-style backbone: frame-embedding encoder + token decoder."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.sites = tuple(range(cfg.n_dec_layers - 1))  # ramps on decoder blocks

    def schema(self) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        S = len(self.sites)
        return {
            "frontend_proj": ParamInfo(
                (cfg.d_frontend, cfg.d_model), dt, P(None, "model"), "normal:0.02"
            ),
            "tok": LY.embed_schema(cfg),
            "enc": _enc_layer_schema(cfg, cfg.n_enc_layers),
            "enc_norm": LY.norm_schema(cfg),
            "dec": _dec_layer_schema(cfg, cfg.n_dec_layers),
            "final_norm": LY.norm_schema(cfg),
            "ramps": {
                "norm_w": ParamInfo((S, cfg.d_model), jnp.float32, P(), "zeros"),
                "head": ParamInfo(
                    (S, cfg.d_model, cfg.padded_vocab), dt, P(None, "data", "model"), "normal:0.02"
                ),
            },
        }

    def init(self, key):
        return init_from_schema(self.schema(), key)

    def pspecs(self, axes: MeshAxes):
        return specs_from_schema(LY.resolve_schema(self.schema(), axes))

    def abstract(self):
        return abstract_from_schema(self.schema())

    # -- encoder --------------------------------------------------------------

    def encode(self, params, frames, *, axes=LY.TEST_AXES, mesh=None):
        """frames: (B, M, d_frontend) -> memory (B, M, d)."""
        cfg = self.cfg
        h = frames @ params["frontend_proj"]
        M = h.shape[1]
        positions = jnp.arange(M)[None, :]

        def body(hh, p):
            x = LY.apply_norm(cfg, p["ln1"], hh)
            out, _ = LY.attn_apply(
                cfg, p["attn"], x, positions=positions, mask=None, axes=axes, mesh=mesh
            )
            hh = hh + out
            x = LY.apply_norm(cfg, p["ln2"], hh)
            hh = hh + LY.ffn_apply(cfg, p["ffn"], x, axes, mesh)
            return hh, None

        h, _ = jax.lax.scan(body, h, params["enc"], unroll=True if cfg.scan_unroll else 1)
        return LY.apply_norm(cfg, params["enc_norm"], h)

    # -- decoder --------------------------------------------------------------

    def _dec_stack(self, params, h, *, positions, mask, memory, caches,
                   cache_index, axes, mesh, pool_idx, block_tables=None):
        cfg = self.cfg

        def body(carry, xs):
            hh = carry
            p, c = xs
            x = LY.apply_norm(cfg, p["ln1"], hh)
            sub = {k: c[k] for k in ("k", "v")} if c is not None else None
            out, nc = LY.attn_apply(
                cfg, p["attn"], x, positions=positions, mask=mask, axes=axes,
                mesh=mesh, cache=sub, cache_index=cache_index,
                decode_impl=(cfg.decode_attn if block_tables is not None else "dense"),
                block_table=block_tables,
            )
            hh = hh + out
            x = LY.apply_norm(cfg, p["lnx"], hh)
            kvc = c.get("xkv") if c is not None else None
            if block_tables is not None and kvc is not None:
                # read-only pinned xkv pages: gather the M encoder-memory
                # tokens from the trailing table columns; never written back.
                bsz = kvc["k"].shape[1]
                M = cfg.n_image_tokens
                nbx = -(-M // bsz)
                xtab = jnp.asarray(block_tables, jnp.int32)[:, -nbx:]
                Bq = xtab.shape[0]

                def _gather(pool):
                    return pool[xtab].reshape((Bq, nbx * bsz) + pool.shape[2:])[:, :M]

                out, _ = LY.cross_attn_apply(
                    cfg, p["xattn"], x, memory=None,
                    kv_cache={"k": _gather(kvc["k"]), "v": _gather(kvc["v"])},
                    axes=axes, mesh=mesh,
                )
                kv = kvc
            else:
                out, kv = LY.cross_attn_apply(
                    cfg, p["xattn"], x, memory=memory, kv_cache=kvc, axes=axes, mesh=mesh
                )
            hh = hh + out
            x = LY.apply_norm(cfg, p["ln2"], hh)
            hh = hh + LY.ffn_apply(cfg, p["ffn"], x, axes, mesh)
            ncache = None
            if c is not None:
                ncache = dict(nc)
                ncache["xkv"] = kv
            pooled = jnp.take(hh, pool_idx, axis=1)
            return hh, (pooled, ncache if ncache is not None else 0)

        h, (pooled, ncaches) = jax.lax.scan(
            body, h, (params["dec"], caches), unroll=True if cfg.scan_unroll else 1
        )
        return h, pooled, (ncaches if caches is not None else None)

    def cache_abstract(self, B, S, shard_batch=True):
        cfg = self.cfg
        L, K, hd = cfg.n_dec_layers, cfg.n_kv_heads, cfg.hd
        M = None  # cross kv seq from memory; set at prefill
        dt = jnp.dtype(cfg.dtype)
        raise NotImplementedError  # caches built by prefill below

    # -- paged (block-pool) cache ---------------------------------------------

    def paged_cache_schema(self, n_blocks: int, block_size: int) -> dict:
        """Paged decode layout for the enc-dec decoder: self-attn k/v token
        pools plus read-only pinned ``xkv`` pools for the encoder memory
        (prefilled once by the runner, refcount-pinned, never appended).
        The xkv block ids ride in the LAST ``paged_xkv_blocks`` table
        columns, mirroring the decoder-only cross-attention layout. The
        static encoder-memory token count is ``cfg.n_image_tokens`` (the
        config's generic "frontend memory tokens" knob — image patches for
        vision LMs, speech frames here)."""
        cfg = self.cfg
        L, K, hd = cfg.n_dec_layers, cfg.n_kv_heads, cfg.hd
        dt = jnp.dtype(cfg.dtype)
        hspec = "model" if hd % 16 == 0 else None
        shp = (L, n_blocks, block_size, K, hd)

        def info():
            return ParamInfo(shp, dt, P(None, None, None, None, hspec), "zeros")

        return {"k": info(), "v": info(), "xkv": {"k": info(), "v": info()}}

    def init_paged_cache(self, n_blocks: int, block_size: int) -> dict:
        return jax.tree.map(
            lambda i: jnp.zeros(i.shape, i.dtype),
            self.paged_cache_schema(n_blocks, block_size),
            is_leaf=is_info,
        )

    def paged_cache_kinds(self, n_blocks: int, block_size: int) -> list:
        return paged_leaf_kinds(self.paged_cache_schema(n_blocks, block_size))

    def paged_xkv_blocks(self, block_size: int) -> int:
        """Trailing table columns holding the pinned encoder-memory pages."""
        return -(-self.cfg.n_image_tokens // block_size)

    @property
    def paged_sharing_ok(self) -> bool:
        """Prefix sharing moves token pages between tables; the enc-dec
        decoder's pinned per-slot xkv pages don't share, so the runner
        refuses ``prefix_cache`` for this family."""
        return False

    def prefill(self, params, frames, tokens, *, active_sites=None,
                cache_len=None, axes=LY.TEST_AXES, mesh=None, with_cache=True):
        """Encode frames, run decoder on `tokens` (B,S), return stats for
        the last position + caches (self-attn KV at cache_len + cross KV)."""
        cfg = self.cfg
        B, S = tokens.shape
        cache_len = cache_len or S
        memory = self.encode(params, frames, axes=axes, mesh=mesh)
        positions = jnp.arange(S)[None, :]
        h = LY.embed_apply(cfg, params["tok"], tokens, positions)
        mask = LY.causal_mask(S, cache_len if with_cache else S, 0)
        caches = None
        if with_cache:
            L, K, hd = cfg.n_dec_layers, cfg.n_kv_heads, cfg.hd
            caches = {
                "k": jnp.zeros((L, B, cache_len, K, hd), jnp.dtype(cfg.dtype)),
                "v": jnp.zeros((L, B, cache_len, K, hd), jnp.dtype(cfg.dtype)),
                "xkv": {
                    "k": jnp.zeros((L, B, memory.shape[1], K, hd), jnp.dtype(cfg.dtype)),
                    "v": jnp.zeros((L, B, memory.shape[1], K, hd), jnp.dtype(cfg.dtype)),
                },
            }
        pool_idx = jnp.asarray([S - 1], jnp.int32)
        h, pooled, ncaches = self._dec_stack(
            params, h, positions=positions, mask=mask, memory=memory,
            caches=caches, cache_index=0, axes=axes, mesh=mesh, pool_idx=pool_idx,
        )
        outs = self._head_stats(params, h[:, -1:], pooled, active_sites)
        return ncaches, outs

    def decode(self, params, cache, tokens, pos, *, active_sites=None,
               axes=LY.TEST_AXES, mesh=None, moe_impl="ep", block_tables=None,
               exit_thresholds=None):
        """One decoder step. ``pos`` is an int32 scalar (shared write index)
        or int32[B] per-row indices. With ``block_tables`` the cache is the
        paged pool from ``init_paged_cache``: self-attn tokens scatter
        through the table and cross-attn reads the pinned xkv pages from
        the trailing columns. ``moe_impl`` is accepted for decode_multi
        signature parity (the enc-dec decoder has no MoE layers)."""
        del moe_impl
        cfg = self.cfg
        B, S = tokens.shape
        pos = jnp.asarray(pos, jnp.int32)
        per_row = pos.ndim >= 1
        if per_row:
            positions = pc = pos.reshape(-1, 1)  # (B, 1)
        else:
            positions = pc = jnp.full((1, 1), 0, jnp.int32) + pos
        h = LY.embed_apply(cfg, params["tok"], tokens, positions)
        pool_idx = jnp.asarray([0], jnp.int32)
        if block_tables is not None:
            if not per_row:
                raise ValueError("paged decode requires per-row pos: int32[B]")
            h, pooled, ncaches = self._dec_stack(
                params, h, positions=positions, mask=None, memory=None,
                caches=cache, cache_index=pos.reshape(-1), axes=axes,
                mesh=mesh, pool_idx=pool_idx,
                block_tables=jnp.asarray(block_tables, jnp.int32),
            )
            outs = self._head_stats(params, h, pooled, active_sites,
                                    exit_thresholds=exit_thresholds)
            return ncaches, outs
        Sc = cache["k"].shape[2]
        kpos = jnp.arange(Sc)[None, :]
        mask = (kpos <= pc)[:, None, None, :]
        h, pooled, ncaches = self._dec_stack(
            params, h, positions=positions, mask=mask, memory=None,
            caches=cache, cache_index=(pos.reshape(-1) if per_row else pos),
            axes=axes, mesh=mesh, pool_idx=pool_idx,
        )
        outs = self._head_stats(params, h, pooled, active_sites,
                                exit_thresholds=exit_thresholds)
        return ncaches, outs

    def loss(self, params, batch, *, axes=LY.TEST_AXES, mesh=None, **kw):
        cfg = self.cfg
        frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
        B, S = tokens.shape
        memory = self.encode(params, frames, axes=axes, mesh=mesh)
        positions = jnp.arange(S)[None, :]
        h = LY.embed_apply(cfg, params["tok"], tokens, positions)
        mask = LY.causal_mask(S, S, 0)
        npos = min(16, S)
        pool_idx = jnp.linspace(max(S // npos - 1, 0), S - 1, npos).astype(jnp.int32)
        h, pooled, _ = self._dec_stack(
            params, h, positions=positions, mask=mask, memory=memory,
            caches=None, cache_index=None, axes=axes, mesh=mesh, pool_idx=pool_idx,
        )
        from repro.models.transformer import _masked_ce

        h = LY.apply_norm(cfg, params["final_norm"], h)
        logits = LY.unembed(cfg, params["tok"], h)
        lm = _masked_ce(cfg, logits, labels)
        rl = self._ramp_logits(params, pooled, None)
        R = rl.shape[0]
        rlab = jnp.take(labels, pool_idx, axis=1)
        rloss = _masked_ce(cfg, rl.reshape(R * B, npos, -1), jnp.tile(rlab, (R, 1)))
        return lm + rloss, {"lm_loss": lm, "ramp_loss": rloss}

    def _ramp_logits(self, params, pooled, site_idx):
        if site_idx is None:
            site_idx = jnp.arange(len(self.sites), dtype=jnp.int32)
        hs = jax.lax.stop_gradient(jnp.take(pooled, site_idx, axis=0))
        hs = hs[:, :, 0] if hs.ndim == 5 else hs  # scan pooled has extra dim
        nw = jnp.take(params["ramps"]["norm_w"], site_idx, axis=0)
        hw = jnp.take(params["ramps"]["head"], site_idx, axis=0)
        hs = LY.rms_norm(hs, nw[:, None, None, :])
        return jnp.einsum("kbnd,kdv->kbnv", hs, hw).astype(jnp.float32)

    def _head_stats(self, params, h_last, pooled, active_sites,
                    exit_thresholds=None):
        from repro.models.transformer import _mask_pad_vocab, _stats

        cfg = self.cfg
        h = LY.apply_norm(cfg, params["final_norm"], h_last)
        logits = LY.unembed(cfg, params["tok"], h)[:, 0].astype(jnp.float32)
        outs = {"final": _stats(_mask_pad_vocab(cfg, logits))}
        if active_sites is not None:
            rl = self._ramp_logits(params, pooled, jnp.asarray(active_sites, jnp.int32))
            outs["ramps"] = _stats(_mask_pad_vocab(cfg, rl[:, :, 0]))
            if exit_thresholds is not None:
                thr = jnp.asarray(exit_thresholds, jnp.float32)
                unc = 1.0 - outs["ramps"]["maxprob"].astype(jnp.float32)
                outs["ramps"]["exit"] = (unc < thr[:, None]).astype(jnp.int32)
        return outs


class EncoderClassifier:
    """BERT-style encoder + CLS classifier; ramps = CLS-pool + FC per block."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.sites = tuple(range(cfg.n_layers - 1))

    def schema(self) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        S = len(self.sites)
        return {
            "tok": LY.embed_schema(cfg),
            "enc": _enc_layer_schema(cfg, cfg.n_layers),
            "final_norm": LY.norm_schema(cfg),
            "cls": ParamInfo((cfg.d_model, cfg.n_classes), jnp.float32, P(), "normal:0.02"),
            "ramps": {
                "norm_w": ParamInfo((S, cfg.d_model), jnp.float32, P(), "zeros"),
                "head": ParamInfo((S, cfg.d_model, cfg.n_classes), jnp.float32, P(), "normal:0.02"),
            },
        }

    def init(self, key):
        return init_from_schema(self.schema(), key)

    def pspecs(self, axes: MeshAxes):
        return specs_from_schema(LY.resolve_schema(self.schema(), axes))

    def forward(self, params, tokens, *, axes=LY.TEST_AXES, mesh=None,
                active_sites=None):
        """tokens: (B,S). Returns {'final': stats, 'ramps': stats} over
        n_classes logits (CLS position pooling, paper §3.1 BERT recipe)."""
        cfg = self.cfg
        B, S = tokens.shape
        positions = jnp.arange(S)[None, :]
        h = LY.embed_apply(cfg, params["tok"], tokens, positions)

        def body(hh, p):
            x = LY.apply_norm(cfg, p["ln1"], hh)
            out, _ = LY.attn_apply(
                cfg, p["attn"], x, positions=positions, mask=None, axes=axes, mesh=mesh
            )
            hh = hh + out
            x = LY.apply_norm(cfg, p["ln2"], hh)
            hh = hh + LY.ffn_apply(cfg, p["ffn"], x, axes, mesh)
            return hh, hh[:, 0]  # CLS pool

        h, cls_stack = jax.lax.scan(body, h, params["enc"], unroll=True if cfg.scan_unroll else 1)
        from repro.models.transformer import _stats

        hf = LY.apply_norm(cfg, params["final_norm"], h[:, 0:1])[:, 0]
        logits = (hf.astype(jnp.float32) @ params["cls"]).astype(jnp.float32)
        outs = {"final": _stats(logits), "final_logits": logits}
        if active_sites is not None:
            si = jnp.asarray(active_sites, jnp.int32)
            hs = jnp.take(cls_stack, si, axis=0)  # (K,B,d)
            nw = jnp.take(params["ramps"]["norm_w"], si, axis=0)
            hw = jnp.take(params["ramps"]["head"], si, axis=0)
            hs = LY.rms_norm(hs, nw[:, None, :])
            rl = jnp.einsum("kbd,kdc->kbc", hs.astype(jnp.float32), hw)
            outs["ramps"] = _stats(rl)
            outs["ramp_logits"] = rl
        return outs

    def loss(self, params, batch, *, axes=LY.TEST_AXES, mesh=None, **kw):
        """Classification CE + per-ramp CE (stop-grad features)."""
        tokens, labels = batch["tokens"], batch["labels"]
        outs = self.forward(
            params, tokens, axes=axes, mesh=mesh,
            active_sites=list(range(len(self.sites))),
        )
        lf = outs["final_logits"]
        ce = -jnp.mean(
            jnp.take_along_axis(jax.nn.log_softmax(lf), labels[:, None], 1)
        )
        rl = jax.lax.stop_gradient(0.0) + outs["ramp_logits"]
        rce = -jnp.mean(
            jnp.take_along_axis(
                jax.nn.log_softmax(rl, -1), labels[None, :, None], 2
            )
        )
        return ce + rce, {"cls_loss": ce, "ramp_loss": rce}
