"""Model construction dispatch."""
from __future__ import annotations


def build_model(cfg):
    if cfg.family == "lm":
        from repro.models.transformer import LM

        return LM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg)
    if cfg.family == "encoder_cls":
        from repro.models.encdec import EncoderClassifier

        return EncoderClassifier(cfg)
    if cfg.family == "resnet":
        from repro.models.resnet import ResNet

        return ResNet(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
