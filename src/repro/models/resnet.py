"""ResNet — the paper's CV family (BranchyNet comparison base).

Residual blocks are the cut vertices; ramps = global-avg-pool + FC (the
paper's default CV ramp, §3.1). GroupNorm replaces BatchNorm (no running
stats — keeps training purely functional; noted in DESIGN.md).
"""
from __future__ import annotations

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamInfo, init_from_schema, specs_from_schema


def _conv_info(cin, cout, k):
    scale = 1.0 / math.sqrt(cin * k * k)
    return ParamInfo((k, k, cin, cout), jnp.float32, P(), f"normal:{scale}")


def _gn_info(c):
    return {
        "w": ParamInfo((c,), jnp.float32, P(), "ones"),
        "b": ParamInfo((c,), jnp.float32, P(), "zeros"),
    }


def conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def group_norm(x, p, groups=8):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xn = ((xg - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, H, W, C)
    return xn * p["w"] + p["b"]


class ResNet:
    """cfg.resnet_blocks: blocks per stage; widths per stage; stride 2 between
    stages. n_layers == total residual blocks == ramp-feasible sites."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.block_widths: List[int] = []
        for stage, (n, w) in enumerate(zip(cfg.resnet_blocks, cfg.resnet_widths)):
            for b in range(n):
                self.block_widths.append(w * (4 if cfg.resnet_bottleneck else 1))
        self.sites = tuple(range(len(self.block_widths) - 1))

    def schema(self) -> dict:
        cfg = self.cfg
        w0 = cfg.resnet_widths[0]
        sch = {
            "stem": {"conv": _conv_info(3, w0, 3), "gn": _gn_info(w0)},
            "blocks": [],
        }
        cin = w0
        for stage, (n, w) in enumerate(zip(cfg.resnet_blocks, cfg.resnet_widths)):
            wout = w * (4 if cfg.resnet_bottleneck else 1)
            for b in range(n):
                blk = {}
                if cfg.resnet_bottleneck:
                    blk["c1"] = _conv_info(cin, w, 1)
                    blk["g1"] = _gn_info(w)
                    blk["c2"] = _conv_info(w, w, 3)
                    blk["g2"] = _gn_info(w)
                    blk["c3"] = _conv_info(w, wout, 1)
                    blk["g3"] = _gn_info(wout)
                else:
                    blk["c1"] = _conv_info(cin, w, 3)
                    blk["g1"] = _gn_info(w)
                    blk["c2"] = _conv_info(w, wout, 3)
                    blk["g2"] = _gn_info(wout)
                if cin != wout or (b == 0 and stage > 0):
                    blk["proj"] = _conv_info(cin, wout, 1)
                sch["blocks"].append(blk)
                cin = wout
        sch["fc"] = ParamInfo((cin, cfg.n_classes), jnp.float32, P(), "normal:0.02")
        sch["ramps"] = {
            "head": [
                ParamInfo((bw, cfg.n_classes), jnp.float32, P(), "normal:0.02")
                for bw in self.block_widths[:-1]
            ]
        }
        return sch

    def init(self, key):
        return init_from_schema(self.schema(), key)

    def pspecs(self, axes=None):
        return specs_from_schema(self.schema())

    def forward(self, params, images, *, active_sites=None, axes=None, mesh=None):
        """images: (B,H,W,3) f32. Returns {'final': stats, 'ramps': stats}."""
        cfg = self.cfg
        x = jax.nn.relu(group_norm(conv(images, params["stem"]["conv"]), params["stem"]["gn"]))
        pooled: List = []
        i = 0
        for stage, (n, w) in enumerate(zip(cfg.resnet_blocks, cfg.resnet_widths)):
            for b in range(n):
                blk = params["blocks"][i]
                stride = 2 if (b == 0 and stage > 0) else 1
                if cfg.resnet_bottleneck:
                    h = jax.nn.relu(group_norm(conv(x, blk["c1"]), blk["g1"]))
                    h = jax.nn.relu(group_norm(conv(h, blk["c2"], stride), blk["g2"]))
                    h = group_norm(conv(h, blk["c3"]), blk["g3"])
                else:
                    h = jax.nn.relu(group_norm(conv(x, blk["c1"], stride), blk["g1"]))
                    h = group_norm(conv(h, blk["c2"]), blk["g2"])
                sc = x
                if "proj" in blk:
                    sc = conv(x, blk["proj"], stride)
                elif stride != 1:
                    sc = conv(x, jnp.eye(x.shape[-1])[None, None], stride)
                x = jax.nn.relu(h + sc)
                pooled.append(jnp.mean(x, axis=(1, 2)))  # GAP (paper's CV pooling)
                i += 1
        from repro.models.transformer import _stats

        feats = pooled[-1]
        logits = (feats.astype(jnp.float32) @ params["fc"]).astype(jnp.float32)
        outs = {"final": _stats(logits), "final_logits": logits}
        if active_sites is not None:
            rls = []
            for s in active_sites:
                s = int(s)
                rls.append(pooled[s].astype(jnp.float32) @ params["ramps"]["head"][s])
            rl = jnp.stack(rls) if rls else jnp.zeros((0, images.shape[0], cfg.n_classes))
            outs["ramps"] = _stats(rl)
            outs["ramp_logits"] = rl
        return outs

    def loss(self, params, batch, **kw):
        images, labels = batch["images"], batch["labels"]
        outs = self.forward(params, images, active_sites=list(self.sites))
        lf = outs["final_logits"]
        ce = -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(lf), labels[:, None], 1))
        rl = outs["ramp_logits"]
        # stop-grad on features is implicit: ramp heads see `pooled` values
        # which also receive backbone grads; freeze via optimizer masking in
        # ramp-only training (training/ramp_training.py)
        rce = -jnp.mean(
            jnp.take_along_axis(jax.nn.log_softmax(rl, -1), labels[None, :, None], 2)
        )
        return ce + rce, {"cls_loss": ce, "ramp_loss": rce}
