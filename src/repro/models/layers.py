"""Shared neural-net building blocks (functional, schema-driven).

Conventions:
  * params are nested dicts of arrays; schemas are the same trees of
    ``ParamInfo`` (see common.py).
  * compute happens in ``cfg.dtype`` with float32 softmax / norms.
  * ``axes`` (MeshAxes) carries the mesh axis names used in PartitionSpecs,
    so the same model code serves single-pod, multi-pod, and 1-device test
    meshes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamInfo


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Mesh axis naming + sharding policy.

    data: axis (or tuple of axes) for batch / FSDP sharding.
    model: axis for tensor/expert parallelism.
    fsdp: if True, parameters are additionally sharded over `data`
          (training); if False they are sharded over `model` only (serving).
    """

    data: Tuple[str, ...] = ("data",)
    model: Optional[str] = "model"
    fsdp: bool = True

    @property
    def d(self):  # data spec entry
        return self.data if len(self.data) > 1 else self.data[0]

    def wspec(self, *entries) -> P:
        """Weight spec: replace 'data' by the data axes iff fsdp, 'model' by
        the model axis (or None when the mesh has no model axis)."""
        out = []
        for e in entries:
            if e == "data":
                out.append(self.d if self.fsdp else None)
            elif e == "model":
                out.append(self.model)
            else:
                out.append(e)
        return P(*out)

    def aspec(self, *entries) -> P:
        """Activation spec: 'data' always maps to the data axes."""
        out = []
        for e in entries:
            if e == "data":
                out.append(self.d)
            elif e == "model":
                out.append(self.model)
            else:
                out.append(e)
        return P(*out)


TEST_AXES = MeshAxes(data=("data",), model="model", fsdp=False)


def constrain(x, spec: P, mesh):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec)
    )


# ---------------------------------------------------------------------------
# norms / activations


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm_schema(cfg, L=None) -> dict:
    d = cfg.d_model
    shp = (d,) if L is None else (L, d)
    if cfg.norm_type == "ln":
        return {
            "w": ParamInfo(shp, jnp.float32, P(), "ones"),
            "b": ParamInfo(shp, jnp.float32, P(), "zeros"),
        }
    return {"w": ParamInfo(shp, jnp.float32, P(), "zeros")}


def apply_norm(cfg, p, x):
    if cfg.norm_type == "ln":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"])


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# rotary embeddings


def rope_sincos(positions, dim: int, theta: float):
    """positions: int32[...]. Returns (sin, cos) of shape positions.shape+(dim/2,)."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: (..., S, n, dim) ; sin/cos: (..., S, dim/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s, c = sin[..., None, :], cos[..., None, :]
    # broadcast: x is (..., S, n, half); sin is (..., S, half) -> (..., S, 1, half)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# dense FFN (SwiGLU / MLP)


def ffn_schema(cfg, d_ff: int, L=None, dtype=None) -> dict:
    d = cfg.d_model
    dt = dtype or jnp.dtype(cfg.dtype)
    pre = () if L is None else (L,)
    pfx = (None,) * len(pre)
    sc = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "w_gate": ParamInfo(pre + (d, d_ff), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "w_up": ParamInfo(pre + (d, d_ff), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "w_down": ParamInfo(pre + (d_ff, d), dt, P(*pfx, "model", "data"), f"normal:{sc}"),
    }


def ffn_apply(cfg, p, x, axes: MeshAxes, mesh=None):
    a = act_fn(cfg.act)
    h = a(x @ p["w_gate"]) * (x @ p["w_up"])
    h = constrain(h, axes.aspec("data", None, "model"), mesh)
    return h @ p["w_down"]


def ffn_apply_tp(cfg, p, x, gather):
    """Tensor-parallel FFN over column-sliced params, bit-identical to
    `ffn_apply` on the full weights. `p` holds this device's column slice
    of ``w_gate``/``w_up`` (d, d_ff/m) and of ``w_down`` along its OUTPUT
    dim (d_ff, d/m); ``gather(y)`` concatenates the device slices along
    the last axis (a tiled ``all_gather`` over the model axis on a real
    mesh; plain tiling under the abstract probe). Each output column of a
    matmul is computed independently, so the column-slice-then-gather
    composition reproduces the dense result bitwise — unlike the Megatron
    row-split + psum decomposition, which reassociates the contraction."""
    a = act_fn(cfg.act)
    h = gather(a(x @ p["w_gate"]) * (x @ p["w_up"]))
    return gather(h @ p["w_down"])


# ---------------------------------------------------------------------------
# attention


def _resolve_spec(info: ParamInfo, axes: MeshAxes) -> ParamInfo:
    """Rewrite placeholder axis names 'data'/'model' in a spec via axes."""
    return dataclasses.replace(info, spec=axes.wspec(*info.spec))


def resolve_schema(schema, axes: MeshAxes):
    from repro.models.common import is_info

    return jax.tree.map(lambda i: _resolve_spec(i, axes), schema, is_leaf=is_info)


def gqa_schema(cfg, L=None) -> dict:
    """Standard GQA attention params. Specs use placeholder names resolved
    later against MeshAxes."""
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    pre = () if L is None else (L,)
    pfx = (None,) * len(pre)
    sc = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    sch = {
        "wq": ParamInfo(pre + (d, H * hd), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "wk": ParamInfo(pre + (d, K * hd), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "wv": ParamInfo(pre + (d, K * hd), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "wo": ParamInfo(pre + (H * hd, d), dt, P(*pfx, "model", "data"), f"normal:{sc}"),
    }
    if cfg.qkv_bias:
        sch["bq"] = ParamInfo(pre + (H * hd,), dt, P(*pfx, "model"), "zeros")
        sch["bk"] = ParamInfo(pre + (K * hd,), dt, P(*pfx, "model"), "zeros")
        sch["bv"] = ParamInfo(pre + (K * hd,), dt, P(*pfx, "model"), "zeros")
    if cfg.qk_norm:
        sch["qnorm"] = ParamInfo(pre + (hd,), jnp.float32, P(), "zeros")
        sch["knorm"] = ParamInfo(pre + (hd,), jnp.float32, P(), "zeros")
    return sch


def sdpa(q, k, v, mask, scale=None):
    """q: (B,Sq,H,hd) k,v: (B,Sk,K,hd); GQA expansion; f32 softmax.
    mask: broadcastable to (B, H, Sq, Sk) (bool, True = attend)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K if K else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qh = q.reshape(B, Sq, K, G, hd) if K else q
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qh, k).astype(jnp.float32) * scale
    if mask is not None:
        m = mask if mask.ndim == 4 else mask[:, None]
        m = m.reshape(B, K, G, Sq, -1) if m.shape[1] == H else m[:, :, None]
        logits = jnp.where(m, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])  # v head dim may differ (MLA)


def causal_mask(Sq: int, Sk: int, q_offset) -> jnp.ndarray:
    """(1, 1, Sq, Sk) True where key position <= query position."""
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    return (kpos <= qpos)[None, None]


def window_mask(Sq: int, Sk: int, q_offset, window: int) -> jnp.ndarray:
    qpos = q_offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    return ((kpos <= qpos) & (kpos > qpos - window))[None, None]


def _update_cache_rows(cache_leaf, new, idx):
    """Write `new` (B, S_new, ...) into `cache_leaf` (B, S, ...) at
    sequence offset `idx` — a shared scalar (all rows at the same decode
    position) or an int32[B] of per-row positions (batched slot caches:
    continuous batching leaves every slot at its own position, so each row
    scatters independently)."""
    new = new.astype(cache_leaf.dtype)
    if jnp.ndim(idx) == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache_leaf, new, idx, axis=1)

    def _row(c, x, i):
        return jax.lax.dynamic_update_slice(c, x, (i,) + (0,) * (c.ndim - 1))

    return jax.vmap(_row)(cache_leaf, new, jnp.asarray(idx, jnp.int32))


def attn_apply(
    cfg,
    p,
    x,
    *,
    positions,
    mask,
    axes: MeshAxes,
    mesh=None,
    cache=None,
    cache_index=None,
    rope_theta=None,
    ring_window=None,
    local_window=None,
    decode_impl: str = "dense",
    block_table=None,
    out_proj: bool = True,
):
    """GQA attention. If `cache` (dict k,v: (B, S, K, hd)) is given, new k/v
    are written at `cache_index` (scalar or per-row int32[B]) and attention
    runs against the cache. `ring_window=W` stores only the last W tokens
    (slot = pos % W): the windowed-cache optimization for local-attention
    layers — the caller passes `cache_index = pos % W` at decode and a ring
    mask. `local_window=W` marks a local layer decoding against a FULL
    cache: the window rows are gathered chronologically and attention runs
    over exactly W columns — the same reduction the ring paths compute, so
    ring/full/paged local decode stay bit-identical (a full-length masked
    softmax reduces over a different column count and drifts by ULPs).
    `decode_impl` selects the single-token cache-attention path:
    'dense' (masked sdpa) or the flash-decode wrapper
    (`kernels/decode_attention.attend_decode`) as 'ref' | 'kernel' |
    'interpret' — only meaningful for non-ring decode steps where the write
    index equals the token position.

    With `block_table` (int32[B, nb]), `cache` is a PAGED block pool
    (k/v: (P, bs, K, hd)): the single decode token scatters to pool slot
    ``(block_table[b, pos // bs], pos % bs)`` and attention walks the
    block table (`kernels/decode_attention.attend_decode_paged`;
    `decode_impl` must be 'paged' | 'paged-kernel' | 'paged-interpret').
    `out_proj=False` returns the concatenated head outputs (B, S, H*hd)
    WITHOUT the final `@ wo` projection — the tensor-parallel decode path
    computes per-device head slices and applies a column-sharded `wo`
    after the all-gather, so the projection must stay outside.
    Ring layers (`ring_window=W`) page too: `cache_index` is then the
    TRUE position, the write slot is ``pos % W`` redirected through the
    same table (touching only its first ``ceil(W/bs)`` entries), and
    attention gathers exactly W virtual rows under the ring mask — the
    shapes match the contiguous ring cache, so the two paths agree
    bit-for-bit. Returns (out, new_cache)."""
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"])
        k = rms_norm(k, p["knorm"])
    if cfg.pos_type == "rope":
        theta = rope_theta if rope_theta is not None else cfg.rope_theta
        sin, cos = rope_sincos(positions, hd, theta)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    new_cache = None
    if block_table is not None:
        if cache is None or S != 1:
            raise ValueError("paged attention is a single-token decode path "
                             "over a block pool")
        if not decode_impl.startswith("paged"):
            raise ValueError(f"block_table given but decode_impl={decode_impl!r}")
        from repro.kernels.decode_attention import attend_decode_paged

        bsz = cache["k"].shape[1]
        idx = jnp.asarray(cache_index, jnp.int32).reshape(-1)
        tab = jnp.asarray(block_table, jnp.int32)
        if ring_window is not None:
            # paged ring: redirect the ring slot pos % W through the table
            # (those virtual rows sit in table entries already claimed for
            # earlier positions), gather the W live rows, and apply the
            # same ring mask the contiguous path uses — identical shapes,
            # identical masked sdpa, bit-identical output.
            W = ring_window
            ri = idx % W
            blk = jnp.take_along_axis(tab, (ri // bsz)[:, None], axis=1)[:, 0]
            ck = cache["k"].at[blk, ri % bsz].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[blk, ri % bsz].set(v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            nbw = -(-W // bsz)
            wtab = tab[:, :nbw]
            # gather the W live rows in CHRONOLOGICAL order (positions
            # pos-W+1..pos through slot = tpos % W), not ring-slot order:
            # the softmax then sums the window in the same order as the
            # full-cache dense path, keeping the two paths bit-identical
            # past the first wraparound (rotated sums differ in ULPs).
            tpos = idx[:, None] - (W - 1) + jnp.arange(W)[None, :]  # (B, W)
            slot = tpos % W
            sblk = jnp.take_along_axis(wtab, slot // bsz, axis=1)
            gk = ck[sblk, slot % bsz]  # (B, W, K, hd)
            gv = cv[sblk, slot % bsz]
            # pre-wrap positions alias future slots holding zeros; mask
            # them to exact-zero probs
            rmask = (tpos >= 0)[:, None, None, :]
            q = constrain(q, axes.aspec("data", None, "model", None), mesh)
            out = sdpa(q, gk, gv, rmask)
            out = out.reshape(B, S, H * hd)
            if not out_proj:
                return out, new_cache
            return out @ p["wo"], new_cache
        blk = jnp.take_along_axis(tab, (idx // bsz)[:, None], axis=1)[:, 0]
        # per-row scatter by (block id, in-block offset) instead of flat pos;
        # duplicate rows (bucket padding) write identical values, so the
        # scatter stays deterministic without unique_indices
        ck = cache["k"].at[blk, idx % bsz].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[blk, idx % bsz].set(v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        q = constrain(q, axes.aspec("data", None, "model", None), mesh)
        out = attend_decode_paged(
            q[:, 0], ck, cv, jnp.asarray(block_table, jnp.int32), idx,
            use_kernel=decode_impl in ("paged-kernel", "paged-interpret"),
            interpret=decode_impl == "paged-interpret",
        )[:, None]
        out = out.reshape(B, S, H * hd)
        if not out_proj:
            return out, new_cache
        return out @ p["wo"], new_cache
    if cache is not None:
        if ring_window is not None and S > 1:
            # prefill into a ring: slot j holds the newest token t ≡ j (mod W)
            W = ring_window
            j = jnp.arange(W)
            t = (S - 1) - ((S - 1 - j) % W)
            rk = jnp.take(k, jnp.clip(t, 0), axis=1).astype(cache["k"].dtype)
            rv = jnp.take(v, jnp.clip(t, 0), axis=1).astype(cache["v"].dtype)
            new_cache = {"k": rk, "v": rv}
            # attention runs against the full in-flight k/v (window-masked)
        else:
            ck = _update_cache_rows(cache["k"], k, cache_index)
            cv = _update_cache_rows(cache["v"], v, cache_index)
            new_cache = {"k": ck, "v": cv}
            if local_window is not None and S > 1:
                # local prefill on a full cache: attend the in-flight
                # (S-long) k/v exactly as the ring prefill does — both
                # paths then reduce over S columns instead of one of them
                # reducing over the zero-padded cache_len, which drifts
                # by ULPs once S is large enough to regroup the sum.
                pass
            else:
                k, v = ck, cv
            W = ring_window if ring_window is not None else local_window
            if W is not None and S == 1:
                # local-window decode: gather the W window rows in
                # CHRONOLOGICAL order (positions pos-W+1..pos; ring caches
                # unrotate via slot = tpos % W, full caches index tpos
                # directly) and attend over exactly W columns. Every local
                # decode variant — ring, full, paged — then runs the SAME
                # W-length reduction in the same order, so they agree
                # bit-for-bit; a full-length masked softmax would reduce
                # over a different column count and drift by ULPs.
                pos_r = jnp.asarray(positions, jnp.int32).reshape(-1)
                tpos = pos_r[:, None] - (W - 1) + jnp.arange(W)[None, :]
                slot = (tpos % W) if ring_window is not None else jnp.clip(tpos, 0)
                if slot.shape[0] == 1 and B > 1:
                    slot = jnp.broadcast_to(slot, (B, W))
                k = jnp.take_along_axis(ck, slot[:, :, None, None], axis=1)
                v = jnp.take_along_axis(cv, slot[:, :, None, None], axis=1)
                # pre-window columns (tpos < 0) gather arbitrary live rows;
                # mask them to exact-zero probs
                mask = (tpos >= 0)[:, None, None, :]
    q = constrain(q, axes.aspec("data", None, "model", None), mesh)
    if (
        decode_impl != "dense"
        and cache is not None
        and ring_window is None
        and local_window is None
        and S == 1
    ):
        # flash-decode fast path: one single-token query against the full
        # cache, masked by position (== the write index for non-ring
        # caches; scalar or per-row). Avoids materializing the dense
        # (B, H, 1, S) mask/score tensors of the sdpa path.
        from repro.kernels.decode_attention import attend_decode

        out = attend_decode(
            q[:, 0],
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            jnp.asarray(cache_index, jnp.int32),
            use_kernel=decode_impl in ("kernel", "interpret"),
            interpret=decode_impl == "interpret",
        )[:, None]
    else:
        out = sdpa(q, k, v, mask)
    out = out.reshape(B, S, H * hd)
    if not out_proj:
        return out, new_cache
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)


def mla_schema(cfg, L=None) -> dict:
    d, H = cfg.d_model, cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = jnp.dtype(cfg.dtype)
    pre = () if L is None else (L,)
    pfx = (None,) * len(pre)
    sc = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "wq": ParamInfo(pre + (d, H * (dn + dr)), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "w_dkv": ParamInfo(pre + (d, r + dr), dt, P(*pfx, "data", None), "normal:0.02"),
        "kv_norm": ParamInfo(pre + (r,), jnp.float32, P(), "zeros"),
        "w_uk": ParamInfo(pre + (r, H * dn), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "w_uv": ParamInfo(pre + (r, H * dv), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "wo": ParamInfo(pre + (H * dv, d), dt, P(*pfx, "model", "data"), f"normal:{sc}"),
    }


def mla_apply(
    cfg,
    p,
    x,
    *,
    positions,
    mask,
    axes: MeshAxes,
    mesh=None,
    cache=None,
    cache_index=None,
    absorbed: bool = False,
    decode_impl: str = "dense",
    block_table=None,
):
    """MLA attention. Cache holds the compressed kv latent (B,S,r) and the
    shared rope key (B,S,dr). `absorbed=True` uses the latent-space decode
    path (beyond-paper perf optimization; math-equivalent).

    With `block_table` (int32[B, nb]) the cache is a PAGED pool over the
    latent streams (`c: (P, bs, r)`, `k_pe: (P, bs, dr)`) and
    `cache_index` is the per-row TRUE position: the decode token's latents
    scatter to ``(table[b, pos // bs], pos % bs)``. The jnp oracle gathers
    the table back to a virtually-contiguous stream and reuses the exact
    contiguous math; with `absorbed` and `decode_impl` in
    ('paged-kernel', 'paged-interpret') the gather+softmax runs inside the
    scalar-prefetch Pallas block walk
    (`kernels/decode_attention.attend_decode_paged_mla`) instead — the
    latent cache is MQA-like (one stream shared by all H heads), so the
    kernel never materializes per-head keys."""
    B, S, d = x.shape
    H = cfg.n_heads
    r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = (x @ p["wq"]).reshape(B, S, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    ckv = x @ p["w_dkv"]  # (B,S,r+dr)
    c, k_pe = ckv[..., :r], ckv[..., r:]
    c = rms_norm(c, p["kv_norm"])
    sin, cos = rope_sincos(positions, dr, cfg.rope_theta)
    q_pe = apply_rope(q_pe, sin, cos)
    k_pe = apply_rope(k_pe[:, :, None, :], sin, cos)[:, :, 0]  # single shared head
    new_cache = None
    scale = 1.0 / math.sqrt(dn + dr)
    if block_table is not None:
        if cache is None or S != 1:
            raise ValueError("paged MLA is a single-token decode path over "
                             "a latent block pool")
        if not str(decode_impl).startswith("paged"):
            raise ValueError(f"block_table given but decode_impl={decode_impl!r}")
        bsz = cache["c"].shape[1]
        idx = jnp.asarray(cache_index, jnp.int32).reshape(-1)
        tab = jnp.asarray(block_table, jnp.int32)
        blk = jnp.take_along_axis(tab, (idx // bsz)[:, None], axis=1)[:, 0]
        cc = cache["c"].at[blk, idx % bsz].set(c[:, 0].astype(cache["c"].dtype))
        cp = cache["k_pe"].at[blk, idx % bsz].set(
            k_pe[:, 0].astype(cache["k_pe"].dtype)
        )
        new_cache = {"c": cc, "k_pe": cp}
        if absorbed and decode_impl in ("paged-kernel", "paged-interpret"):
            from repro.kernels.decode_attention import attend_decode_paged_mla

            wuk = p["w_uk"].reshape(r, H, dn)
            q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wuk)[:, 0]  # (B,H,r)
            ctx = attend_decode_paged_mla(
                q_lat, q_pe[:, 0], cc, cp, tab, idx, scale=scale,
                interpret=decode_impl == "paged-interpret",
            )  # (B,H,r)
            wuv = p["w_uv"].reshape(r, H, dv)
            out = jnp.einsum("bhr,rhv->bhv", ctx, wuv)[:, None]
            out = out.reshape(B, S, H * dv)
            return out @ p["wo"], new_cache
        # jnp oracle (and the unabsorbed paged path): gather the table back
        # to a virtually-contiguous latent stream, mask kpos <= pos, and
        # fall through to the exact contiguous math below
        nb = tab.shape[1]
        c = cc[tab].reshape(B, nb * bsz, r)
        k_pe = cp[tab].reshape(B, nb * bsz, dr)
        kpos = jnp.arange(nb * bsz)[None, :]
        mask = (kpos <= idx[:, None])[:, None, None, :]
    elif cache is not None:
        cc = _update_cache_rows(cache["c"], c, cache_index)
        cp = _update_cache_rows(cache["k_pe"], k_pe, cache_index)
        new_cache = {"c": cc, "k_pe": cp}
        c, k_pe = cc, cp
    Sk = c.shape[1]
    if absorbed:
        # q_nope' = q_nope @ w_uk^T  -> score against latent directly
        wuk = p["w_uk"].reshape(r, H, dn)
        q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wuk)
        s_nope = jnp.einsum("bqhr,bsr->bhqs", q_lat, c)
        s_pe = jnp.einsum("bqhn,bsn->bhqs", q_pe, k_pe)
        logits = (s_nope + s_pe).astype(jnp.float32) * scale
        if mask is not None:
            logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(c.dtype)
        ctx = jnp.einsum("bhqs,bsr->bqhr", probs, c)
        wuv = p["w_uv"].reshape(r, H, dv)
        out = jnp.einsum("bqhr,rhv->bqhv", ctx, wuv)
    else:
        k_nope = jnp.einsum("bsr,rx->bsx", c, p["w_uk"]).reshape(B, Sk, H, dn)
        v = jnp.einsum("bsr,rx->bsx", c, p["w_uv"]).reshape(B, Sk, H, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe[:, :, None], (B, Sk, H, dr))], axis=-1)
        qq = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = sdpa(qq, k, v, mask, scale=scale)
    out = out.reshape(B, S, H * dv)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# cross-attention (VLM image layers / enc-dec decoder)


def cross_attn_schema(cfg, L=None, d_kv_in: Optional[int] = None) -> dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dk = d_kv_in or d
    dt = jnp.dtype(cfg.dtype)
    pre = () if L is None else (L,)
    pfx = (None,) * len(pre)
    sc = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "wq": ParamInfo(pre + (d, H * hd), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "wk": ParamInfo(pre + (dk, K * hd), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "wv": ParamInfo(pre + (dk, K * hd), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "wo": ParamInfo(pre + (H * hd, d), dt, P(*pfx, "model", "data"), f"normal:{sc}"),
        "gate": ParamInfo(pre + (), jnp.float32, P(*pfx), "zeros"),
    }


def cross_attn_apply(cfg, p, x, memory=None, kv_cache=None, *, axes, mesh=None):
    """x: (B,S,d); memory: (B,M,dk) or precomputed kv_cache {k,v}: (B,M,K,hd).
    Gated (tanh) residual as in Llama-vision. Returns (out, kv)."""
    B, S, _ = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    if kv_cache is not None:
        k, v = kv_cache["k"], kv_cache["v"]
    else:
        M = memory.shape[1]
        k = (memory @ p["wk"]).reshape(B, M, K, hd)
        v = (memory @ p["wv"]).reshape(B, M, K, hd)
    out = sdpa(q, k, v, mask=None)
    out = out.reshape(B, S, H * hd) @ p["wo"]
    out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return out, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# embedding / unembedding


def embed_schema(cfg) -> dict:
    # vocab-parallel (Megatron): vocab over `model` so the unembed's partial
    # sums stay weight-sized; `data` FSDP on the d dim.
    Vp, d = cfg.padded_vocab, cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    sch = {"embed": ParamInfo((Vp, d), dt, P("model", "data"), "embed:0.02")}
    if cfg.pos_type == "learned":
        sch["pos_embed"] = ParamInfo((cfg.max_position, d), dt, P(None, "model"), "embed:0.02")
    if not cfg.tie_embeddings:
        sch["lm_head"] = ParamInfo((d, Vp), dt, P("data", "model"), "normal:0.02")
    return sch


def embed_apply(cfg, p, tokens, positions=None):
    h = p["embed"][tokens]
    if cfg.pos_type == "learned":
        h = h + p["pos_embed"][positions]
    return h


def unembed(cfg, p, h):
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    return h @ w
