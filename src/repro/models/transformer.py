"""Decoder-only LM covering all assigned transformer/SSM/hybrid archs.

The layer stack is described by a *plan*: an optional unrolled prefix, a
scanned period of heterogeneous slots, and an unrolled suffix. Parameters
for scanned slots carry a leading ``n_periods`` dim; everything inside one
period is unrolled in the scan body. This keeps HLO small (compile time ~
period size, not n_layers) while supporting interleave patterns
(gemma3 5:1 local:global, jamba 1 attn : 7 mamba, llama-vision cross-attn
every 5th layer, deepseek-v2 leading dense layer).

Early-exit ramps (the paper's technique) attach at block boundaries (cut
vertices): pooled hidden -> per-ramp RMSNorm -> per-ramp LM-head. All ramp
weights exist at every feasible site; serving gathers a dynamic
``active_sites`` subset so the active-ramp set changes with **zero
recompiles** (beyond-paper, TPU-native — see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import mesh_axis_size, shard_map
from repro.models import layers as LY
from repro.models import mamba as MB
from repro.models import moe as MOE
from repro.models.common import (
    ParamInfo,
    abstract_from_schema,
    init_from_schema,
    is_info,
    specs_from_schema,
)
from repro.models.layers import MeshAxes


def _tp_gather(axis_name, y):
    """Concatenate the per-device column slices of ``y`` along its last
    axis (device-order = column-order, so the result is the dense array)."""
    return jax.lax.all_gather(y, axis_name, axis=y.ndim - 1, tiled=True)


@dataclasses.dataclass(frozen=True)
class TpCtx:
    """Tensor-parallel context threaded through ``_block``/``decode`` when
    they run INSIDE a shard_map body (``decode_sharded``).

    The decomposition is the exactness-preserving one: activations stay
    replicated at sublayer boundaries; wq/wk/wv (and w_gate/w_up) are
    COLUMN-sliced so each device computes a contiguous head (hidden) block
    bitwise-identically to the corresponding slice of the dense matmul;
    wo/w_down are column-sliced along their OUTPUT dim so the final
    projections are also column slices of the dense result. Combines are
    tiled ``all_gather``s — pure concatenation, no arithmetic — so the
    whole block is bit-identical to single-device decode. (A Megatron
    row-split + psum combine reassociates the contraction and drifts by
    ULPs; it is deliberately not used.)

    m: model-axis size; gather: ``_tp_gather`` bound to the model axis (or
    a shape-only stub under the abstract probe); data_axes: data axis
    names when rows are additionally sharded over data (contiguous caches
    only), used to reduce row-wise predicates across data shards.
    """

    m: int
    gather: Any
    data_axes: Optional[Tuple[str, ...]] = None


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    mixer: str = "attn"  # 'attn' | 'mla' | 'mamba'
    ffn: str = "dense"  # 'dense' | 'moe' | 'none'
    is_local: bool = False
    cross: bool = False


@dataclasses.dataclass(frozen=True)
class Plan:
    prefix: Tuple[SlotSpec, ...]
    period: Tuple[SlotSpec, ...]
    n_periods: int
    suffix: Tuple[SlotSpec, ...]

    @property
    def n_layers(self) -> int:
        return len(self.prefix) + self.n_periods * len(self.period) + len(self.suffix)

    def layer_specs(self) -> List[SlotSpec]:
        return (
            list(self.prefix)
            + [s for _ in range(self.n_periods) for s in self.period]
            + list(self.suffix)
        )


def build_plan(cfg) -> Plan:
    L = cfg.n_layers
    if cfg.ssm and not cfg.hybrid_period:  # mamba2
        return Plan((), (SlotSpec("mamba", "none"),), L, ())
    if cfg.hybrid_period:  # jamba
        p = cfg.hybrid_period
        period = tuple(
            SlotSpec(
                mixer=("attn" if i == p // 2 else "mamba"),
                ffn=("moe" if (cfg.moe and i % cfg.moe_every == 1) else "dense"),
            )
            for i in range(p)
        )
        assert L % p == 0, (L, p)
        return Plan((), period, L // p, ())
    if cfg.local_global_pattern:  # gemma3
        pat = cfg.local_global_pattern
        period = tuple(SlotSpec("attn", "dense", is_local=(i < pat)) for i in range(pat + 1))
        n = L // (pat + 1)
        rem = L - n * (pat + 1)
        suffix = tuple(SlotSpec("attn", "dense", is_local=True) for _ in range(rem))
        return Plan((), period, n, suffix)
    if cfg.cross_attn_every:  # llama-vision
        k = cfg.cross_attn_every
        period = tuple(
            SlotSpec("attn", "dense", cross=(i == k - 1)) for i in range(k)
        )
        assert L % k == 0, (L, k)
        return Plan((), period, L // k, ())
    mixer = "mla" if cfg.mla else "attn"
    ffn = "moe" if cfg.moe else "dense"
    prefix = tuple(SlotSpec(mixer, "dense") for _ in range(cfg.first_k_dense))
    return Plan(prefix, (SlotSpec(mixer, ffn),), L - cfg.first_k_dense, ())


# ---------------------------------------------------------------------------
# schema assembly


def _slot_schema(cfg, slot: SlotSpec, L=None) -> dict:
    sch: Dict[str, Any] = {"ln1": LY.norm_schema(cfg, L)}
    if slot.mixer == "attn":
        sch["mixer"] = LY.gqa_schema(cfg, L)
    elif slot.mixer == "mla":
        sch["mixer"] = LY.mla_schema(cfg, L)
    elif slot.mixer == "mamba":
        sch["mixer"] = MB.mamba_schema(cfg, L)
    if slot.cross:
        sch["lnx"] = LY.norm_schema(cfg, L)
        sch["xattn"] = LY.cross_attn_schema(cfg, L)
    if slot.ffn != "none":
        sch["ln2"] = LY.norm_schema(cfg, L)
        sch["ffn"] = MOE.moe_schema(cfg, L) if slot.ffn == "moe" else LY.ffn_schema(cfg, cfg.d_ff, L)
    return sch


def ramp_sites(cfg, max_sites: int = 12) -> Tuple[int, ...]:
    """Feasible ramp sites = block boundaries (cut vertices); thinned to at
    most `max_sites`, never including the final layer (that's the model)."""
    L = cfg.n_layers
    n = min(L - 1, max_sites)
    if n <= 0:
        return ()
    stride = (L - 1) / n
    sites = sorted({int(math.floor((i + 1) * stride)) - 1 for i in range(n)})
    return tuple(s for s in sites if 0 <= s < L - 1) or (0,)


def ramp_schema(cfg) -> dict:
    S = len(ramp_sites(cfg))
    d, Vp = cfg.d_model, cfg.padded_vocab
    dt = jnp.dtype(cfg.dtype)
    sch = {"norm_w": ParamInfo((S, d), jnp.float32, P(), "zeros")}
    if cfg.ramp_style != "tied":  # 'tied' shares the model's own LM head
        sch["head"] = ParamInfo((S, d, Vp), dt, P(None, "data", "model"), "normal:0.02")
    if cfg.ramp_style == "mlp":  # heavier ramps (paper Fig 9 comparison)
        sch["w1"] = ParamInfo((S, d, cfg.ramp_hidden), dt, P(None, "data", None), "normal:0.02")
        sch["w2"] = ParamInfo((S, cfg.ramp_hidden, d), dt, P(None, None, "data"), "normal:0.02")
    return sch


def paged_leaf_kinds(schema) -> List[str]:
    """Per-leaf kind labels for a paged cache schema, in ``jax.tree``
    flatten order (dicts iterate sorted keys). Kinds drive the serving
    runner's per-leaf scatter/gather branches:

    * ``"tokens"`` — per-token pages ``(P, bs, ...)``: attn k/v, MLA
      latent ``c``/``k_pe``. Prefill scatters prompt rows block-wise;
      appended every decode step.
    * ``"state"`` — per-slot pages ``(P, ...)``: mamba ``conv``/``ssm``.
      One page per slot (the first table entry); overwritten in place.
    * ``"xkv"`` — read-only pinned pages ``(P, bs, ...)``: cross-attn
      encoder k/v. Prefilled once, never appended.
    """
    out: List[str] = []

    def walk(node, kind):
        if is_info(node) or not isinstance(node, (dict, list, tuple)):
            out.append(kind)
            return
        if isinstance(node, dict):
            for kk in sorted(node):
                nk = "xkv" if kk == "xkv" else (
                    "state" if kk in ("conv", "ssm") else kind
                )
                walk(node[kk], nk)
        else:
            for v in node:
                walk(v, kind)

    walk(schema, "tokens")
    return out


class MultiStepDecodeMixin:
    """Multi-step fused-exit decode window, shared by every model class
    exposing a ``decode(params, cache, tokens, pos, ...)`` step (decoder
    LMs and the enc-dec decoder). The window is family-agnostic: the
    ``lax.while_loop`` advances EVERY row exactly ``n_done`` steps
    together and the host keeps exactly ``n_done`` tokens per row, so
    recurrent (mamba) state, ring wraparound, and read-only cross caches
    all stay consistent across early termination."""

    def decode_multi(self, params, cache, tokens, pos, n_steps, *, n_max,
                     active_sites=None, thresholds=None, row_valid=None,
                     axes=LY.TEST_AXES, mesh=None, moe_impl="ep",
                     block_tables=None, tp=None):
        """Up to ``n_steps`` greedy decode steps under ONE dispatch
        (`lax.while_loop`), with the exit decision taken ON DEVICE from a
        resident threshold vector — the host syncs once per window, not
        once per token.

        tokens: (B, 1) int32; pos: int32[B] per-row write indices (per-row
        is REQUIRED: every window row sits at its own offset). ``n_steps``
        is a traced scalar <= the static unroll bound ``n_max`` (callers
        bucket it so compile count stays bounded). ``thresholds`` is the
        (K,) f32 device-resident exit-threshold vector aligned with
        ``active_sites`` (strict ``<``; pad slots carry 0.0, which can
        never trigger). ``row_valid`` (B,) bool masks bucket-padding rows
        out of the all-exited test.

        Semantics (the staleness/accuracy contract, README "On-device
        exits & sync windows"):

        * every step runs the FULL model for every row — exits are
          *decisions*, not compute cuts, because the controller's
          agreement records need the final head's label for every token
          (replay-completeness). What the on-device mask gates is the
          WINDOW: once every valid row has exited, later steps are skipped
          and control returns to the host early.
        * thresholds are frozen across the window — deliberately stale
          between syncs. Records for every executed step are packed and
          streamed back at the sync boundary, so adaptation still sees
          every token; only the *decision* lag is traded for dispatch
          count. At ``n_steps == 1`` the decision uses the exact current
          thresholds: bit-identical to the per-step path.

        Returns ``(new_cache, (ramp_label (n_max,K,B), ramp_maxprob
        (n_max,K,B), final_label (n_max,B), exit_site (n_max,B), n_done))``
        — entries past ``n_done`` are garbage the caller must slice off.
        """
        B = tokens.shape[0]
        pos = jnp.asarray(pos, jnp.int32)
        if pos.ndim < 1:
            raise ValueError("decode_multi requires per-row pos: int32[B]")
        K = 0 if active_sites is None else int(jnp.shape(active_sites)[0])
        if K and thresholds is None:
            raise ValueError("decode_multi with active ramps needs thresholds")
        if row_valid is None:
            row_valid = jnp.ones((B,), bool)
        sites_arr = (jnp.asarray(active_sites, jnp.int32)
                     if K else jnp.zeros((0,), jnp.int32))
        thr = (jnp.asarray(thresholds, jnp.float32)
               if K else jnp.zeros((0,), jnp.float32))

        def body(carry):
            i, all_ex, cache, tok, p, rl, rm, fl, ex = carry
            cache, outs = self.decode(
                params, cache, tok, p, active_sites=active_sites, axes=axes,
                mesh=mesh, moe_impl=moe_impl, block_tables=block_tables,
                exit_thresholds=(thr if K else None),
                # subclasses (EncDecLM) override decode without the tp
                # kwarg; only the TP shard_map body threads a context
                **({"tp": tp} if tp is not None else {}),
            )
            f = outs["final"]["label"].reshape(-1).astype(jnp.int32)  # (B,)
            if K:
                lab = outs["ramps"]["label"].astype(jnp.int32)  # (K, B)
                mp = outs["ramps"]["maxprob"].astype(jnp.float32)
                # per-ramp on-device mask (fused into the pallas head when
                # enabled); argmax returns the FIRST true row = the
                # shallowest exiting site (active_sites ascending)
                mask = outs["ramps"]["exit"].astype(bool)
                anyx = jnp.any(mask, axis=0)
                site = jnp.where(
                    anyx, sites_arr[jnp.argmax(mask, axis=0)], -1
                ).astype(jnp.int32)
            else:
                lab = jnp.zeros((0, B), jnp.int32)
                mp = jnp.zeros((0, B), jnp.float32)
                site = jnp.full((B,), -1, jnp.int32)
            rl = jax.lax.dynamic_update_slice(rl, lab[None], (i, 0, 0))
            rm = jax.lax.dynamic_update_slice(rm, mp[None], (i, 0, 0))
            fl = jax.lax.dynamic_update_slice(fl, f[None], (i, 0))
            ex = jax.lax.dynamic_update_slice(ex, site[None], (i, 0))
            all_ex = jnp.all(jnp.logical_or(~row_valid, site >= 0))
            if tp is not None and tp.data_axes:
                # rows are sharded over data: the window terminates only
                # when EVERY shard's rows have exited — reduce the local
                # predicate across the data axes (replicated over model)
                all_ex = jax.lax.psum(
                    jnp.logical_not(all_ex).astype(jnp.int32), tp.data_axes
                ) == 0
            return (i + 1, all_ex, cache, f.reshape(-1, 1), p + 1,
                    rl, rm, fl, ex)

        def cond(carry):
            i, all_ex = carry[0], carry[1]
            return jnp.logical_and(i < jnp.int32(n_steps),
                                   jnp.logical_not(all_ex))

        init = (
            jnp.int32(0), jnp.asarray(False), cache, tokens, pos,
            jnp.zeros((n_max, K, B), jnp.int32),
            jnp.zeros((n_max, K, B), jnp.float32),
            jnp.zeros((n_max, B), jnp.int32),
            jnp.full((n_max, B), -1, jnp.int32),
        )
        n_done, _, cache, _, _, rl, rm, fl, ex = jax.lax.while_loop(
            cond, body, init
        )
        return cache, (rl, rm, fl, ex, n_done)


class LM(MultiStepDecodeMixin):
    """Functional model wrapper (see DESIGN.md §3)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.plan = build_plan(cfg)
        self.sites = ramp_sites(cfg)

    # -- schema / init ------------------------------------------------------

    def schema(self) -> dict:
        cfg, plan = self.cfg, self.plan
        sch: Dict[str, Any] = {"tok": LY.embed_schema(cfg)}
        if plan.prefix:
            sch["prefix"] = [_slot_schema(cfg, s) for s in plan.prefix]
        sch["blocks"] = [_slot_schema(cfg, s, L=plan.n_periods) for s in plan.period]
        if plan.suffix:
            sch["suffix"] = [_slot_schema(cfg, s) for s in plan.suffix]
        sch["final_norm"] = LY.norm_schema(cfg)
        sch["ramps"] = ramp_schema(cfg)
        if cfg.cross_attn_every:
            sch["frontend"] = {
                "proj": ParamInfo(
                    (cfg.d_frontend, cfg.d_model), jnp.dtype(cfg.dtype), P(None, "model"), "normal:0.02"
                )
            }
        return sch

    def init(self, key) -> dict:
        return init_from_schema(self.schema(), key)

    def pspecs(self, axes: MeshAxes) -> dict:
        return specs_from_schema(LY.resolve_schema(self.schema(), axes))

    def abstract(self) -> dict:
        return abstract_from_schema(self.schema())

    # -- cache --------------------------------------------------------------

    def _slot_cache_schema(self, cfg, slot: SlotSpec, B, S, shard_batch, L=None):
        dt = jnp.dtype(cfg.dtype)
        pre = () if L is None else (L,)
        pfx = (None,) * len(pre)
        bspec, sspec = ("data", None) if shard_batch else (None, "data")
        if cfg.kv_seq_shard:
            # flash-decode layout: seq sharded over `model` (softmax partials
            # psum small stats instead of all-reducing full score tensors)
            sspec = ("data", "model") if not shard_batch else "model"
        if slot.mixer == "attn":
            K, hd = cfg.n_kv_heads, cfg.hd
            hspec = ("model" if hd % 16 == 0 else None) if not cfg.kv_seq_shard else None
            Sl = S
            if cfg.windowed_cache and slot.is_local and cfg.window:
                Sl = min(cfg.window, S)
            c = {
                "k": ParamInfo(pre + (B, Sl, K, hd), dt, P(*pfx, bspec, sspec, None, hspec), "zeros"),
                "v": ParamInfo(pre + (B, Sl, K, hd), dt, P(*pfx, bspec, sspec, None, hspec), "zeros"),
            }
        elif slot.mixer == "mla":
            r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
            c = {
                "c": ParamInfo(pre + (B, S, r), dt, P(*pfx, bspec, sspec, None), "zeros"),
                "k_pe": ParamInfo(pre + (B, S, dr), dt, P(*pfx, bspec, sspec, None), "zeros"),
            }
        elif slot.mixer == "mamba":
            c = MB.mamba_cache_schema(cfg, B, L=None)
            # add period dim manually
            if L is not None:
                c = jax.tree.map(
                    lambda i: ParamInfo((L,) + i.shape, i.dtype, P(None, *i.spec), i.init),
                    c,
                    is_leaf=is_info,
                )
        else:
            c = {}
        if slot.cross:
            K, hd = cfg.n_kv_heads, cfg.hd
            M = cfg.n_image_tokens
            hspec = "model" if hd % 16 == 0 else None
            c["xkv"] = {
                "k": ParamInfo(pre + (B, M, K, hd), dt, P(*pfx, bspec, None, None, hspec), "zeros"),
                "v": ParamInfo(pre + (B, M, K, hd), dt, P(*pfx, bspec, None, None, hspec), "zeros"),
            }
        return c

    def _slot_paged_cache_schema(self, cfg, slot: SlotSpec, n_blocks, bs, L=None):
        """Paged (block-pool) analogue of ``_slot_cache_schema``. Every
        mixer family draws pages from the same refcounted block pool, each
        with its own page layout:

        * full attention: k/v pools ``(P, bs, K, hd)`` — virtual token
          ``t`` lives at ``(table[b, t // bs], t % bs)``.
        * local (ring) attention: same k/v pools, but the write index is
          ``pos % W`` redirected through the table — only the first
          ``ceil(W/bs)`` table entries are ever touched, so the live
          window stays W-bounded inside the shared pool.
        * MLA: pools over the compressed latent streams ``c (P, bs, r)``
          and ``k_pe (P, bs, dr)`` — one shared stream per layer (the
          latent cache is MQA-like), not per-head.
        * mamba: per-SLOT state pages ``conv (P, d_conv-1, conv_dim)`` /
          ``ssm (P, H, hp, N)`` living in the slot's FIRST table entry.
          State is O(1) per slot (not per token), so one page holds it —
          share/CoW degenerate to private allocation (enforced by the
          runner: prefix sharing is refused for these models).
        * cross-attention: read-only ``xkv`` pools ``(P, bs, K, hd)``
          prefilled once and refcount-pinned; their block ids ride in the
          LAST ``ceil(M/bs)`` table columns and are never appended.
        """
        dt = jnp.dtype(cfg.dtype)
        pre = () if L is None else (L,)
        pfx = (None,) * len(pre)
        if slot.mixer == "attn" or slot.cross:
            # pure-SSM configs have n_heads=0: only touch head_dim when an
            # attention leaf actually needs it
            K, hd = cfg.n_kv_heads, cfg.hd
            hspec = "model" if hd % 16 == 0 else None
        if slot.mixer == "attn":
            shp = pre + (n_blocks, bs, K, hd)
            c = {
                "k": ParamInfo(shp, dt, P(*pfx, None, None, None, hspec), "zeros"),
                "v": ParamInfo(shp, dt, P(*pfx, None, None, None, hspec), "zeros"),
            }
        elif slot.mixer == "mla":
            r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
            c = {
                "c": ParamInfo(pre + (n_blocks, bs, r), dt, P(*pfx, None, None, None), "zeros"),
                "k_pe": ParamInfo(pre + (n_blocks, bs, dr), dt, P(*pfx, None, None, None), "zeros"),
            }
        elif slot.mixer == "mamba":
            c = MB.mamba_paged_cache_schema(cfg, n_blocks, L=L)
        else:
            c = {}
        if slot.cross:
            shp = pre + (n_blocks, bs, K, hd)
            c["xkv"] = {
                "k": ParamInfo(shp, dt, P(*pfx, None, None, None, hspec), "zeros"),
                "v": ParamInfo(shp, dt, P(*pfx, None, None, None, hspec), "zeros"),
            }
        return c

    def paged_cache_schema(self, n_blocks: int, block_size: int) -> dict:
        """Cache schema for the paged decode layout: same tree structure as
        ``cache_schema`` but every attention leaf is a block pool shared by
        all slots — total KV memory is ``n_blocks * block_size`` tokens,
        independent of slot count."""
        cfg, plan = self.cfg, self.plan
        sch: Dict[str, Any] = {}
        if plan.prefix:
            sch["prefix"] = [
                self._slot_paged_cache_schema(cfg, s, n_blocks, block_size)
                for s in plan.prefix
            ]
        sch["blocks"] = [
            self._slot_paged_cache_schema(cfg, s, n_blocks, block_size, L=plan.n_periods)
            for s in plan.period
        ]
        if plan.suffix:
            sch["suffix"] = [
                self._slot_paged_cache_schema(cfg, s, n_blocks, block_size)
                for s in plan.suffix
            ]
        return sch

    def init_paged_cache(self, n_blocks: int, block_size: int) -> dict:
        return jax.tree.map(
            lambda i: jnp.zeros(i.shape, i.dtype),
            self.paged_cache_schema(n_blocks, block_size),
            is_leaf=is_info,
        )

    def paged_cache_kinds(self, n_blocks: int, block_size: int) -> list:
        """Flat per-leaf kind labels for ``paged_cache_schema`` (see
        ``paged_leaf_kinds``)."""
        return paged_leaf_kinds(self.paged_cache_schema(n_blocks, block_size))

    def paged_xkv_blocks(self, block_size: int) -> int:
        """Number of extra TRAILING block-table columns holding the pinned
        read-only cross-attention pages (0 for models without cross
        layers). The runner widens every table it ships by this amount."""
        if not any(s.cross for s in self.plan.layer_specs()):
            return 0
        return -(-self.cfg.n_image_tokens // block_size)

    @property
    def paged_sharing_ok(self) -> bool:
        """Whether prefix sharing / copy-on-write are sound for this plan.
        Sharing moves *token* pages between tables; mamba state pages are
        per-slot recurrent state, ring pages are position-aliased mod W,
        and xkv pages are pinned per slot — none of those share, so the
        runner refuses ``prefix_cache`` unless every layer is plain
        full attention."""
        cfg = self.cfg
        return all(
            s.mixer == "attn" and not s.cross and not (s.is_local and cfg.window)
            for s in self.plan.layer_specs()
        )

    def cache_schema(self, B: int, S: int, shard_batch: bool = True) -> dict:
        cfg, plan = self.cfg, self.plan
        sch: Dict[str, Any] = {}
        if plan.prefix:
            sch["prefix"] = [
                self._slot_cache_schema(cfg, s, B, S, shard_batch) for s in plan.prefix
            ]
        sch["blocks"] = [
            self._slot_cache_schema(cfg, s, B, S, shard_batch, L=plan.n_periods)
            for s in plan.period
        ]
        if plan.suffix:
            sch["suffix"] = [
                self._slot_cache_schema(cfg, s, B, S, shard_batch) for s in plan.suffix
            ]
        return sch

    def init_cache(self, B: int, S: int) -> dict:
        return jax.tree.map(
            lambda i: jnp.zeros(i.shape, i.dtype), self.cache_schema(B, S), is_leaf=is_info
        )

    def cache_pspecs(self, B, S, axes: MeshAxes, shard_batch=True) -> dict:
        return specs_from_schema(
            LY.resolve_schema(self.cache_schema(B, S, shard_batch), axes)
        )

    # -- forward ------------------------------------------------------------

    def _block(
        self,
        slot: SlotSpec,
        p,
        h,
        *,
        positions,
        mask_full,
        mask_local,
        axes,
        mesh,
        cache,
        cache_index,
        memory,
        moe_impl,
        block_tables=None,
        rope_theta_local=10_000.0,
        tp: Optional[TpCtx] = None,
    ):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        x = LY.apply_norm(cfg, p["ln1"], h)
        new_cache = dict(cache) if cache is not None else None
        if slot.mixer == "attn":
            mask = mask_local if slot.is_local else mask_full
            theta = rope_theta_local if slot.is_local else cfg.rope_theta
            sub = {k: cache[k] for k in ("k", "v")} if cache is not None else None
            # ring layout: the windowed-cache optimization (contiguous) OR
            # any paged local layer — the block pool always ring-pages
            # local windows through the first ceil(W/bs) table entries
            # (without the redirection a paged local layer would attend
            # full-causal, silently breaking the window semantics).
            ring = (
                cfg.window
                if (slot.is_local and cfg.window
                    and (cfg.windowed_cache or block_tables is not None))
                else None
            )
            # local layer on a FULL contiguous cache: window-gather decode
            lw = cfg.window if (slot.is_local and cfg.window and ring is None) else None
            ci = cache_index
            if ring is not None and ci is not None and block_tables is None:
                ci = cache_index % ring  # ring slot at decode
            # local windowed layers keep the dense masked path (the flash
            # wrapper only knows "attend to <= pos"); everything else routes
            # single-token decode through kernels/decode_attention. Paged
            # ring layers keep the TRUE position (the paged branch derives
            # both the ring write slot and the ring mask from it).
            if block_tables is not None:
                impl = cfg.decode_attn
            else:
                impl = "dense" if (slot.is_local and cfg.window) else cfg.decode_attn
            if tp is not None and tp.m > 1:
                # per-device head slice: the sliced cfg pins head_dim
                # explicitly (the `hd` property would re-derive it from the
                # sliced n_heads otherwise) and keeps the GQA group size
                # H/K unchanged, so contiguous kv-head blocks stay aligned
                # with their query-head groups. `out_proj=False` returns
                # the raw (B,S,Hl*hd) head block; wo is applied AFTER the
                # head gather as an output-column slice.
                cfg_l = cfg.replace(
                    n_heads=cfg.n_heads // tp.m,
                    n_kv_heads=cfg.n_kv_heads // tp.m,
                    head_dim=cfg.hd,
                )
                out, nc = LY.attn_apply(
                    cfg_l, p["mixer"], x, positions=positions, mask=mask,
                    axes=axes, mesh=mesh, cache=sub, cache_index=ci,
                    rope_theta=theta, ring_window=ring, local_window=lw,
                    decode_impl=impl, block_table=block_tables,
                    out_proj=False,
                )
                out = tp.gather(tp.gather(out) @ p["mixer"]["wo"])
            else:
                out, nc = LY.attn_apply(
                    cfg, p["mixer"], x, positions=positions, mask=mask, axes=axes,
                    mesh=mesh, cache=sub, cache_index=ci, rope_theta=theta,
                    ring_window=ring, local_window=lw, decode_impl=impl,
                    block_table=block_tables,
                )
            if nc is not None:
                new_cache.update(nc)
        elif slot.mixer == "mla":
            sub = {k: cache[k] for k in ("c", "k_pe")} if cache is not None else None
            out, nc = LY.mla_apply(
                cfg, p["mixer"], x, positions=positions, mask=mask_full, axes=axes,
                mesh=mesh, cache=sub, cache_index=cache_index,
                absorbed=getattr(cfg, "mla_absorbed", False),
                decode_impl=cfg.decode_attn, block_table=block_tables,
            )
            if nc is not None:
                new_cache.update(nc)
        elif slot.mixer == "mamba":
            sub = (
                {k: cache[k] for k in ("conv", "ssm")} if cache is not None else None
            )
            if block_tables is not None:
                # block-pooled SSM state: the slot's whole recurrent state
                # lives in the page at its FIRST table entry (state is O(1)
                # per slot, not per token). Duplicate bucket-padding rows
                # scatter identical values; free rows hit the trash block.
                blk0 = jnp.asarray(block_tables, jnp.int32)[:, 0]
                view = {"conv": sub["conv"][blk0], "ssm": sub["ssm"][blk0]}
                out, st = MB.mamba_apply(
                    cfg, p["mixer"], x, axes=axes, mesh=mesh, cache=view
                )
                nc = {
                    "conv": sub["conv"].at[blk0].set(st["conv"].astype(sub["conv"].dtype)),
                    "ssm": sub["ssm"].at[blk0].set(st["ssm"].astype(sub["ssm"].dtype)),
                }
            else:
                out, nc = MB.mamba_apply(cfg, p["mixer"], x, axes=axes, mesh=mesh, cache=sub)
            if nc is not None:
                new_cache.update(nc)
        h = h + out
        if slot.cross:
            xx = LY.apply_norm(cfg, p["lnx"], h)
            kvc = cache.get("xkv") if cache is not None else None
            if block_tables is not None and kvc is not None:
                # read-only pinned xkv pages: gather the M encoder tokens
                # from the trailing table columns; never written back.
                bsz = kvc["k"].shape[1]
                M = cfg.n_image_tokens
                nbx = -(-M // bsz)
                xtab = jnp.asarray(block_tables, jnp.int32)[:, -nbx:]
                Bq = xtab.shape[0]

                def _gather(pool):
                    g = pool[xtab]  # (B, nbx, bs, K, hd)
                    return g.reshape((Bq, nbx * bsz) + pool.shape[2:])[:, :M]

                out, _ = LY.cross_attn_apply(
                    cfg, p["xattn"], xx, memory=None,
                    kv_cache={"k": _gather(kvc["k"]), "v": _gather(kvc["v"])},
                    axes=axes, mesh=mesh,
                )
                if new_cache is not None:
                    new_cache["xkv"] = kvc
            else:
                out, kv = LY.cross_attn_apply(
                    cfg, p["xattn"], xx, memory=memory, kv_cache=kvc, axes=axes, mesh=mesh
                )
                if new_cache is not None:
                    new_cache["xkv"] = kv
            h = h + out
        if slot.ffn != "none":
            x = LY.apply_norm(cfg, p["ln2"], h)
            if slot.ffn == "moe":
                if tp is not None and tp.m > 1 and moe_impl == "ep":
                    # expert-parallel inside the TP shard_map body: reuse
                    # the lifted per-device dispatch (no nested shard_map)
                    out, a = MOE.moe_apply_ep_device(cfg, p["ffn"], x, axes, tp.m)
                else:
                    out, a = MOE.moe_apply(cfg, p["ffn"], x, axes, mesh, impl=moe_impl)
                aux = aux + a
            else:
                if tp is not None and tp.m > 1:
                    out = LY.ffn_apply_tp(cfg, p["ffn"], x, tp.gather)
                else:
                    out = LY.ffn_apply(cfg, p["ffn"], x, axes, mesh)
            h = h + out
        return h, new_cache, aux

    def _stack(
        self,
        params,
        h,
        *,
        positions,
        mask_full,
        mask_local,
        axes,
        mesh,
        caches,
        cache_index,
        memory,
        moe_impl,
        pool_idx,
        block_tables=None,
        remat=False,
        tp: Optional[TpCtx] = None,
    ):
        """Run prefix + scanned periods + suffix. Returns
        (h, pooled (L,B,npos,d), new_caches, aux)."""
        cfg, plan = self.cfg, self.plan
        pooled_all: List = []
        aux_total = jnp.zeros((), jnp.float32)

        def pool(hh):
            return jnp.take(hh, pool_idx, axis=1)  # (B, npos, d)

        kw = dict(
            positions=positions, mask_full=mask_full, mask_local=mask_local,
            axes=axes, mesh=mesh, cache_index=cache_index, memory=memory,
            moe_impl=moe_impl, block_tables=block_tables, tp=tp,
        )
        new_caches: Dict[str, Any] = {}
        if plan.prefix:
            new_caches["prefix"] = []
            for i, slot in enumerate(plan.prefix):
                c = caches["prefix"][i] if caches else None
                h, nc, a = self._block(slot, params["prefix"][i], h, cache=c, **kw)
                new_caches["prefix"].append(nc)
                aux_total = aux_total + a
                pooled_all.append(pool(h))

        def body(carry, xs):
            hh, auxc = carry
            pblocks, cblocks = xs
            pooled_s, cout = [], []
            for s, slot in enumerate(plan.period):
                c = cblocks[s] if cblocks is not None else None
                hh, nc, a = self._block(slot, pblocks[s], hh, cache=c, **kw)
                auxc = auxc + a
                pooled_s.append(pool(hh))
                cout.append(nc if nc is not None else 0)
            return (hh, auxc), (jnp.stack(pooled_s), cout)

        if remat:
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat_policy == "dots"
                else None  # save nothing: recompute everything
            )
            body = jax.checkpoint(body, policy=policy)
        cblocks = caches["blocks"] if caches else None
        (h, aux_total), (pooled_scan, cache_scan) = jax.lax.scan(
            body, (h, aux_total), (params["blocks"], cblocks),
            unroll=True if cfg.scan_unroll else 1,
        )
        # pooled_scan: (n_periods, n_slots, B, npos, d) -> flatten layer-major
        ps = pooled_scan.reshape((-1,) + pooled_scan.shape[2:])
        new_caches["blocks"] = cache_scan if caches else None

        if plan.suffix:
            new_caches["suffix"] = []
            for i, slot in enumerate(plan.suffix):
                c = caches["suffix"][i] if caches else None
                h, nc, a = self._block(slot, params["suffix"][i], h, cache=c, **kw)
                new_caches["suffix"].append(nc)
                aux_total = aux_total + a
                pooled_all.append(pool(h))

        # assemble pooled (L, B, npos, d): prefix ++ scan ++ suffix
        n_pre = len(plan.prefix)
        parts = []
        if n_pre:
            parts.append(jnp.stack(pooled_all[:n_pre]))
        parts.append(ps)
        if plan.suffix:
            parts.append(jnp.stack(pooled_all[n_pre:]))
        pooled = jnp.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
        return h, pooled, (new_caches if caches else None), aux_total

    # -- ramp heads ----------------------------------------------------------

    def ramp_outputs(self, params, pooled, site_idx=None, stop_grad=True,
                     axes=None, mesh=None):
        """pooled: (L,B,npos,d). site_idx: int32[K] (dynamic) or None=all
        sites. Returns ramp logits (K,B,npos,Vp) in f32, vocab-sharded."""
        cfg = self.cfg
        sites = jnp.asarray(self.sites, jnp.int32)
        if site_idx is None:
            site_idx = jnp.arange(len(self.sites), dtype=jnp.int32)
        layer_idx = sites[site_idx]
        hs = jnp.take(pooled, layer_idx, axis=0)  # (K,B,npos,d)
        if stop_grad:
            hs = jax.lax.stop_gradient(hs)
        nw = jnp.take(params["ramps"]["norm_w"], site_idx, axis=0)  # (K,d)
        hs = LY.rms_norm(hs, nw[:, None, None, :])
        if cfg.ramp_style == "mlp":
            w1 = jnp.take(params["ramps"]["w1"], site_idx, axis=0)
            w2 = jnp.take(params["ramps"]["w2"], site_idx, axis=0)
            hs = hs + jnp.einsum(
                "kbnh,khd->kbnd", jax.nn.gelu(jnp.einsum("kbnd,kdh->kbnh", hs, w1)), w2
            )
        if cfg.ramp_style == "tied":
            hw = params["tok"]["embed"].T if cfg.tie_embeddings else params["tok"]["lm_head"]
            out = jnp.einsum("kbnd,dv->kbnv", hs, hw).astype(jnp.float32)
        else:
            hw = jnp.take(params["ramps"]["head"], site_idx, axis=0)  # (K,d,Vp)
            out = jnp.einsum("kbnd,kdv->kbnv", hs, hw).astype(jnp.float32)
        if axes is not None:
            # keep vocab sharded over `model` (a d-contraction against an
            # FSDP-sharded head otherwise all-reduces full f32 logits)
            out = LY.constrain(out, axes.aspec(None, "data", None, "model"), mesh)
        return out

    # -- public entry points --------------------------------------------------

    def loss(self, params, batch, *, axes=LY.TEST_AXES, mesh=None, moe_impl="ep",
             remat=False, ramp_positions=16, train_mode="full"):
        """batch: {'tokens': (B,S) int32, 'labels': (B,S) int32 (-1 = pad)}.
        Returns (loss, metrics). Ramp losses always use stop-grad features
        (paper: backbone frozen w.r.t. ramps; ramps trained on all inputs)."""
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        B, S = tokens.shape
        positions = jnp.arange(S)[None, :]
        h = LY.embed_apply(cfg, params["tok"], tokens, positions)
        h = LY.constrain(h, axes.aspec("data", None, None), mesh)
        mask_full = LY.causal_mask(S, S, 0)
        mask_local = LY.window_mask(S, S, 0, cfg.window) if cfg.window else mask_full
        npos = min(ramp_positions, S)
        pool_idx = jnp.linspace(S // npos - 1, S - 1, npos).astype(jnp.int32)
        memory = None
        if cfg.cross_attn_every:
            memory = batch["image_embeds"] @ params["frontend"]["proj"]
        h, pooled, _, aux = self._stack(
            params, h, positions=positions, mask_full=mask_full,
            mask_local=mask_local, axes=axes, mesh=mesh, caches=None,
            cache_index=None, memory=memory, moe_impl=moe_impl,
            pool_idx=pool_idx, remat=remat,
        )
        h = LY.apply_norm(cfg, params["final_norm"], h)
        logits = LY.unembed(cfg, params["tok"], h)
        logits = LY.constrain(logits, axes.aspec("data", None, "model"), mesh)
        lm = _masked_ce(cfg, logits, labels)
        if len(self.sites):
            ramp_logits = self.ramp_outputs(params, pooled, axes=axes, mesh=mesh)
            R = ramp_logits.shape[0]
            ramp_labels = jnp.take(labels, pool_idx, axis=1)  # (B,npos)
            rloss = _masked_ce(
                cfg,
                ramp_logits.reshape(R * B, npos, -1),
                jnp.tile(ramp_labels, (R, 1)),
            )
        else:  # reduced-depth metric lowerings can have zero ramp sites
            rloss = jnp.zeros((), jnp.float32)
        if train_mode == "ramps_only":
            loss = rloss + 0.0 * lm
        else:
            loss = lm + rloss + 0.01 * aux
        return loss, {"lm_loss": lm, "ramp_loss": rloss, "moe_aux": aux}

    def prefill(self, params, tokens, *, cache_len=None, active_sites=None,
                axes=LY.TEST_AXES, mesh=None, moe_impl="ep", image_embeds=None,
                shard_batch=True, with_cache=True):
        """tokens: (B,S). Returns (cache|None, outs) where outs carries final
        + per-active-ramp stats for the LAST position (the generated token)."""
        cfg = self.cfg
        B, S = tokens.shape
        cache_len = cache_len or S
        positions = jnp.arange(S)[None, :]
        h = LY.embed_apply(cfg, params["tok"], tokens, positions)
        h = LY.constrain(h, axes.aspec("data", None, None), mesh)
        mask_full = LY.causal_mask(S, cache_len, 0) if with_cache else LY.causal_mask(S, S, 0)
        if cfg.window:
            # local prefill attention ALWAYS runs against the in-flight
            # (S-long) k/v, never the padded cache: ring and full caches
            # then compute the identical S-column reduction (a cache_len
            # reduction regroups the sum and drifts by ULPs)
            mask_local = LY.window_mask(S, S, 0, cfg.window)
        else:
            mask_local = mask_full
        pool_idx = jnp.asarray([S - 1], jnp.int32)
        memory = None
        if cfg.cross_attn_every and image_embeds is not None:
            memory = image_embeds @ params["frontend"]["proj"]
        caches = self.init_cache(B, cache_len) if with_cache else None
        h, pooled, caches, _ = self._stack(
            params, h, positions=positions, mask_full=mask_full,
            mask_local=mask_local, axes=axes, mesh=mesh, caches=caches,
            cache_index=0, memory=memory, moe_impl=moe_impl, pool_idx=pool_idx,
        )
        outs = self._head_stats(params, h[:, -1:], pooled, active_sites,
                                axes=axes, mesh=mesh)
        return caches, outs

    def decode(self, params, cache, tokens, pos, *, active_sites=None,
               axes=LY.TEST_AXES, mesh=None, moe_impl="ep", block_tables=None,
               exit_thresholds=None, tp: Optional[TpCtx] = None):
        """One decode step. tokens: (B,1); pos: int32 scalar (shared write
        index) or int32[B] per-row write indices — batched slot caches where
        continuous batching leaves every row at its own position (each row
        scatters its token and masks its own history).

        With ``block_tables`` (int32[B, max_blocks]) the cache is the PAGED
        block pool from ``init_paged_cache``: each row's token scatters to
        ``(block_tables[b, pos[b] // bs], pos[b] % bs)`` and attention walks
        the table (``cfg.decode_attn`` must be a 'paged*' variant); masks
        are internal to the paged kernel, so none are built here. Returns
        (new_cache, outs)."""
        cfg = self.cfg
        B, S = tokens.shape
        assert S == 1
        pos = jnp.asarray(pos, jnp.int32)
        per_row = pos.ndim >= 1
        positions = pc = pos.reshape(-1, 1)  # (B, 1) per-row | (1, 1) shared
        h = LY.embed_apply(cfg, params["tok"], tokens, positions)
        if block_tables is not None:
            if not per_row:
                raise ValueError("paged decode requires per-row pos: int32[B]")
            mask_full = mask_local = None
            pool_idx = jnp.asarray([0], jnp.int32)
            h, pooled, new_cache, _ = self._stack(
                params, h, positions=positions, mask_full=None, mask_local=None,
                axes=axes, mesh=mesh, caches=cache, cache_index=pos.reshape(-1),
                memory=None, moe_impl=moe_impl, pool_idx=pool_idx,
                block_tables=jnp.asarray(block_tables, jnp.int32), tp=tp,
            )
            outs = self._head_stats(params, h, pooled, active_sites,
                                    axes=axes, mesh=mesh,
                                    exit_thresholds=exit_thresholds)
            return new_cache, outs
        # cache length from any attn cache leaf (mamba-only models have none)
        try:
            Sc = _cache_len(cache)
            kpos = jnp.arange(Sc)[None, :]
            mask_full = (kpos <= pc)[:, None, None, :]
            if cfg.windowed_cache and cfg.window:
                # ring semantics: attn_apply gathers the W ring slots back
                # into chronological order (positions pos-W+1..pos), so the
                # mask only blanks the pre-wrap columns (tpos < 0)
                j = jnp.arange(cfg.window)[None, :]
                mask_local = (pc - (cfg.window - 1) + j >= 0)[:, None, None, :]
            elif cfg.window:
                mask_local = ((kpos <= pc) & (kpos > pc - cfg.window))[:, None, None, :]
            else:
                mask_local = mask_full
        except ValueError:
            mask_full = mask_local = None
        pool_idx = jnp.asarray([0], jnp.int32)
        h, pooled, new_cache, _ = self._stack(
            params, h, positions=positions, mask_full=mask_full,
            mask_local=mask_local, axes=axes, mesh=mesh, caches=cache,
            cache_index=(pos.reshape(-1) if per_row else pos), memory=None,
            moe_impl=moe_impl, pool_idx=pool_idx, tp=tp,
        )
        outs = self._head_stats(params, h, pooled, active_sites,
                                axes=axes, mesh=mesh,
                                exit_thresholds=exit_thresholds)
        return new_cache, outs

    # -- sharded (tensor-parallel) decode ------------------------------------

    def tp_check(self, tp: int, *, dp: int = 1, paged: bool = True, batch=None):
        """Raise ``NotImplementedError`` (with a why-note the support
        matrix surfaces verbatim) when this plan/config cannot run the
        tensor-parallel sharded-decode path at the given mesh shape."""
        cfg = self.cfg
        if tp <= 1 and dp <= 1:
            return
        for slot in self.plan.layer_specs():
            if slot.mixer == "mamba":
                raise NotImplementedError(
                    "tensor-parallel decode cannot shard the mamba mixer: the "
                    "SSM recurrence is per-row/per-channel with conv and state "
                    "fused, so no head axis divides across devices"
                )
            if slot.mixer == "mla":
                raise NotImplementedError(
                    "MLA shares one compressed latent stream across all heads; "
                    "every head shard still needs the full latent cache, so "
                    "sharding gives no per-device KV scaling"
                )
            if slot.cross:
                raise NotImplementedError(
                    "cross-attention slots pin per-slot read-only encoder "
                    "pages that sit outside the TP-sharded KV pool"
                )
        if tp > 1:
            if cfg.n_heads % tp:
                raise NotImplementedError(
                    f"n_heads={cfg.n_heads} not divisible by tp={tp}"
                )
            if cfg.n_kv_heads % tp:
                raise NotImplementedError(
                    f"n_kv_heads={cfg.n_kv_heads} not divisible by tp={tp} "
                    "(the KV pool shards by kv head, one contiguous block per "
                    "device)"
                )
            if cfg.d_ff % tp:
                raise NotImplementedError(
                    f"d_ff={cfg.d_ff} not divisible by tp={tp}"
                )
            if cfg.d_model % tp:
                raise NotImplementedError(
                    f"d_model={cfg.d_model} not divisible by tp={tp}"
                )
            if cfg.moe and cfg.n_experts % tp:
                raise NotImplementedError(
                    f"n_experts={cfg.n_experts} not divisible by tp={tp} "
                    "(expert-parallel MoE owns E/tp experts per device)"
                )
        if dp > 1:
            if paged:
                raise NotImplementedError(
                    "paged pools cannot shard rows over data: per-shard pool "
                    "scatters would diverge the replicated pool copies; "
                    "paged sharded decode is tensor-parallel only"
                )
            if batch is not None and batch % dp:
                raise NotImplementedError(
                    f"decode batch {batch} not divisible by data-parallel "
                    f"degree {dp}"
                )

    def tp_param_specs(self, axes: MeshAxes, *, moe_ep: bool = False) -> dict:
        """Per-leaf shard_map in_specs for params under tensor-parallel
        decode. Everything replicates except: wq/wk/wv/w_gate/w_up column
        slices (contiguous per-head / hidden blocks), wo/w_down column
        slices on their OUTPUT dim, qkv biases sliced with their columns,
        and (with ``moe_ep``) expert weights sharded on the expert axis.
        Ramp heads, the final head, embeddings, and every norm replicate —
        exit masks are computed identically on all devices, no round-trip."""
        tpx = axes.model
        specs = jax.tree.map(lambda i: P(), self.schema(), is_leaf=is_info)

        def fix_slot(slot: SlotSpec, sp, pfx):
            if slot.mixer == "attn":
                mx = sp["mixer"]
                for k in ("wq", "wk", "wv", "wo"):
                    mx[k] = P(*pfx, None, tpx)
                for k in ("bq", "bk", "bv"):
                    if k in mx:
                        mx[k] = P(*pfx, tpx)
            if slot.ffn == "dense":
                for k in ("w_gate", "w_up", "w_down"):
                    sp["ffn"][k] = P(*pfx, None, tpx)
            elif slot.ffn == "moe" and moe_ep:
                for k in ("w_gate", "w_up", "w_down"):
                    sp["ffn"][k] = P(*pfx, tpx, None, None)

        plan = self.plan
        for i, slot in enumerate(plan.prefix):
            fix_slot(slot, specs["prefix"][i], ())
        for s, slot in enumerate(plan.period):
            fix_slot(slot, specs["blocks"][s], (None,))
        for i, slot in enumerate(plan.suffix):
            fix_slot(slot, specs["suffix"][i], ())
        return specs

    def tp_cache_specs(self, cache, axes: MeshAxes, *, data_shard: bool = False):
        """Per-leaf shard_map specs for a decode cache under TP: every
        supported leaf is an attention k/v (contiguous ``(L?,B,S,K,hd)`` or
        paged ``(L?,P,bs,K,hd)``) with the kv-head axis at ``ndim-2`` —
        that axis shards over `model`, so per-device KV bytes are
        ``total / tp``. With ``data_shard`` (contiguous only) the batch
        axis (``ndim-4``) additionally shards over `data`."""

        def leaf(x):
            ent = [None] * x.ndim
            ent[x.ndim - 2] = axes.model
            if data_shard:
                ent[x.ndim - 4] = axes.d
            return P(*ent)

        return jax.tree.map(leaf, cache)

    def _mesh_degrees(self, mesh, axes: MeshAxes) -> Tuple[int, int]:
        m = mesh_axis_size(mesh, axes.model)
        dp = 1
        for a in axes.data:
            dp *= mesh_axis_size(mesh, a)
        return m, dp

    def decode_sharded(self, params, cache, tokens, pos, *, mesh,
                       axes=LY.TEST_AXES, active_sites=None, moe_impl="dense",
                       block_tables=None, exit_thresholds=None):
        """One decode step through ``shard_map`` on a ``(data, model)``
        mesh: tensor-parallel attention/MLP with the KV cache (contiguous
        or paged pool) sharded by kv head, bit-identical to single-device
        ``decode`` (see ``TpCtx``). Ramp heads, the final head, and the
        fused exit decision replicate, so exit masks never leave the
        device. Returns ``(new_cache, outs)`` with the cache left sharded."""
        m, dp = self._mesh_degrees(mesh, axes)
        paged = block_tables is not None
        tokens = jnp.asarray(tokens)
        self.tp_check(m, dp=dp, paged=paged, batch=tokens.shape[0])
        dsp = axes.d if dp > 1 else None
        pspecs = self.tp_param_specs(axes, moe_ep=(moe_impl == "ep"))
        cspecs = self.tp_cache_specs(cache, axes, data_shard=dp > 1)
        args = [params, cache, tokens, jnp.asarray(pos, jnp.int32)]
        specs = [pspecs, cspecs, P(dsp, None), P(dsp)]
        if paged:
            args.append(jnp.asarray(block_tables, jnp.int32))
            specs.append(P(dsp, None))
        if active_sites is not None:
            args.append(jnp.asarray(active_sites, jnp.int32))
            specs.append(P(None))
        if exit_thresholds is not None:
            args.append(jnp.asarray(exit_thresholds, jnp.float32))
            specs.append(P(None))
        outs_spec = {"final": P(dsp)}
        if active_sites is not None:
            outs_spec["ramps"] = P(None, dsp)
        ctx = TpCtx(m, partial(_tp_gather, axes.model),
                    axes.data if dp > 1 else None)

        def body(p, c, toks, po, *rest):
            it = iter(rest)
            tb = next(it) if paged else None
            act = next(it) if active_sites is not None else None
            thr = next(it) if exit_thresholds is not None else None
            return self.decode(
                p, c, toks, po, active_sites=act, axes=axes, mesh=None,
                moe_impl=moe_impl, block_tables=tb, exit_thresholds=thr,
                tp=ctx,
            )

        return shard_map(body, mesh=mesh, in_specs=tuple(specs),
                         out_specs=(cspecs, outs_spec),
                         check_vma=False)(*args)

    def decode_sharded_multi(self, params, cache, tokens, pos, n_steps, *,
                             mesh, n_max, axes=LY.TEST_AXES, active_sites=None,
                             thresholds=None, row_valid=None, moe_impl="dense",
                             block_tables=None):
        """``decode_multi`` through one ``shard_map``: the whole
        ``lax.while_loop`` window runs INSIDE the mapped body, so the
        PR 8 one-sync-per-window contract survives sharding — exit masks
        are evaluated on replicated ramp heads per device and the only
        host round-trip stays at the window boundary."""
        m, dp = self._mesh_degrees(mesh, axes)
        paged = block_tables is not None
        tokens = jnp.asarray(tokens)
        B = tokens.shape[0]
        self.tp_check(m, dp=dp, paged=paged, batch=B)
        dsp = axes.d if dp > 1 else None
        K = 0 if active_sites is None else int(jnp.shape(active_sites)[0])
        if row_valid is None:
            row_valid = jnp.ones((B,), bool)
        pspecs = self.tp_param_specs(axes, moe_ep=(moe_impl == "ep"))
        cspecs = self.tp_cache_specs(cache, axes, data_shard=dp > 1)
        args = [params, cache, tokens, jnp.asarray(pos, jnp.int32),
                jnp.asarray(n_steps, jnp.int32), jnp.asarray(row_valid, bool)]
        specs = [pspecs, cspecs, P(dsp, None), P(dsp), P(), P(dsp)]
        if paged:
            args.append(jnp.asarray(block_tables, jnp.int32))
            specs.append(P(dsp, None))
        if active_sites is not None:
            args.append(jnp.asarray(active_sites, jnp.int32))
            specs.append(P(None))
        if thresholds is not None:
            args.append(jnp.asarray(thresholds, jnp.float32))
            specs.append(P(None))
        rec_specs = (P(None, None, dsp), P(None, None, dsp),
                     P(None, dsp), P(None, dsp), P())
        ctx = TpCtx(m, partial(_tp_gather, axes.model),
                    axes.data if dp > 1 else None)

        def body(p, c, toks, po, n, valid, *rest):
            it = iter(rest)
            tb = next(it) if paged else None
            act = next(it) if active_sites is not None else None
            thr = next(it) if thresholds is not None else None
            return self.decode_multi(
                p, c, toks, po, n, n_max=n_max, active_sites=act,
                thresholds=thr, row_valid=valid, axes=axes, mesh=None,
                moe_impl=moe_impl, block_tables=tb, tp=ctx,
            )

        return shard_map(body, mesh=mesh, in_specs=tuple(specs),
                         out_specs=(cspecs, rec_specs),
                         check_vma=False)(*args)

    def _head_stats(self, params, h_last, pooled, active_sites,
                    axes=None, mesh=None, exit_thresholds=None):
        """Final + ramp confidence stats for serving. h_last: (B,1,d).

        With cfg.pallas_head != 'off', stats stream through the fused
        ramp_head kernel — (B,V) logits are never materialized in HBM.

        With ``exit_thresholds`` (K,) f32 (the device-resident threshold
        vector, aligned with ``active_sites``), the ramps output also
        carries ``exit`` (K,B) int32 — the per-ramp on-device exit
        decision ``(1 − maxprob) < threshold`` (strict, so 0.0 precludes
        exiting). On the pallas path the compare happens INSIDE the fused
        kernel (``ramp_head_exit``); the dense path applies the identical
        f32 formula, so the two agree bit-for-bit with the host's
        ``simulate_exits``."""
        cfg = self.cfg
        h = LY.apply_norm(cfg, params["final_norm"], h_last)
        if cfg.pallas_head != "off":
            return self._head_stats_pallas(params, h, pooled, active_sites,
                                           exit_thresholds=exit_thresholds)
        logits = LY.unembed(cfg, params["tok"], h)[:, 0].astype(jnp.float32)
        if axes is not None:
            logits = LY.constrain(logits, axes.aspec("data", "model"), mesh)
        logits = _mask_pad_vocab(cfg, logits)
        outs = {"final": _stats(logits)}
        if active_sites is not None:
            rl = self.ramp_outputs(params, pooled, site_idx=active_sites,
                                   axes=axes, mesh=mesh)
            rl = _mask_pad_vocab(cfg, rl[:, :, 0])  # (K,B,V)
            outs["ramps"] = _stats(rl)
            if exit_thresholds is not None:
                thr = jnp.asarray(exit_thresholds, jnp.float32)
                unc = 1.0 - outs["ramps"]["maxprob"].astype(jnp.float32)
                outs["ramps"]["exit"] = (unc < thr[:, None]).astype(jnp.int32)
        return outs

    def _head_stats_pallas(self, params, h_normed, pooled, active_sites,
                           exit_thresholds=None):
        from repro.kernels.ramp_head import (
            ramp_head_exit,
            ramp_head_stats,
            stats_to_confidence,
        )

        cfg = self.cfg
        interp = cfg.pallas_head == "interpret"
        wf = params["tok"]["embed"].T if cfg.tie_embeddings else params["tok"]["lm_head"]

        def stats_of(hb, w, thr=None):
            kw = dict(interpret=interp, v_limit=cfg.vocab_size,
                      block_b=min(8, hb.shape[0]), block_v=min(1024, w.shape[1]))
            if thr is None:
                m, s, t, idx = ramp_head_stats(hb, w, **kw)
                mask = None
            else:
                m, s, t, idx, mask = ramp_head_exit(hb, w, thr, **kw)
            label, maxprob, entropy, _ = stats_to_confidence(m, s, t, idx)
            out = {"label": label, "maxprob": maxprob, "entropy": entropy}
            if mask is not None:
                out["exit"] = mask
            return out

        outs = {"final": stats_of(h_normed[:, 0], wf)}
        if active_sites is not None:
            site_idx = jnp.asarray(active_sites, jnp.int32)
            sites = jnp.asarray(self.sites, jnp.int32)
            hs = jnp.take(pooled, jnp.take(sites, site_idx), axis=0)[:, :, 0]  # (K,B,d)
            nw = jnp.take(params["ramps"]["norm_w"], site_idx, axis=0)
            hs = LY.rms_norm(hs, nw[:, None, :])
            K = hs.shape[0]
            B = hs.shape[1]
            per = []
            for kk in range(K):  # K is small & static (ramp budget slots)
                w = wf if cfg.ramp_style == "tied" else jnp.take(
                    params["ramps"]["head"], site_idx[kk], axis=0
                )
                thr = (jnp.broadcast_to(
                    jnp.asarray(exit_thresholds, jnp.float32)[kk], (B,))
                    if exit_thresholds is not None else None)
                per.append(stats_of(hs[kk], w, thr))
            outs["ramps"] = {
                key: jnp.stack([p[key] for p in per]) for key in per[0]
            }
        return outs


def _stats(logits):
    """logits: (..., V) f32 -> {label, maxprob, entropy} (paper's ~1KB
    per-ramp record: top-1 result + error score)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    label = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    maxprob = jnp.exp(jnp.max(logits, axis=-1) - lse)
    p = jax.nn.softmax(logits, axis=-1)
    plogp = jnp.where(p > 0, p * jnp.log(jnp.clip(p, 1e-30)), 0.0)
    entropy = -jnp.sum(plogp, axis=-1)
    return {"label": label, "maxprob": maxprob, "entropy": entropy}


def _mask_pad_vocab(cfg, logits):
    """Sharding-friendly pad-vocab mask (no concat/gather: keeps the vocab
    dim sharded over `model` with zero resharding)."""
    V = cfg.vocab_size
    if logits.shape[-1] == V:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < V, logits, -1e30)


def _masked_ce(cfg, logits, labels):
    """Cross-entropy with -1 padding labels and padded-vocab masking.
    The label log-prob is extracted with an iota/where reduction rather than
    take_along_axis — a vocab-sharded gather would all-gather full logits
    (hundreds of GB at train_4k scale); the reduction psums a scalar."""
    logits = logits.astype(jnp.float32)
    V, Vp = cfg.vocab_size, logits.shape[-1]
    if Vp > V:
        logits = _mask_pad_vocab(cfg, logits)
    valid = labels >= 0
    lab = jnp.clip(labels, 0)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(jnp.where(col == lab[..., None], logits, 0.0), axis=-1)
    nll = (lse - ll) * valid
    return jnp.sum(nll) / jnp.clip(jnp.sum(valid), 1)


def _cache_len(cache) -> int:
    # attn caches have shape (..., B, S, K, hd); mla (..., B, S, r).
    # With windowed local caches present, the GLOBAL (longest) length is the
    # decode mask length -> take the max across leaves.
    found: List[int] = []

    def _find(c):
        if isinstance(c, dict):
            if "k" in c and hasattr(c["k"], "shape"):
                found.append(c["k"].shape[-3])
            if "c" in c and hasattr(c["c"], "shape"):
                found.append(c["c"].shape[-2])
            # skip cross-attn memory ("xkv"): its M tokens are attended
            # unmasked and must not define the self-attn decode mask length
            for key, v in c.items():
                if key not in ("k", "v", "c", "k_pe", "xkv"):
                    _find(v)
        elif isinstance(c, (list, tuple)):
            for v in c:
                _find(v)

    _find(cache)
    if not found:
        raise ValueError("cache has no attention leaves; decode mask undefined")
    return max(found)
