"""Mamba2 (SSD — state-space duality) block [arXiv:2405.21060].

Layout follows the published block: in_proj -> [z | x | B | C | dt],
causal depthwise conv over [x|B|C], SSD scan, gated RMSNorm, out_proj.

``ssd_ref`` is the chunked reference (pure jnp; also the oracle for the
Pallas kernel in repro/kernels/ssd). ``ssd_decode_step`` is the O(1)
recurrent step used for serving.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamInfo
from repro.models.layers import MeshAxes, rms_norm


def mamba_schema(cfg, L=None) -> dict:
    d, di, N, hp = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_headdim
    H = di // hp
    G = cfg.ssm_ngroups
    conv_dim = di + 2 * G * N
    dt = jnp.dtype(cfg.dtype)
    pre = () if L is None else (L,)
    pfx = (None,) * len(pre)
    d_in_proj = 2 * di + 2 * G * N + H
    sc = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    return {
        "in_proj": ParamInfo(pre + (d, d_in_proj), dt, P(*pfx, "data", "model"), "normal:0.02"),
        "conv_w": ParamInfo(pre + (cfg.d_conv, conv_dim), dt, P(*pfx, None, "model"), "normal:0.2"),
        "conv_b": ParamInfo(pre + (conv_dim,), dt, P(*pfx, "model"), "zeros"),
        "A_log": ParamInfo(pre + (H,), jnp.float32, P(*pfx), "ssm_a"),
        "D": ParamInfo(pre + (H,), jnp.float32, P(*pfx), "ones"),
        "dt_bias": ParamInfo(pre + (H,), jnp.float32, P(*pfx), "dt_bias"),
        "norm_w": ParamInfo(pre + (di,), jnp.float32, P(*pfx), "zeros"),
        "out_proj": ParamInfo(pre + (di, d), dt, P(*pfx, "model", "data"), f"normal:{sc}"),
    }


def segsum(a):
    """Stable segment-sum: out[..., i, j] = sum a[..., j+1:i+1], -inf for j>i.
    a: (..., T) -> (..., T, T)."""
    T = a.shape[-1]
    x = jnp.repeat(a[..., None], T, axis=-1)  # x[..., i, j] = a_i
    mask = jnp.tril(jnp.ones((T, T), bool), -1)
    x = jnp.where(mask, x, 0.0)
    x = jnp.cumsum(x, axis=-2)  # out[i,j] = Σ_{j<i'<=i} a_i'
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, x, -jnp.inf)


def ssd_ref(x, dt, A, B, C, chunk: int = 64, init_state=None):
    """Chunked SSD (Mamba2 Algorithm; fp32 internals).

    x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) (negative);
    B, C: (b, s, g, n). Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    x, dt = x.astype(jnp.float32), dt.astype(jnp.float32)
    B = jnp.repeat(B.astype(jnp.float32), rep, axis=2)  # (b,s,h,n)
    C = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, h, n)
    Cc = C.reshape(b, nc, chunk, h, n)
    a = dtc * A  # (b,nc,l,h)
    a = jnp.moveaxis(a, -1, -2)  # (b,nc,h,l)
    a_cum = jnp.cumsum(a, axis=-1)
    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(segsum(a))  # (b,nc,h,l,l)
    Ydiag = jnp.einsum("bclhn,bcshn,bchls,bcshp->bclhp", Cc, Bc, L, xc * dtc[..., None])
    # 2. chunk states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (b,nc,h,l)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", Bc, decay_states, xc * dtc[..., None])
    # 3. inter-chunk recurrence
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    chunk_decay = jnp.exp(a_cum[..., -1])  # (b,nc,h)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    final, entering = jax.lax.scan(
        step,
        init_state,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    entering = jnp.moveaxis(entering, 0, 1)  # (b,nc,h,p,n)
    # 4. state -> output contribution
    state_decay = jnp.exp(a_cum)  # (b,nc,h,l)
    Yoff = jnp.einsum("bclhn,bchpn,bchl->bclhp", Cc, entering, state_decay)
    y = (Ydiag + Yoff).reshape(b, s, h, p)
    return y, final


def ssd_decode_step(state, x, dt, A, B, C):
    """One recurrent step. state: (b,h,p,n); x: (b,h,p); dt: (b,h);
    A: (h,); B,C: (b,g,n). Returns (y (b,h,p), new_state)."""
    b, h, p = x.shape
    g = B.shape[1]
    rep = h // g
    x, dt = x.astype(jnp.float32), dt.astype(jnp.float32)
    B = jnp.repeat(B.astype(jnp.float32), rep, axis=1)  # (b,h,n)
    C = jnp.repeat(C.astype(jnp.float32), rep, axis=1)
    dA = jnp.exp(dt * A)  # (b,h)
    new_state = state * dA[..., None, None] + jnp.einsum(
        "bhn,bhp->bhpn", B, x * dt[..., None]
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, C)
    return y, new_state


def _conv_step(conv_state, xbc, w, b):
    """Depthwise causal conv, single step. conv_state: (B, d_conv-1, D);
    xbc: (B, D). Returns (out (B,D), new_state)."""
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)  # (B,d_conv,D)
    out = jnp.einsum("bkd,kd->bd", window, w) + b
    return jax.nn.silu(out), window[:, 1:]


def mamba_apply(
    cfg,
    p,
    x,
    *,
    axes: MeshAxes,
    mesh=None,
    cache: Optional[dict] = None,
    chunk: int = 64,
):
    """Mamba2 block. x: (B,S,d). If cache given (decode, S==1): uses
    recurrent step; cache = {'conv': (B,d_conv-1,convdim), 'ssm': (B,h,p,n)}.
    Returns (out (B,S,d), new_cache)."""
    Bb, S, d = x.shape
    di, N, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_headdim
    H, G = di // hp, cfg.ssm_ngroups
    conv_dim = di + 2 * G * N

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + conv_dim]
    dt_raw = zxbcdt[..., di + conv_dim :]  # (B,S,H)
    A = -jnp.exp(p["A_log"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if cache is not None and S == 1:
        xbc_t, new_conv = _conv_step(cache["conv"], xbc[:, 0], p["conv_w"], p["conv_b"])
        xs = xbc_t[:, :di].reshape(Bb, H, hp)
        Bmat = xbc_t[:, di : di + G * N].reshape(Bb, G, N)
        Cmat = xbc_t[:, di + G * N :].reshape(Bb, G, N)
        y, new_ssm = ssd_decode_step(cache["ssm"], xs, dt[:, 0], A, Bmat, Cmat)
        y = y + p["D"][:, None] * xs.astype(jnp.float32)
        y = y.reshape(Bb, 1, di)
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    else:
        # causal depthwise conv over sequence
        pad = jnp.zeros((Bb, cfg.d_conv - 1, conv_dim), xbc.dtype)
        xpad = jnp.concatenate([pad, xbc], axis=1)
        idx = jnp.arange(S)[:, None] + jnp.arange(cfg.d_conv)[None]
        windows = xpad[:, idx]  # (B,S,d_conv,convdim)
        xbc_c = jax.nn.silu(jnp.einsum("bskd,kd->bsd", windows, p["conv_w"]) + p["conv_b"])
        xs = xbc_c[..., :di].reshape(Bb, S, H, hp)
        Bmat = xbc_c[..., di : di + G * N].reshape(Bb, S, G, N)
        Cmat = xbc_c[..., di + G * N :].reshape(Bb, S, G, N)
        ck = chunk if S % chunk == 0 else S
        y, final = ssd_ref(xs, dt, A, Bmat, Cmat, chunk=ck)
        y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(Bb, S, di)
        new_cache = None
        if cache is not None:  # prefill: fill caches for subsequent decode
            new_cache = {
                "conv": xpad[:, S : S + cfg.d_conv - 1] if cfg.d_conv > 1 else xpad[:, :0],
                "ssm": final,
            }
            # conv state = last (d_conv-1) inputs
            new_cache["conv"] = xpad[:, -(cfg.d_conv - 1) :]

    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(y, p["norm_w"])
    return y @ p["out_proj"], new_cache


def mamba_cache_schema(cfg, batch: int, L=None) -> dict:
    di, N, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_headdim
    H, G = di // hp, cfg.ssm_ngroups
    conv_dim = di + 2 * G * N
    dt = jnp.dtype(cfg.dtype)
    pre = () if L is None else (L,)
    pfx = (None,) * len(pre)
    return {
        "conv": ParamInfo(pre + (batch, cfg.d_conv - 1, conv_dim), dt, P(*pfx, "data", None, "model"), "zeros"),
        "ssm": ParamInfo(pre + (batch, H, hp, N), jnp.float32, P(*pfx, "data", "model", None, None), "zeros"),
    }


def mamba_paged_cache_schema(cfg, n_blocks: int, L=None) -> dict:
    """Block-pooled recurrent state: one STATE PAGE per slot, drawn from
    the same refcounted pool as token pages. The leading dim is the pool
    (``n_blocks``), not batch — a slot's whole ``{conv, ssm}`` state lives
    in the page at its FIRST block-table entry, and decode reads/writes it
    through the table. State is per-slot (not per-token), so the page
    count is O(slots) and prefix share/CoW degenerate to private
    allocation (the runner refuses sharing for mamba plans)."""
    di, N, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_headdim
    H, G = di // hp, cfg.ssm_ngroups
    conv_dim = di + 2 * G * N
    dt = jnp.dtype(cfg.dtype)
    pre = () if L is None else (L,)
    pfx = (None,) * len(pre)
    return {
        "conv": ParamInfo(pre + (n_blocks, cfg.d_conv - 1, conv_dim), dt, P(*pfx, None, None, "model"), "zeros"),
        "ssm": ParamInfo(pre + (n_blocks, H, hp, N), jnp.float32, P(*pfx, None, "model", None, None), "zeros"),
    }
