"""Top-k MoE with expert parallelism.

Two implementations:
  * ``moe_apply_dense`` — oracle: every expert processes every token
    (O(T·E·ff) FLOPs). Used in tests as the reference.
  * ``moe_apply_ep`` — production: sort-based token-dropping dispatch inside
    ``jax.shard_map``; experts sharded over the `model` axis, tokens over
    `data`; explicit all-to-alls carry tokens to expert owners and back.
    FLOPs ≈ capacity_factor · top_k-equivalent dense compute.

The sort-based dispatch avoids the O(T·E·C) one-hot cube of einsum-style
GShard dispatch: assignments are argsorted by expert id and scattered into
(E, C) slot buffers (capacity overflows dropped, residual passthrough).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax

from repro.compat import mesh_axis_size, shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ParamInfo
from repro.models.layers import MeshAxes, act_fn


def moe_schema(cfg, L=None) -> dict:
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    dt = jnp.dtype(cfg.dtype)
    pre = () if L is None else (L,)
    pfx = (None,) * len(pre)
    sc = 0.02 / math.sqrt(2 * max(cfg.n_layers, 1))
    sch = {
        "router": ParamInfo(pre + (d, E), jnp.float32, P(*pfx, None, None), "normal:0.006"),
        "w_gate": ParamInfo(pre + (E, d, ff), dt, P(*pfx, "model", "data", None), "normal:0.02"),
        "w_up": ParamInfo(pre + (E, d, ff), dt, P(*pfx, "model", "data", None), "normal:0.02"),
        "w_down": ParamInfo(pre + (E, ff, d), dt, P(*pfx, "model", None, "data"), f"normal:{sc}"),
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * cfg.moe_d_ff
        sch["shared"] = {
            "w_gate": ParamInfo(pre + (d, sff), dt, P(*pfx, "data", "model"), "normal:0.02"),
            "w_up": ParamInfo(pre + (d, sff), dt, P(*pfx, "data", "model"), "normal:0.02"),
            "w_down": ParamInfo(pre + (sff, d), dt, P(*pfx, "model", "data"), f"normal:{sc}"),
        }
    return sch


def _router(cfg, p, x2d):
    """x2d: (T, d) -> (gates (T,k) f32 normalized, idx (T,k) i32, probs)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.clip(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return gates, idx, probs


def _aux_loss(cfg, probs, idx):
    """Switch-style load-balance loss."""
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=1), axis=0
    ) / cfg.top_k
    return E * jnp.sum(me * ce)


def _expert_ffn(cfg, p, xs):
    """xs: (E, C, d) -> (E, C, d); per-expert SwiGLU."""
    a = act_fn(cfg.act)
    h = a(jnp.einsum("ecd,edf->ecf", xs, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xs, p["w_up"]
    )
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _shared_ffn(cfg, p, x):
    a = act_fn(cfg.act)
    h = a(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


def moe_apply_dense(cfg, p, x):
    """Oracle: dense dispatch, no drops, no parallelism. x: (B,S,d)."""
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    gates, idx, probs = _router(cfg, p, x2)
    E = cfg.n_experts
    outs = _expert_ffn(cfg, p, jnp.broadcast_to(x2[None], (E,) + x2.shape))
    # combine: for each token, sum gate_j * outs[idx_j, token]
    tok = jnp.arange(x2.shape[0])
    y = jnp.zeros_like(x2, dtype=jnp.float32)
    for j in range(cfg.top_k):
        y = y + gates[:, j : j + 1] * outs[idx[:, j], tok].astype(jnp.float32)
    y = y.astype(x.dtype)
    if cfg.n_shared_experts:
        y = y + _shared_ffn(cfg, p["shared"], x2)
    return y.reshape(B, S, d), _aux_loss(cfg, probs, idx)


def _dispatch_local(cfg, x2, gates, idx, capacity):
    """Sort-based dispatch of local tokens into (E, C, d) slot buffers.

    Returns (buf (E,C,d), slot (T*k,), keep (T*k,), tok (T*k,), gate (T*k,)).
    """
    T, d = x2.shape
    k, E, C = cfg.top_k, cfg.n_experts, capacity
    flat_e = idx.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    M = se.shape[0]
    is_start = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, jnp.arange(M), 0))
    pos = jnp.arange(M) - seg_start
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)  # E*C = drop sentinel
    buf = (
        jnp.zeros((E * C, d), x2.dtype)
        .at[slot]
        .set(x2[st] * keep[:, None].astype(x2.dtype), mode="drop")
        .reshape(E, C, d)
    )
    return buf, slot, keep, st, sg


def _ep_device_body(cfg, axes: MeshAxes, m: int, x_blk, gates_blk, idx_blk, wg, wu, wd):
    """Per-device EP dispatch body (runs INSIDE a shard_map over
    ``axes.model``). ``x_blk``/``gates_blk``/``idx_blk`` are this device's
    data-shard (replicated over model); ``wg/wu/wd`` its (E/m, ...) expert
    slice. Module-level so the tensor-parallel decode path — itself one big
    shard_map — can reuse the identical dispatch without nesting maps."""
    E = cfg.n_experts
    mi = jax.lax.axis_index(axes.model)
    T_data = x_blk.shape[0]
    Tl = max(1, -(-T_data // m))  # ceil: decode batches can be < m
    pad = Tl * m - T_data
    if pad:
        x_blk = jnp.pad(x_blk, ((0, pad), (0, 0)))
        gates_blk = jnp.pad(gates_blk, ((0, pad), (0, 0)))
        idx_blk = jnp.pad(idx_blk, ((0, pad), (0, 0)))
    xs = jax.lax.dynamic_slice_in_dim(x_blk, mi * Tl, Tl, 0)
    gs = jax.lax.dynamic_slice_in_dim(gates_blk, mi * Tl, Tl, 0)
    ii = jax.lax.dynamic_slice_in_dim(idx_blk, mi * Tl, Tl, 0)
    C = max(1, int(cfg.capacity_factor * Tl * cfg.top_k / E))
    buf, slot, keep, st, sg = _dispatch_local(cfg, xs, gs, ii, C)
    # (E, C, d) -> experts to owners: (E/m, C*m, d)
    buf = jax.lax.all_to_all(buf, axes.model, split_axis=0, concat_axis=1, tiled=True)
    h = act_fn(cfg.act)(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
        "ecd,edf->ecf", buf, wu
    )
    out = jnp.einsum("ecf,efd->ecd", h, wd)
    out = jax.lax.all_to_all(out, axes.model, split_axis=1, concat_axis=0, tiled=True)
    out = jnp.pad(out.reshape(E * C, x_blk.shape[-1]), ((0, 1), (0, 0)))
    taken = out[slot] * (sg * keep)[:, None].astype(out.dtype)
    y = jnp.zeros((Tl, x_blk.shape[-1]), jnp.float32).at[st].add(taken.astype(jnp.float32))
    y = y.astype(x_blk.dtype)
    y = jax.lax.all_gather(y, axes.model, axis=0, tiled=True)
    return y[:T_data] if pad else y


def moe_apply_ep_device(cfg, p_local, x, axes: MeshAxes, m: int):
    """EP MoE callable from INSIDE an existing shard_map body over
    ``axes.model`` (the tensor-parallel decode path). ``p_local`` holds
    this device's (E/m, ...) expert slice of w_gate/w_up/w_down with
    router (and shared experts) replicated; ``x`` (B,S,d) is the
    device-local activation block, replicated over model."""
    B, S, d = x.shape
    x2 = x.reshape(-1, d)
    gates, idx, probs = _router(cfg, p_local, x2)
    aux = _aux_loss(cfg, probs, idx)
    y = _ep_device_body(
        cfg, axes, m, x2, gates, idx,
        p_local["w_gate"], p_local["w_up"], p_local["w_down"],
    )
    if cfg.n_shared_experts:
        y = y + _shared_ffn(cfg, p_local["shared"], x2)
    return y.reshape(B, S, d), aux


def moe_apply_ep(cfg, p, x, axes: MeshAxes, mesh):
    """Expert-parallel MoE via shard_map. x: (B,S,d) sharded over data.

    Inside the map each device owns E/m experts; tokens are model-axis
    sliced, dispatched locally, all-to-all'd to expert owners, processed,
    and returned. Output replicated over model (all-gather)."""
    B, S, d = x.shape
    E = cfg.n_experts
    m = mesh_axis_size(mesh, axes.model)

    x2 = x.reshape(-1, d)
    gates, idx, probs = _router(cfg, p, x2)
    aux = _aux_loss(cfg, probs, idx)

    if mesh is None or m == 1:
        # single-device fast path: local dispatch without collectives
        T = x2.shape[0]
        C = max(1, int(cfg.capacity_factor * T * cfg.top_k / E))
        buf, slot, keep, st, sg = _dispatch_local(cfg, x2, gates, idx, C)
        out = _expert_ffn(cfg, p, buf).reshape(E * C, d)
        out = jnp.pad(out, ((0, 1), (0, 0)))  # row E*C = drop sentinel
        taken = out[slot] * (sg * keep)[:, None].astype(out.dtype)
        y = jnp.zeros_like(x2, dtype=jnp.float32).at[st].add(taken.astype(jnp.float32))
        y = y.astype(x.dtype)
    else:
        dsz = 1
        for a in axes.data:
            dsz *= mesh_axis_size(mesh, a)
        T = x2.shape[0]
        # batch-1 decode: tokens can't shard over data -> replicate there
        # (model-axis token slicing still parallelizes the expert compute)
        dspec = axes.aspec("data", None) if T % dsz == 0 else P(None, None)

        y = shard_map(
            partial(_ep_device_body, cfg, axes, m),
            mesh=mesh,
            in_specs=(
                dspec,
                dspec,
                dspec,
                P(axes.model, None, None),
                P(axes.model, None, None),
                P(axes.model, None, None),
            ),
            out_specs=dspec,
            check_vma=False,
        )(x2, gates, idx, p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared_experts:
        y = y + _shared_ffn(cfg, p["shared"], x2)
    return y.reshape(B, S, d), aux


def moe_apply(cfg, p, x, axes: MeshAxes, mesh=None, impl: str = "ep"):
    if impl == "dense":
        return moe_apply_dense(cfg, p, x)
    return moe_apply_ep(cfg, p, x, axes, mesh)
