"""Chunked, atomic, mesh-agnostic checkpointing with async writes.

Design for 1000+ nodes (see DESIGN.md §5):
  * each leaf saved as its own .npy chunk -> parallel/partial writes and
    per-leaf integrity; a manifest (msgpack) carries the tree structure;
  * atomic: write to `step_XXXX.tmp/`, fsync, rename — a crashed writer
    never corrupts the latest checkpoint;
  * mesh-agnostic: leaves are stored as host numpy, so a checkpoint taken
    on a (16,16) mesh restores onto (2,16,16) or a single CPU device
    (elastic scaling / shrink-to-debug);
  * async: `save_async` snapshots to host then writes on a worker thread,
    keeping the train loop running (overlap I/O with compute);
  * keep-N retention + resume discovery for preemption restart.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    root: Any = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for i, p in enumerate(parts):
            last = i == len(parts) - 1
            if last:
                node[p] = val
            else:
                node = node.setdefault(p, {})
    return _restore_lists(root)


def _restore_lists(node):
    if isinstance(node, dict):
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return [_restore_lists(v) for _, v in items]
        return {k: _restore_lists(v) for k, v in node.items()}
    return node


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save -----------------------------------------------------------------

    def save(self, state, step: int):
        host = jax.tree.map(lambda x: np.asarray(x), state)
        self._write(host, step)

    def save_async(self, state, step: int):
        """Snapshot to host memory synchronously, write on a worker thread."""
        host = jax.tree.map(lambda x: np.asarray(x), state)  # device_get barrier
        self.wait()
        self._thread = threading.Thread(target=self._write, args=(host, step), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host_state, step: int):
        name = f"step_{step:08d}"
        tmp = os.path.join(self.dir, name + ".tmp")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_state)
        manifest = {}
        for i, (key, val) in enumerate(flat.items()):
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), np.asarray(val), allow_pickle=False)
            manifest[key] = fn
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
        dfd = os.open(tmp, os.O_RDONLY)
        os.fsync(dfd)
        os.close(dfd)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- restore -----------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, sharding_tree=None):
        """Load a checkpoint; optionally device_put each leaf with the given
        sharding tree (elastic reload onto any mesh)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {
            key: np.load(os.path.join(path, fn), allow_pickle=False)
            for key, fn in manifest["leaves"].items()
        }
        tree = _unflatten(flat)
        if sharding_tree is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sharding_tree)
        return tree
