from repro.data.synthetic import (
    Stream,
    TokenPipeline,
    make_decode_stream,
    make_image_stream,
    make_token_stream,
)

__all__ = [
    "Stream",
    "TokenPipeline",
    "make_decode_stream",
    "make_image_stream",
    "make_token_stream",
]
