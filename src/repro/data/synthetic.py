"""Synthetic drifting workloads.

The paper's phenomena to reproduce:
  * CV streams (video): strong temporal correlation — object difficulty
    persists across frames; drift is slow (scene changes).
  * NLP streams (reviews): weak continuity — difficulty is closer to iid
    with abrupt topic shifts; past data is less predictive (§5.2).

``make_image_stream``: class = one of C spatial patterns; difficulty =
noise level following a Markov dwell process (CV) or iid-with-shifts (NLP
mode). ``make_token_stream``: class-indicative tokens mixed with noise
tokens at a difficulty-controlled rate.

Also here: the deterministic, resumable token pipeline for LM training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Stream:
    data: np.ndarray  # (N, ...) model inputs
    labels: np.ndarray  # (N,) generative class ids (NOT used as accuracy truth)
    difficulty: np.ndarray  # (N,) in [0,1]


def _difficulty_process(
    n: int, *, mode: str, rng, lo=0.05, hi=0.9, dwell=300, shift_every=800
) -> np.ndarray:
    if mode == "cv":  # Markov dwell: easy/hard scenes persisting ~dwell frames
        d = np.empty(n)
        cur = rng.uniform(lo, hi)
        i = 0
        while i < n:
            k = int(rng.exponential(dwell)) + 30
            d[i : i + k] = np.clip(cur + rng.normal(0, 0.02, min(k, n - i)), 0, 1)
            cur = np.clip(rng.uniform(lo, hi), 0, 1)
            i += k
        return d
    # nlp: iid difficulty with abrupt regime shifts of the mean
    d = np.empty(n)
    i = 0
    while i < n:
        k = int(rng.exponential(shift_every)) + 100
        mean = rng.uniform(lo, hi)
        d[i : i + k] = np.clip(rng.normal(mean, 0.15, min(k, n - i)), 0, 1)
        i += k
    return d


def make_image_stream(
    n: int,
    *,
    img_size: int = 16,
    n_classes: int = 10,
    mode: str = "cv",
    seed: int = 0,
    proto_mix: float = 0.0,
) -> Stream:
    """proto_mix > 0 blends each class prototype with its neighbor's,
    making classes confusable (harder streams: confidence stops being
    perfectly separable, so threshold tuning genuinely matters)."""
    rng = np.random.default_rng(seed)
    # class prototypes: smooth random patterns, renormalized to unit power
    protos = rng.normal(0, 1, (n_classes, img_size, img_size, 3)).astype(np.float32)
    for c in range(n_classes):  # low-pass for spatial structure
        for _ in range(2):
            protos[c] = (
                protos[c]
                + np.roll(protos[c], 1, 0)
                + np.roll(protos[c], 1, 1)
                + np.roll(protos[c], -1, 0)
                + np.roll(protos[c], -1, 1)
            ) / 5.0
        protos[c] /= protos[c].std() + 1e-9
    if proto_mix > 0:
        base = protos.copy()
        for c in range(n_classes):
            protos[c] = (1 - proto_mix) * base[c] + proto_mix * base[(c + 1) % n_classes]
            protos[c] /= protos[c].std() + 1e-9
    diff = _difficulty_process(n, mode=mode, rng=rng)
    if mode == "cv":  # objects persist across frames
        labels = np.empty(n, np.int64)
        i = 0
        while i < n:
            k = int(rng.exponential(300)) + 15
            labels[i : i + k] = rng.integers(n_classes)
            i += k
    else:
        labels = rng.integers(0, n_classes, n)
    noise = rng.normal(0, 1, (n, img_size, img_size, 3)).astype(np.float32)
    scale = (0.15 + 1.6 * diff)[:, None, None, None].astype(np.float32)
    data = protos[labels] + noise * scale
    return Stream(data.astype(np.float32), labels, diff)


def make_token_stream(
    n: int,
    *,
    seq_len: int = 32,
    vocab: int = 512,
    n_classes: int = 10,
    mode: str = "nlp",
    seed: int = 0,
) -> Stream:
    rng = np.random.default_rng(seed)
    # Compositional class code: label = (a + b) mod C where `a` is carried by
    # tokens from range-A slice a and `b` by range-B slice b. Single-token
    # statistics are insufficient (each slice is shared across classes), so
    # shallow ramps genuinely underperform deep ones on noisy inputs.
    C = n_classes
    half = (vocab - 2) // 2
    perA = max(half // C, 2)
    perB = max(half // C, 2)
    diff = _difficulty_process(n, mode=mode, rng=rng)
    labels = rng.integers(0, C, n)
    if mode == "cv":
        labels = make_image_stream(n, mode="cv", n_classes=C, seed=seed).labels
    data = np.empty((n, seq_len), np.int64)
    for i in range(n):
        c = labels[i]
        a = rng.integers(C)
        b = (c - a) % C
        tokA = rng.integers(1 + a * perA, 1 + (a + 1) * perA, seq_len)
        tokB = rng.integers(1 + half + b * perB, 1 + half + (b + 1) * perB, seq_len)
        sig = np.where(rng.random(seq_len) < 0.5, tokA, tokB)
        noise = rng.integers(1, vocab, seq_len)
        m = rng.random(seq_len) < (0.15 + 0.8 * diff[i])
        data[i] = np.where(m, noise, sig)
        data[i, 0] = 0  # CLS token
    return Stream(data, labels, diff)


def make_decode_stream(
    n: int,
    *,
    seq_len: int = 24,
    vocab: int = 512,
    predict: float = 0.9,
    shift: int = 17,
    mode: str = "nlp",
    seed: int = 0,
) -> Stream:
    """Prompts for generative decode serving: Markov chains where token
    ``t+1 = t + shift (mod vocab)`` with per-position probability scaled by
    the stream's difficulty process, else a uniform noise token.

    The transition needs only the *current* token, so both the final head
    and mid-depth ramps of a briefly-trained tiny LM learn it — easy
    (predictable) decode steps become confidently exitable while noisy
    steps stay uncertain: the generative analogue of the paper's easy/hard
    traffic mix. ``difficulty`` follows the same drift process as the
    classification streams, so controllers see regime shifts here too."""
    rng = np.random.default_rng(seed)
    diff = _difficulty_process(n, mode=mode, rng=rng)
    p = np.clip(predict * (1.0 - 0.7 * diff), 0.05, 1.0)
    data = np.empty((n, seq_len), np.int64)
    for i in range(n):
        x = int(rng.integers(1, vocab))
        for t in range(seq_len):
            data[i, t] = x
            if rng.random() < p[i]:
                x = 1 + (x - 1 + shift) % (vocab - 1)
            else:
                x = int(rng.integers(1, vocab))
    return Stream(data, np.zeros(n, np.int64), diff)


# ---------------------------------------------------------------------------
# deterministic resumable LM token pipeline (training substrate)


class TokenPipeline:
    """Synthetic LM pretraining stream: Zipfian unigrams + Markov bigram
    structure; deterministic given (seed, step) — checkpoint-resumable by
    construction (store just the step)."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0):
        self.vocab, self.seq_len, self.batch, self.seed = vocab, seq_len, batch, seed
        rng = np.random.default_rng(seed)
        ranks = np.arange(1, vocab + 1)
        self.probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self.shift = rng.integers(1, vocab - 1)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        base = rng.choice(self.vocab, (self.batch, self.seq_len + 1), p=self.probs)
        # inject predictable bigrams: token t follows (t - shift) 50% of time
        m = rng.random((self.batch, self.seq_len)) < 0.5
        nxt = (base[:, :-1] + self.shift) % self.vocab
        base[:, 1:] = np.where(m, nxt, base[:, 1:])
        return {
            "tokens": base[:, :-1].astype(np.int32),
            "labels": base[:, 1:].astype(np.int32),
        }
