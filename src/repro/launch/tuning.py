"""Runtime tuning presets: XLA flags + allocator env for serving runs.

The multi-step decode window (``DecodeRunner.step_multi``) moves the
decode hot loop into a single on-device ``lax.while_loop``; the env knobs
that matter for it are process-level and must be set BEFORE jax
initializes its backends. This module centralizes them as named presets
(``--runtime-preset`` on the serve launcher) instead of ad-hoc shell
exports:

  * ``serve``  — production serving: step markers at the outermost while
    loop (the sync window IS the step), preallocated device arena so the
    donated cache buffers never bounce through the allocator mid-run,
    quiet logs, tcmalloc large-alloc reports off.
  * ``bench``  — benchmarking: same step markers but the ``platform``
    allocator with preallocation OFF, so per-dispatch allocation cost is
    visible instead of hidden in a warm arena.
  * ``host-sim`` — CPU event-loop simulation (CI, laptops): pin jax to
    the host platform with a single device.

``XLA_FLAGS`` is MERGED, never clobbered: flags already present in the
environment win over the preset's (an operator override outranks a
default). All other vars are set only if absent unless ``force=True``.
"""
from __future__ import annotations

import os
import sys
import warnings
from typing import Dict, MutableMapping, Optional

PRESETS: Dict[str, Dict[str, str]] = {
    "serve": {
        # 1 = mark steps at the outermost while loop — with multi-step
        # decode that loop IS the sync window, so profilers/step counters
        # see one step per window, not per fused token
        "XLA_FLAGS": "--xla_step_marker_location=1",
        "XLA_PYTHON_CLIENT_PREALLOCATE": "true",
        "XLA_PYTHON_CLIENT_MEM_FRACTION": "0.9",
        "TF_CPP_MIN_LOG_LEVEL": "4",
        "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
    },
    "bench": {
        "XLA_FLAGS": "--xla_step_marker_location=1",
        "XLA_PYTHON_CLIENT_PREALLOCATE": "false",
        "XLA_PYTHON_CLIENT_ALLOCATOR": "platform",
        "TF_CPP_MIN_LOG_LEVEL": "4",
    },
    "host-sim": {
        "XLA_FLAGS": "--xla_force_host_platform_device_count=1",
        "JAX_PLATFORMS": "cpu",
        "TF_CPP_MIN_LOG_LEVEL": "4",
    },
}


def _flag_name(tok: str) -> str:
    return tok.split("=", 1)[0]


def merge_xla_flags(preset_flags: str, existing: Optional[str]) -> str:
    """Merge preset XLA flags under any already-exported ones. A flag
    set in the environment shadows the preset's value for the same flag
    name; order is existing-first (XLA honors the LAST occurrence, but we
    drop shadowed preset tokens entirely so the result reads cleanly)."""
    have = [t for t in (existing or "").split() if t]
    names = {_flag_name(t) for t in have}
    add = [t for t in preset_flags.split() if _flag_name(t) not in names]
    return " ".join(have + add)


def _backend_live() -> bool:
    """True once jax has initialized a backend — merely importing jax is
    fine (XLA parses these vars lazily at backend init), so the check
    peeks at the bridge's backend registry, failing safe to False."""
    jx = sys.modules.get("jax")
    if jx is None:
        return False
    bridge = getattr(getattr(jx, "_src", None), "xla_bridge", None)
    return bool(getattr(bridge, "_backends", None))


def apply_preset(
    name: str,
    env: Optional[MutableMapping[str, str]] = None,
    *,
    force: bool = False,
) -> Dict[str, str]:
    """Apply preset ``name`` to ``env`` (default ``os.environ``); returns
    the vars actually written. Warns (but still writes, for any forked
    workers) when jax is already imported — backend-level vars set after
    initialization are silently ignored by XLA."""
    if name in (None, "", "none"):
        return {}
    if name not in PRESETS:
        raise ValueError(f"unknown runtime preset {name!r}; have {sorted(PRESETS)}")
    env = os.environ if env is None else env
    if env is os.environ and _backend_live():
        warnings.warn(
            "runtime preset applied after a jax backend initialized: "
            "XLA_FLAGS/allocator vars will not affect this process",
            RuntimeWarning,
            stacklevel=2,
        )
    written: Dict[str, str] = {}
    for k, v in PRESETS[name].items():
        if k == "XLA_FLAGS":
            merged = merge_xla_flags(v, env.get(k))
            if env.get(k) != merged:
                env[k] = written[k] = merged
        elif force or k not in env:
            env[k] = written[k] = v
    return written
