"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) data×model (256 v5e chips).
Multi-pod: (2, 16, 16) pod×data×model (512 chips); the `pod` axis carries
pure data parallelism across the ICI-disjoint pods (gradient all-reduce
crosses DCI once per step).
"""
from __future__ import annotations

import jax

from repro.models.layers import MeshAxes


def make_mesh(shape, axes):
    """`jax.make_mesh` with explicit Auto axis types where the installed
    JAX supports them (jax.sharding.AxisType landed after 0.4.x; older
    versions already default every axis to Auto)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_axes(mesh, *, fsdp: bool = True) -> MeshAxes:
    names = mesh.axis_names
    data = ("pod", "data") if "pod" in names else ("data",)
    return MeshAxes(data=data, model="model" if "model" in names else None, fsdp=fsdp)


def make_test_mesh(data: int = 1, model: int = 1):
    return make_mesh((data, model), ("data", "model"))
