"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) data×model (256 v5e chips).
Multi-pod: (2, 16, 16) pod×data×model (512 chips); the `pod` axis carries
pure data parallelism across the ICI-disjoint pods (gradient all-reduce
crosses DCI once per step).
"""
from __future__ import annotations

import jax

from repro.models.layers import MeshAxes


def make_mesh(shape, axes):
    """`jax.make_mesh` with explicit Auto axis types where the installed
    JAX supports them (jax.sharding.AxisType landed after 0.4.x; older
    versions already default every axis to Auto)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_axes(mesh, *, fsdp: bool = True) -> MeshAxes:
    names = mesh.axis_names
    data = ("pod", "data") if "pod" in names else ("data",)
    return MeshAxes(data=data, model="model" if "model" in names else None, fsdp=fsdp)


def make_test_mesh(data: int = 1, model: int = 1):
    return make_mesh((data, model), ("data", "model"))


def make_serving_mesh(*, tp: int = 1, dp: int = 1, pp: int = 1):
    """Mesh for the sharded decode serving paths over the devices of the
    current backend. ``tp``/``dp`` build a ``(data, model)`` mesh for
    tensor-parallel decode (``ShardedDecodeRunner``); ``pp`` builds a
    1-D ``(stage,)`` mesh for exit-gated pipeline decode windows — the
    two are alternative layouts, not composable on one mesh here."""
    if pp > 1:
        if tp > 1 or dp > 1:
            raise ValueError("pp is a (stage,) mesh; combine with tp/dp "
                             "by nesting runners, not one mesh")
        need, shape, axes = pp, (pp,), ("stage",)
    else:
        need, shape, axes = dp * tp, (dp, tp), ("data", "model")
    n = len(jax.devices())
    if n < need:
        raise ValueError(
            f"mesh {shape} needs {need} devices, backend has {n} — on CPU "
            "export XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "before the process starts")
    return make_mesh(shape, axes)
