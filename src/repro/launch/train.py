"""Production training launcher.

Single-process CPU runs use a (1,1) mesh; on real pods the same program
lowers against make_production_mesh() (the dry-run proves it). Features:
checkpoint/restart (atomic, async), deterministic resumable data, ramp-only
or full training, elastic restart onto a different mesh.

  PYTHONPATH=src python -m repro.launch.train --arch tiny:qwen2-1.5b \
      --steps 100 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_tiny
from repro.data import TokenPipeline
from repro.models import build_model
from repro.training import AdamWConfig, TrainConfig, init_state, make_train_step


def resolve_cfg(spec: str):
    if spec.startswith("tiny:"):
        return get_tiny(spec[5:])
    return get_config(spec)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help="e.g. qwen2-1.5b or tiny:qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mode", default="full", choices=["full", "ramps_only"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = resolve_cfg(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(
        steps=args.steps, lr=args.lr, train_mode=args.mode, seed=args.seed,
        checkpoint_every=args.ckpt_every,
    )
    step_fn, opt_cfg = make_train_step(model, tcfg)
    jstep = jax.jit(step_fn)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    start = 0
    state = None
    if args.resume and mgr is not None and mgr.latest_step() is not None:
        state = mgr.restore()
        start = int(np.asarray(state["step"]))
        print(f"resumed from step {start}")
    if state is None:
        state = init_state(model, jax.random.PRNGKey(args.seed), opt_cfg)

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    t0 = time.perf_counter()
    for s in range(start, args.steps):
        batch = pipe.batch_at(s)  # deterministic: resume == never-crashed
        state, out = jstep(state, {k: jax.numpy.asarray(v) for k, v in batch.items()})
        if s % max(args.steps // 10, 1) == 0 or s == args.steps - 1:
            print(f"step {s:5d} loss {float(out['loss']):.4f}")
        if mgr is not None and args.ckpt_every and (s + 1) % args.ckpt_every == 0:
            mgr.save_async(state, step=s + 1)
    if mgr is not None:
        mgr.wait()
        mgr.save(state, step=args.steps)
    print(f"done: {args.steps - start} steps in {time.perf_counter() - t0:.1f}s")
    return state


if __name__ == "__main__":
    main()
