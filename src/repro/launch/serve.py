"""Production serving launcher: end-to-end Apparate serving on a trained
(tiny) model with a drifting synthetic workload.

  PYTHONPATH=src python -m repro.launch.serve --domain cv --n 3000
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_bench, get_config
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.data import make_image_stream, make_token_stream
from repro.models import build_model
from repro.serving import (
    ClassifierRunner,
    PlatformConfig,
    ServingSimulator,
    make_requests,
    maf_trace,
    savings_vs,
    summarize,
    video_trace,
)
from repro.training import TrainConfig, train


def build_domain(domain: str, n: int, seed: int = 2):
    """Train a paper-shape bench model on the bootstrap split (first 10%,
    paper §4) and return (model, params, stream, profile)."""
    if domain == "cv":
        cfg = get_bench("resnet18").replace(n_classes=10)
        model = build_model(cfg)
        stream = make_image_stream(n, img_size=cfg.img_size, n_classes=10, mode="cv", seed=seed)
        batch_key = "images"
        prof_cfg = get_config("resnet18").replace(resnet_widths=(64, 128, 256, 512), img_size=224)
        lr, steps = 3e-3, 150
    else:
        cfg = get_bench("bert-base").replace(n_classes=10)
        model = build_model(cfg)
        stream = make_token_stream(n, seq_len=32, vocab=cfg.vocab_size, n_classes=10, mode="nlp", seed=seed)
        batch_key = "tokens"
        prof_cfg = get_config("bert-base")
        lr, steps = 1e-3, 200
    boot = max(n // 10, 256)

    def batches(s):
        rng = np.random.default_rng(s)
        idx = rng.integers(0, boot, 64)
        return {batch_key: stream.data[idx], "labels": stream.labels[idx]}

    state, _ = train(model, batches, TrainConfig(steps=steps, lr=lr), verbose=False)
    profile = build_profile(prof_cfg, mode="decode", chips=1)
    return cfg, model, state["params"], stream, profile, boot


def serve(domain: str, n: int, *, policy="tfserve", budget=0.02, acc=0.99,
          load=0.5, seed=2, slots=6, verbose=True):
    cfg, model, params, stream, prof, boot = build_domain(domain, n, seed)
    runner = ClassifierRunner(model, params, stream.data, max_slots=slots)
    ctl = ApparateController(
        len(model.sites), prof,
        ControllerConfig(max_slots=slots, ramp_budget_frac=budget, acc_constraint=acc),
    )
    exec1 = prof.vanilla_time(1)
    n_serve = n - boot
    if domain == "cv":
        arrivals = video_trace(n_serve, fps=load * 1000.0 / exec1)
    else:
        arrivals = maf_trace(n_serve, mean_qps=load * 1000.0 / exec1, seed=seed)
    reqs = make_requests(arrivals, slo_ms=2 * exec1, items=np.arange(boot, n))
    pf = PlatformConfig(policy=policy, max_batch_size=8, batch_timeout_ms=exec1)
    base = ServingSimulator(prof, pf).run(reqs)
    resp = ServingSimulator(prof, pf, runner, ctl).run(reqs)
    van = runner.vanilla_labels(n)
    agree = float(np.mean([r.label == van[boot + r.rid] for r in resp if not r.dropped]))
    mb, mo = summarize(base), summarize(resp)
    out = {
        "domain": domain, "vanilla": mb, "apparate": mo, "accuracy": agree,
        "wins": savings_vs(mb, mo), "controller": dict(ctl.stats),
        "active_ramps": list(map(int, ctl.active)),
    }
    if verbose:
        print(json.dumps(out, indent=1, default=float))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--domain", default="cv", choices=["cv", "nlp"])
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--policy", default="tfserve", choices=["tfserve", "clockwork"])
    ap.add_argument("--budget", type=float, default=0.02)
    ap.add_argument("--acc", type=float, default=0.99)
    ap.add_argument("--load", type=float, default=0.5)
    args = ap.parse_args(argv)
    serve(args.domain, args.n, policy=args.policy, budget=args.budget,
          acc=args.acc, load=args.load)


if __name__ == "__main__":
    main()
