"""Production serving launcher: end-to-end Apparate serving on a trained
(tiny) model with a drifting synthetic workload. With ``--workers N`` the
stream is served by the scale-out cluster engine: a dispatcher spreads
load across N replicas, each with its own Apparate controller. With
``--mode generative`` the workload is autoregressive decode: each request
generates ``--decode-tokens`` tokens through the continuous-batching
engine with per-token early exits and KV catch-up accounting.

  PYTHONPATH=src python -m repro.launch.serve --domain cv --n 3000
  PYTHONPATH=src python -m repro.launch.serve --workers 4 --dispatch jsq
  PYTHONPATH=src python -m repro.launch.serve --mode generative --decode-tokens 16
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_bench, get_config, get_tiny
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.data import make_decode_stream, make_image_stream, make_token_stream
from repro.launch.tuning import PRESETS, apply_preset
from repro.models import build_model
from repro.serving import (
    AdmissionConfig,
    AdmissionPolicy,
    ClassifierRunner,
    ClusterConfig,
    ClusterSimulator,
    DecodeRunner,
    GenerativeConfig,
    GenerativeEngine,
    PlatformConfig,
    ServingSimulator,
    ShardedDecodeRunner,
    make_gen_requests,
    make_requests,
    maf_trace,
    offered_decode_qps,
    savings_vs,
    summarize,
    summarize_cluster,
    summarize_generative,
    video_trace,
)
from repro.training import TrainConfig, train


def build_domain(domain: str, n: int, seed: int = 2):
    """Train a paper-shape bench model on the bootstrap split (first 10%,
    paper §4) and return (model, params, stream, profile)."""
    if domain == "cv":
        cfg = get_bench("resnet18").replace(n_classes=10)
        model = build_model(cfg)
        stream = make_image_stream(n, img_size=cfg.img_size, n_classes=10, mode="cv", seed=seed)
        batch_key = "images"
        prof_cfg = get_config("resnet18").replace(resnet_widths=(64, 128, 256, 512), img_size=224)
        lr, steps = 3e-3, 150
    else:
        cfg = get_bench("bert-base").replace(n_classes=10)
        model = build_model(cfg)
        stream = make_token_stream(n, seq_len=32, vocab=cfg.vocab_size, n_classes=10, mode="nlp", seed=seed)
        batch_key = "tokens"
        prof_cfg = get_config("bert-base")
        lr, steps = 1e-3, 200
    boot = max(n // 10, 256)

    def batches(s):
        rng = np.random.default_rng(s)
        idx = rng.integers(0, boot, 64)
        return {batch_key: stream.data[idx], "labels": stream.labels[idx]}

    state, _ = train(model, batches, TrainConfig(steps=steps, lr=lr), verbose=False)
    profile = build_profile(prof_cfg, mode="decode", chips=1)
    return cfg, model, state["params"], stream, profile, boot


def serve(domain: str, n: int, *, policy="tfserve", budget=0.02, acc=0.99,
          load=0.5, seed=2, slots=6, workers=1, dispatch="jsq", admission=False,
          admission_slack=1.0, verbose=True):
    cfg, model, params, stream, prof, boot = build_domain(domain, n, seed)
    runner = ClassifierRunner(model, params, stream.data, max_slots=slots)
    ccfg = ControllerConfig(max_slots=slots, ramp_budget_frac=budget, acc_constraint=acc)
    exec1 = prof.vanilla_time(1)
    n_serve = n - boot
    # the offered load scales with the cluster: each replica sees ~`load`
    if domain == "cv":
        arrivals = video_trace(n_serve, fps=workers * load * 1000.0 / exec1)
    else:
        arrivals = maf_trace(n_serve, mean_qps=workers * load * 1000.0 / exec1, seed=seed)
    reqs = make_requests(arrivals, slo_ms=2 * exec1, items=np.arange(boot, n))
    pf = PlatformConfig(policy=policy, max_batch_size=8, batch_timeout_ms=exec1)

    def adm():
        return (AdmissionPolicy(AdmissionConfig(slack=admission_slack))
                if admission else None)

    base_sim = ClusterSimulator(
        prof, ClusterConfig(n_workers=workers, dispatch=dispatch, platform=pf,
                            admission=adm()))
    base = base_sim.run(reqs)
    ctls = [ApparateController(len(model.sites), prof, ccfg) for _ in range(workers)]
    sim = ClusterSimulator(
        prof, ClusterConfig(n_workers=workers, dispatch=dispatch, platform=pf,
                            admission=adm()),
        runner=runner, controllers=ctls)
    resp = sim.run(reqs)
    van = runner.vanilla_labels(n)
    agree = float(np.mean([r.label == van[boot + r.rid] for r in resp if not r.dropped]))
    rep_b = summarize_cluster(base, horizon_ms=base_sim.makespan_ms, n_workers=workers)
    rep_o = summarize_cluster(resp, horizon_ms=sim.makespan_ms, n_workers=workers)
    mb, mo = rep_b["aggregate"], rep_o["aggregate"]
    out = {
        "domain": domain, "workers": workers, "dispatch": dispatch,
        "vanilla": mb, "apparate": mo, "accuracy": agree,
        "wins": savings_vs(mb, mo),
        "controllers": [dict(c.stats) for c in ctls],
        "active_ramps": [list(map(int, c.active)) for c in ctls],
    }
    if admission:
        out["admission"] = {"vanilla": base_sim.cfg.admission.stats(),
                            "apparate": sim.cfg.admission.stats()}
    if workers > 1:
        out["per_worker"] = rep_o["workers"]
        out["worker_stats"] = sim.worker_stats()
    if verbose:
        print(json.dumps(out, indent=1, default=float))
    return out


def serve_generative(n=48, *, decode_tokens=16, budget=0.02, acc=0.99, load=0.5,
                     seed=2, slots=4, layers=6, kv_block_size=0, kv_blocks=None,
                     prefill_chunk=0, admission=False, admission_slack=1.0,
                     prefix_cache=False, preempt="none", steps_per_sync=1,
                     tp=1, dp=1, pp=1, verbose=True):
    """End-to-end generative decode serving on a trained tiny LM: vanilla
    (no-EE) vs Apparate per-token exits, KV catch-up charged, at the same
    accuracy constraint. The latency profile uses the full qwen2-1.5b
    shape truncated to the tiny model's layer count, so sites align with
    the served model while step times reflect production scale.

    ``kv_block_size > 0`` switches the decode cache to the PAGED block
    pool (``decode_attn='paged'``): KV memory scales with live tokens
    instead of ``n_slots * max_len``; ``kv_blocks`` caps the pool (default
    auto-sizes to full slot capacity).

    ``prefill_chunk > 0`` splits each prompt's prefill into chunks
    co-scheduled with in-flight decode steps (the unified engine's
    chunked-prefill path; ``DecodeRunner`` prefills the slot cache
    incrementally). ``admission`` enables the SLO-aware admission policy
    (drop hopeless streams at admission, shed doomed slots mid-run).

    ``prefix_cache`` (paged only) shares cached prompt-prefix blocks
    across slots via the refcounted allocator — repeated prompts skip
    their prefill entirely. ``preempt`` picks the pool-exhaustion
    reaction: 'swap' moves a victim's blocks to a host buffer and
    readmits it later; 'shed' discards the victim; 'none' propagates
    ``PoolExhausted`` (legacy).

    ``steps_per_sync > 1`` dispatches decode SYNC WINDOWS: up to that
    many decode steps per jitted while_loop with on-device exit decisions
    against a stale threshold copy, one controller round-trip per window
    (``GenerativeConfig.steps_per_sync``).

    ``tp`` / ``dp`` > 1 serve through ``ShardedDecodeRunner`` on a
    ``(data, model)`` mesh (tensor-parallel attention/MLP, per-device KV
    shards — bit-identical to the single-device runner); ``pp`` > 1
    additionally reports an exit-gated PIPELINE decode window demo on a
    ``(stage,)`` mesh. Both need enough backend devices (on CPU export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` first)."""
    if prefix_cache and not kv_block_size:
        raise ValueError("--prefix-cache requires --kv-block-size > 0 (paged KV)")
    if preempt != "none" and not kv_block_size:
        raise ValueError("--preempt requires --kv-block-size > 0 (paged KV)")
    # decode_attn='ref' routes single-token attention through the
    # flash-decode wrapper (kernels/decode_attention) — the jnp oracle on
    # CPU; 'kernel' is the Pallas path on real hardware. 'paged' is the
    # block-pool analogue ('paged-kernel' on real hardware).
    tiny = get_tiny("qwen2-1.5b").replace(
        n_layers=layers, vocab_size=128,
        decode_attn="paged" if kv_block_size else "ref",
    )
    model = build_model(tiny)
    seq_len = 24
    stream = make_decode_stream(max(2 * n, 256), seq_len=seq_len + 1,
                                vocab=tiny.vocab_size, predict=0.96, seed=seed)

    def batches(s):
        rng = np.random.default_rng(s)
        idx = rng.integers(0, len(stream.data), 32)
        toks = stream.data[idx].astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    state, _ = train(model, batches, TrainConfig(steps=300, lr=3e-3), verbose=False)
    # production-scale decode profile (the paper's GPT-2 generative setup):
    # n_classes=0 restores the full-vocab token-serving head (the classifier
    # profiles serve 2-way sentiment) with ramps tied to the LM head; the
    # tiny model's K sites map to the same fractional depths of the full
    # stack, exactly like the CV launcher pairing a bench resnet with the
    # full resnet18 profile
    ns = len(model.sites)
    prof_cfg = get_config("gpt2-medium").replace(n_classes=0, ramp_style="tied")
    sites = [round((i + 1) * prof_cfg.n_layers / (ns + 1)) - 1 for i in range(ns)]
    prof = build_profile(prof_cfg, mode="decode", chips=1, sites=sites, charge_kv=True)
    assert ns == len(prof.sites), (ns, len(prof.sites))
    mbs = slots * 2
    qps = offered_decode_qps(prof, max_batch_size=mbs, tokens_per_request=decode_tokens, load=load)
    arr = maf_trace(n, mean_qps=qps, seed=seed)
    reqs = make_gen_requests(arr, n_tokens=decode_tokens, prompt_len=seq_len,
                             slo_ms=3 * prof.vanilla_time(1))
    gcfg = GenerativeConfig(max_batch_size=mbs, prefill_chunk=prefill_chunk,
                            preempt=preempt, steps_per_sync=steps_per_sync)

    def adm():
        return (AdmissionPolicy(AdmissionConfig(slack=admission_slack))
                if admission else None)

    base_eng = GenerativeEngine(prof, gcfg, admission=adm())
    mb = summarize_generative(base_eng.run(reqs), horizon_ms=base_eng.makespan_ms)
    ctl = ApparateController(ns, prof, ControllerConfig(
        max_slots=slots, ramp_budget_frac=budget, acc_constraint=acc))
    rkw = {}
    if kv_block_size:
        rkw = dict(kv_block_size=kv_block_size, kv_blocks=kv_blocks,
                   prefix_cache=prefix_cache)
    if tp > 1 or dp > 1:
        from repro.launch.mesh import make_serving_mesh
        runner = ShardedDecodeRunner(
            model, state["params"], stream.data[:, :seq_len],
            mesh=make_serving_mesh(tp=tp, dp=dp),
            max_new_tokens=decode_tokens + 2, max_slots=slots,
            n_slots=mbs, **rkw)
    else:
        runner = DecodeRunner(model, state["params"], stream.data[:, :seq_len],
                              max_new_tokens=decode_tokens + 2, max_slots=slots,
                              n_slots=mbs, **rkw)
    eng = GenerativeEngine(prof, gcfg, runner, ctl, admission=adm())
    mo = summarize_generative(eng.run(reqs), horizon_ms=eng.makespan_ms)
    out = {
        "mode": "generative", "n": n, "decode_tokens": decode_tokens,
        "vanilla": mb, "apparate": mo,
        # single-token streams have no TPT samples (percentiles are 0.0):
        # there is no per-token win to report, not a NaN/crash
        "tpt_p50_win_pct": (
            100.0 * (mb["tpt_p50_ms"] - mo["tpt_p50_ms"]) / mb["tpt_p50_ms"]
            if mb["tpt_p50_ms"] > 0 else 0.0
        ),
        "engine": eng.stats(), "controller": dict(ctl.stats),
        "active_ramps": list(map(int, ctl.active)),
        "kv_cache": runner.kv_stats(),
    }
    if prefill_chunk:
        out["prefill_chunk"] = prefill_chunk
    if steps_per_sync > 1:
        out["steps_per_sync"] = steps_per_sync
    if preempt != "none":
        out["preempt"] = preempt
    if admission:
        out["admission"] = {"vanilla": base_eng.admission.stats(),
                            "apparate": eng.admission.stats()}
    if tp > 1 or dp > 1:
        out["mesh"] = {"tp": tp, "dp": dp}
    if pp > 1:
        out["pipeline"] = pipeline_escape_demo(
            tiny, state["params"], stream.data[:, :seq_len], pp,
            n_steps=decode_tokens)
    if verbose:
        print(json.dumps(out, indent=1, default=float))
    return out


def pipeline_escape_demo(tiny, params, prompts, pp, *, n_steps=16, thr=0.6):
    """Exit-gated pipeline decode window on a (stage,) mesh: decode the
    same window with thresholds OFF (every row rides all stages) and ON
    (rows clearing a boundary ramp's uncertainty bar skip all later
    stages); reports per-stage work counters for both."""
    import jax.numpy as jnp

    from repro.distributed.pipeline import pipeline_decode_window
    from repro.launch.mesh import make_serving_mesh

    # the paged-pool config is irrelevant here: the pipeline path reads
    # the contiguous slot cache, so rebuild a 'ref' view over same params
    model = build_model(tiny.replace(decode_attn="ref"))
    mesh = make_serving_mesh(pp=pp)
    B = max(pp, (min(8, len(prompts)) // pp) * pp)
    toks = jnp.asarray(prompts[:B], jnp.int32)
    seq_len = toks.shape[1]
    cache, outs = model.prefill(
        params, toks, cache_len=seq_len + n_steps + 1, moe_impl="dense")
    last = outs["final"]["label"].reshape(B, 1).astype(jnp.int32)
    pos = jnp.full((B,), seq_len, jnp.int32)
    # boundary ramps: the active sites sitting at each stage's last layer
    sites = list(model.sites)
    nsl = len(model.plan.period)
    bounds = [(s + 1) * (model.plan.n_periods // pp) * nsl - 1
              for s in range(pp - 1)]
    act = [sites.index(b) for b in bounds if b in sites]
    _, _, _, _, st_off = pipeline_decode_window(
        model, params, cache, last, pos, n_steps, mesh=mesh)
    kw = {}
    if act:
        kw = dict(active_sites=jnp.asarray(act, jnp.int32),
                  thresholds=jnp.full((len(act),), thr, jnp.float32))
    _, _, exit_rec, alive, st_on = pipeline_decode_window(
        model, params, cache, last, pos, n_steps, mesh=mesh, **kw)
    return {
        "stages": pp, "batch": B, "n_steps": n_steps, "threshold": thr,
        "boundary_sites": act,
        "stage_steps_no_exit": list(map(int, st_off)),
        "stage_steps_exit": list(map(int, st_on)),
        "rows_exited": int(B - int(alive.sum())),
        "exits_recorded": int((exit_rec >= 0).sum()),
        "later_stage_work_saved_pct": (
            100.0 * (1.0 - float(st_on[1:].sum()) / float(st_off[1:].sum()))
            if pp > 1 and float(st_off[1:].sum()) else 0.0),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="classify", choices=["classify", "generative"])
    ap.add_argument("--domain", default="cv", choices=["cv", "nlp"])
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--kv-block-size", type=int, default=0,
                    help="generative: >0 pages the decode KV cache into "
                         "blocks of this many tokens (0 = contiguous rows)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="generative: total paged KV pool blocks "
                         "(default: auto-size to full slot capacity)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="generative: >0 splits each prompt's prefill into "
                         "chunks of this many tokens, co-scheduled with "
                         "in-flight decode steps (0 = serial prefill)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="generative + paged: share cached prompt-prefix "
                         "blocks across slots (refcount + copy-on-write); "
                         "repeated prompts skip their prefill (TTFT ~ 0)")
    ap.add_argument("--preempt", default="none", choices=["none", "swap", "shed"],
                    help="generative + paged: pool-exhaustion reaction — "
                         "swap a victim's KV to host and readmit it later, "
                         "shed it outright, or propagate the error")
    ap.add_argument("--steps-per-sync", type=int, default=1,
                    help="generative: decode steps per controller sync; "
                         ">1 fuses them into one on-device while_loop "
                         "window with device-side exit decisions (stale "
                         "thresholds between syncs, records replayed at "
                         "the boundary)")
    ap.add_argument("--tp", type=int, default=1,
                    help="generative: tensor-parallel degree — decode "
                         "through ShardedDecodeRunner on a (data, model) "
                         "mesh with per-device KV shards (needs tp*dp "
                         "backend devices; bit-identical to --tp 1)")
    ap.add_argument("--dp", type=int, default=1,
                    help="generative: data-parallel degree of the decode "
                         "mesh (contiguous KV only)")
    ap.add_argument("--pp", type=int, default=1,
                    help="generative: >1 adds an exit-gated pipeline "
                         "decode window demo over this many stages on a "
                         "(stage,) mesh (reports per-stage work saved)")
    ap.add_argument("--mesh-shape", default=None, metavar="DPxTP",
                    help="generative: '<dp>x<tp>' shorthand that "
                         "overrides --dp/--tp (e.g. '1x4', '2x2')")
    ap.add_argument("--runtime-preset", default="none",
                    choices=["none"] + sorted(PRESETS),
                    help="apply an XLA/allocator env preset before the "
                         "run (see repro.launch.tuning; flags already "
                         "exported in the environment win)")
    ap.add_argument("--admission", action="store_true",
                    help="enable the SLO-aware admission policy: drop "
                         "hopeless requests at admission; generative mode "
                         "also sheds doomed slots mid-stream")
    ap.add_argument("--admission-slack", type=float, default=1.0,
                    help="deadline slack multiplier for --admission")
    ap.add_argument("--policy", default="tfserve", choices=["tfserve", "clockwork"])
    ap.add_argument("--budget", type=float, default=0.02)
    ap.add_argument("--acc", type=float, default=0.99)
    ap.add_argument("--load", type=float, default=0.5)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--dispatch", default="jsq",
                    choices=["round_robin", "jsq", "slo_aware"])
    args = ap.parse_args(argv)
    # env presets must land before any jax backend work in the run
    apply_preset(args.runtime_preset)
    if args.mesh_shape:
        try:
            args.dp, args.tp = (int(x) for x in args.mesh_shape.lower().split("x"))
        except ValueError:
            ap.error("--mesh-shape must look like '<dp>x<tp>', e.g. 1x4")
    if args.mode == "generative":
        serve_generative(args.n if args.n is not None else 48,
                         decode_tokens=args.decode_tokens,
                         budget=args.budget, acc=args.acc, load=args.load,
                         kv_block_size=args.kv_block_size, kv_blocks=args.kv_blocks,
                         prefill_chunk=args.prefill_chunk,
                         admission=args.admission,
                         admission_slack=args.admission_slack,
                         prefix_cache=args.prefix_cache,
                         preempt=args.preempt,
                         steps_per_sync=args.steps_per_sync,
                         tp=args.tp, dp=args.dp, pp=args.pp)
    else:
        serve(args.domain, args.n if args.n is not None else 3000,
              policy=args.policy, budget=args.budget,
              acc=args.acc, load=args.load, workers=args.workers,
              dispatch=args.dispatch, admission=args.admission,
              admission_slack=args.admission_slack)


if __name__ == "__main__":
    main()
