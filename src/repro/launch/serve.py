"""Production serving launcher: end-to-end Apparate serving on a trained
(tiny) model with a drifting synthetic workload. With ``--workers N`` the
stream is served by the scale-out cluster engine: a dispatcher spreads
load across N replicas, each with its own Apparate controller.

  PYTHONPATH=src python -m repro.launch.serve --domain cv --n 3000
  PYTHONPATH=src python -m repro.launch.serve --workers 4 --dispatch jsq
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_bench, get_config
from repro.core import ApparateController, ControllerConfig, build_profile
from repro.data import make_image_stream, make_token_stream
from repro.models import build_model
from repro.serving import (
    ClassifierRunner,
    ClusterConfig,
    ClusterSimulator,
    PlatformConfig,
    ServingSimulator,
    make_requests,
    maf_trace,
    savings_vs,
    summarize,
    summarize_cluster,
    video_trace,
)
from repro.training import TrainConfig, train


def build_domain(domain: str, n: int, seed: int = 2):
    """Train a paper-shape bench model on the bootstrap split (first 10%,
    paper §4) and return (model, params, stream, profile)."""
    if domain == "cv":
        cfg = get_bench("resnet18").replace(n_classes=10)
        model = build_model(cfg)
        stream = make_image_stream(n, img_size=cfg.img_size, n_classes=10, mode="cv", seed=seed)
        batch_key = "images"
        prof_cfg = get_config("resnet18").replace(resnet_widths=(64, 128, 256, 512), img_size=224)
        lr, steps = 3e-3, 150
    else:
        cfg = get_bench("bert-base").replace(n_classes=10)
        model = build_model(cfg)
        stream = make_token_stream(n, seq_len=32, vocab=cfg.vocab_size, n_classes=10, mode="nlp", seed=seed)
        batch_key = "tokens"
        prof_cfg = get_config("bert-base")
        lr, steps = 1e-3, 200
    boot = max(n // 10, 256)

    def batches(s):
        rng = np.random.default_rng(s)
        idx = rng.integers(0, boot, 64)
        return {batch_key: stream.data[idx], "labels": stream.labels[idx]}

    state, _ = train(model, batches, TrainConfig(steps=steps, lr=lr), verbose=False)
    profile = build_profile(prof_cfg, mode="decode", chips=1)
    return cfg, model, state["params"], stream, profile, boot


def serve(domain: str, n: int, *, policy="tfserve", budget=0.02, acc=0.99,
          load=0.5, seed=2, slots=6, workers=1, dispatch="jsq", verbose=True):
    cfg, model, params, stream, prof, boot = build_domain(domain, n, seed)
    runner = ClassifierRunner(model, params, stream.data, max_slots=slots)
    ccfg = ControllerConfig(max_slots=slots, ramp_budget_frac=budget, acc_constraint=acc)
    exec1 = prof.vanilla_time(1)
    n_serve = n - boot
    # the offered load scales with the cluster: each replica sees ~`load`
    if domain == "cv":
        arrivals = video_trace(n_serve, fps=workers * load * 1000.0 / exec1)
    else:
        arrivals = maf_trace(n_serve, mean_qps=workers * load * 1000.0 / exec1, seed=seed)
    reqs = make_requests(arrivals, slo_ms=2 * exec1, items=np.arange(boot, n))
    pf = PlatformConfig(policy=policy, max_batch_size=8, batch_timeout_ms=exec1)
    ccl = ClusterConfig(n_workers=workers, dispatch=dispatch, platform=pf)
    base_sim = ClusterSimulator(prof, ccl)
    base = base_sim.run(reqs)
    ctls = [ApparateController(len(model.sites), prof, ccfg) for _ in range(workers)]
    sim = ClusterSimulator(prof, ccl, runner=runner, controllers=ctls)
    resp = sim.run(reqs)
    van = runner.vanilla_labels(n)
    agree = float(np.mean([r.label == van[boot + r.rid] for r in resp if not r.dropped]))
    rep_b = summarize_cluster(base, horizon_ms=base_sim.makespan_ms, n_workers=workers)
    rep_o = summarize_cluster(resp, horizon_ms=sim.makespan_ms, n_workers=workers)
    mb, mo = rep_b["aggregate"], rep_o["aggregate"]
    out = {
        "domain": domain, "workers": workers, "dispatch": dispatch,
        "vanilla": mb, "apparate": mo, "accuracy": agree,
        "wins": savings_vs(mb, mo),
        "controllers": [dict(c.stats) for c in ctls],
        "active_ramps": [list(map(int, c.active)) for c in ctls],
    }
    if workers > 1:
        out["per_worker"] = rep_o["workers"]
        out["worker_stats"] = sim.worker_stats()
    if verbose:
        print(json.dumps(out, indent=1, default=float))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--domain", default="cv", choices=["cv", "nlp"])
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--policy", default="tfserve", choices=["tfserve", "clockwork"])
    ap.add_argument("--budget", type=float, default=0.02)
    ap.add_argument("--acc", type=float, default=0.99)
    ap.add_argument("--load", type=float, default=0.5)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--dispatch", default="jsq",
                    choices=["round_robin", "jsq", "slo_aware"])
    args = ap.parse_args(argv)
    serve(args.domain, args.n, policy=args.policy, budget=args.budget,
          acc=args.acc, load=args.load, workers=args.workers, dispatch=args.dispatch)


if __name__ == "__main__":
    main()
