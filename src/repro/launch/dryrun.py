import os

# merged under any operator-exported flags (tuning.py contract: an
# existing --xla_force_host_platform_device_count in the environment
# wins over our 512 default) — tuning has no jax import, so pulling the
# helper in here still lands the env var before the backend initializes
from repro.launch.tuning import merge_xla_flags

os.environ["XLA_FLAGS"] = merge_xla_flags(
    "--xla_force_host_platform_device_count=512", os.environ.get("XLA_FLAGS")
)

"""Multi-pod dry run: lower + compile every (arch × shape × mesh) cell and
extract the roofline terms from the compiled artifact.

MUST be run as its own process (the XLA flag above is set before any jax
import — 512 placeholder host devices stand in for the 512 v5e chips).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_config
from repro.core.profiles import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.models import build_model
from repro.models.common import abstract_from_schema, param_count, sanitize_specs
from repro.models.layers import resolve_schema
from repro.training.optim import AdamWConfig, adamw_update

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

COLLECTIVE_W = {
    "all-reduce": 2.0,  # ring: 2N per device
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective type, from post-SPMD HLO."""
    out = {k: 0.0 for k in COLLECTIVE_W}
    counts = {k: 0 for k in COLLECTIVE_W}
    for line in hlo_text.splitlines():
        for op, w in COLLECTIVE_W.items():
            token = f" {op}(" if not op.endswith("start") else op
            if f" {op}(" in line or f" {op}-start(" in line:
                # result shapes appear before the op name
                head = line.split(f" {op}", 1)[0]
                nbytes = 0.0
                for m in _SHAPE_RE.finditer(head):
                    dt, dims = m.group(1), m.group(2)
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    nbytes += n * _DTYPE_BYTES[dt]
                out[op] += nbytes * w
                counts[op] += 1
                break
    return {"bytes": out, "counts": counts}


def model_flops(cfg, shape_info):
    """Reference useful FLOPs: 6·N_active·D (train) / 2·N_active·D (serve);
    N excludes ramp heads (the technique's overhead is reported separately)."""
    model = build_model(cfg)
    schema = model.schema()
    n_total = param_count(schema)
    n_ramps = param_count(schema.get("ramps", {})) if isinstance(schema, dict) else 0
    n_backbone = n_total - n_ramps
    n_active = n_backbone
    if cfg.moe:
        e_tot, e_act = cfg.n_experts, cfg.top_k
        expert_params = 3 * cfg.d_model * cfg.moe_d_ff
        n_moe_layers = sum(
            1 for i in range(cfg.n_layers)
            if (not cfg.hybrid_period or i % cfg.moe_every == 1) and i >= cfg.first_k_dense
        )
        n_active = n_backbone - n_moe_layers * (e_tot - e_act) * expert_params
    D = shape_info["global_batch"] * (shape_info["seq_len"] if shape_info["kind"] != "decode" else 1)
    mult = 6.0 if shape_info["kind"] == "train" else 2.0
    return mult * n_active * D, n_total, n_active


def metric_overrides(cfg):
    """Two reduced-depth fully-unrolled lowerings for exact per-period cost
    extrapolation (scan bodies are otherwise counted once by cost_analysis).
    Returns ([ovr1, ovr2], (units1, units2, units_full))."""
    from repro.models.transformer import build_plan

    if cfg.family == "encdec":
        return (
            [dict(n_enc_layers=2, n_dec_layers=2, n_layers=4, scan_unroll=True),
             dict(n_enc_layers=3, n_dec_layers=3, n_layers=6, scan_unroll=True)],
            (2, 3, cfg.n_dec_layers),
        )
    plan = build_plan(cfg)
    P, pre, suf = len(plan.period), len(plan.prefix), len(plan.suffix)
    u1 = 1 if pre + P + suf >= 2 else 2  # ensure >=1 ramp site at u1
    u2 = u1 + 1
    return (
        [dict(n_layers=pre + u1 * P + suf, scan_unroll=True),
         dict(n_layers=pre + u2 * P + suf, scan_unroll=True)],
        (u1, u2, plan.n_periods),
    )


def _shard(mesh, spec):
    return NamedSharding(mesh, spec)


def abstract_with_sharding(abstracts, specs, mesh):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=_shard(mesh, s)),
        abstracts,
        specs,
    )


def build_cell(arch: str, shape_name: str, mesh, *, moe_impl="ep", overrides=None):
    """Returns (fn, args_abstract, donate) ready for jit().lower()."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    info = SHAPES[shape_name]
    model = build_model(cfg)
    kind = info["kind"]
    GB, S = info["global_batch"], info["seq_len"]
    axes = mesh_axes(mesh, fsdp=(kind == "train"))
    schema = resolve_schema(model.schema(), axes)
    p_specs = sanitize_specs(
        jax.tree.map(lambda i: i.spec, schema, is_leaf=lambda x: hasattr(x, "spec") and hasattr(x, "init")),
        abstract_from_schema(schema),
        mesh,
    )
    p_abs = abstract_with_sharding(abstract_from_schema(schema), p_specs, mesh)
    dspec = axes.aspec("data")
    K = cfg.ramp_budget_slots
    act_abs = jax.ShapeDtypeStruct((K,), jnp.int32, sharding=_shard(mesh, P()))

    if kind == "train":
        opt_abs = {
            "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=_shard(mesh, P())),
            "mu": p_abs,
            "nu": p_abs,
        }
        tok_abs = jax.ShapeDtypeStruct(
            (GB, S if cfg.family != "encdec" else S // 8), jnp.int32,
            sharding=_shard(mesh, P(dspec[0], None)),
        )
        batch_abs = {"tokens": tok_abs, "labels": tok_abs}
        if cfg.family == "encdec":
            batch_abs["frames"] = jax.ShapeDtypeStruct(
                (GB, S, cfg.d_frontend), jnp.dtype(cfg.dtype),
                sharding=_shard(mesh, P(dspec[0], None, None)),
            )
        if cfg.cross_attn_every:
            batch_abs["image_embeds"] = jax.ShapeDtypeStruct(
                (GB, cfg.n_image_tokens, cfg.d_frontend), jnp.dtype(cfg.dtype),
                sharding=_shard(mesh, P(dspec[0], None, None)),
            )
        opt_cfg = AdamWConfig()

        def train_step(params, opt, batch):
            def loss_fn(p):
                return model.loss(
                    p, batch, axes=axes, mesh=mesh, moe_impl=moe_impl,
                    remat=cfg.train_remat,
                )

            (loss, mets), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            newp, newopt, gn = adamw_update(params, grads, opt, opt_cfg)
            return newp, newopt, loss, gn

        return train_step, (p_abs, opt_abs, batch_abs), (0, 1)

    if kind == "prefill":
        B = GB
        tok_abs = jax.ShapeDtypeStruct(
            (B, S if cfg.family != "encdec" else 64), jnp.int32,
            sharding=_shard(mesh, P(dspec[0], None)),
        )
        extra = {}
        if cfg.family == "encdec":
            extra["frames"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_frontend), jnp.dtype(cfg.dtype),
                sharding=_shard(mesh, P(dspec[0], None, None)),
            )
        if cfg.cross_attn_every:
            extra["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_frontend), jnp.dtype(cfg.dtype),
                sharding=_shard(mesh, P(dspec[0], None, None)),
            )

        if cfg.family == "encdec":

            def prefill(params, tokens, active, frames):
                cache, outs = model.prefill(
                    params, frames, tokens, active_sites=active, axes=axes, mesh=mesh
                )
                return cache, outs

            return prefill, (p_abs, tok_abs, act_abs, extra["frames"]), ()

        def prefill(params, tokens, active, **kw):
            cache, outs = model.prefill(
                params, tokens, active_sites=active, axes=axes, mesh=mesh,
                moe_impl=moe_impl, **kw,
            )
            return cache, outs

        args = (p_abs, tok_abs, act_abs)
        if cfg.cross_attn_every:
            return partial(prefill_vlm, model, axes, mesh, moe_impl), (
                p_abs, tok_abs, act_abs, extra["image_embeds"],
            ), ()
        return prefill, args, ()

    # decode
    B = GB
    shard_batch = B >= 16
    if cfg.family == "encdec":
        Sc_self, M = 4096, S
        cdt = jnp.dtype(cfg.dtype)
        L, KH, hd = cfg.n_dec_layers, cfg.n_kv_heads, cfg.hd
        bspec = dspec[0] if shard_batch else None
        sspec = None if shard_batch else dspec[0]
        cache_abs = {
            "k": jax.ShapeDtypeStruct((L, B, Sc_self, KH, hd), cdt, sharding=_shard(mesh, P(None, bspec, sspec, None, None))),
            "v": jax.ShapeDtypeStruct((L, B, Sc_self, KH, hd), cdt, sharding=_shard(mesh, P(None, bspec, sspec, None, None))),
            "xkv": {
                "k": jax.ShapeDtypeStruct((L, B, M, KH, hd), cdt, sharding=_shard(mesh, P(None, bspec, sspec, None, None))),
                "v": jax.ShapeDtypeStruct((L, B, M, KH, hd), cdt, sharding=_shard(mesh, P(None, bspec, sspec, None, None))),
            },
        }
    else:
        c_schema = resolve_schema(model.cache_schema(B, S, shard_batch), axes)
        c_specs = sanitize_specs(
            jax.tree.map(lambda i: i.spec, c_schema, is_leaf=lambda x: hasattr(x, "init") and hasattr(x, "spec")),
            abstract_from_schema(c_schema),
            mesh,
        )
        cache_abs = abstract_with_sharding(abstract_from_schema(c_schema), c_specs, mesh)
    tok_abs = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32,
        sharding=_shard(mesh, P(dspec[0] if shard_batch else None, None)),
    )
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32, sharding=_shard(mesh, P()))

    def serve_step(params, cache, tokens, pos, active):
        new_cache, outs = model.decode(
            params, cache, tokens, pos, active_sites=active, axes=axes, mesh=mesh,
            **({} if cfg.family == "encdec" else {"moe_impl": moe_impl}),
        )
        return new_cache, outs

    return serve_step, (p_abs, cache_abs, tok_abs, pos_abs, act_abs), (1,)


def prefill_vlm(model, axes, mesh, moe_impl, params, tokens, active, image_embeds):
    return model.prefill(
        params, tokens, active_sites=active, axes=axes, mesh=mesh,
        moe_impl=moe_impl, image_embeds=image_embeds,
    )


def _compile_and_measure(arch, shape_name, mesh, overrides):
    fn, args, donate = build_cell(arch, shape_name, mesh, overrides=overrides)
    # wall-clock is legitimate here: we are *measuring* lower/compile time
    # of a one-shot lowering, not feeding a discrete-event simulation
    t0 = time.time()  # repro: allow[no-wallclock]
    lowered = jax.jit(fn, donate_argnums=donate).lower(*args)  # repro: allow[jit-cache-hygiene]
    t_lower = time.time() - t0  # repro: allow[no-wallclock]
    t1 = time.time()  # repro: allow[no-wallclock]
    compiled = lowered.compile()
    t_compile = time.time() - t1  # repro: allow[no-wallclock]
    from repro.compat import cost_analysis

    ca = cost_analysis(compiled)
    text = compiled.as_text()
    m = {
        "lower_s": t_lower,
        "compile_s": t_compile,
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": collective_bytes(text),
        "hlo_chars": len(text),
    }
    try:
        ma = compiled.memory_analysis()
        m["memory"] = {
            k: int(getattr(ma, k))
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
            )
            if hasattr(ma, k)
        }
    except Exception as e:  # pragma: no cover
        m["memory"] = {"error": str(e)}
    return m


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, overrides=None,
             tag="", metrics: bool = True):
    """Compile the full (scanned) program — the shardability/memory proof —
    plus, for single-pod roofline metrics, two reduced-depth unrolled
    lowerings whose exact per-period costs extrapolate to full depth."""
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(mesh.devices.shape))
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    info = SHAPES[shape_name]
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "kind": info["kind"], "seq_len": info["seq_len"],
        "global_batch": info["global_batch"], "tag": tag, "ok": False,
    }
    t0 = time.time()  # repro: allow[no-wallclock] — measuring compile wall time
    try:
        full = _compile_and_measure(arch, shape_name, mesh, overrides)
        rec.update({f"full_{k}": v for k, v in full.items()})
        mf, n_tot, n_act = model_flops(cfg, info)
        rec["model_flops_ref"] = mf
        rec["params_total"] = n_tot
        rec["params_active"] = n_act
        if metrics:
            ovrs, (u1, u2, uf) = metric_overrides(cfg)
            base = dict(overrides or {})
            m1 = _compile_and_measure(arch, shape_name, mesh, {**base, **ovrs[0]})
            m2 = _compile_and_measure(arch, shape_name, mesh, {**base, **ovrs[1]})

            def xp(a, b):  # linear extrapolation in period count
                slope = (b - a) / (u2 - u1)
                return a + slope * (uf - u1)

            rec["xp_flops"] = xp(m1["flops"], m2["flops"])
            rec["xp_bytes"] = xp(m1["bytes"], m2["bytes"])
            c1 = m1["collectives"]["bytes"]
            c2 = m2["collectives"]["bytes"]
            rec["xp_collectives"] = {k: xp(c1[k], c2[k]) for k in c1}
            rec["metric_points"] = {"u": [u1, u2, uf],
                                    "flops": [m1["flops"], m2["flops"]],
                                    "bytes": [m1["bytes"], m2["bytes"]]}
            # roofline terms (seconds, per device; cost_analysis is
            # post-SPMD per-device on the host backend)
            coll = sum(rec["xp_collectives"].values())
            rec["t_compute_s"] = rec["xp_flops"] / PEAK_FLOPS
            rec["t_memory_s"] = rec["xp_bytes"] / HBM_BW
            rec["t_collective_s"] = coll / ICI_BW
            terms = {"compute": rec["t_compute_s"], "memory": rec["t_memory_s"],
                     "collective": rec["t_collective_s"]}
            rec["bottleneck"] = max(terms, key=terms.get)
            rec["useful_flops_ratio"] = rec["model_flops_ref"] / max(
                rec["xp_flops"] * chips, 1.0
            )
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.time() - t0  # repro: allow[no-wallclock]
    os.makedirs(ART_DIR, exist_ok=True)
    sfx = f"__{tag}" if tag else ""
    path = os.path.join(ART_DIR, f"{arch}__{shape_name}__{mesh_kind}{sfx}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error','')[:120]})"
    print(f"[{arch} × {shape_name} × {mesh_kind}{sfx}] {status}  total {rec['total_s']:.1f}s  "
          f"bottleneck={rec.get('bottleneck','-')}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                if cell_is_runnable(a, s):
                    cells.append((a, s))
    else:
        cells.append((args.arch, args.shape))
    n_ok = 0
    for a, s in cells:
        for mk in meshes:
            path = os.path.join(ART_DIR, f"{a}__{s}__{mk}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        n_ok += 1
                        continue
            # roofline metric lowerings are single-pod only (see DESIGN.md)
            rec = run_cell(a, s, mk, metrics=(mk == "single"))
            n_ok += bool(rec["ok"])
    print(f"dryrun: {n_ok}/{len(cells) * len(meshes)} cells OK", flush=True)


if __name__ == "__main__":
    main()
