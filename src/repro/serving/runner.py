"""Model runners: execute the real (tiny, CPU-trained) models per batch and
stream ramp records to the controller.

On hardware this is the accelerator side: a single jitted program computes
the full model + K gathered ramp heads; only ~KB stat arrays (top-1 label,
max-prob, entropy per ramp) travel to the host — never logits. Batches are
padded to power-of-two buckets to bound compilation count.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class SyntheticRunner:
    """Profile-only serving: deterministic ramp records without a model.

    A fixed fraction of items is "easy" — confidently predictable from
    ``exit_site`` onward — so controllers activate ramps and exit traffic
    exactly as with a trained model, at zero model cost. Used by the
    scale-out demos/benchmarks where training one model per replica-count
    sweep would dominate runtime.
    """

    def __init__(self, n_sites: int, exit_site: int, easy_frac: float = 0.7,
                 n_classes: int = 17):
        self.n_sites = n_sites
        self.exit_site = exit_site
        self.easy_frac = easy_frac
        self.n_classes = n_classes

    def infer(self, items: np.ndarray, active: Sequence[int]):
        items = np.asarray(items)
        k = len(active)
        B = len(items)
        final = (items % self.n_classes).astype(np.int64)
        easy = (items % 100) < self.easy_frac * 100
        # hard items DISAGREE with the original model at every ramp (like
        # SyntheticDecodeRunner): an over-opened threshold that releases
        # them costs accuracy, exactly as with a trained model. Tiling the
        # final label into every row made hard exits free.
        wrong = (final + 1) % self.n_classes
        labels = np.tile(wrong, (max(k, 1), 1))
        unc = np.full((max(k, 1), B), 0.9, np.float32)
        for j, s in enumerate(sorted(active)):
            if s >= self.exit_site:
                labels[j] = np.where(easy, final, wrong)
                unc[j] = np.where(easy, 0.02, 0.9)
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def vanilla_labels(self, n: int) -> np.ndarray:
        return np.arange(n, dtype=np.int64) % self.n_classes


class ClassifierRunner:
    """ResNet / BERT-style classifier serving (the paper's workloads)."""

    def __init__(self, model, params, data: np.ndarray, max_slots: int = 8):
        self.model = model
        self.params = params
        self.data = data  # (N, ...) images or token sequences
        self.max_slots = max_slots
        self._fns = {}
        self.compiles = 0  # ramp-set changes recompile (paper: model re-upload)
        self.noramp_compiles = 0  # no-ramp (vanilla) variant compiles

    def _fn(self, bs: int, act: Optional[tuple]):
        """act=None compiles the no-ramp (vanilla) variant: with zero active
        ramps the model must not execute-and-discard a ramp head — vanilla
        serving would silently pay one ramp of compute per batch."""
        key = (bs, act)
        if key not in self._fns:
            m = self.model
            if act is None:
                # no-ramp (vanilla) compiles are NOT ramp-set changes: they
                # must not inflate `compiles`, the "ramp-set change
                # recompile" stat the paper's overhead story rests on
                self.noramp_compiles += 1

                @jax.jit
                def f0(params, x):
                    return m.forward(params, x, active_sites=None)["final"]["label"]

                self._fns[key] = f0
            else:
                self.compiles += 1

                @jax.jit
                def f(params, x):
                    outs = m.forward(params, x, active_sites=list(act))
                    return (
                        outs["ramps"]["label"],
                        1.0 - outs["ramps"]["maxprob"],
                        outs["final"]["label"],
                    )

                self._fns[key] = f
        return self._fns[key]

    def infer(self, items: np.ndarray, active: Sequence[int]):
        bs = _bucket(len(items))
        idx = np.pad(items, (0, bs - len(items)), mode="edge")
        x = jnp.asarray(self.data[idx])
        act = tuple(sorted(active))[: self.max_slots]
        k = len(act)
        if k == 0:
            final = np.asarray(self._fn(bs, None)(self.params, x))[: len(items)]
            return np.zeros((0, len(items)), np.int64), np.zeros((0, len(items)), np.float32), final
        labels, unc, final = self._fn(bs, act)(self.params, x)
        labels = np.asarray(labels)[:, : len(items)]
        unc = np.asarray(unc)[:, : len(items)]
        final = np.asarray(final)[: len(items)]
        return labels[:k], unc[:k].astype(np.float32), final

    def vanilla_labels(self, n: Optional[int] = None) -> np.ndarray:
        """Original-model labels for the whole stream (accuracy ground truth)."""
        n = n or len(self.data)
        out = []
        for lo in range(0, n, 256):
            hi = min(lo + 256, n)
            idx = np.arange(lo, hi)
            _, _, f = self.infer(idx, [])  # no-ramp variant: zero ramp compute
            out.append(f)
        return np.concatenate(out)


class LMTokenRunner:
    """Per-token early-exit serving for decoder LMs: each request is a
    context; the served result is the next token (prefill path)."""

    def __init__(self, model, params, data: np.ndarray, max_slots: int = 8):
        self.model = model
        self.params = params
        self.data = data  # (N, S) int32 contexts
        self.max_slots = max_slots
        self._fns = {}
        self._fns0 = {}  # no-ramp (vanilla) variants

    def _fn_noramp(self, bs: int):
        if bs not in self._fns0:
            m = self.model

            @jax.jit
            def f0(params, toks):
                _, outs = m.prefill(
                    params, toks, active_sites=None, with_cache=False, moe_impl="dense"
                )
                lab = outs["final"]["label"]
                return lab[:, 0] if lab.ndim == 2 else lab

            self._fns0[bs] = f0
        return self._fns0[bs]

    def _fn(self, bs: int):
        if bs not in self._fns:
            m = self.model

            @jax.jit
            def f(params, toks, active):
                _, outs = m.prefill(
                    params, toks, active_sites=active, with_cache=False, moe_impl="dense"
                )
                return (
                    outs["ramps"]["label"][:, :, 0] if outs["ramps"]["label"].ndim == 3 else outs["ramps"]["label"],
                    1.0 - (outs["ramps"]["maxprob"][:, :, 0] if outs["ramps"]["maxprob"].ndim == 3 else outs["ramps"]["maxprob"]),
                    outs["final"]["label"][:, 0] if outs["final"]["label"].ndim == 2 else outs["final"]["label"],
                )

            self._fns[bs] = f
        return self._fns[bs]

    def infer(self, items: np.ndarray, active: Sequence[int]):
        bs = _bucket(len(items))
        idx = np.pad(items, (0, bs - len(items)), mode="edge")
        toks = jnp.asarray(self.data[idx])
        # sort (like ClassifierRunner): the controller consumes record rows
        # in ascending-site order, so an unsorted caller set must not leak
        # row misalignment into the window
        act = sorted(active)[: self.max_slots]
        k = len(act)
        if k == 0:
            final = np.asarray(self._fn_noramp(bs)(self.params, toks))[: len(items)]
            return np.zeros((0, len(items)), np.int64), np.zeros((0, len(items)), np.float32), final
        pad_act = act + [act[-1]] * (self.max_slots - len(act))
        labels, unc, final = self._fn(bs)(
            self.params, toks, jnp.asarray(pad_act, jnp.int32)
        )
        final = np.asarray(final)[: len(items)]
        return (
            np.asarray(labels)[:k, : len(items)],
            np.asarray(unc)[:k, : len(items)].astype(np.float32),
            final,
        )

    def vanilla_labels(self, n: Optional[int] = None) -> np.ndarray:
        n = n or len(self.data)
        out = []
        for lo in range(0, n, 128):
            idx = np.arange(lo, min(lo + 128, n))
            _, _, f = self.infer(idx, [])  # no-ramp variant: zero ramp compute
            out.append(f)
        return np.concatenate(out)


class DecodeRunner:
    """Real-model generative runner: drives ``model.decode`` with ONE
    jitted dispatch per engine step over a single batched slot cache,
    streaming one ramp record per in-flight token to the controller (the
    paper's generative per-token exits).

    Records are replay-complete — the full model and the gathered ramp
    heads run for every token, because the controller needs agreement
    labels to adapt — while serving *time* is simulated by the engine from
    the latency profile (truncated compute + deferred KV catch-up). The
    decoded trajectory follows the original model's greedy tokens so
    per-token agreement against the vanilla stream stays measurable even
    when a ramp disagrees.

    The cache is one batched tree keyed by slot index: ``start`` prefills
    into a slot row, ``step(slots, active)`` gathers the live rows, runs a
    single jitted decode with per-row positions (``model.decode`` takes
    ``pos: int32[B]``), and scatters the rows back; ``free`` just releases
    the row. Continuous batching admits/retires at step boundaries, so row
    positions diverge — per-row cache write indices are what make the
    shared cache sound. Live rows are padded to a power-of-two bucket with
    FREE rows (distinct indices, so the scatter is collision-free and the
    padded rows hold garbage no one reads), bounding compile count at
    log2(n_slots) shapes. Batch-level timing comes from the profile, not
    from here.
    """

    def __init__(self, model, params, prompts: np.ndarray, *, max_new_tokens: int = 64,
                 max_slots: int = 8, n_slots: Optional[int] = None):
        self.model = model
        self.params = params
        self.prompts = np.asarray(prompts, np.int32)  # (N, S)
        self.max_new = max_new_tokens
        self.max_slots = max_slots  # K ramp gather slots (not decode rows)
        self.n_sites = len(model.sites)
        self.dispatches = 0  # jitted decode-step calls (1/step, not 1/slot)
        self._cache = None  # batched slot cache; rows grown on demand
        self._rows = 0 if n_slots is None else _bucket(max(n_slots, 1))
        self._cache_len = self.prompts.shape[1] + self.max_new
        self._live = set()
        self._pos = np.zeros(0, np.int64)
        self._tok = np.zeros(0, np.int64)
        self._axes: Optional[Tuple[int, ...]] = None  # per-leaf batch axis
        self._pf = None
        self._dec = None
        self._dec0 = None  # no-ramp (vanilla) decode variant

    # -- batched-cache plumbing ---------------------------------------------

    def _ensure_rows(self, n: int) -> None:
        """Allocate (or grow) the batched cache to >= n power-of-two rows.
        Growth copies live rows once; steady state never reallocates."""
        if self._cache is not None and n <= self._rows:
            return
        rows = _bucket(max(n, self._rows, 1))
        new = self.model.init_cache(rows, self._cache_len)
        if self._axes is None:
            # per-leaf batch axis: scanned blocks carry a leading period
            # dim, prefix/suffix leaves don't — compare two row counts
            a = jax.tree.leaves(self.model.cache_schema(1, 2))
            b = jax.tree.leaves(self.model.cache_schema(2, 2))
            self._axes = tuple(
                next(i for i, (x, y) in enumerate(zip(la.shape, lb.shape)) if x != y)
                for la, lb in zip(a, b)
            )
        if self._cache is not None:
            old, td = jax.tree.flatten(self._cache)
            new_l = jax.tree.leaves(new)
            new = jax.tree.unflatten(td, [
                jax.lax.dynamic_update_slice_in_dim(nl, ol, 0, axis=ax)
                for nl, ol, ax in zip(new_l, old, self._axes)
            ])
        self._cache = new
        self._rows = rows
        self._pos = np.concatenate([self._pos, np.zeros(rows - len(self._pos), np.int64)])
        self._tok = np.concatenate([self._tok, np.zeros(rows - len(self._tok), np.int64)])

    def _tree_take(self, cache, rows):
        leaves, td = jax.tree.flatten(cache)
        return jax.tree.unflatten(td, [
            jnp.take(l, rows, axis=ax) for l, ax in zip(leaves, self._axes)
        ])

    def _tree_put(self, cache, sub, rows):
        leaves, td = jax.tree.flatten(cache)
        subl = jax.tree.leaves(sub)
        out = []
        for l, s, ax in zip(leaves, subl, self._axes):
            upd = jnp.moveaxis(l, ax, 0).at[rows].set(jnp.moveaxis(s, ax, 0))
            out.append(jnp.moveaxis(upd, 0, ax))
        return jax.tree.unflatten(td, out)

    # -- jitted programs ----------------------------------------------------

    def _prefill_fn(self):
        """Prefill one prompt AND scatter its cache into the slot row —
        one dispatch per admit (`slot` is a traced scalar: no recompile
        per slot id)."""
        if self._pf is None:
            m, cache_len = self.model, self._cache_len

            @jax.jit
            def pf(params, big, toks, slot):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                big = self._tree_put(big, cache, slot[None])
                lab = outs["final"]["label"]
                return big, (lab[:, 0] if lab.ndim == 2 else lab)

            self._pf = pf
        return self._pf

    def _decode_fn(self):
        if self._dec is None:
            m = self.model

            @jax.jit
            def dec(params, big, toks, pos, rows, active):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode(
                    params, sub, toks, pos, active_sites=active, moe_impl="dense"
                )
                big = self._tree_put(big, sub, rows)
                return big, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_noramp(self):
        """Ramp-free decode: with zero active ramps (controller bootstrap /
        budget-busted states) the step must not execute-and-discard ramp
        heads — same fix as the classifier/token runners' no-ramp variants."""
        if self._dec0 is None:
            m = self.model

            @jax.jit
            def dec0(params, big, toks, pos, rows):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode(
                    params, sub, toks, pos, active_sites=None, moe_impl="dense"
                )
                big = self._tree_put(big, sub, rows)
                return big, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    # -- engine interface ----------------------------------------------------

    def start(self, slot: int, item: int) -> int:
        """Prefill ``item``'s prompt into ``slot``'s cache row; returns the
        first generated (greedy) token."""
        self._ensure_rows(slot + 1)
        toks = jnp.asarray(self.prompts[item][None, :])
        self._cache, lab = self._prefill_fn()(
            self.params, self._cache, toks, jnp.int32(slot)
        )
        tok = int(np.asarray(lab).reshape(-1)[0])
        self._live.add(slot)
        self._pos[slot] = self.prompts.shape[1]
        self._tok[slot] = tok
        return tok

    def step(self, slots: Sequence[int], active: Sequence[int]):
        """ONE decode step — one jitted dispatch — for every slot in
        ``slots``. Returns (ramp_labels (K,B), ramp_unc (K,B), final (B,))
        with rows in sorted(active) order and columns in ``slots`` order."""
        slots = list(slots)
        for s in slots:
            if s not in self._live:
                raise KeyError(f"slot {s} is not live (freed or never started)")
        B = len(slots)
        if B == 0:  # nothing in flight: no dispatch (mirrors the loop runner)
            k = len(sorted(active)[: self.max_slots])
            return (np.zeros((k, 0), np.int64), np.zeros((k, 0), np.float32),
                    np.zeros(0, np.int64))
        bucket = min(_bucket(B), self._rows)
        # pad with FREE rows (their state is garbage a future start()
        # overwrites wholesale), then with duplicates of stepped slots
        # (gather precedes every write, so duplicate indices scatter
        # identical values). NEVER a live-but-unstepped row: attention
        # writes would be idempotent previews, but an SSM mixer would
        # advance that slot's recurrent state off-schedule.
        free = [r for r in range(self._rows) if r not in self._live][: bucket - B]
        dup = [slots[i % B] for i in range(bucket - B - len(free))] if B else []
        rows = np.asarray(slots + free + dup, np.int64)
        toks = jnp.asarray(self._tok[rows].reshape(-1, 1), jnp.int32)
        pos = jnp.asarray(self._pos[rows], jnp.int32)
        rows_j = jnp.asarray(rows, jnp.int32)
        act = sorted(active)[: self.max_slots]
        k = len(act)
        if k:
            pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
            self._cache, (rl, ru, fl) = self._decode_fn()(
                self.params, self._cache, toks, pos, rows_j, pad_act
            )
            labels = np.asarray(rl).reshape(self.max_slots, -1)[:k, :B].astype(np.int64)
            unc = np.asarray(ru).reshape(self.max_slots, -1)[:k, :B].astype(np.float32)
        else:
            self._cache, fl = self._decode_fn_noramp()(
                self.params, self._cache, toks, pos, rows_j
            )
            labels = np.zeros((0, B), np.int64)
            unc = np.zeros((0, B), np.float32)
        self.dispatches += 1
        final = np.asarray(fl).reshape(-1)[:B].astype(np.int64)
        self._pos[rows[:B]] += 1
        self._tok[rows[:B]] = final  # vanilla greedy trajectory (agreement baseline)
        return labels, unc, final

    def free(self, slot: int) -> None:
        self._live.discard(slot)


class LoopDecodeRunner:
    """Per-slot-loop reference runner: the pre-batched implementation kept
    for the batched-vs-loop equivalence tests and the dispatch-count
    benchmark. Slots are independent B=1 caches; every engine step issues
    one jitted ``model.decode`` PER SLOT (B dispatches + B small cache
    trees per step — the serialized hot path ``DecodeRunner`` replaces)."""

    def __init__(self, model, params, prompts: np.ndarray, *, max_new_tokens: int = 64,
                 max_slots: int = 8):
        self.model = model
        self.params = params
        self.prompts = np.asarray(prompts, np.int32)  # (N, S)
        self.max_new = max_new_tokens
        self.max_slots = max_slots
        self.n_sites = len(model.sites)
        self.dispatches = 0  # jitted decode calls (B per step)
        self._slots = {}
        self._pf = None
        self._dec = None
        self._dec0 = None  # no-ramp (vanilla) decode variant

    def _prefill_fn(self):
        if self._pf is None:
            m, S = self.model, self.prompts.shape[1]
            cache_len = S + self.max_new

            @jax.jit
            def pf(params, toks):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                lab = outs["final"]["label"]
                return cache, (lab[:, 0] if lab.ndim == 2 else lab)

            self._pf = pf
        return self._pf

    def _decode_fn(self):
        if self._dec is None:
            m = self.model

            @jax.jit
            def dec(params, cache, tok, pos, active):
                new_cache, outs = m.decode(
                    params, cache, tok, pos, active_sites=active, moe_impl="dense"
                )
                return new_cache, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_noramp(self):
        if self._dec0 is None:
            m = self.model

            @jax.jit
            def dec0(params, cache, tok, pos):
                new_cache, outs = m.decode(
                    params, cache, tok, pos, active_sites=None, moe_impl="dense"
                )
                return new_cache, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    def start(self, slot: int, item: int) -> int:
        toks = jnp.asarray(self.prompts[item][None, :])
        cache, lab = self._prefill_fn()(self.params, toks)
        tok = int(np.asarray(lab).reshape(-1)[0])
        self._slots[slot] = {"cache": cache, "pos": self.prompts.shape[1], "tok": tok}
        return tok

    def step(self, slots: Sequence[int], active: Sequence[int]):
        """One decode step for every slot in ``slots`` — one jitted B=1
        dispatch per slot. Row/column order matches ``DecodeRunner.step``."""
        act = sorted(active)[: self.max_slots]
        k = len(act)
        labels = np.zeros((max(k, 1), len(slots)), np.int64)
        unc = np.full((max(k, 1), len(slots)), 1.0, np.float32)
        final = np.zeros(len(slots), np.int64)
        if k:
            pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
            dec = self._decode_fn()
        else:
            dec0 = self._decode_fn_noramp()
        for b, s in enumerate(slots):
            st = self._slots[s]
            tok = jnp.asarray([[st["tok"]]], jnp.int32)
            if k:
                st["cache"], (rl, ru, fl) = dec(
                    self.params, st["cache"], tok, jnp.int32(st["pos"]), pad_act
                )
                labels[:, b] = np.asarray(rl).reshape(self.max_slots, -1)[:k, 0]
                unc[:, b] = np.asarray(ru).reshape(self.max_slots, -1)[:k, 0]
            else:
                st["cache"], fl = dec0(self.params, st["cache"], tok, jnp.int32(st["pos"]))
            self.dispatches += 1
            fl = int(np.asarray(fl).reshape(-1)[0])
            final[b] = fl
            st["pos"] += 1
            st["tok"] = fl  # vanilla greedy trajectory (agreement baseline)
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def free(self, slot: int) -> None:
        self._slots.pop(slot, None)


class SyntheticDecodeRunner:
    """Profile-only generative runner — the decode analogue of
    ``SyntheticRunner``: deterministic per-token ramp records without a
    model. A fixed fraction of tokens is "easy" (confidently predictable
    from ``exit_site`` onward, ramp label agreeing with the final token);
    the rest stay uncertain and disagreeing at every ramp, so an
    over-opened threshold costs accuracy exactly as with a trained LM.
    Used by the generative benchmarks/sweeps where training an LM per
    configuration would dominate runtime."""

    def __init__(self, n_sites: int, exit_site: int, easy_frac: float = 0.7,
                 vocab: int = 101):
        self.n_sites = n_sites
        self.exit_site = exit_site
        self.easy_frac = easy_frac
        self.vocab = vocab
        self._slots = {}

    def _token(self, item: int, t: int) -> int:
        return (item * 31 + t * 7 + 3) % self.vocab

    def _easy(self, item: int, t: int) -> bool:
        return ((item * 131 + t * 17) % 100) < self.easy_frac * 100

    def start(self, slot: int, item: int) -> int:
        self._slots[slot] = {"item": item, "t": 0}
        return self._token(item, 0)

    def step(self, slots: Sequence[int], active: Sequence[int]):
        act = sorted(active)
        k = len(act)
        B = len(slots)
        labels = np.zeros((max(k, 1), B), np.int64)
        unc = np.full((max(k, 1), B), 0.9, np.float32)
        final = np.zeros(B, np.int64)
        for b, s in enumerate(slots):
            st = self._slots[s]
            st["t"] += 1
            item, t = st["item"], st["t"]
            fin = self._token(item, t)
            final[b] = fin
            easy = self._easy(item, t)
            for j, site in enumerate(act):
                if easy and site >= self.exit_site:
                    labels[j, b] = fin
                    unc[j, b] = 0.02
                else:
                    labels[j, b] = (fin + 1) % self.vocab
                    unc[j, b] = 0.9
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def free(self, slot: int) -> None:
        self._slots.pop(slot, None)
