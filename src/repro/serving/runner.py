"""Model runners: execute the real (tiny, CPU-trained) models per batch and
stream ramp records to the controller.

On hardware this is the accelerator side: a single jitted program computes
the full model + K gathered ramp heads; only ~KB stat arrays (top-1 label,
max-prob, entropy per ramp) travel to the host — never logits. Batches are
padded to power-of-two buckets to bound compilation count.
"""
from __future__ import annotations

import heapq
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class PoolExhausted(RuntimeError):
    """Raised when the paged KV pool has no free block for an allocation.
    The allocator checks capacity BEFORE mutating any state, so a failed
    allocation never corrupts the block table."""


class BlockAllocator:
    """Host-side allocator for the paged KV-cache pool.

    The device pool holds ``n_blocks + 1`` physical blocks: block 0 is
    RESERVED as the trash block — bucket-padding rows point their zeroed
    table rows at it, so their (discarded) scatters land in memory no live
    slot ever reads. Allocatable ids are ``1..n_blocks``; the free heap
    always hands out the lowest id, so identical schedules produce
    identical tables (determinism the equivalence harness relies on).

    Physical blocks are REFCOUNTED: ``alloc`` hands out private blocks
    (refcount 1), ``share`` maps an already-live block into another slot's
    table (refcount += 1 — N slots with a common prompt prefix reference
    ONE physical block set), and the prefix cache holds references via
    ``pin``/``unpin``. A block returns to the free heap only when its last
    reference drops. ``cow`` implements copy-on-write: it swaps one table
    entry for a fresh private block so the caller can copy-then-mutate
    without touching the shared original.

    Invariants (asserted by the property tests):
      * every table entry (and every pinned id) references a live block;
      * ``refcount.sum() == sum(owned) + pins`` across any schedule;
      * ``n_free + (refcount > 0).sum() == n_blocks`` — no block is both
        free and referenced, none leaks;
      * allocation at exhaustion raises ``PoolExhausted`` atomically —
        no table/free-list/refcount mutation happens on the failing call.
    """

    def __init__(self, n_blocks: int, max_blocks_per_slot: int, n_slots: int = 0):
        if n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
        self.n_blocks = n_blocks
        self.max_blocks = max_blocks_per_slot
        self._free = list(range(1, n_blocks + 1))  # min-heap of free ids
        heapq.heapify(self._free)
        self.table = np.zeros((n_slots, max_blocks_per_slot), np.int32)
        self.owned = np.zeros(n_slots, np.int32)
        self.refcount = np.zeros(n_blocks + 1, np.int32)  # per physical block
        self.pins = 0  # live cache (non-slot) references
        self.peak_blocks = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def grow_slots(self, n_slots: int) -> None:
        add = n_slots - self.table.shape[0]
        if add > 0:
            self.table = np.concatenate(
                [self.table, np.zeros((add, self.max_blocks), np.int32)]
            )
            self.owned = np.concatenate([self.owned, np.zeros(add, np.int32)])

    def grow_pool(self, n_blocks: int) -> None:
        """Extend the pool with fresh block ids (existing ownership kept)."""
        if n_blocks > self.n_blocks:
            self.refcount = np.concatenate(
                [self.refcount, np.zeros(n_blocks - self.n_blocks, np.int32)]
            )
        for b in range(self.n_blocks + 1, n_blocks + 1):
            heapq.heappush(self._free, b)
        self.n_blocks = max(self.n_blocks, n_blocks)

    def require(self, n: int) -> None:
        """Check ``n`` free blocks exist WITHOUT claiming anything — the
        all-or-nothing precondition for multi-slot claims."""
        if len(self._free) < n:
            raise PoolExhausted(
                f"paged KV pool exhausted: need {n} block(s), "
                f"{len(self._free)}/{self.n_blocks} free"
            )

    def alloc(self, slot: int, n: int = 1) -> List[int]:
        """Claim ``n`` private blocks for ``slot`` (atomic: all or nothing)."""
        if self.owned[slot] + n > self.max_blocks:
            raise ValueError(
                f"slot {slot} would exceed max_blocks={self.max_blocks}"
            )
        self.require(n)
        ids = [heapq.heappop(self._free) for _ in range(n)]
        k = int(self.owned[slot])
        self.table[slot, k : k + n] = ids
        self.owned[slot] += n
        self.refcount[ids] = 1
        self.peak_blocks = max(self.peak_blocks, self.live_blocks)
        return ids

    def alloc_pinned(self, n: int) -> List[int]:
        """Claim ``n`` blocks under a cache (non-slot) reference — the
        read-only pinned pages (cross-attention encoder KV) the runner
        owns directly rather than through a slot's table row. They are
        prefilled once, never appended, and freed via ``unpin``. Atomic:
        all or nothing."""
        self.require(n)
        ids = [heapq.heappop(self._free) for _ in range(n)]
        self.refcount[ids] = 1
        self.pins += n
        self.peak_blocks = max(self.peak_blocks, self.live_blocks)
        return ids

    def share(self, slot: int, ids: Sequence[int]) -> None:
        """Map already-live blocks into ``slot``'s table (prefix sharing):
        the slot references the SAME physical blocks, refcount += 1 each."""
        if not ids:
            return
        if self.owned[slot] + len(ids) > self.max_blocks:
            raise ValueError(
                f"slot {slot} would exceed max_blocks={self.max_blocks}"
            )
        for b in ids:
            if not (1 <= b <= self.n_blocks) or self.refcount[b] < 1:
                raise ValueError(f"cannot share non-live block {b}")
        k = int(self.owned[slot])
        self.table[slot, k : k + len(ids)] = ids
        self.owned[slot] += len(ids)
        for b in ids:
            self.refcount[b] += 1

    def cow(self, slot: int, idx: int) -> Tuple[int, int]:
        """Copy-on-write: replace ``slot``'s ``idx``-th table entry with a
        fresh private block and drop the reference on the old one. Returns
        ``(old_id, new_id)`` — the caller copies the block's contents on
        device before writing. Atomic: raises before any mutation."""
        self.require(1)
        old = int(self.table[slot, idx])
        new = heapq.heappop(self._free)
        self.refcount[new] = 1
        self.table[slot, idx] = new
        self._deref(old)
        self.peak_blocks = max(self.peak_blocks, self.live_blocks)
        return old, new

    def pin(self, b: int) -> None:
        """Take a cache (non-slot) reference on a live block."""
        if not (1 <= b <= self.n_blocks) or self.refcount[b] < 1:
            raise ValueError(f"cannot pin non-live block {b}")
        self.refcount[b] += 1
        self.pins += 1

    def unpin(self, b: int) -> None:
        """Drop a cache reference; the block frees once nothing else holds it."""
        self.pins -= 1
        self._deref(b)

    def _deref(self, b: int) -> None:
        self.refcount[b] -= 1
        if self.refcount[b] == 0:
            heapq.heappush(self._free, b)

    def release_tail(self, slot: int, keep: int) -> None:
        """Drop ``slot``'s table entries beyond the first ``keep`` — a sync
        window that terminated early unwinds its over-claimed appends here,
        restoring the exact allocator state the per-step path would hold.
        ``peak_blocks`` is deliberately NOT rewound: it records the
        transient high-water mark the window really reached."""
        k = int(self.owned[slot])
        if keep >= k:
            return
        for b in self.table[slot, keep:k]:
            self._deref(int(b))
        self.table[slot, keep:k] = 0
        self.owned[slot] = keep

    def free_slot(self, slot: int) -> None:
        """Drop every reference ``slot`` holds (blocks free at refcount 0)."""
        k = int(self.owned[slot])
        for b in self.table[slot, :k]:
            self._deref(int(b))
        self.table[slot, :] = 0  # stale entries must stay valid pool ids
        self.owned[slot] = 0

    def owned_ids(self, slot: int) -> List[int]:
        return [int(b) for b in self.table[slot, : int(self.owned[slot])]]


class PrefixCache:
    """Host-side prompt-prefix trie over the paged KV pool.

    Edges are full ``block_size``-token chunks (keyed by their raw bytes);
    a node pins the physical block holding that chunk's KV, so N prompts
    sharing a prefix resolve to ONE block chain. A whole-prompt entry
    additionally records the partial tail block (when the prompt doesn't
    end on a block boundary) plus the prompt's greedy first token — a
    fully cached prompt starts with ZERO device work (TTFT ~ host time).

    The cache holds one ``pin`` reference per cached block; slots that hit
    ``share`` the same ids. When the pool runs dry, ``evict_for`` unpins
    LRU leaf entries whose block nobody else references (refcount == 1),
    so eviction can never yank a block from under a live slot — and never
    strands a parent, since any slot using a child's chain walked (and
    shares) every ancestor too.
    """

    def __init__(self, alloc: BlockAllocator, block_size: int):
        self._alloc = alloc
        self.bs = int(block_size)
        self._root = {"children": {}, "block": 0, "tick": 0, "tails": {}, "first": None}
        self._tick = 0
        self.hits = 0
        self.tokens_saved = 0
        self.blocks_shared = 0  # cumulative blocks a lookup let a slot skip
        self.evictions = 0

    def lookup(self, toks: np.ndarray, limit: Optional[int] = None):
        """Longest cached cover of ``toks[:limit]`` in whole blocks:
        returns ``(block_ids, n_covered, first_tok)``. ``first_tok`` is
        non-None only on a whole-prompt hit (tail block included)."""
        toks = np.asarray(toks)
        S = len(toks) if limit is None else min(len(toks), int(limit))
        self._tick += 1
        node, ids, m = self._root, [], 0
        while (m + 1) * self.bs <= S:
            child = node["children"].get(toks[m * self.bs : (m + 1) * self.bs].tobytes())
            if child is None:
                break
            child["tick"] = self._tick
            ids.append(child["block"])
            node, m = child, m + 1
        covered = m * self.bs
        if covered == S and node is not self._root and node["first"] is not None:
            return ids, S, node["first"]
        if m == S // self.bs and S % self.bs and S == len(toks):
            tail = node["tails"].get(toks[covered:].tobytes())
            if tail is not None:
                tail["tick"] = self._tick
                return ids + [tail["block"]], S, tail["first"]
        return ids, covered, None

    def register(self, toks: np.ndarray, ids: Sequence[int], first_tok: int) -> None:
        """Record a fully prefilled prompt: ``ids`` are the owning slot's
        blocks in order. New chunks pin their block; chunks already cached
        keep their first-registered block (the slot shares it anyway)."""
        toks = np.asarray(toks)
        S = len(toks)
        self._tick += 1
        node = self._root
        for m in range(S // self.bs):
            key = toks[m * self.bs : (m + 1) * self.bs].tobytes()
            child = node["children"].get(key)
            if child is None:
                child = {"children": {}, "block": int(ids[m]), "tick": self._tick,
                         "tails": {}, "first": None}
                self._alloc.pin(int(ids[m]))
                node["children"][key] = child
            child["tick"] = self._tick
            node = child
        if S % self.bs:
            key = toks[S - S % self.bs :].tobytes()
            tail = node["tails"].get(key)
            if tail is None:
                node["tails"][key] = {"block": int(ids[S // self.bs]),
                                      "first": int(first_tok), "tick": self._tick}
                self._alloc.pin(int(ids[S // self.bs]))
            else:
                tail["tick"] = self._tick
        elif node is not self._root and node["first"] is None:
            node["first"] = int(first_tok)

    def _evictable(self):
        """All LRU-evictable entries: tails, plus chunk nodes with no
        descendants, whose block only the cache still references."""
        out = []
        stack = [self._root]
        while stack:
            node = stack.pop()
            for key, tail in node["tails"].items():
                if self._alloc.refcount[tail["block"]] == 1:
                    out.append((tail["tick"], 1, key, node, tail))
            for key, ch in node["children"].items():
                if (not ch["children"] and not ch["tails"]
                        and self._alloc.refcount[ch["block"]] == 1):
                    out.append((ch["tick"], 0, key, node, ch))
                stack.append(ch)
        return out

    def evict_for(self, n: int) -> None:
        """Unpin least-recently-used cache-only entries until ``n`` blocks
        are free (or nothing evictable remains — the caller's ``require``
        then raises). Deterministic: ties break on kind then key bytes."""
        while self._alloc.n_free < n:
            cands = self._evictable()
            if not cands:
                return
            _, kind, key, parent, entry = min(cands, key=lambda c: c[:3])
            if kind == 1:
                del parent["tails"][key]
            else:
                del parent["children"][key]
            self._alloc.unpin(entry["block"])
            self.evictions += 1

    def clear(self) -> None:
        """Drop every cache reference (slots keep theirs)."""
        stack = [self._root]
        while stack:
            node = stack.pop()
            for tail in node["tails"].values():
                self._alloc.unpin(tail["block"])
            for ch in node["children"].values():
                self._alloc.unpin(ch["block"])
                stack.append(ch)
        self._root = {"children": {}, "block": 0, "tick": 0, "tails": {}, "first": None}


class SyntheticRunner:
    """Profile-only serving: deterministic ramp records without a model.

    A fixed fraction of items is "easy" — confidently predictable from
    ``exit_site`` onward — so controllers activate ramps and exit traffic
    exactly as with a trained model, at zero model cost. Used by the
    scale-out demos/benchmarks where training one model per replica-count
    sweep would dominate runtime.
    """

    def __init__(self, n_sites: int, exit_site: int, easy_frac: float = 0.7,
                 n_classes: int = 17):
        self.n_sites = n_sites
        self.exit_site = exit_site
        self.easy_frac = easy_frac
        self.n_classes = n_classes

    def infer(self, items: np.ndarray, active: Sequence[int]):
        items = np.asarray(items)  # repro: allow[host-sync] — host input normalization — items never lives on device
        k = len(active)
        B = len(items)
        final = (items % self.n_classes).astype(np.int64)
        easy = (items % 100) < self.easy_frac * 100
        # hard items DISAGREE with the original model at every ramp (like
        # SyntheticDecodeRunner): an over-opened threshold that releases
        # them costs accuracy, exactly as with a trained model. Tiling the
        # final label into every row made hard exits free.
        wrong = (final + 1) % self.n_classes
        labels = np.tile(wrong, (max(k, 1), 1))
        unc = np.full((max(k, 1), B), 0.9, np.float32)
        for j, s in enumerate(sorted(active)):
            if s >= self.exit_site:
                labels[j] = np.where(easy, final, wrong)
                unc[j] = np.where(easy, 0.02, 0.9)
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def vanilla_labels(self, n: int) -> np.ndarray:
        return np.arange(n, dtype=np.int64) % self.n_classes


class ClassifierRunner:
    """ResNet / BERT-style classifier serving (the paper's workloads)."""

    def __init__(self, model, params, data: np.ndarray, max_slots: int = 8):
        self.model = model
        self.params = params
        self.data = data  # (N, ...) images or token sequences
        self.max_slots = max_slots
        self._fns = {}
        self.compiles = 0  # ramp-set changes recompile (paper: model re-upload)
        self.noramp_compiles = 0  # no-ramp (vanilla) variant compiles

    def _fn(self, bs: int, act: Optional[tuple]):
        """act=None compiles the no-ramp (vanilla) variant: with zero active
        ramps the model must not execute-and-discard a ramp head — vanilla
        serving would silently pay one ramp of compute per batch."""
        key = (bs, act)
        if key not in self._fns:
            m = self.model
            if act is None:
                # no-ramp (vanilla) compiles are NOT ramp-set changes: they
                # must not inflate `compiles`, the "ramp-set change
                # recompile" stat the paper's overhead story rests on
                self.noramp_compiles += 1

                @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
                def f0(params, x):
                    return m.forward(params, x, active_sites=None)["final"]["label"]

                self._fns[key] = f0
            else:
                self.compiles += 1

                @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
                def f(params, x):
                    outs = m.forward(params, x, active_sites=list(act))
                    return (
                        outs["ramps"]["label"],
                        1.0 - outs["ramps"]["maxprob"],
                        outs["final"]["label"],
                    )

                self._fns[key] = f
        return self._fns[key]

    def infer(self, items: np.ndarray, active: Sequence[int]):
        bs = _bucket(len(items))
        idx = np.pad(items, (0, bs - len(items)), mode="edge")
        x = jnp.asarray(self.data[idx])
        act = tuple(sorted(active))
        if len(act) > self.max_slots:
            # silently truncating would return fewer record rows than the
            # controller asked for — rows land against the wrong sites
            raise ValueError(
                f"active ramp set has {len(act)} sites, max_slots={self.max_slots}"
            )
        k = len(act)
        if k == 0:
            final = np.asarray(self._fn(bs, None)(self.params, x))[: len(items)]  # repro: allow[host-sync] — sanctioned record drain: one stats pull per dispatch
            return np.zeros((0, len(items)), np.int64), np.zeros((0, len(items)), np.float32), final
        labels, unc, final = self._fn(bs, act)(self.params, x)
        labels = np.asarray(labels)[:, : len(items)]  # repro: allow[host-sync] — sanctioned record drain: one stats pull per dispatch
        unc = np.asarray(unc)[:, : len(items)]  # repro: allow[host-sync] — sanctioned record drain: one stats pull per dispatch
        final = np.asarray(final)[: len(items)]  # repro: allow[host-sync] — sanctioned record drain: one stats pull per dispatch
        return labels[:k], unc[:k].astype(np.float32), final

    def vanilla_labels(self, n: Optional[int] = None) -> np.ndarray:
        """Original-model labels for the whole stream (accuracy ground truth)."""
        # `n or len` would remap an explicit n=0 to the whole dataset
        n = n if n is not None else len(self.data)
        if n < 1:
            return np.zeros(0, np.int64)
        out = []
        for lo in range(0, n, 256):
            hi = min(lo + 256, n)
            idx = np.arange(lo, hi)
            _, _, f = self.infer(idx, [])  # no-ramp variant: zero ramp compute
            out.append(f)
        return np.concatenate(out)


class LMTokenRunner:
    """Per-token early-exit serving for decoder LMs: each request is a
    context; the served result is the next token (prefill path)."""

    def __init__(self, model, params, data: np.ndarray, max_slots: int = 8):
        self.model = model
        self.params = params
        self.data = data  # (N, S) int32 contexts
        self.max_slots = max_slots
        self._fns = {}
        self._fns0 = {}  # no-ramp (vanilla) variants

    def _fn_noramp(self, bs: int):
        if bs not in self._fns0:
            m = self.model

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def f0(params, toks):
                _, outs = m.prefill(
                    params, toks, active_sites=None, with_cache=False, moe_impl="dense"
                )
                lab = outs["final"]["label"]
                return lab[:, 0] if lab.ndim == 2 else lab

            self._fns0[bs] = f0
        return self._fns0[bs]

    def _fn(self, bs: int):
        if bs not in self._fns:
            m = self.model

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def f(params, toks, active):
                _, outs = m.prefill(
                    params, toks, active_sites=active, with_cache=False, moe_impl="dense"
                )
                return (
                    outs["ramps"]["label"][:, :, 0] if outs["ramps"]["label"].ndim == 3 else outs["ramps"]["label"],
                    1.0 - (outs["ramps"]["maxprob"][:, :, 0] if outs["ramps"]["maxprob"].ndim == 3 else outs["ramps"]["maxprob"]),
                    outs["final"]["label"][:, 0] if outs["final"]["label"].ndim == 2 else outs["final"]["label"],
                )

            self._fns[bs] = f
        return self._fns[bs]

    def infer(self, items: np.ndarray, active: Sequence[int]):
        bs = _bucket(len(items))
        idx = np.pad(items, (0, bs - len(items)), mode="edge")
        toks = jnp.asarray(self.data[idx])
        # sort (like ClassifierRunner): the controller consumes record rows
        # in ascending-site order, so an unsorted caller set must not leak
        # row misalignment into the window
        act = sorted(active)
        if len(act) > self.max_slots:
            raise ValueError(
                f"active ramp set has {len(act)} sites, max_slots={self.max_slots}"
            )
        k = len(act)
        if k == 0:
            final = np.asarray(self._fn_noramp(bs)(self.params, toks))[: len(items)]  # repro: allow[host-sync] — sanctioned record drain: one stats pull per dispatch
            return np.zeros((0, len(items)), np.int64), np.zeros((0, len(items)), np.float32), final
        pad_act = act + [act[-1]] * (self.max_slots - len(act))
        labels, unc, final = self._fn(bs)(
            self.params, toks, jnp.asarray(pad_act, jnp.int32)
        )
        final = np.asarray(final)[: len(items)]  # repro: allow[host-sync] — sanctioned record drain: one stats pull per dispatch
        return (
            np.asarray(labels)[:k, : len(items)],  # repro: allow[host-sync] — sanctioned record drain: one stats pull per dispatch
            np.asarray(unc)[:k, : len(items)].astype(np.float32),  # repro: allow[host-sync] — sanctioned record drain: one stats pull per dispatch
            final,
        )

    def vanilla_labels(self, n: Optional[int] = None) -> np.ndarray:
        # `n or len` would remap an explicit n=0 to the whole dataset
        n = n if n is not None else len(self.data)
        if n < 1:
            return np.zeros(0, np.int64)
        out = []
        for lo in range(0, n, 128):
            idx = np.arange(lo, min(lo + 128, n))
            _, _, f = self.infer(idx, [])  # no-ramp variant: zero ramp compute
            out.append(f)
        return np.concatenate(out)


class DecodeRunner:
    """Real-model generative runner: drives ``model.decode`` with ONE
    jitted dispatch per engine step over a single batched slot cache,
    streaming one ramp record per in-flight token to the controller (the
    paper's generative per-token exits).

    Records are replay-complete — the full model and the gathered ramp
    heads run for every token, because the controller needs agreement
    labels to adapt — while serving *time* is simulated by the engine from
    the latency profile (truncated compute + deferred KV catch-up). The
    decoded trajectory follows the original model's greedy tokens so
    per-token agreement against the vanilla stream stays measurable even
    when a ramp disagrees.

    The cache is one batched tree keyed by slot index: ``start`` prefills
    into a slot row, ``step(slots, active)`` gathers the live rows, runs a
    single jitted decode with per-row positions (``model.decode`` takes
    ``pos: int32[B]``), and scatters the rows back; ``free`` just releases
    the row. Continuous batching admits/retires at step boundaries, so row
    positions diverge — per-row cache write indices are what make the
    shared cache sound. Live rows are padded to a power-of-two bucket with
    FREE rows (distinct indices, so the scatter is collision-free and the
    padded rows hold garbage no one reads), bounding compile count at
    log2(n_slots) shapes. Batch-level timing comes from the profile, not
    from here.

    With a ``decode_attn='paged*'`` model config the slot cache is PAGED:
    one global pool of ``kv_blocks`` fixed-size blocks (``kv_block_size``
    key/value tokens each) plus a per-slot block table, managed by a
    host-side ``BlockAllocator``. ``start`` claims ``ceil(prompt_len /
    block_size)`` blocks and scatters the prefill KV into them, ``step``
    appends a block only when a slot's current block fills, and ``free``
    returns the slot's blocks to the pool — KV memory scales with LIVE
    TOKENS instead of ``n_slots * max_len``, at the same one dispatch per
    engine step. ``kv_blocks=None`` auto-sizes the pool to full slot
    capacity (the contiguous equivalent); a smaller explicit pool admits
    more slots than contiguous memory would allow, and exhausting it
    raises ``PoolExhausted`` cleanly.
    """

    def __init__(self, model, params, prompts: np.ndarray, *, max_new_tokens: int = 64,
                 max_slots: int = 8, n_slots: Optional[int] = None,
                 kv_block_size: int = 16, kv_blocks: Optional[int] = None,
                 prefix_cache: bool = False):
        self.model = model
        self.params = params
        self.prompts = np.asarray(prompts, np.int32)  # (N, S)
        self.max_new = max_new_tokens
        self.max_slots = max_slots  # K ramp gather slots (not decode rows)
        self.n_sites = len(model.sites)
        self.dispatches = 0  # jitted decode-step calls (1/step, not 1/slot)
        self._cache = None  # batched slot cache; rows grown on demand
        self._rows = 0 if n_slots is None else _bucket(max(n_slots, 1))
        self._cache_len = self.prompts.shape[1] + self.max_new
        self._live = set()
        self._pos = np.zeros(0, np.int64)
        self._tok = np.zeros(0, np.int64)
        self._axes: Optional[Tuple[int, ...]] = None  # per-leaf batch axis
        self._pf = None
        self._pf_paged = {}  # paged prefill programs, keyed by token count
        self._pf_progress = {}  # slot -> item for in-flight chunked prefills
        self._dec = None
        self._dec0 = None  # no-ramp (vanilla) decode variant
        self._decm = {}  # multi-step (sync window) programs, keyed by n_max
        self._decm0 = {}  # no-ramp multi-step variant, keyed by n_max
        # device-resident exit thresholds: pushed once per sync window and
        # ONLY when the controller actually changed them — between syncs
        # the device decides exits from this (deliberately stale) copy
        self._thr_host = None
        self._thr_dev = None
        # -- paged-KV state (decode_attn='paged'|'paged-kernel'|'paged-interpret')
        self.paged = str(getattr(model.cfg, "decode_attn", "")).startswith("paged")
        self._bs_blk = int(kv_block_size)
        self._kv_blocks = kv_blocks
        if self.paged and self._bs_blk < 1:
            raise ValueError(f"paged decode needs kv_block_size >= 1, got {kv_block_size}")
        if prefix_cache and not self.paged:
            raise ValueError("prefix_cache requires a paged decode_attn config")
        if prefix_cache and not getattr(model, "paged_sharing_ok", True):
            # sharing moves TOKEN pages between tables; mamba state pages,
            # ring (position-aliased) pages and pinned xkv pages don't
            # share — refusing here beats silently corrupting slots later
            raise ValueError(
                "prefix_cache: prefix sharing/CoW is unsound for this model "
                "family (recurrent-state, ring-window, or cross-attention "
                "pages cannot be shared between slots)"
            )
        # kv_block_size is meaningless for contiguous runners (0 documents
        # "contiguous" at the CLI) — don't let it poison the ceil below
        self._max_blocks = -(-self._cache_len // self._bs_blk) if self.paged else 0
        self._alloc: Optional[BlockAllocator] = None
        self._pool_axes: Optional[Tuple[int, ...]] = None  # per-leaf pool axis
        # per-leaf page kinds ('tokens' | 'state' | 'xkv') steering the
        # prefill scatter and swap gather/scatter branches, plus the count
        # of trailing pinned xkv table columns (0 for non-cross plans)
        self._kinds: Optional[Tuple[str, ...]] = (
            tuple(model.paged_cache_kinds(2, self._bs_blk)) if self.paged else None
        )
        self._nbx = (
            int(model.paged_xkv_blocks(self._bs_blk))
            if self.paged and hasattr(model, "paged_xkv_blocks") else 0
        )
        self._xkv_tab = np.zeros((0, self._nbx), np.int32)  # per-slot pinned ids
        self._want_prefix = bool(prefix_cache)
        self._prefix: Optional[PrefixCache] = None  # built with the allocator
        self._copy_blk = None  # jitted whole-block pool copy (CoW)
        self.cow_copies = 0
        self.saved_blocks = 0  # cumulative blocks prefix hits let slots skip
        self.swap_outs = 0
        self.swap_ins = 0
        self.swapped_blocks = 0  # cumulative blocks moved to host buffers

    # -- batched-cache plumbing ---------------------------------------------

    @staticmethod
    def _diff_axes(a, b) -> Tuple[int, ...]:
        """Per-leaf axis where two schema variants disagree — the batch
        (contiguous) or pool (paged) dim: scanned blocks carry a leading
        period dim, prefix/suffix leaves don't."""
        return tuple(
            next(i for i, (x, y) in enumerate(zip(la.shape, lb.shape)) if x != y)
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b))
        )

    def _grow_rows(self, rows: int) -> None:
        self._rows = rows
        self._pos = np.concatenate([self._pos, np.zeros(rows - len(self._pos), np.int64)])
        self._tok = np.concatenate([self._tok, np.zeros(rows - len(self._tok), np.int64)])

    def _ensure_rows(self, n: int) -> None:
        """Allocate (or grow) the batched cache to >= n power-of-two rows.
        Growth copies live rows once; steady state never reallocates."""
        if self._cache is not None and n <= self._rows:
            return
        if self.paged:
            self._ensure_rows_paged(n)
            return
        rows = _bucket(max(n, self._rows, 1))
        new = self.model.init_cache(rows, self._cache_len)
        if self._axes is None:
            self._axes = self._diff_axes(
                self.model.cache_schema(1, 2), self.model.cache_schema(2, 2)
            )
        if self._cache is not None:
            old, td = jax.tree.flatten(self._cache)
            new_l = jax.tree.leaves(new)
            new = jax.tree.unflatten(td, [
                jax.lax.dynamic_update_slice_in_dim(nl, ol, 0, axis=ax)
                for nl, ol, ax in zip(new_l, old, self._axes)
            ])
        self._cache = new
        self._grow_rows(rows)

    def _tree_take(self, cache, rows):
        leaves, td = jax.tree.flatten(cache)
        return jax.tree.unflatten(td, [
            jnp.take(l, rows, axis=ax) for l, ax in zip(leaves, self._axes)
        ])

    def _tree_put(self, cache, sub, rows):
        leaves, td = jax.tree.flatten(cache)
        subl = jax.tree.leaves(sub)
        out = []
        for l, s, ax in zip(leaves, subl, self._axes):
            upd = jnp.moveaxis(l, ax, 0).at[rows].set(jnp.moveaxis(s, ax, 0))
            out.append(jnp.moveaxis(upd, 0, ax))
        return jax.tree.unflatten(td, out)

    # -- paged-pool plumbing -------------------------------------------------

    def _ensure_rows_paged(self, n: int) -> None:
        """Grow table rows (and, when ``kv_blocks`` is auto, the block pool)
        to cover >= n power-of-two slots. The pool array holds
        ``n_blocks + 1`` physical blocks — block 0 is the allocator's
        reserved trash block."""
        rows = _bucket(max(n, self._rows, 1))
        nblk = (self._kv_blocks if self._kv_blocks is not None
                else rows * (self._max_blocks + self._nbx))
        if self._alloc is None:
            if self._pool_axes is None:
                self._pool_axes = self._diff_axes(
                    self.model.paged_cache_schema(1, self._bs_blk),
                    self.model.paged_cache_schema(2, self._bs_blk),
                )
            self._alloc = BlockAllocator(nblk, self._max_blocks, rows)
            self._cache = self.model.init_paged_cache(nblk + 1, self._bs_blk)
            if self._want_prefix:
                self._prefix = PrefixCache(self._alloc, self._bs_blk)
        else:
            self._alloc.grow_slots(rows)
            if nblk > self._alloc.n_blocks:
                new = self.model.init_paged_cache(nblk + 1, self._bs_blk)
                old, td = jax.tree.flatten(self._cache)
                new_l = jax.tree.leaves(new)
                self._cache = jax.tree.unflatten(td, [
                    jax.lax.dynamic_update_slice_in_dim(nl, ol, 0, axis=ax)
                    for nl, ol, ax in zip(new_l, old, self._pool_axes)
                ])
                self._alloc.grow_pool(nblk)
        if self._nbx and self._xkv_tab.shape[0] < rows:
            self._xkv_tab = np.concatenate([
                self._xkv_tab,
                np.zeros((rows - self._xkv_tab.shape[0], self._nbx), np.int32),
            ])
        self._grow_rows(rows)

    def cache_bytes(self) -> int:
        """Device bytes held by the KV cache (pool or contiguous rows)."""
        if self._cache is None:
            return 0
        return int(sum(
            l.size * np.dtype(l.dtype).itemsize for l in jax.tree.leaves(self._cache)
        ))

    def kv_stats(self) -> dict:
        out = {"paged": self.paged, "cache_bytes": float(self.cache_bytes())}
        if self.paged and self._alloc is not None:
            out.update(
                block_size=self._bs_blk,
                n_blocks=self._alloc.n_blocks,
                live_blocks=self._alloc.live_blocks,
                peak_blocks=self._alloc.peak_blocks,
                peak_token_capacity=self._alloc.peak_blocks * self._bs_blk,
                shared_blocks=int((self._alloc.refcount > 1).sum()),
                cow_copies=self.cow_copies,
                swap_outs=self.swap_outs,
                swap_ins=self.swap_ins,
                swapped_blocks=self.swapped_blocks,
            )
            if self._prefix is not None:
                out.update(
                    prefix_hits=self._prefix.hits,
                    prefix_tokens_saved=self._prefix.tokens_saved,
                    saved_blocks=self.saved_blocks,
                    prefix_evictions=self._prefix.evictions,
                    pinned_blocks=self._alloc.pins,
                )
        return out

    # -- jitted programs ----------------------------------------------------

    def _prefill_fn(self):
        """Prefill one prompt AND scatter its cache into the slot row —
        one dispatch per admit (`slot` is a traced scalar: no recompile
        per slot id)."""
        if self._pf is None:
            m, cache_len = self.model, self._cache_len

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def pf(params, big, toks, slot):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                big = self._tree_put(big, cache, slot[None])
                lab = outs["final"]["label"]
                return big, (lab[:, 0] if lab.ndim == 2 else lab)

            self._pf = pf
        return self._pf

    def _decode_fn(self):
        if self._dec is None:
            m = self.model

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec(params, big, toks, pos, rows, active):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode(
                    params, sub, toks, pos, active_sites=active, moe_impl="dense"
                )
                big = self._tree_put(big, sub, rows)
                return big, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_noramp(self):
        """Ramp-free decode: with zero active ramps (controller bootstrap /
        budget-busted states) the step must not execute-and-discard ramp
        heads — same fix as the classifier/token runners' no-ramp variants."""
        if self._dec0 is None:
            m = self.model

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec0(params, big, toks, pos, rows):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode(
                    params, sub, toks, pos, active_sites=None, moe_impl="dense"
                )
                big = self._tree_put(big, sub, rows)
                return big, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    def _prefill_fn_paged(self, n_tokens: Optional[int] = None):
        """Prefill one prompt (or its first ``n_tokens`` — a chunked-prefill
        first chunk) contiguously AND scatter its KV into the slot's claimed
        pool blocks — one dispatch per admit (``blk_ids`` is a traced
        array: no recompile per block assignment). Compiled per distinct
        token count (full prompts and one chunk size in practice)."""
        n_tokens = self.prompts.shape[1] if n_tokens is None else n_tokens
        if n_tokens not in self._pf_paged:
            m, cache_len = self.model, self._cache_len
            bs = self._bs_blk
            nb_pf = -(-n_tokens // bs)
            axes, kinds = self._pool_axes, self._kinds
            nbx = self._nbx

            def scatter(pool, cont, ax, blk_ids, nb):
                # cont: contiguous leaf, batch dim (size 1) at ax, tokens at
                # ax+1; pool: (..., P, bs, ...) with P at ax. Regroup the
                # first nb*bs prefill tokens into blocks and write them
                # to the claimed pool slots.
                x = jnp.moveaxis(cont, ax, 0)[0]
                t = jnp.moveaxis(x, ax, 0)  # tokens first, rest order kept
                need = nb * bs
                if t.shape[0] < need:
                    t = jnp.pad(t, [(0, need - t.shape[0])] + [(0, 0)] * (t.ndim - 1))
                t = t[:need].reshape((nb, bs) + t.shape[1:])
                p2 = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
                p2 = p2.at[blk_ids].set(t.astype(p2.dtype))
                return jnp.moveaxis(p2, (0, 1), (ax, ax + 1))

            def scatter_state(pool, cont, ax, page):
                # per-slot state page (mamba conv/ssm): the whole recurrent
                # state of batch row 0 lands in the slot's FIRST block —
                # the same id token pools use for tokens 0..bs-1; distinct
                # leaves, so the double use never collides.
                x = jnp.moveaxis(cont, ax, 0)[0]
                p2 = jnp.moveaxis(pool, ax, 0)
                return jnp.moveaxis(p2.at[page].set(x.astype(p2.dtype)), 0, ax)

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def pf(params, pools, toks, blk_ids, xkv_ids):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                leaves, td = jax.tree.flatten(pools)
                cl = jax.tree.leaves(cache)
                out = []
                for p, c, ax, kind in zip(leaves, cl, axes, kinds):
                    if kind == "state":
                        out.append(scatter_state(p, c, ax, blk_ids[0]))
                    elif kind == "xkv":
                        out.append(scatter(p, c, ax, xkv_ids, nbx))
                    else:
                        out.append(scatter(p, c, ax, blk_ids, nb_pf))
                pools = jax.tree.unflatten(td, out)
                lab = outs["final"]["label"]
                return pools, (lab[:, 0] if lab.ndim == 2 else lab)

            self._pf_paged[n_tokens] = pf
        return self._pf_paged[n_tokens]

    def _decode_fn_paged(self):
        if self._dec is None:
            m = self.model

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec(params, pools, toks, pos, tables, active):
                pools, outs = m.decode(
                    params, pools, toks, pos, active_sites=active,
                    moe_impl="dense", block_tables=tables,
                )
                return pools, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_paged_noramp(self):
        if self._dec0 is None:
            m = self.model

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec0(params, pools, toks, pos, tables):
                pools, outs = m.decode(
                    params, pools, toks, pos, active_sites=None,
                    moe_impl="dense", block_tables=tables,
                )
                return pools, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    def _donate_cache(self):
        """Donate the cache/pool operand to the multi-step program so the
        while_loop reuses its buffers in place (the runner always rebinds
        ``self._cache`` from the result). CPU XLA does not implement
        donation and would warn per dispatch — skip it there."""
        return (1,) if jax.default_backend() != "cpu" else ()

    def _decode_multi_fn(self, n_max: int):
        if n_max not in self._decm:
            m = self.model

            @partial(jax.jit, donate_argnums=self._donate_cache())  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def decm(params, big, toks, pos, rows, active, thr, n, valid):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode_multi(
                    params, sub, toks, pos, n, n_max=n_max,
                    active_sites=active, thresholds=thr, row_valid=valid,
                    moe_impl="dense",
                )
                big = self._tree_put(big, sub, rows)
                return big, outs

            self._decm[n_max] = decm
        return self._decm[n_max]

    def _decode_multi_fn_noramp(self, n_max: int):
        if n_max not in self._decm0:
            m = self.model

            @partial(jax.jit, donate_argnums=self._donate_cache())  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def decm0(params, big, toks, pos, rows, n, valid):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode_multi(
                    params, sub, toks, pos, n, n_max=n_max,
                    active_sites=None, row_valid=valid, moe_impl="dense",
                )
                big = self._tree_put(big, sub, rows)
                return big, outs

            self._decm0[n_max] = decm0
        return self._decm0[n_max]

    def _decode_multi_fn_paged(self, n_max: int):
        if n_max not in self._decm:
            m = self.model

            @partial(jax.jit, donate_argnums=self._donate_cache())  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def decm(params, pools, toks, pos, tables, active, thr, n, valid):
                pools, outs = m.decode_multi(
                    params, pools, toks, pos, n, n_max=n_max,
                    active_sites=active, thresholds=thr, row_valid=valid,
                    moe_impl="dense", block_tables=tables,
                )
                return pools, outs

            self._decm[n_max] = decm
        return self._decm[n_max]

    def _decode_multi_fn_paged_noramp(self, n_max: int):
        if n_max not in self._decm0:
            m = self.model

            @partial(jax.jit, donate_argnums=self._donate_cache())  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def decm0(params, pools, toks, pos, tables, n, valid):
                pools, outs = m.decode_multi(
                    params, pools, toks, pos, n, n_max=n_max,
                    active_sites=None, row_valid=valid,
                    moe_impl="dense", block_tables=tables,
                )
                return pools, outs

            self._decm0[n_max] = decm0
        return self._decm0[n_max]

    def _copy_block_fn(self):
        """Whole-block pool copy (CoW): duplicate physical block ``src``
        into ``dst`` across every cache leaf — src/dst are traced scalars,
        so one compile covers every copy."""
        if self._copy_blk is None:
            axes = self._pool_axes

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def cp(pools, src, dst):
                leaves, td = jax.tree.flatten(pools)
                out = []
                for l, ax in zip(leaves, axes):
                    m = jnp.moveaxis(l, ax, 0)
                    m = m.at[dst].set(m[src])
                    out.append(jnp.moveaxis(m, 0, ax))
                return jax.tree.unflatten(td, out)

            self._copy_blk = cp
        return self._copy_blk

    # -- prefix sharing / CoW / swap plumbing --------------------------------

    def _reserve(self, n: int) -> None:
        """Guarantee ``n`` free blocks, evicting cache-only prefix entries
        (LRU) if needed; raises ``PoolExhausted`` without mutating slot
        state when even a drained cache can't cover the claim."""
        if self._prefix is not None:
            self._prefix.evict_for(n)
        self._alloc.require(n)

    def _claim_step_blocks(self, slots: Sequence[int], offset: int = 0) -> None:
        """All-or-nothing block claim for one decode-token write per slot:
        totals the appends (slot's current block full) and CoW copies
        (append lands in a block another slot or the prefix cache still
        references) across ALL stepped slots, reserves them in one pass,
        THEN mutates — a mid-loop ``PoolExhausted`` can no longer leave
        earlier slots holding freshly appended blocks.

        ``offset`` claims for the write at ``pos + offset`` instead of
        ``pos``: a sync window pre-claims its N steps as N sequential
        calls with offsets 0..N-1, which replicates the per-step claim
        (and prefix-eviction) order EXACTLY — block-id assignment off the
        min-heap stays bit-identical to N separate ``step`` calls."""
        al, bs = self._alloc, self._bs_blk
        need_app, need_cow, total = [], [], 0
        for s in dict.fromkeys(slots):
            k, p = int(al.owned[s]), int(self._pos[s]) + offset
            na = max(0, p // bs + 1 - k)
            if k + na > al.max_blocks:
                raise ValueError(
                    f"slot {s} would exceed max_blocks={al.max_blocks}"
                )
            if na:
                need_app.append((s, na))
                total += na
            elif al.refcount[al.table[s, p // bs]] > 1:
                need_cow.append((s, p // bs))
                total += 1
        if not total:
            return
        self._reserve(total)
        for s, na in need_app:
            al.alloc(s, na)
        for s, bi in need_cow:
            old, new = al.cow(s, bi)
            self._cache = self._copy_block_fn()(
                self._cache, jnp.int32(old), jnp.int32(new)
            )
            self.cow_copies += 1

    def _free_slot_blocks(self, slot: int) -> None:
        """Release every block reference ``slot`` holds: its token table
        row AND its pinned read-only xkv pages."""
        self._alloc.free_slot(slot)
        if self._nbx and self._xkv_tab[slot, 0]:
            for b in self._xkv_tab[slot]:
                self._alloc.unpin(int(b))
            self._xkv_tab[slot] = 0

    def _claim_xkv(self, slot: int) -> None:
        """Claim ``slot``'s pinned xkv pages (cross-attention encoder KV):
        once per admission, prefilled once, never appended, freed with the
        slot. Raises ``PoolExhausted`` atomically."""
        if not self._nbx or self._xkv_tab[slot, 0]:
            return
        self._reserve(self._nbx)
        self._xkv_tab[slot] = self._alloc.alloc_pinned(self._nbx)

    def _xkv_ids_j(self, slot: int):
        ids = self._xkv_tab[slot] if self._nbx else np.zeros(0, np.int32)
        return jnp.asarray(ids, jnp.int32)

    def _ship_tables(self, rows, zero_lo: int, zero_hi: int):
        """Device block tables for ``rows``: the allocator's token-table
        rows widened by the trailing pinned xkv columns. Rows in
        ``[zero_lo, zero_hi)`` — the FREE bucket-padding rows, whose stale
        entries may reference blocks live slots now own — are redirected
        wholesale to the reserved trash block 0."""
        t = self._alloc.table[rows].copy()
        t[zero_lo:zero_hi] = 0
        if self._nbx:
            x = self._xkv_tab[rows].copy()
            x[zero_lo:zero_hi] = 0
            t = np.concatenate([t, x], axis=1)
        return jnp.asarray(t, jnp.int32)

    def _check_admission_capacity(self) -> None:
        """Admission guard: a slot started now will write ``prompt_len +
        max_new`` tokens into a cache sized at construction time. Refuse
        with a clear error HERE instead of silently overflowing the slot
        tail (contiguous: out-of-range scatters clamp; paged: the table
        walk reads another slot's blocks) — catches stale-capacity hazards
        such as the prompts array being swapped for a longer one after the
        runner was built."""
        plen = int(self.prompts.shape[1])
        need = plen + self.max_new
        if self.paged:
            cap = self._max_blocks * self._bs_blk
            layout = (f"paged capacity {cap} tokens "
                      f"({self._max_blocks} blocks x {self._bs_blk})")
        else:
            cap = self._cache_len
            layout = f"contiguous cache_len {cap}"
        if need > cap:
            raise ValueError(
                f"cannot admit: prompt_len({plen}) + max_new({self.max_new}) "
                f"= {need} tokens exceeds the slot cache capacity — {layout}; "
                "rebuild the runner with a larger max_new_tokens/cache"
            )

    def cached_prefix_tokens(self, item: int) -> int:
        """Prompt tokens of ``item`` already covered by the prefix cache
        (0 without one) — the engine prices prefill on the uncached tail."""
        if self._prefix is None:
            return 0
        _, covered, _ = self._prefix.lookup(self.prompts[item])
        return covered

    def swap_out(self, slot: int) -> dict:
        """Preempt ``slot``: gather its KV blocks into host buffers, drop
        its block references, and retire the slot — the pool space funds
        other streams. Returns an opaque handle for ``swap_in``. Shared
        blocks stay live (the other holders keep them); the handle carries
        their CONTENT, so restore never depends on cache survival."""
        if not self.paged:
            raise ValueError("swap_out requires a paged KV cache")
        if slot not in self._live:
            raise KeyError(f"slot {slot} is not live")
        if slot in self._pf_progress:
            raise KeyError(f"slot {slot} is mid-prefill (cannot swap)")
        ids = self._alloc.owned_ids(slot)
        idx = jnp.asarray(ids, jnp.int32)
        # owned token blocks cover the "state" leaves too: a slot's state
        # page IS its first table entry's block id, and both swap_out's
        # gather and swap_in's scatter walk ids in table order, so state
        # content rides along at position 0. Pinned xkv pages are NOT in
        # the owned set — gather them from the slot's xkv row.
        xidx = self._xkv_ids_j(slot)
        bufs = [np.asarray(jnp.take(l, xidx if kd == "xkv" else idx, axis=ax))  # repro: allow[host-sync] — swap-out IS the host transfer — gathering KV blocks is its job
                for l, ax, kd in zip(jax.tree.leaves(self._cache),
                                     self._pool_axes, self._kinds)]
        n_xkv = int(self._nbx) if self._nbx and self._xkv_tab[slot, 0] else 0
        self._free_slot_blocks(slot)
        self._live.discard(slot)
        self.swap_outs += 1
        self.swapped_blocks += len(ids) + n_xkv
        return {"bufs": bufs, "n_blocks": len(ids), "n_xkv": n_xkv,
                "pos": int(self._pos[slot]), "tok": int(self._tok[slot])}

    def swap_in(self, slot: int, handle: dict) -> None:
        """Readmit a swapped stream into ``slot`` (any free slot): claim
        fresh blocks, scatter the host buffers back, restore pos/token.
        The restored blocks are private copies — bit-identical content, so
        the decode trajectory is unchanged by the round trip."""
        if not self.paged:
            raise ValueError("swap_in requires a paged KV cache")
        self._ensure_rows(slot + 1)
        if slot in self._live:  # engine frees before reuse; be defensive
            self._free_slot_blocks(slot)
        n = int(handle["n_blocks"])
        nx = int(handle.get("n_xkv", 0))  # repro: allow[host-sync] — handle is host dict, not device data
        self._reserve(n + nx)
        ids = self._alloc.alloc(slot, n)
        if nx:
            self._xkv_tab[slot] = self._alloc.alloc_pinned(nx)
        idx = jnp.asarray(ids, jnp.int32)
        xidx = self._xkv_ids_j(slot)
        leaves, td = jax.tree.flatten(self._cache)
        out = []
        for l, b, ax, kd in zip(leaves, handle["bufs"], self._pool_axes,
                                self._kinds):
            tgt = xidx if kd == "xkv" else idx
            m = jnp.moveaxis(l, ax, 0).at[tgt].set(jnp.moveaxis(jnp.asarray(b), ax, 0))
            out.append(jnp.moveaxis(m, 0, ax))
        self._cache = jax.tree.unflatten(td, out)
        self._live.add(slot)
        self._pos[slot] = handle["pos"]
        self._tok[slot] = handle["tok"]
        self._pf_progress.pop(slot, None)
        self.swap_ins += 1

    # -- engine interface ----------------------------------------------------

    def start(self, slot: int, item: int) -> int:
        """Prefill ``item``'s prompt into ``slot``'s cache row (contiguous)
        or its freshly claimed pool blocks (paged); returns the first
        generated (greedy) token.

        With a prefix cache, cached blocks are SHARED into the slot's
        table instead of recomputed: a whole-prompt hit returns the cached
        first token with ZERO device work; a partial hit runs the same
        one-shot prefill jit but redirects the cached chunks' scatters to
        the trash block, so only the uncached tail blocks are written —
        either way the slot state is bit-identical to a private prefill."""
        self._check_admission_capacity()
        self._ensure_rows(slot + 1)
        toks = jnp.asarray(self.prompts[item][None, :])
        if self.paged:
            if slot in self._live:  # engine frees before reuse; be defensive
                self._free_slot_blocks(slot)
            S = self.prompts.shape[1]
            nb_pf = -(-S // self._bs_blk)
            shared, covered, first = ([], 0, None)
            if self._prefix is not None:
                shared, covered, first = self._prefix.lookup(self.prompts[item])
                if covered:
                    self._prefix.hits += 1
                    self._prefix.tokens_saved += covered
                    self.saved_blocks += len(shared)
            if shared:
                # share BEFORE reserving: the extra reference protects the
                # cached blocks from the eviction a reserve may trigger
                self._alloc.share(slot, shared)
            if first is not None:
                tok = int(first)  # whole prompt cached: TTFT ~ 0
            else:
                n_new = nb_pf - len(shared)
                try:
                    if n_new:
                        self._reserve(n_new)
                    blks = self._alloc.alloc(slot, n_new) if n_new else []
                    self._claim_xkv(slot)
                except PoolExhausted:
                    self._free_slot_blocks(slot)  # unwind the shares: retry-safe
                    raise
                ids = [0] * len(shared) + blks
                self._cache, lab = self._prefill_fn_paged()(
                    self.params, self._cache, toks, jnp.asarray(ids, jnp.int32),
                    self._xkv_ids_j(slot),
                )
                tok = int(np.asarray(lab).reshape(-1)[0])  # repro: allow[host-sync] — sanctioned first-token read: admission needs the prefill label
            if self._prefix is not None:
                self._prefix.register(self.prompts[item], self._alloc.owned_ids(slot), tok)
        else:
            self._cache, lab = self._prefill_fn()(
                self.params, self._cache, toks, jnp.int32(slot)
            )
            tok = int(np.asarray(lab).reshape(-1)[0])  # repro: allow[host-sync] — sanctioned first-token read: admission needs the prefill label
        self._live.add(slot)
        self._pos[slot] = self.prompts.shape[1]
        self._tok[slot] = tok
        self._pf_progress.pop(slot, None)  # one-shot start supersedes chunks
        return tok

    # -- chunked prefill (resumable against the same slot cache) ------------

    def prefill_begin(self, slot: int, item: int, n_tokens: int) -> Optional[int]:
        """First chunk of a chunked prefill: jitted prefill of the prompt's
        first ``n_tokens`` into the slot row (contiguous) or its freshly
        claimed pool blocks (paged). Returns the first generated token when
        ``n_tokens`` already covers the whole prompt (== ``start``), else
        None — resume with ``prefill_resume``; the slot cache is valid
        mid-prompt, so decode steps for OTHER slots interleave freely."""
        self._check_admission_capacity()
        S = self.prompts.shape[1]
        n = min(int(n_tokens), S)
        if n >= S:
            return self.start(slot, item)
        if n < 1:
            raise ValueError(f"prefill chunk must be >= 1 token, got {n_tokens}")
        self._ensure_rows(slot + 1)
        toks = jnp.asarray(self.prompts[item][None, :n])
        if self.paged:
            if slot in self._live:  # engine frees before reuse; be defensive
                self._free_slot_blocks(slot)
            shared, covered = [], 0
            if self._prefix is not None:
                # cached FULL chunks inside the first chunk are shared, not
                # recomputed (tail entries only apply to whole prompts)
                shared, covered, _ = self._prefix.lookup(self.prompts[item], limit=n)
                if covered:
                    self._prefix.hits += 1
                    self._prefix.tokens_saved += covered
                    self.saved_blocks += len(shared)
                if shared:
                    self._alloc.share(slot, shared)
                if covered == n:  # chunk fully cached: no device work
                    self._live.add(slot)
                    self._pos[slot] = n
                    self._pf_progress[slot] = item
                    return None
            n_new = -(-n // self._bs_blk) - len(shared)
            try:
                if self._prefix is not None:
                    self._reserve(n_new)
                blks = self._alloc.alloc(slot, n_new)
                self._claim_xkv(slot)
            except PoolExhausted:
                self._free_slot_blocks(slot)  # unwind the shares: retry-safe
                raise
            ids = [0] * len(shared) + blks
            self._cache, _ = self._prefill_fn_paged(n)(
                self.params, self._cache, toks, jnp.asarray(ids, jnp.int32),
                self._xkv_ids_j(slot),
            )
        else:
            self._cache, _ = self._prefill_fn()(
                self.params, self._cache, toks, jnp.int32(slot)
            )
        self._live.add(slot)
        self._pos[slot] = n
        self._pf_progress[slot] = item
        return None

    def prefill_resume(self, slot: int, n_tokens: int) -> Optional[int]:
        """Resume a chunked prefill: feed the next ``n_tokens`` prompt
        tokens through the no-ramp decode path, one token per dispatch —
        each token scatters its KV at the slot's position exactly as a
        decode step would (appending pool blocks as they fill on the paged
        layout), so the chunk is genuinely incremental against the shared
        slot cache. Returns the first generated token (the greedy
        continuation of the last prompt token) once the prompt is
        exhausted, else None. A production kernel would run the chunk as
        one (n_tokens)-wide dispatch; the per-token loop is the
        oracle-grade equivalent at the same cache layout."""
        if int(n_tokens) < 1:
            # silently feeding nothing would leave the slot stuck
            # mid-prefill with no progress signal — validate like
            # prefill_begin does
            raise ValueError(f"prefill chunk must be >= 1 token, got {n_tokens}")
        item = self._pf_progress[slot]
        S = self.prompts.shape[1]
        lab = None
        end = min(int(self._pos[slot]) + int(n_tokens), S)
        for p in range(int(self._pos[slot]), end):
            lab = self._feed_prompt_token(slot, int(self.prompts[item][p]))
        if int(self._pos[slot]) >= S:
            del self._pf_progress[slot]
            self._tok[slot] = int(lab)
            if self._prefix is not None:
                self._prefix.register(
                    self.prompts[item], self._alloc.owned_ids(slot), int(lab)
                )
            return int(lab)
        return None

    def _feed_prompt_token(self, slot: int, tok: int) -> int:
        """One resumed-prefill token through the (no-ramp) decode program:
        B=1 gather/scatter on the batched cache, per-row position — the
        same compiled path a decode step uses, so the cache layout cannot
        diverge between chunked and one-shot prefill."""
        rows = np.asarray([slot], np.int64)  # repro: allow[host-sync] — host row-index build — no device operand
        toks = jnp.asarray([[tok]], jnp.int32)
        pos = jnp.asarray(self._pos[rows], jnp.int32)
        if self.paged:
            self._claim_step_blocks([slot])
            tables = self._ship_tables(rows, 1, 1)
            self._cache, fl = self._decode_fn_paged_noramp()(
                self.params, self._cache, toks, pos, tables
            )
        else:
            self._cache, fl = self._decode_fn_noramp()(
                self.params, self._cache, toks, pos, jnp.asarray(rows, jnp.int32)
            )
        self.dispatches += 1
        self._pos[slot] += 1
        return int(np.asarray(fl).reshape(-1)[0])  # repro: allow[host-sync] — sanctioned token read: resumed prefill feeds it to the next chunk

    def _bucket_rows(self, B: int) -> int:
        """Bucket size for a step over ``B`` live slots. Subclasses with a
        data-parallel mesh raise the floor so the padded batch divides the
        `data` axis (both are powers of two)."""
        return _bucket(B)

    def _validate_active(self, active: Sequence[int]) -> List[int]:
        """Sorted active set, refusing (not silently truncating) oversize
        sets: truncation would return fewer record rows than the controller
        asked for and land rows against the wrong sites — the same fix
        ``ClassifierRunner.infer``/``LMTokenRunner.infer`` carry."""
        act = sorted(active)
        if len(act) > self.max_slots:
            raise ValueError(
                f"active ramp set has {len(act)} sites, max_slots={self.max_slots}"
            )
        return act

    def _validate_slots(self, slots: Sequence[int]) -> List[int]:
        slots = list(slots)
        for s in slots:
            if s not in self._live:
                raise KeyError(f"slot {s} is not live (freed or never started)")
            if s in self._pf_progress:
                raise KeyError(f"slot {s} is mid-prefill (resume its chunks first)")
        return slots

    def step(self, slots: Sequence[int], active: Sequence[int]):
        """ONE decode step — one jitted dispatch — for every slot in
        ``slots``. Returns (ramp_labels (K,B), ramp_unc (K,B), final (B,))
        with rows in sorted(active) order and columns in ``slots`` order."""
        slots = self._validate_slots(slots)
        act = self._validate_active(active)
        B = len(slots)
        if B == 0:  # nothing in flight: no dispatch (mirrors the loop runner)
            k = len(act)
            return (np.zeros((k, 0), np.int64), np.zeros((k, 0), np.float32),
                    np.zeros(0, np.int64))
        bucket = min(self._bucket_rows(B), self._rows)
        # pad with FREE rows (their state is garbage a future start()
        # overwrites wholesale), then with duplicates of stepped slots
        # (gather precedes every write, so duplicate indices scatter
        # identical values). NEVER a live-but-unstepped row: attention
        # writes would be idempotent previews, but an SSM mixer would
        # advance that slot's recurrent state off-schedule.
        free = [r for r in range(self._rows) if r not in self._live][: bucket - B]
        dup = [slots[i % B] for i in range(bucket - B - len(free))] if B else []
        rows = np.asarray(slots + free + dup, np.int64)  # repro: allow[host-sync] — host row-index build — no device operand
        toks = jnp.asarray(self._tok[rows].reshape(-1, 1), jnp.int32)
        pos = jnp.asarray(self._pos[rows], jnp.int32)
        k = len(act)
        if self.paged:
            # append a block only when a stepped slot's current block is
            # full (CoW-copying it first if it's shared); the claim totals
            # every stepped slot's needs and reserves them in ONE pass, so
            # a pool with no free block raises PoolExhausted here BEFORE
            # any allocator or device state changes
            self._claim_step_blocks(slots)
            # FREE pad rows keep stale table rows that may now reference
            # blocks owned by live slots — _ship_tables redirects them to
            # the reserved trash block 0 so their (discarded) scatters
            # land there
            tables_j = self._ship_tables(rows, B, B + len(free))
            if k:
                pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
                self._cache, (rl, ru, fl) = self._decode_fn_paged()(
                    self.params, self._cache, toks, pos, tables_j, pad_act
                )
        else:
            rows_j = jnp.asarray(rows, jnp.int32)
            if k:
                pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
                self._cache, (rl, ru, fl) = self._decode_fn()(
                    self.params, self._cache, toks, pos, rows_j, pad_act
                )
        if k:
            labels = np.asarray(rl).reshape(self.max_slots, -1)[:k, :B].astype(np.int64)  # repro: allow[host-sync] — sanctioned per-step record drain (the sync step_multi amortizes)
            unc = np.asarray(ru).reshape(self.max_slots, -1)[:k, :B].astype(np.float32)  # repro: allow[host-sync] — sanctioned per-step record drain (the sync step_multi amortizes)
        else:
            if self.paged:
                self._cache, fl = self._decode_fn_paged_noramp()(
                    self.params, self._cache, toks, pos, tables_j
                )
            else:
                self._cache, fl = self._decode_fn_noramp()(
                    self.params, self._cache, toks, pos, rows_j
                )
            labels = np.zeros((0, B), np.int64)
            unc = np.zeros((0, B), np.float32)
        self.dispatches += 1
        final = np.asarray(fl).reshape(-1)[:B].astype(np.int64)  # repro: allow[host-sync] — sanctioned per-step final-token drain (the sync step_multi amortizes)
        self._pos[rows[:B]] += 1
        self._tok[rows[:B]] = final  # vanilla greedy trajectory (agreement baseline)
        return labels, unc, final

    def _thr_device(self, thr: np.ndarray):
        """Device-resident per-site exit thresholds, padded to
        ``max_slots`` with 0.0 (strict ``<`` means the pad sites can never
        fire). Re-pushed ONLY when the controller's values actually
        changed — unchanged windows reuse the device copy with zero
        host→device traffic."""
        pad = np.zeros(self.max_slots, np.float32)
        pad[: len(thr)] = thr
        if self._thr_host is None or not np.array_equal(pad, self._thr_host):
            self._thr_host = pad
            self._thr_dev = jnp.asarray(pad)
        return self._thr_dev

    def step_multi(self, slots: Sequence[int], active: Sequence[int],
                   n_steps: int, thresholds: np.ndarray):
        """A SYNC WINDOW: up to ``n_steps`` decode steps in ONE jitted
        dispatch (a ``lax.while_loop`` on device), with per-row exit
        decisions made ON DEVICE against ``thresholds`` — the device copy
        of the controller's per-active-site thresholds, deliberately
        STALE between syncs (the controller only retunes at window
        boundaries).

        Returns ``(labels, unc, finals, exits)`` with a leading
        executed-step axis ``nd <= n_steps``: ``labels``/``unc`` are
        ``(nd, K, B)`` in sorted(active) x ``slots`` order, ``finals``/
        ``exits`` are ``(nd, B)``. ``exits[t, b]`` is the FIRST active
        site whose on-device mask fired for slot ``b`` at window step
        ``t`` (−1 = none), bit-identical to ``simulate_exits`` over the
        returned records. The window terminates early after the first
        step where every live row exits — the remaining steps would be
        tokens the serving layer has already cut.

        Staleness/accuracy contract: exit decisions inside the window use
        the thresholds as of dispatch time, but the packed records stream
        back at the sync boundary and the controller REPLAYS every one of
        them — adaptation sees every token, delayed by at most one
        window, never lossy. ``n_steps=1`` is bit-identical to ``step``
        (the equivalence oracle the tests pin)."""
        slots = self._validate_slots(slots)
        act = self._validate_active(active)
        k = len(act)
        if int(n_steps) < 1:
            raise ValueError(f"sync window needs n_steps >= 1, got {n_steps}")
        thr = np.asarray(thresholds, np.float32).reshape(-1)  # repro: allow[host-sync] — host threshold normalization — controller thresholds are host numpy
        if thr.shape[0] != k:
            raise ValueError(
                f"thresholds has {thr.shape[0]} entries for {k} active sites"
            )
        B = len(slots)
        if B == 0:  # nothing in flight: no dispatch (mirrors ``step``)
            return (np.zeros((0, k, 0), np.int64), np.zeros((0, k, 0), np.float32),
                    np.zeros((0, 0), np.int64), np.zeros((0, 0), np.int64))
        headroom = min(self._cache_len - int(self._pos[s]) for s in slots)
        n = min(int(n_steps), max(1, headroom))
        n_max = _bucket(n)
        bucket = min(self._bucket_rows(B), self._rows)
        free = [r for r in range(self._rows) if r not in self._live][: bucket - B]
        dup = [slots[i % B] for i in range(bucket - B - len(free))]
        rows = np.asarray(slots + free + dup, np.int64)  # repro: allow[host-sync] — host row-index build — no device operand
        toks = jnp.asarray(self._tok[rows].reshape(-1, 1), jnp.int32)
        pos = jnp.asarray(self._pos[rows], jnp.int32)
        # FREE pad rows hold garbage — mask them out of the all-exited
        # early-termination vote (dup rows mirror a stepped slot, so
        # their vote is redundant either way)
        valid = np.zeros(bucket, bool)
        valid[:B] = True
        valid_j = jnp.asarray(valid)
        if self.paged:
            # pre-claim the whole window as n sequential per-step claims:
            # identical claim/eviction order to n ``step`` calls, so
            # block-id assignment off the min-heap stays bit-identical.
            # On PoolExhausted the appended tail is unwound to the
            # pre-window watermark (CoW copies stay — they are private,
            # content-identical replacements), leaving the claim
            # retry-safe for the engine's preempt-and-retry loop.
            al = self._alloc
            base_owned = {s: int(al.owned[s]) for s in slots}
            try:
                for i in range(n):
                    self._claim_step_blocks(slots, offset=i)
            except PoolExhausted:
                for s in slots:
                    al.release_tail(s, base_owned[s])
                raise
            tables_j = self._ship_tables(rows, B, B + len(free))
            if k:
                pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
                self._cache, (rl, rm, fl, ex, ndv) = self._decode_multi_fn_paged(n_max)(
                    self.params, self._cache, toks, pos, tables_j, pad_act,
                    self._thr_device(thr), jnp.int32(n), valid_j
                )
            else:
                self._cache, (rl, rm, fl, ex, ndv) = self._decode_multi_fn_paged_noramp(
                    n_max
                )(self.params, self._cache, toks, pos, tables_j, jnp.int32(n), valid_j)
        else:
            rows_j = jnp.asarray(rows, jnp.int32)
            if k:
                pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
                self._cache, (rl, rm, fl, ex, ndv) = self._decode_multi_fn(n_max)(
                    self.params, self._cache, toks, pos, rows_j, pad_act,
                    self._thr_device(thr), jnp.int32(n), valid_j
                )
            else:
                self._cache, (rl, rm, fl, ex, ndv) = self._decode_multi_fn_noramp(
                    n_max
                )(self.params, self._cache, toks, pos, rows_j, jnp.int32(n), valid_j)
        self.dispatches += 1  # ONE dispatch per window, however many steps ran
        # the executed-step count is the ONE scalar the host must learn
        # before slicing the packed outputs — the single sync per window
        # is the whole point of the design
        nd = int(ndv)  # repro: allow[host-sync] — the one sanctioned sync per window
        # repro: allow[host-sync] — sync-boundary record drain (replay-completeness)
        labels = np.asarray(rl)[:nd, :k, :B].astype(np.int64)
        # host 1.0 − maxprob in f32 is the same IEEE op the per-step
        # program runs on device — unc stays bit-identical to ``step``
        # repro: allow[host-sync] — sync-boundary record drain (replay-completeness)
        unc = (np.float32(1.0) - np.asarray(rm)[:nd, :k, :B]).astype(np.float32)
        # repro: allow[host-sync] — sync-boundary record drain (replay-completeness)
        finals = np.asarray(fl)[:nd, :B].astype(np.int64)
        # repro: allow[host-sync] — sync-boundary exit-mask drain
        exits = np.asarray(ex)[:nd, :B].astype(np.int64)
        self._pos[rows[:B]] += nd
        self._tok[rows[:B]] = finals[nd - 1]
        if self.paged and nd < n:
            # early termination: return the blocks pre-claimed for steps
            # that never ran. They were never written (executed-step
            # writes all land within ``keep``), so releasing them cannot
            # leak state; ``peak_blocks`` keeps the transient high-water
            # mark by design.
            bs = self._bs_blk
            for s in slots:
                keep = max(base_owned[s], (int(self._pos[s]) - 1) // bs + 1)
                self._alloc.release_tail(s, keep)
        return labels, unc, finals, exits

    def free(self, slot: int) -> None:
        if self.paged and self._alloc is not None and slot in self._live:
            self._free_slot_blocks(slot)
        self._live.discard(slot)
        self._pf_progress.pop(slot, None)


class ShardedDecodeRunner(DecodeRunner):
    """``DecodeRunner`` over a ``(data, model)`` device mesh: every jitted
    program is the tensor-parallel ``model.decode_sharded`` /
    ``decode_sharded_multi`` path (attention heads, FFN hidden, and —
    where the plan has MoE slots — experts sharded over `model`), with
    the KV cache (contiguous rows or the paged block pool) sharded by kv
    head so per-device KV bytes are ``total / tp``.

    Everything host-side is INHERITED unchanged: the one global
    ``BlockAllocator`` (page ids are mesh-global — only page *bytes*
    shard), block tables, prefix sharing/CoW/swap, claim ordering, bucket
    padding, the sync-window pre-claim/unwind. The TP decomposition is
    bitwise exact (see ``TpCtx`` in models.transformer), so records,
    tokens, and allocator state are bit-identical to the single-device
    ``DecodeRunner`` over any schedule — the property the fuzz harness
    pins at tp=2 and tp=4.

    Prefill runs REPLICATED inside the same shard_map (params enter
    under ``P()``), then each device slices its own kv-head block out of
    the freshly computed cache before scattering into its local shard —
    one dispatch per admit, no separate resharding step.

    ``dp > 1`` (contiguous caches only — a data-sharded paged pool would
    diverge the replicated pool copies) additionally shards decode rows
    over `data`; ``_bucket_rows`` raises the pad floor so every bucket
    divides the data axis.
    """

    def __init__(self, model, params, prompts, *, mesh=None, tp: int = 2,
                 dp: int = 1, **kw):
        from repro.compat import mesh_axis_size
        from repro.models import layers as _LY

        if mesh is None:
            devs = jax.devices()
            if len(devs) < dp * tp:
                raise ValueError(
                    f"mesh ({dp}x{tp}) needs {dp * tp} devices, "
                    f"have {len(devs)}"
                )
            mesh = jax.sharding.Mesh(
                np.asarray(devs[: dp * tp]).reshape(dp, tp), ("data", "model")
            )
        self.mesh = mesh
        self.tp = mesh_axis_size(mesh, "model")
        self.dp = mesh_axis_size(mesh, "data")
        self._maxes = _LY.TEST_AXES
        paged = str(getattr(model.cfg, "decode_attn", "")).startswith("paged")
        # fail at construction, not at the first step: the support matrix
        # carries the same why-note for the rejected cell
        model.tp_check(self.tp, dp=self.dp, paged=paged)
        super().__init__(model, params, prompts, **kw)

    # -- mesh plumbing -------------------------------------------------------

    def _bucket_rows(self, B: int) -> int:
        return max(_bucket(B), self.dp)

    def _ensure_rows(self, n: int) -> None:
        # a data-sharded step needs >= dp rows to gather from
        super()._ensure_rows(max(n, self.dp))

    @staticmethod
    def _rep_specs(tree):
        from jax.sharding import PartitionSpec as P

        return jax.tree.map(lambda _: P(), tree)

    def kv_stats(self) -> dict:
        out = super().kv_stats()
        out["tp"] = self.tp
        out["dp"] = self.dp
        if self._cache is not None:
            per_dev = {}
            for l in jax.tree.leaves(self._cache):
                if not hasattr(l, "addressable_shards"):
                    continue
                for sh in l.addressable_shards:
                    per_dev[sh.device.id] = (
                        per_dev.get(sh.device.id, 0)
                        + sh.data.size * np.dtype(l.dtype).itemsize
                    )
            if per_dev:
                out["per_device_cache_bytes"] = float(max(per_dev.values()))
        return out

    # -- jitted programs (shard_map variants) --------------------------------

    def _prefill_fn(self):
        if self._pf is None:
            from jax.sharding import PartitionSpec as P

            from repro.compat import shard_map

            m, cache_len = self.model, self._cache_len
            mesh, axes, tpn = self.mesh, self._maxes, self.tp
            runner = self

            def body(params, big, toks, slot):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                mi = jax.lax.axis_index(axes.model)
                cache = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, mi * (x.shape[x.ndim - 2] // tpn),
                        x.shape[x.ndim - 2] // tpn, axis=x.ndim - 2,
                    ),
                    cache,
                )
                big = runner._tree_put(big, cache, slot[None])
                lab = outs["final"]["label"]
                return big, (lab[:, 0] if lab.ndim == 2 else lab)

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def pf(params, big, toks, slot):
                cspecs = m.tp_cache_specs(big, axes)
                return shard_map(
                    body, mesh=mesh,
                    in_specs=(self._rep_specs(params), cspecs, P(), P()),
                    out_specs=(cspecs, P()), check_vma=False,
                )(params, big, toks, slot)

            self._pf = pf
        return self._pf

    def _prefill_fn_paged(self, n_tokens: Optional[int] = None):
        n_tokens = self.prompts.shape[1] if n_tokens is None else n_tokens
        if n_tokens not in self._pf_paged:
            from jax.sharding import PartitionSpec as P

            from repro.compat import shard_map

            m, cache_len = self.model, self._cache_len
            mesh, axes, tpn = self.mesh, self._maxes, self.tp
            bs = self._bs_blk
            nb_pf = -(-n_tokens // bs)
            paxes = self._pool_axes

            def scatter(pool, cont, ax, blk_ids, nb):
                # identical to DecodeRunner's scatter, on the LOCAL kv-head
                # slice: every paged leaf the TP path admits is an attn k/v
                # with the kv-head axis at ndim-2 on both layouts
                x = jnp.moveaxis(cont, ax, 0)[0]
                t = jnp.moveaxis(x, ax, 0)
                need = nb * bs
                if t.shape[0] < need:
                    t = jnp.pad(t, [(0, need - t.shape[0])] + [(0, 0)] * (t.ndim - 1))
                t = t[:need].reshape((nb, bs) + t.shape[1:])
                p2 = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
                p2 = p2.at[blk_ids].set(t.astype(p2.dtype))
                return jnp.moveaxis(p2, (0, 1), (ax, ax + 1))

            def body(params, pools, toks, blk_ids, xkv_ids):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                mi = jax.lax.axis_index(axes.model)
                cache = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, mi * (x.shape[x.ndim - 2] // tpn),
                        x.shape[x.ndim - 2] // tpn, axis=x.ndim - 2,
                    ),
                    cache,
                )
                leaves, td = jax.tree.flatten(pools)
                cl = jax.tree.leaves(cache)
                out = [
                    scatter(p, c, ax, blk_ids, nb_pf)
                    for p, c, ax in zip(leaves, cl, paxes)
                ]
                pools = jax.tree.unflatten(td, out)
                lab = outs["final"]["label"]
                return pools, (lab[:, 0] if lab.ndim == 2 else lab)

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def pf(params, pools, toks, blk_ids, xkv_ids):
                cspecs = m.tp_cache_specs(pools, axes)
                return shard_map(
                    body, mesh=mesh,
                    in_specs=(self._rep_specs(params), cspecs, P(), P(), P()),
                    out_specs=(cspecs, P()), check_vma=False,
                )(params, pools, toks, blk_ids, xkv_ids)

            self._pf_paged[n_tokens] = pf
        return self._pf_paged[n_tokens]

    def _decode_fn(self):
        if self._dec is None:
            m, mesh, axes = self.model, self.mesh, self._maxes

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec(params, big, toks, pos, rows, active):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode_sharded(
                    params, sub, toks, pos, mesh=mesh, axes=axes,
                    active_sites=active, moe_impl="dense",
                )
                big = self._tree_put(big, sub, rows)
                return big, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_noramp(self):
        if self._dec0 is None:
            m, mesh, axes = self.model, self.mesh, self._maxes

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec0(params, big, toks, pos, rows):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode_sharded(
                    params, sub, toks, pos, mesh=mesh, axes=axes,
                    active_sites=None, moe_impl="dense",
                )
                big = self._tree_put(big, sub, rows)
                return big, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    def _decode_fn_paged(self):
        if self._dec is None:
            m, mesh, axes = self.model, self.mesh, self._maxes

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec(params, pools, toks, pos, tables, active):
                pools, outs = m.decode_sharded(
                    params, pools, toks, pos, mesh=mesh, axes=axes,
                    active_sites=active, moe_impl="dense", block_tables=tables,
                )
                return pools, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_paged_noramp(self):
        if self._dec0 is None:
            m, mesh, axes = self.model, self.mesh, self._maxes

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec0(params, pools, toks, pos, tables):
                pools, outs = m.decode_sharded(
                    params, pools, toks, pos, mesh=mesh, axes=axes,
                    active_sites=None, moe_impl="dense", block_tables=tables,
                )
                return pools, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    def _decode_multi_fn(self, n_max: int):
        if n_max not in self._decm:
            m, mesh, axes = self.model, self.mesh, self._maxes

            @partial(jax.jit, donate_argnums=self._donate_cache())  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def decm(params, big, toks, pos, rows, active, thr, n, valid):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode_sharded_multi(
                    params, sub, toks, pos, n, mesh=mesh, n_max=n_max,
                    axes=axes, active_sites=active, thresholds=thr,
                    row_valid=valid, moe_impl="dense",
                )
                big = self._tree_put(big, sub, rows)
                return big, outs

            self._decm[n_max] = decm
        return self._decm[n_max]

    def _decode_multi_fn_noramp(self, n_max: int):
        if n_max not in self._decm0:
            m, mesh, axes = self.model, self.mesh, self._maxes

            @partial(jax.jit, donate_argnums=self._donate_cache())  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def decm0(params, big, toks, pos, rows, n, valid):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode_sharded_multi(
                    params, sub, toks, pos, n, mesh=mesh, n_max=n_max,
                    axes=axes, active_sites=None, row_valid=valid,
                    moe_impl="dense",
                )
                big = self._tree_put(big, sub, rows)
                return big, outs

            self._decm0[n_max] = decm0
        return self._decm0[n_max]

    def _decode_multi_fn_paged(self, n_max: int):
        if n_max not in self._decm:
            m, mesh, axes = self.model, self.mesh, self._maxes

            @partial(jax.jit, donate_argnums=self._donate_cache())  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def decm(params, pools, toks, pos, tables, active, thr, n, valid):
                pools, outs = m.decode_sharded_multi(
                    params, pools, toks, pos, n, mesh=mesh, n_max=n_max,
                    axes=axes, active_sites=active, thresholds=thr,
                    row_valid=valid, moe_impl="dense", block_tables=tables,
                )
                return pools, outs

            self._decm[n_max] = decm
        return self._decm[n_max]

    def _decode_multi_fn_paged_noramp(self, n_max: int):
        if n_max not in self._decm0:
            m, mesh, axes = self.model, self.mesh, self._maxes

            @partial(jax.jit, donate_argnums=self._donate_cache())  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def decm0(params, pools, toks, pos, tables, n, valid):
                pools, outs = m.decode_sharded_multi(
                    params, pools, toks, pos, n, mesh=mesh, n_max=n_max,
                    axes=axes, active_sites=None, row_valid=valid,
                    moe_impl="dense", block_tables=tables,
                )
                return pools, outs

            self._decm0[n_max] = decm0
        return self._decm0[n_max]


class LoopDecodeRunner:
    """Per-slot-loop reference runner: the pre-batched implementation kept
    for the batched-vs-loop equivalence tests and the dispatch-count
    benchmark. Slots are independent B=1 caches; every engine step issues
    one jitted ``model.decode`` PER SLOT (B dispatches + B small cache
    trees per step — the serialized hot path ``DecodeRunner`` replaces)."""

    def __init__(self, model, params, prompts: np.ndarray, *, max_new_tokens: int = 64,
                 max_slots: int = 8):
        self.model = model
        self.params = params
        self.prompts = np.asarray(prompts, np.int32)  # (N, S)
        self.max_new = max_new_tokens
        self.max_slots = max_slots
        self.n_sites = len(model.sites)
        self.dispatches = 0  # jitted decode calls (B per step)
        self._slots = {}
        self._pf = None
        self._dec = None
        self._dec0 = None  # no-ramp (vanilla) decode variant

    def _prefill_fn(self):
        if self._pf is None:
            m, S = self.model, self.prompts.shape[1]
            cache_len = S + self.max_new

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def pf(params, toks):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                lab = outs["final"]["label"]
                return cache, (lab[:, 0] if lab.ndim == 2 else lab)

            self._pf = pf
        return self._pf

    def _decode_fn(self):
        if self._dec is None:
            m = self.model

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec(params, cache, tok, pos, active):
                new_cache, outs = m.decode(
                    params, cache, tok, pos, active_sites=active, moe_impl="dense"
                )
                return new_cache, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_noramp(self):
        if self._dec0 is None:
            m = self.model

            @jax.jit  # repro: allow[jit-cache-hygiene] — wrapper memoized by the enclosing runner
            def dec0(params, cache, tok, pos):
                new_cache, outs = m.decode(
                    params, cache, tok, pos, active_sites=None, moe_impl="dense"
                )
                return new_cache, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    def start(self, slot: int, item: int) -> int:
        toks = jnp.asarray(self.prompts[item][None, :])
        cache, lab = self._prefill_fn()(self.params, toks)
        tok = int(np.asarray(lab).reshape(-1)[0])  # repro: allow[host-sync] — sanctioned first-token read (per-slot loop oracle)
        self._slots[slot] = {"cache": cache, "pos": self.prompts.shape[1], "tok": tok}
        return tok

    def step(self, slots: Sequence[int], active: Sequence[int]):
        """One decode step for every slot in ``slots`` — one jitted B=1
        dispatch per slot. Row/column order matches ``DecodeRunner.step``."""
        act = sorted(active)
        if len(act) > self.max_slots:
            # refuse, never silently truncate (matches DecodeRunner.step)
            raise ValueError(
                f"active ramp set has {len(act)} sites, max_slots={self.max_slots}"
            )
        k = len(act)
        labels = np.zeros((max(k, 1), len(slots)), np.int64)
        unc = np.full((max(k, 1), len(slots)), 1.0, np.float32)
        final = np.zeros(len(slots), np.int64)
        if k:
            pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
            dec = self._decode_fn()
        else:
            dec0 = self._decode_fn_noramp()
        for b, s in enumerate(slots):
            st = self._slots[s]
            tok = jnp.asarray([[st["tok"]]], jnp.int32)
            if k:
                st["cache"], (rl, ru, fl) = dec(
                    self.params, st["cache"], tok, jnp.int32(st["pos"]), pad_act
                )
                labels[:, b] = np.asarray(rl).reshape(self.max_slots, -1)[:k, 0]  # repro: allow[host-sync] — sanctioned record drain (per-slot loop oracle)
                unc[:, b] = np.asarray(ru).reshape(self.max_slots, -1)[:k, 0]  # repro: allow[host-sync] — sanctioned record drain (per-slot loop oracle)
            else:
                st["cache"], fl = dec0(self.params, st["cache"], tok, jnp.int32(st["pos"]))
            self.dispatches += 1
            fl = int(np.asarray(fl).reshape(-1)[0])  # repro: allow[host-sync] — sanctioned token read (per-slot loop oracle)
            final[b] = fl
            st["pos"] += 1
            st["tok"] = fl  # vanilla greedy trajectory (agreement baseline)
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def free(self, slot: int) -> None:
        self._slots.pop(slot, None)


class SyntheticDecodeRunner:
    """Profile-only generative runner — the decode analogue of
    ``SyntheticRunner``: deterministic per-token ramp records without a
    model. A fixed fraction of tokens is "easy" (confidently predictable
    from ``exit_site`` onward, ramp label agreeing with the final token);
    the rest stay uncertain and disagreeing at every ramp, so an
    over-opened threshold costs accuracy exactly as with a trained LM.
    Used by the generative benchmarks/sweeps where training an LM per
    configuration would dominate runtime."""

    def __init__(self, n_sites: int, exit_site: int, easy_frac: float = 0.7,
                 vocab: int = 101):
        self.n_sites = n_sites
        self.exit_site = exit_site
        self.easy_frac = easy_frac
        self.vocab = vocab
        self._slots = {}

    def _token(self, item: int, t: int) -> int:
        return (item * 31 + t * 7 + 3) % self.vocab

    def _easy(self, item: int, t: int) -> bool:
        return ((item * 131 + t * 17) % 100) < self.easy_frac * 100

    def start(self, slot: int, item: int) -> int:
        self._slots[slot] = {"item": item, "t": 0}
        return self._token(item, 0)

    def step(self, slots: Sequence[int], active: Sequence[int]):
        act = sorted(active)
        k = len(act)
        B = len(slots)
        labels = np.zeros((max(k, 1), B), np.int64)
        unc = np.full((max(k, 1), B), 0.9, np.float32)
        final = np.zeros(B, np.int64)
        for b, s in enumerate(slots):
            st = self._slots[s]
            st["t"] += 1
            item, t = st["item"], st["t"]
            fin = self._token(item, t)
            final[b] = fin
            easy = self._easy(item, t)
            for j, site in enumerate(act):
                if easy and site >= self.exit_site:
                    labels[j, b] = fin
                    unc[j, b] = 0.02
                else:
                    labels[j, b] = (fin + 1) % self.vocab
                    unc[j, b] = 0.9
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def free(self, slot: int) -> None:
        self._slots.pop(slot, None)
