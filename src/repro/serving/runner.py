"""Model runners: execute the real (tiny, CPU-trained) models per batch and
stream ramp records to the controller.

On hardware this is the accelerator side: a single jitted program computes
the full model + K gathered ramp heads; only ~KB stat arrays (top-1 label,
max-prob, entropy per ramp) travel to the host — never logits. Batches are
padded to power-of-two buckets to bound compilation count.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class SyntheticRunner:
    """Profile-only serving: deterministic ramp records without a model.

    A fixed fraction of items is "easy" — confidently predictable from
    ``exit_site`` onward — so controllers activate ramps and exit traffic
    exactly as with a trained model, at zero model cost. Used by the
    scale-out demos/benchmarks where training one model per replica-count
    sweep would dominate runtime.
    """

    def __init__(self, n_sites: int, exit_site: int, easy_frac: float = 0.7,
                 n_classes: int = 17):
        self.n_sites = n_sites
        self.exit_site = exit_site
        self.easy_frac = easy_frac
        self.n_classes = n_classes

    def infer(self, items: np.ndarray, active: Sequence[int]):
        items = np.asarray(items)
        k = len(active)
        B = len(items)
        final = (items % self.n_classes).astype(np.int64)
        easy = (items % 100) < self.easy_frac * 100
        labels = np.tile(final, (max(k, 1), 1))
        unc = np.full((max(k, 1), B), 0.9, np.float32)
        for j, s in enumerate(sorted(active)):
            if s >= self.exit_site:
                unc[j] = np.where(easy, 0.02, 0.9)
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def vanilla_labels(self, n: int) -> np.ndarray:
        return np.arange(n, dtype=np.int64) % self.n_classes


class ClassifierRunner:
    """ResNet / BERT-style classifier serving (the paper's workloads)."""

    def __init__(self, model, params, data: np.ndarray, max_slots: int = 8):
        self.model = model
        self.params = params
        self.data = data  # (N, ...) images or token sequences
        self.max_slots = max_slots
        self._fns = {}
        self.compiles = 0  # ramp-set changes recompile (paper: model re-upload)

    def _fn(self, bs: int, act: tuple):
        key = (bs, act)
        if key not in self._fns:
            m = self.model
            self.compiles += 1

            @jax.jit
            def f(params, x):
                outs = m.forward(params, x, active_sites=list(act))
                return (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._fns[key] = f
        return self._fns[key]

    def infer(self, items: np.ndarray, active: Sequence[int]):
        bs = _bucket(len(items))
        idx = np.pad(items, (0, bs - len(items)), mode="edge")
        x = jnp.asarray(self.data[idx])
        act = tuple(sorted(active))[: self.max_slots]
        k = len(act)
        labels, unc, final = self._fn(bs, act if act else (0,))(self.params, x)
        labels = np.asarray(labels)[:, : len(items)]
        unc = np.asarray(unc)[:, : len(items)]
        final = np.asarray(final)[: len(items)]
        if k == 0:
            return np.zeros((0, len(items)), np.int64), np.zeros((0, len(items)), np.float32), final
        return labels[:k], unc[:k].astype(np.float32), final

    def vanilla_labels(self, n: Optional[int] = None) -> np.ndarray:
        """Original-model labels for the whole stream (accuracy ground truth)."""
        n = n or len(self.data)
        out = []
        for lo in range(0, n, 256):
            hi = min(lo + 256, n)
            idx = np.arange(lo, hi)
            _, _, f = self.infer(idx, [0])
            out.append(f)
        return np.concatenate(out)


class LMTokenRunner:
    """Per-token early-exit serving for decoder LMs: each request is a
    context; the served result is the next token (prefill path)."""

    def __init__(self, model, params, data: np.ndarray, max_slots: int = 8):
        self.model = model
        self.params = params
        self.data = data  # (N, S) int32 contexts
        self.max_slots = max_slots
        self._fns = {}

    def _fn(self, bs: int):
        if bs not in self._fns:
            m = self.model

            @jax.jit
            def f(params, toks, active):
                _, outs = m.prefill(
                    params, toks, active_sites=active, with_cache=False, moe_impl="dense"
                )
                return (
                    outs["ramps"]["label"][:, :, 0] if outs["ramps"]["label"].ndim == 3 else outs["ramps"]["label"],
                    1.0 - (outs["ramps"]["maxprob"][:, :, 0] if outs["ramps"]["maxprob"].ndim == 3 else outs["ramps"]["maxprob"]),
                    outs["final"]["label"][:, 0] if outs["final"]["label"].ndim == 2 else outs["final"]["label"],
                )

            self._fns[bs] = f
        return self._fns[bs]

    def infer(self, items: np.ndarray, active: Sequence[int]):
        bs = _bucket(len(items))
        idx = np.pad(items, (0, bs - len(items)), mode="edge")
        toks = jnp.asarray(self.data[idx])
        act = list(active)[: self.max_slots]
        if not act:
            act = [0]
        pad_act = act + [act[-1]] * (self.max_slots - len(act))
        labels, unc, final = self._fn(bs)(
            self.params, toks, jnp.asarray(pad_act, jnp.int32)
        )
        k = len(list(active)) if active else 0
        final = np.asarray(final)[: len(items)]
        if k == 0:
            return np.zeros((0, len(items)), np.int64), np.zeros((0, len(items)), np.float32), final
        return (
            np.asarray(labels)[:k, : len(items)],
            np.asarray(unc)[:k, : len(items)].astype(np.float32),
            final,
        )

    def vanilla_labels(self, n: Optional[int] = None) -> np.ndarray:
        n = n or len(self.data)
        out = []
        for lo in range(0, n, 128):
            idx = np.arange(lo, min(lo + 128, n))
            _, _, f = self.infer(idx, [0])
            out.append(f)
        return np.concatenate(out)
