"""Model runners: execute the real (tiny, CPU-trained) models per batch and
stream ramp records to the controller.

On hardware this is the accelerator side: a single jitted program computes
the full model + K gathered ramp heads; only ~KB stat arrays (top-1 label,
max-prob, entropy per ramp) travel to the host — never logits. Batches are
padded to power-of-two buckets to bound compilation count.
"""
from __future__ import annotations

import heapq
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class PoolExhausted(RuntimeError):
    """Raised when the paged KV pool has no free block for an allocation.
    The allocator checks capacity BEFORE mutating any state, so a failed
    allocation never corrupts the block table."""


class BlockAllocator:
    """Host-side allocator for the paged KV-cache pool.

    The device pool holds ``n_blocks + 1`` physical blocks: block 0 is
    RESERVED as the trash block — bucket-padding rows point their zeroed
    table rows at it, so their (discarded) scatters land in memory no live
    slot ever reads. Allocatable ids are ``1..n_blocks``; the free heap
    always hands out the lowest id, so identical schedules produce
    identical tables (determinism the equivalence harness relies on).

    Invariants (asserted by the property tests):
      * a block is owned by at most one slot at a time;
      * ``n_free + sum(owned) == n_blocks`` across any schedule;
      * allocation at exhaustion raises ``PoolExhausted`` atomically —
        no table/free-list mutation happens on the failing call.
    """

    def __init__(self, n_blocks: int, max_blocks_per_slot: int, n_slots: int = 0):
        if n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
        self.n_blocks = n_blocks
        self.max_blocks = max_blocks_per_slot
        self._free = list(range(1, n_blocks + 1))  # min-heap of free ids
        heapq.heapify(self._free)
        self.table = np.zeros((n_slots, max_blocks_per_slot), np.int32)
        self.owned = np.zeros(n_slots, np.int32)
        self.peak_blocks = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def grow_slots(self, n_slots: int) -> None:
        add = n_slots - self.table.shape[0]
        if add > 0:
            self.table = np.concatenate(
                [self.table, np.zeros((add, self.max_blocks), np.int32)]
            )
            self.owned = np.concatenate([self.owned, np.zeros(add, np.int32)])

    def grow_pool(self, n_blocks: int) -> None:
        """Extend the pool with fresh block ids (existing ownership kept)."""
        for b in range(self.n_blocks + 1, n_blocks + 1):
            heapq.heappush(self._free, b)
        self.n_blocks = max(self.n_blocks, n_blocks)

    def alloc(self, slot: int, n: int = 1) -> List[int]:
        """Claim ``n`` blocks for ``slot`` (atomic: all or nothing)."""
        if self.owned[slot] + n > self.max_blocks:
            raise ValueError(
                f"slot {slot} would exceed max_blocks={self.max_blocks}"
            )
        if len(self._free) < n:
            raise PoolExhausted(
                f"paged KV pool exhausted: need {n} block(s), "
                f"{len(self._free)}/{self.n_blocks} free"
            )
        ids = [heapq.heappop(self._free) for _ in range(n)]
        k = int(self.owned[slot])
        self.table[slot, k : k + n] = ids
        self.owned[slot] += n
        self.peak_blocks = max(self.peak_blocks, self.live_blocks)
        return ids

    def free_slot(self, slot: int) -> None:
        """Return every block owned by ``slot`` to the pool."""
        k = int(self.owned[slot])
        for b in self.table[slot, :k]:
            heapq.heappush(self._free, int(b))
        self.table[slot, :] = 0  # stale entries must stay valid pool ids
        self.owned[slot] = 0

    def owned_ids(self, slot: int) -> List[int]:
        return [int(b) for b in self.table[slot, : int(self.owned[slot])]]


class SyntheticRunner:
    """Profile-only serving: deterministic ramp records without a model.

    A fixed fraction of items is "easy" — confidently predictable from
    ``exit_site`` onward — so controllers activate ramps and exit traffic
    exactly as with a trained model, at zero model cost. Used by the
    scale-out demos/benchmarks where training one model per replica-count
    sweep would dominate runtime.
    """

    def __init__(self, n_sites: int, exit_site: int, easy_frac: float = 0.7,
                 n_classes: int = 17):
        self.n_sites = n_sites
        self.exit_site = exit_site
        self.easy_frac = easy_frac
        self.n_classes = n_classes

    def infer(self, items: np.ndarray, active: Sequence[int]):
        items = np.asarray(items)
        k = len(active)
        B = len(items)
        final = (items % self.n_classes).astype(np.int64)
        easy = (items % 100) < self.easy_frac * 100
        # hard items DISAGREE with the original model at every ramp (like
        # SyntheticDecodeRunner): an over-opened threshold that releases
        # them costs accuracy, exactly as with a trained model. Tiling the
        # final label into every row made hard exits free.
        wrong = (final + 1) % self.n_classes
        labels = np.tile(wrong, (max(k, 1), 1))
        unc = np.full((max(k, 1), B), 0.9, np.float32)
        for j, s in enumerate(sorted(active)):
            if s >= self.exit_site:
                labels[j] = np.where(easy, final, wrong)
                unc[j] = np.where(easy, 0.02, 0.9)
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def vanilla_labels(self, n: int) -> np.ndarray:
        return np.arange(n, dtype=np.int64) % self.n_classes


class ClassifierRunner:
    """ResNet / BERT-style classifier serving (the paper's workloads)."""

    def __init__(self, model, params, data: np.ndarray, max_slots: int = 8):
        self.model = model
        self.params = params
        self.data = data  # (N, ...) images or token sequences
        self.max_slots = max_slots
        self._fns = {}
        self.compiles = 0  # ramp-set changes recompile (paper: model re-upload)
        self.noramp_compiles = 0  # no-ramp (vanilla) variant compiles

    def _fn(self, bs: int, act: Optional[tuple]):
        """act=None compiles the no-ramp (vanilla) variant: with zero active
        ramps the model must not execute-and-discard a ramp head — vanilla
        serving would silently pay one ramp of compute per batch."""
        key = (bs, act)
        if key not in self._fns:
            m = self.model
            if act is None:
                # no-ramp (vanilla) compiles are NOT ramp-set changes: they
                # must not inflate `compiles`, the "ramp-set change
                # recompile" stat the paper's overhead story rests on
                self.noramp_compiles += 1

                @jax.jit
                def f0(params, x):
                    return m.forward(params, x, active_sites=None)["final"]["label"]

                self._fns[key] = f0
            else:
                self.compiles += 1

                @jax.jit
                def f(params, x):
                    outs = m.forward(params, x, active_sites=list(act))
                    return (
                        outs["ramps"]["label"],
                        1.0 - outs["ramps"]["maxprob"],
                        outs["final"]["label"],
                    )

                self._fns[key] = f
        return self._fns[key]

    def infer(self, items: np.ndarray, active: Sequence[int]):
        bs = _bucket(len(items))
        idx = np.pad(items, (0, bs - len(items)), mode="edge")
        x = jnp.asarray(self.data[idx])
        act = tuple(sorted(active))[: self.max_slots]
        k = len(act)
        if k == 0:
            final = np.asarray(self._fn(bs, None)(self.params, x))[: len(items)]
            return np.zeros((0, len(items)), np.int64), np.zeros((0, len(items)), np.float32), final
        labels, unc, final = self._fn(bs, act)(self.params, x)
        labels = np.asarray(labels)[:, : len(items)]
        unc = np.asarray(unc)[:, : len(items)]
        final = np.asarray(final)[: len(items)]
        return labels[:k], unc[:k].astype(np.float32), final

    def vanilla_labels(self, n: Optional[int] = None) -> np.ndarray:
        """Original-model labels for the whole stream (accuracy ground truth)."""
        n = n or len(self.data)
        out = []
        for lo in range(0, n, 256):
            hi = min(lo + 256, n)
            idx = np.arange(lo, hi)
            _, _, f = self.infer(idx, [])  # no-ramp variant: zero ramp compute
            out.append(f)
        return np.concatenate(out)


class LMTokenRunner:
    """Per-token early-exit serving for decoder LMs: each request is a
    context; the served result is the next token (prefill path)."""

    def __init__(self, model, params, data: np.ndarray, max_slots: int = 8):
        self.model = model
        self.params = params
        self.data = data  # (N, S) int32 contexts
        self.max_slots = max_slots
        self._fns = {}
        self._fns0 = {}  # no-ramp (vanilla) variants

    def _fn_noramp(self, bs: int):
        if bs not in self._fns0:
            m = self.model

            @jax.jit
            def f0(params, toks):
                _, outs = m.prefill(
                    params, toks, active_sites=None, with_cache=False, moe_impl="dense"
                )
                lab = outs["final"]["label"]
                return lab[:, 0] if lab.ndim == 2 else lab

            self._fns0[bs] = f0
        return self._fns0[bs]

    def _fn(self, bs: int):
        if bs not in self._fns:
            m = self.model

            @jax.jit
            def f(params, toks, active):
                _, outs = m.prefill(
                    params, toks, active_sites=active, with_cache=False, moe_impl="dense"
                )
                return (
                    outs["ramps"]["label"][:, :, 0] if outs["ramps"]["label"].ndim == 3 else outs["ramps"]["label"],
                    1.0 - (outs["ramps"]["maxprob"][:, :, 0] if outs["ramps"]["maxprob"].ndim == 3 else outs["ramps"]["maxprob"]),
                    outs["final"]["label"][:, 0] if outs["final"]["label"].ndim == 2 else outs["final"]["label"],
                )

            self._fns[bs] = f
        return self._fns[bs]

    def infer(self, items: np.ndarray, active: Sequence[int]):
        bs = _bucket(len(items))
        idx = np.pad(items, (0, bs - len(items)), mode="edge")
        toks = jnp.asarray(self.data[idx])
        # sort (like ClassifierRunner): the controller consumes record rows
        # in ascending-site order, so an unsorted caller set must not leak
        # row misalignment into the window
        act = sorted(active)[: self.max_slots]
        k = len(act)
        if k == 0:
            final = np.asarray(self._fn_noramp(bs)(self.params, toks))[: len(items)]
            return np.zeros((0, len(items)), np.int64), np.zeros((0, len(items)), np.float32), final
        pad_act = act + [act[-1]] * (self.max_slots - len(act))
        labels, unc, final = self._fn(bs)(
            self.params, toks, jnp.asarray(pad_act, jnp.int32)
        )
        final = np.asarray(final)[: len(items)]
        return (
            np.asarray(labels)[:k, : len(items)],
            np.asarray(unc)[:k, : len(items)].astype(np.float32),
            final,
        )

    def vanilla_labels(self, n: Optional[int] = None) -> np.ndarray:
        n = n or len(self.data)
        out = []
        for lo in range(0, n, 128):
            idx = np.arange(lo, min(lo + 128, n))
            _, _, f = self.infer(idx, [])  # no-ramp variant: zero ramp compute
            out.append(f)
        return np.concatenate(out)


class DecodeRunner:
    """Real-model generative runner: drives ``model.decode`` with ONE
    jitted dispatch per engine step over a single batched slot cache,
    streaming one ramp record per in-flight token to the controller (the
    paper's generative per-token exits).

    Records are replay-complete — the full model and the gathered ramp
    heads run for every token, because the controller needs agreement
    labels to adapt — while serving *time* is simulated by the engine from
    the latency profile (truncated compute + deferred KV catch-up). The
    decoded trajectory follows the original model's greedy tokens so
    per-token agreement against the vanilla stream stays measurable even
    when a ramp disagrees.

    The cache is one batched tree keyed by slot index: ``start`` prefills
    into a slot row, ``step(slots, active)`` gathers the live rows, runs a
    single jitted decode with per-row positions (``model.decode`` takes
    ``pos: int32[B]``), and scatters the rows back; ``free`` just releases
    the row. Continuous batching admits/retires at step boundaries, so row
    positions diverge — per-row cache write indices are what make the
    shared cache sound. Live rows are padded to a power-of-two bucket with
    FREE rows (distinct indices, so the scatter is collision-free and the
    padded rows hold garbage no one reads), bounding compile count at
    log2(n_slots) shapes. Batch-level timing comes from the profile, not
    from here.

    With a ``decode_attn='paged*'`` model config the slot cache is PAGED:
    one global pool of ``kv_blocks`` fixed-size blocks (``kv_block_size``
    key/value tokens each) plus a per-slot block table, managed by a
    host-side ``BlockAllocator``. ``start`` claims ``ceil(prompt_len /
    block_size)`` blocks and scatters the prefill KV into them, ``step``
    appends a block only when a slot's current block fills, and ``free``
    returns the slot's blocks to the pool — KV memory scales with LIVE
    TOKENS instead of ``n_slots * max_len``, at the same one dispatch per
    engine step. ``kv_blocks=None`` auto-sizes the pool to full slot
    capacity (the contiguous equivalent); a smaller explicit pool admits
    more slots than contiguous memory would allow, and exhausting it
    raises ``PoolExhausted`` cleanly.
    """

    def __init__(self, model, params, prompts: np.ndarray, *, max_new_tokens: int = 64,
                 max_slots: int = 8, n_slots: Optional[int] = None,
                 kv_block_size: int = 16, kv_blocks: Optional[int] = None):
        self.model = model
        self.params = params
        self.prompts = np.asarray(prompts, np.int32)  # (N, S)
        self.max_new = max_new_tokens
        self.max_slots = max_slots  # K ramp gather slots (not decode rows)
        self.n_sites = len(model.sites)
        self.dispatches = 0  # jitted decode-step calls (1/step, not 1/slot)
        self._cache = None  # batched slot cache; rows grown on demand
        self._rows = 0 if n_slots is None else _bucket(max(n_slots, 1))
        self._cache_len = self.prompts.shape[1] + self.max_new
        self._live = set()
        self._pos = np.zeros(0, np.int64)
        self._tok = np.zeros(0, np.int64)
        self._axes: Optional[Tuple[int, ...]] = None  # per-leaf batch axis
        self._pf = None
        self._pf_paged = {}  # paged prefill programs, keyed by token count
        self._pf_progress = {}  # slot -> item for in-flight chunked prefills
        self._dec = None
        self._dec0 = None  # no-ramp (vanilla) decode variant
        # -- paged-KV state (decode_attn='paged'|'paged-kernel'|'paged-interpret')
        self.paged = str(getattr(model.cfg, "decode_attn", "")).startswith("paged")
        self._bs_blk = int(kv_block_size)
        self._kv_blocks = kv_blocks
        if self.paged and self._bs_blk < 1:
            raise ValueError(f"paged decode needs kv_block_size >= 1, got {kv_block_size}")
        # kv_block_size is meaningless for contiguous runners (0 documents
        # "contiguous" at the CLI) — don't let it poison the ceil below
        self._max_blocks = -(-self._cache_len // self._bs_blk) if self.paged else 0
        self._alloc: Optional[BlockAllocator] = None
        self._pool_axes: Optional[Tuple[int, ...]] = None  # per-leaf pool axis

    # -- batched-cache plumbing ---------------------------------------------

    @staticmethod
    def _diff_axes(a, b) -> Tuple[int, ...]:
        """Per-leaf axis where two schema variants disagree — the batch
        (contiguous) or pool (paged) dim: scanned blocks carry a leading
        period dim, prefix/suffix leaves don't."""
        return tuple(
            next(i for i, (x, y) in enumerate(zip(la.shape, lb.shape)) if x != y)
            for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b))
        )

    def _grow_rows(self, rows: int) -> None:
        self._rows = rows
        self._pos = np.concatenate([self._pos, np.zeros(rows - len(self._pos), np.int64)])
        self._tok = np.concatenate([self._tok, np.zeros(rows - len(self._tok), np.int64)])

    def _ensure_rows(self, n: int) -> None:
        """Allocate (or grow) the batched cache to >= n power-of-two rows.
        Growth copies live rows once; steady state never reallocates."""
        if self._cache is not None and n <= self._rows:
            return
        if self.paged:
            self._ensure_rows_paged(n)
            return
        rows = _bucket(max(n, self._rows, 1))
        new = self.model.init_cache(rows, self._cache_len)
        if self._axes is None:
            self._axes = self._diff_axes(
                self.model.cache_schema(1, 2), self.model.cache_schema(2, 2)
            )
        if self._cache is not None:
            old, td = jax.tree.flatten(self._cache)
            new_l = jax.tree.leaves(new)
            new = jax.tree.unflatten(td, [
                jax.lax.dynamic_update_slice_in_dim(nl, ol, 0, axis=ax)
                for nl, ol, ax in zip(new_l, old, self._axes)
            ])
        self._cache = new
        self._grow_rows(rows)

    def _tree_take(self, cache, rows):
        leaves, td = jax.tree.flatten(cache)
        return jax.tree.unflatten(td, [
            jnp.take(l, rows, axis=ax) for l, ax in zip(leaves, self._axes)
        ])

    def _tree_put(self, cache, sub, rows):
        leaves, td = jax.tree.flatten(cache)
        subl = jax.tree.leaves(sub)
        out = []
        for l, s, ax in zip(leaves, subl, self._axes):
            upd = jnp.moveaxis(l, ax, 0).at[rows].set(jnp.moveaxis(s, ax, 0))
            out.append(jnp.moveaxis(upd, 0, ax))
        return jax.tree.unflatten(td, out)

    # -- paged-pool plumbing -------------------------------------------------

    def _ensure_rows_paged(self, n: int) -> None:
        """Grow table rows (and, when ``kv_blocks`` is auto, the block pool)
        to cover >= n power-of-two slots. The pool array holds
        ``n_blocks + 1`` physical blocks — block 0 is the allocator's
        reserved trash block."""
        rows = _bucket(max(n, self._rows, 1))
        nblk = self._kv_blocks if self._kv_blocks is not None else rows * self._max_blocks
        if self._alloc is None:
            if self._pool_axes is None:
                self._pool_axes = self._diff_axes(
                    self.model.paged_cache_schema(1, self._bs_blk),
                    self.model.paged_cache_schema(2, self._bs_blk),
                )
            self._alloc = BlockAllocator(nblk, self._max_blocks, rows)
            self._cache = self.model.init_paged_cache(nblk + 1, self._bs_blk)
        else:
            self._alloc.grow_slots(rows)
            if nblk > self._alloc.n_blocks:
                new = self.model.init_paged_cache(nblk + 1, self._bs_blk)
                old, td = jax.tree.flatten(self._cache)
                new_l = jax.tree.leaves(new)
                self._cache = jax.tree.unflatten(td, [
                    jax.lax.dynamic_update_slice_in_dim(nl, ol, 0, axis=ax)
                    for nl, ol, ax in zip(new_l, old, self._pool_axes)
                ])
                self._alloc.grow_pool(nblk)
        self._grow_rows(rows)

    def cache_bytes(self) -> int:
        """Device bytes held by the KV cache (pool or contiguous rows)."""
        if self._cache is None:
            return 0
        return int(sum(
            l.size * np.dtype(l.dtype).itemsize for l in jax.tree.leaves(self._cache)
        ))

    def kv_stats(self) -> dict:
        out = {"paged": self.paged, "cache_bytes": float(self.cache_bytes())}
        if self.paged and self._alloc is not None:
            out.update(
                block_size=self._bs_blk,
                n_blocks=self._alloc.n_blocks,
                live_blocks=self._alloc.live_blocks,
                peak_blocks=self._alloc.peak_blocks,
                peak_token_capacity=self._alloc.peak_blocks * self._bs_blk,
            )
        return out

    # -- jitted programs ----------------------------------------------------

    def _prefill_fn(self):
        """Prefill one prompt AND scatter its cache into the slot row —
        one dispatch per admit (`slot` is a traced scalar: no recompile
        per slot id)."""
        if self._pf is None:
            m, cache_len = self.model, self._cache_len

            @jax.jit
            def pf(params, big, toks, slot):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                big = self._tree_put(big, cache, slot[None])
                lab = outs["final"]["label"]
                return big, (lab[:, 0] if lab.ndim == 2 else lab)

            self._pf = pf
        return self._pf

    def _decode_fn(self):
        if self._dec is None:
            m = self.model

            @jax.jit
            def dec(params, big, toks, pos, rows, active):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode(
                    params, sub, toks, pos, active_sites=active, moe_impl="dense"
                )
                big = self._tree_put(big, sub, rows)
                return big, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_noramp(self):
        """Ramp-free decode: with zero active ramps (controller bootstrap /
        budget-busted states) the step must not execute-and-discard ramp
        heads — same fix as the classifier/token runners' no-ramp variants."""
        if self._dec0 is None:
            m = self.model

            @jax.jit
            def dec0(params, big, toks, pos, rows):
                sub = self._tree_take(big, rows)
                sub, outs = m.decode(
                    params, sub, toks, pos, active_sites=None, moe_impl="dense"
                )
                big = self._tree_put(big, sub, rows)
                return big, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    def _prefill_fn_paged(self, n_tokens: Optional[int] = None):
        """Prefill one prompt (or its first ``n_tokens`` — a chunked-prefill
        first chunk) contiguously AND scatter its KV into the slot's claimed
        pool blocks — one dispatch per admit (``blk_ids`` is a traced
        array: no recompile per block assignment). Compiled per distinct
        token count (full prompts and one chunk size in practice)."""
        n_tokens = self.prompts.shape[1] if n_tokens is None else n_tokens
        if n_tokens not in self._pf_paged:
            m, cache_len = self.model, self._cache_len
            bs = self._bs_blk
            nb_pf = -(-n_tokens // bs)
            axes = self._pool_axes

            def scatter(pool, cont, ax, blk_ids):
                # cont: contiguous leaf, batch dim (size 1) at ax, tokens at
                # ax+1; pool: (..., P, bs, ...) with P at ax. Regroup the
                # first nb_pf*bs prefill tokens into blocks and write them
                # to the claimed pool slots.
                x = jnp.moveaxis(cont, ax, 0)[0]
                t = jnp.moveaxis(x, ax, 0)  # tokens first, rest order kept
                need = nb_pf * bs
                if t.shape[0] < need:
                    t = jnp.pad(t, [(0, need - t.shape[0])] + [(0, 0)] * (t.ndim - 1))
                t = t[:need].reshape((nb_pf, bs) + t.shape[1:])
                p2 = jnp.moveaxis(pool, (ax, ax + 1), (0, 1))
                p2 = p2.at[blk_ids].set(t.astype(p2.dtype))
                return jnp.moveaxis(p2, (0, 1), (ax, ax + 1))

            @jax.jit
            def pf(params, pools, toks, blk_ids):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                leaves, td = jax.tree.flatten(pools)
                cl = jax.tree.leaves(cache)
                pools = jax.tree.unflatten(td, [
                    scatter(p, c, ax, blk_ids)
                    for p, c, ax in zip(leaves, cl, axes)
                ])
                lab = outs["final"]["label"]
                return pools, (lab[:, 0] if lab.ndim == 2 else lab)

            self._pf_paged[n_tokens] = pf
        return self._pf_paged[n_tokens]

    def _decode_fn_paged(self):
        if self._dec is None:
            m = self.model

            @jax.jit
            def dec(params, pools, toks, pos, tables, active):
                pools, outs = m.decode(
                    params, pools, toks, pos, active_sites=active,
                    moe_impl="dense", block_tables=tables,
                )
                return pools, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_paged_noramp(self):
        if self._dec0 is None:
            m = self.model

            @jax.jit
            def dec0(params, pools, toks, pos, tables):
                pools, outs = m.decode(
                    params, pools, toks, pos, active_sites=None,
                    moe_impl="dense", block_tables=tables,
                )
                return pools, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    # -- engine interface ----------------------------------------------------

    def start(self, slot: int, item: int) -> int:
        """Prefill ``item``'s prompt into ``slot``'s cache row (contiguous)
        or its freshly claimed pool blocks (paged); returns the first
        generated (greedy) token."""
        self._ensure_rows(slot + 1)
        toks = jnp.asarray(self.prompts[item][None, :])
        if self.paged:
            if slot in self._live:  # engine frees before reuse; be defensive
                self._alloc.free_slot(slot)
            nb_pf = -(-self.prompts.shape[1] // self._bs_blk)
            blks = self._alloc.alloc(slot, nb_pf)
            self._cache, lab = self._prefill_fn_paged()(
                self.params, self._cache, toks, jnp.asarray(blks, jnp.int32)
            )
        else:
            self._cache, lab = self._prefill_fn()(
                self.params, self._cache, toks, jnp.int32(slot)
            )
        tok = int(np.asarray(lab).reshape(-1)[0])
        self._live.add(slot)
        self._pos[slot] = self.prompts.shape[1]
        self._tok[slot] = tok
        self._pf_progress.pop(slot, None)  # one-shot start supersedes chunks
        return tok

    # -- chunked prefill (resumable against the same slot cache) ------------

    def prefill_begin(self, slot: int, item: int, n_tokens: int) -> Optional[int]:
        """First chunk of a chunked prefill: jitted prefill of the prompt's
        first ``n_tokens`` into the slot row (contiguous) or its freshly
        claimed pool blocks (paged). Returns the first generated token when
        ``n_tokens`` already covers the whole prompt (== ``start``), else
        None — resume with ``prefill_resume``; the slot cache is valid
        mid-prompt, so decode steps for OTHER slots interleave freely."""
        S = self.prompts.shape[1]
        n = min(int(n_tokens), S)
        if n >= S:
            return self.start(slot, item)
        if n < 1:
            raise ValueError(f"prefill chunk must be >= 1 token, got {n_tokens}")
        self._ensure_rows(slot + 1)
        toks = jnp.asarray(self.prompts[item][None, :n])
        if self.paged:
            if slot in self._live:  # engine frees before reuse; be defensive
                self._alloc.free_slot(slot)
            blks = self._alloc.alloc(slot, -(-n // self._bs_blk))
            self._cache, _ = self._prefill_fn_paged(n)(
                self.params, self._cache, toks, jnp.asarray(blks, jnp.int32)
            )
        else:
            self._cache, _ = self._prefill_fn()(
                self.params, self._cache, toks, jnp.int32(slot)
            )
        self._live.add(slot)
        self._pos[slot] = n
        self._pf_progress[slot] = item
        return None

    def prefill_resume(self, slot: int, n_tokens: int) -> Optional[int]:
        """Resume a chunked prefill: feed the next ``n_tokens`` prompt
        tokens through the no-ramp decode path, one token per dispatch —
        each token scatters its KV at the slot's position exactly as a
        decode step would (appending pool blocks as they fill on the paged
        layout), so the chunk is genuinely incremental against the shared
        slot cache. Returns the first generated token (the greedy
        continuation of the last prompt token) once the prompt is
        exhausted, else None. A production kernel would run the chunk as
        one (n_tokens)-wide dispatch; the per-token loop is the
        oracle-grade equivalent at the same cache layout."""
        item = self._pf_progress[slot]
        S = self.prompts.shape[1]
        lab = None
        end = min(int(self._pos[slot]) + int(n_tokens), S)
        for p in range(int(self._pos[slot]), end):
            lab = self._feed_prompt_token(slot, int(self.prompts[item][p]))
        if int(self._pos[slot]) >= S:
            del self._pf_progress[slot]
            self._tok[slot] = int(lab)
            return int(lab)
        return None

    def _feed_prompt_token(self, slot: int, tok: int) -> int:
        """One resumed-prefill token through the (no-ramp) decode program:
        B=1 gather/scatter on the batched cache, per-row position — the
        same compiled path a decode step uses, so the cache layout cannot
        diverge between chunked and one-shot prefill."""
        rows = np.asarray([slot], np.int64)
        toks = jnp.asarray([[tok]], jnp.int32)
        pos = jnp.asarray(self._pos[rows], jnp.int32)
        if self.paged:
            while int(self._alloc.owned[slot]) * self._bs_blk <= int(self._pos[slot]):
                self._alloc.alloc(slot, 1)
            tables = jnp.asarray(self._alloc.table[rows], jnp.int32)
            self._cache, fl = self._decode_fn_paged_noramp()(
                self.params, self._cache, toks, pos, tables
            )
        else:
            self._cache, fl = self._decode_fn_noramp()(
                self.params, self._cache, toks, pos, jnp.asarray(rows, jnp.int32)
            )
        self.dispatches += 1
        self._pos[slot] += 1
        return int(np.asarray(fl).reshape(-1)[0])

    def step(self, slots: Sequence[int], active: Sequence[int]):
        """ONE decode step — one jitted dispatch — for every slot in
        ``slots``. Returns (ramp_labels (K,B), ramp_unc (K,B), final (B,))
        with rows in sorted(active) order and columns in ``slots`` order."""
        slots = list(slots)
        for s in slots:
            if s not in self._live:
                raise KeyError(f"slot {s} is not live (freed or never started)")
            if s in self._pf_progress:
                raise KeyError(f"slot {s} is mid-prefill (resume its chunks first)")
        B = len(slots)
        if B == 0:  # nothing in flight: no dispatch (mirrors the loop runner)
            k = len(sorted(active)[: self.max_slots])
            return (np.zeros((k, 0), np.int64), np.zeros((k, 0), np.float32),
                    np.zeros(0, np.int64))
        bucket = min(_bucket(B), self._rows)
        # pad with FREE rows (their state is garbage a future start()
        # overwrites wholesale), then with duplicates of stepped slots
        # (gather precedes every write, so duplicate indices scatter
        # identical values). NEVER a live-but-unstepped row: attention
        # writes would be idempotent previews, but an SSM mixer would
        # advance that slot's recurrent state off-schedule.
        free = [r for r in range(self._rows) if r not in self._live][: bucket - B]
        dup = [slots[i % B] for i in range(bucket - B - len(free))] if B else []
        rows = np.asarray(slots + free + dup, np.int64)
        toks = jnp.asarray(self._tok[rows].reshape(-1, 1), jnp.int32)
        pos = jnp.asarray(self._pos[rows], jnp.int32)
        act = sorted(active)[: self.max_slots]
        k = len(act)
        if self.paged:
            # append a block only when a stepped slot's current block is
            # full; a pool with no free block raises PoolExhausted here,
            # BEFORE any device state changes
            for s in dict.fromkeys(slots):
                while int(self._alloc.owned[s]) * self._bs_blk <= int(self._pos[s]):
                    self._alloc.alloc(s, 1)
            tables = self._alloc.table[rows].copy()
            # FREE pad rows keep stale table rows that may now reference
            # blocks owned by live slots — zero them so their (discarded)
            # scatters land in the reserved trash block 0
            if free:
                tables[B : B + len(free)] = 0
            tables_j = jnp.asarray(tables, jnp.int32)
            if k:
                pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
                self._cache, (rl, ru, fl) = self._decode_fn_paged()(
                    self.params, self._cache, toks, pos, tables_j, pad_act
                )
        else:
            rows_j = jnp.asarray(rows, jnp.int32)
            if k:
                pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
                self._cache, (rl, ru, fl) = self._decode_fn()(
                    self.params, self._cache, toks, pos, rows_j, pad_act
                )
        if k:
            labels = np.asarray(rl).reshape(self.max_slots, -1)[:k, :B].astype(np.int64)
            unc = np.asarray(ru).reshape(self.max_slots, -1)[:k, :B].astype(np.float32)
        else:
            if self.paged:
                self._cache, fl = self._decode_fn_paged_noramp()(
                    self.params, self._cache, toks, pos, tables_j
                )
            else:
                self._cache, fl = self._decode_fn_noramp()(
                    self.params, self._cache, toks, pos, rows_j
                )
            labels = np.zeros((0, B), np.int64)
            unc = np.zeros((0, B), np.float32)
        self.dispatches += 1
        final = np.asarray(fl).reshape(-1)[:B].astype(np.int64)
        self._pos[rows[:B]] += 1
        self._tok[rows[:B]] = final  # vanilla greedy trajectory (agreement baseline)
        return labels, unc, final

    def free(self, slot: int) -> None:
        if self.paged and self._alloc is not None and slot in self._live:
            self._alloc.free_slot(slot)
        self._live.discard(slot)
        self._pf_progress.pop(slot, None)


class LoopDecodeRunner:
    """Per-slot-loop reference runner: the pre-batched implementation kept
    for the batched-vs-loop equivalence tests and the dispatch-count
    benchmark. Slots are independent B=1 caches; every engine step issues
    one jitted ``model.decode`` PER SLOT (B dispatches + B small cache
    trees per step — the serialized hot path ``DecodeRunner`` replaces)."""

    def __init__(self, model, params, prompts: np.ndarray, *, max_new_tokens: int = 64,
                 max_slots: int = 8):
        self.model = model
        self.params = params
        self.prompts = np.asarray(prompts, np.int32)  # (N, S)
        self.max_new = max_new_tokens
        self.max_slots = max_slots
        self.n_sites = len(model.sites)
        self.dispatches = 0  # jitted decode calls (B per step)
        self._slots = {}
        self._pf = None
        self._dec = None
        self._dec0 = None  # no-ramp (vanilla) decode variant

    def _prefill_fn(self):
        if self._pf is None:
            m, S = self.model, self.prompts.shape[1]
            cache_len = S + self.max_new

            @jax.jit
            def pf(params, toks):
                cache, outs = m.prefill(
                    params, toks, cache_len=cache_len, active_sites=None,
                    with_cache=True, moe_impl="dense",
                )
                lab = outs["final"]["label"]
                return cache, (lab[:, 0] if lab.ndim == 2 else lab)

            self._pf = pf
        return self._pf

    def _decode_fn(self):
        if self._dec is None:
            m = self.model

            @jax.jit
            def dec(params, cache, tok, pos, active):
                new_cache, outs = m.decode(
                    params, cache, tok, pos, active_sites=active, moe_impl="dense"
                )
                return new_cache, (
                    outs["ramps"]["label"],
                    1.0 - outs["ramps"]["maxprob"],
                    outs["final"]["label"],
                )

            self._dec = dec
        return self._dec

    def _decode_fn_noramp(self):
        if self._dec0 is None:
            m = self.model

            @jax.jit
            def dec0(params, cache, tok, pos):
                new_cache, outs = m.decode(
                    params, cache, tok, pos, active_sites=None, moe_impl="dense"
                )
                return new_cache, outs["final"]["label"]

            self._dec0 = dec0
        return self._dec0

    def start(self, slot: int, item: int) -> int:
        toks = jnp.asarray(self.prompts[item][None, :])
        cache, lab = self._prefill_fn()(self.params, toks)
        tok = int(np.asarray(lab).reshape(-1)[0])
        self._slots[slot] = {"cache": cache, "pos": self.prompts.shape[1], "tok": tok}
        return tok

    def step(self, slots: Sequence[int], active: Sequence[int]):
        """One decode step for every slot in ``slots`` — one jitted B=1
        dispatch per slot. Row/column order matches ``DecodeRunner.step``."""
        act = sorted(active)[: self.max_slots]
        k = len(act)
        labels = np.zeros((max(k, 1), len(slots)), np.int64)
        unc = np.full((max(k, 1), len(slots)), 1.0, np.float32)
        final = np.zeros(len(slots), np.int64)
        if k:
            pad_act = jnp.asarray(act + [act[-1]] * (self.max_slots - k), jnp.int32)
            dec = self._decode_fn()
        else:
            dec0 = self._decode_fn_noramp()
        for b, s in enumerate(slots):
            st = self._slots[s]
            tok = jnp.asarray([[st["tok"]]], jnp.int32)
            if k:
                st["cache"], (rl, ru, fl) = dec(
                    self.params, st["cache"], tok, jnp.int32(st["pos"]), pad_act
                )
                labels[:, b] = np.asarray(rl).reshape(self.max_slots, -1)[:k, 0]
                unc[:, b] = np.asarray(ru).reshape(self.max_slots, -1)[:k, 0]
            else:
                st["cache"], fl = dec0(self.params, st["cache"], tok, jnp.int32(st["pos"]))
            self.dispatches += 1
            fl = int(np.asarray(fl).reshape(-1)[0])
            final[b] = fl
            st["pos"] += 1
            st["tok"] = fl  # vanilla greedy trajectory (agreement baseline)
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def free(self, slot: int) -> None:
        self._slots.pop(slot, None)


class SyntheticDecodeRunner:
    """Profile-only generative runner — the decode analogue of
    ``SyntheticRunner``: deterministic per-token ramp records without a
    model. A fixed fraction of tokens is "easy" (confidently predictable
    from ``exit_site`` onward, ramp label agreeing with the final token);
    the rest stay uncertain and disagreeing at every ramp, so an
    over-opened threshold costs accuracy exactly as with a trained LM.
    Used by the generative benchmarks/sweeps where training an LM per
    configuration would dominate runtime."""

    def __init__(self, n_sites: int, exit_site: int, easy_frac: float = 0.7,
                 vocab: int = 101):
        self.n_sites = n_sites
        self.exit_site = exit_site
        self.easy_frac = easy_frac
        self.vocab = vocab
        self._slots = {}

    def _token(self, item: int, t: int) -> int:
        return (item * 31 + t * 7 + 3) % self.vocab

    def _easy(self, item: int, t: int) -> bool:
        return ((item * 131 + t * 17) % 100) < self.easy_frac * 100

    def start(self, slot: int, item: int) -> int:
        self._slots[slot] = {"item": item, "t": 0}
        return self._token(item, 0)

    def step(self, slots: Sequence[int], active: Sequence[int]):
        act = sorted(active)
        k = len(act)
        B = len(slots)
        labels = np.zeros((max(k, 1), B), np.int64)
        unc = np.full((max(k, 1), B), 0.9, np.float32)
        final = np.zeros(B, np.int64)
        for b, s in enumerate(slots):
            st = self._slots[s]
            st["t"] += 1
            item, t = st["item"], st["t"]
            fin = self._token(item, t)
            final[b] = fin
            easy = self._easy(item, t)
            for j, site in enumerate(act):
                if easy and site >= self.exit_site:
                    labels[j, b] = fin
                    unc[j, b] = 0.02
                else:
                    labels[j, b] = (fin + 1) % self.vocab
                    unc[j, b] = 0.9
        if k == 0:
            return labels[:0], unc[:0], final
        return labels[:k], unc[:k], final

    def free(self, slot: int) -> None:
        self._slots.pop(slot, None)
