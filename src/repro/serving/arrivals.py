"""Arrival-trace generation.

``video_trace``: fixed-fps arrivals (the paper's CV workloads — 30 fps).
``maf_trace``: bursty arrivals emulating the Microsoft Azure Functions
shape the paper uses for NLP: per-bucket rates drawn from a lognormal
rate process with temporal correlation, Poisson arrivals within buckets.
"""
from __future__ import annotations

import numpy as np


def video_trace(n: int, fps: float = 30.0, start_ms: float = 0.0) -> np.ndarray:
    return start_ms + np.arange(n) * (1000.0 / fps)


def maf_trace(
    n: int,
    mean_qps: float,
    *,
    burstiness: float = 0.8,
    bucket_ms: float = 1000.0,
    corr: float = 0.85,
    seed: int = 0,
) -> np.ndarray:
    """Arrival times (ms) for n requests with lognormal AR(1) rate process."""
    if mean_qps <= 0:
        raise ValueError(f"mean_qps must be positive, got {mean_qps}")
    rng = np.random.default_rng(seed)
    times = []
    t = 0.0
    z = 0.0
    while len(times) < n:
        z = corr * z + np.sqrt(1 - corr**2) * rng.normal()
        rate = mean_qps * np.exp(burstiness * z - 0.5 * burstiness**2)
        lam = max(rate * bucket_ms / 1000.0, 1e-6)
        k = rng.poisson(lam)
        if k:
            ts = np.sort(rng.uniform(t, t + bucket_ms, k))
            times.extend(ts.tolist())
        t += bucket_ms
    return np.asarray(times[:n])
