"""Unified event-driven serving engine core.

Every serving workload in this repo used to hand-roll its own
discrete-event loop — ``ServingSimulator`` (single worker),
``ClusterSimulator``/``MixedClusterSimulator`` (scale-out + mixed pools),
and the generative decode engine — each re-implementing clock advance,
queue draining, and controller feedback. This module is the single core
they are now thin facades over:

  * ``EngineCore`` — ONE event heap and ONE monotone clock. Adapters
    schedule wake events; completions are themselves heap events, so
    ``EngineCore.completions`` pops globally time-ordered across every
    pool (the property ``MixedClusterSimulator`` could never test while
    its pools ran on independent clocks).
  * ``ClassificationAdapter`` — per-replica queues (``Worker`` objects),
    the `repro.serving.policies` batch-formation strategies, dispatcher
    routing at arrival, and the Apparate controller hookpoint in
    ``Worker.execute``.
  * ``GenerativeAdapter`` — slot-based continuous batching, per-token
    early exits with deferred KV catch-up, plus the two capabilities the
    split loops made impossible: **chunked prefill interleaving**
    (``GenerativeConfig.prefill_chunk`` splits a long prompt into chunks
    co-scheduled with in-flight decode steps, so TPT never stalls behind
    a monolithic prefill) and **SLO-aware admission / mid-stream shedding**
    via the shared ``AdmissionPolicy`` (`repro.serving.policies`).

Exactness contract: with ``prefill_chunk == 0`` and no admission policy,
both adapters reproduce the pre-refactor loops bit-for-bit — pinned by
the facade-vs-reference fuzz in ``tests/test_engine_equivalence.py``
against the frozen oracles in `repro.serving.reference`.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import GenResponse, Request, Response
from repro.serving.runner import PoolExhausted


def release_offset(profile, site: int, bs: int, active: Sequence[int]) -> float:
    """Time into batch execution at which a result exiting at ``site``
    leaves the platform: the trunk compute through the site's layer plus
    every active ramp head at or before it (all on the critical path)."""
    ovh = 0.0
    for s in sorted(active):
        if s <= site:
            ovh += profile.ramp_overhead(s, bs)
    return profile.time_to_layer(profile.sites[site], bs) + ovh


class EngineCore:
    """Single discrete-event core: one heap, one clock, N adapters.

    Adapters schedule their own wake events (``schedule``) and log
    completions (``emit``); the core pops events in global time order, so
    ``now`` is monotone across every pool and ``completions`` interleaves
    classification and generative releases in true time order.
    """

    def __init__(self):
        self.now = 0.0
        self.adapters: List = []
        self._heap: List = []  # (time, seq, adapter | None, completion)
        self._seq = 0
        #: (time, pool, record) tuples appended as the clock passes them —
        #: globally time-ordered across every adapter on this core.
        self.completions: List = []

    def add(self, adapter):
        adapter.core = self
        self.adapters.append(adapter)
        return adapter

    def schedule(self, t: float, adapter) -> None:
        """Wake ``adapter`` when the clock reaches ``t`` (FIFO at ties)."""
        heapq.heappush(self._heap, (float(t), self._seq, adapter, None))
        self._seq += 1

    def emit(self, t: float, pool: str, record) -> None:
        """Log a completion at time ``t``. The record rides the heap, so it
        lands in ``completions`` only when the clock reaches it — later
        emissions with earlier timestamps still order correctly."""
        heapq.heappush(self._heap, (float(t), self._seq, None, (pool, record)))
        self._seq += 1

    def run(self) -> "EngineCore":
        for a in self.adapters:
            a.prime(self)
        while self._heap:
            t, _, adapter, rec = heapq.heappop(self._heap)
            if t > self.now:
                self.now = t
            if adapter is None:
                self.completions.append((t, rec[0], rec[1]))
            else:
                adapter.wake(self, self.now)
        return self


class ClassificationAdapter:
    """Classification-batch workload on the shared core.

    Exact port of the pre-refactor ``ClusterSimulator`` loop: dispatch at
    arrival (routing sees the state at that instant), every free worker
    acts until quiescent at each decision point, then one wake is
    scheduled at the next decision instant (arrival, a busy worker with
    backlog freeing up, or a waiting policy's timeout expiry).

    ``admission`` (an ``AdmissionPolicy``) adds SLO-aware admission
    control: a request whose earliest estimated completion on its routed
    worker already misses its deadline is shed at arrival instead of
    wasting queue capacity — the InferLine-style early drop the
    ``slo_aware`` dispatcher estimates but never acts on.
    """

    pool = "classification"

    def __init__(self, workers, dispatcher, requests, admission=None):
        self.workers = workers
        self.dispatcher = dispatcher
        self.reqs = list(requests)
        self.admission = admission
        self.responses: List[Response] = []
        self._i = 0
        self._now = 0.0  # last decision instant (the old loop's final `now`)

    def prime(self, core: EngineCore) -> None:
        if self.reqs:
            core.schedule(0.0, self)

    def _pending(self) -> bool:
        return self._i < len(self.reqs) or any(w.queue for w in self.workers)

    def wake(self, core: EngineCore, now: float) -> None:
        workers = self.workers
        self._now = now
        nxt = np.inf
        while True:
            # dispatch arrivals up to `now` (routing sees the state at arrival)
            while self._i < len(self.reqs) and self.reqs[self._i].arrival_ms <= now + 1e-9:
                req = self.reqs[self._i]
                self._i += 1
                w = self.dispatcher.pick(workers, req, now)
                if self.admission is not None and not self.admission.admit_request(
                    req, now, w.backlog_eta(now)
                ):
                    r = Response(req.rid, now, -1, -1, now - req.arrival_ms, 0, True,
                                 worker=w.wid, slo_ms=req.slo_ms)
                    self.responses.append(r)
                    core.emit(now, self.pool, r)
                    continue
                w.queue.append(req)
            nxt = self.reqs[self._i].arrival_ms if self._i < len(self.reqs) else np.inf
            # let every free worker with queued requests act at `now`
            acted = False
            for w in workers:
                if not w.queue or now + 1e-9 < w.free_at:
                    continue
                batch = w.policy.form_batch(w.queue, now, nxt, w.exec_time)
                if batch is None:
                    continue
                acted = True
                if not batch:  # DROP sentinel: shed head-of-line request
                    r = w.queue.pop(0)
                    resp = Response(r.rid, now, -1, -1, now - r.arrival_ms, 0, True,
                                    worker=w.wid, slo_ms=r.slo_ms)
                    self.responses.append(resp)
                    core.emit(now, self.pool, resp)
                    continue
                del w.queue[: len(batch)]
                out = w.execute(batch, now)
                self.responses.extend(out)
                for r in out:
                    core.emit(r.release_ms, self.pool, r)
            if not acted:
                break
        if not self._pending():
            return
        # next decision point: arrival, a busy worker freeing up, or a
        # waiting policy's timeout expiry
        cand = [nxt]
        for w in workers:
            if not w.queue:
                continue
            cand.append(w.free_at if now < w.free_at else w.policy.next_wake(w.queue, now, nxt))
        t = min(cand)
        if np.isfinite(t):
            core.schedule(t, self)
        # else: defensive — nothing can ever progress (the old loop's break)

    def makespan(self) -> float:
        return max([self._now] + [w.free_at for w in self.workers])


class GenerativeAdapter:
    """Generative decode workload on the shared core.

    Owns slot admission and decode steps for one ``GenerativeEngine``
    (the engine object carries config/profile/runner/controller and
    accumulates the run stats). The legacy path (``prefill_chunk == 0``,
    no admission) is an exact port of the pre-refactor engine loop:
    admission prefills serially at the step boundary and the whole batch
    stalls behind it.

    With ``prefill_chunk > 0`` admission only *claims* the slot; the
    prompt then prefills in ``prefill_chunk``-token chunks co-scheduled
    with the in-flight decode steps (one chunk per prefilling slot per
    step, priced by ``prefill_ms``), and the first token releases at the
    end of the step that completes the prompt. Runners exposing
    ``prefill_begin``/``prefill_resume`` (``DecodeRunner``) fill the real
    slot cache incrementally; other runners are started once the last
    chunk lands (timing-only chunking).

    With an ``AdmissionPolicy``, a request whose per-token SLO is hopeless
    is dropped at admission, and a live slot whose observed TPT has
    violated its SLO for ``shed_after`` consecutive tokens is shed at the
    next step boundary (partial response marked ``shed=True``).

    With ``GenerativeConfig.preempt != 'none'``, a mid-run
    ``PoolExhausted`` from the paged KV pool no longer propagates: the
    adapter preempts the victim slot with the most SLO slack — swapping
    its KV blocks to a host buffer for later readmission ('swap', via
    ``DecodeRunner.swap_out``/``swap_in``) or discarding it ('shed') —
    and retries. An ``AdmissionPolicy`` refines the swap-vs-shed choice
    per victim by SLO slack (``preempt_stream``).
    """

    pool = "generative"

    def __init__(self, eng, requests):
        self.eng = eng
        self.reqs = sorted(requests, key=lambda r: (r.arrival_ms, r.rid))
        self.queue: deque = deque()
        self.slots: Dict[int, dict] = {}  # slot id -> {req, resp, [pf_left, pf_fed]}
        self.free = list(range(eng.cfg.max_batch_size))
        self.swapped: deque = deque()  # preempted streams awaiting readmission
        self.responses: List[GenResponse] = []
        self._i = 0
        self._now = 0.0  # pool-local clock (the old loop's `now`)
        self._pending_kv = 0.0

    def prime(self, core: EngineCore) -> None:
        if self.reqs:
            core.schedule(0.0, self)

    # -- helpers -------------------------------------------------------------

    def _finish(self, sid: int, core: EngineCore, shed: bool = False):
        sl = self.slots.pop(sid)
        self.free.append(sid)
        self.free.sort()
        if self.eng.runner is not None:
            self.eng.runner.free(sid)
        if self.eng.admission is not None:
            # the stream ended: drop its violation streak so the next
            # stream reusing this (wid, slot, rid) key starts fresh
            self.eng.admission.forget((self.eng.wid, sid, sl["req"].rid))
        resp = sl["resp"]
        if shed:
            resp.shed = True
            self.eng.n_shed += 1
        self.responses.append(resp)

    def _cached_tokens(self, r) -> int:
        """Prompt tokens the runner's prefix cache already holds for ``r``
        — the engine prices prefill on the uncached tail only."""
        eng = self.eng
        if eng.runner is None or not hasattr(eng.runner, "cached_prefix_tokens"):
            return 0
        return min(int(eng.runner.cached_prefix_tokens(r.item)), int(r.prompt_len))

    def _preempt_one(self, core: EngineCore, exclude: Optional[int] = None) -> bool:
        """Pick a preemption victim for an exhausted KV pool and evict it.
        Victim = the decoding slot with the most per-token SLO slack
        (ties: lowest slot id); with no decoding slot, a prefilling slot
        (excluding ``exclude``, the one mid-feed) is shed — its partial
        prefill cannot swap. Returns False when nothing is evictable."""
        eng = self.eng

        def slack(sid):
            s = self.slots[sid]["req"].slo_ms
            return s if np.isfinite(s) else np.inf

        decoding = [s for s in sorted(self.slots)
                    if self.slots[s]["resp"] is not None and s != exclude]
        if decoding:
            victim = max(decoding, key=lambda s: (slack(s), -s))
            sl = self.slots[victim]
            action = eng.cfg.preempt
            if action == "swap":
                if eng.admission is not None:
                    action = eng.admission.preempt_stream(
                        sl["req"], self._now, eng.profile.vanilla_time(1)
                    )
                if not hasattr(eng.runner, "swap_out"):
                    action = "shed"
            if action == "swap":
                handle = eng.runner.swap_out(victim)
                sl = self.slots.pop(victim)
                self.free.append(victim)
                self.free.sort()
                if eng.admission is not None:
                    eng.admission.forget((eng.wid, victim, sl["req"].rid))
                self.swapped.append({"req": sl["req"], "resp": sl["resp"],
                                     "handle": handle})
                eng.n_preempt_swaps += 1
            else:
                self._finish(victim, core, shed=True)
                eng.n_preempt_sheds += 1
            return True
        prefilling = [s for s in sorted(self.slots)
                      if self.slots[s]["resp"] is None and s != exclude]
        if not prefilling:
            return False
        victim = max(prefilling, key=lambda s: (slack(s), -s))
        sl = self.slots.pop(victim)
        self.free.append(victim)
        self.free.sort()
        if eng.runner is not None:
            eng.runner.free(victim)
        if eng.admission is not None:
            eng.admission.forget((eng.wid, victim, sl["req"].rid))
        resp = GenResponse(rid=sl["req"].rid, arrival_ms=sl["req"].arrival_ms,
                           release_ms=[], exit_sites=[], tokens=[],
                           final_tokens=[], worker=eng.wid,
                           slo_ms=sl["req"].slo_ms, shed=True)
        self.responses.append(resp)
        eng.n_shed += 1
        eng.n_preempt_sheds += 1
        return True

    def _readmit(self, core: EngineCore) -> None:
        """Swap preempted streams back into free slots while the pool has
        room (FIFO — the earliest victim resumes first)."""
        eng = self.eng
        while self.swapped and self.free:
            sid = self.free[0]
            try:
                eng.runner.swap_in(sid, self.swapped[0]["handle"])
            except PoolExhausted:
                return
            ent = self.swapped.popleft()
            self.free.pop(0)
            self.slots[sid] = {"req": ent["req"], "resp": ent["resp"]}
            eng.n_swap_ins += 1

    def _admit_one(self, r, core: EngineCore) -> bool:
        """Claim a slot for ``r``. Legacy path: serial prefill advances the
        pool clock and the first token releases immediately. Chunked path:
        the slot enters the prefilling state; chunks run inside steps.
        Returns False when the KV pool rejected the prompt and ``r`` was
        put back at the queue head to wait for live slots to drain."""
        eng = self.eng
        sid = self.free.pop(0)
        if eng.cfg.prefill_chunk > 0:
            self.slots[sid] = {"req": r, "resp": None,
                               "pf_left": r.prompt_len, "pf_fed": 0}
            return True
        skip = self._cached_tokens(r)
        while True:
            try:
                tok = eng.runner.start(sid, r.item) if eng.runner is not None else 0
                break
            except PoolExhausted:
                if eng.cfg.preempt != "none" and self._preempt_one(core):
                    continue
                self.free.append(sid)
                self.free.sort()
                if self.slots:
                    # live slots will free blocks: retry at a later boundary
                    self.queue.appendleft(r)
                    return False
                # an empty engine still can't fit the prompt: hopeless
                resp = GenResponse(rid=r.rid, arrival_ms=r.arrival_ms,
                                   release_ms=[], exit_sites=[], tokens=[],
                                   final_tokens=[], worker=eng.wid,
                                   slo_ms=r.slo_ms, dropped=True)
                self.responses.append(resp)
                core.emit(self._now, self.pool, (r.rid, -1))
                return True
        self._now += eng.prefill_ms(max(int(r.prompt_len) - skip, 0))
        resp = GenResponse(
            rid=r.rid, arrival_ms=r.arrival_ms, release_ms=[self._now],
            exit_sites=[-1], tokens=[tok], final_tokens=[tok],
            worker=eng.wid, slo_ms=r.slo_ms,
        )
        self.slots[sid] = {"req": r, "resp": resp}
        eng.n_tokens += 1
        core.emit(self._now, self.pool, (r.rid, 0))
        if r.n_tokens <= 1:
            self._finish(sid, core)
        return True

    def _prefill_chunks(self, core: EngineCore) -> float:
        """Run one prefill chunk per prefilling slot; returns the chunk time
        co-scheduled into this step. Completed prompts are recorded in the
        slot state; their first token releases at step end."""
        eng = self.eng
        incremental = eng.runner is not None and hasattr(eng.runner, "prefill_begin")
        chunk_ms = 0.0
        for sid in sorted(self.slots):
            if sid not in self.slots:  # preempted earlier in this pass
                continue
            sl = self.slots[sid]
            if sl["resp"] is not None:
                continue
            r = sl["req"]
            if incremental and sl["pf_fed"] == 0 and "pf_skip" not in sl:
                # prompt tokens the prefix cache covers cost no chunk time;
                # the runner shares their cached blocks at prefill_begin
                sl["pf_skip"] = min(self._cached_tokens(r), sl["pf_left"])
                sl["pf_left"] -= sl["pf_skip"]
            c = min(eng.cfg.prefill_chunk, sl["pf_left"])
            if c > 0:
                chunk_ms += eng.prefill_ms(c)
                eng.n_chunks += 1
                if incremental and "pf_tok" not in sl:
                    tok = self._feed_chunk(sid, sl, r, c, core)
                    if sid not in self.slots:  # shed: its prompt can't fit
                        continue
                    if tok is not None:  # runner's prompt exhausted: first token
                        sl["pf_tok"] = int(tok)
                sl["pf_left"] -= c
                sl["pf_fed"] += c
            if sl["pf_left"] <= 0 and "pf_tok" not in sl:
                # non-incremental runner (or None), a zero-length prompt, or
                # a fully prefix-cached one: one-shot start at the
                # completing chunk
                sl["pf_tok"] = int(eng.runner.start(sid, r.item)) if (
                    eng.runner is not None) else 0
        eng.chunk_ms += chunk_ms
        return chunk_ms

    def _feed_chunk(self, sid: int, sl: dict, r, c: int, core: EngineCore):
        """Feed one prefill chunk into the runner, preempting victims on
        pool exhaustion when configured; as a last resort the slot itself
        is shed (its prompt cannot fit even a drained pool)."""
        eng = self.eng
        while True:
            try:
                if sl["pf_fed"] == 0:
                    return eng.runner.prefill_begin(sid, r.item, sl.get("pf_skip", 0) + c)
                return eng.runner.prefill_resume(sid, c)
            except PoolExhausted:
                if eng.cfg.preempt == "none":
                    raise
                if not self._preempt_one(core, exclude=sid):
                    if not self._preempt_one(core):  # shed sid itself
                        raise
                    return None

    # -- event loop ----------------------------------------------------------

    def wake(self, core: EngineCore, t: float) -> None:
        eng = self.eng
        self._now = max(self._now, t)
        n = len(self.reqs)
        while self._i < n or self.queue or self.slots or self.swapped:
            now = self._now
            while self._i < n and self.reqs[self._i].arrival_ms <= now + 1e-9:
                r = self.reqs[self._i]
                self._i += 1
                if eng.admission is not None and not eng.admission.admit_token_stream(
                    r, now, eng.profile.vanilla_time(1)
                ):
                    resp = GenResponse(rid=r.rid, arrival_ms=r.arrival_ms,
                                       release_ms=[], exit_sites=[], tokens=[],
                                       final_tokens=[], worker=eng.wid,
                                       slo_ms=r.slo_ms, dropped=True)
                    self.responses.append(resp)
                    core.emit(now, self.pool, (r.rid, -1))
                    continue
                self.queue.append(r)
            # swapped victims get their slots back before new admissions
            if self.swapped:
                self._readmit(core)
            if not self.slots and not self.queue:
                if self.swapped:
                    # an EMPTY engine still can't readmit the head stream —
                    # its blocks exceed the drained pool: hopeless, shed it
                    ent = self.swapped.popleft()
                    ent["resp"].shed = True
                    eng.n_shed += 1
                    self.responses.append(ent["resp"])
                    continue
                if self._i >= n:
                    break
                core.schedule(self.reqs[self._i].arrival_ms, self)  # idle
                return
            # admit queued requests into free slots (FCFS, step boundary)
            while self.queue and self.free:
                if not self._admit_one(self.queue.popleft(), core):
                    break  # pool-blocked: wait for live slots to drain
            if not self.slots:
                continue
            self._step(core)
            core.schedule(self._now, self)
            return

    def _step(self, core: EngineCore) -> None:
        """One engine step — or one SYNC WINDOW when the runner exposes
        ``step_multi``: up to ``steps_per_sync`` decode steps run in ONE
        dispatch with exit decisions made on-device against the
        controller's (stale-between-syncs) threshold copy, and the packed
        per-step records are REPLAYED here through the exact per-step
        accounting (observe → releases → KV deferral → shed), so the
        controller still sees every token and timing/SLO semantics are
        per-step. Chunked prefills are co-scheduled with the first decode
        step; windows shrink to 1 while any slot is prefilling (chunks
        must interleave every step) and never extend past the earliest
        finishing stream. The legacy per-step path is the special case of
        a runner without ``step_multi`` (and the equivalence tests pin
        ``steps_per_sync=1`` bit-identical across both)."""
        eng = self.eng
        chunk_ms = self._prefill_chunks(core) if eng.cfg.prefill_chunk > 0 else 0.0
        ctl = eng.controller
        act = sorted(ctl.active) if ctl is not None else []
        multi = eng.runner is not None and ctl is not None and hasattr(
            eng.runner, "step_multi"
        )
        exits_d = None
        while True:
            sids = [s for s in sorted(self.slots) if self.slots[s]["resp"] is not None]
            B = len(sids)
            if not (B and eng.runner is not None and ctl is not None):
                break
            try:
                if multi:
                    prefilling = any(v["resp"] is None for v in self.slots.values())
                    n_window = 1 if prefilling else max(1, min(
                        eng.cfg.steps_per_sync,
                        min(self.slots[s]["req"].n_tokens
                            - len(self.slots[s]["resp"].tokens) for s in sids),
                    ))
                    # per-active-site thresholds as of DISPATCH time — the
                    # device copy the window's exits are decided against
                    thr = (ctl.thresholds[np.asarray(act, np.int64)].astype(np.float32)  # repro: allow[host-sync] — host index build from a python list — no device operand
                           if act else np.zeros(0, np.float32))
                    labels, unc, finals, exits_d = eng.runner.step_multi(
                        sids, act, n_window, thr
                    )
                    eng.n_windows += 1
                else:
                    l1, u1, f1 = eng.runner.step(sids, act)
                    labels, unc, finals = l1[None], u1[None], f1[None]
                break
            except PoolExhausted:
                # a stepped slot needs a block the pool can't give: preempt
                # the slackest victim and retry with the survivors
                if eng.cfg.preempt == "none" or not self._preempt_one(core):
                    raise
        eng.peak_slots = max(eng.peak_slots, B)
        live = bool(B and eng.runner is not None and ctl is not None)
        nd = finals.shape[0] if live else 1
        for t in range(nd):
            if live:
                # replay one window step: the device-decided exits are
                # honored (forced), the records still feed adaptation, and
                # ``act`` pins the gather set even if a mid-window _adjust
                # changes the controller's active ramps. The per-step path
                # keeps the bare legacy signature (stub controllers in the
                # tests implement exactly that protocol).
                if exits_d is None:
                    dec = ctl.observe(labels[t], unc[t], finals[t])
                else:
                    dec = ctl.observe(labels[t], unc[t], finals[t],
                                      forced_exits=exits_d[t], act=act)
                fin = finals[t]
                ex = np.asarray(dec.exit_sites, np.int64)  # repro: allow[host-sync] — controller decisions are already host numpy
                released = np.asarray(dec.released_labels)  # repro: allow[host-sync] — controller decisions are already host numpy
            else:
                fin = np.zeros(B, np.int64)
                ex = np.full(B, -1, np.int64)
                released = fin
            eng.slot_history.append(B)
            kv_now = self._pending_kv
            step_ms = eng.profile.decode_step_time(ex, act) + (
                chunk_ms if t == 0 else 0.0
            )
            start = self._now
            end = start + kv_now + step_ms
            self._pending_kv = 0.0
            eng.kv_ms += kv_now
            # releases + next-step KV deferral, grouped by exit site so the
            # catch-up's weight traffic amortizes across this step's exits
            kv_by_site: Dict[int, int] = {}
            for j, sid in enumerate(sids):
                sl = self.slots.get(sid)
                if sl is None or sl["resp"] is None:
                    continue  # shed at an earlier replayed step of this window
                site = int(ex[j])
                if site >= 0:
                    off = release_offset(eng.profile, site, B, act)
                    rel = min(start + kv_now + off, end)
                else:
                    rel = end
                resp = sl["resp"]
                resp.release_ms.append(rel)
                resp.exit_sites.append(site)
                resp.tokens.append(int(released[j]))
                resp.final_tokens.append(int(fin[j]))
                eng.n_tokens += 1
                core.emit(rel, self.pool, (sl["req"].rid, len(resp.tokens) - 1))
                done = len(resp.tokens)
                if done >= sl["req"].n_tokens:
                    self._finish(sid, core)  # slot reusable at the next step boundary
                elif eng.admission is not None and eng.admission.note_token(
                    (eng.wid, sid, sl["req"].rid), rel - resp.release_ms[-2],
                    sl["req"].slo_ms,
                ):
                    self._finish(sid, core, shed=True)  # doomed mid-stream: shed
                elif site >= 0:
                    kv_by_site[site] = kv_by_site.get(site, 0) + 1
            for site, cnt in kv_by_site.items():
                self._pending_kv += eng.profile.kv_fill_cost(site, cnt)
            eng.busy_ms += kv_now + step_ms
            eng.n_steps += 1
            self._now = end
        # completed prefills release their first token at step end
        for sid in sorted(self.slots):
            sl = self.slots[sid]
            if sl["resp"] is not None or sl.get("pf_left", 1) > 0:
                continue
            r, tok = sl["req"], sl.pop("pf_tok")
            del sl["pf_left"], sl["pf_fed"]
            sl.pop("pf_skip", None)
            sl["resp"] = GenResponse(
                rid=r.rid, arrival_ms=r.arrival_ms, release_ms=[end],
                exit_sites=[-1], tokens=[tok], final_tokens=[tok],
                worker=eng.wid, slo_ms=r.slo_ms,
            )
            eng.n_tokens += 1
            core.emit(end, self.pool, (r.rid, 0))
            if r.n_tokens <= 1:
                self._finish(sid, core)

    def finalize(self) -> List[GenResponse]:
        self.eng.makespan_ms = self._now
        self.responses.sort(key=lambda r: r.rid)
        return self.responses
