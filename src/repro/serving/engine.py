"""Unified event-driven serving engine core.

Every serving workload in this repo used to hand-roll its own
discrete-event loop — ``ServingSimulator`` (single worker),
``ClusterSimulator``/``MixedClusterSimulator`` (scale-out + mixed pools),
and the generative decode engine — each re-implementing clock advance,
queue draining, and controller feedback. This module is the single core
they are now thin facades over:

  * ``EngineCore`` — ONE event heap and ONE monotone clock. Adapters
    schedule wake events; completions are themselves heap events, so
    ``EngineCore.completions`` pops globally time-ordered across every
    pool (the property ``MixedClusterSimulator`` could never test while
    its pools ran on independent clocks).
  * ``ClassificationAdapter`` — per-replica queues (``Worker`` objects),
    the `repro.serving.policies` batch-formation strategies, dispatcher
    routing at arrival, and the Apparate controller hookpoint in
    ``Worker.execute``.
  * ``GenerativeAdapter`` — slot-based continuous batching, per-token
    early exits with deferred KV catch-up, plus the two capabilities the
    split loops made impossible: **chunked prefill interleaving**
    (``GenerativeConfig.prefill_chunk`` splits a long prompt into chunks
    co-scheduled with in-flight decode steps, so TPT never stalls behind
    a monolithic prefill) and **SLO-aware admission / mid-stream shedding**
    via the shared ``AdmissionPolicy`` (`repro.serving.policies`).

Exactness contract: with ``prefill_chunk == 0`` and no admission policy,
both adapters reproduce the pre-refactor loops bit-for-bit — pinned by
the facade-vs-reference fuzz in ``tests/test_engine_equivalence.py``
against the frozen oracles in `repro.serving.reference`.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import GenResponse, Request, Response


def release_offset(profile, site: int, bs: int, active: Sequence[int]) -> float:
    """Time into batch execution at which a result exiting at ``site``
    leaves the platform: the trunk compute through the site's layer plus
    every active ramp head at or before it (all on the critical path)."""
    ovh = 0.0
    for s in sorted(active):
        if s <= site:
            ovh += profile.ramp_overhead(s, bs)
    return profile.time_to_layer(profile.sites[site], bs) + ovh


class EngineCore:
    """Single discrete-event core: one heap, one clock, N adapters.

    Adapters schedule their own wake events (``schedule``) and log
    completions (``emit``); the core pops events in global time order, so
    ``now`` is monotone across every pool and ``completions`` interleaves
    classification and generative releases in true time order.
    """

    def __init__(self):
        self.now = 0.0
        self.adapters: List = []
        self._heap: List = []  # (time, seq, adapter | None, completion)
        self._seq = 0
        #: (time, pool, record) tuples appended as the clock passes them —
        #: globally time-ordered across every adapter on this core.
        self.completions: List = []

    def add(self, adapter):
        adapter.core = self
        self.adapters.append(adapter)
        return adapter

    def schedule(self, t: float, adapter) -> None:
        """Wake ``adapter`` when the clock reaches ``t`` (FIFO at ties)."""
        heapq.heappush(self._heap, (float(t), self._seq, adapter, None))
        self._seq += 1

    def emit(self, t: float, pool: str, record) -> None:
        """Log a completion at time ``t``. The record rides the heap, so it
        lands in ``completions`` only when the clock reaches it — later
        emissions with earlier timestamps still order correctly."""
        heapq.heappush(self._heap, (float(t), self._seq, None, (pool, record)))
        self._seq += 1

    def run(self) -> "EngineCore":
        for a in self.adapters:
            a.prime(self)
        while self._heap:
            t, _, adapter, rec = heapq.heappop(self._heap)
            if t > self.now:
                self.now = t
            if adapter is None:
                self.completions.append((t, rec[0], rec[1]))
            else:
                adapter.wake(self, self.now)
        return self


class ClassificationAdapter:
    """Classification-batch workload on the shared core.

    Exact port of the pre-refactor ``ClusterSimulator`` loop: dispatch at
    arrival (routing sees the state at that instant), every free worker
    acts until quiescent at each decision point, then one wake is
    scheduled at the next decision instant (arrival, a busy worker with
    backlog freeing up, or a waiting policy's timeout expiry).

    ``admission`` (an ``AdmissionPolicy``) adds SLO-aware admission
    control: a request whose earliest estimated completion on its routed
    worker already misses its deadline is shed at arrival instead of
    wasting queue capacity — the InferLine-style early drop the
    ``slo_aware`` dispatcher estimates but never acts on.
    """

    pool = "classification"

    def __init__(self, workers, dispatcher, requests, admission=None):
        self.workers = workers
        self.dispatcher = dispatcher
        self.reqs = list(requests)
        self.admission = admission
        self.responses: List[Response] = []
        self._i = 0
        self._now = 0.0  # last decision instant (the old loop's final `now`)

    def prime(self, core: EngineCore) -> None:
        if self.reqs:
            core.schedule(0.0, self)

    def _pending(self) -> bool:
        return self._i < len(self.reqs) or any(w.queue for w in self.workers)

    def wake(self, core: EngineCore, now: float) -> None:
        workers = self.workers
        self._now = now
        nxt = np.inf
        while True:
            # dispatch arrivals up to `now` (routing sees the state at arrival)
            while self._i < len(self.reqs) and self.reqs[self._i].arrival_ms <= now + 1e-9:
                req = self.reqs[self._i]
                self._i += 1
                w = self.dispatcher.pick(workers, req, now)
                if self.admission is not None and not self.admission.admit_request(
                    req, now, w.backlog_eta(now)
                ):
                    r = Response(req.rid, now, -1, -1, now - req.arrival_ms, 0, True,
                                 worker=w.wid, slo_ms=req.slo_ms)
                    self.responses.append(r)
                    core.emit(now, self.pool, r)
                    continue
                w.queue.append(req)
            nxt = self.reqs[self._i].arrival_ms if self._i < len(self.reqs) else np.inf
            # let every free worker with queued requests act at `now`
            acted = False
            for w in workers:
                if not w.queue or now + 1e-9 < w.free_at:
                    continue
                batch = w.policy.form_batch(w.queue, now, nxt, w.exec_time)
                if batch is None:
                    continue
                acted = True
                if not batch:  # DROP sentinel: shed head-of-line request
                    r = w.queue.pop(0)
                    resp = Response(r.rid, now, -1, -1, now - r.arrival_ms, 0, True,
                                    worker=w.wid, slo_ms=r.slo_ms)
                    self.responses.append(resp)
                    core.emit(now, self.pool, resp)
                    continue
                del w.queue[: len(batch)]
                out = w.execute(batch, now)
                self.responses.extend(out)
                for r in out:
                    core.emit(r.release_ms, self.pool, r)
            if not acted:
                break
        if not self._pending():
            return
        # next decision point: arrival, a busy worker freeing up, or a
        # waiting policy's timeout expiry
        cand = [nxt]
        for w in workers:
            if not w.queue:
                continue
            cand.append(w.free_at if now < w.free_at else w.policy.next_wake(w.queue, now, nxt))
        t = min(cand)
        if np.isfinite(t):
            core.schedule(t, self)
        # else: defensive — nothing can ever progress (the old loop's break)

    def makespan(self) -> float:
        return max([self._now] + [w.free_at for w in self.workers])


class GenerativeAdapter:
    """Generative decode workload on the shared core.

    Owns slot admission and decode steps for one ``GenerativeEngine``
    (the engine object carries config/profile/runner/controller and
    accumulates the run stats). The legacy path (``prefill_chunk == 0``,
    no admission) is an exact port of the pre-refactor engine loop:
    admission prefills serially at the step boundary and the whole batch
    stalls behind it.

    With ``prefill_chunk > 0`` admission only *claims* the slot; the
    prompt then prefills in ``prefill_chunk``-token chunks co-scheduled
    with the in-flight decode steps (one chunk per prefilling slot per
    step, priced by ``prefill_ms``), and the first token releases at the
    end of the step that completes the prompt. Runners exposing
    ``prefill_begin``/``prefill_resume`` (``DecodeRunner``) fill the real
    slot cache incrementally; other runners are started once the last
    chunk lands (timing-only chunking).

    With an ``AdmissionPolicy``, a request whose per-token SLO is hopeless
    is dropped at admission, and a live slot whose observed TPT has
    violated its SLO for ``shed_after`` consecutive tokens is shed at the
    next step boundary (partial response marked ``shed=True``).
    """

    pool = "generative"

    def __init__(self, eng, requests):
        self.eng = eng
        self.reqs = sorted(requests, key=lambda r: (r.arrival_ms, r.rid))
        self.queue: deque = deque()
        self.slots: Dict[int, dict] = {}  # slot id -> {req, resp, [pf_left, pf_fed]}
        self.free = list(range(eng.cfg.max_batch_size))
        self.responses: List[GenResponse] = []
        self._i = 0
        self._now = 0.0  # pool-local clock (the old loop's `now`)
        self._pending_kv = 0.0

    def prime(self, core: EngineCore) -> None:
        if self.reqs:
            core.schedule(0.0, self)

    # -- helpers -------------------------------------------------------------

    def _finish(self, sid: int, core: EngineCore, shed: bool = False):
        sl = self.slots.pop(sid)
        self.free.append(sid)
        self.free.sort()
        if self.eng.runner is not None:
            self.eng.runner.free(sid)
        if self.eng.admission is not None:
            # the stream ended: drop its violation streak so the next
            # stream reusing this (wid, slot, rid) key starts fresh
            self.eng.admission.forget((self.eng.wid, sid, sl["req"].rid))
        resp = sl["resp"]
        if shed:
            resp.shed = True
            self.eng.n_shed += 1
        self.responses.append(resp)

    def _admit_one(self, r, core: EngineCore):
        """Claim a slot for ``r``. Legacy path: serial prefill advances the
        pool clock and the first token releases immediately. Chunked path:
        the slot enters the prefilling state; chunks run inside steps."""
        eng = self.eng
        sid = self.free.pop(0)
        if eng.cfg.prefill_chunk > 0:
            self.slots[sid] = {"req": r, "resp": None,
                               "pf_left": r.prompt_len, "pf_fed": 0}
            return
        self._now += eng.prefill_ms(r.prompt_len)
        tok = eng.runner.start(sid, r.item) if eng.runner is not None else 0
        resp = GenResponse(
            rid=r.rid, arrival_ms=r.arrival_ms, release_ms=[self._now],
            exit_sites=[-1], tokens=[tok], final_tokens=[tok],
            worker=eng.wid, slo_ms=r.slo_ms,
        )
        self.slots[sid] = {"req": r, "resp": resp}
        eng.n_tokens += 1
        core.emit(self._now, self.pool, (r.rid, 0))
        if r.n_tokens <= 1:
            self._finish(sid, core)

    def _prefill_chunks(self, core: EngineCore) -> float:
        """Run one prefill chunk per prefilling slot; returns the chunk time
        co-scheduled into this step. Completed prompts are recorded in the
        slot state; their first token releases at step end."""
        eng = self.eng
        incremental = eng.runner is not None and hasattr(eng.runner, "prefill_begin")
        chunk_ms = 0.0
        for sid in sorted(self.slots):
            sl = self.slots[sid]
            if sl["resp"] is not None:
                continue
            c = min(eng.cfg.prefill_chunk, sl["pf_left"])
            r = sl["req"]
            if c > 0:
                chunk_ms += eng.prefill_ms(c)
                eng.n_chunks += 1
                if incremental and "pf_tok" not in sl:
                    tok = (eng.runner.prefill_begin(sid, r.item, c) if sl["pf_fed"] == 0
                           else eng.runner.prefill_resume(sid, c))
                    if tok is not None:  # runner's prompt exhausted: first token
                        sl["pf_tok"] = int(tok)
                sl["pf_left"] -= c
                sl["pf_fed"] += c
            if sl["pf_left"] <= 0 and "pf_tok" not in sl:
                # non-incremental runner (or None), or a zero-length prompt:
                # one-shot start at the completing chunk
                sl["pf_tok"] = int(eng.runner.start(sid, r.item)) if (
                    eng.runner is not None) else 0
        eng.chunk_ms += chunk_ms
        return chunk_ms

    # -- event loop ----------------------------------------------------------

    def wake(self, core: EngineCore, t: float) -> None:
        eng = self.eng
        self._now = max(self._now, t)
        n = len(self.reqs)
        while self._i < n or self.queue or self.slots:
            now = self._now
            while self._i < n and self.reqs[self._i].arrival_ms <= now + 1e-9:
                r = self.reqs[self._i]
                self._i += 1
                if eng.admission is not None and not eng.admission.admit_token_stream(
                    r, now, eng.profile.vanilla_time(1)
                ):
                    resp = GenResponse(rid=r.rid, arrival_ms=r.arrival_ms,
                                       release_ms=[], exit_sites=[], tokens=[],
                                       final_tokens=[], worker=eng.wid,
                                       slo_ms=r.slo_ms, dropped=True)
                    self.responses.append(resp)
                    core.emit(now, self.pool, (r.rid, -1))
                    continue
                self.queue.append(r)
            if not self.slots and not self.queue:
                if self._i >= n:
                    break
                core.schedule(self.reqs[self._i].arrival_ms, self)  # idle
                return
            # admit queued requests into free slots (FCFS, step boundary)
            while self.queue and self.free:
                self._admit_one(self.queue.popleft(), core)
            if not self.slots:
                continue
            self._step(core)
            core.schedule(self._now, self)
            return

    def _step(self, core: EngineCore) -> None:
        """One engine step: chunked prefills co-scheduled with one decode
        step over the decoding slots (the legacy path is the special case
        of zero prefilling slots)."""
        eng = self.eng
        chunk_ms = self._prefill_chunks(core) if eng.cfg.prefill_chunk > 0 else 0.0
        sids = [s for s in sorted(self.slots) if self.slots[s]["resp"] is not None]
        B = len(sids)
        eng.peak_slots = max(eng.peak_slots, B)
        eng.slot_history.append(B)
        ctl = eng.controller
        act = sorted(ctl.active) if ctl is not None else []
        if B and eng.runner is not None and ctl is not None:
            labels, unc, finals = eng.runner.step(sids, act)
            dec = ctl.observe(labels, unc, finals)
            ex = np.asarray(dec.exit_sites, np.int64)
            released = np.asarray(dec.released_labels)
        else:
            finals = np.zeros(B, np.int64)
            ex = np.full(B, -1, np.int64)
            released = finals
        kv_now = self._pending_kv
        step_ms = eng.profile.decode_step_time(ex, act) + chunk_ms
        start = self._now
        end = start + kv_now + step_ms
        self._pending_kv = 0.0
        eng.kv_ms += kv_now
        # releases + next-step KV deferral, grouped by exit site so the
        # catch-up's weight traffic amortizes across this step's exits
        kv_by_site: Dict[int, int] = {}
        for j, sid in enumerate(sids):
            sl = self.slots[sid]
            site = int(ex[j])
            if site >= 0:
                off = release_offset(eng.profile, site, B, act)
                rel = min(start + kv_now + off, end)
            else:
                rel = end
            resp = sl["resp"]
            resp.release_ms.append(rel)
            resp.exit_sites.append(site)
            resp.tokens.append(int(released[j]))
            resp.final_tokens.append(int(finals[j]))
            eng.n_tokens += 1
            core.emit(rel, self.pool, (sl["req"].rid, len(resp.tokens) - 1))
            done = len(resp.tokens)
            if done >= sl["req"].n_tokens:
                self._finish(sid, core)  # slot reusable at the next step boundary
            elif eng.admission is not None and eng.admission.note_token(
                (eng.wid, sid, sl["req"].rid), rel - resp.release_ms[-2], sl["req"].slo_ms
            ):
                self._finish(sid, core, shed=True)  # doomed mid-stream: shed
            elif site >= 0:
                kv_by_site[site] = kv_by_site.get(site, 0) + 1
        # completed prefills release their first token at step end
        for sid in sorted(self.slots):
            sl = self.slots[sid]
            if sl["resp"] is not None or sl.get("pf_left", 1) > 0:
                continue
            r, tok = sl["req"], sl.pop("pf_tok")
            del sl["pf_left"], sl["pf_fed"]
            sl["resp"] = GenResponse(
                rid=r.rid, arrival_ms=r.arrival_ms, release_ms=[end],
                exit_sites=[-1], tokens=[tok], final_tokens=[tok],
                worker=eng.wid, slo_ms=r.slo_ms,
            )
            eng.n_tokens += 1
            core.emit(end, self.pool, (r.rid, 0))
            if r.n_tokens <= 1:
                self._finish(sid, core)
        for site, cnt in kv_by_site.items():
            self._pending_kv += eng.profile.kv_fill_cost(site, cnt)
        eng.busy_ms += kv_now + step_ms
        eng.n_steps += 1
        self._now = end

    def finalize(self) -> List[GenResponse]:
        self.eng.makespan_ms = self._now
        self.responses.sort(key=lambda r: r.rid)
        return self.responses
