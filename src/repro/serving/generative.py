"""Generative autoregressive decode serving (paper §5, Table 4).

Discrete-event engine over decode *steps*: each request is a
(prompt, n_tokens) pair that occupies one continuous-batching slot from
admission until its last token; finished requests free their slot
mid-run, and queued requests join at the next step boundary (slot-based
continuous batching).

Every step consults the replica's ``ApparateController`` with one ramp
record per in-flight token. A token that exits at ramp ``s``:

  * releases early within the step (the client sees it at its exit
    offset, not at step end);
  * lets the per-layer batch shrink — deeper layers run with fewer
    tokens, and a layer with zero alive tokens is skipped entirely
    (``LatencyProfile.decode_step_time``), which is where the paper's
    22.6–77.9% median time-per-token wins come from;
  * still owes the deeper layers its KV / recurrent state so FUTURE
    tokens can attend to it — the paper's hidden-state catch-up. That
    deferred ``kv_fill_cost`` is amortized into the NEXT decode step
    (grouped by exit site so weight traffic amortizes across the step's
    exits). Exits are never free; a request's LAST token owes nothing.

TTFT = queue wait + prefill; per-token TPT = successive release deltas —
the split `summarize_generative` reports.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.cluster import release_offset
from repro.serving.request import GenRequest, GenResponse


@dataclasses.dataclass
class GenerativeConfig:
    max_batch_size: int = 8  # continuous-batching decode slots
    # prefill cost per prompt token relative to a bs=1 decode step: prefill
    # is compute-dense (weights amortize over the whole prompt), so a prompt
    # token costs a fraction of a memory-bound decode step. Overridable per
    # engine via ``prefill_ms``.
    prefill_frac: float = 0.3


def offered_decode_qps(profile, *, max_batch_size: int, tokens_per_request: int,
                       load: float) -> float:
    """Request arrival rate (req/s) offering ``load`` of one generative
    replica's decode capacity: a fully-batched replica retires one request
    per ``tokens_per_request`` steps at the batched step time (batching
    amortizes memory-bound decode — sizing from ``vanilla_time(1)`` would
    look ~max_batch_size times lighter than intended)."""
    step = profile.vanilla_time(max_batch_size)
    return load * max_batch_size * 1000.0 / (tokens_per_request * step)


class GenerativeEngine:
    """One generative serving replica (the decode analogue of ``Worker``).

    ``runner``/``controller`` may both be None for the vanilla (no-EE)
    baseline: identical admission and batching, every token runs to
    completion, no ramp overhead, no KV catch-up.
    """

    def __init__(
        self,
        profile,
        cfg: Optional[GenerativeConfig] = None,
        runner=None,
        controller=None,
        *,
        wid: int = 0,
        prefill_ms: Optional[Callable[[int], float]] = None,
    ):
        self.profile = profile
        self.cfg = cfg or GenerativeConfig()
        if self.cfg.max_batch_size < 1:
            raise ValueError(f"max_batch_size must be >= 1, got {self.cfg.max_batch_size}")
        if (runner is None) != (controller is None):
            raise ValueError("runner and controller must be supplied together (or neither)")
        self.runner = runner
        self.controller = controller
        self.wid = wid
        self.prefill_ms = prefill_ms or (
            lambda plen: plen * self.cfg.prefill_frac * profile.vanilla_time(1)
        )
        # run stats
        self.makespan_ms = 0.0
        self.busy_ms = 0.0
        self.kv_ms = 0.0  # total deferred KV catch-up paid
        self.n_steps = 0
        self.n_tokens = 0
        self.peak_slots = 0
        self.slot_history: List[int] = []  # per-step batch sizes

    # -- event loop ----------------------------------------------------------

    def run(self, requests: Sequence[GenRequest]) -> List[GenResponse]:
        reqs = sorted(requests, key=lambda r: (r.arrival_ms, r.rid))
        queue: deque = deque()
        slots: Dict[int, dict] = {}  # slot id -> {req, resp}
        free = list(range(self.cfg.max_batch_size))
        responses: List[GenResponse] = []
        now, i, n = 0.0, 0, len(reqs)
        pending_kv = 0.0

        def finish(sid: int):
            sl = slots.pop(sid)
            free.append(sid)
            free.sort()
            if self.runner is not None:
                self.runner.free(sid)
            responses.append(sl["resp"])

        while i < n or queue or slots:
            while i < n and reqs[i].arrival_ms <= now + 1e-9:
                queue.append(reqs[i])
                i += 1
            if not slots and not queue:
                now = max(now, reqs[i].arrival_ms)  # idle: jump to next arrival
                continue
            # admit queued requests into free slots (FCFS, step boundary);
            # their prefills run before this step's decode launch
            while queue and free:
                r = queue.popleft()
                sid = free.pop(0)
                now += self.prefill_ms(r.prompt_len)
                tok = self.runner.start(sid, r.item) if self.runner is not None else 0
                resp = GenResponse(
                    rid=r.rid, arrival_ms=r.arrival_ms, release_ms=[now],
                    exit_sites=[-1], tokens=[tok], final_tokens=[tok],
                    worker=self.wid, slo_ms=r.slo_ms,
                )
                slots[sid] = {"req": r, "resp": resp}
                self.n_tokens += 1
                if r.n_tokens <= 1:
                    finish(sid)
            if not slots:
                continue
            # one decode step over the current slot set
            sids = sorted(slots)
            B = len(sids)
            self.peak_slots = max(self.peak_slots, B)
            self.slot_history.append(B)
            ctl = self.controller
            act = sorted(ctl.active) if ctl is not None else []
            if self.runner is not None and ctl is not None:
                labels, unc, finals = self.runner.step(sids, act)
                dec = ctl.observe(labels, unc, finals)
                ex = np.asarray(dec.exit_sites, np.int64)
                released = np.asarray(dec.released_labels)
            else:
                finals = np.zeros(B, np.int64)
                ex = np.full(B, -1, np.int64)
                released = finals
            kv_now = pending_kv
            step_ms = self.profile.decode_step_time(ex, act)
            start = now
            end = start + kv_now + step_ms
            pending_kv = 0.0
            self.kv_ms += kv_now
            # releases + next-step KV deferral, grouped by exit site so the
            # catch-up's weight traffic amortizes across this step's exits
            kv_by_site: Dict[int, int] = {}
            for j, sid in enumerate(sids):
                sl = slots[sid]
                site = int(ex[j])
                if site >= 0:
                    off = release_offset(self.profile, site, B, act)
                    rel = min(start + kv_now + off, end)
                else:
                    rel = end
                resp = sl["resp"]
                resp.release_ms.append(rel)
                resp.exit_sites.append(site)
                resp.tokens.append(int(released[j]))
                resp.final_tokens.append(int(finals[j]))
                self.n_tokens += 1
                done = len(resp.tokens)
                if done >= sl["req"].n_tokens:
                    finish(sid)  # slot reusable at the next step boundary
                elif site >= 0:
                    kv_by_site[site] = kv_by_site.get(site, 0) + 1
            for site, cnt in kv_by_site.items():
                pending_kv += self.profile.kv_fill_cost(site, cnt)
            self.busy_ms += kv_now + step_ms
            self.n_steps += 1
            now = end
        self.makespan_ms = now
        responses.sort(key=lambda r: r.rid)
        return responses

    def stats(self) -> Dict[str, float]:
        out = {
            "busy_ms": self.busy_ms,
            "kv_catchup_ms": self.kv_ms,
            "steps": float(self.n_steps),
            "tokens": float(self.n_tokens),
            "peak_slots": float(self.peak_slots),
            "mean_step_batch": float(np.mean(self.slot_history)) if self.slot_history else 0.0,
        }
        if self.controller is not None:
            out["ramp_overhead_ms"] = self.controller.total_ramp_overhead(1)
            out["active_ramps"] = float(len(self.controller.active))
        if self.runner is not None and hasattr(self.runner, "dispatches"):
            # accelerator dispatches issued by the runner across the run:
            # 1/step for the batched DecodeRunner, B/step for the per-slot
            # loop — the tension bench_decode_dispatch measures
            out["decode_dispatches"] = float(self.runner.dispatches)
        return out
