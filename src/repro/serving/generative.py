"""Generative autoregressive decode serving (paper §5, Table 4).

Discrete-event engine over decode *steps*: each request is a
(prompt, n_tokens) pair that occupies one continuous-batching slot from
admission until its last token; finished requests free their slot
mid-run, and queued requests join at the next step boundary (slot-based
continuous batching).

Every step consults the replica's ``ApparateController`` with one ramp
record per in-flight token. A token that exits at ramp ``s``:

  * releases early within the step (the client sees it at its exit
    offset, not at step end);
  * lets the per-layer batch shrink — deeper layers run with fewer
    tokens, and a layer with zero alive tokens is skipped entirely
    (``LatencyProfile.decode_step_time``), which is where the paper's
    22.6–77.9% median time-per-token wins come from;
  * still owes the deeper layers its KV / recurrent state so FUTURE
    tokens can attend to it — the paper's hidden-state catch-up. That
    deferred ``kv_fill_cost`` is amortized into the NEXT decode step
    (grouped by exit site so weight traffic amortizes across the step's
    exits). Exits are never free; a request's LAST token owes nothing.

The event loop itself lives in `repro.serving.engine`
(``GenerativeAdapter`` on the shared ``EngineCore``); this class is the
replica facade holding config, profile, runner/controller, and run
stats. Unification opened two capabilities the bespoke loop could not
express:

  * **chunked prefill** — ``GenerativeConfig.prefill_chunk > 0`` splits
    each prompt into chunks co-scheduled with in-flight decode steps
    (one chunk per prefilling slot per step), so TPT never stalls behind
    a monolithic prefill; ``DecodeRunner`` prefills the real slot cache
    incrementally via ``prefill_begin``/``prefill_resume``;
  * **SLO-aware admission** — an ``AdmissionPolicy``
    (`repro.serving.policies`) drops hopeless requests at admission and
    sheds doomed slots mid-stream (reported by ``summarize_generative``).

TTFT = queue wait + prefill; per-token TPT = successive release deltas —
the split `summarize_generative` reports.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import EngineCore, GenerativeAdapter
from repro.serving.request import GenRequest, GenResponse


@dataclasses.dataclass
class GenerativeConfig:
    max_batch_size: int = 8  # continuous-batching decode slots
    # prefill cost per prompt token relative to a bs=1 decode step: prefill
    # is compute-dense (weights amortize over the whole prompt), so a prompt
    # token costs a fraction of a memory-bound decode step. Overridable per
    # engine via ``prefill_ms``.
    prefill_frac: float = 0.3
    # > 0: chunked prefill — split each prompt into chunks of this many
    # tokens, co-scheduled with in-flight decode steps (0 = legacy serial
    # prefill at admission, which stalls the whole batch)
    prefill_chunk: int = 0
    # overload reaction when the paged KV pool exhausts mid-run:
    #   'none' — propagate PoolExhausted (legacy: pool sizing is a hard cap)
    #   'shed' — shed the slackest victim slot (its work is discarded)
    #   'swap' — swap the victim's KV blocks to a host buffer and readmit
    #            it when the pool drains; an AdmissionPolicy (if present)
    #            refines the choice per victim by SLO slack
    preempt: str = "none"
    # decode steps per controller sync (host round-trip). > 1 dispatches a
    # SYNC WINDOW: up to this many decode steps in one jitted while_loop
    # with exit decisions made on-device against a deliberately STALE
    # threshold copy; the window's packed records stream back at the sync
    # boundary and the controller replays every one of them, so
    # adaptation sees every token at most one window late. 1 = classic
    # per-step sync (bit-identical records either way — the equivalence
    # oracle the tests pin). Needs a runner exposing ``step_multi``;
    # others fall back to per-step.
    steps_per_sync: int = 1


def offered_decode_qps(profile, *, max_batch_size: int, tokens_per_request: int,
                       load: float) -> float:
    """Request arrival rate (req/s) offering ``load`` of one generative
    replica's decode capacity: a fully-batched replica retires one request
    per ``tokens_per_request`` steps at the batched step time (batching
    amortizes memory-bound decode — sizing from ``vanilla_time(1)`` would
    look ~max_batch_size times lighter than intended)."""
    step = profile.vanilla_time(max_batch_size)
    return load * max_batch_size * 1000.0 / (tokens_per_request * step)


class GenerativeEngine:
    """One generative serving replica (the decode analogue of ``Worker``).

    ``runner``/``controller`` may both be None for the vanilla (no-EE)
    baseline: identical admission and batching, every token runs to
    completion, no ramp overhead, no KV catch-up. ``admission`` is an
    optional ``AdmissionPolicy`` for SLO-aware drop/shed behavior.
    """

    def __init__(
        self,
        profile,
        cfg: Optional[GenerativeConfig] = None,
        runner=None,
        controller=None,
        *,
        wid: int = 0,
        prefill_ms: Optional[Callable[[int], float]] = None,
        admission=None,
    ):
        self.profile = profile
        self.cfg = cfg or GenerativeConfig()
        if self.cfg.max_batch_size < 1:
            raise ValueError(f"max_batch_size must be >= 1, got {self.cfg.max_batch_size}")
        if self.cfg.prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, got {self.cfg.prefill_chunk}")
        if self.cfg.preempt not in ("none", "swap", "shed"):
            raise ValueError(
                f"preempt must be 'none'|'swap'|'shed', got {self.cfg.preempt!r}"
            )
        if self.cfg.steps_per_sync < 1:
            raise ValueError(
                f"steps_per_sync must be >= 1, got {self.cfg.steps_per_sync}"
            )
        if (runner is None) != (controller is None):
            raise ValueError("runner and controller must be supplied together (or neither)")
        self.runner = runner
        self.controller = controller
        self.admission = admission
        self.wid = wid
        self.prefill_ms = prefill_ms or (
            lambda plen: plen * self.cfg.prefill_frac * profile.vanilla_time(1)
        )
        # run stats
        self.makespan_ms = 0.0
        self.busy_ms = 0.0
        self.kv_ms = 0.0  # total deferred KV catch-up paid
        self.chunk_ms = 0.0  # co-scheduled chunked-prefill time
        self.n_steps = 0
        self.n_tokens = 0
        self.n_windows = 0  # sync windows dispatched (step_multi runners)
        self.n_chunks = 0  # prefill chunks co-scheduled into steps
        self.n_shed = 0  # slots shed mid-stream by the admission policy
        self.n_preempt_swaps = 0  # pool-exhaustion victims swapped to host
        self.n_preempt_sheds = 0  # pool-exhaustion victims shed outright
        self.n_swap_ins = 0  # swapped streams readmitted
        self.peak_slots = 0
        self.slot_history: List[int] = []  # per-step decoding batch sizes
        self.core: Optional[EngineCore] = None  # last run's engine core

    # -- event loop (delegated to the unified engine core) -------------------

    def _make_adapter(self, requests: Sequence[GenRequest]) -> GenerativeAdapter:
        """The engine-core adapter for this replica (shared with
        ``MixedClusterSimulator``, which co-schedules several replicas on
        one core)."""
        return GenerativeAdapter(self, requests)

    def run(self, requests: Sequence[GenRequest]) -> List[GenResponse]:
        core = EngineCore()
        adapter = core.add(self._make_adapter(requests))
        core.run()
        self.core = core
        return adapter.finalize()

    def stats(self) -> Dict[str, float]:
        out = {
            "busy_ms": self.busy_ms,
            "kv_catchup_ms": self.kv_ms,
            "steps": float(self.n_steps),
            "tokens": float(self.n_tokens),
            "peak_slots": float(self.peak_slots),
            "mean_step_batch": float(np.mean(self.slot_history)) if self.slot_history else 0.0,
        }
        if self.cfg.prefill_chunk > 0:
            out["prefill_chunks"] = float(self.n_chunks)
            out["prefill_chunk_ms"] = self.chunk_ms
        if self.cfg.preempt != "none":
            out["preempt_swaps"] = float(self.n_preempt_swaps)
            out["preempt_sheds"] = float(self.n_preempt_sheds)
            out["swap_ins"] = float(self.n_swap_ins)
        if self.admission is not None:
            out["shed"] = float(self.n_shed)
            out.update({f"admission_{k}": v for k, v in self.admission.stats().items()})
        if self.controller is not None:
            out["ramp_overhead_ms"] = self.controller.total_ramp_overhead(1)
            out["active_ramps"] = float(len(self.controller.active))
        if self.n_windows:
            # host round-trips: one controller sync per window instead of
            # one per decode step (host_syncs / tokens is the bench metric)
            out["sync_windows"] = float(self.n_windows)
        if self.runner is not None and hasattr(self.runner, "dispatches"):
            # accelerator dispatches issued by the runner across the run:
            # 1/step for the batched DecodeRunner, B/step for the per-slot
            # loop — the tension bench_decode_dispatch measures
            out["decode_dispatches"] = float(self.runner.dispatches)
        return out
