from repro.serving.arrivals import maf_trace, video_trace
from repro.serving.cluster import (
    ClusterConfig,
    ClusterSimulator,
    Worker,
    get_dispatcher,
    release_offset,
)
from repro.serving.metrics import savings_vs, summarize, summarize_cluster
from repro.serving.platform import PlatformConfig, ServingSimulator, make_requests
from repro.serving.policies import BatchPolicy, get_policy
from repro.serving.request import Request, Response
from repro.serving.runner import ClassifierRunner, LMTokenRunner, SyntheticRunner

__all__ = [
    "maf_trace",
    "video_trace",
    "savings_vs",
    "summarize",
    "summarize_cluster",
    "PlatformConfig",
    "ServingSimulator",
    "ClusterConfig",
    "ClusterSimulator",
    "Worker",
    "get_dispatcher",
    "release_offset",
    "BatchPolicy",
    "get_policy",
    "make_requests",
    "Request",
    "Response",
    "ClassifierRunner",
    "LMTokenRunner",
    "SyntheticRunner",
]
