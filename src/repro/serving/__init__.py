from repro.serving.arrivals import maf_trace, video_trace
from repro.serving.metrics import savings_vs, summarize
from repro.serving.platform import PlatformConfig, ServingSimulator, make_requests
from repro.serving.request import Request, Response
from repro.serving.runner import ClassifierRunner, LMTokenRunner

__all__ = [
    "maf_trace",
    "video_trace",
    "savings_vs",
    "summarize",
    "PlatformConfig",
    "ServingSimulator",
    "make_requests",
    "Request",
    "Response",
    "ClassifierRunner",
    "LMTokenRunner",
]
