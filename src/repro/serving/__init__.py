from repro.serving.arrivals import maf_trace, video_trace
from repro.serving.cluster import (
    ClusterConfig,
    ClusterSimulator,
    MixedClusterSimulator,
    Worker,
    get_dispatcher,
    release_offset,
)
from repro.serving.engine import (
    ClassificationAdapter,
    EngineCore,
    GenerativeAdapter,
)
from repro.serving.generative import (
    GenerativeConfig,
    GenerativeEngine,
    offered_decode_qps,
)
from repro.serving.metrics import (
    savings_vs,
    summarize,
    summarize_cluster,
    summarize_generative,
)
from repro.serving.platform import PlatformConfig, ServingSimulator, make_requests
from repro.serving.policies import (
    AdmissionConfig,
    AdmissionPolicy,
    BatchPolicy,
    get_policy,
)
from repro.serving.reference import (
    ReferenceClusterSimulator,
    ReferenceGenerativeEngine,
    ReferenceMixedClusterSimulator,
)
from repro.serving.request import (
    GenRequest,
    GenResponse,
    Request,
    Response,
    make_gen_requests,
)
from repro.serving.runner import (
    BlockAllocator,
    ClassifierRunner,
    DecodeRunner,
    LMTokenRunner,
    LoopDecodeRunner,
    PoolExhausted,
    PrefixCache,
    ShardedDecodeRunner,
    SyntheticDecodeRunner,
    SyntheticRunner,
)

__all__ = [
    "maf_trace",
    "video_trace",
    "savings_vs",
    "summarize",
    "summarize_cluster",
    "summarize_generative",
    "PlatformConfig",
    "ServingSimulator",
    "ClusterConfig",
    "ClusterSimulator",
    "MixedClusterSimulator",
    "GenerativeConfig",
    "GenerativeEngine",
    "offered_decode_qps",
    "Worker",
    "get_dispatcher",
    "release_offset",
    "EngineCore",
    "ClassificationAdapter",
    "GenerativeAdapter",
    "AdmissionConfig",
    "AdmissionPolicy",
    "BatchPolicy",
    "get_policy",
    "ReferenceClusterSimulator",
    "ReferenceGenerativeEngine",
    "ReferenceMixedClusterSimulator",
    "make_requests",
    "make_gen_requests",
    "Request",
    "Response",
    "GenRequest",
    "GenResponse",
    "BlockAllocator",
    "PoolExhausted",
    "PrefixCache",
    "ClassifierRunner",
    "DecodeRunner",
    "LMTokenRunner",
    "LoopDecodeRunner",
    "ShardedDecodeRunner",
    "SyntheticRunner",
    "SyntheticDecodeRunner",
]
