"""Frozen pre-refactor serving loops — the facade-equivalence oracles.

When the three hand-rolled discrete-event loops were unified onto the
event-driven core (`repro.serving.engine`), the original loop bodies
moved here VERBATIM, following the PR 3/4 pattern (``LoopDecodeRunner``,
``tune_thresholds_reference``): the refactored facades must stay
bit-identical to these references, and
``tests/test_engine_equivalence.py`` fuzzes seeded arrival schedules
through both to prove it. Do not "improve" this module — its only value
is being exactly the pre-refactor behavior.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.cluster import ClusterConfig, Worker, get_dispatcher
from repro.serving.engine import release_offset
from repro.serving.request import GenRequest, GenResponse, Request, Response


class ReferenceClusterSimulator:
    """The pre-refactor N-worker discrete-event loop (PR 1), kept as the
    oracle the ``ClusterSimulator`` facade is fuzzed against."""

    def __init__(self, profile, cluster: Optional[ClusterConfig] = None, runner=None,
                 controllers: Optional[Sequence] = None):
        cluster = cluster or ClusterConfig()
        if cluster.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {cluster.n_workers}")
        if controllers is not None and len(controllers) != cluster.n_workers:
            raise ValueError(
                f"need one controller per worker: got {len(controllers)} "
                f"for {cluster.n_workers} workers"
            )
        self.profile = profile
        self.cfg = cluster
        self.workers = [
            Worker(i, profile, cluster.platform, runner,
                   controllers[i] if controllers is not None else None)
            for i in range(cluster.n_workers)
        ]
        self.dispatcher = get_dispatcher(cluster.dispatch)
        self.makespan_ms = 0.0

    def run(self, requests: List[Request]) -> List[Response]:
        workers = self.workers
        responses: List[Response] = []
        i, n = 0, len(requests)
        now = 0.0
        while i < n or any(w.queue for w in workers):
            # dispatch arrivals up to `now` (routing sees the state at arrival)
            while i < n and requests[i].arrival_ms <= now + 1e-9:
                self.dispatcher.pick(workers, requests[i], now).queue.append(requests[i])
                i += 1
            nxt = requests[i].arrival_ms if i < n else np.inf
            # let every free worker with queued requests act at `now`
            acted = False
            for w in workers:
                if not w.queue or now + 1e-9 < w.free_at:
                    continue
                batch = w.policy.form_batch(w.queue, now, nxt, w.exec_time)
                if batch is None:
                    continue
                acted = True
                if not batch:  # DROP sentinel: shed head-of-line request
                    r = w.queue.pop(0)
                    responses.append(
                        Response(r.rid, now, -1, -1, now - r.arrival_ms, 0, True,
                                 worker=w.wid, slo_ms=r.slo_ms)
                    )
                    continue
                del w.queue[: len(batch)]
                responses.extend(w.execute(batch, now))
            if acted:
                continue
            # advance to the next decision point: arrival, a busy worker
            # freeing up, or a waiting policy's timeout expiry
            cand = [nxt]
            for w in workers:
                if not w.queue:
                    continue
                if now < w.free_at:
                    cand.append(w.free_at)
                else:
                    cand.append(w.policy.next_wake(w.queue, now, nxt))
            t = min(cand)
            if not np.isfinite(t):
                break  # defensive: nothing can ever progress
            now = max(now, t)
        self.makespan_ms = max([now] + [w.free_at for w in workers])
        return responses

    def worker_stats(self) -> Dict[int, Dict[str, float]]:
        return {w.wid: w.stats() for w in self.workers}


class ReferenceGenerativeEngine:
    """The pre-refactor generative decode loop (PR 2), kept as the oracle
    the ``GenerativeEngine`` facade is fuzzed against."""

    def __init__(self, profile, cfg=None, runner=None, controller=None, *,
                 wid: int = 0, prefill_ms=None):
        from repro.serving.generative import GenerativeConfig

        self.profile = profile
        self.cfg = cfg or GenerativeConfig()
        if self.cfg.max_batch_size < 1:
            raise ValueError(f"max_batch_size must be >= 1, got {self.cfg.max_batch_size}")
        if (runner is None) != (controller is None):
            raise ValueError("runner and controller must be supplied together (or neither)")
        self.runner = runner
        self.controller = controller
        self.wid = wid
        self.prefill_ms = prefill_ms or (
            lambda plen: plen * self.cfg.prefill_frac * profile.vanilla_time(1)
        )
        self.makespan_ms = 0.0
        self.busy_ms = 0.0
        self.kv_ms = 0.0
        self.n_steps = 0
        self.n_tokens = 0
        self.peak_slots = 0
        self.slot_history: List[int] = []

    def run(self, requests: Sequence[GenRequest]) -> List[GenResponse]:
        reqs = sorted(requests, key=lambda r: (r.arrival_ms, r.rid))
        queue: deque = deque()
        slots: Dict[int, dict] = {}
        free = list(range(self.cfg.max_batch_size))
        responses: List[GenResponse] = []
        now, i, n = 0.0, 0, len(reqs)
        pending_kv = 0.0

        def finish(sid: int):
            sl = slots.pop(sid)
            free.append(sid)
            free.sort()
            if self.runner is not None:
                self.runner.free(sid)
            responses.append(sl["resp"])

        while i < n or queue or slots:
            while i < n and reqs[i].arrival_ms <= now + 1e-9:
                queue.append(reqs[i])
                i += 1
            if not slots and not queue:
                now = max(now, reqs[i].arrival_ms)  # idle: jump to next arrival
                continue
            while queue and free:
                r = queue.popleft()
                sid = free.pop(0)
                now += self.prefill_ms(r.prompt_len)
                tok = self.runner.start(sid, r.item) if self.runner is not None else 0
                resp = GenResponse(
                    rid=r.rid, arrival_ms=r.arrival_ms, release_ms=[now],
                    exit_sites=[-1], tokens=[tok], final_tokens=[tok],
                    worker=self.wid, slo_ms=r.slo_ms,
                )
                slots[sid] = {"req": r, "resp": resp}
                self.n_tokens += 1
                if r.n_tokens <= 1:
                    finish(sid)
            if not slots:
                continue
            sids = sorted(slots)
            B = len(sids)
            self.peak_slots = max(self.peak_slots, B)
            self.slot_history.append(B)
            ctl = self.controller
            act = sorted(ctl.active) if ctl is not None else []
            if self.runner is not None and ctl is not None:
                labels, unc, finals = self.runner.step(sids, act)
                dec = ctl.observe(labels, unc, finals)
                ex = np.asarray(dec.exit_sites, np.int64)
                released = np.asarray(dec.released_labels)
            else:
                finals = np.zeros(B, np.int64)
                ex = np.full(B, -1, np.int64)
                released = finals
            kv_now = pending_kv
            step_ms = self.profile.decode_step_time(ex, act)
            start = now
            end = start + kv_now + step_ms
            pending_kv = 0.0
            self.kv_ms += kv_now
            kv_by_site: Dict[int, int] = {}
            for j, sid in enumerate(sids):
                sl = slots[sid]
                site = int(ex[j])
                if site >= 0:
                    off = release_offset(self.profile, site, B, act)
                    rel = min(start + kv_now + off, end)
                else:
                    rel = end
                resp = sl["resp"]
                resp.release_ms.append(rel)
                resp.exit_sites.append(site)
                resp.tokens.append(int(released[j]))
                resp.final_tokens.append(int(finals[j]))
                self.n_tokens += 1
                done = len(resp.tokens)
                if done >= sl["req"].n_tokens:
                    finish(sid)
                elif site >= 0:
                    kv_by_site[site] = kv_by_site.get(site, 0) + 1
            for site, cnt in kv_by_site.items():
                pending_kv += self.profile.kv_fill_cost(site, cnt)
            self.busy_ms += kv_now + step_ms
            self.n_steps += 1
            now = end
        self.makespan_ms = now
        responses.sort(key=lambda r: r.rid)
        return responses


class ReferenceMixedClusterSimulator:
    """The pre-refactor mixed-pool frontend (PR 2): pools simulated fully
    independently, each on its own clock."""

    def __init__(self, cls_sim=None, gen_engines: Sequence = ()):
        if cls_sim is None and not gen_engines:
            raise ValueError("need at least one pool (cls_sim or gen_engines)")
        self.cls_sim = cls_sim
        self.gen_engines = list(gen_engines)
        self.makespan_ms = 0.0

    def run(self, cls_requests: Sequence[Request] = (), gen_requests: Sequence = ()):
        if cls_requests and self.cls_sim is None:
            raise ValueError("classification requests but no classification pool")
        if gen_requests and not self.gen_engines:
            raise ValueError("generative requests but no generative pool")
        cls_resp: List[Response] = (
            self.cls_sim.run(list(cls_requests)) if cls_requests else []
        )
        buckets: List[list] = [[] for _ in self.gen_engines]
        load = [0.0] * len(self.gen_engines)
        for r in sorted(gen_requests, key=lambda q: (q.arrival_ms, q.rid)):
            k = min(range(len(load)), key=lambda j: (load[j], j))
            buckets[k].append(r)
            load[k] += r.n_tokens
        gen_resp: List = []
        for k, eng in enumerate(self.gen_engines):
            rs = eng.run(buckets[k])
            for r in rs:
                r.worker = k
            gen_resp.extend(rs)
        gen_resp.sort(key=lambda r: r.rid)
        spans = [eng.makespan_ms for eng in self.gen_engines]
        if self.cls_sim is not None and cls_requests:
            spans.append(self.cls_sim.makespan_ms)
        self.makespan_ms = max(spans) if spans else 0.0
        return cls_resp, gen_resp
