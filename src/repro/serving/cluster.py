"""Scale-out serving facades over the unified engine core.

Generalizes the single-GPU ``ServingSimulator`` to N replicas: a
dispatcher routes each request to a worker at its arrival instant, and
every worker runs its own batching policy (`repro.serving.policies`)
plus — when serving with Apparate — its **own** ``ApparateController``
adapting from its own ramp-record stream. This mirrors the paper's
CPU/GPU controller split per replica: records never cross workers, so
threshold tuning and ramp adjustment stay an O(window) host-side loop
regardless of cluster size.

The event loop itself lives in `repro.serving.engine` (shared with the
generative decode adapter); ``ClusterSimulator`` and
``MixedClusterSimulator`` are thin facades that build a
``ClassificationAdapter`` (and, for the mixed case, generative adapters)
on ONE ``EngineCore`` — one heap, one clock — and stay bit-identical to
the pre-refactor loops (`repro.serving.reference`).

Dispatch strategies:

  * ``round_robin`` — arrival-order striping (the baseline most serving
    frontends ship);
  * ``jsq`` — join-shortest-queue on queued + in-flight requests;
  * ``slo_aware`` — earliest-estimated-completion: residual busy time +
    backlog batches at the worker's current (ramp-aware) batch latency,
    i.e. the replica most likely to meet this request's deadline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import ClassificationAdapter, EngineCore, GenerativeAdapter, release_offset  # noqa: F401  (release_offset re-exported)
from repro.serving.policies import PlatformConfig, get_policy
from repro.serving.request import Request, Response


@dataclasses.dataclass
class ClusterConfig:
    n_workers: int = 1
    dispatch: str = "round_robin"  # 'round_robin' | 'jsq' | 'slo_aware'
    platform: PlatformConfig = dataclasses.field(default_factory=PlatformConfig)
    # SLO-aware admission control (None = queue everything, the paper's
    # platforms): an AdmissionPolicy shared with the generative adapter
    admission: Optional[object] = None


class Worker:
    """One serving replica: its own queue, batching policy, and (optional)
    Apparate controller fed exclusively by this replica's batches."""

    def __init__(self, wid: int, profile, platform: PlatformConfig, runner=None, controller=None):
        self.wid = wid
        self.profile = profile
        self.policy = get_policy(platform)
        self.runner = runner
        self.controller = controller
        self.queue: List[Request] = []
        self.free_at = 0.0
        self.busy_ms = 0.0
        self.n_batches = 0
        self.n_served = 0
        self.inflight_bs = 0  # size of the batch executing until free_at

    def exec_time(self, bs: int) -> float:
        t = self.profile.vanilla_time(bs)
        if self.controller is not None:
            t += self.controller.total_ramp_overhead(bs)
        return t

    def backlog_eta(self, now: float) -> float:
        """Estimated completion delay for a request enqueued at ``now``."""
        mbs = self.policy.cfg.max_batch_size
        q = len(self.queue) + 1
        n_batches = -(-q // mbs)
        return max(self.free_at - now, 0.0) + n_batches * self.exec_time(min(q, mbs))

    def execute(self, batch: List[Request], start: float) -> List[Response]:
        bs = len(batch)
        t_exec = self.exec_time(bs)
        self.free_at = start + t_exec
        self.busy_ms += t_exec
        self.n_batches += 1
        self.n_served += bs
        self.inflight_bs = bs
        ctl = self.controller
        out: List[Response] = []
        if self.runner is None or ctl is None:
            for r in batch:
                out.append(
                    Response(r.rid, start + t_exec, 0, -1, start + t_exec - r.arrival_ms,
                             bs, worker=self.wid, slo_ms=r.slo_ms)
                )
            return out
        items = np.asarray([r.item for r in batch])
        active = sorted(ctl.active)
        ramp_labels, ramp_unc, final_labels = self.runner.infer(items, active)
        dec = ctl.observe(ramp_labels, ramp_unc, final_labels)
        for j, r in enumerate(batch):
            site = int(dec.exit_sites[j])
            off = release_offset(self.profile, site, bs, active) if site >= 0 else t_exec
            rel = start + off
            out.append(
                Response(r.rid, rel, int(dec.released_labels[j]), site, rel - r.arrival_ms,
                         bs, worker=self.wid, slo_ms=r.slo_ms)
            )
        return out

    def stats(self) -> Dict[str, float]:
        out = {
            "busy_ms": self.busy_ms,
            "batches": float(self.n_batches),
            "served": float(self.n_served),
            "mean_batch": self.n_served / self.n_batches if self.n_batches else 0.0,
        }
        if self.controller is not None:
            out["ramp_overhead_ms"] = self.controller.total_ramp_overhead(1)
            out["active_ramps"] = float(len(self.controller.active))
        return out


class Dispatcher:
    name = "base"

    def pick(self, workers: List[Worker], req: Request, now: float) -> Worker:
        raise NotImplementedError


class RoundRobinDispatcher(Dispatcher):
    name = "round_robin"

    def __init__(self):
        self._next = 0

    def pick(self, workers, req, now):
        w = workers[self._next % len(workers)]
        self._next += 1
        return w


class JSQDispatcher(Dispatcher):
    """Join-shortest-queue on queued + in-flight requests."""

    name = "jsq"

    def pick(self, workers, req, now):
        return min(
            workers,
            key=lambda w: (
                len(w.queue) + (w.inflight_bs if w.free_at > now + 1e-9 else 0),
                w.wid,
            ),
        )


class SLOAwareDispatcher(Dispatcher):
    """Earliest-estimated-completion routing (ramp-aware batch latency)."""

    name = "slo_aware"

    def pick(self, workers, req, now):
        return min(workers, key=lambda w: (w.backlog_eta(now), w.wid))


DISPATCHERS = {
    d.name: d for d in (RoundRobinDispatcher, JSQDispatcher, SLOAwareDispatcher)
}


def get_dispatcher(name: str) -> Dispatcher:
    try:
        return DISPATCHERS[name]()
    except KeyError:
        raise ValueError(f"unknown dispatch strategy {name!r}; have {sorted(DISPATCHERS)}")


class ClusterSimulator:
    """N-worker serving facade over the unified engine core.

    ``controllers`` — one per worker (each replica adapts independently),
    or ``None`` for vanilla serving. The runner is shared: it is a pure
    batch→records function, so replicas reuse its compile cache the way
    replicas of one model reuse a compiled executable.
    """

    def __init__(self, profile, cluster: Optional[ClusterConfig] = None, runner=None,
                 controllers: Optional[Sequence] = None):
        cluster = cluster or ClusterConfig()
        if cluster.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {cluster.n_workers}")
        if controllers is not None and len(controllers) != cluster.n_workers:
            raise ValueError(
                f"need one controller per worker: got {len(controllers)} "
                f"for {cluster.n_workers} workers"
            )
        self.profile = profile
        self.cfg = cluster
        self.workers = [
            Worker(i, profile, cluster.platform, runner,
                   controllers[i] if controllers is not None else None)
            for i in range(cluster.n_workers)
        ]
        self.dispatcher = get_dispatcher(cluster.dispatch)
        self.makespan_ms = 0.0
        self.core: Optional[EngineCore] = None  # last run's engine core

    def _make_adapter(self, requests: Sequence[Request]) -> ClassificationAdapter:
        """The engine-core adapter over THIS simulator's workers/dispatcher
        (shared with ``MixedClusterSimulator``, which co-schedules it with
        generative adapters on one core)."""
        return ClassificationAdapter(self.workers, self.dispatcher, requests,
                                     admission=self.cfg.admission)

    def run(self, requests: List[Request]) -> List[Response]:
        core = EngineCore()
        adapter = core.add(self._make_adapter(requests))
        core.run()
        self.core = core
        self.makespan_ms = adapter.makespan()
        return adapter.responses

    def worker_stats(self) -> Dict[int, Dict[str, float]]:
        return {w.wid: w.stats() for w in self.workers}


class MixedClusterSimulator:
    """Heterogeneous replica pools in one cluster: classification workers
    (a ``ClusterSimulator``) + generative decode replicas
    (``GenerativeEngine`` from ``repro.serving.generative``) behind one
    frontend — the ROADMAP's CV/NLP/generative mixture.

    Replicas share nothing (a generative replica holds an LM plus its KV
    slots, a classification replica its classifier), but since the
    unification all pools run on ONE ``EngineCore``: a single event heap
    and a single monotone clock, so cross-pool event interleavings are
    globally time-ordered (``self.core.completions``) instead of each
    pool living on its own clock — the property the pre-refactor
    independent-pool frontend could never even observe. Per-pool results
    are unchanged (pools still share no state).

    Generative dispatch is arrival-order greedy on outstanding token work
    (the decode analogue of join-shortest-queue: queued tokens, not queued
    requests, measure a generative replica's backlog).
    """

    def __init__(self, cls_sim: Optional[ClusterSimulator] = None,
                 gen_engines: Sequence = ()):
        if cls_sim is None and not gen_engines:
            raise ValueError("need at least one pool (cls_sim or gen_engines)")
        self.cls_sim = cls_sim
        self.gen_engines = list(gen_engines)
        self.makespan_ms = 0.0
        self.core: Optional[EngineCore] = None  # last run's shared engine core

    def run(self, cls_requests: Sequence[Request] = (), gen_requests: Sequence = ()):
        """Returns (classification Responses, GenResponses)."""
        if cls_requests and self.cls_sim is None:
            raise ValueError("classification requests but no classification pool")
        if gen_requests and not self.gen_engines:
            raise ValueError("generative requests but no generative pool")
        core = EngineCore()
        cls_adapter = None
        if cls_requests:
            cls_adapter = core.add(self.cls_sim._make_adapter(list(cls_requests)))
        buckets: List[list] = [[] for _ in self.gen_engines]
        load = [0.0] * len(self.gen_engines)
        for r in sorted(gen_requests, key=lambda q: (q.arrival_ms, q.rid)):
            k = min(range(len(load)), key=lambda j: (load[j], j))
            buckets[k].append(r)
            load[k] += r.n_tokens
        gen_adapters = [
            core.add(GenerativeAdapter(eng, buckets[k]))
            for k, eng in enumerate(self.gen_engines)
        ]
        core.run()
        self.core = core
        cls_resp: List[Response] = []
        if cls_adapter is not None:
            cls_resp = cls_adapter.responses
            self.cls_sim.core = core
            self.cls_sim.makespan_ms = cls_adapter.makespan()
        gen_resp: List = []
        for k, ad in enumerate(gen_adapters):
            rs = ad.finalize()
            for r in rs:
                r.worker = k
            gen_resp.extend(rs)
        gen_resp.sort(key=lambda r: r.rid)
        spans = [eng.makespan_ms for eng in self.gen_engines]
        if self.cls_sim is not None and cls_requests:
            spans.append(self.cls_sim.makespan_ms)
        self.makespan_ms = max(spans) if spans else 0.0
        return cls_resp, gen_resp
