"""Batching-policy strategy layer.

The serving platforms the paper runs atop differ only in *batch
formation*; everything else (queueing, execution, release) is shared.
Each policy answers two questions for one worker:

  * ``form_batch`` — given the worker's queue at time ``now``, either
    return the batch to launch (a request list), the ``DROP`` sentinel
    (clockwork sheds a hopeless head-of-line request), or ``None``
    (keep waiting);
  * ``next_wake`` — when waiting, the next instant at which the
    decision could change (arrival or timeout expiry).

Policies are pure and per-worker, so the N-worker cluster engine
(`repro.serving.cluster`) instantiates one per worker and the 1-worker
``ServingSimulator`` stays a special case of the same code path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass
class PlatformConfig:
    policy: str = "clockwork"  # 'clockwork' | 'tfserve'
    max_batch_size: int = 16
    batch_timeout_ms: float = 5.0
    drop_on_slo_miss: bool = False  # clockwork drops hopeless requests


#: sentinel returned by ``form_batch``: drop the head-of-line request.
DROP: List[Request] = []


class BatchPolicy:
    """One worker's batch-formation strategy."""

    name = "base"

    def __init__(self, cfg: PlatformConfig):
        self.cfg = cfg

    def form_batch(
        self,
        queue: List[Request],
        now: float,
        next_arrival_ms: float,
        exec_time: Callable[[int], float],
    ) -> Optional[List[Request]]:
        raise NotImplementedError

    def next_wake(self, queue: List[Request], now: float, next_arrival_ms: float) -> float:
        """Earliest future time a waiting decision could change."""
        return next_arrival_ms


class TFServePolicy(BatchPolicy):
    """Tunable ``max_batch_size`` / ``batch_timeout_ms`` knobs (paper Fig 3)."""

    name = "tfserve"

    def form_batch(self, queue, now, next_arrival_ms, exec_time):
        cfg = self.cfg
        if len(queue) >= cfg.max_batch_size:
            return queue[: cfg.max_batch_size]
        oldest_wait = now - queue[0].arrival_ms
        if oldest_wait + 1e-9 >= cfg.batch_timeout_ms:
            return queue[: cfg.max_batch_size]
        if not np.isfinite(next_arrival_ms):  # no more arrivals: flush
            return queue[: cfg.max_batch_size]
        return None

    def next_wake(self, queue, now, next_arrival_ms):
        return min(next_arrival_ms, queue[0].arrival_ms + self.cfg.batch_timeout_ms)


class ClockworkPolicy(BatchPolicy):
    """Work-conserving, SLO-aware max-batch selection with drop-on-miss
    (paper §2.1): the largest batch whose completion meets the earliest
    deadline among its members."""

    name = "clockwork"

    def form_batch(self, queue, now, next_arrival_ms, exec_time):
        cfg = self.cfg
        cap = min(len(queue), cfg.max_batch_size)
        for b in range(cap, 0, -1):
            dl = min(q.arrival_ms + q.slo_ms for q in queue[:b])
            if now + exec_time(b) <= dl + 1e-9:
                return queue[:b]
        if cfg.drop_on_slo_miss:
            return DROP  # shed hopeless head-of-line request
        return queue[:1]  # serve anyway (degraded)


@dataclasses.dataclass
class AdmissionConfig:
    """Knobs for SLO-aware admission (shared by both workload adapters)."""

    slack: float = 1.0  # deadline multiplier before a request is hopeless
    shed_after: int = 3  # consecutive SLO-violating tokens before a shed
    drop_on_admit: bool = True  # shed hopeless requests at admission
    shed_mid_stream: bool = True  # shed doomed generative slots mid-run


class AdmissionPolicy:
    """SLO-aware admission shared by the classification and generative
    adapters of the unified engine (`repro.serving.engine`).

    The paper's platforms only shed *after* queueing (clockwork's
    drop-on-miss); InferLine/SuperServe-style serving sheds at admission,
    before a hopeless request wastes queue or slot capacity:

      * classification (request granularity): a request whose earliest
        estimated completion on its routed worker — residual busy time +
        backlog, the same estimate ``slo_aware`` dispatch ranks by —
        already misses ``arrival + slack * slo`` is dropped at arrival;
      * generative admission (stream granularity): a request whose
        per-token SLO is tighter than even an unbatched decode step can
        ever meet is dropped instead of occupying a slot;
      * generative mid-stream (token granularity): a live slot whose
        observed per-token latency has violated its SLO for
        ``shed_after`` consecutive tokens is shed at the next step
        boundary, freeing the slot for admissible work (the partial
        response is marked ``shed`` and reported by
        ``summarize_generative``).
    """

    def __init__(self, cfg: Optional[AdmissionConfig] = None):
        self.cfg = cfg or AdmissionConfig()
        if self.cfg.shed_after < 1:
            raise ValueError(f"shed_after must be >= 1, got {self.cfg.shed_after}")
        self.n_admit_drops = 0
        self.n_sheds = 0
        self._viol: dict = {}  # stream key -> consecutive SLO violations

    def admit_request(self, req, now: float, eta_ms: float) -> bool:
        """Classification: False = drop (projected completion misses the
        deadline even on the best-estimate worker)."""
        if not self.cfg.drop_on_admit or not np.isfinite(req.slo_ms):
            return True
        if now + eta_ms <= req.arrival_ms + self.cfg.slack * req.slo_ms + 1e-9:
            return True
        self.n_admit_drops += 1
        return False

    def admit_token_stream(self, req, now: float, best_step_ms: float) -> bool:
        """Generative: False = drop (the per-token SLO is tighter than the
        best achievable step time — the stream is doomed before it starts)."""
        if not self.cfg.drop_on_admit or not np.isfinite(req.slo_ms):
            return True
        if best_step_ms <= self.cfg.slack * req.slo_ms + 1e-9:
            return True
        self.n_admit_drops += 1
        return False

    def note_token(self, key, tpt_ms: float, slo_ms: float) -> bool:
        """Generative mid-stream: record one decode token's TPT sample for
        stream ``key``; True = shed the slot now (``shed_after``
        consecutive violations)."""
        if not self.cfg.shed_mid_stream or not np.isfinite(slo_ms):
            return False
        if tpt_ms <= self.cfg.slack * slo_ms + 1e-9:
            self._viol.pop(key, None)
            return False
        n = self._viol.get(key, 0) + 1
        if n >= self.cfg.shed_after:
            self._viol.pop(key, None)
            self.n_sheds += 1
            return True
        self._viol[key] = n
        return False

    def preempt_stream(self, req, now: float, best_step_ms: float) -> str:
        """Generative overload: the KV pool is exhausted and ``req``'s slot
        was chosen as the preemption victim — pick the reaction by SLO
        slack (InferLine's currency, SuperServe's reactive fine-grained
        overload handling). A stream whose per-token SLO a best-case step
        still meets has slack to absorb a swap round-trip, so its work is
        preserved ('swap'); a stream already doomed against its SLO frees
        the pool permanently ('shed')."""
        if not np.isfinite(req.slo_ms):
            return "swap"  # no deadline: never discard work
        if best_step_ms <= self.cfg.slack * req.slo_ms + 1e-9:
            return "swap"
        return "shed"

    def forget(self, key) -> None:
        """Drop stream ``key``'s violation streak. The engine calls this
        when a stream ends (finish or shed): ``(wid, slot, rid)`` keys
        repeat across runs and slot reuse, so a streak left behind by a
        stream that ended mid-streak must not be inherited by the next
        stream with the same key."""
        self._viol.pop(key, None)

    def stats(self) -> dict:
        return {"admit_drops": float(self.n_admit_drops),
                "sheds": float(self.n_sheds)}


POLICIES = {
    TFServePolicy.name: TFServePolicy,
    ClockworkPolicy.name: ClockworkPolicy,
}


def get_policy(cfg: PlatformConfig) -> BatchPolicy:
    try:
        return POLICIES[cfg.policy](cfg)
    except KeyError:
        raise ValueError(f"unknown platform policy {cfg.policy!r}; have {sorted(POLICIES)}")
