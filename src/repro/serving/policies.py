"""Batching-policy strategy layer.

The serving platforms the paper runs atop differ only in *batch
formation*; everything else (queueing, execution, release) is shared.
Each policy answers two questions for one worker:

  * ``form_batch`` — given the worker's queue at time ``now``, either
    return the batch to launch (a request list), the ``DROP`` sentinel
    (clockwork sheds a hopeless head-of-line request), or ``None``
    (keep waiting);
  * ``next_wake`` — when waiting, the next instant at which the
    decision could change (arrival or timeout expiry).

Policies are pure and per-worker, so the N-worker cluster engine
(`repro.serving.cluster`) instantiates one per worker and the 1-worker
``ServingSimulator`` stays a special case of the same code path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass
class PlatformConfig:
    policy: str = "clockwork"  # 'clockwork' | 'tfserve'
    max_batch_size: int = 16
    batch_timeout_ms: float = 5.0
    drop_on_slo_miss: bool = False  # clockwork drops hopeless requests


#: sentinel returned by ``form_batch``: drop the head-of-line request.
DROP: List[Request] = []


class BatchPolicy:
    """One worker's batch-formation strategy."""

    name = "base"

    def __init__(self, cfg: PlatformConfig):
        self.cfg = cfg

    def form_batch(
        self,
        queue: List[Request],
        now: float,
        next_arrival_ms: float,
        exec_time: Callable[[int], float],
    ) -> Optional[List[Request]]:
        raise NotImplementedError

    def next_wake(self, queue: List[Request], now: float, next_arrival_ms: float) -> float:
        """Earliest future time a waiting decision could change."""
        return next_arrival_ms


class TFServePolicy(BatchPolicy):
    """Tunable ``max_batch_size`` / ``batch_timeout_ms`` knobs (paper Fig 3)."""

    name = "tfserve"

    def form_batch(self, queue, now, next_arrival_ms, exec_time):
        cfg = self.cfg
        if len(queue) >= cfg.max_batch_size:
            return queue[: cfg.max_batch_size]
        oldest_wait = now - queue[0].arrival_ms
        if oldest_wait + 1e-9 >= cfg.batch_timeout_ms:
            return queue[: cfg.max_batch_size]
        if not np.isfinite(next_arrival_ms):  # no more arrivals: flush
            return queue[: cfg.max_batch_size]
        return None

    def next_wake(self, queue, now, next_arrival_ms):
        return min(next_arrival_ms, queue[0].arrival_ms + self.cfg.batch_timeout_ms)


class ClockworkPolicy(BatchPolicy):
    """Work-conserving, SLO-aware max-batch selection with drop-on-miss
    (paper §2.1): the largest batch whose completion meets the earliest
    deadline among its members."""

    name = "clockwork"

    def form_batch(self, queue, now, next_arrival_ms, exec_time):
        cfg = self.cfg
        cap = min(len(queue), cfg.max_batch_size)
        for b in range(cap, 0, -1):
            dl = min(q.arrival_ms + q.slo_ms for q in queue[:b])
            if now + exec_time(b) <= dl + 1e-9:
                return queue[:b]
        if cfg.drop_on_slo_miss:
            return DROP  # shed hopeless head-of-line request
        return queue[:1]  # serve anyway (degraded)


POLICIES = {
    TFServePolicy.name: TFServePolicy,
    ClockworkPolicy.name: ClockworkPolicy,
}


def get_policy(cfg: PlatformConfig) -> BatchPolicy:
    try:
        return POLICIES[cfg.policy](cfg)
    except KeyError:
        raise ValueError(f"unknown platform policy {cfg.policy!r}; have {sorted(POLICIES)}")
