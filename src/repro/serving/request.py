"""Serving request/response records (classification + generative)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    arrival_ms: float
    slo_ms: float
    item: int  # index into the workload stream


@dataclasses.dataclass
class Response:
    rid: int
    release_ms: float
    label: int
    exit_site: int  # -1 = full model
    latency_ms: float
    batch_size: int
    dropped: bool = False
    worker: int = 0  # serving replica that handled the request
    slo_ms: float = float("nan")  # copied from the request (goodput accounting)


@dataclasses.dataclass
class GenRequest:
    """Generative request: decode ``n_tokens`` from ``item``'s prompt.
    ``slo_ms`` is a per-token (TPT) SLO — the paper's generative unit."""

    rid: int
    arrival_ms: float
    slo_ms: float
    item: int  # index into the prompt stream
    prompt_len: int
    n_tokens: int  # tokens to generate (incl. the prefill token)


@dataclasses.dataclass
class GenResponse:
    """One served generative request: per-token release times / exit sites /
    released tokens, plus the original model's greedy tokens for agreement
    accounting. ``release_ms[0]`` is the first (prefill) token: TTFT =
    release_ms[0] - arrival_ms; TPT samples are diff(release_ms)."""

    rid: int
    arrival_ms: float
    release_ms: List[float]
    exit_sites: List[int]  # per token; -1 = full model
    tokens: List[int]  # released (possibly ramp) tokens
    final_tokens: List[int]  # original-model greedy tokens
    worker: int = 0
    slo_ms: float = float("nan")
    dropped: bool = False  # shed at admission (SLO-aware admission policy)
    shed: bool = False  # shed mid-stream (doomed slot; partial tokens kept)

    @property
    def ttft_ms(self) -> float:
        return self.release_ms[0] - self.arrival_ms

    @property
    def tpt_ms(self) -> np.ndarray:
        return np.diff(np.asarray(self.release_ms))


def make_gen_requests(
    arrivals: np.ndarray,
    *,
    n_tokens,
    prompt_len: int,
    slo_ms: float,
    items: Optional[Sequence[int]] = None,
) -> List[GenRequest]:
    """``n_tokens`` may be a scalar or a per-request array."""
    nt = np.broadcast_to(np.asarray(n_tokens, np.int64), (len(arrivals),))
    items = items if items is not None else np.arange(len(arrivals))
    return [
        GenRequest(rid=k, arrival_ms=float(t), slo_ms=slo_ms, item=int(items[k]),
                   prompt_len=prompt_len, n_tokens=int(nt[k]))
        for k, t in enumerate(arrivals)
    ]
