"""Serving request/response records."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class Request:
    rid: int
    arrival_ms: float
    slo_ms: float
    item: int  # index into the workload stream


@dataclasses.dataclass
class Response:
    rid: int
    release_ms: float
    label: int
    exit_site: int  # -1 = full model
    latency_ms: float
    batch_size: int
    dropped: bool = False
    worker: int = 0  # serving replica that handled the request
    slo_ms: float = float("nan")  # copied from the request (goodput accounting)
