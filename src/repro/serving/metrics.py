"""Serving metrics: latency percentiles, throughput, goodput (on-time
completions/sec), accuracy-vs-original — per worker and cluster-wide."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Response


def summarize(
    responses: List[Response],
    *,
    vanilla_labels: Optional[np.ndarray] = None,
    horizon_ms: Optional[float] = None,
) -> Dict[str, float]:
    ok = [r for r in responses if not r.dropped]
    lat = np.asarray([r.latency_ms for r in ok])
    out = {
        "n": float(len(responses)),
        "dropped": float(sum(r.dropped for r in responses)),
        "p25_ms": float(np.percentile(lat, 25)) if len(lat) else np.nan,
        "p50_ms": float(np.percentile(lat, 50)) if len(lat) else np.nan,
        "p95_ms": float(np.percentile(lat, 95)) if len(lat) else np.nan,
        "p99_ms": float(np.percentile(lat, 99)) if len(lat) else np.nan,
        "mean_batch": float(np.mean([r.batch_size for r in ok])) if ok else np.nan,
        "exit_rate": float(np.mean([r.exit_site >= 0 for r in ok])) if ok else 0.0,
    }
    if ok:
        span = (
            horizon_ms
            if horizon_ms is not None
            else max(r.release_ms for r in ok) - min(0.0, min(r.release_ms for r in ok))
        )
        out["throughput_qps"] = len(ok) / max(span / 1000.0, 1e-9)
        slo = np.asarray([r.slo_ms for r in ok])
        if np.isfinite(slo).all():
            on_time = lat <= slo + 1e-9
            out["goodput_qps"] = float(on_time.sum()) / max(span / 1000.0, 1e-9)
            # misses count drops too: a shed request is a violated SLO
            out["slo_miss_rate"] = 1.0 - float(on_time.sum()) / max(len(responses), 1)
    if vanilla_labels is not None and ok:
        # accuracy = agreement with the original model's label (paper metric)
        agree = [r.label == vanilla_labels[r.rid] for r in ok]
        out["accuracy"] = float(np.mean(agree))
    return out


def summarize_cluster(
    responses: List[Response],
    *,
    vanilla_labels: Optional[np.ndarray] = None,
    horizon_ms: Optional[float] = None,
    n_workers: Optional[int] = None,
) -> Dict[str, object]:
    """Aggregate + per-worker summaries over one cluster run.

    Per-worker throughput/goodput use the *shared* horizon (the cluster
    run's span), so worker rates sum to the aggregate rate instead of
    each worker normalizing by its own last release. Pass ``n_workers``
    (the cluster size) explicitly — under light load an idle replica
    answers nothing and would be invisible in the responses.
    """
    ok = [r for r in responses if not r.dropped]
    span = (
        horizon_ms
        if horizon_ms is not None
        else (max(r.release_ms for r in ok) - min(0.0, min(r.release_ms for r in ok)) if ok else None)
    )
    agg = summarize(responses, vanilla_labels=vanilla_labels, horizon_ms=span)
    by_worker: Dict[int, List[Response]] = {}
    for r in responses:
        by_worker.setdefault(r.worker, []).append(r)
    agg["n_workers"] = float(n_workers if n_workers is not None else len(by_worker))
    return {
        "aggregate": agg,
        "workers": {
            w: summarize(rs, vanilla_labels=vanilla_labels, horizon_ms=span)
            for w, rs in sorted(by_worker.items())
        },
    }


def savings_vs(base: Dict[str, float], ours: Dict[str, float]) -> Dict[str, float]:
    out = {}
    for k in ("p25_ms", "p50_ms", "p95_ms", "p99_ms"):
        if np.isfinite(base.get(k, np.nan)) and np.isfinite(ours.get(k, np.nan)):
            out[k.replace("_ms", "_win_pct")] = 100.0 * (base[k] - ours[k]) / base[k]
    if base.get("throughput_qps") and ours.get("throughput_qps"):
        out["throughput_delta_pct"] = (
            100.0 * (ours["throughput_qps"] - base["throughput_qps"]) / base["throughput_qps"]
        )
    return out
