"""Serving metrics: latency percentiles, throughput, goodput (on-time
completions/sec), accuracy-vs-original — per worker and cluster-wide.

The percentile/span/rate plumbing is shared by every summary
(``summarize``, ``summarize_cluster``, ``summarize_generative``) via the
``_percentile_block`` / ``_span_ms`` / ``_per_sec`` helpers below, with
the NaN-proofing contract from PR 4 kept: an empty stream never produces
NaN where a downstream win%/JSON consumer would choke (generative
percentiles pin 0.0; the classification summary keeps its historical
NaN sentinels for empty latency sets).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Response


def _percentile_block(values, spec: Dict[str, float], empty: float) -> Dict[str, float]:
    """Shared percentile plumbing: ``spec`` maps output key -> percentile.
    An empty stream yields ``empty`` for every key (np.nan for the
    classification summary's historical sentinels, 0.0 for the NaN-proof
    generative keys)."""
    vals = np.asarray(values, float)
    if vals.size == 0:
        return {key: empty for key in spec}
    return {key: float(np.percentile(vals, q)) for key, q in spec.items()}


def _span_ms(horizon_ms: Optional[float], last: float, earliest: float) -> float:
    """Shared horizon plumbing: an explicit horizon wins; otherwise the
    stream spans from 0 (or ``earliest``, if negative) to ``last``."""
    return horizon_ms if horizon_ms is not None else last - min(0.0, earliest)


def _per_sec(count: float, span_ms: float) -> float:
    """Rate over a span. A zero (or degenerate negative) span yields 0.0:
    a single-instant stream has no meaningful rate, and the old
    ``count / max(span, 1e-9)`` guard turned it into an astronomically
    large bogus value. Clean under ``np.errstate(raise)`` — no inf/NaN."""
    if span_ms <= 0.0:
        return 0.0
    return float(count) / (float(span_ms) / 1000.0)


def summarize(
    responses: List[Response],
    *,
    vanilla_labels: Optional[np.ndarray] = None,
    horizon_ms: Optional[float] = None,
) -> Dict[str, float]:
    ok = [r for r in responses if not r.dropped]
    lat = np.asarray([r.latency_ms for r in ok])
    out = {
        "n": float(len(responses)),
        "dropped": float(sum(r.dropped for r in responses)),
        **_percentile_block(
            lat, {"p25_ms": 25, "p50_ms": 50, "p95_ms": 95, "p99_ms": 99}, np.nan
        ),
        "mean_batch": float(np.mean([r.batch_size for r in ok])) if ok else np.nan,
        "exit_rate": float(np.mean([r.exit_site >= 0 for r in ok])) if ok else 0.0,
    }
    if ok:
        span = _span_ms(horizon_ms, max(r.release_ms for r in ok),
                        min(r.release_ms for r in ok))
        out["throughput_qps"] = _per_sec(len(ok), span)
        slo = np.asarray([r.slo_ms for r in ok])
        if np.isfinite(slo).all():
            on_time = lat <= slo + 1e-9
            out["goodput_qps"] = _per_sec(float(on_time.sum()), span)
            # misses count drops too: a shed request is a violated SLO
            out["slo_miss_rate"] = 1.0 - float(on_time.sum()) / max(len(responses), 1)
    if vanilla_labels is not None and ok:
        # accuracy = agreement with the original model's label (paper metric)
        agree = [r.label == vanilla_labels[r.rid] for r in ok]
        out["accuracy"] = float(np.mean(agree))
    return out


def summarize_cluster(
    responses: List[Response],
    *,
    vanilla_labels: Optional[np.ndarray] = None,
    horizon_ms: Optional[float] = None,
    n_workers: Optional[int] = None,
) -> Dict[str, object]:
    """Aggregate + per-worker summaries over one cluster run.

    Per-worker throughput/goodput use the *shared* horizon (the cluster
    run's span), so worker rates sum to the aggregate rate instead of
    each worker normalizing by its own last release. Pass ``n_workers``
    (the cluster size) explicitly — under light load an idle replica
    answers nothing and would be invisible in the responses.
    """
    ok = [r for r in responses if not r.dropped]
    span = (
        _span_ms(horizon_ms, max(r.release_ms for r in ok), min(r.release_ms for r in ok))
        if ok
        else horizon_ms
    )
    agg = summarize(responses, vanilla_labels=vanilla_labels, horizon_ms=span)
    by_worker: Dict[int, List[Response]] = {}
    for r in responses:
        by_worker.setdefault(r.worker, []).append(r)
    agg["n_workers"] = float(n_workers if n_workers is not None else len(by_worker))
    return {
        "aggregate": agg,
        "workers": {
            w: summarize(rs, vanilla_labels=vanilla_labels, horizon_ms=span)
            for w, rs in sorted(by_worker.items())
        },
    }


#: summarize_generative's full key set, all zeroed (the NaN-proof shape a
#: degenerate stream must still return)
_GEN_EMPTY = {
    "n": 0.0, "tokens": 0.0, "dropped": 0.0, "shed": 0.0,
    "ttft_p50_ms": 0.0, "ttft_p95_ms": 0.0,
    "tpt_p50_ms": 0.0, "tpt_p95_ms": 0.0, "tpt_mean_ms": 0.0,
    "tokens_per_sec": 0.0, "exit_rate": 0.0, "agreement": 1.0,
    "ttft_frac": 0.0,
}


def summarize_generative(
    responses: List,
    *,
    horizon_ms: Optional[float] = None,
) -> Dict[str, float]:
    """Generative serving metrics (paper §5): per-token TPT percentiles,
    tokens/sec, TTFT vs TPT split, exit rate over decode tokens, and
    agreement of released tokens with the original model's greedy stream.

    TPT samples are successive release deltas within each request
    (``diff(release_ms)``); the first token is TTFT's job, not TPT's.

    Requests shed by the SLO-aware admission policy are reported:
    ``dropped`` counts admission drops (no tokens served; excluded from
    every token metric) and ``shed`` counts mid-stream sheds (partial
    token streams, which DO contribute their served tokens). A shed
    stream that never released a token — a mid-prefill preemption
    victim — still counts under ``shed`` but, like a drop, is excluded
    from every latency/token statistic.

    Degenerate streams stay NaN-free: an empty (or fully-dropped) stream
    returns the full key set zeroed, and a stream of single-token
    requests (no TPT samples at all) reports 0.0 TPT percentiles rather
    than NaN — downstream win%/JSON consumers choke on NaN.
    """
    served = [r for r in responses if not getattr(r, "dropped", False)]
    n_shed = float(sum(getattr(r, "shed", False) for r in served))
    # zero-token sheds (mid-prefill preemption victims) have no releases
    # to take statistics over — count them, then set them aside
    voiced = [r for r in served if len(r.release_ms) > 0]
    if not voiced:
        return dict(_GEN_EMPTY, n=float(len(responses)), shed=n_shed,
                    dropped=float(len(responses) - len(served)))
    ttft = np.asarray([r.ttft_ms for r in voiced])
    tpt = np.concatenate([r.tpt_ms for r in voiced if len(r.release_ms) > 1] or
                         [np.zeros(0)])
    decode_sites = np.concatenate(
        [np.asarray(r.exit_sites[1:], np.int64) for r in voiced if len(r.exit_sites) > 1]
        or [np.zeros(0, np.int64)]
    )
    total_tokens = int(sum(len(r.tokens) for r in voiced))
    last = max(max(r.release_ms) for r in voiced)
    first = min(r.arrival_ms for r in voiced)
    span = _span_ms(horizon_ms, last, first)
    # agreement over DECODE tokens only (same denominator as exit_rate):
    # the prefill token is the final model's own output by construction
    agree = np.concatenate(
        [np.asarray(r.tokens[1:]) == np.asarray(r.final_tokens[1:]) for r in voiced]
        or [np.zeros(0, bool)]
    )
    out = {
        "n": float(len(responses)),
        "tokens": float(total_tokens),
        "dropped": float(len(responses) - len(served)),
        "shed": n_shed,
        **_percentile_block(ttft, {"ttft_p50_ms": 50, "ttft_p95_ms": 95}, 0.0),
        **_percentile_block(tpt, {"tpt_p50_ms": 50, "tpt_p95_ms": 95}, 0.0),
        "tpt_mean_ms": float(tpt.mean()) if len(tpt) else 0.0,
        "tokens_per_sec": _per_sec(total_tokens, span),
        "exit_rate": float((decode_sites >= 0).mean()) if len(decode_sites) else 0.0,
        "agreement": float(agree.mean()) if len(agree) else 1.0,
        # per-request latency split: how much of a request's life is TTFT
        "ttft_frac": float(
            np.mean([r.ttft_ms / max(max(r.release_ms) - r.arrival_ms, 1e-9)
                     for r in voiced])
        ),
    }
    slo = np.asarray([r.slo_ms for r in voiced])
    if np.isfinite(slo).all() and len(tpt):
        # per-token SLO: a request is on time if its median TPT meets it
        per_req = [
            float(np.median(r.tpt_ms)) <= r.slo_ms + 1e-9
            for r in voiced if len(r.release_ms) > 1
        ]
        if per_req:
            out["tpt_slo_miss_rate"] = 1.0 - float(np.mean(per_req))
    return out


def savings_vs(base: Dict[str, float], ours: Dict[str, float]) -> Dict[str, float]:
    out = {}
    for k in ("p25_ms", "p50_ms", "p95_ms", "p99_ms"):
        if np.isfinite(base.get(k, np.nan)) and np.isfinite(ours.get(k, np.nan)):
            out[k.replace("_ms", "_win_pct")] = 100.0 * (base[k] - ours[k]) / base[k]
    if base.get("throughput_qps") and ours.get("throughput_qps"):
        out["throughput_delta_pct"] = (
            100.0 * (ours["throughput_qps"] - base["throughput_qps"]) / base["throughput_qps"]
        )
    return out
