"""Serving metrics: latency percentiles, throughput, accuracy-vs-original."""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Response


def summarize(
    responses: List[Response],
    *,
    vanilla_labels: Optional[np.ndarray] = None,
    horizon_ms: Optional[float] = None,
) -> Dict[str, float]:
    ok = [r for r in responses if not r.dropped]
    lat = np.asarray([r.latency_ms for r in ok])
    out = {
        "n": float(len(responses)),
        "dropped": float(sum(r.dropped for r in responses)),
        "p25_ms": float(np.percentile(lat, 25)) if len(lat) else np.nan,
        "p50_ms": float(np.percentile(lat, 50)) if len(lat) else np.nan,
        "p95_ms": float(np.percentile(lat, 95)) if len(lat) else np.nan,
        "p99_ms": float(np.percentile(lat, 99)) if len(lat) else np.nan,
        "mean_batch": float(np.mean([r.batch_size for r in ok])) if ok else np.nan,
        "exit_rate": float(np.mean([r.exit_site >= 0 for r in ok])) if ok else 0.0,
    }
    if ok:
        span = (
            horizon_ms
            if horizon_ms is not None
            else max(r.release_ms for r in ok) - min(0.0, min(r.release_ms for r in ok))
        )
        out["throughput_qps"] = len(ok) / max(span / 1000.0, 1e-9)
    if vanilla_labels is not None and ok:
        # accuracy = agreement with the original model's label (paper metric)
        agree = [r.label == vanilla_labels[r.rid] for r in ok]
        out["accuracy"] = float(np.mean(agree))
    return out


def savings_vs(base: Dict[str, float], ours: Dict[str, float]) -> Dict[str, float]:
    out = {}
    for k in ("p25_ms", "p50_ms", "p95_ms", "p99_ms"):
        if np.isfinite(base.get(k, np.nan)) and np.isfinite(ours.get(k, np.nan)):
            out[k.replace("_ms", "_win_pct")] = 100.0 * (base[k] - ours[k]) / base[k]
    if base.get("throughput_qps") and ours.get("throughput_qps"):
        out["throughput_delta_pct"] = (
            100.0 * (ours["throughput_qps"] - base["throughput_qps"]) / base["throughput_qps"]
        )
    return out
