"""Discrete-event serving simulator.

Reproduces the serving-platform behaviors the paper runs atop:

  * ``tfserve`` policy — tunable ``max_batch_size`` / ``batch_timeout_ms``
    knobs (paper Fig 3);
  * ``clockwork`` policy — work-conserving, SLO-aware max-batch selection
    with drop-on-miss (paper §2.1).

Apparate runs ON TOP: batch execution calls the model runner once
(inputs always run to completion), streams ramp records to the
controller, and per-request *results* are released at their exit ramp's
time offset (§3). Batch execution time = vanilla + active ramp overheads
(the ramp-budget guarantee is directly visible in the tail latency).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.serving.request import Request, Response


@dataclasses.dataclass
class PlatformConfig:
    policy: str = "clockwork"  # 'clockwork' | 'tfserve'
    max_batch_size: int = 16
    batch_timeout_ms: float = 5.0
    drop_on_slo_miss: bool = False  # clockwork drops hopeless requests


class ServingSimulator:
    """Single-worker discrete-event loop (the paper's single-GPU setup)."""

    def __init__(
        self,
        profile,
        platform: PlatformConfig,
        runner=None,
        controller=None,
    ):
        self.profile = profile
        self.pf = platform
        self.runner = runner
        self.controller = controller

    def exec_time(self, bs: int) -> float:
        t = self.profile.vanilla_time(bs)
        if self.controller is not None:
            t += self.controller.total_ramp_overhead(bs)
        return t

    def _release_offset(self, site: int, bs: int, active: Sequence[int]) -> float:
        """Time into batch execution at which a result exiting at `site`
        leaves the platform."""
        act = sorted(active)
        ovh = 0.0
        for s in act:
            if s <= site:
                ovh += self.profile.ramp_overhead(s, bs)
        return self.profile.time_to_layer(self.profile.sites[site], bs) + ovh

    def run(self, requests: List[Request]) -> List[Response]:
        pf = self.pf
        queue: List[Request] = []
        responses: List[Response] = []
        i = 0
        n = len(requests)
        now = 0.0
        free_at = 0.0
        while i < n or queue:
            # admit arrivals up to `now`
            while i < n and requests[i].arrival_ms <= now + 1e-9:
                queue.append(requests[i])
                i += 1
            if not queue:
                now = max(requests[i].arrival_ms, free_at) if i < n else now
                continue
            if now < free_at:
                now = free_at
                continue
            batch = self._form_batch(queue, now, requests, i)
            if batch is None:
                # wait for more arrivals or timeout expiry
                t_next = requests[i].arrival_ms if i < n else np.inf
                t_tmo = queue[0].arrival_ms + pf.batch_timeout_ms
                now = min(t_next, t_tmo)
                continue
            if not batch:  # dropped hopeless head-of-line request
                r = queue.pop(0)
                responses.append(Response(r.rid, now, -1, -1, now - r.arrival_ms, 0, True))
                continue
            bs = len(batch)
            del queue[:bs]
            t_exec = self.exec_time(bs)
            free_at = now + t_exec
            responses.extend(self._execute(batch, now, bs, t_exec))
        return responses

    def _form_batch(self, queue, now, requests, i) -> Optional[List[Request]]:
        pf = self.pf
        if pf.policy == "tfserve":
            if len(queue) >= pf.max_batch_size:
                return queue[: pf.max_batch_size]
            oldest_wait = now - queue[0].arrival_ms
            if oldest_wait + 1e-9 >= pf.batch_timeout_ms:
                return queue[: pf.max_batch_size]
            if i >= len(requests):  # no more arrivals: flush
                return queue[: pf.max_batch_size]
            return None
        # clockwork: largest batch whose completion meets the earliest deadline
        cap = min(len(queue), pf.max_batch_size)
        for b in range(cap, 0, -1):
            dl = min(q.arrival_ms + q.slo_ms for q in queue[:b])
            if now + self.exec_time(b) <= dl + 1e-9:
                return queue[:b]
        if pf.drop_on_slo_miss:
            return []  # sentinel: drop head-of-line
        return queue[:1]  # serve anyway (degraded)

    def _execute(self, batch: List[Request], start: float, bs: int, t_exec: float):
        ctl = self.controller
        out = []
        if self.runner is None or ctl is None:
            for r in batch:
                out.append(
                    Response(r.rid, start + t_exec, 0, -1, start + t_exec - r.arrival_ms, bs)
                )
            return out
        items = np.asarray([r.item for r in batch])
        active = sorted(ctl.active)
        ramp_labels, ramp_unc, final_labels = self.runner.infer(items, active)
        dec = ctl.observe(ramp_labels, ramp_unc, final_labels)
        for j, r in enumerate(batch):
            site = int(dec.exit_sites[j])
            if site >= 0:
                off = self._release_offset(site, bs, active)
            else:
                off = t_exec
            rel = start + off
            out.append(
                Response(r.rid, rel, int(dec.released_labels[j]), site, rel - r.arrival_ms, bs)
            )
        return out


def make_requests(arrivals: np.ndarray, slo_ms: float, items=None) -> List[Request]:
    items = items if items is not None else np.arange(len(arrivals))
    return [
        Request(rid=k, arrival_ms=float(t), slo_ms=slo_ms, item=int(items[k]))
        for k, t in enumerate(arrivals)
    ]
