"""Discrete-event serving simulator (single-worker facade).

Reproduces the serving-platform behaviors the paper runs atop:

  * ``tfserve`` policy — tunable ``max_batch_size`` / ``batch_timeout_ms``
    knobs (paper Fig 3);
  * ``clockwork`` policy — work-conserving, SLO-aware max-batch selection
    with drop-on-miss (paper §2.1).

Apparate runs ON TOP: batch execution calls the model runner once
(inputs always run to completion), streams ramp records to the
controller, and per-request *results* are released at their exit ramp's
time offset (§3). Batch execution time = vanilla + active ramp overheads
(the ramp-budget guarantee is directly visible in the tail latency).

Batch formation lives in `repro.serving.policies`; the event loop lives
in `repro.serving.engine` (the unified event-driven core shared with the
generative decode adapter). ``ServingSimulator`` is the 1-worker special
case of ``ClusterSimulator`` (the paper's single-GPU setup) and keeps
the original call signature.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.serving.cluster import ClusterConfig, ClusterSimulator, release_offset
from repro.serving.policies import PlatformConfig  # noqa: F401  (re-export)
from repro.serving.request import Request, Response


class ServingSimulator:
    """Single-worker discrete-event loop (the paper's single-GPU setup)."""

    def __init__(
        self,
        profile,
        platform: PlatformConfig,
        runner=None,
        controller=None,
        *,
        admission=None,
    ):
        self.profile = profile
        self.pf = platform
        self.runner = runner
        self.controller = controller
        self.admission = admission  # optional SLO-aware AdmissionPolicy

    def exec_time(self, bs: int) -> float:
        t = self.profile.vanilla_time(bs)
        if self.controller is not None:
            t += self.controller.total_ramp_overhead(bs)
        return t

    def _release_offset(self, site: int, bs: int, active: Sequence[int]) -> float:
        """Time into batch execution at which a result exiting at `site`
        leaves the platform."""
        return release_offset(self.profile, site, bs, active)

    def run(self, requests: List[Request]) -> List[Response]:
        sim = ClusterSimulator(
            self.profile,
            ClusterConfig(n_workers=1, platform=self.pf, admission=self.admission),
            runner=self.runner,
            controllers=[self.controller] if self.controller is not None else None,
        )
        return sim.run(requests)


def make_requests(arrivals: np.ndarray, slo_ms: float, items=None) -> List[Request]:
    items = items if items is not None else np.arange(len(arrivals))
    return [
        Request(rid=k, arrival_ms=float(t), slo_ms=slo_ms, item=int(items[k]))
        for k, t in enumerate(arrivals)
    ]
