"""Accuracy-aware threshold tuning (paper §3.2, Algorithm 1).

Greedy hill-climb over per-ramp thresholds exploiting EE monotonicity:
raising any threshold monotonically increases exit rate / latency savings
and monotonically decreases agreement accuracy. MIMD step sizing: a chosen
ramp's step doubles (promising direction); an overstepped ramp's step
halves (hone in on the accuracy boundary), lower-bounded at
`smallest_step`. Runs on host numpy in ~ms (paper: up to 3 orders of
magnitude faster than grid search, within 0–3.8% of optimal savings).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.exits import evaluate_config, evaluate_configs, site_cost_vectors


@dataclasses.dataclass
class TuneResult:
    thresholds: np.ndarray  # (n_sites,) full vector (inactive sites untouched)
    savings_ms: float
    accuracy: float
    rounds: int
    wall_s: float


def tune_thresholds(
    window_data,
    active: Sequence[int],
    profile,
    *,
    n_sites: int,
    acc_constraint: float = 0.99,
    init_step: float = 0.1,
    smallest_step: float = 0.01,
    bs: int = 1,
    max_rounds: int = 10_000,
) -> TuneResult:
    """Paper Algorithm 1. Thresholds start at 0 (no exits) and climb.

    The per-round candidate sweep is vectorized: all K per-ramp candidate
    threshold vectors are priced in ONE batched `simulate_exits` pass
    (`evaluate_configs`), with the per-site overhead/savings vectors
    precomputed once per tune — bit-identical to evaluating the K
    candidates sequentially (`tune_thresholds_reference`), at a fraction
    of the controller's tuning wall time."""
    t0 = time.perf_counter()
    act = sorted(active)
    thr = np.zeros(n_sites, np.float32)
    steps = {s: float(init_step) for s in act}
    ovh, sav = site_cost_vectors(profile, act, bs)
    base_acc, base_sav, _, _ = evaluate_configs(
        window_data, thr[None, :], act, profile, bs, ovh=ovh, sav=sav
    )
    cur_acc, cur_sav = float(base_acc[0]), float(base_sav[0])
    rounds = 0
    while rounds < max_rounds:
        rounds += 1
        # one candidate per movable ramp, evaluated in a single batched pass
        cand_sites: List[int] = []
        cands: List[np.ndarray] = []
        for s in act:
            if thr[s] >= 1.0:
                continue
            cand = thr.copy()
            cand[s] = min(1.0, cand[s] + steps[s])
            if cand[s] == thr[s]:
                continue
            cand_sites.append(s)
            cands.append(cand)
        movable = bool(cands)
        if movable:
            accs, savs, _, _ = evaluate_configs(
                window_data, np.stack(cands), act, profile, bs, ovh=ovh, sav=sav
            )
        best_s, best_score, best_eval = None, -np.inf, None
        overstepped: List[int] = []
        for j, s in enumerate(cand_sites):
            ev_acc, ev_sav = float(accs[j]), float(savs[j])
            if ev_acc + 1e-9 < acc_constraint:
                overstepped.append(s)
                continue
            d_sav = ev_sav - cur_sav
            d_acc = max(cur_acc - ev_acc, 0.0)
            score = d_sav / (d_acc + 1e-6)
            if d_sav <= 0:
                score = d_sav  # never prefer a savings regression
            if score > best_score:
                best_s, best_score, best_eval = s, score, (ev_acc, ev_sav)
        if best_s is not None and best_eval[1] >= cur_sav - 1e-12:
            thr[best_s] = min(1.0, thr[best_s] + steps[best_s])
            steps[best_s] = min(steps[best_s] * 2, 1.0)  # MI
            cur_acc, cur_sav = best_eval
        else:
            if all(steps[s] <= smallest_step for s in act) or not movable:
                break
            for s in overstepped:
                steps[s] = max(steps[s] / 2, smallest_step)  # MD
            # also shrink steps of ramps that produced no gain
            for s in act:
                if s not in overstepped:
                    steps[s] = max(steps[s] / 2, smallest_step)
    return TuneResult(thr, cur_sav, cur_acc, rounds, time.perf_counter() - t0)


def tune_thresholds_reference(
    window_data,
    active: Sequence[int],
    profile,
    *,
    n_sites: int,
    acc_constraint: float = 0.99,
    init_step: float = 0.1,
    smallest_step: float = 0.01,
    bs: int = 1,
    max_rounds: int = 10_000,
) -> TuneResult:
    """Sequential (one `evaluate_config` per candidate) implementation of
    Algorithm 1, kept as the oracle for the vectorized hot loop: the
    equivalence tests and `bench_tune_wall` compare against it."""
    t0 = time.perf_counter()
    act = sorted(active)
    thr = np.zeros(n_sites, np.float32)
    steps = {s: float(init_step) for s in act}
    base = evaluate_config(window_data, thr, act, profile, bs)
    cur_acc, cur_sav = base.accuracy, base.mean_saved_ms
    rounds = 0
    while rounds < max_rounds:
        rounds += 1
        best_s, best_score, best_eval = None, -np.inf, None
        overstepped: List[int] = []
        movable = False
        for s in act:
            if thr[s] >= 1.0:
                continue
            cand = thr.copy()
            cand[s] = min(1.0, cand[s] + steps[s])
            if cand[s] == thr[s]:
                continue
            movable = True
            ev = evaluate_config(window_data, cand, act, profile, bs)
            if ev.accuracy + 1e-9 < acc_constraint:
                overstepped.append(s)
                continue
            d_sav = ev.mean_saved_ms - cur_sav
            d_acc = max(cur_acc - ev.accuracy, 0.0)
            score = d_sav / (d_acc + 1e-6)
            if d_sav <= 0:
                score = d_sav  # never prefer a savings regression
            if score > best_score:
                best_s, best_score, best_eval = s, score, ev
        if best_s is not None and best_eval.mean_saved_ms >= cur_sav - 1e-12:
            thr[best_s] = min(1.0, thr[best_s] + steps[best_s])
            steps[best_s] = min(steps[best_s] * 2, 1.0)  # MI
            cur_acc, cur_sav = best_eval.accuracy, best_eval.mean_saved_ms
        else:
            if all(steps[s] <= smallest_step for s in act) or not movable:
                break
            for s in overstepped:
                steps[s] = max(steps[s] / 2, smallest_step)  # MD
            # also shrink steps of ramps that produced no gain
            for s in act:
                if s not in overstepped:
                    steps[s] = max(steps[s] / 2, smallest_step)
    return TuneResult(thr, cur_sav, cur_acc, rounds, time.perf_counter() - t0)


def grid_search_thresholds(
    window_data,
    active: Sequence[int],
    profile,
    *,
    n_sites: int,
    acc_constraint: float = 0.99,
    step: float = 0.1,
    bs: int = 1,
) -> TuneResult:
    """Exhaustive O((1/step)^R) baseline (paper Fig 11 comparison)."""
    t0 = time.perf_counter()
    act = sorted(active)
    grid = np.arange(0.0, 1.0 + 1e-9, step)
    best = (np.zeros(n_sites, np.float32), 0.0, 1.0)
    n = 0
    base = evaluate_config(window_data, best[0], act, profile, bs)
    best = (best[0], base.mean_saved_ms, base.accuracy)
    for combo in itertools.product(grid, repeat=len(act)):
        n += 1
        thr = np.zeros(n_sites, np.float32)
        for s, v in zip(act, combo):
            thr[s] = v
        ev = evaluate_config(window_data, thr, act, profile, bs)
        if ev.accuracy + 1e-9 >= acc_constraint and ev.mean_saved_ms > best[1]:
            best = (thr, ev.mean_saved_ms, ev.accuracy)
    return TuneResult(best[0], best[1], best[2], n, time.perf_counter() - t0)
