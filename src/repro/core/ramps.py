"""Ramp placement & architecture policy (paper §3.1).

Placement rule — **cut vertices**: a ramp may only attach where the
operator graph would split into two disjoint subgraphs, i.e. no edge may
start before the ramp and re-enter after it. For residual families
(ResNet blocks, transformer blocks, Mamba blocks, MoE blocks) those are
exactly the *block boundaries* — the residual add is the cut vertex;
nothing inside a block qualifies because the skip edge bypasses it. For
chain models (VGG-style) every layer qualifies.

In this JAX build the models are schema-defined (not ONNX graphs), so the
cut-vertex analysis is realized structurally:

  * transformer/SSM/hybrid LMs  -> after every block (``transformer.ramp_sites``:
    thinned to ≤12 sites for very deep models, matching the paper's
    9.2–68.4% feasible-layer coverage),
  * enc-dec                     -> decoder block boundaries only,
  * encoder classifiers         -> every encoder block,
  * ResNets                     -> every residual-block output.

Architecture rule — **shallowest viable ramp**: lightweight pooling +
the model's final FC with input width matched to the site (§3.1):

  * LMs: last-position hidden -> ramp RMSNorm -> per-site LM head,
  * BERT-style: CLS-token pool -> classifier FC,
  * ResNet: global-average-pool -> classifier FC.

A heavier 'mlp' style (cfg.ramp_style) exists for the paper's Fig 9
comparison. Ramps are trained with the backbone frozen and with exiting
disabled so every ramp sees every input (training independence, §3.1);
see training/ramp_training.py.
"""
from __future__ import annotations

from typing import Tuple


def feasible_sites(model) -> Tuple[int, ...]:
    """Cut-vertex ramp sites for any built model (see module docstring)."""
    return tuple(model.sites)


def describe(model) -> str:
    cfg = model.cfg
    sites = feasible_sites(model)
    n_layers = getattr(cfg, "n_layers", len(sites) + 1)
    cov = 100.0 * len(sites) / max(n_layers, 1)
    return (
        f"{cfg.name}: {len(sites)} feasible ramp sites over {n_layers} blocks "
        f"({cov:.1f}% coverage; paper range 9.2-68.4%)"
    )
