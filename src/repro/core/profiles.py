"""Layerwise latency profiles.

The paper profiles per-layer runtimes once per model (different batch
sizes) and uses them for (a) ramp utility scoring and (b) translating exit
locations into latency savings. On this CPU-only container we derive the
profile analytically from the architecture's per-layer FLOPs / bytes and
the TPU v5e roofline constants — the same model used in EXPERIMENTS.md
§Roofline — so measured profiles can drop in unchanged on real hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

# TPU v5e (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def _layer_flops_bytes(
    cfg, seq: int, mode: str
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-layer (FLOPs, weight HBM bytes, per-input HBM bytes) for one input
    at seq length `seq`. mode: 'prefill' (process seq tokens) | 'decode'
    (1 token, seq-long cache). Weight bytes are loaded once per batch;
    per-input bytes (KV-cache / recurrent-state traffic) scale with batch
    size — the split is what makes per-token early exits save real time in
    the memory-bound decode regime."""
    from repro.models.transformer import build_plan

    d = cfg.d_model
    bpe = 2  # bf16
    if cfg.family == "resnet":
        f, b = _resnet_flops_bytes(cfg)
        return f, b, np.zeros_like(b)
    if cfg.family in ("encdec", "encoder_cls"):
        L = cfg.n_dec_layers if cfg.family == "encdec" else cfg.n_layers
        specs = ["attn"] * L
    else:
        specs = [s.mixer for s in build_plan(cfg).layer_specs()]
    flops, bytes_, bytes_pi = [], [], []
    ntok = seq if mode == "prefill" else 1
    kvlen = seq
    for i, mixer in enumerate(specs):
        f = b = bpi = 0.0
        if mixer == "attn":
            H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            wqkvo = d * H * hd * 2 + d * K * hd * 2 + H * hd * d
            f += 2 * ntok * wqkvo
            b += wqkvo * bpe
            att_len = min(kvlen, cfg.window) if (cfg.window and _is_local(cfg, i)) else kvlen
            f += 2 * ntok * att_len * (H * hd) * 2  # qk + pv
            bpi += ntok * att_len * K * hd * 2 * bpe if mode == "decode" else 0
        elif mixer == "mla":
            r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
            H = cfg.n_heads
            w = d * H * (dn + dr) + d * (r + dr) + r * H * dn + r * H * dv + H * dv * d
            f += 2 * ntok * w
            b += w * bpe
            if mode == "decode":
                # naive path re-expands the latent cache per step
                f += 2 * kvlen * r * H * (dn + dv)
                bpi += kvlen * (r + dr) * bpe
            f += 2 * ntok * kvlen * H * (dn + dr + dv)
        elif mixer == "mamba":
            di, N, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_headdim
            Hs, G = di // hp, cfg.ssm_ngroups
            w = d * (2 * di + 2 * G * N + Hs) + di * d
            f += 2 * ntok * w
            b += w * bpe
            f += ntok * (di * N * 6)  # ssd state update + output
            bpi += Hs * hp * N * 4 if mode == "decode" else 0
        # ffn
        ffn_kind = _ffn_kind(cfg, i)
        if ffn_kind == "dense":
            w = 3 * d * cfg.d_ff
            f += 2 * ntok * w
            b += w * bpe
        elif ffn_kind == "moe":
            w_active = 3 * d * cfg.moe_d_ff * (cfg.top_k + cfg.n_shared_experts)
            f += 2 * ntok * w_active
            # decode touches top_k expert weights per token
            b += w_active * bpe
        flops.append(f)
        bytes_.append(b)
        bytes_pi.append(bpi)
    return np.asarray(flops), np.asarray(bytes_), np.asarray(bytes_pi)


def _layer_kv_fill(cfg) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-layer (FLOPs, weight bytes, per-token bytes) to *catch up* one
    exited token's sequence state at that layer.

    The paper's generative mode: a token exiting at ramp `s` skips layers
    > s, but future tokens still attend to it — so each deeper attention
    layer needs this token's K/V (filled from the exit layer's hidden
    state via the k/v projections only), and each deeper SSM layer must
    still run its recurrent state update (sequential state cannot be
    approximated away). This is the deferred ``kv_fill_cost`` the serving
    engine amortizes into the following decode step — exits are never
    free."""
    from repro.models.transformer import build_plan

    d = cfg.d_model
    bpe = 2
    if cfg.family == "resnet":
        z = np.zeros(sum(cfg.resnet_blocks))
        return z, z.copy(), z.copy()
    if cfg.family in ("encdec", "encoder_cls"):
        L = cfg.n_dec_layers if cfg.family == "encdec" else cfg.n_layers
        specs = ["attn"] * L
    else:
        specs = [s.mixer for s in build_plan(cfg).layer_specs()]
    f_l, wb_l, pib_l = [], [], []
    for mixer in specs:
        f = wb = pib = 0.0
        if mixer == "attn":
            K, hd = cfg.n_kv_heads, cfg.hd
            wkv = d * K * hd * 2  # k + v projections
            f = 2 * wkv
            wb = wkv * bpe
            pib = K * hd * 2 * bpe + d * bpe  # cache write + hidden read
        elif mixer == "mla":
            r, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
            wkv = d * (r + dr)  # latent + rope-key projection
            f = 2 * wkv
            wb = wkv * bpe
            pib = (r + dr) * bpe + d * bpe
        elif mixer == "mamba":
            # the recurrence is sequential: the full mixer runs for the
            # exited token (no cheap fill exists for SSM state)
            di, N, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_headdim
            Hs, G = di // hp, cfg.ssm_ngroups
            w = d * (2 * di + 2 * G * N + Hs) + di * d
            f = 2 * w + di * N * 6
            wb = w * bpe
            pib = Hs * hp * N * 4 + d * bpe
        f_l.append(f)
        wb_l.append(wb)
        pib_l.append(pib)
    return np.asarray(f_l), np.asarray(wb_l), np.asarray(pib_l)


def _is_local(cfg, i: int) -> bool:
    if not cfg.local_global_pattern:
        return False
    return (i % (cfg.local_global_pattern + 1)) < cfg.local_global_pattern


def _ffn_kind(cfg, i: int) -> str:
    if cfg.family == "resnet":
        return "none"
    if cfg.ssm and not cfg.hybrid_period:
        return "none"
    if cfg.hybrid_period:
        return "moe" if (cfg.moe and i % cfg.moe_every == 1) else "dense"
    if cfg.moe:
        return "dense" if i < cfg.first_k_dense else "moe"
    return "dense"


def _resnet_flops_bytes(cfg) -> Tuple[np.ndarray, np.ndarray]:
    """Per-residual-block FLOPs for img_size inputs (CV latency skews early —
    exactly the skew the paper calls out in §3.3)."""
    flops, bytes_ = [], []
    hw = cfg.img_size
    cin = cfg.resnet_widths[0]
    for stage, (n, w) in enumerate(zip(cfg.resnet_blocks, cfg.resnet_widths)):
        wout = w * (4 if cfg.resnet_bottleneck else 1)
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            hw = hw // stride
            if cfg.resnet_bottleneck:
                f = 2 * hw * hw * (cin * w + 9 * w * w + w * wout)
                nbytes = (cin * w + 9 * w * w + w * wout) * 4
            else:
                f = 2 * hw * hw * (9 * cin * w + 9 * w * wout)
                nbytes = (9 * cin * w + 9 * w * wout) * 4
            flops.append(f)
            bytes_.append(nbytes)
            cin = wout
    return np.asarray(flops, np.float64), np.asarray(bytes_, np.float64)


@dataclasses.dataclass
class LatencyProfile:
    """Cumulative layerwise serving-time model.

    layer_flops/layer_bytes: per-layer, per-input (reference seq);
    layer_bytes are weight traffic (loaded once per batch) while
    layer_bytes_pi is per-input traffic (KV cache / recurrent state) that
    scales with batch size.
    head_flops/head_bytes: final head (norm + unembed).
    ramp_flops/ramp_bytes: per-site ramp overhead.
    kv_flops/kv_wbytes/kv_pibytes: per-layer cost to catch up one exited
    token's KV / recurrent state at that layer (generative decode; the
    paper's deferred hidden-state copy + KV-projection fill).
    chips: devices the model is sharded over.
    """

    layer_flops: np.ndarray
    layer_bytes: np.ndarray
    head_flops: float
    head_bytes: float
    ramp_flops: np.ndarray
    ramp_bytes: np.ndarray
    sites: Tuple[int, ...]
    chips: int = 1
    flops_scale: float = 1.0  # efficiency derate (MXU util)
    layer_bytes_pi: Optional[np.ndarray] = None  # per-input bytes (KV reads)
    kv_flops: Optional[np.ndarray] = None
    kv_wbytes: Optional[np.ndarray] = None
    kv_pibytes: Optional[np.ndarray] = None
    charge_kv_in_savings: bool = False  # net exit savings of KV catch-up

    def _time(self, flops, nbytes, bs: int, nbytes_pi: float = 0.0) -> float:
        """Roofline time (ms) for a batch of `bs` inputs."""
        c = max(self.chips, 1)
        t_c = flops * bs / (PEAK_FLOPS * c * self.flops_scale)
        t_m = (nbytes + bs * nbytes_pi) / (HBM_BW * c)
        return float(np.maximum(t_c, t_m)) * 1e3

    def _layer_pi(self, i: int) -> float:
        return float(self.layer_bytes_pi[i]) if self.layer_bytes_pi is not None else 0.0

    def layer_time(self, i: int, bs: int) -> float:
        return self._time(self.layer_flops[i], self.layer_bytes[i], bs, self._layer_pi(i))

    def time_to_layer(self, i: int, bs: int) -> float:
        """Time through layer i inclusive (no ramps, no head)."""
        return sum(self.layer_time(j, bs) for j in range(i + 1))

    def head_time(self, bs: int) -> float:
        return self._time(self.head_flops, self.head_bytes, bs)

    def ramp_overhead(self, site_idx: int, bs: int) -> float:
        return self._time(self.ramp_flops[site_idx], self.ramp_bytes[site_idx], bs)

    def vanilla_time(self, bs: int) -> float:
        return self.time_to_layer(len(self.layer_flops) - 1, bs) + self.head_time(bs)

    def time_to_site(self, site_idx: int, bs: int) -> float:
        """Time until ramp at `site_idx` produces its result (incl. its own
        head compute)."""
        return self.time_to_layer(self.sites[site_idx], bs) + self.ramp_overhead(site_idx, bs)

    def savings_at_site(self, site_idx: int, bs: int) -> float:
        """Latency avoided by releasing at this site (paper's savings).
        With ``charge_kv_in_savings`` (generative decode profiles) the
        deferred KV catch-up for the exited token is netted out, so the
        whole adaptation stack (threshold tuning, ramp utilities) scores
        exits by their true decode value."""
        raw = self.vanilla_time(bs) - self.time_to_layer(self.sites[site_idx], bs)
        if self.charge_kv_in_savings:
            raw -= self.kv_fill_cost(site_idx, 1)
        return raw

    # -- generative decode (per-token exits; paper §5 generative results) ----

    def kv_fill_cost(self, site_idx: int, n_tokens: int = 1) -> float:
        """Deferred catch-up cost (ms) for ``n_tokens`` tokens that exited at
        ``site_idx`` in the same decode step: deeper attention layers still
        need each token's K/V (filled from the exit layer's hidden state via
        the k/v projections) and deeper SSM layers must run their recurrent
        state update. Weight traffic amortizes across the step's exited
        tokens; per-token traffic does not."""
        if self.kv_flops is None or n_tokens <= 0:
            return 0.0
        lo = self.sites[site_idx] + 1
        if lo >= len(self.kv_flops):
            return 0.0
        return self._time(
            float(self.kv_flops[lo:].sum()),
            float(self.kv_wbytes[lo:].sum()),
            n_tokens,
            float(self.kv_pibytes[lo:].sum()),
        )

    def prefill_chunk_time(self, n_tokens: int, bs: int = 1) -> float:
        """Roofline time (ms) for one prefill chunk of ``n_tokens`` prompt
        tokens per input: each layer's compute scales with the chunk while
        its weight traffic is paid once per chunk — prefill is the
        compute-dense regime chunked prefill co-schedules against
        memory-bound decode steps. Sub-additive in the chunk size (weight
        reads amortize: two merged chunks never cost more than the split),
        which is exactly why a chunk must be priced as a unit instead of
        ``n_tokens`` independent decode-step fractions. The serving
        engine's default admission pricing stays the engine-level
        ``prefill_frac`` model (linear, so chunked and unchunked totals
        match exactly); this method is the physical reference — pass it as
        ``GenerativeEngine(prefill_ms=profile.prefill_chunk_time)`` to
        price prefill from the roofline instead."""
        if n_tokens <= 0:
            return 0.0
        t = 0.0
        for i in range(len(self.layer_flops)):
            t += self._time(self.layer_flops[i] * n_tokens, self.layer_bytes[i],
                            bs, self._layer_pi(i) * n_tokens)
        return t

    def decode_step_time(self, exit_sites: Sequence[int], active: Sequence[int] = ()) -> float:
        """One continuous-batching decode step (ms) where slot ``b``'s token
        exits at site ``exit_sites[b]`` (-1 = runs to completion).

        The per-layer batch shrinks as tokens peel off at their exit sites:
        a layer pays its weight traffic only while at least one token is
        still alive, plus per-alive-token KV traffic and compute. Active
        ramp heads run over the tokens alive at their site; the final LM
        head runs only over non-exited tokens. With no exits and no ramps
        this equals ``vanilla_time(B)`` exactly."""
        ex = np.asarray(exit_sites, np.int64)
        B = len(ex)
        if B == 0:
            return 0.0
        L = len(self.layer_flops)
        # token b is alive at layer j iff it never exits or exits at a site
        # whose layer is >= j (it runs through its exit layer inclusive)
        sites_arr = np.asarray(self.sites, np.int64)
        last_layer = np.where(ex >= 0, sites_arr[np.clip(ex, 0, len(sites_arr) - 1)], L - 1)
        t = 0.0
        alive_at = np.zeros(L, np.int64)
        for j in range(L):
            alive_at[j] = int((last_layer >= j).sum())
            if alive_at[j] > 0:
                t += self.layer_time(j, int(alive_at[j]))
        for k, s in enumerate(sorted(active)):
            n = int(alive_at[self.sites[s]])
            if n > 0:
                t += self.ramp_overhead(s, n)
        n_full = int((ex < 0).sum())
        if n_full > 0:
            t += self.head_time(n_full)
        return t

    # convenience vectors (reference batch size)

    def cum_times(self, bs: int) -> np.ndarray:
        t = np.cumsum([self.layer_time(j, bs) for j in range(len(self.layer_flops))])
        return t

    def max_ramps_within_budget(self, budget_frac: float, bs: int) -> int:
        ovh = np.sort([self.ramp_overhead(s, bs) for s in range(len(self.sites))])
        lim = budget_frac * self.vanilla_time(bs)
        return int(np.searchsorted(np.cumsum(ovh), lim, side="right"))


def build_profile(
    cfg,
    *,
    seq: int = 2048,
    mode: str = "decode",
    chips: int = 1,
    sites: Optional[Sequence[int]] = None,
    ramp_cost_mult: float = 1.0,
    flops_scale: float = 0.6,
    charge_kv: bool = False,
) -> LatencyProfile:
    lf, lb, lbpi = _layer_flops_bytes(cfg, seq, mode)
    if cfg.family == "resnet":
        head_f = 2 * cfg.resnet_widths[-1] * (4 if cfg.resnet_bottleneck else 1) * cfg.n_classes
        head_b = head_f * 2
        if sites is None:
            from repro.models import build_model

            sites = build_model(cfg).sites
        widths = _resnet_widths(cfg)
        rf = np.asarray([2 * widths[s] * cfg.n_classes for s in sites], np.float64)
        rb = rf * 2.0
    else:
        ntok = 1 if mode == "decode" else seq
        # classification-served models (the paper's own: BERT/GPT2 sentiment)
        # have tiny heads; token-serving LMs pay the full (padded) vocab head.
        out_width = cfg.n_classes if cfg.n_classes > 0 else cfg.padded_vocab
        head_f = 2 * ntok * cfg.d_model * out_width
        head_b = cfg.d_model * out_width * 2
        if sites is None:
            if cfg.family == "lm":
                from repro.models.transformer import ramp_sites

                sites = ramp_sites(cfg)
            else:
                from repro.models import build_model

                sites = build_model(cfg).sites
        rf = np.full(len(sites), 2.0 * cfg.d_model * out_width * ramp_cost_mult)
        if cfg.ramp_style == "tied":
            # beyond-paper: ramp head shares the LM-head weights -> no extra
            # HBM traffic beyond the per-site norm vector; compute unchanged.
            rb = np.full(len(sites), cfg.d_model * 4.0 * ramp_cost_mult)
        else:
            rb = np.full(len(sites), cfg.d_model * out_width * 2.0 * ramp_cost_mult)
    kvf, kvw, kvp = _layer_kv_fill(cfg)
    return LatencyProfile(
        layer_flops=lf,
        layer_bytes=lb,
        head_flops=float(head_f),
        head_bytes=float(head_b),
        ramp_flops=np.asarray(rf, np.float64),
        ramp_bytes=np.asarray(rb, np.float64),
        sites=tuple(sites),
        chips=chips,
        flops_scale=flops_scale,
        layer_bytes_pi=lbpi,
        kv_flops=kvf,
        kv_wbytes=kvw,
        kv_pibytes=kvp,
        charge_kv_in_savings=charge_kv,
    )


def _resnet_widths(cfg):
    widths = []
    for n, w in zip(cfg.resnet_blocks, cfg.resnet_widths):
        widths += [w * (4 if cfg.resnet_bottleneck else 1)] * n
    return widths
