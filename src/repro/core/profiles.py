"""Layerwise latency profiles.

The paper profiles per-layer runtimes once per model (different batch
sizes) and uses them for (a) ramp utility scoring and (b) translating exit
locations into latency savings. On this CPU-only container we derive the
profile analytically from the architecture's per-layer FLOPs / bytes and
the TPU v5e roofline constants — the same model used in EXPERIMENTS.md
§Roofline — so measured profiles can drop in unchanged on real hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

# TPU v5e (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def _layer_flops_bytes(cfg, seq: int, mode: str) -> Tuple[np.ndarray, np.ndarray]:
    """Per-layer (FLOPs, HBM bytes) for one input at seq length `seq`.
    mode: 'prefill' (process seq tokens) | 'decode' (1 token, seq-long cache)."""
    from repro.models.transformer import build_plan

    d = cfg.d_model
    bpe = 2  # bf16
    if cfg.family == "resnet":
        return _resnet_flops_bytes(cfg)
    if cfg.family in ("encdec", "encoder_cls"):
        L = cfg.n_dec_layers if cfg.family == "encdec" else cfg.n_layers
        specs = ["attn"] * L
    else:
        specs = [s.mixer for s in build_plan(cfg).layer_specs()]
    flops, bytes_ = [], []
    ntok = seq if mode == "prefill" else 1
    kvlen = seq
    for i, mixer in enumerate(specs):
        f = b = 0.0
        if mixer == "attn":
            H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            wqkvo = d * H * hd * 2 + d * K * hd * 2 + H * hd * d
            f += 2 * ntok * wqkvo
            b += wqkvo * bpe
            att_len = min(kvlen, cfg.window) if (cfg.window and _is_local(cfg, i)) else kvlen
            f += 2 * ntok * att_len * (H * hd) * 2  # qk + pv
            b += ntok * att_len * K * hd * 2 * bpe if mode == "decode" else 0
        elif mixer == "mla":
            r, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
            H = cfg.n_heads
            w = d * H * (dn + dr) + d * (r + dr) + r * H * dn + r * H * dv + H * dv * d
            f += 2 * ntok * w
            b += w * bpe
            if mode == "decode":
                # naive path re-expands the latent cache per step
                f += 2 * kvlen * r * H * (dn + dv)
                b += kvlen * (r + dr) * bpe
            f += 2 * ntok * kvlen * H * (dn + dr + dv)
        elif mixer == "mamba":
            di, N, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_headdim
            Hs, G = di // hp, cfg.ssm_ngroups
            w = d * (2 * di + 2 * G * N + Hs) + di * d
            f += 2 * ntok * w
            b += w * bpe
            f += ntok * (di * N * 6)  # ssd state update + output
            b += Hs * hp * N * 4 if mode == "decode" else 0
        # ffn
        ffn_kind = _ffn_kind(cfg, i)
        if ffn_kind == "dense":
            w = 3 * d * cfg.d_ff
            f += 2 * ntok * w
            b += w * bpe
        elif ffn_kind == "moe":
            w_active = 3 * d * cfg.moe_d_ff * (cfg.top_k + cfg.n_shared_experts)
            f += 2 * ntok * w_active
            # decode touches top_k expert weights per token
            b += w_active * bpe
        flops.append(f)
        bytes_.append(b)
    return np.asarray(flops), np.asarray(bytes_)


def _is_local(cfg, i: int) -> bool:
    if not cfg.local_global_pattern:
        return False
    return (i % (cfg.local_global_pattern + 1)) < cfg.local_global_pattern


def _ffn_kind(cfg, i: int) -> str:
    if cfg.family == "resnet":
        return "none"
    if cfg.ssm and not cfg.hybrid_period:
        return "none"
    if cfg.hybrid_period:
        return "moe" if (cfg.moe and i % cfg.moe_every == 1) else "dense"
    if cfg.moe:
        return "dense" if i < cfg.first_k_dense else "moe"
    return "dense"


def _resnet_flops_bytes(cfg) -> Tuple[np.ndarray, np.ndarray]:
    """Per-residual-block FLOPs for img_size inputs (CV latency skews early —
    exactly the skew the paper calls out in §3.3)."""
    flops, bytes_ = [], []
    hw = cfg.img_size
    cin = cfg.resnet_widths[0]
    for stage, (n, w) in enumerate(zip(cfg.resnet_blocks, cfg.resnet_widths)):
        wout = w * (4 if cfg.resnet_bottleneck else 1)
        for b in range(n):
            stride = 2 if (b == 0 and stage > 0) else 1
            hw = hw // stride
            if cfg.resnet_bottleneck:
                f = 2 * hw * hw * (cin * w + 9 * w * w + w * wout)
                nbytes = (cin * w + 9 * w * w + w * wout) * 4
            else:
                f = 2 * hw * hw * (9 * cin * w + 9 * w * wout)
                nbytes = (9 * cin * w + 9 * w * wout) * 4
            flops.append(f)
            bytes_.append(nbytes)
            cin = wout
    return np.asarray(flops, np.float64), np.asarray(bytes_, np.float64)


@dataclasses.dataclass
class LatencyProfile:
    """Cumulative layerwise serving-time model.

    layer_flops/layer_bytes: per-layer, per-input (reference seq).
    head_flops/head_bytes: final head (norm + unembed).
    ramp_flops/ramp_bytes: per-site ramp overhead.
    chips: devices the model is sharded over.
    """

    layer_flops: np.ndarray
    layer_bytes: np.ndarray
    head_flops: float
    head_bytes: float
    ramp_flops: np.ndarray
    ramp_bytes: np.ndarray
    sites: Tuple[int, ...]
    chips: int = 1
    flops_scale: float = 1.0  # efficiency derate (MXU util)

    def _time(self, flops, nbytes, bs: int) -> float:
        """Roofline time (ms) for a batch of `bs` inputs."""
        c = max(self.chips, 1)
        t_c = flops * bs / (PEAK_FLOPS * c * self.flops_scale)
        t_m = nbytes / (HBM_BW * c)
        return float(np.maximum(t_c, t_m)) * 1e3

    def layer_time(self, i: int, bs: int) -> float:
        return self._time(self.layer_flops[i], self.layer_bytes[i], bs)

    def time_to_layer(self, i: int, bs: int) -> float:
        """Time through layer i inclusive (no ramps, no head)."""
        return sum(self.layer_time(j, bs) for j in range(i + 1))

    def head_time(self, bs: int) -> float:
        return self._time(self.head_flops, self.head_bytes, bs)

    def ramp_overhead(self, site_idx: int, bs: int) -> float:
        return self._time(self.ramp_flops[site_idx], self.ramp_bytes[site_idx], bs)

    def vanilla_time(self, bs: int) -> float:
        return self.time_to_layer(len(self.layer_flops) - 1, bs) + self.head_time(bs)

    def time_to_site(self, site_idx: int, bs: int) -> float:
        """Time until ramp at `site_idx` produces its result (incl. its own
        head compute)."""
        return self.time_to_layer(self.sites[site_idx], bs) + self.ramp_overhead(site_idx, bs)

    def savings_at_site(self, site_idx: int, bs: int) -> float:
        """Raw latency avoided by releasing at this site (paper's savings)."""
        return self.vanilla_time(bs) - self.time_to_layer(self.sites[site_idx], bs)

    # convenience vectors (reference batch size)

    def cum_times(self, bs: int) -> np.ndarray:
        t = np.cumsum([self.layer_time(j, bs) for j in range(len(self.layer_flops))])
        return t

    def max_ramps_within_budget(self, budget_frac: float, bs: int) -> int:
        ovh = np.sort([self.ramp_overhead(s, bs) for s in range(len(self.sites))])
        lim = budget_frac * self.vanilla_time(bs)
        return int(np.searchsorted(np.cumsum(ovh), lim, side="right"))


def build_profile(
    cfg,
    *,
    seq: int = 2048,
    mode: str = "decode",
    chips: int = 1,
    sites: Optional[Sequence[int]] = None,
    ramp_cost_mult: float = 1.0,
    flops_scale: float = 0.6,
) -> LatencyProfile:
    lf, lb = _layer_flops_bytes(cfg, seq, mode)
    if cfg.family == "resnet":
        head_f = 2 * cfg.resnet_widths[-1] * (4 if cfg.resnet_bottleneck else 1) * cfg.n_classes
        head_b = head_f * 2
        if sites is None:
            from repro.models import build_model

            sites = build_model(cfg).sites
        widths = _resnet_widths(cfg)
        rf = np.asarray([2 * widths[s] * cfg.n_classes for s in sites], np.float64)
        rb = rf * 2.0
    else:
        ntok = 1 if mode == "decode" else seq
        # classification-served models (the paper's own: BERT/GPT2 sentiment)
        # have tiny heads; token-serving LMs pay the full (padded) vocab head.
        out_width = cfg.n_classes if cfg.n_classes > 0 else cfg.padded_vocab
        head_f = 2 * ntok * cfg.d_model * out_width
        head_b = cfg.d_model * out_width * 2
        if sites is None:
            if cfg.family == "lm":
                from repro.models.transformer import ramp_sites

                sites = ramp_sites(cfg)
            else:
                from repro.models import build_model

                sites = build_model(cfg).sites
        rf = np.full(len(sites), 2.0 * cfg.d_model * out_width * ramp_cost_mult)
        if cfg.ramp_style == "tied":
            # beyond-paper: ramp head shares the LM-head weights -> no extra
            # HBM traffic beyond the per-site norm vector; compute unchanged.
            rb = np.full(len(sites), cfg.d_model * 4.0 * ramp_cost_mult)
        else:
            rb = np.full(len(sites), cfg.d_model * out_width * 2.0 * ramp_cost_mult)
    return LatencyProfile(
        layer_flops=lf,
        layer_bytes=lb,
        head_flops=float(head_f),
        head_bytes=float(head_b),
        ramp_flops=np.asarray(rf, np.float64),
        ramp_bytes=np.asarray(rb, np.float64),
        sites=tuple(sites),
        chips=chips,
        flops_scale=flops_scale,
    )


def _resnet_widths(cfg):
    widths = []
    for n, w in zip(cfg.resnet_blocks, cfg.resnet_widths):
        widths += [w * (4 if cfg.resnet_bottleneck else 1)] * n
    return widths
