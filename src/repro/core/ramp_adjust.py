"""Latency-focused ramp adjustment (paper §3.3).

Periodic (every `adjust_every` samples): score each active ramp's utility
(savings − overheads) from recorded exit patterns; deactivate negative
ramps (after a rescue threshold-tuning round); propose replacement ramps
after the latest positive ramp using *upper-bound exit rates* (a
candidate's exit rate is bounded by the summed profiled rates of the
nearest downstream deactivated ramp and earlier deactivations — Fig 12);
when all utilities are positive, probe earlier ramps (add before the best
ramp if budget remains, else shift the worst ramp one site earlier).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.exits import evaluate_config, exit_rates, ramp_utilities, simulate_exits
from repro.core.threshold_tuning import tune_thresholds


@dataclasses.dataclass
class AdjustResult:
    active: List[int]
    thresholds: np.ndarray
    deactivated: List[int]
    added: List[int]
    utilities: Dict[int, float]
    reason: str


def _within_budget(profile, active, budget_frac: float, bs: int) -> bool:
    ovh = sum(profile.ramp_overhead(s, bs) for s in active)
    return ovh <= budget_frac * profile.vanilla_time(bs) + 1e-12


def _candidates_between(lo: int, hi: int) -> Optional[int]:
    """Midpoint site in the open interval (lo, hi); None if empty."""
    if hi - lo <= 1:
        return None
    return (lo + hi) // 2


def adjust_ramps(
    window_data,
    active: Sequence[int],
    thresholds: np.ndarray,
    profile,
    *,
    n_sites: int,
    acc_constraint: float = 0.99,
    budget_frac: float = 0.02,
    max_slots: int = 8,
    bs: int = 1,
) -> AdjustResult:
    act = sorted(active)
    thr = thresholds.copy()
    # one exit simulation of the current (window, thr, act) shared by both
    # scorers — they used to each re-simulate the identical pattern
    ex0 = simulate_exits(window_data[0], window_data[2], thr, act)
    utils = ramp_utilities(window_data, thr, act, profile, bs, ex=ex0)
    rates = exit_rates(window_data, thr, act, ex=ex0)
    negatives = [s for s in act if utils[s] < 0]

    if negatives:
        # rescue round: can tuning alone fix the negatives without hurting savings?
        before = evaluate_config(window_data, thr, act, profile, bs)
        res = tune_thresholds(
            window_data, act, profile, n_sites=n_sites,
            acc_constraint=acc_constraint, bs=bs,
        )
        utils2 = ramp_utilities(window_data, res.thresholds, act, profile, bs)
        if all(u >= 0 for u in utils2.values()) and res.savings_ms >= before.mean_saved_ms:
            return AdjustResult(act, res.thresholds, [], [], utils2, "rescued-by-tuning")
        # deactivate all negative-utility ramps
        deact = sorted(negatives)
        survivors = [s for s in act if s not in deact]
        positives = [s for s in survivors if utils.get(s, 0) >= 0]
        latest_pos = max(positives) if positives else -1
        # interval structure after latest positive ramp, split by deactivations
        walls = [s for s in deact if s > latest_pos]
        bounds = [latest_pos] + walls + [n_sites]
        added: List[int] = []
        # iterative candidate search: midpoints, then later midpoints
        search = [(bounds[i], bounds[i + 1]) for i in range(len(bounds) - 1)]
        tried = 0
        while search and not added and tried < 2 * n_sites:
            tried += 1
            best = None
            nxt = []
            for lo, hi in search:
                c = _candidates_between(lo, hi)
                if c is None or c in survivors:
                    continue
                # upper-bound exit rate: nearest downstream deactivated ramp
                # + any earlier deactivations inside (lo, hi)  (Fig 12)
                ub = sum(rates.get(w, 0.0) for w in deact if lo < w <= hi)
                sav = profile.savings_at_site(c, bs)
                ovh = profile.ramp_overhead(c, bs)
                n = window_data[0].shape[0]
                util_ub = ub * n * sav - (1.0 - ub) * n * ovh
                if util_ub > 0 and (best is None or util_ub > best[1]):
                    best = (c, util_ub)
                nxt.append((c, hi))  # later candidates next round
            if best is not None:
                added.append(best[0])
                break
            search = nxt
        new_active = sorted(survivors + added)
        # enforce slots + budget
        new_active = new_active[: max_slots]
        while new_active and not _within_budget(profile, new_active, budget_frac, bs):
            new_active.pop()
        for s in added:
            thr[s] = 0.0  # trial ramps start closed (paper)
        return AdjustResult(
            new_active, thr, deact, [a for a in added if a in new_active],
            utils, "deactivated-negative",
        )

    # all positive: first re-enforce the budget (it may have tightened)
    if act and not _within_budget(profile, act, budget_frac, bs):
        keep = sorted(act, key=lambda s: -utils[s])
        pruned = []
        for s in keep:
            if _within_budget(profile, pruned + [s], budget_frac, bs):
                pruned.append(s)
        return AdjustResult(
            sorted(pruned), thr, [s for s in act if s not in pruned], [],
            utils, "budget-shrink",
        )
    # low-risk earlier-ramp probing
    if not act:
        mid = n_sites // 2
        if not _within_budget(profile, [mid], budget_frac, bs):
            # even one mid ramp busts the budget (e.g. untied full-vocab
            # heads): stay ramp-less rather than violate the guarantee
            return AdjustResult([], thr, [], [], utils, "noop")
        thr[mid] = 0.0
        return AdjustResult([mid], thr, [], [mid], utils, "bootstrap")
    best_site = max(act, key=lambda s: utils[s])
    worst_site = min(act, key=lambda s: utils[s])
    can_add = len(act) < max_slots and _within_budget(
        profile, act + [max(best_site - 1, 0)], budget_frac, bs
    )
    if can_add:
        cand = best_site - 1
        prev_active = [s for s in act if s < best_site]
        floor = max(prev_active) + 1 if prev_active else 0
        cand = max(cand, floor)
        if cand not in act and cand >= 0:
            thr[cand] = 0.0
            return AdjustResult(sorted(act + [cand]), thr, [], [cand], utils, "probe-add")
        return AdjustResult(act, thr, [], [], utils, "noop")
    # no budget: shift worst ramp one earlier (keep best untouched)
    tgt = worst_site - 1
    if tgt >= 0 and tgt not in act and worst_site != best_site:
        new_active = sorted([s for s in act if s != worst_site] + [tgt])
        thr[tgt] = 0.0
        return AdjustResult(new_active, thr, [worst_site], [tgt], utils, "probe-shift")
    return AdjustResult(act, thr, [], [], utils, "noop")
