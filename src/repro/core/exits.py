"""Exit-pattern evaluation over recorded ramp statistics.

The paper's key enabler: because inputs always run to completion, every
active ramp's (top-1 result, error score) is recorded for every sample —
so *any* threshold configuration can be evaluated offline against the
original model's outputs, accounting for inter-ramp dependencies (§3.2).

`RecordWindow` is the controller-side ring buffer of those records;
evaluation functions are vectorized numpy (the controller runs on host,
off the accelerator critical path).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


class RecordWindow:
    """Ring buffer over samples × feasible sites.

    unc[n, s]     uncertainty (1 - maxprob by default) of ramp s on sample n
    correct[n, s] ramp-s top-1 == original model top-1
    valid[n, s]   ramp s was active (recorded) when sample n was served
    """

    def __init__(self, n_sites: int, capacity: int = 2048):
        self.capacity = capacity
        self.n_sites = n_sites
        self.unc = np.full((capacity, n_sites), np.nan, np.float32)
        self.correct = np.zeros((capacity, n_sites), bool)
        self.valid = np.zeros((capacity, n_sites), bool)
        self.ptr = 0
        self.count = 0  # total samples ever observed

    def append(self, sites: Sequence[int], unc: np.ndarray, correct: np.ndarray):
        """sites: (K,) site indices; unc/correct: (K, B).

        When ``B > capacity`` only the newest ``capacity`` samples can
        survive; keep exactly those (``(ptr + arange(B)) % capacity``
        would produce duplicate ring indices, corrupting row order while
        ``count`` silently advanced past the write)."""
        B = unc.shape[1]
        keep = min(B, self.capacity)
        if keep < B:
            unc = unc[:, B - keep:]
            correct = correct[:, B - keep:]
        idx = (self.ptr + np.arange(keep)) % self.capacity
        self.unc[idx] = np.nan
        self.correct[idx] = False
        self.valid[idx] = False
        for j, s in enumerate(sites):
            self.unc[idx, s] = unc[j]
            self.correct[idx, s] = correct[j]
            self.valid[idx, s] = True
        self.ptr = int((self.ptr + keep) % self.capacity)
        self.count += B

    def last(self, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = min(n, self.count, self.capacity)
        idx = (self.ptr - n + np.arange(n)) % self.capacity
        return self.unc[idx], self.correct[idx], self.valid[idx]


def simulate_exits(
    unc: np.ndarray,
    valid: np.ndarray,
    thresholds: np.ndarray,
    active: Sequence[int],
) -> np.ndarray:
    """First active site (ascending site order) whose uncertainty clears its
    threshold; -1 = no exit. unc/valid: (N, S); thresholds: (S,)."""
    if len(active) == 0 or unc.shape[0] == 0:
        return np.full(unc.shape[0], -1, np.int64)
    act = np.asarray(sorted(active))
    # STRICT comparison: threshold 0 precludes exiting (paper's bootstrap
    # state) even for saturated uncertainty-0 records.
    sub = valid[:, act] & (unc[:, act] < thresholds[act][None, :])
    anyx = sub.any(axis=1)
    first = sub.argmax(axis=1)
    return np.where(anyx, act[first], -1)


@dataclasses.dataclass
class EvalResult:
    accuracy: float  # agreement w/ original model (non-exits count correct)
    mean_saved_ms: float  # mean latency delta vs vanilla (can be < 0)
    exit_rate: float
    exit_sites: np.ndarray  # per-sample site (-1 = none)


def evaluate_config(
    window_data,
    thresholds: np.ndarray,
    active: Sequence[int],
    profile,
    bs: int = 1,
) -> EvalResult:
    """Evaluate (thresholds, active-set) on recorded samples against the
    latency profile. window_data = (unc, correct, valid)."""
    unc, correct, valid = window_data
    N = unc.shape[0]
    if N == 0:
        return EvalResult(1.0, 0.0, 0.0, np.full(0, -1, np.int64))
    ex = simulate_exits(unc, valid, thresholds, active)
    acc = np.where(ex >= 0, correct[np.arange(N), np.clip(ex, 0, None)], True).mean()
    act = np.asarray(sorted(active))
    ovh = np.asarray([profile.ramp_overhead(s, bs) for s in act]) if len(act) else np.zeros(0)
    total_ovh = ovh.sum()
    saved = np.full(N, -total_ovh)
    for i, s in enumerate(act):
        m = ex == s
        if m.any():
            # released after ramp s: save downstream layers; pay ramps ≤ s
            saved[m] = profile.savings_at_site(s, bs) - ovh[: i + 1].sum()
    return EvalResult(float(acc), float(saved.mean()), float((ex >= 0).mean()), ex)


def ramp_utilities(
    window_data,
    thresholds: np.ndarray,
    active: Sequence[int],
    profile,
    bs: int = 1,
) -> dict:
    """Paper §3.3: utility(r) = Σ savings(exits at r) − Σ ovh(r)·(alive non-
    exits at r). Returns {site: utility_ms_total} over the window."""
    unc, correct, valid = window_data
    N = unc.shape[0]
    ex = simulate_exits(unc, valid, thresholds, active)
    act = sorted(active)
    out = {}
    alive = np.ones(N, bool)
    for s in act:
        exits_here = ex == s
        savings = profile.savings_at_site(s, bs)
        ovh = profile.ramp_overhead(s, bs)
        util = exits_here.sum() * savings - (alive & ~exits_here).sum() * ovh
        out[s] = float(util)
        alive = alive & ~exits_here
    return out


def exit_rates(window_data, thresholds, active) -> dict:
    unc, correct, valid = window_data
    ex = simulate_exits(unc, valid, thresholds, active)
    N = max(len(ex), 1)
    return {s: float((ex == s).sum() / N) for s in sorted(active)}
