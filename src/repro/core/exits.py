"""Exit-pattern evaluation over recorded ramp statistics.

The paper's key enabler: because inputs always run to completion, every
active ramp's (top-1 result, error score) is recorded for every sample —
so *any* threshold configuration can be evaluated offline against the
original model's outputs, accounting for inter-ramp dependencies (§3.2).

`RecordWindow` is the controller-side ring buffer of those records;
evaluation functions are vectorized numpy (the controller runs on host,
off the accelerator critical path).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


class RecordWindow:
    """Ring buffer over samples × feasible sites.

    unc[n, s]     uncertainty (1 - maxprob by default) of ramp s on sample n
    correct[n, s] ramp-s top-1 == original model top-1
    valid[n, s]   ramp s was active (recorded) when sample n was served
    """

    def __init__(self, n_sites: int, capacity: int = 2048):
        self.capacity = capacity
        self.n_sites = n_sites
        self.unc = np.full((capacity, n_sites), np.nan, np.float32)
        self.correct = np.zeros((capacity, n_sites), bool)
        self.valid = np.zeros((capacity, n_sites), bool)
        self.ptr = 0
        self.count = 0  # total samples ever observed

    def append(self, sites: Sequence[int], unc: np.ndarray, correct: np.ndarray):
        """sites: (K,) site indices; unc/correct: (K, B).

        When ``B > capacity`` only the newest ``capacity`` samples can
        survive; keep exactly those (``(ptr + arange(B)) % capacity``
        would produce duplicate ring indices, corrupting row order while
        ``count`` silently advanced past the write)."""
        B = unc.shape[1]
        keep = min(B, self.capacity)
        if keep < B:
            unc = unc[:, B - keep:]
            correct = correct[:, B - keep:]
        idx = (self.ptr + np.arange(keep)) % self.capacity
        self.unc[idx] = np.nan
        self.correct[idx] = False
        self.valid[idx] = False
        for j, s in enumerate(sites):
            self.unc[idx, s] = unc[j]
            self.correct[idx, s] = correct[j]
            self.valid[idx, s] = True
        self.ptr = int((self.ptr + keep) % self.capacity)
        self.count += B

    def last(self, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        n = min(n, self.count, self.capacity)
        idx = (self.ptr - n + np.arange(n)) % self.capacity
        return self.unc[idx], self.correct[idx], self.valid[idx]


def simulate_exits(
    unc: np.ndarray,
    valid: np.ndarray,
    thresholds: np.ndarray,
    active: Sequence[int],
) -> np.ndarray:
    """First active site (ascending site order) whose uncertainty clears its
    threshold; -1 = no exit. unc/valid: (N, S); thresholds: (S,)."""
    if len(active) == 0 or unc.shape[0] == 0:
        return np.full(unc.shape[0], -1, np.int64)
    act = np.asarray(sorted(active))
    # STRICT comparison: threshold 0 precludes exiting (paper's bootstrap
    # state) even for saturated uncertainty-0 records.
    sub = valid[:, act] & (unc[:, act] < thresholds[act][None, :])
    anyx = sub.any(axis=1)
    first = sub.argmax(axis=1)
    return np.where(anyx, act[first], -1)


def simulate_exits_many(
    unc: np.ndarray,
    valid: np.ndarray,
    thr_batch: np.ndarray,
    active: Sequence[int],
) -> np.ndarray:
    """Vectorized `simulate_exits` over C candidate threshold vectors in
    one pass. thr_batch: (C, S); returns (C, N) exit sites (-1 = none).
    Row c is bit-identical to `simulate_exits(unc, valid, thr_batch[c],
    active)` — the adaptation hot loop depends on that."""
    C, N = thr_batch.shape[0], unc.shape[0]
    if len(active) == 0 or N == 0:
        return np.full((C, N), -1, np.int64)
    act = np.asarray(sorted(active))
    sub = valid[None, :, act] & (unc[None, :, act] < thr_batch[:, None, act])
    anyx = sub.any(axis=2)
    first = sub.argmax(axis=2)
    return np.where(anyx, act[first], -1)


@dataclasses.dataclass
class EvalResult:
    accuracy: float  # agreement w/ original model (non-exits count correct)
    mean_saved_ms: float  # mean latency delta vs vanilla (can be < 0)
    exit_rate: float
    exit_sites: np.ndarray  # per-sample site (-1 = none)


def site_cost_vectors(profile, active: Sequence[int], bs: int = 1):
    """Per-active-site (overhead, savings) vectors, in sorted-site order.
    Hoisted out of the evaluation loop so a tuning round prices its K
    candidates without re-walking the latency profile K times."""
    act = sorted(active)
    ovh = np.asarray([profile.ramp_overhead(s, bs) for s in act]) if act else np.zeros(0)
    sav = np.asarray([profile.savings_at_site(s, bs) for s in act]) if act else np.zeros(0)
    return ovh, sav


def evaluate_configs(
    window_data,
    thr_batch: np.ndarray,
    active: Sequence[int],
    profile,
    bs: int = 1,
    *,
    ovh: Optional[np.ndarray] = None,
    sav: Optional[np.ndarray] = None,
):
    """Vectorized `evaluate_config` over C candidate threshold vectors:
    one `simulate_exits_many` pass instead of C sequential evaluations
    (the threshold-tuning hot loop). thr_batch: (C, S). Returns
    (accuracy (C,), mean_saved_ms (C,), exit_rate (C,), exit_sites (C, N));
    row c is bit-identical to `evaluate_config(..., thr_batch[c], ...)`.
    ``ovh``/``sav`` accept the precomputed `site_cost_vectors` output."""
    unc, correct, valid = window_data
    thr_batch = np.asarray(thr_batch)
    C, N = thr_batch.shape[0], unc.shape[0]
    if N == 0:
        return (np.ones(C), np.zeros(C), np.zeros(C), np.full((C, 0), -1, np.int64))
    ex = simulate_exits_many(unc, valid, thr_batch, active)
    acc = np.where(
        ex >= 0, correct[np.arange(N)[None, :], np.clip(ex, 0, None)], True
    ).mean(axis=1)
    act = np.asarray(sorted(active))
    if ovh is None or sav is None:
        ovh, sav = site_cost_vectors(profile, active, bs)
    total_ovh = ovh.sum()
    if len(act):
        # released after ramp s: save downstream layers; pay ramps <= s.
        # Python-loop prefix sums match evaluate_config's sequential
        # `ovh[:i+1].sum()` accumulation exactly (np.cumsum may not).
        val = np.asarray([sav[i] - ovh[: i + 1].sum() for i in range(len(act))])
        pos = np.searchsorted(act, np.clip(ex, 0, None))
        saved = np.where(ex >= 0, val[pos], -total_ovh)
    else:
        saved = np.full((C, N), -total_ovh)
    return acc, saved.mean(axis=1), (ex >= 0).mean(axis=1), ex


def evaluate_config(
    window_data,
    thresholds: np.ndarray,
    active: Sequence[int],
    profile,
    bs: int = 1,
) -> EvalResult:
    """Evaluate (thresholds, active-set) on recorded samples against the
    latency profile. window_data = (unc, correct, valid)."""
    unc, correct, valid = window_data
    N = unc.shape[0]
    if N == 0:
        return EvalResult(1.0, 0.0, 0.0, np.full(0, -1, np.int64))
    acc, saved, rate, ex = evaluate_configs(
        window_data, np.asarray(thresholds)[None, :], active, profile, bs
    )
    return EvalResult(float(acc[0]), float(saved[0]), float(rate[0]), ex[0])


def ramp_utilities(
    window_data,
    thresholds: np.ndarray,
    active: Sequence[int],
    profile,
    bs: int = 1,
    *,
    ex: Optional[np.ndarray] = None,
) -> dict:
    """Paper §3.3: utility(r) = Σ savings(exits at r) − Σ ovh(r)·(alive non-
    exits at r). Returns {site: utility_ms_total} over the window. ``ex``
    accepts a precomputed `simulate_exits` result so callers evaluating the
    same (window, thresholds, active) don't re-simulate."""
    unc, correct, valid = window_data
    N = unc.shape[0]
    if ex is None:
        ex = simulate_exits(unc, valid, thresholds, active)
    act = sorted(active)
    out = {}
    alive = np.ones(N, bool)
    for s in act:
        exits_here = ex == s
        savings = profile.savings_at_site(s, bs)
        ovh = profile.ramp_overhead(s, bs)
        util = exits_here.sum() * savings - (alive & ~exits_here).sum() * ovh
        out[s] = float(util)
        alive = alive & ~exits_here
    return out


def exit_rates(window_data, thresholds, active, *, ex: Optional[np.ndarray] = None) -> dict:
    unc, correct, valid = window_data
    if ex is None:
        ex = simulate_exits(unc, valid, thresholds, active)
    N = max(len(ex), 1)
    return {s: float((ex == s).sum() / N) for s in sorted(active)}
