"""Apparate core: early-exit management (the paper's contribution)."""
from repro.core.controller import ApparateController, ControllerConfig
from repro.core.exits import (
    RecordWindow,
    evaluate_config,
    exit_rates,
    ramp_utilities,
    simulate_exits,
)
from repro.core.profiles import LatencyProfile, build_profile
from repro.core.ramp_adjust import adjust_ramps
from repro.core.threshold_tuning import grid_search_thresholds, tune_thresholds

__all__ = [
    "ApparateController",
    "ControllerConfig",
    "RecordWindow",
    "evaluate_config",
    "exit_rates",
    "ramp_utilities",
    "simulate_exits",
    "LatencyProfile",
    "build_profile",
    "adjust_ramps",
    "tune_thresholds",
    "grid_search_thresholds",
]
