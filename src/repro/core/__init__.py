"""Apparate core: early-exit management (the paper's contribution)."""
from repro.core.controller import ApparateController, ControllerConfig
from repro.core.exits import (
    RecordWindow,
    evaluate_config,
    evaluate_configs,
    exit_rates,
    ramp_utilities,
    simulate_exits,
    simulate_exits_many,
    site_cost_vectors,
)
from repro.core.profiles import LatencyProfile, build_profile
from repro.core.ramp_adjust import adjust_ramps
from repro.core.threshold_tuning import (
    grid_search_thresholds,
    tune_thresholds,
    tune_thresholds_reference,
)

__all__ = [
    "ApparateController",
    "ControllerConfig",
    "RecordWindow",
    "evaluate_config",
    "evaluate_configs",
    "exit_rates",
    "ramp_utilities",
    "simulate_exits",
    "simulate_exits_many",
    "site_cost_vectors",
    "LatencyProfile",
    "build_profile",
    "adjust_ramps",
    "tune_thresholds",
    "tune_thresholds_reference",
    "grid_search_thresholds",
]
