"""The Apparate controller (paper §3, Fig 7).

Consumes per-batch ramp records streamed from the accelerator (top-1 label
+ confidence per active ramp + the original model's top-1 — ~1KB/batch),
maintains the record window, issues exit decisions, and runs the two
adaptation loops:

  * accuracy monitor: 16-sample windowed agreement; tuning triggered the
    moment it drops below the constraint (§3.2);
  * periodic ramp adjustment every `adjust_every` samples (§3.3).

The controller is pure host-side numpy — on real hardware it runs on CPU
while the TPU streams records non-blocking, exactly like the paper's
CPU/GPU split.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.exits import RecordWindow, evaluate_config, simulate_exits
from repro.core.ramp_adjust import adjust_ramps
from repro.core.threshold_tuning import tune_thresholds


@dataclasses.dataclass
class ControllerConfig:
    acc_constraint: float = 0.99  # min agreement w/ original model
    ramp_budget_frac: float = 0.02  # max Σ ramp-overhead / vanilla latency
    max_slots: int = 8  # K gather slots on the accelerator
    monitor_window: int = 16  # paper: accuracy over past 16 samples
    tune_window: int = 512  # samples used to evaluate threshold configs
    adjust_every: int = 128  # paper: ramp adjustment every 128 samples
    metric: str = "maxprob"  # 'maxprob' -> unc = 1-maxprob | 'entropy'
    min_samples_to_tune: int = 32
    uniform_init: bool = True  # evenly space initial ramps (paper)


@dataclasses.dataclass
class BatchDecisions:
    exit_sites: np.ndarray  # (B,) site index or -1
    released_labels: np.ndarray  # (B,) label released to the client
    exited_early: np.ndarray  # (B,) bool


class ApparateController:
    def __init__(self, n_sites: int, profile, cfg: ControllerConfig = ControllerConfig()):
        self.n_sites = n_sites
        self.profile = profile
        self.cfg = cfg
        self.window = RecordWindow(n_sites, capacity=max(cfg.tune_window * 4, 2048))
        self.thresholds = np.zeros(n_sites, np.float32)
        self.active: List[int] = self._initial_ramps()
        self._since_adjust = 0
        self.stats = {
            "tunes": 0,
            "adjusts": 0,
            "ramp_changes": 0,
            "samples": 0,
            "tune_wall_s": 0.0,
        }
        self.history: List[dict] = []

    # -- initial placement (paper §3.1: evenly space max allowable ramps) ----

    def _initial_ramps(self) -> List[int]:
        k = min(
            self.cfg.max_slots,
            self.profile.max_ramps_within_budget(self.cfg.ramp_budget_frac, bs=1),
            self.n_sites,
        )
        if k <= 0:
            return []
        pos = np.linspace(0, self.n_sites - 1, k + 1, endpoint=False)[1:]
        return sorted({int(round(p)) for p in pos})

    # -- record ingestion ------------------------------------------------------

    def uncertainty(self, stats: dict) -> np.ndarray:
        if self.cfg.metric == "entropy":
            # normalized entropy in [0, 1]: H / log(n_classes). The class
            # count must come from the caller — inferring it from the
            # observed entropy can under-estimate the normalizer and yield
            # uncertainties > 1 (thresholds in [0,1] then never preclude
            # exiting on those records).
            if "n_classes" not in stats:
                raise KeyError(
                    "entropy metric requires 'n_classes' in the stats dict "
                    "(normalizer log(n_classes))"
                )
            return np.asarray(stats["entropy"]) / np.log(max(float(stats["n_classes"]), 2.0))
        return 1.0 - np.asarray(stats["maxprob"])

    def observe(
        self,
        ramp_labels: np.ndarray,  # (K, B)
        ramp_unc: np.ndarray,  # (K, B) uncertainty (already metric-mapped)
        final_labels: np.ndarray,  # (B,)
        *,
        forced_exits: Optional[np.ndarray] = None,  # (B,) device-decided sites
        act: Optional[Sequence[int]] = None,  # pin the record's active set
    ) -> BatchDecisions:
        """Ingest one batch of records; return exit decisions for it.

        ``forced_exits`` replays exit sites already decided ON DEVICE (the
        sync-window runner's fused kernel): the records still enter the
        adaptation window — replay-completeness — but the serving
        decision honors what the device did under its (possibly stale)
        threshold copy instead of re-simulating under thresholds that may
        have just been retuned. ``act`` pins the active-site set the
        records were GATHERED under: a mid-window ``_adjust`` can change
        ``self.active``, and later replayed steps of that window must
        still land their rows against the sites that produced them."""
        act = list(self.active) if act is None else list(act)
        B = final_labels.shape[0]
        K = len(act)
        correct = ramp_labels[:K] == final_labels[None, :]
        self.window.append(act, ramp_unc[:K], correct)
        self.stats["samples"] += B
        self._since_adjust += B

        # decisions for THIS batch under current thresholds
        unc_m = np.full((B, self.n_sites), np.nan, np.float32)
        val_m = np.zeros((B, self.n_sites), bool)
        cor_m = np.zeros((B, self.n_sites), bool)
        for j, s in enumerate(act):
            unc_m[:, s] = ramp_unc[j]
            val_m[:, s] = True
            cor_m[:, s] = correct[j]
        if forced_exits is None:
            ex = simulate_exits(unc_m, val_m, self.thresholds, act)
        else:
            ex = np.asarray(forced_exits, np.int64).copy()
        released = np.asarray(final_labels).copy()
        for j, s in enumerate(act):
            m = ex == s
            released[m] = ramp_labels[j][m]

        # --- monitor: windowed accuracy triggers tuning (paper 16 samples)
        wd = self.window.last(self.cfg.monitor_window)
        mon = evaluate_config(wd, self.thresholds, act, self.profile)
        if (
            mon.accuracy < self.cfg.acc_constraint
            and self.window.count >= self.cfg.min_samples_to_tune
        ):
            self._tune()

        # --- periodic ramp adjustment
        if self._since_adjust >= self.cfg.adjust_every:
            self._since_adjust = 0
            self._adjust()

        return BatchDecisions(ex, released, ex >= 0)

    # -- adaptation -------------------------------------------------------------

    def _tune(self):
        wd = self.window.last(self.cfg.tune_window)
        res = tune_thresholds(
            wd,
            self.active,
            self.profile,
            n_sites=self.n_sites,
            acc_constraint=self.cfg.acc_constraint,
        )
        self.thresholds = res.thresholds
        self.stats["tunes"] += 1
        self.stats["tune_wall_s"] += res.wall_s
        self.history.append(
            {"kind": "tune", "acc": res.accuracy, "sav": res.savings_ms,
             "sample": self.stats["samples"]}
        )

    def _adjust(self):
        if self.window.count < self.cfg.min_samples_to_tune:
            return
        wd = self.window.last(self.cfg.tune_window)
        res = adjust_ramps(
            wd,
            self.active,
            self.thresholds,
            self.profile,
            n_sites=self.n_sites,
            acc_constraint=self.cfg.acc_constraint,
            budget_frac=self.cfg.ramp_budget_frac,
            max_slots=self.cfg.max_slots,
        )
        changed = set(res.active) != set(self.active)
        self.active = list(res.active)
        self.thresholds = res.thresholds
        self.stats["adjusts"] += 1
        if changed:
            self.stats["ramp_changes"] += 1
            # fresh trial ramps need records before thresholds move; tuning
            # will re-trigger via the monitor as data accrues
        self.history.append(
            {"kind": "adjust", "reason": res.reason, "active": list(res.active),
             "sample": self.stats["samples"]}
        )

    # -- serving-side helpers ----------------------------------------------------

    def active_slots(self, pad_to: Optional[int] = None) -> np.ndarray:
        """Active site indices padded to the accelerator's K gather slots."""
        k = pad_to or self.cfg.max_slots
        act = sorted(self.active)[:k]
        pad = act + [act[-1] if act else 0] * (k - len(act))
        return np.asarray(pad, np.int32)

    def total_ramp_overhead(self, bs: int = 1) -> float:
        return sum(self.profile.ramp_overhead(s, bs) for s in self.active)
