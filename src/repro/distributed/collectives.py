"""Distributed-optimization tricks.

int8 error-feedback gradient all-reduce: quantize per-block to int8 before
the cross-pod reduction (the DCI hop between pods is the scarce link at
512+ chips), all-reduce int32-accumulated, dequantize, and carry the
quantization residual into the next step (error feedback keeps SGD/Adam
convergence — Seide et al., 1-bit SGD lineage). 4× wire-byte reduction on
the gradient sync.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax

from repro.compat import shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str, residual: jax.Array, block: int = 256):
    """Inside shard_map: int8 error-feedback all-reduce over `axis_name`.

    A shared per-block scale (pmax of local amax — 1/256 of the payload)
    makes the int8 payloads summable; residual carries the quantization
    error into the next step. Returns (reduced fp value, new residual)."""
    y = (x + residual).astype(jnp.float32)
    flat = y.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    local_amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    shared_amax = jax.lax.pmax(local_amax, axis_name)  # small collective
    scale = jnp.maximum(shared_amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    sent = (q.astype(jnp.float32) * scale).reshape(-1)[: y.size].reshape(y.shape)
    new_residual = y - sent
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int8 on the wire
    out = (summed.astype(jnp.float32) * scale).reshape(-1)[: y.size].reshape(y.shape)
    return out, new_residual


def make_compressed_grad_allreduce(mesh, axis_name: str = "pod"):
    """Returns f(grads_tree, residual_tree) -> (summed_grads, new_residuals),
    each leaf all-reduced over `axis_name` with int8 error feedback. Leaves
    are assumed replicated over `axis_name` pre-reduction (per-pod grads)."""

    def leaf_fn(g, r):
        return compressed_psum(g, axis_name, r)

    def mapped(grads, residuals):
        pairs = jax.tree.map(leaf_fn, grads, residuals)
        outs = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return outs, res

    def run(grads, residuals):
        spec = jax.tree.map(lambda _: P(), grads)
        return shard_map(
            mapped, mesh=mesh,
            in_specs=(spec, spec), out_specs=(spec, spec), check_vma=False,
        )(grads, residuals)

    return run
