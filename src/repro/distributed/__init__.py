from repro.distributed.collectives import (
    compressed_psum,
    dequantize_int8,
    make_compressed_grad_allreduce,
    quantize_int8,
)
from repro.distributed.pipeline import pipeline_apply
