"""GPipe-style pipeline parallelism demonstrator (shard_map + ppermute).

Maps a stack of identical stages onto a mesh axis: microbatches stream
through stages with collective_permute between neighbors; the classic
(S + M - 1) schedule. This demonstrates PP composition for configs where
DP×TP×EP is not enough (e.g. >8k-chip jobs); the assigned cells use
DP/FSDP×TP×EP which is the right fit for v5e pods (DESIGN.md §5).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax

from repro.compat import shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(
    mesh,
    axis: str,
    stage_fn: Callable,  # (stage_params, x) -> x
    stacked_params,  # leaves with leading dim = n_stages
    x,  # (n_micro, mb, ...) microbatched input
):
    """Run x through n_stages stages living on mesh axis `axis`."""
    n_stages = mesh.shape[axis]

    def mapped(params, xs):
        # params: this stage's slice (leading dim 1); xs: full microbatch set
        sid = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params)
        n_micro = xs.shape[0]
        total = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])  # current in-flight microbatch
        outs = jnp.zeros_like(xs)

        def step(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if any); others take the permuted input
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(sid == 0, xs[inject], buf)
            y = stage_fn(p, x_in)
            # last stage writes result for microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            write = (sid == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                write,
                lambda o: o.at[jnp.maximum(out_idx, 0)].set(y),
                lambda o: o,
                outs,
            )
            # pass activations downstream
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, n_micro + n_stages - 1, step, (buf, outs))
        # results live on the last stage only; broadcast (all other stages
        # contributed zeros, so a psum is an exact broadcast)
        return jax.lax.psum(outs, axis)

    in_specs = (
        jax.tree.map(lambda _: P(axis), stacked_params),
        P(),  # microbatches replicated in; real deployments shard the batch dim
    )
    return shard_map(
        mapped, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False,
    )(stacked_params, x)
