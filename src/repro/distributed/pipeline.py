"""Pipeline parallelism: GPipe demonstrator + exit-gated decode windows.

``pipeline_apply`` maps a stack of identical stages onto a mesh axis:
microbatches stream through stages with collective_permute between
neighbors; the classic (S + M - 1) schedule.

``pipeline_decode_window`` is the SERVING path: a multi-token decode
window over pipeline-sharded period blocks where per-row EARLY-EXIT masks
gate the ``ppermute`` forwarding — a row whose boundary ramp fires takes
the ramp label as its token and never enters later stages (its slot in
the microbatch stops contributing to downstream stage-step counters),
turning early exits into the paper's pipeline-escape throughput win. When
every row of a microbatch has exited, the whole payload goes inert and
the window terminates early. The 1-stage mesh degenerates to plain
batched multi-step decode sharing one weight upload.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax

from repro.compat import shard_map
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(
    mesh,
    axis: str,
    stage_fn: Callable,  # (stage_params, x) -> x
    stacked_params,  # leaves with leading dim = n_stages
    x,  # (n_micro, mb, ...) microbatched input
):
    """Run x through n_stages stages living on mesh axis `axis`."""
    n_stages = mesh.shape[axis]

    def mapped(params, xs):
        # params: this stage's slice (leading dim 1); xs: full microbatch set
        sid = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params)
        n_micro = xs.shape[0]
        total = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])  # current in-flight microbatch
        outs = jnp.zeros_like(xs)

        def step(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if any); others take the permuted input
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(sid == 0, xs[inject], buf)
            y = stage_fn(p, x_in)
            # last stage writes result for microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            write = (sid == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                write,
                lambda o: o.at[jnp.maximum(out_idx, 0)].set(y),
                lambda o: o,
                outs,
            )
            # pass activations downstream
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, n_micro + n_stages - 1, step, (buf, outs))
        # results live on the last stage only; broadcast (all other stages
        # contributed zeros, so a psum is an exact broadcast)
        return jax.lax.psum(outs, axis)

    in_specs = (
        jax.tree.map(lambda _: P(axis), stacked_params),
        P(),  # microbatches replicated in; real deployments shard the batch dim
    )
    return shard_map(
        mapped, mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False,
    )(stacked_params, x)


def pipeline_check(model, n_stages: int, batch: Optional[int] = None) -> None:
    """Raise ``NotImplementedError`` (why-note surfaced by the support
    matrix) when this plan/config cannot run the exit-gated pipeline
    decode path at ``n_stages`` stages."""
    cfg, plan = model.cfg, model.plan
    if plan.prefix or plan.suffix:
        raise NotImplementedError(
            "pipeline decode shards the scanned period blocks only: plans "
            "with prefix/suffix layers (first_k_dense, trailing globals) "
            "have no uniform stage split"
        )
    for slot in plan.period:
        if slot.mixer != "attn" or slot.cross:
            raise NotImplementedError(
                f"pipeline decode supports attention-mixer stages only "
                f"(got mixer={slot.mixer!r}, cross={slot.cross})"
            )
        if slot.ffn == "moe":
            raise NotImplementedError(
                "pipeline decode stages run single-device: MoE slots need "
                "the expert-parallel `model` axis the stage mesh does not "
                "carry"
            )
        if slot.is_local:
            raise NotImplementedError(
                "local-window slots pin ring caches whose chronological "
                "gather is not stage-local"
            )
    if cfg.window:
        raise NotImplementedError("windowed attention plans are not staged")
    if str(getattr(cfg, "decode_attn", "ref")).startswith("paged"):
        raise NotImplementedError(
            "pipeline decode reads the contiguous slot cache; the paged "
            "block pool shards per-device over `model`, not over stages"
        )
    if str(cfg.pallas_head) != "off":
        raise NotImplementedError(
            "the fused ramp-head kernel is per-device; pipeline boundary "
            "ramps use the dense head"
        )
    if plan.n_periods % n_stages:
        raise NotImplementedError(
            f"n_periods={plan.n_periods} not divisible by "
            f"n_stages={n_stages}"
        )
    if batch is not None and batch % n_stages:
        raise NotImplementedError(
            f"decode batch {batch} not divisible into {n_stages} "
            "microbatches"
        )


def pipeline_decode_window(model, params, cache, tokens, pos, n_steps, *,
                           mesh, axis: str = "stage", active_sites=None,
                           thresholds=None):
    """Multi-token decode window over pipeline-sharded period blocks with
    EXIT-GATED forwarding.

    The ``axis`` mesh dimension carries ``S`` stages; stage ``s`` owns
    periods ``[s·L/S, (s+1)·L/S)`` of the scanned blocks (params AND the
    contiguous KV cache shard on the leading period axis — per-device KV
    bytes are ``total / S``). The batch splits into ``S`` microbatches
    that stream through stages on a ``ppermute`` ring: one payload is
    resident per stage per tick, so after the fill every stage works
    every tick and a full token step costs ``S`` ticks per microbatch.

    Early-exit contract (the Apparate pipeline escape): after its LAST
    local period, a non-final stage evaluates the boundary ramp for any
    ``active_sites`` entry sitting at that layer; a row whose uncertainty
    clears the threshold (strict ``<``, matching ``_head_stats``) takes
    the RAMP label as its step-``k`` token and goes dead for the rest of
    the window — later stages never count it (see ``stage_steps``) and
    once a whole microbatch is dead its payload goes inert (its ticks
    stop costing stage work) and the window can terminate early. With
    ``thresholds`` all-zero no exit can fire and the emitted tokens are
    bit-identical to plain (single-device) greedy decode — the anchor the
    tests pin.

    tokens: (B,1) int32; pos: int32[B] per-row write indices; ``n_steps``
    static. Returns ``(new_cache, tok_rec (n_steps,B), exit_rec
    (n_steps,B), alive (B,), stage_steps (S,))`` — ``exit_rec[k,b]`` is
    the global ramp-site index that fired for row ``b`` at step ``k``
    (−1 = none); ``tok_rec`` entries after a row's exit step are garbage
    the caller must mask (exactly like ``decode_multi``'s packed
    records); ``stage_steps[s]`` counts alive-row×step work stage ``s``
    actually ran.
    """
    from repro.models import layers as LY
    from repro.models.transformer import _mask_pad_vocab

    cfg, plan = model.cfg, model.plan
    S = mesh.shape[axis]
    B = int(tokens.shape[0])
    pipeline_check(model, S, batch=B)
    n_steps = int(n_steps)
    Bm = B // S
    n_slots = len(plan.period)
    Lp = plan.n_periods // S  # periods per stage

    # host-side ramp routing: stage s's boundary layer -> active-site row
    sites = list(model.sites)
    act = [] if active_sites is None else [int(a) for a in active_sites]
    thr_in = ([0.0] * len(act) if thresholds is None
              else [float(t) for t in thresholds])
    site_idx_per_stage = [0] * S   # index into model.sites (for ramp params)
    thr_per_stage = [0.0] * S      # 0.0 can never fire (strict <)
    for s in range(S - 1):
        boundary = (s + 1) * Lp * n_slots - 1
        for j, a in enumerate(act):
            if sites[a] == boundary:
                site_idx_per_stage[s] = a
                thr_per_stage[s] = thr_in[j]
    site_arr = jnp.asarray(site_idx_per_stage, jnp.int32)
    thr_arr = jnp.asarray(thr_per_stage, jnp.float32)

    tokens = jnp.asarray(tokens, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)

    def ramp_stats(p, h, si):
        """Boundary ramp label/uncertainty for one site — the dense
        ``ramp_outputs``/``_stats`` math specialized to K=1, npos=1."""
        hs = h[:, 0]  # (Bm, d)
        nw = p["ramps"]["norm_w"][si]
        hs = LY.rms_norm(hs, nw[None, :])
        if cfg.ramp_style == "mlp":
            w1, w2 = p["ramps"]["w1"][si], p["ramps"]["w2"][si]
            hs = hs + jax.nn.gelu(hs @ w1) @ w2
        if cfg.ramp_style == "tied":
            hw = (p["tok"]["embed"].T if cfg.tie_embeddings
                  else p["tok"]["lm_head"])
        else:
            hw = p["ramps"]["head"][si]
        logits = _mask_pad_vocab(cfg, (hs @ hw).astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        maxprob = jnp.exp(jnp.max(logits, axis=-1) - lse)
        return lab, 1.0 - maxprob

    def final_label(p, h):
        hn = LY.apply_norm(cfg, p["final_norm"], h)
        logits = LY.unembed(cfg, p["tok"], hn)[:, 0].astype(jnp.float32)
        logits = _mask_pad_vocab(cfg, logits)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def mapped(p, cb, toks, po, site_a, thr_a):
        sid = jax.lax.axis_index(axis)
        last = S - 1
        mb0 = (S - sid) % S  # payload j enters stage 0 at tick j

        pl = dict(
            mb=mb0.astype(jnp.int32),
            h=jnp.zeros((Bm, 1, cfg.d_model), jnp.dtype(cfg.dtype)),
            tok=jax.lax.dynamic_slice_in_dim(toks, mb0 * Bm, Bm, 0),
            k=jnp.zeros((), jnp.int32),
            nxt=jnp.zeros((), jnp.int32),
            alive=jnp.ones((Bm,), bool),
            done=jnp.asarray(n_steps <= 0),
            tok_rec=jnp.zeros((max(n_steps, 1), Bm), jnp.int32),
            exit_rec=jnp.full((max(n_steps, 1), Bm), -1, jnp.int32),
        )
        steps = jnp.zeros((), jnp.int32)

        def tick(carry):
            t, pl, cb, steps, _ = carry
            proc = (pl["nxt"] == sid) & ~pl["done"]
            pos_mb = jax.lax.dynamic_slice_in_dim(po, pl["mb"] * Bm, Bm, 0) + pl["k"]
            pc = pos_mb.reshape(-1, 1)
            h = jnp.where(
                sid == 0,
                LY.embed_apply(cfg, p["tok"], pl["tok"], pc).astype(pl["h"].dtype),
                pl["h"],
            )
            cb_mb = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(x, pl["mb"] * Bm, Bm, 1),
                cb,
            )
            Sc = jax.tree.leaves(cb)[0].shape[2]
            kpos = jnp.arange(Sc)[None, :]
            mask = (kpos <= pc)[:, None, None, :]
            h, _, ncb, _ = model._stack(
                p, h, positions=pc, mask_full=mask, mask_local=mask,
                axes=LY.TEST_AXES, mesh=None, caches={"blocks": cb_mb},
                cache_index=pos_mb, memory=None, moe_impl="dense",
                pool_idx=jnp.asarray([0], jnp.int32),
            )
            cb2 = jax.tree.map(
                lambda big, sub: jnp.where(
                    proc,
                    jax.lax.dynamic_update_slice_in_dim(
                        big, sub.astype(big.dtype), pl["mb"] * Bm, 1),
                    big,
                ),
                cb, ncb["blocks"],
            )
            steps = steps + jnp.where(proc, jnp.sum(pl["alive"].astype(jnp.int32)), 0)

            # -- boundary: non-final stages evaluate their exit ramp -------
            if act and S > 1:
                rl, runc = ramp_stats(p, h, site_a[sid])
                fire = ((sid != last) & proc & pl["alive"]
                        & (runc < thr_a[sid]))
            else:
                rl = jnp.zeros((Bm,), jnp.int32)
                fire = jnp.zeros((Bm,), bool)
            tok_rec = pl["tok_rec"].at[pl["k"]].set(
                jnp.where(fire, rl, pl["tok_rec"][pl["k"]]))
            exit_rec = pl["exit_rec"].at[pl["k"]].set(
                jnp.where(fire, site_a[sid], pl["exit_rec"][pl["k"]]))
            alive = pl["alive"] & ~fire

            # -- final stage: head, token, step count ----------------------
            fl = final_label(p, h)
            at_last = (sid == last) & proc
            tok_rec = jnp.where(
                at_last,
                tok_rec.at[pl["k"]].set(
                    jnp.where(alive, fl, tok_rec[pl["k"]])),
                tok_rec,
            )
            new_tok = jnp.where(
                at_last,
                jnp.where(alive[:, None], fl[:, None], pl["tok"]),
                pl["tok"],
            )
            k2 = pl["k"] + at_last.astype(jnp.int32)
            done2 = pl["done"] | (at_last & (
                (k2 >= n_steps) | ~jnp.any(alive)))
            nxt2 = jnp.where(sid == last, 0, sid + 1).astype(jnp.int32)

            pl2 = dict(
                mb=pl["mb"], h=h.astype(pl["h"].dtype), tok=new_tok, k=k2,
                nxt=nxt2, alive=alive, done=done2, tok_rec=tok_rec,
                exit_rec=exit_rec,
            )
            # a payload not being processed this tick rides through unchanged
            pl2 = jax.tree.map(
                lambda new, old: jnp.where(proc, new, old), pl2, pl)
            perm = [(i, (i + 1) % S) for i in range(S)]
            pl2 = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, perm), pl2)
            all_done = jax.lax.psum(pl2["done"].astype(jnp.int32), axis) >= S
            return t + 1, pl2, cb2, steps, all_done

        def cond(carry):
            t, _, _, _, all_done = carry
            return (t < n_steps * S + S) & ~all_done

        _, pl, cb, steps, _ = jax.lax.while_loop(
            cond, tick, (jnp.zeros((), jnp.int32), pl, cb, steps,
                         jnp.asarray(False)))

        # reassemble records: each microbatch's rows live in exactly one
        # payload — scatter into (n_micro, ...) zeros and psum (an exact
        # broadcast-sum, every other stage contributes zeros)
        def collect(x, fill=0):
            buf = jnp.zeros((S,) + x.shape, x.dtype).at[pl["mb"]].set(x - fill)
            return jax.lax.psum(buf, axis) + fill

        tok_rec = collect(pl["tok_rec"])              # (S, n_steps, Bm)
        exit_rec = collect(pl["exit_rec"], fill=-1)   # (S, n_steps, Bm)
        alive = collect(pl["alive"].astype(jnp.int32))
        tok_rec = jnp.moveaxis(tok_rec, 0, 1).reshape(max(n_steps, 1), B)
        exit_rec = jnp.moveaxis(exit_rec, 0, 1).reshape(max(n_steps, 1), B)
        alive = alive.reshape(B).astype(bool)
        return cb, tok_rec, exit_rec, alive, steps[None]

    cspec = jax.tree.map(
        lambda x: P(axis, *([None] * (x.ndim - 1))), cache["blocks"])
    pspec = {k: jax.tree.map(lambda _: P(), v)
             for k, v in params.items() if k != "blocks"}
    pspec["blocks"] = jax.tree.map(
        lambda x: P(axis, *([None] * (x.ndim - 1))), params["blocks"])
    new_cb, tok_rec, exit_rec, alive, steps = shard_map(
        mapped, mesh=mesh,
        in_specs=(pspec, cspec, P(), P(), P(), P()),
        out_specs=(cspec, P(), P(), P(), P(axis)),
        check_vma=False,
    )(params, cache["blocks"], tokens, pos, site_arr, thr_arr)
    return ({"blocks": new_cb}, tok_rec[:n_steps], exit_rec[:n_steps],
            alive, steps)
