"""Hand-built optimizers (no optax dependency): AdamW + Adafactor-lite,
global-norm clipping, cosine/linear schedules, and parameter masking (for
frozen-backbone ramp training)."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32


def adamw_init(params, cfg: AdamWConfig):
    z = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(z, params),
        "nu": jax.tree.map(z, params),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0, mask=None):
    """mask: pytree of bools (True = trainable). Frozen params keep value."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9)) if cfg.clip_norm else 1.0

    def upd(p, g, mu, nu, m):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu2 / (1 - cfg.b1 ** step.astype(jnp.float32))
        nhat = nu2 / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - cfg.lr * lr_scale * delta
        if m is not None:
            newp = jnp.where(m, newp, p.astype(jnp.float32))
            mu2 = jnp.where(m, mu2, mu)
            nu2 = jnp.where(m, nu2, nu)
        return newp.astype(p.dtype), mu2.astype(mu.dtype), nu2.astype(nu.dtype)

    if mask is None:
        mask = jax.tree.map(lambda _: None, params)
    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"], mask,
                       is_leaf=lambda x: x is None)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"step": step, "mu": mu, "nu": nu}, gn


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        return base_lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog)) / base_lr

    return f  # returns lr_scale in [0,1]


# --- Adafactor-lite: factored second moments for huge embeddings -----------


def adafactor_init(params):
    def z(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"step": jnp.zeros((), jnp.int32), "v": jax.tree.map(z, params)}


def adafactor_update(params, grads, state, lr=1e-2, decay=0.8, eps=1e-30, clip=1.0):
    step = state["step"] + 1
    beta = 1.0 - (step.astype(jnp.float32) + 1) ** (-decay)

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, -1, keepdims=True), eps)
            u = g / jnp.sqrt(
                vr[..., None] * vc[..., None, :] / denom[..., None] + eps
            )
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta * v["v"] + (1 - beta) * g2}
            u = g / jnp.sqrt(nv["v"] + eps)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

    out = jax.tree.map(
        upd, params, grads, state["v"],
        is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x),
    )
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nv = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"step": step, "v": nv}
