"""Bootstrap ramp training (paper §3.1).

Properties enforced:
  * backbone FROZEN — optimizer masking (ramps_only) + stop-gradient on
    pooled features inside the model, so non-EE behavior and accuracy
    feedback are unchanged;
  * NO exiting during training — every ramp sees every input, making ramps
    independent of whichever upstream ramps happen to be active at runtime;
  * per-ramp losses are independent terms of one scalar loss → a single
    backward pass trains all ramps in parallel.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import numpy as np

from repro.training.train_loop import TrainConfig, train


def train_ramps(model, batches: Callable[[int], Dict[str, np.ndarray]], *,
                steps: int = 150, lr: float = 1e-3, state=None, verbose=True):
    """Train only ramp parameters on bootstrap data (10% split per paper §4)."""
    tcfg = TrainConfig(steps=steps, lr=lr, train_mode="ramps_only", log_every=max(steps // 5, 1))
    return train(model, batches, tcfg, state=state, verbose=verbose)
