from repro.training.optim import (
    AdamWConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)
from repro.training.ramp_training import train_ramps
from repro.training.train_loop import TrainConfig, init_state, make_train_step, ramp_mask, train
