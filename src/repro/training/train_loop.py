"""pjit training loop with remat, grad-accum, checkpoint/restart.

`TrainState` is a plain pytree (params + optimizer state + step); the
update step is a single jitted function whose in/out shardings come from
the model schema — the same function lowers on the 1-device test mesh and
the 512-chip production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import TEST_AXES, MeshAxes
from repro.training.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    lr: float = 3e-4
    warmup: int = 20
    weight_decay: float = 0.01
    grad_accum: int = 1
    remat: bool = False
    moe_impl: str = "dense"
    train_mode: str = "full"  # 'full' | 'ramps_only'
    log_every: int = 20
    checkpoint_every: int = 0  # 0 = off
    seed: int = 0


def ramp_mask(params) -> Any:
    """True only for ramp parameters (frozen-backbone ramp training).
    The paper freezes original weights so non-EE behavior is unchanged."""

    def walk(tree, under_ramp):
        if isinstance(tree, dict):
            return {k: walk(v, under_ramp or k == "ramps") for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = type(tree)
            return t(walk(v, under_ramp) for v in tree)
        return jnp.full(tree.shape, under_ramp, bool) if hasattr(tree, "shape") else under_ramp

    return walk(params, False)


def make_train_step(model, tcfg: TrainConfig, axes: MeshAxes = TEST_AXES, mesh=None,
                    opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig(lr=tcfg.lr, weight_decay=tcfg.weight_decay)
    sched = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.steps)

    def loss_fn(params, batch):
        return model.loss(
            params, batch, axes=axes, mesh=mesh, moe_impl=tcfg.moe_impl,
            remat=tcfg.remat, train_mode=tcfg.train_mode,
        ) if model.cfg.family == "lm" else model.loss(params, batch, axes=axes, mesh=mesh)

    def step_fn(state, batch):
        params, opt, step = state["params"], state["opt"], state["step"]
        if tcfg.grad_accum > 1:
            def micro(i, acc):
                g_acc, l_acc = acc
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // tcfg.grad_accum), x.shape[0] // tcfg.grad_accum, 0
                    ),
                    batch,
                )
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return jax.tree.map(jnp.add, g_acc, g), l_acc + l

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, loss = jax.lax.fori_loop(0, tcfg.grad_accum, micro, (zeros, 0.0))
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            loss = loss / tcfg.grad_accum
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        mask = ramp_mask(params) if tcfg.train_mode == "ramps_only" else None
        newp, newopt, gn = adamw_update(
            params, grads, opt, opt_cfg, lr_scale=sched(step), mask=mask
        )
        out = {"loss": loss, "grad_norm": gn, **metrics}
        return {"params": newp, "opt": newopt, "step": step + 1}, out

    return step_fn, opt_cfg


def init_state(model, key, opt_cfg: AdamWConfig):
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params, opt_cfg), "step": jnp.zeros((), jnp.int32)}


def train(
    model,
    batches: Callable[[int], Dict[str, np.ndarray]],
    tcfg: TrainConfig,
    *,
    state=None,
    checkpoint_mgr=None,
    start_step: int = 0,
    verbose: bool = True,
):
    """Simple driver used by examples/tests; production uses launch/train.py."""
    step_fn, opt_cfg = make_train_step(model, tcfg)
    jstep = jax.jit(step_fn)
    if state is None:
        state = init_state(model, jax.random.PRNGKey(tcfg.seed), opt_cfg)
    logs = []
    t0 = time.perf_counter()
    for s in range(start_step, tcfg.steps):
        batch = {k: jnp.asarray(v) for k, v in batches(s).items()}
        state, out = jstep(state, batch)
        if s % tcfg.log_every == 0 or s == tcfg.steps - 1:
            logs.append({k: float(v) for k, v in out.items()})
            if verbose:
                print(f"  step {s:5d} loss {logs[-1]['loss']:.4f} gnorm {logs[-1]['grad_norm']:.3f}")
        if checkpoint_mgr and tcfg.checkpoint_every and (s + 1) % tcfg.checkpoint_every == 0:
            checkpoint_mgr.save(state, step=s + 1)
    if verbose:
        dt = time.perf_counter() - t0
        print(f"  trained {tcfg.steps - start_step} steps in {dt:.1f}s")
    return state, logs
