"""``tier1-deps``: tier-1 tests import stdlib + numpy + jax + pytest + repro.

ROADMAP test-suite policy: the tier-1 suite must stay green with
"stdlib + numpy + jax + pytest only — no ``hypothesis``, no pytest
plugins". This rule applies to files under ``tests/`` and flags:

* imports whose top-level module is outside the allowed set;
* ``pytest_plugins = ...`` assignments (plugin loading by another name).
"""
from __future__ import annotations

import ast
import sys

from repro.analysis.lint import SourceFile
from repro.analysis.rules import register

ALLOWED_ROOTS = frozenset(sys.stdlib_module_names) | {"numpy", "jax", "pytest", "repro"}


@register
class Tier1DepsRule:
    id = "tier1-deps"
    doc = "tests/ imports restricted to stdlib+numpy+jax+pytest+repro (no hypothesis, no pytest plugins)"
    scope = "file"

    def check(self, file: SourceFile):
        if not file.in_tests:
            return
        for node in ast.walk(file.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    root = alias.name.split(".")[0]
                    if root not in ALLOWED_ROOTS:
                        yield file.finding(
                            self.id,
                            node,
                            f"tier-1 test imports {alias.name!r} — suite policy is "
                            "stdlib+numpy+jax+pytest+repro only",
                        )
            elif isinstance(node, ast.ImportFrom):
                if node.level:  # relative import stays inside tests/
                    continue
                root = (node.module or "").split(".")[0]
                if root and root not in ALLOWED_ROOTS:
                    yield file.finding(
                        self.id,
                        node,
                        f"tier-1 test imports from {node.module!r} — suite policy is "
                        "stdlib+numpy+jax+pytest+repro only",
                    )
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == "pytest_plugins":
                        yield file.finding(
                            self.id,
                            node,
                            "pytest_plugins loads a plugin — tier-1 forbids pytest plugins",
                        )
