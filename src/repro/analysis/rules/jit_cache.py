"""``jit-cache-hygiene``: jit call-sites that defeat or poison the trace cache.

``jax.jit``'s cache is keyed on the *function object* plus abstract
argument signatures. Patterns that silently recompile every call:

* ``jax.jit(lambda ...)`` — a fresh lambda object per evaluation of the
  enclosing expression, so the cache never hits;
* ``jax.jit(f)(x)`` / ``jax.jit(f).lower(...)`` — a fresh jitted wrapper
  built and immediately invoked, same effect;
* ``@jax.jit`` on a *nested* ``def`` — a new function object (and cache)
  per call of the enclosing function. Legitimate when the enclosing code
  memoizes the wrapper (the serving runners key them per cache-layout in
  ``self._fns``-style dicts) — annotate those sites with
  ``# repro: allow[jit-cache-hygiene]`` and a why-note.

Also flagged, because it raises ``TracerBoolConversionError`` at trace
time (or worse, silently bakes in a branch if the arg is weakly typed):

* ``if x:`` / ``while x:`` truthiness tests on a bare parameter of a
  jitted function when that parameter is not in ``static_argnames`` /
  ``static_argnums``.
"""
from __future__ import annotations

import ast
from typing import FrozenSet, Optional, Set, Tuple

from repro.analysis.lint import SourceFile, dotted_name
from repro.analysis.rules import register

_JIT_NAMES = ("jax.jit", "jit")
_PARTIAL_NAMES = ("partial", "functools.partial")


def _is_jit(node: ast.AST) -> bool:
    return dotted_name(node) in _JIT_NAMES


def _str_values(node: ast.AST) -> Set[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out: Set[str] = set()
        for elt in node.elts:
            out |= _str_values(elt)
        return out
    return set()


def _int_values(node: ast.AST) -> Set[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out: Set[int] = set()
        for elt in node.elts:
            out |= _int_values(elt)
        return out
    return set()


def _jit_decorator(dec: ast.AST) -> Tuple[bool, Optional[ast.Call]]:
    """(is_jit, the Call carrying static_arg* kwargs if any)."""
    if _is_jit(dec):
        return True, None
    if isinstance(dec, ast.Call):
        if _is_jit(dec.func):
            return True, dec
        if dotted_name(dec.func) in _PARTIAL_NAMES and dec.args and _is_jit(dec.args[0]):
            return True, dec
    return False, None


def _static_params(call: Optional[ast.Call], fndef: ast.FunctionDef) -> FrozenSet[str]:
    names: Set[str] = set()
    nums: Set[int] = set()
    if call is not None:
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                names |= _str_values(kw.value)
            elif kw.arg == "static_argnums":
                nums |= _int_values(kw.value)
    params = [a.arg for a in fndef.args.posonlyargs + fndef.args.args]
    for i in nums:
        if 0 <= i < len(params):
            names.add(params[i])
    return frozenset(names)


@register
class JitCacheRule:
    id = "jit-cache-hygiene"
    doc = (
        "no jax.jit(lambda)/jax.jit(f)(x) fresh-wrapper call-sites, no @jax.jit "
        "on nested defs (unless memoized + pragma'd), no truthiness branches on "
        "traced params"
    )
    scope = "file"

    def check(self, file: SourceFile):
        for node in ast.walk(file.tree):
            if isinstance(node, ast.Call):
                fn = dotted_name(node.func)
                if fn in _JIT_NAMES and node.args and isinstance(node.args[0], ast.Lambda):
                    yield file.finding(
                        self.id,
                        node,
                        "jax.jit(lambda ...) builds a fresh function object each "
                        "evaluation — the trace cache never hits; def + decorate "
                        "at module scope instead",
                    )
                elif isinstance(node.func, ast.Call) and _is_jit(node.func.func):
                    yield file.finding(
                        self.id,
                        node,
                        "jax.jit(f)(...) creates and invokes a throwaway jitted "
                        "wrapper — recompiles every call; bind the wrapper once",
                    )
            elif isinstance(node, ast.Attribute):
                if isinstance(node.value, ast.Call) and _is_jit(node.value.func):
                    yield file.finding(
                        self.id,
                        node,
                        f"jax.jit(f).{node.attr}(...) on a throwaway wrapper — "
                        "retraces from scratch; bind the jitted function once",
                    )

        # nested jitted defs + truthiness branches on traced params
        for outer in ast.walk(file.tree):
            if not isinstance(outer, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for inner in ast.walk(outer):
                if inner is outer or not isinstance(
                    inner, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    continue
                for dec in inner.decorator_list:
                    is_jit, _ = _jit_decorator(dec)
                    if is_jit:
                        yield file.finding(
                            self.id,
                            dec,
                            f"@jax.jit on nested def {inner.name!r} makes a new "
                            "function object (and jit cache) per enclosing call — "
                            "hoist to module scope, or memoize the wrapper and "
                            "annotate with # repro: allow[jit-cache-hygiene]",
                        )

        for fndef in ast.walk(file.tree):
            if not isinstance(fndef, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            jit_call = None
            jitted = False
            for dec in fndef.decorator_list:
                is_jit, call = _jit_decorator(dec)
                if is_jit:
                    jitted, jit_call = True, call
                    break
            if not jitted:
                continue
            traced = (
                frozenset(a.arg for a in fndef.args.posonlyargs + fndef.args.args)
                - _static_params(jit_call, fndef)
            )
            for node in ast.walk(fndef):
                if isinstance(node, (ast.If, ast.While)):
                    test = node.test
                    if isinstance(test, ast.Name) and test.id in traced:
                        yield file.finding(
                            self.id,
                            test,
                            f"truthiness branch on traced argument {test.id!r} inside "
                            f"jitted {fndef.name!r} — raises TracerBoolConversionError; "
                            "mark it static or branch with jnp.where/lax.cond",
                        )
