"""``host-sync``: no implicit device→host syncs in serving hot paths.

Every ``np.asarray(jax_array)`` / ``float(...)`` / ``int(...)`` /
``.item()`` / ``.block_until_ready()`` on a device value blocks the host
on the accelerator — exactly the per-token round-trip the sync-window
decode path (``DecodeRunner.step_multi``) exists to eliminate. A stray
conversion buried in a hot method silently reintroduces one sync per
step and the latency win evaporates without any test failing.

Scope: the serving hot-path methods (``step`` / ``step_multi`` /
``infer`` / ``start`` / ``prefill_begin`` / ``prefill_resume`` /
``_feed_prompt_token`` / ``swap_out`` / ``swap_in`` / ``_step``) in
files under ``src/repro/serving/``. Flagged:

* ``np.asarray(...)`` / ``numpy.asarray`` / ``np.array(...)`` /
  ``jax.device_get(...)`` — device buffers cross to host;
* ``int(f(...))`` / ``float(f(...))`` where the argument is itself a
  call (the classic ``int(lab[0])``-style scalar pull; ``int(x)`` on a
  plain host variable is not flagged);
* ``.item()`` / ``.block_until_ready()`` calls.

SANCTIONED syncs — the per-window record drain at the sync boundary,
prefill first-token reads, swap buffer gathers — carry
``# repro: allow[host-sync]`` pragmas with why-notes; everything else is
a bug. The rule is a tripwire for future edits, not a claim that zero
syncs exist.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import SourceFile, dotted_name
from repro.analysis.rules import register

HOT_METHODS = frozenset({
    "step", "step_multi", "infer", "start", "prefill_begin",
    "prefill_resume", "_feed_prompt_token", "swap_out", "swap_in", "_step",
})

_SYNC_CALLS = frozenset({
    "np.asarray", "numpy.asarray", "np.array", "numpy.array",
    "jax.device_get",
})
_SYNC_METHODS = frozenset({"item", "block_until_ready"})
_SCALAR_PULLS = frozenset({"int", "float"})


@register
class HostSyncRule:
    id = "host-sync"
    doc = (
        "no implicit device->host syncs (np.asarray/int()/float()/.item()/"
        ".block_until_ready()) in serving hot-path methods; sanctioned "
        "sync points carry pragmas"
    )
    scope = "file"

    def check(self, file: SourceFile):
        if not file.rel.startswith("src/repro/serving/"):
            return
        for fndef in ast.walk(file.tree):
            if not isinstance(fndef, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fndef.name not in HOT_METHODS:
                continue
            for node in ast.walk(fndef):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func)
                if name in _SYNC_CALLS:
                    yield file.finding(
                        self.id,
                        node,
                        f"{name}(...) in hot-path {fndef.name!r} blocks on the "
                        "device — batch the transfer at the sync boundary (or "
                        "pragma a sanctioned sync point)",
                    )
                elif (
                    name in _SCALAR_PULLS
                    and node.args
                    and isinstance(node.args[0], ast.Call)
                ):
                    yield file.finding(
                        self.id,
                        node,
                        f"{name}(...) on a computed value in hot-path "
                        f"{fndef.name!r} — a scalar pull is one full device "
                        "round-trip per call",
                    )
                elif (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in _SYNC_METHODS
                ):
                    yield file.finding(
                        self.id,
                        node,
                        f".{node.func.attr}() in hot-path {fndef.name!r} "
                        "synchronizes with the device",
                    )
