"""Rule registry for the AST invariant linter.

A rule is an object with:

* ``id`` — stable kebab-case identifier (used in findings and pragmas);
* ``doc`` — one-line contract statement (rendered in README / --list);
* ``scope`` — ``"file"`` (default; ``check(file)`` called per file) or
  ``"project"`` (``check_project(files, root)`` called once with every
  parsed file — for cross-file contracts like kernel/ref pairing);
* ``check`` / ``check_project`` — generators of ``Finding``s.

Register with the ``@register`` decorator; ``all_rules()`` returns one
instance of each in registration order.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Type

_REGISTRY: Dict[str, Type] = {}


def register(cls: Type) -> Type:
    rid = cls.id
    if rid in _REGISTRY:
        raise ValueError(f"duplicate rule id {rid!r}")
    _REGISTRY[rid] = cls
    return cls


def all_rules() -> List:
    # import for side effect: each module registers its rule class
    from repro.analysis.rules import (  # noqa: F401
        compat_shim,
        host_sync,
        jit_cache,
        kernel_pairing,
        no_wallclock,
        seeded_rng,
        tier1_deps,
    )

    return [cls() for cls in _REGISTRY.values()]


def rule_ids() -> List[str]:
    all_rules()
    return list(_REGISTRY)
