"""``kernel-pairing``: every Pallas kernel ships with a reference + a test.

The numerics workflow (ROADMAP: "kernel-vs-ref equivalence") requires each
``src/repro/kernels/<name>/kernel.py`` to have:

* a ``ref.py`` sibling — the pure-jnp oracle the kernel is checked against;
* at least one ``tests/`` file whose imports reach **both** modules
  (directly, or through the kernel package's ``__init__`` when that
  ``__init__`` re-exports them).

This is a project-scope rule: it sees every parsed file at once.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Set

from repro.analysis.lint import Finding, SourceFile
from repro.analysis.rules import register

KERNELS_REL = "src/repro/kernels"


def _imported_modules(file: SourceFile) -> Set[str]:
    """Absolute module names a file imports (best-effort, for reachability)."""
    mods: Set[str] = set()
    for node in ast.walk(file.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                mods.add(alias.name)
        elif isinstance(node, ast.ImportFrom) and not node.level and node.module:
            mods.add(node.module)
            for alias in node.names:
                mods.add(f"{node.module}.{alias.name}")
    return mods


def _init_reexports(init: SourceFile, leaf: str) -> bool:
    """Does the package __init__ import its ``.<leaf>`` submodule?"""
    pkg = Path(init.rel).parent.as_posix().replace("src/", "", 1).replace("/", ".")
    for node in ast.walk(init.tree):
        if isinstance(node, ast.ImportFrom):
            if node.level == 1 and (node.module or "").split(".")[0] in ("", leaf):
                if node.module and node.module.split(".")[0] == leaf:
                    return True
                if not node.module and any(a.name == leaf for a in node.names):
                    return True
            elif not node.level and node.module:
                if node.module == f"{pkg}.{leaf}" or (
                    node.module == pkg and any(a.name == leaf for a in node.names)
                ):
                    return True
        elif isinstance(node, ast.Import):
            if any(a.name == f"{pkg}.{leaf}" for a in node.names):
                return True
    return False


@register
class KernelPairingRule:
    id = "kernel-pairing"
    doc = (
        "every kernels/<name>/kernel.py has a ref.py sibling and a test "
        "importing both"
    )
    scope = "project"

    def check_project(self, files: List[SourceFile], root: Path) -> Iterable[Finding]:
        by_rel = {f.rel: f for f in files}
        kernel_files = [f for f in files if f.rel.startswith(KERNELS_REL + "/") and f.rel.endswith("/kernel.py")]
        test_imports = {f.rel: _imported_modules(f) for f in files if f.in_tests}

        for kf in kernel_files:
            pkg_rel = Path(kf.rel).parent.as_posix()  # src/repro/kernels/<name>
            name = Path(pkg_rel).name
            pkg_mod = f"repro.kernels.{name}"

            ref_rel = f"{pkg_rel}/ref.py"
            if ref_rel not in by_rel and not (root / ref_rel).is_file():
                yield Finding(
                    self.id,
                    kf.rel,
                    1,
                    0,
                    f"kernel package {name!r} has no ref.py oracle sibling",
                )
                continue

            init = by_rel.get(f"{pkg_rel}/__init__.py")
            reach: dict = {}
            for leaf in ("kernel", "ref"):
                mods = {f"{pkg_mod}.{leaf}"}
                if init is not None and _init_reexports(init, leaf):
                    mods.add(pkg_mod)
                reach[leaf] = mods

            paired = any(
                (imps & reach["kernel"]) and (imps & reach["ref"])
                for imps in test_imports.values()
            )
            if not paired:
                yield Finding(
                    self.id,
                    kf.rel,
                    1,
                    0,
                    f"no tests/ file imports both {pkg_mod}.kernel and "
                    f"{pkg_mod}.ref (directly or via the package __init__) — "
                    "add an equivalence test",
                )
