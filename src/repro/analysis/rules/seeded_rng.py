"""``seeded-rng``: all randomness flows through explicitly-seeded generators.

The repo's tests and data pipelines must be reproducible run-to-run, so:

* ``np.random.seed(...)`` is banned — it mutates the legacy *global*
  generator, and ordering between tests then changes results;
* legacy global draws (``np.random.randn``, ``np.random.uniform``,
  ``np.random.permutation``, ...) are banned for the same reason;
* ``default_rng()`` with no seed argument is banned — it seeds from OS
  entropy, which is exactly the nondeterminism the policy exists to stop.

The sanctioned idiom is ``np.random.default_rng(<seed>)`` (or an explicit
``Generator``/``SeedSequence``/``Philox`` etc. construction with a seed)
threaded through the code, and ``jax.random.key``/``PRNGKey`` on the JAX
side (always seeded by construction, so never flagged).
"""
from __future__ import annotations

import ast

from repro.analysis.lint import SourceFile, dotted_name
from repro.analysis.rules import register

# np.random attributes that are NOT legacy-global-state draws.
_SANCTIONED_ATTRS = frozenset(
    {
        "default_rng",
        "Generator",
        "SeedSequence",
        "BitGenerator",
        "PCG64",
        "PCG64DXSM",
        "Philox",
        "SFC64",
        "MT19937",
        "RandomState",  # explicit instance construction carries its own seed arg
    }
)

_NP_ROOTS = ("np.random", "numpy.random")


def _np_random_attr(name: str):
    """('np.random', attr) if name is a np.random.<attr> chain, else None."""
    for root in _NP_ROOTS:
        prefix = root + "."
        if name.startswith(prefix):
            rest = name[len(prefix) :]
            if rest and "." not in rest:
                return rest
    return None


@register
class SeededRngRule:
    id = "seeded-rng"
    doc = (
        "no np.random.seed / legacy global np.random draws / unseeded "
        "default_rng() — thread explicitly-seeded generators"
    )
    scope = "file"

    def check(self, file: SourceFile):
        # Track names bound by `from numpy.random import default_rng [as d]`.
        local_default_rng = set()
        for node in ast.walk(file.tree):
            if isinstance(node, ast.ImportFrom) and (node.module or "") in (
                "numpy.random",
                "numpy.random._generator",
            ):
                for alias in node.names:
                    if alias.name == "default_rng":
                        local_default_rng.add(alias.asname or alias.name)

        for node in ast.walk(file.tree):
            if not isinstance(node, (ast.Call, ast.Attribute)):
                continue
            if isinstance(node, ast.Call):
                name = dotted_name(node.func) or ""
                attr = _np_random_attr(name)
                bare = name in local_default_rng
                if attr == "seed":
                    yield file.finding(
                        self.id,
                        node,
                        "np.random.seed mutates the legacy global generator — "
                        "use np.random.default_rng(seed) and thread it through",
                    )
                elif (attr == "default_rng" or bare) and not node.args and not node.keywords:
                    yield file.finding(
                        self.id,
                        node,
                        "default_rng() without a seed draws OS entropy — pass an "
                        "explicit seed",
                    )
                elif attr is not None and attr not in _SANCTIONED_ATTRS and attr != "seed":
                    yield file.finding(
                        self.id,
                        node,
                        f"np.random.{attr} draws from the legacy global generator — "
                        "use a seeded np.random.default_rng(...) instance",
                    )
