"""``compat-shim``: JAX version sniffing belongs in ``repro.compat``.

ROADMAP test-suite policy: "JAX-version differences ... are absorbed in
``repro.compat`` / ``repro.launch.mesh.make_mesh`` — never inline
``hasattr`` checks at call sites." This rule flags, everywhere else:

* ``hasattr``/``getattr`` probes whose object is rooted at ``jax`` /
  ``jaxlib`` (``hasattr(jax, "shard_map")``, ``hasattr(jax.sharding, ...)``);
* ``jax.__version__`` / ``jaxlib.__version__`` reads;
* ``hasattr(<obj>, "<sentinel>")`` where the probed attribute is a known
  cross-version API sentinel (``shard_map``, ``AxisType``, ``check_vma``,
  ``check_rep``, and ``get`` — the old-vs-new ``Mesh.shape`` mapping probe
  that ``moe.py`` once inlined).

Duck-typing probes like ``hasattr(x, "shape")`` or capability checks on
repo objects (``hasattr(runner, "swap_out")``) are NOT flagged.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import SourceFile, dotted_name
from repro.analysis.rules import register

# Attribute names whose presence differs across the JAX versions the repo
# supports; probing for them outside repro.compat is version sniffing even
# when the probed object isn't literally the `jax` module (e.g. Mesh.shape).
VERSION_SENTINELS = frozenset({"shard_map", "AxisType", "check_vma", "check_rep", "get"})


def _root(node: ast.AST) -> str:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


@register
class CompatShimRule:
    id = "compat-shim"
    doc = (
        "JAX version probes (hasattr(jax, ...), jax.__version__, Mesh.shape "
        "API sniffing) only in repro/compat.py and launch/mesh.py"
    )
    scope = "file"

    def check(self, file: SourceFile):
        for node in ast.walk(file.tree):
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name in ("hasattr", "getattr") and node.args:
                    obj = node.args[0]
                    root = _root(obj)
                    probe = (
                        node.args[1].value
                        if len(node.args) > 1
                        and isinstance(node.args[1], ast.Constant)
                        and isinstance(node.args[1].value, str)
                        else None
                    )
                    if root in ("jax", "jaxlib"):
                        yield file.finding(
                            self.id,
                            node,
                            f"{name}() probe on {dotted_name(obj) or root!s} — "
                            "route JAX version differences through repro.compat",
                        )
                    elif name == "hasattr" and probe in VERSION_SENTINELS:
                        yield file.finding(
                            self.id,
                            node,
                            f"hasattr(..., {probe!r}) sniffs a cross-version JAX "
                            "API — add a helper to repro.compat instead",
                        )
            elif isinstance(node, ast.Attribute) and node.attr == "__version__":
                if _root(node.value) in ("jax", "jaxlib"):
                    yield file.finding(
                        self.id,
                        node,
                        "jax.__version__ read — version branches live in repro.compat",
                    )
