"""``no-wallclock``: discrete-event code never reads the host clock.

The serving simulator and schedulers advance a single logical clock; a
``time.time()`` buried in a queue-depth heuristic silently couples results
to host load and breaks replayability (ROADMAP: "single-clock invariant").
The trace-driven harness replays identically only if every timestamp comes
from the event loop.

Flagged everywhere under ``src/`` and ``tests/``:

* ``time.time`` / ``time.time_ns`` / ``time.monotonic`` / ``time.monotonic_ns``
  (call or bare reference, including ``from time import time``);

additionally, under ``src/repro/serving/`` only:

* ``time.perf_counter`` / ``perf_counter_ns`` — legal for wall-clock
  *measurement* in training/launch utilities, but never as an input to
  serving decisions.

Genuine profiling call-sites outside serving (e.g. ``launch/dryrun.py``
compile-time measurement) carry ``# repro: allow[no-wallclock]`` pragmas.
"""
from __future__ import annotations

import ast

from repro.analysis.lint import SourceFile, dotted_name
from repro.analysis.rules import register

_BANNED = frozenset({"time", "time_ns", "monotonic", "monotonic_ns"})
_SERVING_ONLY = frozenset({"perf_counter", "perf_counter_ns"})


@register
class NoWallclockRule:
    id = "no-wallclock"
    doc = (
        "no time.time/monotonic anywhere (single logical clock); "
        "perf_counter additionally banned under serving/"
    )
    scope = "file"

    def check(self, file: SourceFile):
        in_serving = file.rel.startswith("src/repro/serving/")
        banned = _BANNED | _SERVING_ONLY if in_serving else _BANNED

        imported = {}  # local name -> time.<fn> it aliases
        for node in ast.walk(file.tree):
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in banned:
                        imported[alias.asname or alias.name] = alias.name
                        yield file.finding(
                            self.id,
                            node,
                            f"from time import {alias.name} — wall-clock reads break "
                            "the single-logical-clock invariant",
                        )

        for node in ast.walk(file.tree):
            if isinstance(node, ast.Attribute):
                name = dotted_name(node) or ""
                if name.startswith("time.") and name[len("time.") :] in banned:
                    yield file.finding(
                        self.id,
                        node,
                        f"{name} reads the host clock — use the event-loop clock "
                        "(sim time) instead",
                    )
            elif isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                if node.func.id in imported:
                    yield file.finding(
                        self.id,
                        node,
                        f"{node.func.id}() aliases time.{imported[node.func.id]} — "
                        "wall-clock read in discrete-event code",
                    )
